"""Hot-spot microbench: the fused kernel matvec (chunked-XLA execution path)
and the Pallas kernel's arithmetic-intensity analysis for the TPU target —
both swept over the precision policy (f32 vs bf16 tiles, f32 accumulation).

Wall-clock is CPU (execution backend); the Pallas-tile roofline numbers are
derived analytically from the BlockSpec tiling (docs/architecture.md) since
the TPU is the target, not the runtime.  The tile analysis is parameterized
by the tile dtype: bf16 halves the A/B/V bytes per tile (the f32 accumulator
row stays 4 bytes) AND doubles the MXU rate, so its roofline ridge sits at
the full ``PEAK_FLOPS_BF16``; both dtypes report attainable throughput as a
fraction of that bf16 peak so the two rows are directly comparable.

``BENCH_KERNELS_SMOKE=1`` shrinks the wall-clock sweep for CI smoke runs
(same shape of output, small-n inputs)."""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, note, timeit, write_results


def tile_roofline(d: int, bm: int = 256, bn: int = 256):
    """Analytic per-tile roofline rows for the Pallas matvec, one per dtype.

    Returns a list of (precision, flops_per_byte, bound, frac_peak_bf16)
    tuples.  Per tile: the distance matmul (2*d MACs per element), the kernel
    map + matvec epilogue (~8 flops per element), bm*d + bn*d + bn input
    elements at the tile dtype's width and a bm-element f32 accumulator row.
    """
    from repro.roofline import hw

    tile_flops = bm * bn * (2 * d + 8)  # dist matmul + kernel map + mv
    rows = []
    for precision, nbytes, peak in (
        ("f32", 4, hw.PEAK_FLOPS_F32),
        ("bf16", 2, hw.PEAK_FLOPS_BF16),
    ):
        tile_bytes = (bm * d + bn * d + bn) * nbytes + bm * 4
        intensity = tile_flops / tile_bytes
        ridge = peak / hw.HBM_BW
        bound = "compute" if intensity > ridge else "memory"
        attainable = min(peak, intensity * hw.HBM_BW)
        rows.append((precision, intensity, bound, attainable / hw.PEAK_FLOPS_BF16))
    return rows


def main() -> None:
    import jax

    from repro.kernels import ops
    from repro.obs import diff, snapshot
    from repro.obs.metrics import record_tile_work

    smoke = os.environ.get("BENCH_KERNELS_SMOKE") == "1"
    sizes = ((20_000, 500),) if smoke else ((100_000, 1000), (400_000, 4000))
    iters = 2 if smoke else 3

    snap0 = snapshot()
    matvec_rows = []
    r = np.random.default_rng(0)
    d = 9
    for n, b in sizes:
        a = r.standard_normal((b, d)).astype(np.float32)
        x = r.standard_normal((n, d)).astype(np.float32)
        v = r.standard_normal((n,)).astype(np.float32)

        for precision in ("f32", "bf16"):

            def run(a=a, x=x, v=v, precision=precision):
                jax.block_until_ready(
                    ops.kernel_matvec(
                        a, x, v, kernel="rbf", sigma=1.0, backend="xla",
                        precision=precision,
                    )
                )

            us = timeit(run, iters=iters)
            record_tile_work(b, n, d, precision, count=iters)
            flops = b * n * (3 * d + 2)
            emit(f"kernel_matvec_n{n}_b{b}_{precision}", us,
                 f"gflops_cpu={flops/us/1e3:.2f}")
            matvec_rows.append({"n": n, "b": b, "precision": precision,
                                "us": us, "gflops_cpu": flops / us / 1e3})

    # Pallas tile analysis (bm=bn=256): MXU work vs VMEM traffic, per dtype.
    # bf16 tiles halve the bytes AND double the MXU rate — the two rows per d
    # show how much of the bf16 hardware peak each policy can reach.
    tile_rows = []
    for dd in (9, 64, 256):
        for precision, intensity, bound, frac in tile_roofline(dd):
            note(
                f"pallas tile d={dd} {precision}: {intensity:.0f} flop/B "
                f"-> {bound}-bound, {frac:.1%} of bf16 peak"
            )
            emit(
                f"pallas_tile_intensity_d{dd}_{precision}", 0.0,
                f"flops_per_byte={intensity:.1f};bound={bound};"
                f"frac_peak_bf16={frac:.3f}",
            )
            tile_rows.append({"d": dd, "precision": precision,
                              "flops_per_byte": intensity, "bound": bound,
                              "frac_peak_bf16": frac})

    write_results("kernels", {
        "smoke": smoke,
        "matvec": matvec_rows,
        "pallas_tiles": tile_rows,
        # per-dtype FLOP/byte tallies from the metrics registry — the same
        # counters the solvers bump via record_tile_work
        "telemetry_delta": diff(snap0, snapshot()),
    })


if __name__ == "__main__":
    main()
