"""Hot-spot microbench: the fused kernel matvec (chunked-XLA execution path)
and the Pallas kernel's arithmetic-intensity analysis for the TPU target.

Wall-clock is CPU (execution backend); the Pallas-tile roofline numbers are
derived analytically from the BlockSpec tiling (docs/architecture.md) since the TPU
is the target, not the runtime."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, note, timeit


def main() -> None:
    import jax

    from repro.kernels import ops
    from repro.roofline import hw

    r = np.random.default_rng(0)
    d = 9
    for n, b in ((100_000, 1000), (400_000, 4000)):
        a = r.standard_normal((b, d)).astype(np.float32)
        x = r.standard_normal((n, d)).astype(np.float32)
        v = r.standard_normal((n,)).astype(np.float32)

        def run(a=a, x=x, v=v):
            jax.block_until_ready(
                ops.kernel_matvec(a, x, v, kernel="rbf", sigma=1.0, backend="xla")
            )

        us = timeit(run, iters=3)
        flops = b * n * (3 * d + 2)
        emit(f"kernel_matvec_n{n}_b{b}", us, f"gflops_cpu={flops/us/1e3:.2f}")

    # Pallas tile analysis (bm=bn=256, f32): MXU work vs VMEM traffic
    bm = bn = 256
    for dd in (9, 64, 256):
        tile_flops = bm * bn * (2 * dd + 8)  # dist matmul + kernel map + mv
        tile_bytes = (bm * dd + bn * dd + bn + bm) * 4
        intensity = tile_flops / tile_bytes
        ridge = hw.PEAK_FLOPS_BF16 / hw.HBM_BW  # ~240 flops/byte
        bound = "compute" if intensity > ridge else "memory"
        note(f"pallas tile d={dd}: {intensity:.0f} flop/B (ridge {ridge:.0f}) -> {bound}-bound")
        emit(f"pallas_tile_intensity_d{dd}", 0.0,
             f"flops_per_byte={intensity:.1f};bound={bound}")


if __name__ == "__main__":
    main()
