"""Multi-kernel weight search: shared stacked engine vs the naive loop.

The acceptance claim (ISSUE 4 / docs/tuning.md "Multi-kernel sweeps"): a
``tune_multikernel`` search over q = 3 kernels, M = 8 Dirichlet weight
samples, l = 4 lambdas and k = 5 folds performs at most **1.5x the kernel
sweeps of a single-candidate solve per sigma** — every (w, lam, fold)
candidate is one more column of the same blocked-CG, and the fused
multi-kernel tiles make a q-kernel matvec cost ONE data sweep.  The naive
loop pays one Nystrom-PCG solve per (weight, lam, fold) candidate.

Emits:

    multikernel_shared  — the stacked path; derived: sweeps + per-sigma budget
    multikernel_naive   — per-candidate loop; derived: sweeps + ratio
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, note, timeit, write_results

KERNELS = ("rbf", "laplacian", "matern52")
M_WEIGHTS, L_LAMS, K_FOLDS = 8, 4, 5


def main() -> None:
    import jax.numpy as jnp

    from repro.core.krr import KRRProblem
    from repro.core.tune import tune_multikernel
    from repro.obs import diff, snapshot

    snap0 = snapshot()
    r = np.random.default_rng(0)
    n, d = 512, 6
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    # a target with one smooth and one rough component — a kernel mixture
    # genuinely helps, so the search is not degenerate
    y = jnp.sin(2.0 * x[:, 0]) + 0.3 * jnp.sign(jnp.sin(5.0 * x[:, 1]))
    prob = KRRProblem(x=x, y=y, backend="xla")
    kw = dict(
        kernels=KERNELS, sigmas=(1.0,), lams=tuple(np.geomspace(1e-4, 1e-1, L_LAMS)),
        folds=K_FOLDS, n_weight_samples=M_WEIGHTS, rank=64,
        max_iters=300, tol=1e-5, seed=0,
    )

    results = {}

    def run(strategy):
        results[strategy] = tune_multikernel(prob, strategy=strategy, **kw)

    us_shared = timeit(lambda: run("shared"), iters=1, warmup=1)
    us_naive = timeit(lambda: run("naive"), iters=1, warmup=0)
    rs, rn = results["shared"], results["naive"]
    if (rs.best["weights"] != rn.best["weights"]
            or rs.best["lam_unscaled"] != rn.best["lam_unscaled"]):
        raise RuntimeError(
            f"shared and naive multi-kernel sweeps disagree on the best "
            f"config: {rs.best} vs {rn.best}"
        )
    s = 1  # sigma groups
    iters = max(int(v) for v in rs.info["iters_by_sigma"].values())
    # a single-candidate solve per sigma = sketch + iters + scoring sweeps;
    # the acceptance bound is 1.5x that, PER SIGMA, for the WHOLE search
    single_candidate = iters + 2
    if rs.sweeps / s > 1.5 * single_candidate:
        raise RuntimeError(
            f"shared multi-kernel sweep consumed {rs.sweeps / s:.1f} sweeps "
            f"per sigma — above 1.5x a single-candidate solve "
            f"({single_candidate})"
        )
    budget = s * (iters + 3)  # sketch + warm start + iters + scoring
    if rs.sweeps > budget + 1e-6:
        raise RuntimeError(
            f"shared sweep consumed {rs.sweeps:.1f} sweeps, above the "
            f"~s-solves budget of {budget}"
        )
    emit("multikernel_shared", us_shared,
         f"sweeps={rs.sweeps:.1f}_per_sigma<=1.5x_single={1.5 * single_candidate:.0f}")
    emit("multikernel_naive", us_naive,
         f"sweeps={rn.sweeps:.1f}_ratio={rn.sweeps / rs.sweeps:.1f}x")
    note(f"q={len(KERNELS)} M={M_WEIGHTS} l={L_LAMS} k={K_FOLDS}: "
         f"{rs.info['candidates']} candidates share ONE stacked solve "
         f"({rs.sweeps:.1f} sweeps, {iters} CG iters) vs naive "
         f"{rn.sweeps:.1f} sweeps over {rs.info['candidates'] * K_FOLDS} "
         f"solves ({rn.sweeps / rs.sweeps:.1f}x more kernel work)")
    note(f"wall: shared {us_shared / 1e6:.1f} s vs naive {us_naive / 1e6:.1f} s")
    note("weight candidates are columns: a c-candidate search costs ~1 "
         "solve's kernel work per sigma — the multi-kernel acceptance claim")

    write_results("multikernel", {
        "n": n, "d": d, "kernels": list(KERNELS),
        "weight_samples": M_WEIGHTS, "lams": L_LAMS, "folds": K_FOLDS,
        "candidates": rs.info["candidates"],
        "shared": {"us": us_shared, "sweeps": float(rs.sweeps)},
        "naive": {"us": us_naive, "sweeps": float(rn.sweeps)},
        "sweep_ratio": float(rn.sweeps / rs.sweeps),
        "telemetry_delta": diff(snap0, snapshot()),
    })


if __name__ == "__main__":
    main()
