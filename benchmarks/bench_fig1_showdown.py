"""Fig. 1 / §6.1–6.2: ASkotch vs the field on a taxi-like large-n problem,
equal time budget, predictive RMSE reported.

CPU-scaled: n = 20k (the structure — full KRR beating inducing-points and
PCG under a fixed budget — is scale-free; the paper runs n = 1e8 on GPU)."""

from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import emit, note
from repro.core.krr import KRRProblem, evaluate
from repro.core.solver_api import solve as solve_any
from repro.data import synthetic


def main(n: int = 20_000, budget_s: float = 30.0) -> None:
    x, y = synthetic.taxi_like(0, n + 2000, 9)
    x_tr, y_tr, x_te, y_te = x[:n], y[:n], x[n:], y[n:]
    prob = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.0,
                      lam_unscaled=2e-7, backend="xla")
    runs = [
        ("askotch", dict(max_iters=10_000, eval_every=50, time_budget_s=budget_s)),
        ("skotch", dict(max_iters=10_000, eval_every=50, time_budget_s=budget_s)),
        ("pcg-nystrom", dict(rank=100, max_iters=10_000, time_budget_s=budget_s)),
        ("pcg-rpcholesky", dict(rank=50, max_iters=10_000, time_budget_s=budget_s)),
        ("falkon", dict(m=1000, max_iters=10_000, time_budget_s=budget_s)),
        ("eigenpro", dict(rank=100, subsample=1000, epochs=100,
                          time_budget_s=budget_s)),
    ]
    for method, kw in runs:
        t0 = time.perf_counter()
        out = solve_any(prob, method, **kw)
        dt = time.perf_counter() - t0
        m = evaluate(out.predict_fn(x_te), y_te)
        rel = float(prob.relative_residual(out.w)) if out.w.shape[0] == n else -1.0
        note(f"fig1 {method}: rmse={float(m.rmse):.2f} rel={rel:.2e} "
             f"iters={out.info.get('iters')} {dt:.1f}s")
        emit(f"fig1_{method}", dt * 1e6 / max(out.info.get("iters", 1), 1),
             f"test_rmse={float(m.rmse):.3f};rel_res={rel:.3e}")
    base = float(jnp.std(y_te))
    emit("fig1_const_baseline", 0.0, f"test_rmse={base:.3f}")


if __name__ == "__main__":
    main()
