"""Serving-engine throughput under open-loop traffic: coalesced vs naive.

The repo's first p50/p99/qps numbers.  A simulated heavy-traffic open loop —
Poisson arrivals, mixed request sizes, several registered models (single- and
multi-kernel) — is replayed twice against the SAME models and the SAME
arrival schedule:

  * **naive** — one-request-at-a-time serving: each request waits its turn
    and pays a full fused kernel pass of its own (the per-model
    ``make_krr_predict_fn`` closure, buckets pre-warmed).  Under load the
    queue grows without bound — this is the baseline every serving system
    must beat.
  * **coalesced** — the :class:`repro.serving.engine.ServingEngine` worker
    drains the queue under a ``max_wait_ms`` deadline and serves every
    queued request for a model with ONE fused bucket pass, so k co-arriving
    requests cost ~one kernel sweep instead of k.

The arrival rate is calibrated to ~``OVERLOAD``x the naive capacity (measured
mean per-request service time), so the naive loop saturates while the engine
keeps up — the qps ratio IS the coalescing win.  Emitted rows (open-loop
latency = completion minus SCHEDULED arrival, so queueing delay counts):

    serving_naive      — p50/p99 ms + qps, derived string
    serving_coalesced  — p50/p99 ms + qps + ratio + mean batch occupancy

Acceptance (full mode): coalesced qps >= 3x naive qps, and every coalesced
output is BITWISE-equal to the naive per-request result at f32.  Set
``BENCH_SERVING_SMOKE=1`` (the CI smoke does) to shrink the traffic and skip
the ratio enforcement (a loaded CI box can't promise scheduling fidelity)
while still checking structure + bitwise equality.  Results are appended to
``BENCH_SERVING.json`` via ``benchmarks.common.write_results``.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import emit, note, write_results

#: offered load as a multiple of measured naive (sequential) capacity —
#: high enough that the engine's own capacity, not the arrival tape, is
#: what the coalesced qps measures
OVERLOAD = 8.0
#: full-mode acceptance floor for coalesced/naive qps
MIN_RATIO = 3.0
#: mixed request sizes (rows per request) and their draw probabilities —
#: weighted toward the small interactive requests coalescing exists for,
#: with a bulk tail (mean ~3 rows).  Per-row kernel work is the part of a
#: request coalescing CANNOT amortize, so the mean request size sets the
#: achievable qps ratio ceiling.
SIZES = (1, 2, 4, 8, 16)
SIZE_P = (0.45, 0.25, 0.15, 0.10, 0.05)


def _make_models(smoke: bool, r: np.random.Generator):
    """Register several models: two RBF (different n/sigma) + one
    multi-kernel — the mixed fleet a registry is for."""
    d = 6
    n_small, n_big = (300, 500) if smoke else (700, 1_000)
    t = 4
    specs = {
        "rbf-small": (
            n_small,
            {"kernel": "rbf", "sigma": 1.0, "backend": "xla",
             "precision": "f32"},
        ),
        "rbf-big": (
            n_big,
            {"kernel": "rbf", "sigma": 2.0, "backend": "xla",
             "precision": "f32"},
        ),
        "multi": (
            n_small,
            {"kernel": ["rbf", "laplacian"], "sigma": 1.0,
             "weights": [0.7, 0.3], "backend": "xla", "precision": "f32"},
        ),
    }
    models = {}
    for name, (n, cfg) in specs.items():
        x = r.standard_normal((n, d)).astype(np.float32)
        w = r.standard_normal((n, t)).astype(np.float32)
        models[name] = (cfg, x, w)
    return d, models


def _schedule(n_requests: int, rate_qps: float, d: int, names: list[str],
              r: np.random.Generator):
    """Open-loop traffic tape: (arrival_s, model, (q, d) queries) triples —
    Poisson arrivals, mixed power-of-two-straddling request sizes, models
    drawn uniformly.  The SAME tape drives both serving modes."""
    sizes = np.array(SIZES)
    arrivals = np.cumsum(r.exponential(1.0 / rate_qps, size=n_requests))
    tape = []
    for i in range(n_requests):
        q = int(r.choice(sizes, p=SIZE_P))
        tape.append((
            float(arrivals[i]),
            names[int(r.integers(len(names)))],
            r.standard_normal((q, d)).astype(np.float32),
        ))
    return tape


def _percentiles(lat_ms: list[float]) -> tuple[float, float]:
    a = np.asarray(lat_ms)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _pace(t0: float, t_arr: float) -> None:
    """Hold the caller until scheduled time ``t_arr`` (relative to ``t0``):
    coarse sleep to within ~1 ms, then spin — ``time.sleep``'s wakeup
    granularity would otherwise cap the offered request rate."""
    while True:
        ahead = t_arr - (time.monotonic() - t0)
        if ahead <= 0:
            return
        if ahead > 0.002:
            time.sleep(ahead - 0.001)


def _run_naive(tape, predict_fns):
    """Sequential one-request-at-a-time replay of the tape; returns
    (outputs, latencies_ms, qps)."""
    outs, lat = [], []
    t0 = time.monotonic()
    for t_arr, name, xq in tape:
        _pace(t0, t_arr)
        out = predict_fns[name](xq)
        out.block_until_ready()
        done = time.monotonic() - t0
        outs.append(np.asarray(out))
        lat.append((done - t_arr) * 1e3)
    span = (time.monotonic() - t0) - tape[0][0]
    return outs, lat, len(tape) / span


def _run_coalesced(tape, engine):
    """Open-loop replay through the engine: a dispatcher thread submits at
    the scheduled arrival times, never waiting on results.  Per-request
    latency = dispatch delay behind schedule + the engine-stamped
    ``future.latency_ms``; qps spans first arrival to full drain."""
    futures: list = [None] * len(tape)
    submit_at: list = [0.0] * len(tape)
    t0 = time.monotonic()

    def dispatch():
        for i, (t_arr, name, xq) in enumerate(tape):
            _pace(t0, t_arr)
            submit_at[i] = time.monotonic() - t0
            futures[i] = engine.submit(name, xq)

    th = threading.Thread(target=dispatch)
    th.start()
    th.join()
    engine.drain()
    span = (time.monotonic() - t0) - tape[0][0]
    outs = [np.asarray(f.result()) for f in futures]
    lat = [
        (submit_at[i] - tape[i][0]) * 1e3 + futures[i].latency_ms
        for i in range(len(tape))
    ]
    return outs, lat, len(tape) / span


def main() -> None:
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine
    from repro.serving.krr_serve import make_krr_predict_fn_from_config

    smoke = os.environ.get("BENCH_SERVING_SMOKE", "") == "1"
    r = np.random.default_rng(0)
    d, models = _make_models(smoke, r)
    names = list(models)
    max_batch = 256 if smoke else 1024
    max_wait_ms = 3.0 if smoke else 5.0
    # the tape must span MANY max_wait windows for steady-state numbers;
    # the request count is fixed after rate calibration below
    duration_s = 0.25 if smoke else 1.0
    n_cap = 600 if smoke else 4_000

    # naive per-model closures warmed over EVERY tape request size, so both
    # modes serve steady-state compile-free traffic (pad/slice eager-op
    # executables included, not just the jit buckets)
    predict_fns = {}
    for name, (cfg, x, w) in models.items():
        fn = make_krr_predict_fn_from_config(cfg, x, w, max_batch=max_batch)
        for q in SIZES:
            fn(jnp.zeros((q, d), jnp.float32)).block_until_ready()
        predict_fns[name] = fn

    # calibrate offered load to ~OVERLOAD x the measured naive capacity:
    # a hot back-to-back loop over a size-mix probe tape, exactly how the
    # saturated naive replay will run
    probe_tape = [
        (names[i % len(names)],
         r.standard_normal((int(r.choice(SIZES, p=SIZE_P)), d))
         .astype(np.float32))
        for i in range(30)
    ]
    for name, xq in probe_tape:  # one warm lap, then the timed laps
        predict_fns[name](xq).block_until_ready()
    t0 = time.perf_counter()
    laps = 3
    for _ in range(laps):
        for name, xq in probe_tape:
            predict_fns[name](xq).block_until_ready()
    mean_service_s = (time.perf_counter() - t0) / (laps * len(probe_tape))
    rate_qps = OVERLOAD / mean_service_s
    n_requests = min(n_cap, max(100, int(rate_qps * duration_s)))
    note(f"mean naive service {mean_service_s * 1e3:.2f} ms -> offered load "
         f"{rate_qps:.0f} rps ({OVERLOAD}x naive capacity), "
         f"{n_requests} requests over {len(names)} models")

    tape = _schedule(n_requests, rate_qps, d, names, r)

    naive_outs, naive_lat, naive_qps = _run_naive(tape, predict_fns)
    p50_n, p99_n = _percentiles(naive_lat)
    emit("serving_naive", p50_n * 1e3,
         f"p50={p50_n:.1f}ms_p99={p99_n:.1f}ms_qps={naive_qps:.0f}")

    engine = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms)
    try:
        for name, (cfg, x, w) in models.items():
            engine.register(name, cfg, x, w)
        co_outs, co_lat, co_qps = _run_coalesced(tape, engine)
        stats = engine.stats()
    finally:
        engine.shutdown()

    # bitwise identity: coalescing must change throughput, never values
    mismatch = sum(
        not np.array_equal(a, b) for a, b in zip(naive_outs, co_outs)
    )
    if mismatch:
        raise RuntimeError(
            f"{mismatch}/{len(tape)} coalesced outputs differ from the "
            f"naive per-request results (f32 must be bitwise-equal)"
        )

    p50_c, p99_c = _percentiles(co_lat)
    ratio = co_qps / naive_qps
    occ = [
        (b, o["rows"] / max(o["runs"], 1))
        for m in stats["models"].values()
        for b, o in m["occupancy"].items()
    ]
    mean_rows = (sum(rows for _, rows in occ) / len(occ)) if occ else 0.0
    emit("serving_coalesced", p50_c * 1e3,
         f"p50={p50_c:.1f}ms_p99={p99_c:.1f}ms_qps={co_qps:.0f}_"
         f"ratio={ratio:.1f}x_meanbatchrows={mean_rows:.1f}_bitwise_equal")
    note(f"naive:     p50 {p50_n:8.1f} ms  p99 {p99_n:8.1f} ms  "
         f"qps {naive_qps:7.0f}")
    note(f"coalesced: p50 {p50_c:8.1f} ms  p99 {p99_c:8.1f} ms  "
         f"qps {co_qps:7.0f}  ({ratio:.1f}x)")
    for name in names:
        m = stats["models"][name]
        note(f"  {name}: {m['n_requests']} reqs, compile-cache depth "
             f"{m['compile_cache_depth']}, occupancy {m['occupancy']}")

    write_results("serving", {
        "smoke": smoke,
        "n_requests": n_requests,
        "models": len(names),
        "offered_rps": rate_qps,
        "naive": {"p50_ms": p50_n, "p99_ms": p99_n, "qps": naive_qps},
        "coalesced": {"p50_ms": p50_c, "p99_ms": p99_c, "qps": co_qps},
        "qps_ratio": ratio,
        "bitwise_equal": True,
        "mean_batch_rows": mean_rows,
    })

    if not smoke and ratio < MIN_RATIO:
        raise RuntimeError(
            f"coalesced serving reached only {ratio:.2f}x the naive qps "
            f"({co_qps:.0f} vs {naive_qps:.0f}); the acceptance floor is "
            f"{MIN_RATIO}x"
        )
    if smoke:
        note(f"BENCH_SERVING_SMOKE=1: ratio {ratio:.2f}x reported, "
             f">= {MIN_RATIO}x floor only enforced in full mode")


if __name__ == "__main__":
    main()
