"""Shared benchmark helpers.  Every bench prints ``name,us_per_call,derived``
CSV rows (harness contract) plus human-readable context on stderr."""

from __future__ import annotations

import sys
import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def note(msg: str) -> None:
    print(msg, file=sys.stderr, flush=True)


def timeit(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
