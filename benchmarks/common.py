"""Shared benchmark helpers.  Every bench prints ``name,us_per_call,derived``
CSV rows (harness contract) plus human-readable context on stderr;
``write_results`` additionally appends a machine-readable record to a
``BENCH_<NAME>.json`` trajectory file so perf numbers accumulate across
runs/commits instead of scrolling away in CI logs."""

from __future__ import annotations

import json
import os
import sys
import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def write_results(bench: str, record: dict, path: str | None = None) -> str:
    """Append ``record`` to the ``BENCH_<BENCH>.json`` trajectory file.

    The file holds a JSON LIST of run records (appended read-modify-write;
    a fresh file starts the list), each stamped with a UTC timestamp and the
    smoke flag, so ``BENCH_SERVING.json`` etc. accumulate a machine-readable
    perf trajectory.  ``path`` overrides the default location (the repo root
    when run as ``python -m benchmarks.run``).  Returns the path written.
    """
    fname = path or f"BENCH_{bench.upper()}.json"
    runs: list = []
    if os.path.exists(fname):
        try:
            with open(fname) as fh:
                runs = json.load(fh)
            if not isinstance(runs, list):
                runs = [runs]
        except (OSError, ValueError):
            runs = []
    runs.append({
        "bench": bench,
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        **record,
    })
    with open(fname, "w") as fh:
        json.dump(runs, fh, indent=2, default=float)
        fh.write("\n")
    note(f"wrote {fname} ({len(runs)} run record(s))")
    return fname


def note(msg: str) -> None:
    print(msg, file=sys.stderr, flush=True)


def timeit(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
