"""Multi-RHS (one-vs-all) scaling: one batched (n, t) ASkotch solve vs t
sequential single-RHS solves.

The batched solve performs the kernel-tile work of a single solve per
iteration (the O(n b d) fused matvec is shared by all t heads), so wall-time
must scale sublinearly in t while the sequential baseline scales ~linearly.

Both sides run pre-compiled jitted steps (compile absorbed in warmup; the
sequential baseline reuses ONE compiled single-RHS step for all t heads) so
the numbers measure per-iteration runtime work, not tracing.  Emits, per
t in {1, 8, 64}:

    multirhs_batched_t{t}    — batched (n, t) solve, `iters` iterations
    multirhs_sequential_t{t} — t independent (n,) solves, `iters` each
    derived: speedup = sequential / batched, and batched cost relative to t=1
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, note, timeit


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core import ASkotchConfig, KRRProblem
    from repro.core.askotch import init_state, make_step

    r = np.random.default_rng(0)
    n, d, iters = 2000, 8, 10
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    cfg = ASkotchConfig(block_size=128, rank=64, backend="xla")

    # one compiled single-RHS step serves every sequential head (same shapes)
    y1 = jnp.asarray(r.standard_normal((n,)).astype(np.float32))
    prob_1 = KRRProblem(x=x, y=y1, kernel="rbf", sigma=1.5,
                        lam_unscaled=1e-4, backend="xla")
    step_1 = jax.jit(make_step(prob_1, cfg))
    state0_1 = init_state(prob_1, 0)

    def run_n_iters(step, state0):
        s = state0
        for _ in range(iters):
            s, _ = step(s)
        jax.block_until_ready(s.w)

    base_us = None
    for t in (1, 8, 64):
        y_t = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
        prob_t = KRRProblem(x=x, y=y_t, kernel="rbf", sigma=1.5,
                            lam_unscaled=1e-4, backend="xla")
        step_t = jax.jit(make_step(prob_t, cfg))
        state0_t = init_state(prob_t, 0)

        def run_batched(step_t=step_t, state0_t=state0_t):
            run_n_iters(step_t, state0_t)

        def run_sequential(t=t):
            for _ in range(t):  # t heads, one head per compiled solve
                run_n_iters(step_1, state0_1)

        us_b = timeit(run_batched, iters=3)
        us_s = timeit(run_sequential, iters=1 if t == 64 else 3)
        base_us = us_b if base_us is None else base_us
        emit(f"multirhs_batched_t{t}", us_b,
             f"speedup_vs_sequential={us_s / us_b:.2f}x")
        emit(f"multirhs_sequential_t{t}", us_s,
             f"batched_cost_vs_t1={us_b / base_us:.2f}x")
        note(f"t={t}: batched {us_b/1e3:.1f} ms vs sequential {us_s/1e3:.1f} ms "
             f"({us_s/us_b:.1f}x); batched cost vs t=1: {us_b/base_us:.2f}x")

    note("sublinear scaling in t == the shared-kernel-tile claim holds")


if __name__ == "__main__":
    main()
