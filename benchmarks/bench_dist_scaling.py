"""Distributed scaling: sharded-operator matvec + ASkotch iteration +
tuning-sweep throughput vs. host-device count.

Each device count needs its own process (XLA_FLAGS must be set before the
first jax import), so this bench spawns one subprocess per point and
aggregates the timings.  Emits, per devices in {1, 2, 4, 8}:

    dist_matvec_dev{D}       — sharded k_lam_matvec, (n, t) RHS
    dist_askotch_dev{D}      — one fused distributed ASkotch iteration
    dist_tune_dev{D}         — a full tune(mesh=...) sweep (the tuning
                               column: wall + kernel sweeps per device count)
    derived: speedup vs. the 1-device run

On CPU the collectives are in-process memcpy, so this measures the sharding
overhead floor, not real scaling — the point is that the overhead stays flat
while per-device work shrinks (the dry-run roofline covers real meshes).
Device counts the host cannot force (or that time out) are skipped with a
note rather than failing the harness.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit, note

DEVICE_COUNTS = (1, 2, 4, 8)
N, D, T, ITERS = 2048, 8, 4, 10

_CHILD = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core.krr import KRRProblem
from repro.distributed.krr_dist import (DistKRRConfig, init_dist_state,
                                        make_dist_askotch_step)
from repro.distributed.meshes import make_solver_mesh
from repro.distributed.sharded_operator import ShardedKernelOperator

n, d, t, iters = {n}, {d}, {t}, {iters}
mesh = make_solver_mesh(({rows}, {model}))
r = np.random.default_rng(0)
x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
v = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
op = ShardedKernelOperator.bind(mesh, x, kernel="rbf", sigma=1.5, backend="xla")
v = jax.device_put(v, op.sharding(2))

def timeit(fn, reps=3):
    fn()  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6

mv_us = timeit(lambda: jax.block_until_ready(op.k_lam_matvec(v, 0.5)))

y = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
cfg = DistKRRConfig(n=n, d=d, sigma=1.5, lam_unscaled=1e-5, block_size=128,
                    rank=32, heads=t)
step, sh = make_dist_askotch_step(mesh, cfg)
jstep = jax.jit(step)
state = jax.device_put(init_dist_state(cfg), sh["state"])
xs = jax.device_put(x, sh["x"]); ys = jax.device_put(y, sh["y"])

def run_iters():
    s = state
    for _ in range(iters):
        s = jstep(s, xs, ys)
    jax.block_until_ready(s.w)

ask_us = timeit(run_iters) / iters

# the tuning column: one full tune(mesh=...) sweep through the stacked
# engine (sigma x lam x fold columns over the sharded operator)
from repro.core.solver_api import tune
prob = KRRProblem(x=x, y=y[:, 0], backend="xla")
tune_res = {{}}
def run_tune():
    tune_res["r"] = tune(prob, mesh=mesh, sigmas=(0.8, 1.5), lams=(1e-3, 1e-1),
                         folds=2, rank=32, max_iters=40, tol=1e-4, seed=0)
tune_us = timeit(run_tune, reps=1)
print(json.dumps({{"matvec_us": mv_us, "askotch_us": ask_us,
                   "tune_us": tune_us, "tune_sweeps": tune_res["r"].sweeps}}))
"""


def _run_point(devices: int) -> dict | None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    code = _CHILD.format(n=N, d=D, t=T, iters=ITERS, rows=devices, model=1)
    try:
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600, env=env,
        )
    except subprocess.TimeoutExpired:
        note(f"dist bench: {devices} devices timed out; skipped")
        return None
    if out.returncode != 0:
        err = (out.stderr.strip().splitlines() or ["?"])[-1]
        note(f"dist bench: {devices} devices failed; skipped ({err[:120]})")
        return None
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> None:
    note(f"distributed scaling: n={N} d={D} t={T}, rows-only meshes, "
         f"devices {DEVICE_COUNTS}")
    base: dict | None = None
    for devices in DEVICE_COUNTS:
        res = _run_point(devices)
        if res is None:
            continue
        if base is None:
            base = res
        for key, tag in (("matvec_us", "matvec"), ("askotch_us", "askotch")):
            speedup = base[key] / res[key] if base else 1.0
            emit(f"dist_{tag}_dev{devices}", res[key],
                 f"speedup_vs_1dev={speedup:.2f}")
        if "tune_us" in res:
            speedup = base["tune_us"] / res["tune_us"]
            emit(f"dist_tune_dev{devices}", res["tune_us"],
                 f"sweeps={res['tune_sweeps']:.1f}_speedup_vs_1dev={speedup:.2f}")


if __name__ == "__main__":
    main()
