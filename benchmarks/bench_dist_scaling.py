"""Distributed scaling: sharded-operator matvec + ASkotch iteration +
tuning-sweep throughput vs. host-device count, and the divide-and-conquer
accuracy/communication frontier.

Each device count needs its own process (XLA_FLAGS must be set before the
first jax import), so this bench spawns one subprocess per point and
aggregates the timings.  Emits, per devices in {1, 2, 4, 8}:

    dist_matvec_dev{D}       — sharded k_lam_matvec, (n, t) RHS
    dist_askotch_dev{D}      — one fused distributed ASkotch iteration
    dist_tune_dev{D}         — a full tune(mesh=...) sweep (the tuning
                               column: wall + kernel sweeps per device count)
    dc_dev{D}                — solve(method="dc", dc_shards=D) vs the
                               collective-heavy sharded PCG at the same
                               device count: wall speedup, the MEASURED
                               collective-dispatch counts of both paths
                               (repro_collective_dispatch_total — DC's is
                               ~zero, that is the point), and the test-RMSE
                               delta (the accuracy price of avoiding the
                               communication) — the frontier
    derived: speedup vs. the 1-device run

Every run appends the full machine-readable frontier record to
``BENCH_DIST.json`` via ``write_results``.

``BENCH_DIST_SMOKE=1`` shrinks the problem and the device sweep for CI:
structure (every column present) and DC k=1 parity with the plain solver
are ENFORCED (non-zero exit on violation); the frontier numbers are
reported but unenforced, since CPU "devices" share the same cores.

On CPU the collectives are in-process memcpy, so this measures the sharding
overhead floor, not real scaling — the point is that the overhead stays flat
while per-device work shrinks (the dry-run roofline covers real meshes).
Device counts the host cannot force (or that time out) are skipped with a
note rather than failing the harness.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit, note, write_results

SMOKE = os.environ.get("BENCH_DIST_SMOKE") == "1"
DEVICE_COUNTS = (1, 2) if SMOKE else (1, 2, 4, 8)
N, D, T, ITERS = (512, 6, 2, 5) if SMOKE else (2048, 8, 4, 10)

_CHILD = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core.krr import KRRProblem
from repro.distributed.krr_dist import (DistKRRConfig, init_dist_state,
                                        make_dist_askotch_step)
from repro.distributed.meshes import make_solver_mesh
from repro.distributed.sharded_operator import ShardedKernelOperator

n, d, t, iters = {n}, {d}, {t}, {iters}
mesh = make_solver_mesh(({rows}, {model}))
r = np.random.default_rng(0)
x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
v = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
op = ShardedKernelOperator.bind(mesh, x, kernel="rbf", sigma=1.5, backend="xla")
v = jax.device_put(v, op.sharding(2))

def timeit(fn, reps=3):
    fn()  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6

mv_us = timeit(lambda: jax.block_until_ready(op.k_lam_matvec(v, 0.5)))

y = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
cfg = DistKRRConfig(n=n, d=d, sigma=1.5, lam_unscaled=1e-5, block_size=128,
                    rank=32, heads=t)
step, sh = make_dist_askotch_step(mesh, cfg)
jstep = jax.jit(step)
state = jax.device_put(init_dist_state(cfg), sh["state"])
xs = jax.device_put(x, sh["x"]); ys = jax.device_put(y, sh["y"])

def run_iters():
    s = state
    for _ in range(iters):
        s = jstep(s, xs, ys)
    jax.block_until_ready(s.w)

ask_us = timeit(run_iters) / iters

# the tuning column: one full tune(mesh=...) sweep through the stacked
# engine (sigma x lam x fold columns over the sharded operator)
from repro.core.solver_api import tune
prob = KRRProblem(x=x, y=y[:, 0], backend="xla")
tune_res = {{}}
def run_tune():
    tune_res["r"] = tune(prob, mesh=mesh, sigmas=(0.8, 1.5), lams=(1e-3, 1e-1),
                         folds=2, rank=32, max_iters=40, tol=1e-4, seed=0)
tune_us = timeit(run_tune, reps=1)
print(json.dumps({{"matvec_us": mv_us, "askotch_us": ask_us,
                   "tune_us": tune_us, "tune_sweeps": tune_res["r"].sweeps}}))
"""

# the frontier child: sharded PCG (collective-heavy) vs solve(method="dc")
# (communication-avoiding) at the same device count, measuring wall, the
# collective-dispatch counter, and test RMSE for both paths
_DC_CHILD = """
import json, time
import jax, jax.numpy as jnp
from repro.core.krr import KRRProblem
from repro.core.solver_api import solve
from repro.data.synthetic import krr_regression
from repro.distributed.dc import collective_dispatch_delta
from repro.distributed.meshes import make_solver_mesh
from repro.obs import metrics as M

n, d, devices, check_parity = {n}, {d}, {rows}, {parity}
mesh = make_solver_mesh(({rows}, 1))
x, y, xt, yt = krr_regression(0, n, d, n_test=max(n // 4, 64))
prob = KRRProblem(x=x, y=y, sigma=1.5, lam_unscaled=1e-5, backend="xla")
kw = dict(rank=32, max_iters=60, tol=1e-5, seed=0)

def rmse(pred):
    return float(jnp.sqrt(jnp.mean((jnp.asarray(pred) - yt) ** 2)))

def measured(fn):
    before = M.snapshot()
    t0 = time.perf_counter()
    out = fn()
    wall = time.perf_counter() - t0
    return out, wall, collective_dispatch_delta(before, M.snapshot())

sh_out, sh_wall, sh_coll = measured(
    lambda: solve(prob, "pcg-nystrom", mesh=mesh, **kw))
dc_out, dc_wall, dc_coll = measured(
    lambda: solve(prob, "dc", dc_shards=devices, dc_method="pcg-nystrom",
                  mesh=mesh, **kw))
rec = {{
    "sharded_wall_s": sh_wall, "sharded_collectives": sh_coll,
    "sharded_rmse": rmse(sh_out.predict_fn(xt)),
    "dc_wall_s": dc_wall, "dc_collectives": dc_coll,
    "dc_rmse": rmse(dc_out.predict_fn(xt)),
    "dc_iters": dc_out.info["per_shard_iters"],
}}
if check_parity:
    plain = solve(prob, "pcg-nystrom", **kw)
    dc1 = solve(prob, "dc", dc_shards=1, dc_method="pcg-nystrom", **kw)
    rec["k1_parity"] = bool(jnp.array_equal(plain.w, dc1.w))
print(json.dumps(rec))
"""


def _spawn(code: str, devices: int, tag: str) -> dict | None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    try:
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600, env=env,
        )
    except subprocess.TimeoutExpired:
        note(f"dist bench: {tag} at {devices} devices timed out; skipped")
        return None
    if out.returncode != 0:
        err = (out.stderr.strip().splitlines() or ["?"])[-1]
        note(f"dist bench: {tag} at {devices} devices failed; skipped "
             f"({err[:120]})")
        return None
    return json.loads(out.stdout.strip().splitlines()[-1])


def _run_point(devices: int) -> dict | None:
    code = _CHILD.format(n=N, d=D, t=T, iters=ITERS, rows=devices, model=1)
    return _spawn(code, devices, "sharded")


def _run_dc_point(devices: int) -> dict | None:
    code = _DC_CHILD.format(n=N, d=D, rows=devices,
                            parity=(devices == DEVICE_COUNTS[0]))
    return _spawn(code, devices, "dc")


def main() -> None:
    note(f"distributed scaling: n={N} d={D} t={T}, rows-only meshes, "
         f"devices {DEVICE_COUNTS}" + (" [smoke]" if SMOKE else ""))
    base: dict | None = None
    record: dict = {"smoke": SMOKE, "n": N, "d": D, "t": T,
                    "device_counts": list(DEVICE_COUNTS), "points": {}}
    for devices in DEVICE_COUNTS:
        res = _run_point(devices)
        if res is None:
            continue
        if base is None:
            base = res
        record["points"].setdefault(str(devices), {}).update(res)
        for key, tag in (("matvec_us", "matvec"), ("askotch_us", "askotch")):
            speedup = base[key] / res[key] if base else 1.0
            emit(f"dist_{tag}_dev{devices}", res[key],
                 f"speedup_vs_1dev={speedup:.2f}")
        if "tune_us" in res:
            speedup = base["tune_us"] / res["tune_us"]
            emit(f"dist_tune_dev{devices}", res["tune_us"],
                 f"sweeps={res['tune_sweeps']:.1f}_speedup_vs_1dev={speedup:.2f}")

    # the accuracy/communication frontier: DC vs sharded, same device count
    for devices in DEVICE_COUNTS:
        res = _run_dc_point(devices)
        if res is None:
            continue
        record["points"].setdefault(str(devices), {}).update(res)
        speedup = res["sharded_wall_s"] / res["dc_wall_s"]
        emit(
            f"dc_dev{devices}", res["dc_wall_s"] * 1e6,
            f"collectives={res['dc_collectives']:.0f}"
            f"_vs_sharded={res['sharded_collectives']:.0f}"
            f"_speedup_vs_sharded={speedup:.2f}"
            f"_rmse_delta={res['dc_rmse'] - res['sharded_rmse']:+.4f}",
        )
        if "k1_parity" in res and not res["k1_parity"]:
            raise SystemExit(
                "dc bench: k=1 DC solve is NOT bit-identical to the plain "
                "solver — the degeneracy contract is broken"
            )
    dc_points = [p for p in record["points"].values() if "dc_wall_s" in p]
    if SMOKE and not dc_points:
        raise SystemExit("dc bench (smoke): no dc_dev point completed")
    if SMOKE and not any("k1_parity" in p for p in dc_points):
        raise SystemExit("dc bench (smoke): k=1 parity check never ran")
    write_results("dist", record)


if __name__ == "__main__":
    main()
