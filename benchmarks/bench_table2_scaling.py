"""Table 2: per-iteration cost scaling.  Skotch/ASkotch iterations are O(nb);
PCG iterations are O(n^2).  Measured by timing jitted iterations across n —
the ratio trend (quadratic vs linear in n at fixed b-fraction^2...) is the
deliverable, plus the preconditioner-storage footprint (O(br) vs O(nr))."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, note, timeit


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.askotch import ASkotchConfig, init_state, make_step
    from repro.core.krr import KRRProblem
    from repro.data import synthetic

    sizes = [2000, 4000, 8000]
    askotch_t, pcg_t = [], []
    for n in sizes:
        x_tr, y_tr, _, _ = synthetic.krr_regression(0, n, 8)
        prob = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.5,
                          lam_unscaled=1e-6, backend="xla")
        b, r = n // 100 + 64, 64
        cfg = ASkotchConfig(block_size=b, rank=r, backend="xla")
        step = jax.jit(make_step(prob, cfg))
        state = init_state(prob)

        def one_askotch(state=state, step=step):
            s, _ = step(state)
            jax.block_until_ready(s.w)

        us_a = timeit(one_askotch, iters=5)
        askotch_t.append(us_a)

        v = jnp.ones((n,), jnp.float32)
        mv = jax.jit(prob.k_lam_matvec)

        def one_pcg(v=v, mv=mv):
            jax.block_until_ready(mv(v))

        us_p = timeit(one_pcg, iters=5)
        pcg_t.append(us_p)
        # storage: ASkotch preconditioner O(b r); PCG Nystrom O(n r)
        emit(f"table2_askotch_iter_n{n}", us_a,
             f"b={b};precond_floats={b*r}")
        emit(f"table2_pcg_iter_n{n}", us_p, f"precond_floats={n*64}")

    ra = askotch_t[-1] / askotch_t[0]
    rp = pcg_t[-1] / pcg_t[0]
    note(f"table2: n x4 -> askotch iter x{ra:.1f} (O(nb)~x16 worst if b~n), "
         f"pcg iter x{rp:.1f} (O(n^2)~x16)")
    growth = np.log(rp) / np.log(sizes[-1] / sizes[0])
    emit("table2_pcg_growth_exponent", 0.0, f"exp={growth:.2f}(expect~2)")


if __name__ == "__main__":
    main()
