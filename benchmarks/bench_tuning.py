"""Tile-sharing tuning vs the naive per-candidate loop, and successive
halving vs exhaustive grid (docs/tuning.md).

Two acceptance claims:

  * **Sharing** — a shared (sigma, lam, fold) sweep over s sigmas, l
    lambdas, k folds performs ~s kernel-tile sweeps' worth of matvec work —
    one stacked solve per sigma — where the naive loop pays for s*l*k
    independent solves.
  * **Halving** — ``policy="halving"`` prunes losing lam columns at rungs
    MID-SOLVE (``blocked_cg`` external freezing), so each sigma group's
    stacked solve ends when the survivors converge instead of when the
    slowest loser does: strictly fewer kernel sweeps than the exhaustive
    grid at the SAME best config (enforced below, budget-checked).

Kernel work is counted in *sweeps* (full passes over the n x n tile grid,
``TuneResult.sweeps``); wall time is reported alongside.

Emits:

    tuning_shared   — the stacked path, derived: sweeps + per-sigma budget
    tuning_naive    — per-(sigma, lam, fold) loop, derived: sweeps + ratio
    tuning_grid     — exhaustive grid on the wide-lam testbed
    tuning_halving  — successive halving, derived: sweeps + ratio + agreement

Set ``BENCH_TUNING_SMOKE=1`` (the CI tier-1 bench smoke does) to shrink the
problem and skip the slow naive reference loop while still enforcing the
halving-vs-grid claim.
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, note, timeit, write_results


def main() -> None:
    import jax.numpy as jnp

    from repro.core.krr import KRRProblem
    from repro.core.tune import tune
    from repro.obs import diff, snapshot

    smoke = os.environ.get("BENCH_TUNING_SMOKE", "") == "1"
    snap0 = snapshot()  # telemetry baseline: kernel pairs / CG iters delta
    r = np.random.default_rng(0)
    n, d = (320, 6) if smoke else (768, 6)
    s_sigmas, l_lams, k_folds = 3, 8, 5
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    # observation noise puts the CV-optimal lam mid-grid (the realistic
    # tuning regime): the sub-optimal tiny lams are then slow LOSERS —
    # exactly what successive halving should prune
    y = (jnp.sin(2.0 * x[:, 0]) + 0.3 * jnp.cos(x[:, 1] * x[:, 2])
         + 0.3 * jnp.asarray(r.standard_normal(n).astype(np.float32)))
    prob = KRRProblem(x=x, y=y, backend="xla")
    # the lam floor keeps every (sigma, lam, fold) system solvable to tol
    # within the iteration budget on BOTH paths — an unconverged candidate
    # scores differently under different preconditioners, which is a tuning
    # outcome (pick a bigger budget), not a tile-sharing property
    kw = dict(
        sigmas=tuple(np.geomspace(0.5, 2.0, s_sigmas)),
        lams=tuple(np.geomspace(1e-5, 1e-1, l_lams)),
        folds=k_folds, rank=64, max_iters=300, tol=1e-5, seed=0,
    )

    results = {}

    def run(name, **extra):
        results[name] = tune(prob, **{**kw, **extra})

    # -- sharing: stacked engine vs the naive per-candidate loop ------------
    us_shared = timeit(lambda: run("shared", strategy="shared"),
                       iters=1, warmup=1)
    rs = results["shared"]
    iters = max(int(v) for v in rs.info["iters_by_sigma"].values())
    budget = s_sigmas * (iters + 3)  # sketch + warm start + scoring per sigma
    if rs.sweeps > budget + 1e-6:
        raise RuntimeError(
            f"shared sweep consumed {rs.sweeps:.1f} sweeps, above the "
            f"~s-solves budget of {budget}"
        )
    emit("tuning_shared", us_shared,
         f"sweeps={rs.sweeps:.1f}_budget<=s*(iters+3)={budget}")
    if smoke:
        note("BENCH_TUNING_SMOKE=1: skipping the naive reference loop "
             f"(s*l*k = {s_sigmas * l_lams * k_folds} independent solves)")
    else:
        us_naive = timeit(lambda: run("naive", strategy="naive"),
                          iters=1, warmup=0)
        rn = results["naive"]
        if rs.best["sigma"] != rn.best["sigma"] or (
            rs.best["lam_unscaled"] != rn.best["lam_unscaled"]
        ):
            raise RuntimeError(
                f"shared and naive sweeps disagree on the best config: "
                f"{rs.best} vs {rn.best}"
            )
        emit("tuning_naive", us_naive,
             f"sweeps={rn.sweeps:.1f}_ratio={rn.sweeps / rs.sweeps:.1f}x")
        note(f"s={s_sigmas} l={l_lams} k={k_folds}: shared {rs.sweeps:.1f} "
             f"sweeps (~{rs.sweeps / s_sigmas:.0f}/sigma, {iters} CG iters) "
             f"vs naive {rn.sweeps:.1f} ({rn.sweeps / rs.sweeps:.1f}x more "
             f"kernel work; candidate count {rs.info['candidates']}, "
             f"{s_sigmas * l_lams * k_folds} naive solves)")
        note(f"wall: shared {us_shared / 1e6:.1f} s vs naive "
             f"{us_naive / 1e6:.1f} s")

    # -- halving vs grid: wide lam grid whose smallest lams are slow losers
    # (worst-conditioned AND overfit) — the candidates halving should prune
    # at the first rung instead of iterating to the budget
    hkw = dict(kw, lams=tuple(np.geomspace(1e-8, 1e-1, l_lams)))
    us_grid = timeit(lambda: run("grid", policy="grid", **hkw),
                     iters=1, warmup=0)
    us_halving = timeit(lambda: run("halving", policy="halving", **hkw),
                        iters=1, warmup=0)
    rg, rh = results["grid"], results["halving"]
    if rg.best["sigma"] != rh.best["sigma"] or (
        rg.best["lam_unscaled"] != rh.best["lam_unscaled"]
    ):
        raise RuntimeError(
            f"halving and grid disagree on the best config: "
            f"{rh.best} vs {rg.best}"
        )
    if not rh.sweeps < rg.sweeps:  # the budget claim, strictly enforced
        raise RuntimeError(
            f"halving consumed {rh.sweeps:.1f} sweeps, not strictly below "
            f"the exhaustive grid's {rg.sweeps:.1f}"
        )
    pruned = sum(1 for t in rh.trace if t["pruned_at_rung"] is not None)
    emit("tuning_grid", us_grid, f"sweeps={rg.sweeps:.1f}")
    emit("tuning_halving", us_halving,
         f"sweeps={rh.sweeps:.1f}_ratio={rg.sweeps / rh.sweeps:.1f}x_"
         f"pruned={pruned}/{len(rh.trace)}_best_agrees")
    note(f"halving: {rh.sweeps:.1f} sweeps vs grid {rg.sweeps:.1f} "
         f"({rg.sweeps / rh.sweeps:.1f}x fewer), {pruned}/{len(rh.trace)} "
         f"candidates pruned mid-solve, same best config "
         f"(sigma={rh.best['sigma']:.3g}, lam={rh.best['lam_unscaled']:.3g})")
    note(f"wall: grid {us_grid / 1e6:.1f} s vs halving {us_halving / 1e6:.1f} s")
    note("one stacked multi-RHS solve per sigma == the tile-sharing claim; "
         "halving ends each solve at the survivors' convergence")

    record = {
        "smoke": smoke,
        "n": n, "d": d,
        "sigmas": s_sigmas, "lams": l_lams, "folds": k_folds,
        "shared": {"us": us_shared, "sweeps": float(rs.sweeps)},
        "grid": {"us": us_grid, "sweeps": float(rg.sweeps)},
        "halving": {"us": us_halving, "sweeps": float(rh.sweeps),
                    "pruned": pruned},
        "telemetry_delta": diff(snap0, snapshot()),
    }
    if not smoke:
        record["naive"] = {"us": us_naive, "sweeps": float(rn.sweeps)}
    write_results("tuning", record)


if __name__ == "__main__":
    main()
