"""Tile-sharing tuning vs the naive per-candidate loop (docs/tuning.md).

The acceptance claim: a shared (sigma, lam, fold) sweep over s sigmas,
l lambdas, k folds performs ~s kernel-tile sweeps' worth of matvec work —
one stacked solve per sigma — where the naive loop pays for s*l*k
independent solves.  Kernel work is counted in *sweeps* (full passes over
the n x n tile grid, ``TuneResult.sweeps``); wall time is reported alongside.

Emits:

    tuning_shared   — the stacked path, derived: sweeps + per-sigma budget
    tuning_naive    — per-(sigma, lam, fold) loop, derived: sweeps + ratio
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, note, timeit


def main() -> None:
    import jax.numpy as jnp

    from repro.core.krr import KRRProblem
    from repro.core.tuning import tune

    r = np.random.default_rng(0)
    n, d = 768, 6
    s_sigmas, l_lams, k_folds = 3, 8, 5
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    y = jnp.sin(2.0 * x[:, 0]) + 0.3 * jnp.cos(x[:, 1] * x[:, 2])
    prob = KRRProblem(x=x, y=y, backend="xla")
    # the lam floor keeps every (sigma, lam, fold) system solvable to tol
    # within the iteration budget on BOTH paths — an unconverged candidate
    # scores differently under different preconditioners, which is a tuning
    # outcome (pick a bigger budget), not a tile-sharing property
    kw = dict(
        sigmas=tuple(np.geomspace(0.5, 2.0, s_sigmas)),
        lams=tuple(np.geomspace(1e-5, 1e-1, l_lams)),
        folds=k_folds, rank=64, max_iters=300, tol=1e-5, seed=0,
    )

    results = {}

    def run(strategy):
        results[strategy] = tune(prob, strategy=strategy, **kw)

    us_shared = timeit(lambda: run("shared"), iters=1, warmup=1)
    us_naive = timeit(lambda: run("naive"), iters=1, warmup=0)
    rs, rn = results["shared"], results["naive"]
    if rs.best["sigma"] != rn.best["sigma"] or (
        rs.best["lam_unscaled"] != rn.best["lam_unscaled"]
    ):
        raise RuntimeError(
            f"shared and naive sweeps disagree on the best config: "
            f"{rs.best} vs {rn.best}"
        )
    iters = max(int(v) for v in rs.info["iters_by_sigma"].values())
    budget = s_sigmas * (iters + 3)  # sketch + warm start + scoring per sigma
    if rs.sweeps > budget + 1e-6:
        raise RuntimeError(
            f"shared sweep consumed {rs.sweeps:.1f} sweeps, above the "
            f"~s-solves budget of {budget}"
        )
    emit("tuning_shared", us_shared,
         f"sweeps={rs.sweeps:.1f}_budget<=s*(iters+3)={budget}")
    emit("tuning_naive", us_naive,
         f"sweeps={rn.sweeps:.1f}_ratio={rn.sweeps / rs.sweeps:.1f}x")
    note(f"s={s_sigmas} l={l_lams} k={k_folds}: shared {rs.sweeps:.1f} sweeps "
         f"(~{rs.sweeps / s_sigmas:.0f}/sigma, {iters} CG iters) vs naive "
         f"{rn.sweeps:.1f} ({rn.sweeps / rs.sweeps:.1f}x more kernel work; "
         f"candidate count {rs.info['candidates']}, "
         f"{s_sigmas * l_lams * k_folds} naive solves)")
    note(f"wall: shared {us_shared / 1e6:.1f} s vs naive {us_naive / 1e6:.1f} s")
    note("one stacked multi-RHS solve per sigma == the tile-sharing claim")


if __name__ == "__main__":
    main()
