"""Figs. 10/11 / §6.4 ablations: Nystrom-vs-identity projector, acceleration,
damped-vs-regularization rho, uniform-vs-ARLS sampling — equal iteration
budget, final relative residual + test MAE reported per arm."""

from __future__ import annotations

from benchmarks.common import emit, note


def main(n: int = 6000, iters: int = 300) -> None:
    from repro.core.askotch import ASkotchConfig, solve
    from repro.core.krr import KRRProblem, evaluate
    from repro.data import synthetic

    x_tr, y_tr, x_te, y_te = synthetic.krr_regression(0, n, 8, 1000)
    prob = KRRProblem(x=x_tr, y=y_tr, kernel="matern52", sigma=2.8,
                      lam_unscaled=1e-7, backend="xla")
    arms = {
        "askotch_damped": ASkotchConfig(backend="xla"),
        "askotch_regularization": ASkotchConfig(rho_mode="regularization", backend="xla"),
        "skotch": ASkotchConfig(accelerated=False, backend="xla"),
        "askotch_identity_precond": ASkotchConfig(precond="identity", backend="xla"),
        "askotch_arls": ASkotchConfig(sampling="arls", backend="xla"),
    }
    results = {}
    for name, cfg in arms.items():
        res = solve(prob, cfg, max_iters=iters, eval_every=iters)
        rel = res.history[-1]["rel_residual"]
        mae = float(evaluate(prob.predict(res.w, x_te), y_te).mae)
        results[name] = (rel, mae)
        note(f"ablation {name}: rel={rel:.3e} mae={mae:.4f} {res.wall_time_s:.1f}s")
        emit(f"ablation_{name}", res.wall_time_s * 1e6 / iters,
             f"rel_res={rel:.3e};test_mae={mae:.4f}")
    # paper-claim checks
    assert results["askotch_damped"][0] < results["askotch_identity_precond"][0], \
        "Nystrom projector must beat identity (Fig. 10/11)"


if __name__ == "__main__":
    main()
