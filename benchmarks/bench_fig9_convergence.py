"""Fig. 9 / §6.3: ASkotch converges linearly to (near) machine precision.

Runs in f64 (paper uses double precision for this figure): with
``jax_enable_x64`` the dense kernel maps promote rather than truncate
(``core.kernels._sq_dists`` keeps f64 operands in f64 — it only UPCASTS
sub-f32 inputs), so the trajectory below ~1e-8 is a true double-precision
measurement.  This is the opposite end of the precision policy from
``precision="bf16"`` (docs/architecture.md, "Precision policy"): bf16 kernel
tiles bottom out near ~1e-2..1e-1 relative residual depending on
conditioning, so machine-precision targets are meaningless there —
``solver_api.solve`` warns on any bf16 solve asked for tol below its
``BF16_TOL_FLOOR``, and this benchmark intentionally has no bf16 variant.

Reports the relative residual trajectory and the fitted per-pass geometric
rate."""

from __future__ import annotations

import math

from benchmarks.common import emit, note


def main(n: int = 4000) -> None:
    import jax

    jax.config.update("jax_enable_x64", True)
    try:
        import jax.numpy as jnp
        import numpy as np

        from repro.core.askotch import ASkotchConfig, solve
        from repro.core.krr import KRRProblem
        from repro.data import synthetic

        x_tr, y_tr, _, _ = synthetic.krr_regression(0, n, 8)
        x_tr = jnp.asarray(np.asarray(x_tr), jnp.float64)
        y_tr = jnp.asarray(np.asarray(y_tr), jnp.float64)
        prob = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.5,
                          lam_unscaled=1e-6, backend="xla")
        for rank in (50, 100, 200):
            cfg = ASkotchConfig(block_size=n // 10, rank=rank, backend="xla")
            res = solve(prob, cfg, max_iters=600, eval_every=100, tol=1e-13)
            rels = [(h["iter"], h["rel_residual"]) for h in res.history]
            note(f"fig9 r={rank}: " + " ".join(f"{i}:{r:.2e}" for i, r in rels))
            first, last = rels[0], rels[-1]
            passes = (last[0] - first[0]) / 10  # b = n/10 -> 10 iters/pass
            rate = (
                math.exp(math.log(max(last[1], 1e-300) / first[1]) / max(passes, 1))
                if first[1] > 0 else 1.0
            )
            emit(f"fig9_rank{rank}", res.wall_time_s * 1e6 / last[0],
                 f"final_rel={last[1]:.3e};rate_per_pass={rate:.3f}")
            assert last[1] < first[1], "not converging"
    finally:
        jax.config.update("jax_enable_x64", False)


if __name__ == "__main__":
    main()
