# One function per paper table/figure.  Prints ``name,us_per_call,derived`` CSV.
#
#   fig1      — ASkotch vs PCG/Falkon/EigenPro showdown (Fig. 1, §6.1-6.2)
#   fig9      — linear convergence to machine precision in f64 (Fig. 9, §6.3)
#   table2    — per-iteration cost/storage scaling (Table 2)
#   ablation  — Nystrom/accel/rho/sampling ablations (Figs. 10-11, §6.4)
#   kernels   — fused kernel-matvec hot-spot microbench + Pallas tile analysis
#   multirhs  — batched (n, t) one-vs-all solve vs t sequential solves
#   dist      — sharded matvec/ASkotch iteration + tune() vs device count
#   tuning    — tile-sharing sweep vs naive loop + halving-vs-grid policies
#   multikernel — weight-axis sharing: q-kernel random search vs naive loop
#   serving   — engine coalescing vs naive per-request loop: p50/p99/qps
#
# Scaled to CPU execution (the container is the oracle runtime; TPU numbers
# come from the dry-run roofline in EXPERIMENTS.md).  Select a subset with
#   python -m benchmarks.run fig1 ablation
#
# ``python -m benchmarks.run obs-report <telemetry.jsonl>...`` is not a bench:
# it validates + summarizes telemetry JSONL files (repro.obs.report).
from __future__ import annotations

import sys
import traceback


def main() -> None:
    want = sys.argv[1:]
    if want and want[0] == "obs-report":
        from repro.obs import report

        raise SystemExit(report.main(want[1:]))
    from benchmarks import (
        bench_ablation,
        bench_dist_scaling,
        bench_fig1_showdown,
        bench_fig9_convergence,
        bench_kernels,
        bench_multikernel,
        bench_multirhs,
        bench_serving,
        bench_table2_scaling,
        bench_tuning,
    )

    benches = {
        "kernels": bench_kernels.main,
        "table2": bench_table2_scaling.main,
        "fig9": bench_fig9_convergence.main,
        "ablation": bench_ablation.main,
        "fig1": bench_fig1_showdown.main,
        "multirhs": bench_multirhs.main,
        "dist": bench_dist_scaling.main,
        "tuning": bench_tuning.main,
        "multikernel": bench_multikernel.main,
        "serving": bench_serving.main,
    }
    want = want or list(benches)
    failed = []
    for name in want:
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        try:
            benches[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
