"""Dead-link check over the markdown docs (CI ``docs-check`` job).

    python tools/check_links.py [file.md ...]

Default file set: README.md, DESIGN.md, docs/*.md.  Every relative markdown
link ``[text](target)`` must resolve to an existing file (anchors are
stripped; ``http(s)://`` and ``mailto:`` targets are skipped — no network in
CI).  Exits non-zero listing the dead links.  ``tests/test_docs.py`` runs the
same check in-process so tier-1 catches dead links without the CI job.
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target captured up to the closing paren, no spaces
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")


def default_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = [root / "README.md", root / "DESIGN.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def dead_links(files: list[pathlib.Path]) -> list[str]:
    """Return ``"file: target"`` entries for every unresolvable relative link."""
    bad = []
    for f in files:
        for target in _LINK.findall(f.read_text()):
            if target.startswith(_SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            if not (f.parent / path).exists():
                bad.append(f"{f}: {target}")
    return bad


def main(argv: list[str]) -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    files = [pathlib.Path(a) for a in argv] or default_files(root)
    missing = [str(f) for f in files if not f.exists()]
    if missing:
        print("missing input file(s):", *missing, sep="\n  ")
        return 1
    bad = dead_links(files)
    if bad:
        print("dead links:", *bad, sep="\n  ")
        return 1
    print(f"ok: {len(files)} file(s), no dead links")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
