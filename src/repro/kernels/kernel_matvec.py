"""Pallas TPU kernel: fused pairwise-kernel x matvec — ASkotch's O(n*b) hot spot.

Computes ``out = K(A, B) @ V`` without materializing K, where
``K[i, j] = k(A[i], B[j])`` for any kernel in ``core.kernels.KERNEL_NAMES``.

TPU-native tiling (see docs/architecture.md, "Pallas matvec tiling"):

  grid = (m // bm, n // bn); the n axis is the contraction and iterates
  innermost so the (bm, kv) f32 accumulator tile stays resident in VMEM.

  Per grid step, VMEM holds:
    A tile (bm, d), B tile (bn, d), V tile (bn, kv), base tile (bm, bn),
    accumulator (bm, kv).
  The base tile depends on the kernel's FAMILY (``core.kernels.
  KERNEL_FAMILIES``): the squared-L2 tile (rbf/matern52) comes from the MXU
  via the ||a||^2 + ||b||^2 - 2 a.b^T expansion (one (bm,d)x(d,bn) matmul,
  f32 accumulate); the dot-product family (linear/polynomial/sigmoid) and
  cosine skip the norm terms and use the raw (or row-normalized) a.b^T matmul
  directly — same MXU shape, strictly less VPU work.  The L1 distance
  (laplacian) has no matmul form, so we stream the feature dim in ``dchunk``
  slabs and reduce |a-b| on the VPU, bounding the (bm, bn, dchunk) broadcast
  slab to ~2 MB of VMEM.

  Default bm=bn=256, d padded to a multiple of 8, kv padded to 128: the MXU
  matmuls are (256,d)x(d,256) and (256,256)x(256,kv) — both 128-aligned.

Validated against ``ref.kernel_matvec`` in interpret mode (tests sweep shapes,
dtypes and kernels); on TPU hardware the same code runs compiled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.kernels import kernel_family

_SQRT5 = 5.0**0.5


def _apply_kernel(base: jax.Array, kernel: str, sigma: float) -> jax.Array:
    """Elementwise kernel map on the VPU given the kernel's base tile
    (squared-L2 / L1 distances, inner products, or cosine similarities —
    whichever ``core.kernels.KERNEL_FAMILIES[kernel]`` names)."""
    if kernel == "rbf":
        return jnp.exp(-base / (2.0 * sigma**2))
    if kernel == "laplacian":
        return jnp.exp(-base / sigma)
    if kernel == "matern52":
        d2 = base
        d = jnp.sqrt(d2 + 1e-20)
        s5 = _SQRT5 * d / sigma
        return (1.0 + s5 + 5.0 * d2 / (3.0 * sigma**2)) * jnp.exp(-s5)
    if kernel == "linear":
        return base / sigma**2
    if kernel == "polynomial":
        return (base / sigma**2 + 1.0) ** 3
    if kernel == "sigmoid":
        return jnp.tanh(base / sigma**2 + 1.0)
    if kernel == "cosine":
        return base
    raise ValueError(f"unknown kernel {kernel!r}")


def _dot_tile(a: jax.Array, b: jax.Array) -> jax.Array:
    """(bm, bn) f32 inner-product tile a.b^T from the MXU — operands at their
    stored width (f32/bf16) with f32 accumulation."""
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )


def _base_tile(a: jax.Array, b: jax.Array, family: str, dchunk: int) -> jax.Array:
    """(bm, bn) f32 base tile for a kernel family: squared-L2 ("l2"), L1
    ("l1"), inner product ("dot"), or cosine similarity ("cos").

    Accepts raw operand tiles in f32 OR bf16 — the mixed-precision contract:
    the MXU contraction takes the operands at their stored width with
    ``preferred_element_type=f32`` (f32 accumulation), the norms and the L1
    slab reduction upcast to f32 first (bf16 -> f32 is exact per element).
    The returned tile is always f32.  Zero-padded feature columns leave every
    family's tile unchanged; zero-padded ROWS yield 0 similarities under the
    "cos" family's zero-norm-divides-by-1 convention (sklearn's), so padding
    never pollutes live rows in any family.
    """
    if family == "l1":
        bm, d = a.shape
        bn = b.shape[0]
        nchunks = d // dchunk  # d is pre-padded to a multiple of dchunk

        def body(c, acc):
            a_s = lax.dynamic_slice(a, (0, c * dchunk), (bm, dchunk))
            b_s = lax.dynamic_slice(b, (0, c * dchunk), (bn, dchunk))
            diff = a_s[:, None, :].astype(jnp.float32) - b_s[None, :, :].astype(
                jnp.float32
            )
            return acc + jnp.sum(jnp.abs(diff), axis=-1)

        return lax.fori_loop(0, nchunks, body, jnp.zeros((bm, bn), jnp.float32))
    if family == "dot":
        return _dot_tile(a, b)
    if family == "cos":
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        an = jnp.sqrt(jnp.sum(af * af, axis=-1, keepdims=True))  # (bm, 1)
        bn_ = jnp.sqrt(jnp.sum(bf * bf, axis=-1, keepdims=True)).T  # (1, bn)
        ab = _dot_tile(a, b)
        return ab / (jnp.where(an == 0.0, 1.0, an) * jnp.where(bn_ == 0.0, 1.0, bn_))
    if family != "l2":
        raise ValueError(f"unknown kernel family {family!r}")
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    aa = jnp.sum(af * af, axis=-1, keepdims=True)  # (bm, 1)
    bb = jnp.sum(bf * bf, axis=-1, keepdims=True).T  # (1, bn)
    ab = _dot_tile(a, b)
    return jnp.maximum(aa + bb - 2.0 * ab, 0.0)


def _distance_tile(a: jax.Array, b: jax.Array, kernel: str, dchunk: int) -> jax.Array:
    """Base tile for one kernel — :func:`_base_tile` keyed by the kernel's
    family (kept as the per-kernel spelling the single-kernel bodies use)."""
    return _base_tile(a, b, kernel_family(kernel), dchunk)


def _cast_tiles(precision: str, *arrays: jax.Array) -> tuple[jax.Array, ...]:
    """Host-side tile dtype for the requested precision policy: bf16 halves
    the HBM/VMEM traffic of every A/B/V tile; f32 is the identity."""
    if precision == "bf16":
        return tuple(x.astype(jnp.bfloat16) for x in arrays)
    return arrays


def _matvec_body(a_ref, b_ref, v_ref, o_ref, *, kernel: str, sigma: float, dchunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # tiles arrive at policy width (f32 or bf16); the distance tile and the
    # kernel map are f32, the second matmul runs at policy width with f32
    # accumulation (preferred_element_type) into the resident o_ref tile
    v = v_ref[...]
    dist = _distance_tile(a_ref[...], b_ref[...], kernel, dchunk)
    ktile = _apply_kernel(dist, kernel, sigma)
    o_ref[...] += jax.lax.dot_general(
        ktile.astype(v.dtype),
        v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernel", "sigma", "bm", "bn", "dchunk", "interpret", "precision",
    ),
)
def kernel_matvec_pallas(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    dchunk: int = 32,
    interpret: bool = False,
    precision: str = "f32",
) -> jax.Array:
    """out = K(a, b) @ v.  a: (m, d), b: (n, d), v: (n, k)|(n,) -> (m, k)|(m,).

    ``precision="bf16"`` loads the A/B/V tiles in bf16 (half the HBM/VMEM
    traffic, 2x MXU rate on TPU) while the distance accumulation, kernel map
    and output accumulator stay f32; the output is f32 either way.
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    m, d = a.shape
    n = b.shape[0]
    kv = v.shape[1]

    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    # Pad everything to tile multiples.  Zero-padded V rows nullify padded-B
    # contributions; padded-A rows are sliced off the output; zero-padded
    # features leave both L2 and L1 distances unchanged.
    mp, np_, dp = -(-m // bm) * bm, -(-n // bn) * bn, -(-d // dchunk) * dchunk
    kvp = -(-kv // 128) * 128 if not interpret else kv
    a_p = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    b_p = jnp.pad(b, ((0, np_ - n), (0, dp - d)))
    v_p = jnp.pad(v, ((0, np_ - n), (0, kvp - kv)))
    a_p, b_p, v_p = _cast_tiles(precision, a_p, b_p, v_p)

    out = pl.pallas_call(
        functools.partial(
            _matvec_body, kernel=kernel, sigma=float(sigma), dchunk=dchunk
        ),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, kvp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, kvp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, kvp), jnp.float32),
        interpret=interpret,
    )(a_p, b_p, v_p)
    out = out[:m, :kv]
    return out[:, 0] if squeeze else out
