"""The kernel tile-compute precision policy — shared vocabulary and validation.

Dependency-free on purpose: both the kernel dispatch (``kernels/ops.py``) and
the solver API (``core/solver_api.py``) validate precision strings, and the
import chains between ``repro.kernels`` and ``repro.core`` run in both
directions, so the policy's single source of truth lives below both.

``"f32"`` — tiles, distances, kernel maps and accumulators all f32 (the
bit-identical default).  ``"bf16"`` — A/B/V tile/chunk traffic and the
kernel-times-value matmul run in bf16 with f32 accumulation; distances,
kernel maps, outputs and every solver-internal quantity stay f32 (the
f32-islands rule, docs/architecture.md "Precision policy").
"""

from __future__ import annotations

PRECISIONS = ("f32", "bf16")


def check_precision(precision: str) -> str:
    """Validate a precision-policy string ("f32" | "bf16") and return it."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}"
        )
    return precision
