"""Pallas TPU kernels: fused multi-kernel matvec / block build.

A weighted-sum kernel ``K_w = sum_i w_i K_i`` (q base kernels, weights w on
the simplex) costs the same data movement as a single kernel: per (bm, bn)
tile the pairwise base tile is computed at most once per kernel FAMILY
(``core.kernels.KERNEL_FAMILIES``: squared-L2 on the MXU for rbf/matern52,
L1 slab-reduction on the VPU for laplacian, raw / normalized a.b^T for the
dot-product and cosine kernels) and the q elementwise kernel maps + weighted
accumulation stay in VMEM.  This is what makes a q-kernel operator sweep
cost ~1 kernel sweep instead of q (docs/tuning.md, "Multi-kernel sweeps").

Three entry points, all validated against ``ref.kernel_*_multi`` in
interpret mode:

  * ``kernel_matvec_multi_pallas``      — (sum_i w_i K_i) @ V; ``weights``
    may be (q,) or per-column (q, t) (the stacked tuning engine's case) and
    is a traced array input, so weight changes never recompile.
  * ``kernel_matvec_components_pallas`` — stacked per-kernel K_i @ V
    (q, m, t): the per-kernel Nystrom sketches in one data sweep.
  * ``kernel_block_multi_pallas``       — materialize sum_i w_i K_i(A, B).

Tiling is identical to ``kernel_matvec``/``kernel_block`` (same bm/bn/dchunk
defaults, same padding rules); the only extra VMEM is one (q, kv) weight
tile and, for the components variant, a (q, bm, kv) accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.kernels import kernel_family
from repro.kernels.kernel_matvec import _apply_kernel, _base_tile, _cast_tiles


def _tiles(a, b, kernels, dchunk):
    """Base tiles shared by every kernel map, one per family present
    ("l2"/"l1"/"dot"/"cos" -> (bm, bn) f32 tile)."""
    return {
        fam: _base_tile(a, b, fam, dchunk)
        for fam in dict.fromkeys(kernel_family(k) for k in kernels)
    }


def _tile_for(kernel, tiles, sigma):
    return _apply_kernel(tiles[kernel_family(kernel)], kernel, sigma)


def _multi_matvec_body(
    a_ref, b_ref, v_ref, w_ref, o_ref, *, kernels, sigmas, dchunk
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # tiles at policy width (f32/bf16); base tiles, weight row products
    # and the accumulator stay f32, the per-kernel matmul runs at policy
    # width with f32 accumulation
    v = v_ref[...]
    tiles = _tiles(a_ref[...], b_ref[...], kernels, dchunk)
    acc = jnp.zeros_like(o_ref)
    for i, (kn, sg) in enumerate(zip(kernels, sigmas)):
        ktile = _tile_for(kn, tiles, sg)
        # w_ic (K_i v)[:, c] == (K_i (v * w_i))[:, c]: pre-scaling v per
        # kernel lets one accumulator serve every kernel and column
        acc += lax.dot_general(
            ktile.astype(v.dtype),
            (v * w_ref[i, :][None, :]).astype(v.dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    o_ref[...] += acc


def _components_body(a_ref, b_ref, v_ref, o_ref, *, kernels, sigmas, dchunk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = v_ref[...]
    tiles = _tiles(a_ref[...], b_ref[...], kernels, dchunk)
    for i, (kn, sg) in enumerate(zip(kernels, sigmas)):
        ktile = _tile_for(kn, tiles, sg)
        o_ref[i, ...] += lax.dot_general(
            ktile.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def _block_multi_body(a_ref, b_ref, o_ref, *, kernels, sigmas, weights, dchunk):
    tiles = _tiles(a_ref[...], b_ref[...], kernels, dchunk)
    acc = jnp.zeros_like(o_ref)
    for kn, sg, w in zip(kernels, sigmas, weights):
        acc += w * _tile_for(kn, tiles, sg)
    o_ref[...] = acc


def _pad_multi(a, b, v, bm, bn, dchunk, interpret, precision="f32"):
    m, d = a.shape
    n = b.shape[0]
    kv = v.shape[1]
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    mp, np_, dp = -(-m // bm) * bm, -(-n // bn) * bn, -(-d // dchunk) * dchunk
    kvp = -(-kv // 128) * 128 if not interpret else kv
    a_p = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    b_p = jnp.pad(b, ((0, np_ - n), (0, dp - d)))
    v_p = jnp.pad(v, ((0, np_ - n), (0, kvp - kv)))
    a_p, b_p, v_p = _cast_tiles(precision, a_p, b_p, v_p)
    return a_p, b_p, v_p, (m, n, kv, bm, bn, mp, np_, dp, kvp)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernels", "sigmas", "bm", "bn", "dchunk", "interpret", "precision",
    ),
)
def kernel_matvec_multi_pallas(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    weights: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    bm: int = 256,
    bn: int = 256,
    dchunk: int = 32,
    interpret: bool = False,
    precision: str = "f32",
) -> jax.Array:
    """out = (sum_i w_i K_i(a, b)) @ v; weights (q,) or per-column (q, kv).

    ``precision="bf16"`` loads the A/B/V tiles in bf16; the weight tile,
    distance tiles and accumulator stay f32 (output is f32 either way).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    a_p, b_p, v_p, (m, n, kv, bm, bn, mp, np_, dp, kvp) = _pad_multi(
        a, b, v, bm, bn, dchunk, interpret, precision
    )
    q = len(kernels)
    w2 = jnp.broadcast_to(
        weights[:, None] if weights.ndim == 1 else weights, (q, kv)
    ).astype(jnp.float32)
    # pad the sublane (q) dim to a multiple of the f32 tile minimum; only
    # rows [0, q) are ever read (static python loop), the lane dim pads with
    # the v columns
    qp = -(-q // 8) * 8
    w_p = jnp.pad(w2, ((0, qp - q), (0, kvp - kv)))

    out = pl.pallas_call(
        functools.partial(
            _multi_matvec_body, kernels=kernels, sigmas=sigmas, dchunk=dchunk
        ),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, kvp), lambda i, j: (j, 0)),
            pl.BlockSpec((qp, kvp), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, kvp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, kvp), jnp.float32),
        interpret=interpret,
    )(a_p, b_p, v_p, w_p)
    out = out[:m, :kv]
    return out[:, 0] if squeeze else out


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernels", "sigmas", "bm", "bn", "dchunk", "interpret", "precision",
    ),
)
def kernel_matvec_components_pallas(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    bm: int = 256,
    bn: int = 256,
    dchunk: int = 32,
    interpret: bool = False,
    precision: str = "f32",
) -> jax.Array:
    """Stacked per-kernel products: out[i] = K_i(a, b) @ v, shape (q, m[, kv]).

    ``precision="bf16"`` loads the A/B/V tiles in bf16 with f32 accumulation.
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    a_p, b_p, v_p, (m, n, kv, bm, bn, mp, np_, dp, kvp) = _pad_multi(
        a, b, v, bm, bn, dchunk, interpret, precision
    )
    q = len(kernels)

    out = pl.pallas_call(
        functools.partial(
            _components_body, kernels=kernels, sigmas=sigmas, dchunk=dchunk
        ),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, kvp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((q, bm, kvp), lambda i, j: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, mp, kvp), jnp.float32),
        interpret=interpret,
    )(a_p, b_p, v_p)
    out = out[:, :m, :kv]
    return out[:, :, 0] if squeeze else out


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernels", "sigmas", "weights", "bm", "bn", "dchunk", "interpret",
        "precision",
    ),
)
def kernel_block_multi_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    weights: tuple[float, ...],
    bm: int = 256,
    bn: int = 256,
    dchunk: int = 32,
    interpret: bool = False,
    precision: str = "f32",
) -> jax.Array:
    """Materialize sum_i w_i K_i(a, b): (m, d), (n, d) -> (m, n) f32.

    ``precision="bf16"`` loads the A/B tiles in bf16 with f32 accumulation.
    """
    m, d = a.shape
    n = b.shape[0]
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    mp, np_, dp = -(-m // bm) * bm, -(-n // bn) * bn, -(-d // dchunk) * dchunk
    a_p = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    b_p = jnp.pad(b, ((0, np_ - n), (0, dp - d)))
    a_p, b_p = _cast_tiles(precision, a_p, b_p)

    out = pl.pallas_call(
        functools.partial(
            _block_multi_body, kernels=kernels, sigmas=sigmas,
            weights=weights, dchunk=dchunk,
        ),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
