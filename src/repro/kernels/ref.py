"""Pure-jnp oracles for the fused kernel ops.

These are the correctness references for the Pallas kernels AND the default
execution backend on CPU.  They stream over the dataset in fixed-size chunks
(via lax.scan / lax.map) so that K is never materialized — the same contract
as the Pallas kernels, minus the explicit VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.kernels import (
    _cos_sims,
    _dots,
    _l1_dists,
    _sq_dists,
    kernel_family,
    kernel_fn,
)

#: streaming base-tile builder per kernel family (the jnp mirror of the
#: Pallas ``_base_tile``) — each promotes to at least f32 before accumulating
_FAMILY_TILES = {
    "l2": _sq_dists,
    "l1": _l1_dists,
    "dot": _dots,
    "cos": _cos_sims,
}


def tile_from_dists(kernel: str, tiles: dict, sigma: jax.Array) -> jax.Array:
    """Elementwise kernel map given precomputed base tiles.

    ``tiles`` maps each kernel FAMILY present to its shared base tile
    (squared-L2, L1, inner-product, or cosine — see ``core.kernels.
    KERNEL_FAMILIES``); the multi-kernel ops compute each family tile at most
    once per chunk pair and apply every kernel map to the shared tile.  The
    map itself is the Pallas kernels' ``_apply_kernel`` (one formula source;
    it is plain jnp, so a traced sigma works here too).
    """
    from repro.kernels.kernel_matvec import _apply_kernel

    return _apply_kernel(tiles[kernel_family(kernel)], kernel, sigma)


def _cast_chunks(precision: str, *arrays: jax.Array) -> tuple[jax.Array, ...]:
    """Chunk dtype for the requested precision policy — the streaming mirror
    of the Pallas tile cast: bf16 halves the bytes every scanned chunk moves.

    The existing distance helpers (``core.kernels._sq_dists`` / ``_l1_dists``)
    upcast their operands to f32 before accumulating, and bf16 -> f32 is
    exact per element, so bf16 chunks through those helpers reproduce the
    "bf16 operands, f32 accumulation" MXU contract bit-for-bit in spirit.
    """
    if precision == "bf16":
        return tuple(x.astype(jnp.bfloat16) for x in arrays)
    return arrays


def _acc_dot(ktile: jax.Array, v_blk: jax.Array, precision: str) -> jax.Array:
    """ktile @ v_blk under the precision policy: the bf16 path downcasts the
    kernel tile to bf16 (matching the Pallas second matmul) and accumulates
    in f32 via ``preferred_element_type``."""
    if precision == "bf16":
        return lax.dot_general(
            ktile.astype(jnp.bfloat16),
            v_blk.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return ktile @ v_blk


def _pad_rows(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


@functools.partial(
    jax.jit, static_argnames=("kernel", "chunk_a", "chunk_b", "precision")
)
def kernel_matvec(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    sigma: jax.Array,
    *,
    kernel: str = "rbf",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
) -> jax.Array:
    """out = K(a, b) @ v, streamed.

    a: (m, d), b: (n, d), v: (n, k) or (n,) -> out (m, k) or (m,).
    Memory high-water mark is O(chunk_a * chunk_b) instead of O(m * n).
    ``precision="bf16"`` streams the a/b/v chunks in bf16 with f32 distance
    and output accumulation (the Pallas tile contract); output stays f32.
    """
    kfn = kernel_fn(kernel)
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    m = a.shape[0]
    chunk_a = min(chunk_a, max(m, 1))
    chunk_b = min(chunk_b, max(b.shape[0], 1))

    bp, n = _pad_rows(b, chunk_b)
    vp, _ = _pad_rows(v, chunk_b)
    vp = jnp.where(
        (jnp.arange(bp.shape[0]) < n)[:, None], vp, 0.0
    )  # padded rows contribute exactly zero
    nb = bp.shape[0] // chunk_b
    b_chunks = bp.reshape(nb, chunk_b, b.shape[1])
    v_chunks = vp.reshape(nb, chunk_b, v.shape[1])

    ap, m0 = _pad_rows(a, chunk_a)
    na = ap.shape[0] // chunk_a
    a_chunks = ap.reshape(na, chunk_a, a.shape[1])
    a_chunks, b_chunks, v_chunks = _cast_chunks(
        precision, a_chunks, b_chunks, v_chunks
    )

    acc_dt = jnp.promote_types(jnp.promote_types(a.dtype, v.dtype), jnp.float32)

    def row_block(a_blk):
        def body(acc, bv):
            b_blk, v_blk = bv
            return acc + _acc_dot(kfn(a_blk, b_blk, sigma), v_blk, precision), None

        init = jnp.zeros((a_blk.shape[0], v.shape[1]), acc_dt)
        out, _ = lax.scan(body, init, (b_chunks, v_chunks))
        return out

    out = lax.map(row_block, a_chunks).reshape(na * chunk_a, v.shape[1])[:m0]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("kernel", "precision"))
def kernel_block(
    a: jax.Array,
    b: jax.Array,
    sigma: jax.Array,
    *,
    kernel: str = "rbf",
    precision: str = "f32",
) -> jax.Array:
    """Materialize K(a, b).  Reference for the Pallas block-build kernel.
    ``precision="bf16"`` rounds the operands to bf16 first; the distance
    accumulation (``core.kernels`` helpers upcast to f32) and the block
    stay f32."""
    a, b = _cast_chunks(precision, a, b)
    return kernel_fn(kernel)(a, b, sigma)


# ---------------------------------------------------------------------------
# multi-kernel ops: ONE data sweep serves all q kernels (docs/tuning.md,
# "Multi-kernel sweeps").  The pairwise base tile is computed at most once
# per kernel family (l2/l1/dot/cos) per chunk pair; the q elementwise kernel
# maps and the weighted accumulation ride the same streamed chunks.
# ---------------------------------------------------------------------------


def _multi_chunks(a, b, v, chunk_a, chunk_b):
    """Shared padding/chunking plumbing for the multi-kernel matvecs."""
    m = a.shape[0]
    chunk_a = min(chunk_a, max(m, 1))
    chunk_b = min(chunk_b, max(b.shape[0], 1))
    bp, n = _pad_rows(b, chunk_b)
    vp, _ = _pad_rows(v, chunk_b)
    vp = jnp.where((jnp.arange(bp.shape[0]) < n)[:, None], vp, 0.0)
    nb = bp.shape[0] // chunk_b
    b_chunks = bp.reshape(nb, chunk_b, b.shape[1])
    v_chunks = vp.reshape(nb, chunk_b, v.shape[1])
    ap, m0 = _pad_rows(a, chunk_a)
    na = ap.shape[0] // chunk_a
    a_chunks = ap.reshape(na, chunk_a, a.shape[1])
    return a_chunks, b_chunks, v_chunks, na, chunk_a, m0


def _dist_tiles(a_blk, b_blk, kernels):
    """One shared base tile per family present (dict family -> tile)."""
    return {
        fam: _FAMILY_TILES[fam](a_blk, b_blk)
        for fam in dict.fromkeys(kernel_family(k) for k in kernels)
    }


@functools.partial(
    jax.jit, static_argnames=("kernels", "chunk_a", "chunk_b", "precision")
)
def kernel_matvec_multi(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    sigmas: jax.Array,
    weights: jax.Array,
    *,
    kernels: tuple[str, ...],
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
) -> jax.Array:
    """out = (sum_i w_i K_i(a, b)) @ v, streamed — one data sweep for all q.

    ``weights`` is (q,) — one scalar weight per kernel — or (q, t) with a
    per-COLUMN weight vector (the tuning engine's case: column c solves the
    system of weight vector w[:, c]).  Per-column weights use the identity
    ``w_ic (K_i v)[:, c] = (K_i (v * w_i))[:, c]``: v is pre-scaled per
    kernel, so one (m, t) accumulator serves every kernel and column.
    ``precision="bf16"`` streams a/b/v chunks in bf16 with f32 accumulation;
    the weight rows stay f32 and the output is f32 either way.
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    a_chunks, b_chunks, v_chunks, na, chunk_a, m0 = _multi_chunks(
        a, b, v, chunk_a, chunk_b
    )
    a_chunks, b_chunks, v_chunks = _cast_chunks(
        precision, a_chunks, b_chunks, v_chunks
    )
    w_rows = weights[:, None, :] if weights.ndim == 2 else weights[:, None, None]
    acc_dt = jnp.promote_types(jnp.promote_types(a.dtype, v.dtype), jnp.float32)

    def row_block(a_blk):
        def body(acc, bv):
            b_blk, v_blk = bv
            tiles = _dist_tiles(a_blk, b_blk, kernels)
            for i, kn in enumerate(kernels):
                ktile = tile_from_dists(kn, tiles, sigmas[i])
                acc = acc + _acc_dot(ktile, v_blk * w_rows[i], precision)
            return acc, None

        init = jnp.zeros((a_blk.shape[0], v.shape[1]), acc_dt)
        out, _ = lax.scan(body, init, (b_chunks, v_chunks))
        return out

    out = lax.map(row_block, a_chunks).reshape(na * chunk_a, v.shape[1])[:m0]
    return out[:, 0] if squeeze else out


@functools.partial(
    jax.jit, static_argnames=("kernels", "chunk_a", "chunk_b", "precision")
)
def kernel_matvec_components(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    sigmas: jax.Array,
    *,
    kernels: tuple[str, ...],
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
) -> jax.Array:
    """Stacked per-kernel products (q, m[, t]): out[i] = K_i(a, b) @ v.

    The per-kernel Nystrom sketches of the multi-kernel tuner come from ONE
    call: the distance tile is shared, only the cheap elementwise maps and
    matmuls repeat per kernel.  ``precision="bf16"`` streams the chunks in
    bf16 with f32 accumulation.
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    a_chunks, b_chunks, v_chunks, na, chunk_a, m0 = _multi_chunks(
        a, b, v, chunk_a, chunk_b
    )
    a_chunks, b_chunks, v_chunks = _cast_chunks(
        precision, a_chunks, b_chunks, v_chunks
    )
    q = len(kernels)
    acc_dt = jnp.promote_types(jnp.promote_types(a.dtype, v.dtype), jnp.float32)

    def row_block(a_blk):
        def body(acc, bv):
            b_blk, v_blk = bv
            tiles = _dist_tiles(a_blk, b_blk, kernels)
            outs = [
                acc[i]
                + _acc_dot(tile_from_dists(kn, tiles, sigmas[i]), v_blk, precision)
                for i, kn in enumerate(kernels)
            ]
            return jnp.stack(outs), None

        init = jnp.zeros((q, a_blk.shape[0], v.shape[1]), acc_dt)
        out, _ = lax.scan(body, init, (b_chunks, v_chunks))
        return out

    out = lax.map(row_block, a_chunks)  # (na, q, chunk_a, t)
    out = jnp.moveaxis(out, 1, 0).reshape(q, na * chunk_a, v.shape[1])[:, :m0]
    return out[:, :, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("kernels", "precision"))
def kernel_block_multi(
    a: jax.Array,
    b: jax.Array,
    sigmas: jax.Array,
    weights: jax.Array,
    *,
    kernels: tuple[str, ...],
    precision: str = "f32",
) -> jax.Array:
    """Materialize sum_i w_i K_i(a, b) with the distance tiles computed once.
    ``precision="bf16"`` rounds the operands to bf16 first (distances and the
    weighted accumulation stay f32)."""
    a, b = _cast_chunks(precision, a, b)
    tiles = _dist_tiles(a, b, kernels)
    out = jnp.zeros((a.shape[0], b.shape[0]), jnp.float32)
    for i, kn in enumerate(kernels):
        out = out + weights[i] * tile_from_dists(kn, tiles, sigmas[i])
    return out
