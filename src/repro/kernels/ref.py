"""Pure-jnp oracles for the fused kernel ops.

These are the correctness references for the Pallas kernels AND the default
execution backend on CPU.  They stream over the dataset in fixed-size chunks
(via lax.scan / lax.map) so that K is never materialized — the same contract
as the Pallas kernels, minus the explicit VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.kernels import kernel_fn


def _pad_rows(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


@functools.partial(jax.jit, static_argnames=("kernel", "chunk_a", "chunk_b"))
def kernel_matvec(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    sigma: jax.Array,
    *,
    kernel: str = "rbf",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
) -> jax.Array:
    """out = K(a, b) @ v, streamed.

    a: (m, d), b: (n, d), v: (n, k) or (n,) -> out (m, k) or (m,).
    Memory high-water mark is O(chunk_a * chunk_b) instead of O(m * n).
    """
    kfn = kernel_fn(kernel)
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    m = a.shape[0]
    chunk_a = min(chunk_a, max(m, 1))
    chunk_b = min(chunk_b, max(b.shape[0], 1))

    bp, n = _pad_rows(b, chunk_b)
    vp, _ = _pad_rows(v, chunk_b)
    vp = jnp.where(
        (jnp.arange(bp.shape[0]) < n)[:, None], vp, 0.0
    )  # padded rows contribute exactly zero
    nb = bp.shape[0] // chunk_b
    b_chunks = bp.reshape(nb, chunk_b, b.shape[1])
    v_chunks = vp.reshape(nb, chunk_b, v.shape[1])

    ap, m0 = _pad_rows(a, chunk_a)
    na = ap.shape[0] // chunk_a
    a_chunks = ap.reshape(na, chunk_a, a.shape[1])

    def row_block(a_blk):
        def body(acc, bv):
            b_blk, v_blk = bv
            return acc + kfn(a_blk, b_blk, sigma) @ v_blk, None

        init = jnp.zeros((a_blk.shape[0], v.shape[1]), jnp.float32)
        out, _ = lax.scan(body, init, (b_chunks, v_chunks))
        return out

    out = lax.map(row_block, a_chunks).reshape(na * chunk_a, v.shape[1])[:m0]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("kernel",))
def kernel_block(
    a: jax.Array, b: jax.Array, sigma: jax.Array, *, kernel: str = "rbf"
) -> jax.Array:
    """Materialize K(a, b).  Reference for the Pallas block-build kernel."""
    return kernel_fn(kernel)(a, b, sigma)
