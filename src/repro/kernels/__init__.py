"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

The paper's per-iteration cost is dominated by streaming kernel-matrix
evaluation (KeOps on GPU); here that is `kernel_matvec` (fused pairwise
kernel x matvec) and `kernel_block` (fused block build), with `ops.py` as
the jit'd dispatch layer and `ref.py` as the pure-jnp oracle.
"""

from repro.kernels import ops, ref
from repro.kernels.kernel_block import kernel_block_pallas
from repro.kernels.kernel_matvec import kernel_matvec_pallas
from repro.kernels.multi import (
    kernel_block_multi_pallas,
    kernel_matvec_components_pallas,
    kernel_matvec_multi_pallas,
)

__all__ = [
    "ops",
    "ref",
    "kernel_block_pallas",
    "kernel_matvec_pallas",
    "kernel_block_multi_pallas",
    "kernel_matvec_components_pallas",
    "kernel_matvec_multi_pallas",
]
