"""Pallas TPU kernel: fused K(A, B) block materialization.

Skotch/ASkotch materialize the b x b block K_BB once per iteration (Nystrom
sketch input + powering matvecs reuse it).  This kernel builds it tile by
tile — pairwise distance on the MXU (or VPU slab-reduction for L1) fused with
the elementwise kernel map, writing each (bm, bn) tile straight from VMEM.

Same tiling contract as kernel_matvec (see that module's docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.kernel_matvec import _apply_kernel, _cast_tiles, _distance_tile


def _block_body(a_ref, b_ref, o_ref, *, kernel: str, sigma: float, dchunk: int):
    # operand tiles at policy width (f32/bf16); distance + map + output f32
    dist = _distance_tile(a_ref[...], b_ref[...], kernel, dchunk)
    o_ref[...] = _apply_kernel(dist, kernel, sigma)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernel", "sigma", "bm", "bn", "dchunk", "interpret", "precision",
    ),
)
def kernel_block_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    dchunk: int = 32,
    interpret: bool = False,
    precision: str = "f32",
) -> jax.Array:
    """Materialize K(a, b): (m, d), (n, d) -> (m, n) f32.

    ``precision="bf16"`` loads the A/B tiles in bf16; the distance
    accumulation and the materialized block stay f32.
    """
    m, d = a.shape
    n = b.shape[0]
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    mp, np_, dp = -(-m // bm) * bm, -(-n // bn) * bn, -(-d // dchunk) * dchunk
    a_p = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    b_p = jnp.pad(b, ((0, np_ - n), (0, dp - d)))
    a_p, b_p = _cast_tiles(precision, a_p, b_p)

    out = pl.pallas_call(
        functools.partial(
            _block_body, kernel=kernel, sigma=float(sigma), dchunk=dchunk
        ),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
