"""Backend dispatch for the fused kernel ops.

Backends:
  "xla"       — chunked pure-jnp streaming (ref.py).  Default on CPU.
  "pallas"    — compiled Pallas TPU kernels.  Default on TPU.
  "interpret" — Pallas kernels in interpret mode (CPU correctness tests).
  "auto"      — "pallas" if a TPU is present else "xla".

All entry points share the contract: never materialize K(a, b) beyond one
(block) tile, accumulate in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kernel_block import kernel_block_pallas
from repro.kernels.kernel_matvec import kernel_matvec_pallas


def _resolve(backend: str) -> str:
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def kernel_matvec(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
) -> jax.Array:
    """out = K(a, b) @ v without materializing K."""
    backend = _resolve(backend)
    if backend == "xla":
        return ref.kernel_matvec(
            a, b, v, jnp.float32(sigma), kernel=kernel, chunk_a=chunk_a, chunk_b=chunk_b
        )
    return kernel_matvec_pallas(
        a, b, v, kernel=kernel, sigma=float(sigma), interpret=(backend == "interpret")
    )


def kernel_block(
    a: jax.Array,
    b: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
) -> jax.Array:
    """Materialize K(a, b) (use for small/medium blocks only)."""
    backend = _resolve(backend)
    if backend == "xla":
        return ref.kernel_block(a, b, jnp.float32(sigma), kernel=kernel)
    return kernel_block_pallas(
        a, b, kernel=kernel, sigma=float(sigma), interpret=(backend == "interpret")
    )
