"""Backend dispatch for the fused kernel ops.

Backends:
  "xla"       — chunked pure-jnp streaming (ref.py).  Default on CPU.
  "pallas"    — compiled Pallas TPU kernels.  Default on TPU.
  "interpret" — Pallas kernels in interpret mode (CPU correctness tests).
  "auto"      — "pallas" if a TPU is present else "xla".

All entry points share the contract: never materialize K(a, b) beyond one
(block) tile, accumulate in f32, and accept multi-RHS value matrices — a
``(n, t)`` v rides the same kernel tiles as a ``(n,)`` v, which is what makes
one-vs-all (t-head) solves cost one kernel sweep instead of t.

Solvers should not call these directly; they go through
``repro.core.operator.KernelOperator``, which owns the (kernel, sigma,
backend, chunking) configuration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import multi, ref
from repro.kernels.kernel_block import kernel_block_pallas
from repro.kernels.kernel_matvec import kernel_matvec_pallas


def resolve_backend(backend: str) -> str:
    """Resolve "auto" to the concrete backend for this process."""
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def kernel_matvec(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
) -> jax.Array:
    """out = K(a, b) @ v without materializing K.

    v: (n,) -> (m,) or (n, t) -> (m, t); all t columns share the kernel tiles.
    """
    backend = resolve_backend(backend)
    if backend == "xla":
        return ref.kernel_matvec(
            a, b, v, jnp.float32(sigma), kernel=kernel, chunk_a=chunk_a, chunk_b=chunk_b
        )
    return kernel_matvec_pallas(
        a, b, v, kernel=kernel, sigma=float(sigma), interpret=(backend == "interpret")
    )


def kernel_block(
    a: jax.Array,
    b: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
) -> jax.Array:
    """Materialize K(a, b) (use for small/medium blocks only)."""
    backend = resolve_backend(backend)
    if backend == "xla":
        return ref.kernel_block(a, b, jnp.float32(sigma), kernel=kernel)
    return kernel_block_pallas(
        a, b, kernel=kernel, sigma=float(sigma), interpret=(backend == "interpret")
    )


# ---------------------------------------------------------------------------
# multi-kernel entry points — same contract, q kernels per data sweep.
# ``kernels``/``sigmas`` are per-kernel tuples; ``weights`` is (q,) for a
# fixed weighted-sum operator or (q, t) for per-column weight vectors (the
# multi-kernel tuning engine).  One streamed pass computes each distance
# family once per tile and applies every kernel map in registers/VMEM.
# ---------------------------------------------------------------------------


def kernel_matvec_multi(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    weights: jax.Array,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
) -> jax.Array:
    """out = (sum_i w_i K_i(a, b)) @ v without materializing any K_i.

    v: (n,) -> (m,) or (n, t) -> (m, t); weights (q,) or per-column (q, t).
    """
    backend = resolve_backend(backend)
    kernels = tuple(kernels)
    w = jnp.asarray(weights, jnp.float32)
    if backend == "xla":
        return ref.kernel_matvec_multi(
            a, b, v, jnp.asarray(sigmas, jnp.float32), w, kernels=kernels,
            chunk_a=chunk_a, chunk_b=chunk_b,
        )
    return multi.kernel_matvec_multi_pallas(
        a, b, v, w, kernels=kernels,
        sigmas=tuple(float(s) for s in sigmas),
        interpret=(backend == "interpret"),
    )


def kernel_matvec_components(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
) -> jax.Array:
    """Stacked per-kernel products (q, m[, t]): out[i] = K_i(a, b) @ v.

    One data sweep serves all q sketches (per-kernel Nystrom factors of the
    multi-kernel tuner come from a single call).
    """
    backend = resolve_backend(backend)
    kernels = tuple(kernels)
    if backend == "xla":
        return ref.kernel_matvec_components(
            a, b, v, jnp.asarray(sigmas, jnp.float32), kernels=kernels,
            chunk_a=chunk_a, chunk_b=chunk_b,
        )
    return multi.kernel_matvec_components_pallas(
        a, b, v, kernels=kernels, sigmas=tuple(float(s) for s in sigmas),
        interpret=(backend == "interpret"),
    )


def kernel_block_multi(
    a: jax.Array,
    b: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    weights: tuple[float, ...],
    backend: str = "auto",
) -> jax.Array:
    """Materialize sum_i w_i K_i(a, b) (small/medium blocks only)."""
    backend = resolve_backend(backend)
    kernels = tuple(kernels)
    if backend == "xla":
        return ref.kernel_block_multi(
            a, b, jnp.asarray(sigmas, jnp.float32),
            jnp.asarray(weights, jnp.float32), kernels=kernels,
        )
    return multi.kernel_block_multi_pallas(
        a, b, kernels=kernels, sigmas=tuple(float(s) for s in sigmas),
        weights=tuple(float(w) for w in weights),
        interpret=(backend == "interpret"),
    )
