"""Backend dispatch for the fused kernel ops.

Backends:
  "xla"       — chunked pure-jnp streaming (ref.py).  Default on CPU.
  "pallas"    — compiled Pallas TPU kernels.  Default on TPU.
  "interpret" — Pallas kernels in interpret mode (CPU correctness tests).
  "auto"      — "pallas" if a TPU is present else "xla".

All entry points share the contract: never materialize K(a, b) beyond one
(block) tile, accumulate in f32, and accept multi-RHS value matrices — a
``(n, t)`` v rides the same kernel tiles as a ``(n,)`` v, which is what makes
one-vs-all (t-head) solves cost one kernel sweep instead of t.

Solvers should not call these directly; they go through
``repro.core.operator.KernelOperator``, which owns the (kernel, sigma,
backend, chunking) configuration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kernel_block import kernel_block_pallas
from repro.kernels.kernel_matvec import kernel_matvec_pallas


def resolve_backend(backend: str) -> str:
    """Resolve "auto" to the concrete backend for this process."""
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def kernel_matvec(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
) -> jax.Array:
    """out = K(a, b) @ v without materializing K.

    v: (n,) -> (m,) or (n, t) -> (m, t); all t columns share the kernel tiles.
    """
    backend = resolve_backend(backend)
    if backend == "xla":
        return ref.kernel_matvec(
            a, b, v, jnp.float32(sigma), kernel=kernel, chunk_a=chunk_a, chunk_b=chunk_b
        )
    return kernel_matvec_pallas(
        a, b, v, kernel=kernel, sigma=float(sigma), interpret=(backend == "interpret")
    )


def kernel_block(
    a: jax.Array,
    b: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
) -> jax.Array:
    """Materialize K(a, b) (use for small/medium blocks only)."""
    backend = resolve_backend(backend)
    if backend == "xla":
        return ref.kernel_block(a, b, jnp.float32(sigma), kernel=kernel)
    return kernel_block_pallas(
        a, b, kernel=kernel, sigma=float(sigma), interpret=(backend == "interpret")
    )
