"""Backend dispatch for the fused kernel ops.

Backends:
  "xla"       — chunked pure-jnp streaming (ref.py).  Default on CPU.
  "pallas"    — compiled Pallas TPU kernels.  Default on TPU.
  "interpret" — Pallas kernels in interpret mode (CPU correctness tests).
  "auto"      — "pallas" if a TPU is present else "xla".

All entry points share the contract: never materialize K(a, b) beyond one
(block) tile, accumulate in f32, and accept multi-RHS value matrices — a
``(n, t)`` v rides the same kernel tiles as a ``(n,)`` v, which is what makes
one-vs-all (t-head) solves cost one kernel sweep instead of t.

Precision policy: every entry point takes ``precision="f32"|"bf16"``.
``"bf16"`` runs the tile/chunk traffic (A/B/V loads + the kernel-times-value
matmul) in bf16 with f32 accumulation — half the HBM/VMEM bytes and the 2x
MXU rate on TPU — while distances, kernel maps and outputs stay f32.
``"f32"`` is bit-identical to the pre-policy behavior.

Sigma canonicalization: dispatch owns ONE cast — ``sigma = float(sigma)``
(tuple-of-float for the multi ops) — so numpy/jnp scalars, python ints and
0-d arrays all reach both backends identically: the Pallas path needs a
hashable static, the xla path wraps the float in ``jnp.float32`` so a bf16
input can never promote or demote the kernel bandwidth.

Solvers should not call these directly; they go through
``repro.core.operator.KernelOperator``, which owns the (kernel, sigma,
backend, chunking, precision) configuration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import multi, ref
from repro.kernels.kernel_block import kernel_block_pallas
from repro.kernels.kernel_matvec import kernel_matvec_pallas
from repro.kernels.precision import PRECISIONS, check_precision

__all__ = [
    "PRECISIONS", "check_precision", "resolve_backend",
    "kernel_matvec", "kernel_block",
    "kernel_matvec_multi", "kernel_matvec_components", "kernel_block_multi",
]


def resolve_backend(backend: str) -> str:
    """Resolve "auto" to the concrete backend for this process."""
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def kernel_matvec(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
) -> jax.Array:
    """out = K(a, b) @ v without materializing K.

    v: (n,) -> (m,) or (n, t) -> (m, t); all t columns share the kernel tiles.
    """
    backend = resolve_backend(backend)
    precision = check_precision(precision)
    sigma = float(sigma)
    if backend == "xla":
        return ref.kernel_matvec(
            a, b, v, jnp.float32(sigma), kernel=kernel, chunk_a=chunk_a,
            chunk_b=chunk_b, precision=precision,
        )
    return kernel_matvec_pallas(
        a, b, v, kernel=kernel, sigma=sigma,
        interpret=(backend == "interpret"), precision=precision,
    )


def kernel_block(
    a: jax.Array,
    b: jax.Array,
    *,
    kernel: str = "rbf",
    sigma: float = 1.0,
    backend: str = "auto",
    precision: str = "f32",
) -> jax.Array:
    """Materialize K(a, b) (use for small/medium blocks only)."""
    backend = resolve_backend(backend)
    precision = check_precision(precision)
    sigma = float(sigma)
    if backend == "xla":
        return ref.kernel_block(
            a, b, jnp.float32(sigma), kernel=kernel, precision=precision
        )
    return kernel_block_pallas(
        a, b, kernel=kernel, sigma=sigma,
        interpret=(backend == "interpret"), precision=precision,
    )


# ---------------------------------------------------------------------------
# multi-kernel entry points — same contract, q kernels per data sweep.
# ``kernels``/``sigmas`` are per-kernel tuples; ``weights`` is (q,) for a
# fixed weighted-sum operator or (q, t) for per-column weight vectors (the
# multi-kernel tuning engine).  One streamed pass computes each distance
# family once per tile and applies every kernel map in registers/VMEM.
# ---------------------------------------------------------------------------


def kernel_matvec_multi(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    weights: jax.Array,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
) -> jax.Array:
    """out = (sum_i w_i K_i(a, b)) @ v without materializing any K_i.

    v: (n,) -> (m,) or (n, t) -> (m, t); weights (q,) or per-column (q, t).
    """
    backend = resolve_backend(backend)
    precision = check_precision(precision)
    kernels = tuple(kernels)
    sigmas = tuple(float(s) for s in sigmas)
    w = jnp.asarray(weights, jnp.float32)
    if backend == "xla":
        return ref.kernel_matvec_multi(
            a, b, v, jnp.asarray(sigmas, jnp.float32), w, kernels=kernels,
            chunk_a=chunk_a, chunk_b=chunk_b, precision=precision,
        )
    return multi.kernel_matvec_multi_pallas(
        a, b, v, w, kernels=kernels, sigmas=sigmas,
        interpret=(backend == "interpret"), precision=precision,
    )


def kernel_matvec_components(
    a: jax.Array,
    b: jax.Array,
    v: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
) -> jax.Array:
    """Stacked per-kernel products (q, m[, t]): out[i] = K_i(a, b) @ v.

    One data sweep serves all q sketches (per-kernel Nystrom factors of the
    multi-kernel tuner come from a single call).
    """
    backend = resolve_backend(backend)
    precision = check_precision(precision)
    kernels = tuple(kernels)
    sigmas = tuple(float(s) for s in sigmas)
    if backend == "xla":
        return ref.kernel_matvec_components(
            a, b, v, jnp.asarray(sigmas, jnp.float32), kernels=kernels,
            chunk_a=chunk_a, chunk_b=chunk_b, precision=precision,
        )
    return multi.kernel_matvec_components_pallas(
        a, b, v, kernels=kernels, sigmas=sigmas,
        interpret=(backend == "interpret"), precision=precision,
    )


def kernel_block_multi(
    a: jax.Array,
    b: jax.Array,
    *,
    kernels: tuple[str, ...],
    sigmas: tuple[float, ...],
    weights: tuple[float, ...],
    backend: str = "auto",
    precision: str = "f32",
) -> jax.Array:
    """Materialize sum_i w_i K_i(a, b) (small/medium blocks only)."""
    backend = resolve_backend(backend)
    precision = check_precision(precision)
    kernels = tuple(kernels)
    sigmas = tuple(float(s) for s in sigmas)
    if backend == "xla":
        return ref.kernel_block_multi(
            a, b, jnp.asarray(sigmas, jnp.float32),
            jnp.asarray(weights, jnp.float32), kernels=kernels,
            precision=precision,
        )
    return multi.kernel_block_multi_pallas(
        a, b, kernels=kernels, sigmas=sigmas,
        weights=tuple(float(w) for w in weights),
        interpret=(backend == "interpret"), precision=precision,
    )
