"""Fault-tolerant checkpointing: atomic, manifest-driven, elastic.

Layout (one directory per step):

    <root>/step_000123.tmp/      # written first
        arrays.npz               # flattened pytree leaves ('a.b.c' keys)
        manifest.json            # step, config name, PRNG/data state, tree meta
    <root>/step_000123/          # atomic rename on success

Restore is **elastic**: arrays are loaded host-side and ``device_put`` with
whatever sharding the *current* mesh prescribes, so a run checkpointed on a
2x16x16 mesh restarts unchanged on 16x16 (or a test mesh) — the logical-axis
spec system makes this a pure relayout.  At true multi-host scale each host
would write its addressable shards (same manifest format, per-host npz);
single-process here writes the full arrays.

``latest_step``/``restore`` skip ``.tmp`` directories, so a crash mid-write
can never be mistaken for a valid checkpoint (crash-consistency test covers
this).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any

import jax
import numpy as np

SEP = "||"


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def walk(node, prefix):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{prefix}{SEP}{k}" if prefix else str(k))
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(v, f"{prefix}{SEP}#{i}" if prefix else f"#{i}")
        else:
            flat[prefix] = node

    walk(tree, "")
    return flat


def _unflatten(flat: dict[str, Any]):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if isinstance(node, dict) and node and all(k.startswith("#") for k in node):
            return tuple(fix(node[f"#{i}"]) for i in range(len(node)))
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def save(root: str, step: int, state: dict, extra: dict | None = None,
         keep_last: int = 3) -> str:
    """Atomically persist `state` (a pytree of arrays) + metadata."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(arrays.keys()),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic on POSIX
    _gc(root, keep_last)
    return final


def _gc(root: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(root, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(root: str, step: int | None = None, shardings=None):
    """Load a checkpoint; device_put with `shardings` (elastic relayout).

    Returns (state, manifest_extra, step)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    path = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten(flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return state, manifest["extra"], step
