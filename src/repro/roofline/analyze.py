"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per assignment):
    compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_operand_bytes_per_device / ICI_BW

``cost_analysis()`` on the partitioned module is per-device.  XLA counts
while-loop bodies ONCE, so scanned-layer models undercount by ~L x; the
dry-run therefore also compiles small UNROLLED probes (L=1, L=2,
microbatches=1, unchunked attention) and extrapolates:

    per_layer = cost(L=2) - cost(L=1);   total = cost(L=1) + per_layer*(L-1)

The same probe-diff is applied to collective bytes parsed out of the HLO
text (operand shapes resolved through an instruction-definition table).
"""

from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_DEF_RE = re.compile(r"%([\w.\-]+) = \(?([a-z0-9]+)\[([\d,]*)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device operand bytes of every collective op, by op kind.

    Resolves operand names through the definition table; ops inside while
    bodies are counted once (see module docstring for the probe correction).
    """
    defs: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        defs[m.group(1)] = _shape_bytes(m.group(2), m.group(3))

    totals = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%([\w.\-]+) = .*? ([a-z\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        matched = next(
            (c for c in COLLECTIVE_OPS if op == c or op.startswith(c + "-")), None
        )
        if matched is None:
            continue
        # operand list between the first '(' after the op name and its ')'
        call = stripped[stripped.index(op + "(") + len(op) + 1 :]
        operands = re.findall(r"%([\w.\-]+)", call.split(")")[0])
        size = sum(defs.get(o, 0) for o in operands)
        if size == 0:  # fallback: output shape
            sm = _SHAPE_RE.search(stripped)
            if sm:
                size = _shape_bytes(sm.group(1), sm.group(2))
        totals[matched] += size
    return totals


@dataclasses.dataclass
class CellCost:
    flops: float  # per device
    bytes_accessed: float  # per device
    coll_bytes: float  # per device
    coll_breakdown: dict[str, float]


def cell_cost(compiled) -> CellCost:
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown={k: float(v) for k, v in coll.items()},
    )


def extrapolate(base1: CellCost, base2: CellCost, layers_probe_delta: int,
                layers_full_minus_probe1: int) -> CellCost:
    """cost(L_full) from two unrolled probes."""

    def ext(a1, a2):
        per = max((a2 - a1) / max(layers_probe_delta, 1), 0.0)
        return a1 + per * layers_full_minus_probe1

    breakdown = {
        k: ext(base1.coll_breakdown.get(k, 0), base2.coll_breakdown.get(k, 0))
        for k in COLLECTIVE_OPS
    }
    return CellCost(
        flops=ext(base1.flops, base2.flops),
        bytes_accessed=ext(base1.bytes_accessed, base2.bytes_accessed),
        coll_bytes=sum(breakdown.values()),
        coll_breakdown=breakdown,
    )


def roofline_terms(cost: CellCost) -> dict[str, float]:
    compute = cost.flops / hw.PEAK_FLOPS_BF16
    memory = cost.bytes_accessed / hw.HBM_BW
    collective = cost.coll_bytes / hw.ICI_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }


def model_flops(n_params: int, n_active: int, tokens: int, train: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode/prefill use the fwd 2*N*D."""
    n = n_active or n_params
    per_token = 6.0 * n if train else 2.0 * n
    return per_token * tokens
