"""Turn results/dryrun/*.json into the EXPERIMENTS.md §Dry-run / §Roofline
tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys

from repro.roofline import analyze, hw


def load(out_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def _terms(rec: dict) -> dict | None:
    cost = rec.get("cost_extrapolated") or rec.get("cost_raw")
    if not cost:
        return None
    c = analyze.CellCost(
        flops=cost["flops"], bytes_accessed=cost["bytes_accessed"],
        coll_bytes=cost["coll_bytes"], coll_breakdown=cost.get("coll_breakdown", {}),
    )
    return analyze.roofline_terms(c)


def dryrun_table(recs: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | status | arg GiB/dev | temp GiB/dev | fits 16G | top collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | — | "
                f"{r.get('reason', r.get('error', ''))[:60]} |"
            )
            continue
        mem = r["memory"]
        total = mem["argument_bytes"] + mem["temp_bytes"]
        fits = "yes" if total <= hw.HBM_BYTES else f"no ({total/2**30:.1f}G)"
        coll = (r.get("cost_extrapolated") or r.get("cost_raw", {})).get(
            "coll_breakdown", {}
        )
        top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
        coll_s = "; ".join(f"{k}:{v/2**20:.0f}M" for k, v in top if v > 0) or "none"
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt_bytes(mem['argument_bytes'])} "
            f"| {_fmt_bytes(mem['temp_bytes'])} | {fits} | {coll_s} |"
        )
    return "\n".join(rows)


def roofline_table(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO_FLOPS |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != "single" or r["status"] != "ok":
            continue
        t = _terms(r)
        if t is None:
            continue
        cost = r.get("cost_extrapolated") or r.get("cost_raw")
        hlo_total = cost["flops"] * hw.SINGLE_POD_CHIPS
        ratio = r.get("model_flops_total", 0) / hlo_total if hlo_total else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | {t['dominant']} | "
            f"{ratio:.2f} |"
        )
    return "\n".join(rows)


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(out_dir)
    print("## §Dry-run (single-pod 16x16, 256 chips)\n")
    print(dryrun_table(recs, "single"))
    print("\n## §Dry-run (multi-pod 2x16x16, 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## §Roofline (single-pod, per-device terms)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
