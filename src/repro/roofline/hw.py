"""Target hardware constants (TPU v5e-class, per assignment)."""

PEAK_FLOPS_BF16 = 197e12  # per chip
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 2  # MXU f32 passthrough runs at half rate
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 2**30  # 16 GiB per chip

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
