"""repro.obs — unified telemetry: spans, metrics, and solver traces.

One dependency-free subsystem replaces the repo's three ad-hoc measurement
paths (tune-engine ``SweepCounter`` pair accounting, per-solver ``history``
dicts, ``ServingEngine.stats()`` latency lists):

  * **spans** (:mod:`repro.obs.spans`) — nested wall+CPU timed regions via a
    contextvar stack; thread-safe; no-op by default.
  * **metrics** (:mod:`repro.obs.metrics`) — process-global counters /
    gauges / bounded histograms (kernel pairs, tile FLOPs+bytes by dtype,
    CG iterations, distributed collective dispatches, serving queue depth),
    with ``snapshot()/diff()`` for benchmarks and Prometheus text exposition.
  * **traces** (:mod:`repro.obs.trace`) — one canonical per-iteration record
    emitted by every solver through :class:`TraceRecorder`, with the legacy
    ``history`` shape kept as a compatibility view.

Thread a :class:`Telemetry` session through the public entry points::

    tel = Telemetry(jsonl="run.jsonl")
    result = solve(problem, method="askotch", telemetry=tel)
    tel.close()
    validate_jsonl("run.jsonl")   # strict schema check

``telemetry=None`` (the default) resolves to the shared disabled session;
the disabled path is an identity check, <5% overhead on a small solve.
See docs/observability.md for the quickstart and the event schema reference.
"""

from repro.obs.metrics import (
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    counter,
    diff,
    gauge,
    histogram,
    log_buckets,
    prometheus_text,
    record_tile_work,
    snapshot,
)
from repro.obs.sinks import NULL_SINK, JsonlSink, MultiSink, NullSink, RingSink
from repro.obs.spans import current_span_id, set_sink, span
from repro.obs.telemetry import NULL_TELEMETRY, Telemetry, as_telemetry
from repro.obs.trace import SCHEMAS, TraceRecorder, validate_event, validate_jsonl

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MultiSink",
    "NULL_SINK",
    "NULL_TELEMETRY",
    "NullSink",
    "REGISTRY",
    "RingSink",
    "SCHEMAS",
    "Telemetry",
    "TraceRecorder",
    "as_telemetry",
    "counter",
    "current_span_id",
    "diff",
    "gauge",
    "histogram",
    "log_buckets",
    "prometheus_text",
    "record_tile_work",
    "set_sink",
    "snapshot",
    "span",
    "validate_event",
    "validate_jsonl",
]
