"""Contextvar-based span tracer: nested timed regions, thread-safe.

``with span("solve/askotch", n=n, t=t): ...`` times a region with both the
wall clock and the process CPU clock and emits one structured event at exit:

    {"type": "span", "name": ..., "t_wall": ..., "dur_s": ..., "cpu_s": ...,
     "span_id": ..., "parent_id": ..., "depth": ..., "thread": ...,
     "attrs": {...}}

Nesting is tracked through a :mod:`contextvars` stack, so each thread (the
serving engine's worker plus any number of client threads) gets its own
independent span tree while sharing one sink — the sink itself serializes
writes.  ``parent_id`` stitches the tree back together offline.

The module-level default sink is :data:`~repro.obs.sinks.NULL_SINK`; with it
active :func:`span` returns a shared no-op context manager without allocating
anything, so un-configured telemetry costs one identity check per call site.
Per-session sinks (the usual path) come from
:class:`repro.obs.telemetry.Telemetry`, which passes its sink explicitly.
"""

from __future__ import annotations

import contextvars
import itertools
import threading
import time

from repro.obs.sinks import NULL_SINK

__all__ = ["NULL_SPAN", "Span", "current_span_id", "set_sink", "span"]

_ids = itertools.count(1)
#: per-context stack of active span ids (tuple → copy-on-write, thread-safe)
_stack: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_obs_span_stack", default=()
)


class _NullSpan:
    """Shared no-op context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


#: the singleton returned by :func:`span` when the sink is disabled
NULL_SPAN = _NullSpan()


class Span:
    """One timed region; use as a context manager.

    Records ``time.perf_counter`` (wall) and ``time.process_time`` (CPU) at
    entry, pushes itself on the context stack, and on exit emits a single
    ``type="span"`` event to its sink with durations, ids, nesting depth,
    thread name, and any keyword attributes given at creation.
    """

    __slots__ = ("name", "sink", "attrs", "span_id", "parent_id", "depth",
                 "_t0", "_c0", "_t_wall", "_token")

    def __init__(self, name: str, sink, attrs: dict):
        self.name = name
        self.sink = sink
        self.attrs = attrs
        self.span_id = next(_ids)

    def __enter__(self):
        stack = _stack.get()
        self.parent_id = stack[-1] if stack else 0
        self.depth = len(stack)
        self._token = _stack.set(stack + (self.span_id,))
        self._t_wall = time.time()
        self._c0 = time.process_time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        cpu = time.process_time() - self._c0
        _stack.reset(self._token)
        event = {
            "type": "span",
            "name": self.name,
            "t_wall": self._t_wall,
            "dur_s": dur,
            "cpu_s": cpu,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "depth": self.depth,
            "thread": threading.current_thread().name,
        }
        if self.attrs:
            event["attrs"] = self.attrs
        self.sink.emit(event)
        return False


_default_sink = NULL_SINK


def set_sink(sink) -> None:
    """Install ``sink`` as the module-level default for bare :func:`span`
    calls (pass :data:`~repro.obs.sinks.NULL_SINK` to disable again).
    Telemetry sessions normally pass their sink explicitly instead."""
    global _default_sink
    _default_sink = sink if sink is not None else NULL_SINK


def span(name: str, *, sink=None, **attrs):
    """Open a timed span named ``name`` (use as a context manager).

    ``attrs`` keyword values are attached verbatim to the emitted event.
    With no sink configured this returns the shared :data:`NULL_SPAN`
    no-op — the disabled path allocates nothing.
    """
    s = _default_sink if sink is None else sink
    if s is NULL_SINK:
        return NULL_SPAN
    return Span(name, s, attrs)


def current_span_id() -> int:
    """Id of the innermost active span in this context (0 when outside
    any span) — lets detached work (e.g. serving batches) link events to
    the span that enqueued them."""
    stack = _stack.get()
    return stack[-1] if stack else 0
