"""Summary CLI for telemetry JSONL files (``python -m benchmarks.run
obs-report <file.jsonl> [...]``).

Validates every line against the strict schemas in ``repro.obs.trace`` (so
CI can use this as its schema gate), then prints a human summary per file:
event counts by type, the slowest top-level spans, per-solver trace
convergence (first/last rel_residual, iterations, wall), and the metric
snapshot embedded at close.  ``--no-validate`` skips the schema gate for
quick looks at partial files.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import validate_jsonl

__all__ = ["main", "summarize"]


def _load(path: str) -> list[dict]:
    events = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def summarize(path: str) -> dict:
    """Structured summary of one telemetry JSONL file.

    Returns ``{"path", "counts", "spans", "traces", "metrics"}`` where
    ``spans`` lists the top spans by duration, ``traces`` maps solver name
    to {iters, first/last rel_residual, wall_s}, and ``metrics`` is the
    flushed end-of-run snapshot.
    """
    events = _load(path)
    counts: dict[str, int] = {}
    for e in events:
        counts[e.get("type", "?")] = counts.get(e.get("type", "?"), 0) + 1

    spans = sorted(
        (e for e in events if e.get("type") == "span"),
        key=lambda e: -e.get("dur_s", 0.0),
    )[:10]
    span_rows = [
        {"name": e["name"], "dur_s": e["dur_s"], "cpu_s": e["cpu_s"],
         "depth": e["depth"], "thread": e["thread"]}
        for e in spans
    ]

    traces: dict[str, dict] = {}
    for e in events:
        if e.get("type") != "trace":
            continue
        t = traces.setdefault(e["solver"], {
            "iters": 0, "first_rel_residual": e["rel_residual"],
            "last_rel_residual": e["rel_residual"], "wall_s": 0.0,
        })
        t["iters"] += 1
        t["last_rel_residual"] = e["rel_residual"]
        t["wall_s"] = max(t["wall_s"], e["wall_s"])
        if "sweeps" in e:
            t["sweeps"] = e["sweeps"]

    metrics = {}
    for e in events:
        if e.get("type") == "metric":
            key = e["name"]
            if e.get("labels"):
                inner = ",".join(f'{k}="{v}"' for k, v in sorted(e["labels"].items()))
                key = f"{key}{{{inner}}}"
            metrics[key] = e["value"]

    return {"path": path, "counts": counts, "spans": span_rows,
            "traces": traces, "metrics": metrics}


def _print_summary(s: dict) -> None:
    print(f"== {s['path']}")
    print("  events: " + ", ".join(
        f"{k}={v}" for k, v in sorted(s["counts"].items())) or "  (empty)")
    if s["spans"]:
        print("  slowest spans:")
        for row in s["spans"][:5]:
            print(f"    {row['dur_s']:9.4f}s cpu {row['cpu_s']:8.4f}s  "
                  f"{'  ' * row['depth']}{row['name']}  [{row['thread']}]")
    for solver, t in sorted(s["traces"].items()):
        extra = f", sweeps={t['sweeps']:.2f}" if "sweeps" in t else ""
        print(f"  trace[{solver}]: {t['iters']} iters, rel_residual "
              f"{t['first_rel_residual']:.3e} -> {t['last_rel_residual']:.3e}, "
              f"wall {t['wall_s']:.3f}s{extra}")
    if s["metrics"]:
        print("  metrics:")
        for k, v in sorted(s["metrics"].items()):
            print(f"    {k} = {v:g}")


def main(argv=None) -> int:
    """Entry point: validate (by default) and summarize each given file.

    Returns a nonzero exit code if any file fails schema validation.
    """
    ap = argparse.ArgumentParser(
        prog="obs-report", description="Summarize repro telemetry JSONL files."
    )
    ap.add_argument("paths", nargs="+", help="telemetry .jsonl files")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip strict schema validation")
    args = ap.parse_args(argv)

    rc = 0
    for path in args.paths:
        if not args.no_validate:
            try:
                counts = validate_jsonl(path)
            except (OSError, ValueError) as e:
                print(f"== {path}\n  SCHEMA FAIL: {e}", file=sys.stderr)
                rc = 1
                continue
            print(f"== schema OK: {path} ({sum(counts.values())} events)")
        _print_summary(summarize(path))
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
