"""Event sinks — where telemetry events go.

Every pillar of ``repro.obs`` (spans, solver traces, the metric snapshot a
:class:`~repro.obs.telemetry.Telemetry` session flushes at close) emits plain
JSON-able dicts through one ``Sink`` interface:

  * :data:`NULL_SINK` — the process-wide no-op default.  ``emit`` is a bound
    no-op method, so a disabled telemetry path costs one attribute check.
  * :class:`RingSink` — a bounded in-memory ring buffer (``collections.deque``)
    for tests and live inspection; ``events()`` copies the current contents.
  * :class:`JsonlSink` — one JSON object per line, appended under a lock so
    serving worker + client threads never interleave partial lines.
  * :class:`MultiSink` — fan-out to several sinks at once.

Sinks are deliberately dependency-free (stdlib only) and never raise out of
``emit`` on shutdown races; schema enforcement lives in ``repro.obs.trace``.
"""

from __future__ import annotations

import collections
import json
import threading
from typing import Any, Iterable

__all__ = ["JsonlSink", "MultiSink", "NULL_SINK", "NullSink", "RingSink"]


class NullSink:
    """The disabled-path sink: swallows every event.

    A single shared instance (:data:`NULL_SINK`) is the default everywhere,
    so ``sink is NULL_SINK`` is the one-branch fast path that keeps disabled
    telemetry at near-zero overhead.
    """

    __slots__ = ()

    def emit(self, event: dict) -> None:
        """Drop the event."""

    def close(self) -> None:
        """Nothing to release."""


#: the shared no-op sink — identity-compared by the span/recorder fast paths
NULL_SINK = NullSink()


class RingSink:
    """Bounded in-memory event buffer (newest ``capacity`` events kept).

    ``collections.deque`` appends are atomic under the GIL, so concurrent
    emitters need no extra locking; ``events()`` returns a list copy.
    """

    def __init__(self, capacity: int = 4096):
        self._buf: collections.deque = collections.deque(maxlen=int(capacity))

    def emit(self, event: dict) -> None:
        """Append ``event`` (a dict) to the ring, evicting the oldest."""
        self._buf.append(event)

    def events(self) -> list[dict]:
        """Snapshot of the buffered events, oldest first."""
        return list(self._buf)

    def close(self) -> None:
        """Keep the buffer readable after close (tests inspect it)."""


class JsonlSink:
    """Append events to ``path`` as JSON Lines, one object per line.

    A lock serializes writes — the serving engine's worker thread and any
    number of client threads can share one sink without interleaving lines.
    Values that are not JSON-native (numpy scalars, jax arrays) are coerced
    through ``float``/``str`` by the encoder's ``default`` hook.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._fh = open(self.path, "a")

    @staticmethod
    def _default(obj: Any):
        try:
            return float(obj)
        except (TypeError, ValueError):
            return str(obj)

    def emit(self, event: dict) -> None:
        """Write one JSON line (locked; silently drops after close)."""
        line = json.dumps(event, default=self._default)
        with self._lock:
            if self._fh.closed:
                return
            self._fh.write(line + "\n")

    def close(self) -> None:
        """Flush and close the file (idempotent)."""
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                self._fh.close()


class MultiSink:
    """Fan one event stream out to several sinks."""

    def __init__(self, sinks: Iterable):
        self.sinks = tuple(sinks)

    def emit(self, event: dict) -> None:
        """Emit to every child sink in order."""
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        """Close every child sink."""
        for s in self.sinks:
            s.close()
