"""The per-run telemetry session object threaded through the public APIs.

:class:`Telemetry` bundles a sink with span/recorder factories so one object
flows through ``solve(..., telemetry=tel)``, ``tune(..., telemetry=tel)``,
``ServingEngine(..., telemetry=tel)``, and the ``--telemetry PATH`` launch
flags:

    >>> from repro.obs import Telemetry
    >>> tel = Telemetry(ring=True)          # or jsonl="/tmp/run.jsonl"
    >>> with tel.span("demo", n=4):
    ...     pass
    >>> tel.close()

``close()`` flushes a final batch of ``type="metric"`` events (the global
registry's snapshot, so the JSONL is self-contained) and closes the sink.

:data:`NULL_TELEMETRY` is the shared disabled instance; :func:`as_telemetry`
maps ``None`` to it so every instrumented call site can do
``tel = as_telemetry(telemetry)`` and then branch on the precomputed
``tel.enabled`` bool — the whole disabled path is one attribute load per
iteration, measured <5% overhead on a small solve (tests/test_obs.py).

Optional ``profiler=True`` additionally wraps spans in
``jax.profiler.TraceAnnotation`` so they show up on the device timeline
(lazy import; silently unavailable without jax).
"""

from __future__ import annotations

from repro.obs import metrics as _metrics
from repro.obs.sinks import NULL_SINK, JsonlSink, MultiSink, RingSink
from repro.obs.spans import NULL_SPAN, Span
from repro.obs.trace import TraceRecorder

__all__ = ["NULL_TELEMETRY", "Telemetry", "as_telemetry"]


class Telemetry:
    """One telemetry session: a sink plus span / trace-recorder factories.

    Construct with ``jsonl=path`` (file), ``ring=True`` / ``ring=RingSink``
    (in-memory), an explicit ``sink=``, or nothing (disabled).  Passing more
    than one of jsonl/ring/sink fans out through a ``MultiSink``.
    """

    def __init__(self, *, jsonl=None, ring=None, sink=None, profiler=False):
        sinks = []
        self.ring = None
        if jsonl is not None:
            sinks.append(JsonlSink(jsonl))
        if ring:
            self.ring = ring if isinstance(ring, RingSink) else RingSink()
            sinks.append(self.ring)
        if sink is not None:
            sinks.append(sink)
        if not sinks:
            self.sink = NULL_SINK
        elif len(sinks) == 1:
            self.sink = sinks[0]
        else:
            self.sink = MultiSink(sinks)
        self.profiler = bool(profiler)
        self._closed = False

    @property
    def enabled(self) -> bool:
        """True when events actually go somewhere — instrumented hot loops
        read this once up front and skip per-iteration work when False."""
        return self.sink is not NULL_SINK

    def span(self, name: str, **attrs):
        """Open a span on this session's sink (no-op when disabled).

        With ``profiler=True`` the span is additionally annotated on the
        jax device timeline via ``jax.profiler.TraceAnnotation``.
        """
        if self.sink is NULL_SINK and not self.profiler:
            return NULL_SPAN
        s = Span(name, self.sink, attrs) if self.sink is not NULL_SINK else NULL_SPAN
        if not self.profiler:
            return s
        return _ProfiledSpan(name, s)

    def recorder(self, solver: str, *, precision=None, sweep_counter=None,
                 n=None) -> TraceRecorder:
        """Create a :class:`~repro.obs.trace.TraceRecorder` bound to this
        session (legacy ``history`` always recorded; events when enabled)."""
        return TraceRecorder(solver, precision=precision, telemetry=self,
                             sweep_counter=sweep_counter, n=n)

    def emit_metrics(self) -> None:
        """Emit one ``type="metric"`` event per global-registry series so
        the JSONL stream is self-contained (no separate scrape needed)."""
        if self.sink is NULL_SINK:
            return
        with _metrics.REGISTRY._lock:
            items = sorted(_metrics.REGISTRY._metrics.items())
        for (name, lk), m in items:
            if m.kind == "histogram":
                vals = {"_count": float(m.count), "_sum": float(m.sum)}
            else:
                vals = {"": float(m.value)}
            for suffix, v in vals.items():
                event = {"type": "metric", "name": name + suffix,
                         "kind": m.kind, "value": v}
                if lk:
                    event["labels"] = dict(lk)
                self.sink.emit(event)

    def close(self) -> None:
        """Flush the metric snapshot into the stream and close the sink
        (idempotent)."""
        if self._closed:
            return
        self._closed = True
        self.emit_metrics()
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _ProfiledSpan:
    """Span wrapper that mirrors the region onto the jax profiler timeline."""

    __slots__ = ("_span", "_annot")

    def __init__(self, name: str, inner):
        self._span = inner
        try:
            from jax.profiler import TraceAnnotation
            self._annot = TraceAnnotation(name)
        except Exception:  # jax absent or profiler unavailable
            self._annot = None

    def __enter__(self):
        if self._annot is not None:
            self._annot.__enter__()
        self._span.__enter__()
        return self

    def __exit__(self, *exc):
        self._span.__exit__(*exc)
        if self._annot is not None:
            self._annot.__exit__(*exc)
        return False


#: the shared disabled session — what ``telemetry=None`` resolves to
NULL_TELEMETRY = Telemetry()


def as_telemetry(obj) -> Telemetry:
    """Coerce a ``telemetry=`` argument: ``None`` → :data:`NULL_TELEMETRY`,
    a :class:`Telemetry` passes through, anything else raises."""
    if obj is None:
        return NULL_TELEMETRY
    if isinstance(obj, Telemetry):
        return obj
    raise TypeError(
        f"telemetry= expects a repro.obs.Telemetry or None, got {type(obj).__name__}"
    )
