"""Process-global metrics registry: counters, gauges, log-bucket histograms.

One registry (:data:`REGISTRY`) serves the whole stack — kernel-pair sweeps
(``repro_kernel_pairs_total``, fed by the tune engine's ``SweepCounter``),
tile FLOPs/bytes by dtype (:func:`record_tile_work`, the same cost model as
``benchmarks/bench_kernels.tile_roofline``), CG iterations, the distributed
operator's psum/all_gather dispatch counts, and the serving engine's queue
depth.  Everything is stdlib-only and thread-safe (one lock per metric, one
for registration).

Three consumption paths:

  * :func:`snapshot` / :func:`diff` — flat ``{metric_key: value}`` dicts;
    benchmarks bracket a run with two snapshots and persist the diff.
  * :func:`prometheus_text` — the Prometheus text exposition format
    (``# TYPE`` headers, ``_bucket{le=...}``/``_sum``/``_count`` histogram
    series) for scraping or file export.
  * Direct handles — ``counter(name).inc()`` etc.; handles are get-or-create
    and re-fetching by (name, labels) returns the same object.

:class:`Histogram` uses FIXED log-spaced buckets (:func:`log_buckets`), so
memory is bounded no matter how many observations arrive — the serving
engine's per-model latency stats ride this instead of an unbounded list.
Quantiles interpolate linearly inside the hit bucket.
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "diff",
    "gauge",
    "histogram",
    "log_buckets",
    "prometheus_text",
    "record_tile_work",
    "roofline_time_s",
    "snapshot",
]


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> tuple[float, ...]:
    """Log-spaced bucket upper bounds from ``lo`` to (at least) ``hi``.

    ``per_decade`` bounds per factor of 10; the ladder always includes ``hi``
    so the overflow bucket only catches true outliers.
    """
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got lo={lo}, hi={hi}")
    steps = int(math.ceil(math.log10(hi / lo) * per_decade))
    bounds = [lo * 10 ** (i / per_decade) for i in range(steps + 1)]
    bounds[-1] = max(bounds[-1], hi)
    return tuple(bounds)


#: default latency ladder (milliseconds): 10 us .. 100 s, 3 buckets/decade
LATENCY_BUCKETS_MS = log_buckets(1e-2, 1e5, per_decade=3)


def _label_key(labels: "Mapping[str, str] | None") -> tuple:
    return () if not labels else tuple(sorted(labels.items()))


def _series_name(name: str, label_key: tuple) -> str:
    if not label_key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in label_key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing float counter (thread-safe)."""

    kind = "counter"
    __slots__ = ("name", "labels", "help", "_value", "_lock")

    def __init__(self, name: str, labels: tuple = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        """Add ``v`` (must be >= 0) to the counter."""
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (v={v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        """Current cumulative value."""
        return self._value


class Gauge:
    """Instantaneous value that can move both ways (thread-safe)."""

    kind = "gauge"
    __slots__ = ("name", "labels", "help", "_value", "_lock")

    def __init__(self, name: str, labels: tuple = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        """Set the gauge to ``v``."""
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        """Add ``v`` to the gauge."""
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        """Subtract ``v`` from the gauge."""
        self.inc(-v)

    @property
    def value(self) -> float:
        """Current value."""
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    Bucket bounds are set at construction (default :data:`LATENCY_BUCKETS_MS`)
    and never grow, so memory stays O(len(bounds)) regardless of observation
    count — the bounded replacement for keeping raw latency lists.  Usable
    standalone (the serving engine keeps one per model) or via the registry.
    """

    kind = "histogram"
    __slots__ = ("name", "labels", "help", "bounds", "_counts", "_sum",
                 "_count", "_lock")

    def __init__(self, name: str, labels: tuple = (), help: str = "",
                 buckets: "tuple[float, ...] | None" = None):
        self.name, self.labels, self.help = name, labels, help
        self.bounds = tuple(buckets if buckets is not None else LATENCY_BUCKETS_MS)
        if list(self.bounds) != sorted(self.bounds) or not self.bounds:
            raise ValueError("histogram buckets must be non-empty ascending")
        self._counts = [0] * (len(self.bounds) + 1)  # +1 overflow
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        """Record one observation."""
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        """Total observations recorded."""
        return self._count

    @property
    def sum(self) -> float:
        """Exact sum of all observations."""
        return self._sum

    @property
    def mean(self) -> float:
        """Exact mean (0.0 when empty)."""
        return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile, interpolated inside the hit bucket.

        Exact sums/counts make the mean exact; quantiles are bucket-resolution
        estimates (overflow observations report the top bound).  0.0 when
        empty.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            cum += c
            if cum >= target and c:
                if i == len(self.bounds):  # overflow bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                frac = (target - (cum - c)) / c
                return lo + frac * (hi - lo)
        return self.bounds[-1]

    def reset(self) -> None:
        """Zero every bucket and the sum/count (long-running servers)."""
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._sum = 0.0
            self._count = 0

    def bucket_counts(self) -> "list[tuple[float, int]]":
        """Cumulative (upper_bound, count) pairs, Prometheus ``le`` style
        (the final pair is ``(inf, total)``)."""
        with self._lock:
            counts = list(self._counts)
        out, cum = [], 0
        for b, c in zip(self.bounds, counts):
            cum += c
            out.append((b, cum))
        out.append((math.inf, cum + counts[-1]))
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Thread-safe get-or-create store of metrics keyed by (name, labels).

    The process-global instance is :data:`REGISTRY`; the module-level
    :func:`counter`/:func:`gauge`/:func:`histogram`/:func:`snapshot`/
    :func:`prometheus_text` helpers all operate on it.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple[str, tuple], Any] = {}

    def _get(self, kind: str, name: str, labels, help, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = _KINDS[kind](name, labels=key[1], help=help, **kw)
                self._metrics[key] = m
            elif m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}"
                )
            return m

    def counter(self, name: str, labels=None, help: str = "") -> Counter:
        """Get-or-create a :class:`Counter`."""
        return self._get("counter", name, labels, help)

    def gauge(self, name: str, labels=None, help: str = "") -> Gauge:
        """Get-or-create a :class:`Gauge`."""
        return self._get("gauge", name, labels, help)

    def histogram(self, name: str, labels=None, help: str = "",
                  buckets=None) -> Histogram:
        """Get-or-create a :class:`Histogram` (fixed ``buckets``)."""
        return self._get("histogram", name, labels, help, buckets=buckets)

    def snapshot(self) -> dict[str, float]:
        """Flat ``{series_key: value}`` view of every registered metric.

        Counters/gauges map to their value; a histogram contributes
        ``<series>_count`` and ``<series>_sum`` entries.  Pair two snapshots
        with :func:`diff` to isolate one run's contribution.
        """
        with self._lock:
            items = list(self._metrics.items())
        out: dict[str, float] = {}
        for (name, lk), m in items:
            series = _series_name(name, lk)
            if m.kind == "histogram":
                out[series + "_count"] = float(m.count)
                out[series + "_sum"] = float(m.sum)
            else:
                out[series] = float(m.value)
        return out

    def prometheus_text(self) -> str:
        """Render every metric in the Prometheus text exposition format."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: list[str] = []
        seen_header: set[str] = set()
        for (name, lk), m in items:
            if name not in seen_header:
                seen_header.add(name)
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(_render_series(name, lk, m))
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        """Drop every registered metric (tests only — handles held by
        callers keep working but are no longer exported)."""
        with self._lock:
            self._metrics.clear()


def _render_series(name: str, lk: tuple, m) -> list[str]:
    if m.kind != "histogram":
        return [f"{_series_name(name, lk)} {m.value}"]
    lines = []
    for ub, cum in m.bucket_counts():
        le = "+Inf" if math.isinf(ub) else repr(ub)
        lines.append(_series_name(name + "_bucket", lk + (("le", le),)) + f" {cum}")
    lines.append(f"{_series_name(name + '_sum', lk)} {m.sum}")
    lines.append(f"{_series_name(name + '_count', lk)} {m.count}")
    return lines


#: the process-global registry every subsystem reports into
REGISTRY = MetricsRegistry()


def counter(name: str, labels=None, help: str = "") -> Counter:
    """Get-or-create a counter in the global :data:`REGISTRY`."""
    return REGISTRY.counter(name, labels, help)


def gauge(name: str, labels=None, help: str = "") -> Gauge:
    """Get-or-create a gauge in the global :data:`REGISTRY`."""
    return REGISTRY.gauge(name, labels, help)


def histogram(name: str, labels=None, help: str = "", buckets=None) -> Histogram:
    """Get-or-create a histogram in the global :data:`REGISTRY`."""
    return REGISTRY.histogram(name, labels, help, buckets=buckets)


def snapshot() -> dict[str, float]:
    """Snapshot the global registry (see :meth:`MetricsRegistry.snapshot`)."""
    return REGISTRY.snapshot()


def prometheus_text() -> str:
    """Prometheus text exposition of the global registry."""
    return REGISTRY.prometheus_text()


def diff(before: dict[str, float], after: dict[str, float]) -> dict[str, float]:
    """Per-series delta between two :func:`snapshot` dicts.

    Series absent from ``before`` count from 0; unchanged series are dropped,
    so the result is exactly "what this run contributed" — the record
    benchmarks persist next to their wall-clock numbers.
    """
    out: dict[str, float] = {}
    for k, v in after.items():
        d = v - before.get(k, 0.0)
        if d != 0.0:
            out[k] = d
    return out


def record_tile_work(rows: int, cols: int, d: int, precision: str = "f32",
                     count: int = 1) -> None:
    """Account kernel-tile FLOPs and HBM bytes for a (rows, cols) K block.

    Same cost model as ``benchmarks/bench_kernels.tile_roofline``: the
    distance matmul is 2*d MACs per pair plus ~8 flops of kernel map /
    matvec epilogue; bytes charge the two point sets and the RHS at the tile
    dtype's width plus an f32 accumulator row.  Feeds the per-dtype
    ``repro_tile_flops_total`` / ``repro_tile_bytes_total`` counters that
    :func:`roofline_time_s` converts into TPU-time lower bounds.
    """
    nbytes = 2 if precision == "bf16" else 4
    flops = float(rows) * float(cols) * (2 * d + 8) * count
    nbyte_total = (
        (float(rows) * d + float(cols) * d + cols) * nbytes + rows * 4.0
    ) * count
    counter("repro_tile_flops_total", labels={"dtype": precision},
            help="kernel-tile floating point operations").inc(flops)
    counter("repro_tile_bytes_total", labels={"dtype": precision},
            help="kernel-tile HBM bytes moved").inc(nbyte_total)


def roofline_time_s(flops: float, nbytes: float, precision: str = "f32") -> float:
    """Roofline lower bound (seconds) for doing ``flops`` work over
    ``nbytes`` of HBM traffic on the target chip — max of the compute and
    memory times from ``repro.roofline.hw`` (bf16 runs the MXU at full rate,
    f32 at half)."""
    from repro.roofline import hw  # lazy: obs stays stdlib-only otherwise

    peak = hw.PEAK_FLOPS_BF16 if precision == "bf16" else hw.PEAK_FLOPS_F32
    return max(flops / peak, nbytes / hw.HBM_BW)
