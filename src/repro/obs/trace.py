"""Canonical solver traces and the event schemas every sink line obeys.

Historically each solver shaped its own ``history`` dicts (askotch carried
``sketch_res``/``step_L``, blocked-CG/pcg/falkon/eigenpro a 4-key subset),
so time-to-tolerance plots needed per-solver parsing.  :class:`TraceRecorder`
is now the single emission point: every iterate goes through :meth:`add`,
which (a) appends the solver's legacy-shaped dict to ``.history`` — a
compatibility view, bit-identical field-for-field to the old records — and
(b) emits one canonical ``type="trace"`` event to the telemetry sink:

    {"type": "trace", "solver": ..., "iter": ..., "wall_s": ...,
     "rel_residual": ...[, "rel_residual_per_head", "sweeps", "precision",
     and solver extras like "sketch_res"/"step_L"]}

``sweeps`` is kernel-sweep-equivalents so far (pairs / n²) when the recorder
is linked to a tune-engine ``SweepCounter`` — the paper's budget unit.

:data:`SCHEMAS` + :func:`validate_event` / :func:`validate_jsonl` close the
loop: CI validates emitted JSONL strictly (unknown or missing fields fail),
so the schema documented in docs/observability.md is enforced, not advisory.
"""

from __future__ import annotations

import json

from repro.obs.sinks import NULL_SINK

__all__ = ["SCHEMAS", "TraceRecorder", "validate_event", "validate_jsonl"]

#: required / optional fields per event type — the wire contract
SCHEMAS: dict[str, dict[str, frozenset]] = {
    "span": {
        "required": frozenset({
            "type", "name", "t_wall", "dur_s", "cpu_s", "span_id",
            "parent_id", "depth", "thread",
        }),
        "optional": frozenset({"attrs"}),
    },
    "trace": {
        "required": frozenset({
            "type", "solver", "iter", "wall_s", "rel_residual",
        }),
        "optional": frozenset({
            "rel_residual_per_head", "sweeps", "precision", "sketch_res",
            "step_L", "head",
        }),
    },
    "metric": {
        "required": frozenset({"type", "name", "kind", "value"}),
        "optional": frozenset({"labels"}),
    },
}


class TraceRecorder:
    """Per-solve iterate recorder: legacy ``history`` view + canonical events.

    Solvers call :meth:`add` once per (evaluated) iteration; the recorder
    appends the legacy-shaped dict to :attr:`history` (what callers and
    existing tests consume, unchanged) and, when a telemetry sink is live,
    emits the canonical trace event.  With no telemetry the event path is a
    single identity check, so plain solves pay nothing.
    """

    __slots__ = ("solver", "precision", "sweep_counter", "n", "_sink",
                 "history")

    def __init__(self, solver: str, *, precision=None, telemetry=None,
                 sweep_counter=None, n=None):
        self.solver = solver
        self.precision = precision
        self.sweep_counter = sweep_counter
        self.n = n
        self._sink = NULL_SINK if telemetry is None else telemetry.sink
        self.history: list[dict] = []

    def add(self, it: int, rel_residual: float, *, time_s: float,
            rel_residual_per_head=None, **extras) -> dict:
        """Record iteration ``it``.

        Builds the legacy history dict (``iter``/``rel_residual``
        [/``rel_residual_per_head``][/solver extras]/``time_s`` — same keys,
        same order as the pre-telemetry solvers), appends it to
        :attr:`history`, emits the canonical event when enabled, and returns
        the history dict so callers can reuse it (callbacks).
        """
        rec: dict = {"iter": int(it), "rel_residual": float(rel_residual)}
        if rel_residual_per_head is not None:
            rec["rel_residual_per_head"] = rel_residual_per_head
        rec.update(extras)
        rec["time_s"] = float(time_s)
        self.history.append(rec)

        if self._sink is not NULL_SINK:
            event: dict = {
                "type": "trace",
                "solver": self.solver,
                "iter": int(it),
                "wall_s": float(time_s),
                "rel_residual": float(rel_residual),
            }
            if rel_residual_per_head is not None:
                event["rel_residual_per_head"] = [
                    float(v) for v in rel_residual_per_head
                ]
            if self.sweep_counter is not None and self.n:
                event["sweeps"] = self.sweep_counter.pairs / float(self.n) ** 2
            if self.precision is not None:
                event["precision"] = self.precision
            for k, v in extras.items():
                event[k] = float(v) if isinstance(v, (int, float)) else v
            self._sink.emit(event)
        return rec


def validate_event(event: dict) -> None:
    """Strictly validate one event dict against :data:`SCHEMAS`.

    Raises ``ValueError`` on an unknown ``type``, a missing required field,
    or any field outside required ∪ optional — CI runs every emitted JSONL
    line through this, so schema drift fails loudly.
    """
    etype = event.get("type")
    schema = SCHEMAS.get(etype)
    if schema is None:
        raise ValueError(f"unknown event type: {etype!r} in {event!r}")
    keys = set(event)
    missing = schema["required"] - keys
    if missing:
        raise ValueError(f"{etype} event missing fields {sorted(missing)}: {event!r}")
    unknown = keys - schema["required"] - schema["optional"]
    if unknown:
        raise ValueError(f"{etype} event has unknown fields {sorted(unknown)}: {event!r}")


def validate_jsonl(path: str) -> dict[str, int]:
    """Validate every line of a telemetry JSONL file.

    Returns ``{event_type: count}`` on success; raises ``ValueError`` (with
    the offending line number) on the first malformed or schema-violating
    line.  An empty file validates to ``{}``.
    """
    counts: dict[str, int] = {}
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not valid JSON: {e}") from e
            try:
                validate_event(event)
            except ValueError as e:
                raise ValueError(f"{path}:{lineno}: {e}") from e
            counts[event["type"]] = counts.get(event["type"], 0) + 1
    return counts
