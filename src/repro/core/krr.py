"""Full-KRR problem container, prediction, and metrics (paper Eqs. (2)-(3)).

The problem is the linear system (K + lam I) W = Y with lam = n * lam_unscaled
(the paper scales regularization by n, App. C.2.1).  Y may be (n,) — scalar
regression / binary ±1 — or (n, t) with t one-vs-all heads; every solver in
the stack handles both, and all kernel access goes through a single
:class:`~repro.core.operator.KernelOperator`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.operator import KernelOperator, as_multirhs, widen_gram


def scaled_lam(n: int, lam_unscaled: float) -> float:
    """The paper's regularization scaling, lam = n * lam_unscaled (App.
    C.2.1) — the ONE place the rule lives; ``KRRProblem.lam`` and
    ``distributed.krr_dist.DistKRRConfig.lam`` both delegate here."""
    return float(n) * float(lam_unscaled)


def residual_report(op, y: jax.Array, lam: float, w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(aggregate, per-head) relative residuals of (K + lam I) W = Y from ONE
    streamed matvec.  ``op`` is anything exposing the ``k_lam_matvec``
    operator contract — a single-device KernelOperator or a mesh-aware
    ShardedKernelOperator (row-sharded y/w) — so distributed and local
    history records share these numerics by construction."""
    w2, _ = as_multirhs(w)
    y2, _ = as_multirhs(y)
    r = op.k_lam_matvec(w2, lam) - y2
    ynorm = jnp.maximum(jnp.linalg.norm(y2, axis=0), jnp.finfo(y2.dtype).tiny)
    per_head = jnp.linalg.norm(r, axis=0) / ynorm
    return jnp.linalg.norm(r) / jnp.linalg.norm(y2), per_head


@dataclasses.dataclass(frozen=True)
class KRRProblem:
    """Problem container.  ``kernel`` may be one kernel name or a tuple of
    names — a tuple makes the problem *multi-kernel*: K is the convex
    combination ``sum_i weights[i] K_i`` (``weights`` defaults to uniform,
    ``sigma`` may be shared or per-kernel) and every solver runs through a
    :class:`~repro.core.multikernel.WeightedSumKernelOperator` unchanged."""

    x: jax.Array  # (n, d) features
    y: jax.Array  # (n,) or (n, t) targets (t one-vs-all heads)
    kernel: str | tuple[str, ...] = "rbf"
    sigma: float | tuple[float, ...] = 1.0
    lam_unscaled: float = 1e-6
    backend: str = "auto"
    weights: tuple[float, ...] | None = None  # multi-kernel combination weights
    precision: str = "f32"  # kernel tile-compute policy: "f32" | "bf16"

    def __post_init__(self) -> None:
        if isinstance(self.kernel, list):
            object.__setattr__(self, "kernel", tuple(self.kernel))
        if isinstance(self.sigma, list):
            object.__setattr__(self, "sigma", tuple(self.sigma))
        if self.weights is not None:
            object.__setattr__(
                self, "weights", tuple(float(w) for w in self.weights)
            )
        if self.kernel == "precomputed":
            # ``x`` is the train Gram: widen ONCE here (validating shape) so
            # every ``.op`` access and dataclasses.replace() re-entry is a
            # cheap pass-through (widen_gram is idempotent)
            object.__setattr__(self, "x", widen_gram(self.x))

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def t(self) -> int:
        """Number of right-hand sides (1 for a scalar-target problem)."""
        return 1 if self.y.ndim == 1 else self.y.shape[1]

    @property
    def lam(self) -> float:
        return scaled_lam(self.n, self.lam_unscaled)

    @property
    def op(self):
        """The kernel operator owning (kernel, sigma, backend) plumbing —
        a :class:`KernelOperator`, or a :class:`~repro.core.multikernel.
        WeightedSumKernelOperator` when ``kernel`` is a tuple."""
        from repro.core.multikernel import make_operator  # avoid import cycle

        return make_operator(
            self.x, kernel=self.kernel, sigma=self.sigma,
            weights=self.weights, backend=self.backend,
            precision=self.precision,
        )

    def matvec(self, v: jax.Array) -> jax.Array:
        """K @ v (streamed, O(n^2 d) — baselines/metrics only)."""
        return self.op.matvec(v)

    def k_lam_matvec(self, v: jax.Array) -> jax.Array:
        """(K + lam I) @ v."""
        return self.op.k_lam_matvec(v, self.lam)

    def residual_per_head(self, w: jax.Array) -> jax.Array:
        """||K_lam w_j - y_j|| / ||y_j|| per head — (t,) even when t = 1."""
        return self.residual_report(w)[1]

    def relative_residual(self, w: jax.Array) -> jax.Array:
        """||K_lam W - Y||_F / ||Y||_F  (paper §6.3; aggregate over heads)."""
        return self.residual_report(w)[0]

    def residual_report(self, w: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(aggregate, per-head) relative residuals from ONE streamed matvec.

        Solvers record both every eval; sharing the O(n^2 d) pass matters.
        """
        return residual_report(self.op, self.y, self.lam, w)

    def predict(self, w: jax.Array, x_test: jax.Array) -> jax.Array:
        """f(x) = K(x_test, X_train) @ w; w (n,) -> (m,), w (n, t) -> (m, t)."""
        return self.op.row_block_matvec(x_test, w)


class Metrics(NamedTuple):
    rmse: jax.Array
    mae: jax.Array
    accuracy: jax.Array  # sign agreement (±1 tasks) / top-1 over one-vs-all heads


def evaluate(y_pred: jax.Array, y_true: jax.Array) -> Metrics:
    """RMSE/MAE over all entries; accuracy is sign agreement for scalar or
    single-head targets and argmax (top-1 one-vs-all decoding) when t > 1."""
    err = y_pred - y_true
    rmse = jnp.sqrt(jnp.mean(err**2))
    mae = jnp.mean(jnp.abs(err))
    if y_pred.ndim == 2 and y_pred.shape[1] > 1:
        acc = jnp.mean(
            (jnp.argmax(y_pred, axis=1) == jnp.argmax(y_true, axis=1)).astype(
                jnp.float32
            )
        )
    else:
        acc = jnp.mean((jnp.sign(y_pred) == jnp.sign(y_true)).astype(jnp.float32))
    return Metrics(rmse=rmse, mae=mae, accuracy=acc)


def evaluate_per_head(y_pred: jax.Array, y_true: jax.Array) -> Metrics:
    """Per-head metrics — each field is (t,).  Accuracy is per-head sign
    agreement (the one-vs-all margins are ±1-coded per head)."""
    p2, _ = as_multirhs(y_pred)
    t2, _ = as_multirhs(y_true)
    err = p2 - t2
    return Metrics(
        rmse=jnp.sqrt(jnp.mean(err**2, axis=0)),
        mae=jnp.mean(jnp.abs(err), axis=0),
        accuracy=jnp.mean((jnp.sign(p2) == jnp.sign(t2)).astype(jnp.float32), axis=0),
    )
