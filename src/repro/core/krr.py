"""Full-KRR problem container, prediction, and metrics (paper Eqs. (2)-(3)).

The problem is the linear system (K + lam I) w = y with lam = n * lam_unscaled
(the paper scales regularization by n, App. C.2.1).  K is only ever accessed
through the fused streaming kernel ops.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class KRRProblem:
    x: jax.Array  # (n, d) features
    y: jax.Array  # (n,) or (n, t) targets (t one-vs-all heads)
    kernel: str = "rbf"
    sigma: float = 1.0
    lam_unscaled: float = 1e-6
    backend: str = "auto"

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def lam(self) -> float:
        return self.n * self.lam_unscaled

    def matvec(self, v: jax.Array) -> jax.Array:
        """K @ v (streamed, O(n^2 d) — baselines/metrics only)."""
        return ops.kernel_matvec(
            self.x, self.x, v, kernel=self.kernel, sigma=self.sigma, backend=self.backend
        )

    def k_lam_matvec(self, v: jax.Array) -> jax.Array:
        """(K + lam I) @ v."""
        return self.matvec(v) + self.lam * v

    def relative_residual(self, w: jax.Array) -> jax.Array:
        """||K_lam w - y|| / ||y||  (paper §6.3)."""
        r = self.k_lam_matvec(w) - self.y
        return jnp.linalg.norm(r) / jnp.linalg.norm(self.y)

    def predict(self, w: jax.Array, x_test: jax.Array) -> jax.Array:
        """f(x) = K(x_test, X_train) @ w."""
        return ops.kernel_matvec(
            x_test, self.x, w, kernel=self.kernel, sigma=self.sigma, backend=self.backend
        )


class Metrics(NamedTuple):
    rmse: jax.Array
    mae: jax.Array
    accuracy: jax.Array  # sign-agreement (binary ±1 tasks); NaN-free for regression too


def evaluate(y_pred: jax.Array, y_true: jax.Array) -> Metrics:
    err = y_pred - y_true
    rmse = jnp.sqrt(jnp.mean(err**2))
    mae = jnp.mean(jnp.abs(err))
    acc = jnp.mean((jnp.sign(y_pred) == jnp.sign(y_true)).astype(jnp.float32))
    return Metrics(rmse=rmse, mae=mae, accuracy=acc)
