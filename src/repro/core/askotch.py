"""Skotch (Algorithm 2) and ASkotch (Algorithm 3): approximate sketch-and-
project solvers for full KRR.

Per iteration (blocksize b, Nystrom rank r, n training points, t heads):
  1. sample block B                          — uniform or ARLS (paper §3.1)
  2. K_BB                                    — fused block build, O(b^2 d)
  3. K_hat_BB = Nystrom(K_BB, r)             — Algorithm 4, O(b^2 r)
  4. rho = lam + lam_r(K_hat_BB) ("damped")  — paper §3.2 default
  5. L_PB via randomized powering            — Algorithm 5, O(b r + b^2) * 10
  6. G_B = (K_lam)_{B,:} Z - Y_B             — fused kernel matvec, O(n b d)  << hot spot
  7. D_B = (K_hat_BB + rho I)^{-1} G_B       — Woodbury, O(b r t)
  8. iterate updates (+ Nesterov mixing for ASkotch), O(n t)

The solve is multi-RHS throughout: with Y of shape (n, t) (one-vs-all heads)
steps 1-5 are shared across all t heads and steps 6-8 batch over columns, so
a t-head solve performs the kernel-tile work of a single solve per iteration.
A 1-D y is the t = 1 special case (1-D w out, no API change).

Defaults (paper §3.2): b = n/100, r = 100, uniform sampling,
mu_hat = lam (clipped so mu_hat <= nu_hat and mu_hat * nu_hat <= 1),
nu_hat = n/b, eta = 1/max(L_PB, 1).

The step is a single jit-able function; ``solve`` wraps it in a Python loop
with residual tracking and checkpoint callbacks, ``solve_scan`` in a pure
lax.scan for benchmarking.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import samplers
from repro.core.get_l import get_l
from repro.core.krr import KRRProblem
from repro.core.nystrom import (
    NystromFactors,
    nystrom_from_sketch,
    stable_inv_apply,
    stable_inv_apply_setup,
    woodbury_inv_apply,
)
from repro.obs.metrics import record_tile_work
from repro.obs.telemetry import as_telemetry


@dataclasses.dataclass(frozen=True)
class ASkotchConfig:
    """Hyperparameters; defaults are the paper's recommended settings."""

    block_size: int | None = None  # default n // 100 (>= rank + 8)
    rank: int = 100
    rho_mode: str = "damped"  # "damped" (lam + lam_r) | "regularization" (lam)
    sampling: str = "uniform"  # "uniform" | "arls"
    precond: str = "nystrom"  # "nystrom" | "identity" (Lin et al. ablation)
    accelerated: bool = True  # ASkotch; False -> Skotch
    mu: float | None = None  # default: lam (clipped)
    nu: float | None = None  # default: n / b
    stable_inv: bool = True  # f32-stable Cholesky Woodbury (App. A.1.1)
    backend: str = "auto"
    powering_iters: int = 10

    def resolve_block(self, n: int) -> int:
        b = self.block_size if self.block_size is not None else max(n // 100, 1)
        return int(min(max(b, self.rank + 8), n))


class SolverState(NamedTuple):
    w: jax.Array  # (n,) or (n, t) primal iterate
    v: jax.Array  # acceleration sequence (= w when not accelerated)
    z: jax.Array  # acceleration sequence (= w when not accelerated)
    key: jax.Array
    it: jax.Array  # iteration counter
    sketch_res: jax.Array  # ||G_B|| per head ((t,) or scalar) — progress proxy


class StepAux(NamedTuple):
    step_l: jax.Array  # L_PB estimate
    rho: jax.Array


def _accel_params(mu: float, nu: float) -> tuple[float, float, float]:
    """beta, gamma, alpha from (mu_hat, nu_hat) — Algorithm 3 preamble."""
    beta = 1.0 - math.sqrt(mu / nu)
    gamma = 1.0 / math.sqrt(mu * nu)
    alpha = 1.0 / (1.0 + gamma * nu)
    return beta, gamma, alpha


def resolve_accel_params(cfg: ASkotchConfig, n: int, lam: float) -> tuple[float, float]:
    """Paper §3.2: mu_hat = lam, nu_hat = n/b, with the two safeguards
    mu_hat <= nu_hat and mu_hat * nu_hat <= 1 enforced by clipping mu."""
    b = cfg.resolve_block(n)
    nu = cfg.nu if cfg.nu is not None else n / b
    mu = cfg.mu if cfg.mu is not None else lam
    mu = min(mu, nu, 1.0 / nu)
    return mu, nu


def make_step(
    problem: KRRProblem, cfg: ASkotchConfig, probs: jax.Array | None = None
) -> Callable[[SolverState], tuple[SolverState, StepAux]]:
    """Build the jit-able Skotch/ASkotch step for a fixed problem.

    The step is shape-polymorphic in the RHS: with y (n, t) every per-block
    quantity batches over the trailing head axis while the block sample, the
    Nystrom preconditioner, and the fused kernel tiles are computed once.
    """
    n = problem.n
    b = cfg.resolve_block(n)
    r = min(cfg.rank, b - 1)
    lam = jnp.float32(problem.lam)
    op = dataclasses.replace(problem.op, backend=cfg.backend)

    if cfg.sampling == "arls":
        if probs is None:
            raise ValueError("ARLS sampling requires precomputed probs")
        sampler = samplers.arls_sampler(probs, b)
    elif cfg.sampling == "uniform":
        sampler = samplers.uniform_sampler(n, b)
    else:
        raise ValueError(f"unknown sampling {cfg.sampling!r}")

    if cfg.accelerated:
        mu, nu = resolve_accel_params(cfg, n, float(lam))
        beta, gamma, alpha = _accel_params(mu, nu)

    x, y = problem.x, problem.y
    head_axes = None if y.ndim == 1 else (0,)

    def step(state: SolverState) -> tuple[SolverState, StepAux]:
        key, kb, knys, kl = jax.random.split(state.key, 4)
        idx = sampler(kb)
        xb = jnp.take(x, idx, axis=0)
        yb = jnp.take(y, idx, axis=0)
        zref = state.z if cfg.accelerated else state.w
        zb = jnp.take(zref, idx, axis=0)

        # -- block build + Nystrom preconditioner (shared across heads) -----
        kbb = op.block(xb)

        omega = jax.random.normal(knys, (b, r), dtype=kbb.dtype)
        omega, _ = jnp.linalg.qr(omega)
        factors = nystrom_from_sketch(kbb @ omega, omega, jnp.trace(kbb))

        if cfg.rho_mode == "damped":
            rho = lam + factors.lam[-1]
        else:
            rho = lam

        def kbb_lam_mv(u):
            return kbb @ u + lam * u

        if cfg.precond == "identity":
            # Ablation (paper §6.4 / Lin et al. 2024): K_hat = 0, rho = 1 =>
            # plain sketched-gradient step with powering-estimated stepsize.
            factors_id = NystromFactors(
                u=jnp.zeros((b, 1), kbb.dtype), lam=jnp.zeros((1,), kbb.dtype)
            )
            step_l = get_l(
                kl, kbb_lam_mv, factors_id, jnp.float32(1.0), num_iters=cfg.powering_iters
            )
            solve_g = lambda g: g  # noqa: E731
        else:
            step_l = get_l(kl, kbb_lam_mv, factors, rho, num_iters=cfg.powering_iters)
            if cfg.stable_inv:
                chol_l = stable_inv_apply_setup(factors, rho)
                solve_g = lambda g: stable_inv_apply(factors, rho, chol_l, g)  # noqa: E731
            else:
                solve_g = lambda g: woodbury_inv_apply(factors, rho, g)  # noqa: E731

        eta = 1.0 / jnp.maximum(step_l, 1.0)  # eta = 1 / hat-L_PB (Lemma 8)

        # -- fused O(nbt) kernel matvec: G_B = (K_lam)_{B,:} Z - Y_B --------
        # one kernel-tile pass serves all t heads
        gb = op.row_block_matvec(xb, zref) + lam * zb - yb
        db = solve_g(gb)

        # -- iterate updates (batched over the head axis) --------------------
        if cfg.accelerated:
            w_new = state.z.at[idx].add(-eta * db)
            v_new = (beta * state.v + (1.0 - beta) * state.z).at[idx].add(
                -gamma * eta * db
            )
            z_new = alpha * v_new + (1.0 - alpha) * w_new
        else:
            w_new = state.w.at[idx].add(-eta * db)
            v_new = w_new
            z_new = w_new

        new_state = SolverState(
            w=w_new,
            v=v_new,
            z=z_new,
            key=key,
            it=state.it + 1,
            sketch_res=jnp.linalg.norm(gb, axis=head_axes),
        )
        return new_state, StepAux(step_l=step_l, rho=rho)

    return step


def init_state(problem: KRRProblem, seed: int = 0, w0: jax.Array | None = None) -> SolverState:
    """Zero-initialized state; iterates take the shape of problem.y
    ((n,) or (n, t)) so multi-head solves carry one column per head."""
    if w0 is None:
        w0 = jnp.zeros(problem.y.shape, jnp.float32)
    res0 = jnp.full(() if problem.y.ndim == 1 else (problem.t,), jnp.inf, jnp.float32)
    return SolverState(
        w=w0,
        v=w0,
        z=w0,
        key=jax.random.PRNGKey(seed),
        it=jnp.zeros((), jnp.int32),
        sketch_res=res0,
    )


@dataclasses.dataclass
class SolveResult:
    w: jax.Array
    iters: int
    history: list[dict]
    converged: bool
    wall_time_s: float


def _maybe_arls_probs(problem: KRRProblem, cfg: ASkotchConfig, seed: int):
    if cfg.sampling != "arls":
        return None
    scores = samplers.approx_rls_bless(
        jax.random.PRNGKey(seed + 1),
        dataclasses.replace(problem.op, backend=cfg.backend),
        lam=problem.lam,
    )
    return samplers.arls_probs(scores)


def solve(
    problem: KRRProblem,
    cfg: ASkotchConfig | None = None,
    *,
    max_iters: int = 500,
    tol: float = 1e-8,
    eval_every: int = 25,
    seed: int = 0,
    time_budget_s: float | None = None,
    callback: Callable[[int, SolverState, dict], None] | None = None,
    w0: jax.Array | None = None,
    telemetry=None,
) -> SolveResult:
    """Python-loop driver: jitted steps + periodic full-residual evaluation.

    The full relative residual costs one O(n^2 d) streamed matvec (shared by
    the per-head and aggregate reports), so it is only computed every
    ``eval_every`` iterations (and at the end).  History records carry
    ``rel_residual`` (aggregate over heads) and ``rel_residual_per_head``.

    ``telemetry`` (a ``repro.obs.Telemetry``) adds a solve span, canonical
    trace events mirroring the history records, and per-iteration tile-work
    metrics; ``None`` (default) keeps the whole telemetry path to a single
    identity check.
    """
    cfg = cfg or ASkotchConfig()
    tel = as_telemetry(telemetry)
    solver_name = "askotch" if cfg.accelerated else "skotch"
    n, b, d = problem.n, cfg.resolve_block(problem.n), problem.x.shape[1]
    precision = getattr(problem.op, "precision", "f32")
    recorder = tel.recorder(solver_name, precision=precision, n=n)
    probs = _maybe_arls_probs(problem, cfg, seed)
    step = jax.jit(make_step(problem, cfg, probs))
    state = init_state(problem, seed, w0)
    history = recorder.history
    tel_enabled = tel.enabled  # hoisted: the loop pays one bool test
    with tel.span(f"solve/{solver_name}", n=n, t=problem.t, b=b,
                  max_iters=max_iters, tol=tol):
        t0 = time.perf_counter()
        converged = False
        it = 0
        for it in range(1, max_iters + 1):
            state, aux = step(state)
            if tel_enabled:
                # per-step kernel-tile work: K_BB block + the (b, n) fused
                # row-block matvec (host-loop counting — exact per execution)
                record_tile_work(b, b, d, precision)
                record_tile_work(b, n, d, precision)
            if it % eval_every == 0 or it == max_iters:
                rel_agg, rel_heads = problem.residual_report(state.w)
                rel = float(rel_agg)
                rec = recorder.add(
                    it, rel,
                    rel_residual_per_head=[float(v) for v in rel_heads],
                    sketch_res=float(jnp.linalg.norm(state.sketch_res)),
                    step_L=float(aux.step_l),
                    time_s=time.perf_counter() - t0,
                )
                if callback:
                    callback(it, state, rec)
                # every head must pass (aggregate alone dilutes a bad head by
                # ~1/sqrt(t)); identical to the aggregate test when t = 1, and
                # the same convergence meaning as blocked_cg
                if bool(jnp.all(rel_heads < tol)):
                    converged = True
                    break
            if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
                break
    return SolveResult(
        w=state.w,
        iters=it,
        history=history,
        converged=converged,
        wall_time_s=time.perf_counter() - t0,
    )


def solve_scan(
    problem: KRRProblem,
    cfg: ASkotchConfig | None = None,
    *,
    num_iters: int = 100,
    seed: int = 0,
    w0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Pure lax.scan solve (benchmarks / dry-run lowering): returns (w, per-
    iteration sketched residuals — (iters,) or (iters, t))."""
    cfg = cfg or ASkotchConfig()
    probs = _maybe_arls_probs(problem, cfg, seed)
    step = make_step(problem, cfg, probs)

    def body(state, _):
        state, _aux = step(state)
        return state, state.sketch_res

    state, res = jax.lax.scan(body, init_state(problem, seed, w0), None, length=num_iters)
    return state.w, res
