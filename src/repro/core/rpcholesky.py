"""Randomly pivoted (partial) Cholesky — RPC (Diaz et al. 2023, Epperly et
al. 2024).  Produces a rank-r factor F (n x r) with K ≈ F F^T by sampling
pivots proportionally to the diagonal of the residual kernel.

Used as one of the two PCG preconditioners the paper benchmarks against
(Fig. 1: "Randomly Pivoted Cholesky" with rank-50 preconditioner).

Blocked variant: draws ``block`` pivots per round from the residual-diagonal
distribution, then performs the exact partial-Cholesky update for accepted
pivots; O(n r^2 + n r d) total.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operator import KernelOperator


def rp_cholesky(
    key: jax.Array,
    op: KernelOperator,
    rank: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (F, pivots): F (n, rank) with K ≈ F F^T.

    ``op`` owns the kernel configuration; sequential pivoting (one pivot per
    round) — the kernels used here have unit diagonal so diag(K) = 1
    initially.
    """
    x = op.x
    n = op.n
    diag = jnp.ones((n,), jnp.float32)
    f = jnp.zeros((n, rank), jnp.float32)
    pivots = jnp.zeros((rank,), jnp.int32)

    def body(carry, k_key):
        diag, f, pivots, i = carry
        probs = jnp.maximum(diag, 0.0)
        probs = probs / jnp.maximum(jnp.sum(probs), 1e-30)
        piv = jax.random.choice(k_key, n, (), p=probs)
        xp = jax.lax.dynamic_slice_in_dim(x, piv, 1, axis=0)
        col = op.block(x, xp)[:, 0]
        # subtract the projection onto the factors found so far
        col = col - f @ f[piv]
        denom = jnp.sqrt(jnp.maximum(col[piv], 1e-12))
        newcol = col / denom
        f = jax.lax.dynamic_update_slice_in_dim(f, newcol[:, None], i, axis=1)
        diag = jnp.maximum(diag - newcol**2, 0.0)
        pivots = pivots.at[i].set(piv)
        return (diag, f, pivots, i + 1), None

    keys = jax.random.split(key, rank)
    (diag, f, pivots, _), _ = jax.lax.scan(body, (diag, f, pivots, 0), keys)
    return f, pivots
