"""Skotch (Algorithm 2) — the non-accelerated variant of ASkotch.

Thin wrapper: Skotch is exactly the ASkotch machinery with the Nesterov
mixing disabled (see ``repro.core.askotch`` for the shared step).
"""

from __future__ import annotations

import dataclasses

from repro.core.askotch import ASkotchConfig, SolveResult, solve
from repro.core.krr import KRRProblem


def skotch_config(**kwargs) -> ASkotchConfig:
    kwargs.setdefault("accelerated", False)
    cfg = ASkotchConfig(**kwargs)
    if cfg.accelerated:
        cfg = dataclasses.replace(cfg, accelerated=False)
    return cfg


def solve_skotch(problem: KRRProblem, cfg: ASkotchConfig | None = None, **kw) -> SolveResult:
    cfg = cfg or skotch_config()
    cfg = dataclasses.replace(cfg, accelerated=False)
    return solve(problem, cfg, **kw)
