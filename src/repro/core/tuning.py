"""Tile-sharing hyperparameter tuning: (sigma, lam) search with k-fold CV.

ASkotch's headline results all sit behind a (kernel, sigma, lam) choice; this
module is the machinery that makes it.  The engineering rule is that
candidates share kernel work instead of multiplying it (docs/tuning.md):

  * **Folds are column masks.**  The fold-j training system
    ``(K_j + lam I) w = y_j`` embeds into the full n x n operator as the
    block-diagonal system ``(M_j K M_j + lam I) w = M_j y`` with
    ``M_j = diag(fold-j train mask)`` — off-mask coordinates decouple to
    ``lam w = 0``.  Masked iterates stay masked, so every fold rides the SAME
    fused kernel tiles as every other fold.
  * **Lambdas are per-column diagonal shifts.**  Columns of one blocked-CG
    solve may each carry their own shift ``lam_c``; the kernel matvec
    ``K @ V`` is one fused pass over all columns, the shift is elementwise.
  * **One Nystrom sketch per sigma.**  The rank-r sketch of ``K`` does not
    depend on lam (Diaz et al. 2023's shift-invariant preconditioner
    observation), so a single ``K @ Omega`` pass preconditions — and
    Woodbury-warm-starts — every (lam, fold) column.

So for s sigmas, l lambdas, k folds, and t one-vs-all heads, the whole sweep
runs s stacked solves over ``l*k*t`` columns each: total kernel-tile work is
~s solves' worth instead of the naive ``s*l*k`` (``benchmarks/
bench_tuning.py`` measures it; ``TuneResult.sweeps`` carries the count).

:func:`tune_multikernel` extends the engine with a WEIGHT axis — himalaya-
style random search over convex kernel combinations ``sum_i w_i K_i``:
every weight candidate contributes ``l*k*t`` more columns carrying its own
per-column weight vector (the fused multi-kernel matvec makes a q-kernel
pass cost ONE data sweep), and the per-kernel Nystrom sketches from one
``sketch_components`` sweep combine per candidate for preconditioning and
warm starts.  A c-candidate weight search costs ~1 solve's kernel work per
sigma (``benchmarks/bench_multikernel.py``).

Quickstart (the full walkthrough lives in docs/tuning.md):

>>> import numpy as np
>>> import jax.numpy as jnp
>>> from repro.core.krr import KRRProblem
>>> from repro.core.tuning import tune
>>> r = np.random.default_rng(0)
>>> x = jnp.asarray(r.standard_normal((64, 3)).astype(np.float32))
>>> y = jnp.sin(2.0 * x[:, 0]) + 0.1 * x[:, 1]
>>> res = tune(KRRProblem(x=x, y=y), sigmas=(0.5, 2.0),
...            lams=(1e-3, 1e-2, 1e-1), folds=3, rank=16, max_iters=60, seed=0)
>>> sorted(res.best)
['backend', 'cv_mse', 'folds', 'kernel', 'lam_unscaled', 'sigma']
>>> res.best["sigma"] in (0.5, 2.0) and res.best["lam_unscaled"] in (1e-3, 1e-2, 1e-1)
True
>>> len(res.records)  # one record per (sigma, lam) candidate
6
>>> res.sweeps < res.info["naive_sweep_estimate"]  # shared < the l*k loop
True
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked_cg import blocked_cg
from repro.core.krr import KRRProblem, scaled_lam
from repro.core.nystrom import NystromFactors, nystrom_from_sketch
from repro.core.operator import as_multirhs

SEARCHES = ("grid", "random")
STRATEGIES = ("shared", "naive")


@dataclasses.dataclass
class SweepCounter:
    """Kernel-pair-evaluation tally.

    ``pairs`` counts (row, col) kernel evaluations touched by matvec work; a
    multi-RHS matvec touches the same tiles as a single-RHS one, so the
    natural unit is a *sweep* = one full pass over the n x n tile grid
    (``pairs / n**2``).  This is the cost model docs/tuning.md accounts in.
    """

    pairs: float = 0.0

    def add_matvec(self, rows: int, cols: int, count: int = 1) -> None:
        self.pairs += float(rows) * float(cols) * count

    def sweeps(self, n: int) -> float:
        return self.pairs / float(n) ** 2


@dataclasses.dataclass
class TuneResult:
    """Outcome of a (sigma, lam) sweep with k-fold CV.

    Attributes:
      best: JSON-able best-config dict — ``kernel``, ``sigma``,
        ``lam_unscaled``, ``backend``, ``folds``, ``cv_mse`` — consumable by
        :func:`repro.serving.krr_serve.make_krr_predict_fn_from_config` and
        :func:`apply_best`.
      best_score: the winning mean CV validation MSE (lower is better).
      records: one dict per evaluated candidate: ``sigma``, ``lam_unscaled``,
        ``cv_mse``, ``fold_mse`` (length-k list), and ``cv_acc`` (top-1
        one-vs-all accuracy) when the problem has t > 1 heads.
      folds / search / strategy: the sweep configuration actually run.
      sweeps: kernel-tile sweep equivalents consumed (see
        :class:`SweepCounter`); the tile-sharing claim is ``sweeps`` staying
        ~s solves' worth for an s-sigma grid.
      info: extras — ``pairs``, ``n``, ``t``, ``candidates``,
        ``naive_sweep_estimate`` (what the per-candidate loop would cost),
        per-sigma iteration counts.
      best_w0: fold-averaged weights of the winning candidate (the
        mask-supported mean of its k CV fold solutions; (n,) or (n, t)) —
        the refit warm start ``apply_best`` can thread to the solver.  None
        for the naive strategy (its fold solves are discarded).
    """

    best: dict[str, Any]
    best_score: float
    records: list[dict[str, Any]]
    folds: int
    search: str
    strategy: str
    sweeps: float
    info: dict[str, Any]
    best_w0: np.ndarray | None = None


def apply_best(problem: KRRProblem, result: TuneResult, *, with_w0: bool = False):
    """Return ``problem`` re-parameterized with the tuned best config —
    the refit step of tune -> refit -> serve.

    For a multi-kernel sweep (``result.best`` carries ``weights``) the
    returned problem gets the kernel tuple and winning weight vector too.
    With ``with_w0=True`` returns ``(problem, w0)`` where ``w0`` is the
    fold-averaged CV solution of the winning candidate ((n,) or (n, t), or
    None under the naive strategy) — pass it as the solver's warm start
    (``solve(..., w0=w0)``) instead of starting from zero (ROADMAP item).
    """
    rep: dict[str, Any] = {
        "sigma": result.best["sigma"],
        "lam_unscaled": float(result.best["lam_unscaled"]),
    }
    if isinstance(rep["sigma"], (tuple, list)):
        rep["sigma"] = tuple(float(s) for s in rep["sigma"])
    else:
        rep["sigma"] = float(rep["sigma"])
    if "weights" in result.best:
        rep["kernel"] = tuple(result.best["kernel"])
        rep["weights"] = tuple(float(w) for w in result.best["weights"])
    refit = dataclasses.replace(problem, **rep)
    if with_w0:
        return refit, result.best_w0
    return refit


def _fold_avg_w0(
    w_cols: np.ndarray, col0: int, folds: int, t: int, squeeze: bool
) -> np.ndarray:
    """Mask-supported mean of one candidate's k fold solutions.

    ``w_cols`` is the stacked solve's (n, C) solution block; the candidate's
    fold-j/head-h column sits at ``col0 + j*t + h``.  Off-mask rows of each
    column are exactly zero (the masked system decouples to ``lam w = 0``),
    and every row is on-mask in exactly ``k - 1`` folds, so the mean over its
    supporting folds is the column sum divided by ``k - 1``.
    """
    block = w_cols[:, col0 : col0 + folds * t]
    w0 = block.reshape(block.shape[0], folds, t).sum(axis=1) / max(folds - 1, 1)
    return w0[:, 0] if squeeze else w0


# ---------------------------------------------------------------------------
# candidate + fold construction
# ---------------------------------------------------------------------------


def _candidates(
    sigmas: Sequence[float],
    lams: Sequence[float],
    search: str,
    num_samples: int | None,
    rng: np.random.Generator,
) -> list[tuple[float, float]]:
    grid = [(float(s), float(l)) for s in sigmas for l in lams]
    if search == "grid":
        if num_samples is not None:
            raise ValueError(
                "num_samples only applies to search='random'; grid search "
                "always runs the full cross product"
            )
        return grid
    k = len(grid) if num_samples is None else min(int(num_samples), len(grid))
    if k < 1:
        raise ValueError("random search needs num_samples >= 1")
    picks = rng.choice(len(grid), size=k, replace=False)
    return [grid[i] for i in sorted(picks)]


def _make_folds(n: int, folds: int, rng: np.random.Generator) -> list[np.ndarray]:
    """Shuffled index sets of the k validation folds (near-equal sizes)."""
    perm = rng.permutation(n)
    return [np.sort(f) for f in np.array_split(perm, folds)]


# ---------------------------------------------------------------------------
# shared (tile-sharing) engine — one stacked solve per sigma
# ---------------------------------------------------------------------------


def _operator_for(problem: KRRProblem, sigma: float, mesh, weights=None) -> Any:
    """Operator for one sigma candidate — local or mesh-bound; ``weights``
    re-weights a multi-kernel problem's combination (naive reference loop)."""
    if mesh is None:
        rep: dict[str, Any] = {"sigma": float(sigma)}
        if weights is not None:
            rep["weights"] = tuple(float(w) for w in weights)
        return dataclasses.replace(problem.op, **rep)
    from repro.distributed.sharded_operator import ShardedKernelOperator

    return ShardedKernelOperator.bind(
        mesh, problem.x, kernel=problem.kernel, sigma=float(sigma),
        backend=problem.backend, weights=weights,
    )


def _place(op: Any, arr: np.ndarray) -> jax.Array:
    """Device-put row-aligned host data, row-sharded when ``op`` is mesh-aware."""
    a = jnp.asarray(arr)
    if hasattr(op, "sharding"):
        return jax.device_put(a, op.sharding(a.ndim))
    return a


def _sigma_sketch(
    op: Any, rank: int, seed: int, counter: SweepCounter
) -> NystromFactors:
    """ONE rank-r Nystrom sketch of K(sigma) — reused by every (lam, fold)
    column's preconditioner and warm start (the shift-invariant observation)."""
    rng = np.random.default_rng(seed)
    omega = _place(op, rng.standard_normal((op.n, rank)).astype(np.float32))
    omega, _ = jnp.linalg.qr(omega)
    sketch = op.sketch(omega)
    counter.add_matvec(op.n, op.n)
    return nystrom_from_sketch(sketch, omega, op.trace_est())


def _tune_one_sigma_shared(
    op: Any,
    y2: np.ndarray,
    lam_list: list[float],
    val_folds: list[np.ndarray],
    *,
    rank: int,
    max_iters: int,
    tol: float,
    seed: int,
    warm_start: bool,
    counter: SweepCounter,
) -> tuple[np.ndarray, int, np.ndarray]:
    """Solve ALL (lam, fold, head) systems for one sigma in ONE stacked
    blocked-CG: columns ordered ``c = (lam_i * k + fold_j) * t + head_h``.

    Returns ``(preds, iters, w_cols)`` with preds (n, C) = K @ W host-side —
    row i of column (lam_i, fold_j, head_h) is the fold-j model's prediction
    at x[i] (exact at validation rows, where w is zero by the mask) — and
    ``w_cols`` (n, C) the solution block itself (mask-supported fold weights;
    the refit warm start averages the winner's columns).
    """
    n, t = y2.shape
    k = len(val_folds)
    l = len(lam_list)

    fold_mask = np.ones((n, k), np.float32)
    for j, val in enumerate(val_folds):
        fold_mask[val, j] = 0.0
    n_train = [n - len(val) for val in val_folds]

    # columns: lam outer, fold middle, head inner
    masks_cols = np.tile(np.repeat(fold_mask, t, axis=1), (1, l))  # (n, l*k*t)
    rhs = np.tile(
        (fold_mask[:, :, None] * y2[:, None, :]).reshape(n, k * t), (1, l)
    )
    lam_cols = np.repeat(
        np.asarray(
            [scaled_lam(n_train[j], lam_u) for lam_u in lam_list for j in range(k)],
            np.float32,
        ),
        t,
    )  # (l*k,) -> (l*k*t,)

    masks_d = _place(op, masks_cols)
    rhs_d = _place(op, rhs)
    lam_d = jnp.asarray(lam_cols)

    f = _sigma_sketch(op, rank, seed, counter)
    # damped rho per column; coefficients are O(r * C) scalars — lam-dependent
    # parts of the preconditioner cost nothing against the shared sketch
    rho = lam_d + f.lam[-1]
    coeff = (f.lam[-1] + rho)[None, :] / (f.lam[:, None] + rho[None, :])  # (r, C)

    @jax.jit
    def matvec(v: jax.Array) -> jax.Array:
        # one fused kernel pass over ALL columns; mask + shift are elementwise
        return masks_d * op.matvec(masks_d * v) + lam_d * v

    @jax.jit
    def pinv(r_blk: jax.Array) -> jax.Array:
        # residuals are mask-supported by construction, so masking the output
        # makes this exactly the restricted (SPD) Nystrom preconditioner
        utv = f.u.T @ r_blk
        return masks_d * (f.u @ (coeff * utv) + (r_blk - f.u @ utv))

    x0 = None
    if warm_start:

        @jax.jit
        def _warm(rhs_in: jax.Array) -> jax.Array:
            # Woodbury apply of the Nystrom approximation of (K + lam I)^{-1}
            # (Eq. (15)), per-column rho = lam_c — zero extra kernel sweeps
            utg = f.u.T @ rhs_in
            core = utg / (f.lam[:, None] + lam_d[None, :])
            return masks_d * (f.u @ core + (rhs_in - f.u @ utg) / lam_d)

        x0 = _warm(rhs_d)

    res = blocked_cg(matvec, rhs_d, pinv, x0=x0, max_iters=max_iters, tol=tol)
    counter.add_matvec(n, n, res.iters + (1 if x0 is not None else 0))

    preds = op.matvec(res.x)  # scoring: ONE more sweep serves every candidate
    counter.add_matvec(n, n)
    return np.asarray(preds), res.iters, np.asarray(res.x)


# ---------------------------------------------------------------------------
# naive reference engine — one solve per (sigma, lam, fold)
# ---------------------------------------------------------------------------


def _tune_one_candidate_naive(
    problem: KRRProblem,
    sigma: float,
    lam_u: float,
    val_folds: list[np.ndarray],
    *,
    rank: int,
    max_iters: int,
    tol: float,
    seed: int,
    counter: SweepCounter,
    mesh=None,
    weights=None,
) -> list[np.ndarray]:
    """The loop the shared path replaces: an independent Nystrom-PCG solve
    per fold, each with its own sketch.  Returns per-fold validation
    predictions (len(val), t).  ``weights`` makes the candidate a weighted
    kernel combination (the multi-kernel naive reference)."""
    n = problem.n
    x_np = np.asarray(problem.x)
    y2, _ = as_multirhs(problem.y)
    y_np = np.asarray(y2)
    base_op = _operator_for(problem, sigma, mesh, weights=weights)
    out = []
    for j, val in enumerate(val_folds):
        train = np.setdiff1d(np.arange(n), val)
        op_f = base_op.restrict(jnp.asarray(train))
        n_f = len(train)
        lam_f = scaled_lam(n_f, lam_u)
        f = _sigma_sketch(op_f, min(rank, n_f), seed, SweepCounter())
        counter.add_matvec(n_f, n_f)  # the per-candidate sketch is NOT shared
        rho = lam_f + f.lam[-1]
        coeff = (f.lam[-1] + rho) / (f.lam + rho)

        @jax.jit
        def matvec(v, op_f=op_f, lam_f=lam_f):
            return op_f.matvec(v) + lam_f * v

        @jax.jit
        def pinv(r_blk, f=f, coeff=coeff):
            utv = f.u.T @ r_blk
            return f.u @ (coeff[:, None] * utv) + (r_blk - f.u @ utv)

        rhs = jnp.asarray(y_np[train])
        res = blocked_cg(matvec, rhs, pinv, max_iters=max_iters, tol=tol)
        counter.add_matvec(n_f, n_f, res.iters)
        pred_val = op_f.row_block_matvec(jnp.asarray(x_np[val]), res.x)
        counter.add_matvec(len(val), n_f)
        out.append(np.asarray(pred_val))
    return out


# ---------------------------------------------------------------------------
# scoring + entry point
# ---------------------------------------------------------------------------


def _score_fold(pred: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """(mse, top1-accuracy) of validation predictions vs targets, all heads."""
    mse = float(np.mean((pred - truth) ** 2))
    if truth.ndim == 2 and truth.shape[1] > 1:
        acc = float(np.mean(pred.argmax(axis=1) == truth.argmax(axis=1)))
    else:
        acc = float(np.mean(np.sign(pred) == np.sign(truth)))
    return mse, acc


def tune(
    problem: KRRProblem,
    *,
    sigmas: Sequence[float] = (0.5, 1.0, 2.0),
    lams: Sequence[float] = (1e-6, 1e-4, 1e-2),
    folds: int = 5,
    search: str = "grid",
    num_samples: int | None = None,
    strategy: str = "shared",
    rank: int = 100,
    max_iters: int = 200,
    tol: float = 1e-5,
    seed: int = 0,
    warm_start: bool = True,
    mesh=None,
) -> TuneResult:
    """Grid/random search over (sigma, lam_unscaled) with k-fold CV.

    Args:
      problem: the data container; its ``x``/``y``/``kernel``/``backend`` are
        used, its ``sigma``/``lam_unscaled`` are ignored (they are what is
        being tuned).  ``y`` may be (n,) or (n, t) one-vs-all heads — all t
        heads ride the same stacked solve.
      sigmas / lams: candidate kernel bandwidths and *unscaled* regularizers
        (the solved shift is ``n_train_fold * lam_unscaled``, the paper's
        App. C.2.1 scaling — same rule :class:`KRRProblem` applies).
      folds: k for k-fold CV (2 <= k <= n); folds are a seeded shuffle-split
        shared by every candidate and both strategies.
      search: "grid" (full cross product) or "random" (``num_samples``
        candidates drawn from the grid without replacement).
      strategy: "shared" — per sigma, ONE stacked blocked-CG over all
        (lam, fold, head) columns (the tile-sharing path); "naive" — an
        independent PCG solve per (sigma, lam, fold), the reference loop the
        benchmark compares against.
      rank: Nystrom sketch rank for the preconditioner (and warm start).
      max_iters / tol: blocked-CG budget per stacked (or per-candidate) solve.
      warm_start: start each column from the Woodbury apply of the shared
        sketch instead of zero ("shared" strategy only; costs no kernel
        sweeps).
      mesh: optional ``jax.sharding.Mesh`` — candidates then run over a
        :class:`~repro.distributed.sharded_operator.ShardedKernelOperator`
        with x/iterates row-sharded (a 1-device mesh is valid everywhere).

    Returns:
      A :class:`TuneResult`; ``result.best`` is the serving-ready config and
      ``result.sweeps`` the kernel-tile work consumed.
    """
    if search not in SEARCHES:
        raise ValueError(f"unknown search {search!r}; accepted: {SEARCHES}")
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; accepted: {STRATEGIES}")
    if not sigmas or not lams:
        raise ValueError("sigmas and lams must be non-empty")
    if any(s <= 0 for s in sigmas) or any(l <= 0 for l in lams):
        raise ValueError("sigmas and lams must be positive")
    n = problem.n
    if not 2 <= folds <= n:
        raise ValueError(f"folds must be in [2, n={n}]; got {folds}")
    if strategy == "naive" and mesh is not None and mesh.devices.size > 1:
        # the naive loop restricts to (k-1)/k * n rows per fold, which the
        # sharded operator would gather fully replicated onto every device —
        # anti-scalable by construction; the reference loop is single-device
        raise ValueError(
            "strategy='naive' is a single-device reference loop; it supports "
            "at most a 1-device mesh (use strategy='shared' for mesh runs)"
        )

    rng = np.random.default_rng(seed)
    cands = _candidates(sigmas, lams, search, num_samples, rng)
    val_folds = _make_folds(n, folds, np.random.default_rng(seed + 1))
    y2, _ = as_multirhs(problem.y)
    y_np = np.asarray(y2)
    t = y_np.shape[1]
    counter = SweepCounter()

    # group candidates by sigma, preserving first-seen sigma order
    by_sigma: dict[float, list[float]] = {}
    for s, l in cands:
        by_sigma.setdefault(s, []).append(l)

    records: list[dict[str, Any]] = []
    iters_by_sigma: dict[float, int] = {}
    best_w0: np.ndarray | None = None
    best_mse_so_far = np.inf
    squeeze_w0 = problem.y.ndim == 1
    for s, lam_list in by_sigma.items():
        if strategy == "shared":
            op = _operator_for(problem, s, mesh)
            preds, iters, w_cols = _tune_one_sigma_shared(
                op, y_np, lam_list, val_folds, rank=min(rank, n),
                max_iters=max_iters, tol=tol, seed=seed, warm_start=warm_start,
                counter=counter,
            )
            iters_by_sigma[s] = iters
            k = len(val_folds)
            for li, lam_u in enumerate(lam_list):
                fold_mse, fold_acc = [], []
                for j, val in enumerate(val_folds):
                    cols = slice((li * k + j) * t, (li * k + j) * t + t)
                    mse, acc = _score_fold(preds[val, cols], y_np[val])
                    fold_mse.append(mse)
                    fold_acc.append(acc)
                records.append(_record(s, lam_u, fold_mse, fold_acc, t))
                if records[-1]["cv_mse"] < best_mse_so_far:
                    # the winner's refit warm start: mask-supported mean of
                    # its k fold solutions (computed lazily — slicing w_cols
                    # is free, keeping every candidate's block would not be)
                    best_mse_so_far = records[-1]["cv_mse"]
                    best_w0 = _fold_avg_w0(
                        w_cols, li * k * t, k, t, squeeze_w0
                    )
        else:
            for lam_u in lam_list:
                fold_mse, fold_acc = [], []
                per_fold = _tune_one_candidate_naive(
                    problem, s, lam_u, val_folds, rank=rank,
                    max_iters=max_iters, tol=tol, seed=seed, counter=counter,
                    mesh=mesh,
                )
                for pred, val in zip(per_fold, val_folds):
                    mse, acc = _score_fold(pred, y_np[val])
                    fold_mse.append(mse)
                    fold_acc.append(acc)
                records.append(_record(s, lam_u, fold_mse, fold_acc, t))

    best_i = int(np.argmin([r["cv_mse"] for r in records]))
    best_rec = records[best_i]
    best = {
        "kernel": problem.kernel,
        "sigma": best_rec["sigma"],
        "lam_unscaled": best_rec["lam_unscaled"],
        "backend": problem.backend,
        "folds": folds,
        "cv_mse": best_rec["cv_mse"],
    }
    # what the per-candidate loop would have cost, in full-K sweeps: each of
    # the |cands| * k fold solves pays its own sketch + iteration sweeps over
    # ((k-1)/k * n)^2 tiles
    frac = ((folds - 1) / folds) ** 2
    est_iters = max(iters_by_sigma.values()) if iters_by_sigma else max_iters
    naive_est = len(cands) * folds * frac * (est_iters + 1)
    return TuneResult(
        best=best,
        best_score=best_rec["cv_mse"],
        records=records,
        folds=folds,
        search=search,
        strategy=strategy,
        sweeps=counter.sweeps(n),
        info={
            "pairs": counter.pairs,
            "n": n,
            "t": t,
            "candidates": len(cands),
            "iters_by_sigma": {str(k_): v for k_, v in iters_by_sigma.items()},
            "naive_sweep_estimate": naive_est,
        },
        best_w0=best_w0,
    )


def _record(
    sigma: float, lam_u: float, fold_mse: list[float], fold_acc: list[float], t: int
) -> dict[str, Any]:
    rec: dict[str, Any] = {
        "sigma": sigma,
        "lam_unscaled": lam_u,
        "cv_mse": float(np.mean(fold_mse)),
        "fold_mse": fold_mse,
    }
    if t > 1:
        rec["cv_acc"] = float(np.mean(fold_acc))
    return rec


# ---------------------------------------------------------------------------
# multi-kernel search: himalaya-style random search over convex kernel
# combinations, layered onto the SAME stacked engine — every (w, lam, fold,
# head) candidate is one more column of the one blocked-CG per sigma
# ---------------------------------------------------------------------------


def _weight_candidates(
    q: int,
    n_weight_samples: int,
    weights,
    dirichlet_alpha: float,
    rng: np.random.Generator,
) -> np.ndarray:
    """The (M, q) weight-candidate matrix: explicit rows, or Dirichlet draws
    from the simplex (himalaya's ``solve_multiple_kernel_ridge_random_search``
    sampling scheme)."""
    if weights is not None:
        w = np.atleast_2d(np.asarray(weights, np.float32))
        if w.shape[1] != q:
            raise ValueError(
                f"weight candidates have {w.shape[1]} entries per row for "
                f"{q} kernels"
            )
        if (w < 0).any() or (w.sum(axis=1) <= 0).any():
            raise ValueError(
                "weight candidates must be nonnegative with positive row sums"
            )
        return w
    if n_weight_samples < 1:
        raise ValueError("n_weight_samples must be >= 1")
    if dirichlet_alpha <= 0:
        raise ValueError("dirichlet_alpha must be positive")
    return rng.dirichlet(
        np.full(q, float(dirichlet_alpha)), size=int(n_weight_samples)
    ).astype(np.float32)


def _tune_one_sigma_multi_shared(
    op: Any,
    y2: np.ndarray,
    weight_samples: np.ndarray,
    lam_list: list[float],
    val_folds: list[np.ndarray],
    *,
    rank: int,
    max_iters: int,
    tol: float,
    seed: int,
    warm_start: bool,
    counter: SweepCounter,
) -> tuple[np.ndarray, int, np.ndarray]:
    """Solve ALL (weight, lam, fold, head) systems for one sigma in ONE
    stacked blocked-CG: columns ``c = ((m * l + lam_i) * k + fold_j) * t + h``.

    Column c's operator is ``M_j (sum_i W[m, i] K_i) M_j + lam_c I`` — the
    per-column weight vector rides the fused multi-kernel matvec
    (``op.matvec_cols``), so the kernel-tile work per iteration is ONE data
    sweep no matter how many weight candidates are in flight.  The q
    per-kernel Nystrom sketches come from one ``sketch_components`` sweep;
    candidate m's preconditioner/warm-start factors are its weighted sketch
    combination (``K_w Omega = sum_i w_i K_i Omega``) — zero extra sweeps.

    Returns ``(preds, iters, w_cols)`` exactly like the single-kernel engine.
    """
    n, t = y2.shape
    k = len(val_folds)
    l = len(lam_list)
    m_w = weight_samples.shape[0]
    c_m = l * k * t  # columns per weight sample

    fold_mask = np.ones((n, k), np.float32)
    for j, val in enumerate(val_folds):
        fold_mask[val, j] = 0.0
    n_train = [n - len(val) for val in val_folds]

    # columns: weight outer, then lam, fold, head (head innermost)
    fh_mask = np.repeat(fold_mask, t, axis=1)  # (n, k*t)
    fh_rhs = (fold_mask[:, :, None] * y2[:, None, :]).reshape(n, k * t)
    masks_cols = np.tile(fh_mask, (1, m_w * l))
    rhs = np.tile(fh_rhs, (1, m_w * l))
    lam_block = np.repeat(
        np.asarray(
            [scaled_lam(n_train[j], lam_u) for lam_u in lam_list for j in range(k)],
            np.float32,
        ),
        t,
    )  # (l*k*t,)
    lam_cols = np.tile(lam_block, m_w)  # (C,)
    col_weights = np.repeat(weight_samples.T, c_m, axis=1)  # (q, C)

    masks_d = _place(op, masks_cols)
    rhs_d = _place(op, rhs)
    lam_d = jnp.asarray(lam_cols)
    wc_d = jnp.asarray(col_weights)

    # ONE data sweep: q per-kernel sketches of the shared test matrix
    rng = np.random.default_rng(seed)
    omega = _place(op, rng.standard_normal((n, rank)).astype(np.float32))
    omega, _ = jnp.linalg.qr(omega)
    y_stack = op.sketch_components(omega)  # (q, n, r)
    counter.add_matvec(n, n)

    # per weight sample: Nystrom factors of K_w from the combined sketch
    us, lams_ny = [], []
    for m in range(m_w):
        w_m = jnp.asarray(weight_samples[m])
        f_m = nystrom_from_sketch(
            jnp.tensordot(w_m, y_stack, axes=1), omega,
            float(weight_samples[m].sum()) * op.trace_est(),
        )
        us.append(f_m.u)
        lams_ny.append(f_m.lam)
    u_st = jnp.stack(us)  # (M, n, r)
    lam_st = jnp.stack(lams_ny)  # (M, r)

    lam3 = lam_d.reshape(m_w, c_m)  # (M, Cm) per-column shifts
    rho = lam3 + lam_st[:, -1:]  # damped rho per column
    coeff = (lam_st[:, -1:][:, :, None] + rho[:, None, :]) / (
        lam_st[:, :, None] + rho[:, None, :]
    )  # (M, r, Cm)

    @jax.jit
    def matvec(v: jax.Array) -> jax.Array:
        # one fused multi-kernel pass over ALL columns; the per-column weight
        # vector, mask and shift are elementwise
        return masks_d * op.matvec_cols(masks_d * v, wc_d) + lam_d * v

    @jax.jit
    def pinv(r_blk: jax.Array) -> jax.Array:
        r3 = r_blk.reshape(n, m_w, c_m)
        utv = jnp.einsum("mnr,nmc->mrc", u_st, r3)
        uutv = jnp.einsum("mnr,mrc->nmc", u_st, utv)
        out3 = jnp.einsum("mnr,mrc->nmc", u_st, coeff * utv) + (r3 - uutv)
        return masks_d * out3.reshape(n, m_w * c_m)

    x0 = None
    if warm_start:

        @jax.jit
        def _warm(rhs_in: jax.Array) -> jax.Array:
            # per-column Woodbury apply of candidate m's Nystrom inverse
            rhs3 = rhs_in.reshape(n, m_w, c_m)
            utg = jnp.einsum("mnr,nmc->mrc", u_st, rhs3)
            core = utg / (lam_st[:, :, None] + lam3[:, None, :])
            out3 = jnp.einsum("mnr,mrc->nmc", u_st, core) + (
                rhs3 - jnp.einsum("mnr,mrc->nmc", u_st, utg)
            ) / lam3[None, :, :]
            return masks_d * out3.reshape(n, m_w * c_m)

        x0 = _warm(rhs_d)

    res = blocked_cg(matvec, rhs_d, pinv, x0=x0, max_iters=max_iters, tol=tol)
    counter.add_matvec(n, n, res.iters + (1 if x0 is not None else 0))

    preds = op.matvec_cols(res.x, wc_d)  # ONE more sweep scores every candidate
    counter.add_matvec(n, n)
    return np.asarray(preds), res.iters, np.asarray(res.x)


def _mk_record(
    sigma: float,
    w: np.ndarray,
    lam_u: float,
    fold_mse: list[float],
    fold_acc: list[float],
    t: int,
) -> dict[str, Any]:
    rec = _record(sigma, lam_u, fold_mse, fold_acc, t)
    rec["weights"] = [float(x) for x in w]
    return rec


def tune_multikernel(
    problem: KRRProblem,
    *,
    kernels: Sequence[str] | None = None,
    sigmas: Sequence[float] = (0.5, 1.0, 2.0),
    lams: Sequence[float] = (1e-6, 1e-4, 1e-2),
    folds: int = 5,
    n_weight_samples: int = 8,
    weights=None,
    dirichlet_alpha: float = 1.0,
    strategy: str = "shared",
    rank: int = 100,
    max_iters: int = 200,
    tol: float = 1e-5,
    seed: int = 0,
    warm_start: bool = True,
    mesh=None,
) -> TuneResult:
    """Random search over convex kernel combinations with k-fold CV.

    himalaya's ``solve_multiple_kernel_ridge_random_search`` draws weight
    vectors from the simplex and scores the banded per-candidate systems;
    here every (weight, lam, fold, head) candidate becomes one more COLUMN
    of the same stacked blocked-CG the (sigma, lam) tuner runs — per sigma,
    the whole c-candidate search costs ~1 solve's kernel-tile work (the
    acceptance claim ``benchmarks/bench_multikernel.py`` measures).

    Args:
      problem: data container; ``kernels`` defaults to ``problem.kernel``
        when that is already a tuple.  ``y`` may be (n,) or (n, t).
      kernels: the q base-kernel names of the combination.
      sigmas: candidate bandwidths, shared by all q kernels per sigma group.
      lams: candidate *unscaled* regularizers (paper App. C.2.1 scaling).
      folds: k for k-fold CV (same seeded shuffle-split as :func:`tune`).
      n_weight_samples: number of Dirichlet(``dirichlet_alpha``) weight
        draws from the simplex.
      weights: explicit (M, q) weight-candidate rows (overrides sampling;
        e.g. one-hot rows reproduce single-kernel tuning exactly).
      strategy: "shared" (the stacked engine) or "naive" (independent
        Nystrom-PCG per (sigma, weight, lam, fold) — the reference loop).
      rank / max_iters / tol / warm_start / seed / mesh: as in :func:`tune`.

    Returns:
      A :class:`TuneResult`; ``best`` carries ``kernel`` (the q names),
      ``weights``, ``sigma``, ``lam_unscaled`` — serving-ready via
      ``make_krr_predict_fn_from_config`` — and ``best_w0`` the winner's
      fold-averaged warm start.  Records carry per-candidate ``weights``.
    """
    from repro.core.multikernel import canonical_kernels

    if kernels is None:
        if not isinstance(problem.kernel, tuple):
            raise ValueError(
                "tune_multikernel needs kernels=(...) or a problem whose "
                f"kernel is a tuple; got kernel={problem.kernel!r}"
            )
        kernels = problem.kernel
    kernels, _, _ = canonical_kernels(kernels, 1.0, None)
    q = len(kernels)
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; accepted: {STRATEGIES}")
    if not sigmas or not lams:
        raise ValueError("sigmas and lams must be non-empty")
    if any(s <= 0 for s in sigmas) or any(lv <= 0 for lv in lams):
        raise ValueError("sigmas and lams must be positive")
    n = problem.n
    if not 2 <= folds <= n:
        raise ValueError(f"folds must be in [2, n={n}]; got {folds}")
    if strategy == "naive" and mesh is not None and mesh.devices.size > 1:
        raise ValueError(
            "strategy='naive' is a single-device reference loop; it supports "
            "at most a 1-device mesh (use strategy='shared' for mesh runs)"
        )

    rng = np.random.default_rng(seed)
    w_cands = _weight_candidates(q, n_weight_samples, weights, dirichlet_alpha, rng)
    m_w = w_cands.shape[0]
    sig_list = [float(s) for s in dict.fromkeys(sigmas)]
    lam_list = [float(lv) for lv in lams]
    l = len(lam_list)
    val_folds = _make_folds(n, folds, np.random.default_rng(seed + 1))
    y2, _ = as_multirhs(problem.y)
    y_np = np.asarray(y2)
    t = y_np.shape[1]
    counter = SweepCounter()
    # the problem restated as the multi-kernel combination being searched
    mk_problem = dataclasses.replace(
        problem, kernel=kernels, sigma=1.0, weights=None
    )

    records: list[dict[str, Any]] = []
    iters_by_sigma: dict[float, int] = {}
    best_w0: np.ndarray | None = None
    best_mse_so_far = np.inf
    squeeze_w0 = problem.y.ndim == 1
    k = len(val_folds)
    for s in sig_list:
        if strategy == "shared":
            op = _operator_for(mk_problem, s, mesh)
            preds, iters, w_cols = _tune_one_sigma_multi_shared(
                op, y_np, w_cands, lam_list, val_folds, rank=min(rank, n),
                max_iters=max_iters, tol=tol, seed=seed, warm_start=warm_start,
                counter=counter,
            )
            iters_by_sigma[s] = iters
            for m in range(m_w):
                for li, lam_u in enumerate(lam_list):
                    col0 = (m * l + li) * k * t
                    fold_mse, fold_acc = [], []
                    for j, val in enumerate(val_folds):
                        cols = slice(col0 + j * t, col0 + (j + 1) * t)
                        mse, acc = _score_fold(preds[val, cols], y_np[val])
                        fold_mse.append(mse)
                        fold_acc.append(acc)
                    records.append(
                        _mk_record(s, w_cands[m], lam_u, fold_mse, fold_acc, t)
                    )
                    if records[-1]["cv_mse"] < best_mse_so_far:
                        best_mse_so_far = records[-1]["cv_mse"]
                        best_w0 = _fold_avg_w0(w_cols, col0, k, t, squeeze_w0)
        else:
            for m in range(m_w):
                for lam_u in lam_list:
                    fold_mse, fold_acc = [], []
                    per_fold = _tune_one_candidate_naive(
                        mk_problem, s, lam_u, val_folds, rank=rank,
                        max_iters=max_iters, tol=tol, seed=seed,
                        counter=counter, mesh=mesh, weights=w_cands[m],
                    )
                    for pred, val in zip(per_fold, val_folds):
                        mse, acc = _score_fold(pred, y_np[val])
                        fold_mse.append(mse)
                        fold_acc.append(acc)
                    records.append(
                        _mk_record(s, w_cands[m], lam_u, fold_mse, fold_acc, t)
                    )

    best_i = int(np.argmin([r["cv_mse"] for r in records]))
    best_rec = records[best_i]
    best = {
        "kernel": list(kernels),
        "sigma": best_rec["sigma"],
        "weights": best_rec["weights"],
        "lam_unscaled": best_rec["lam_unscaled"],
        "backend": problem.backend,
        "folds": folds,
        "cv_mse": best_rec["cv_mse"],
    }
    n_cands = len(sig_list) * m_w * l
    frac = ((folds - 1) / folds) ** 2
    est_iters = max(iters_by_sigma.values()) if iters_by_sigma else max_iters
    naive_est = n_cands * folds * frac * (est_iters + 1)
    return TuneResult(
        best=best,
        best_score=best_rec["cv_mse"],
        records=records,
        folds=folds,
        search="random",
        strategy=strategy,
        sweeps=counter.sweeps(n),
        info={
            "pairs": counter.pairs,
            "n": n,
            "t": t,
            "q": q,
            "kernels": list(kernels),
            "weight_samples": m_w,
            "candidates": n_cands,
            "iters_by_sigma": {str(k_): v for k_, v in iters_by_sigma.items()},
            "naive_sweep_estimate": naive_est,
        },
        best_w0=best_w0,
    )
