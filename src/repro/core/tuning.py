"""Deprecated shim — the tuning monolith moved to the ``repro.core.tune``
package (PR 5: engine/policy split).

``core/tuning.py`` grew into a 900-line monolith with near-duplicate
single- and multi-kernel code paths; it is now:

  * ``repro.core.tune.engine`` — the stacked per-sigma solve (fold masks,
    column assembly, sketch + lam-damped preconditioning, sweep accounting),
    single-kernel as the q = 1 degenerate case of multi-kernel.
  * ``repro.core.tune.policies`` — GridSearch / RandomSearch /
    SuccessiveHalving behind the ``SearchPolicy`` protocol.
  * ``repro.core.tune.api`` — ``tune`` / ``tune_multikernel`` /
    ``apply_best`` / ``TuneResult``.

Every public name is re-exported here so existing imports keep working;
new code should import from :mod:`repro.core.tune`.
"""

from repro.core.tune import (  # noqa: F401
    SEARCHES,
    STRATEGIES,
    SweepCounter,
    TuneResult,
    apply_best,
    tune,
    tune_multikernel,
)

__all__ = [
    "SEARCHES",
    "STRATEGIES",
    "SweepCounter",
    "TuneResult",
    "apply_best",
    "tune",
    "tune_multikernel",
]
