"""Blocked (multi-RHS) preconditioned conjugate gradient.

One CG loop shared by ``pcg.solve_pcg`` (full-K system, Nystrom/RPCholesky
preconditioners), ``falkon.solve_falkon`` (inducing-point system, plain CG
on the Falkon-preconditioned operator) and the tuning engine
(``core/tune/engine.py``, one stacked solve per sigma group).  Each of the t
right-hand-side columns carries its own alpha/beta/residual; columns whose
relative residual reaches ``tol`` are frozen (their search direction zeroed)
while the rest continue — trajectories are identical to t independent CG
runs, but every ``matvec`` is one fused pass over all t columns.

Two freezing mechanisms compose:

  * **Convergence freezing** (always on): a column below ``tol`` stops
    moving; the solve ends when every column is below ``tol``.
  * **External freezing** (``freeze_at``/``freeze_callback``): at chosen
    iterations — the *rungs* of a successive-halving search — a callback
    inspects the current block and may freeze additional columns (losing
    tuning candidates).  Externally frozen columns keep their prune-time
    values and are excluded from the convergence requirement; if every
    column ends up frozen (externally or by convergence) the loop exits
    early.  Because each column's alpha/beta depend only on its own data,
    freezing one column never perturbs the trajectory of another.

All-zero RHS columns (a one-vs-all head with no positives in a fold, say)
are frozen at iteration 0 with ``rel_residual_per_head = 0`` — the exact
solution of ``A x = 0`` is ``x = 0`` for SPD ``A`` — instead of riding the
loop and risking 0/0 in the per-column scalars.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import counter as _obs_counter
from repro.obs.trace import TraceRecorder

#: signature of the external-freeze hook: ``(it, x, rel_heads, frozen) ->
#: bool mask of columns to freeze now (or None)``; ``frozen`` is the
#: cumulative external-freeze mask so far and the returned mask is OR-ed in.
FreezeCallback = Callable[
    [int, jax.Array, np.ndarray, np.ndarray], "np.ndarray | None"
]


@dataclasses.dataclass
class BlockedCGResult:
    x: jax.Array  # (p, t) solution block
    iters: int
    history: list[dict]
    converged: bool
    #: (t,) bool — columns frozen externally (freeze_callback / zero RHS);
    #: their x columns hold the value at freeze time
    frozen: np.ndarray | None = None


def blocked_cg(
    matvec: Callable[[jax.Array], jax.Array],
    rhs: jax.Array,
    pinv: Callable[[jax.Array], jax.Array] | None = None,
    *,
    x0: jax.Array | None = None,
    max_iters: int = 200,
    tol: float = 1e-8,
    t0: float | None = None,
    time_budget_s: float | None = None,
    freeze_at: "tuple[int, ...] | list[int] | None" = None,
    freeze_callback: FreezeCallback | None = None,
    recorder: "TraceRecorder | None" = None,
) -> BlockedCGResult:
    """Solve A X = RHS column-blocked, RHS of shape (p, t).

    ``x0`` warm-starts the iteration (one extra ``matvec`` to form the
    initial residual; default is the zero start, which costs none).  History
    records carry ``rel_residual`` (aggregate ||R||_F / ||RHS||_F) and
    ``rel_residual_per_head``; convergence requires every non-frozen column
    below ``tol`` (relative to its own RHS column norm).

    ``freeze_at`` is a collection of iteration numbers (rungs); after each
    listed iteration completes, ``freeze_callback(it, x, rel_heads, frozen)``
    runs and may return a (t,) bool mask of columns to freeze externally —
    those columns stop moving (their search direction and scalars zero) but
    keep their current x values, exactly as if they had converged.  Columns
    whose RHS is identically zero are externally frozen at iteration 0 with
    ``rel_residual_per_head = 0``.  ``result.frozen`` reports the final
    external-freeze mask; ``converged`` stays the strict all-columns-below-
    tol statement.

    ``recorder`` (a ``repro.obs.trace.TraceRecorder``) receives every
    iterate; callers that don't pass one still get the same ``history``
    list via an internal recorder's compatibility view.
    """
    if recorder is None:
        recorder = TraceRecorder("cg")
    t0 = time.perf_counter() if t0 is None else t0
    tiny = jnp.finfo(rhs.dtype).tiny
    rhs_norm_raw = jnp.linalg.norm(rhs, axis=0)  # (t,) true norms, may be 0
    rhs_norm = jnp.maximum(rhs_norm_raw, tiny)
    rhs_norm_np = np.asarray(rhs_norm)
    rhs_norm_f = max(float(np.sqrt((rhs_norm_np**2).sum())), float(tiny))
    # all-zero RHS columns: the solution is exactly 0 — freeze them at
    # iteration 0 instead of letting 0/0 scalars decide
    ext_frozen = np.asarray(rhs_norm_raw) == 0.0  # (t,) cumulative mask
    rungs = frozenset(int(i) for i in freeze_at) if freeze_at else frozenset()
    if ext_frozen.any():
        live = jnp.asarray(~ext_frozen, rhs.dtype)
        rhs = rhs * live
        if x0 is not None:
            x0 = x0 * live
    if x0 is None:
        x = jnp.zeros_like(rhs)
        r = rhs  # residual for x0 = 0
    else:
        x = x0
        r = rhs - matvec(x0)
    history = recorder.history
    converged = bool(ext_frozen.all())
    if converged:  # every column zero: nothing to solve
        return BlockedCGResult(
            x=x, iters=0, history=history, converged=True, frozen=ext_frozen
        )
    z = pinv(r) if pinv is not None else r
    p = z
    rz = jnp.sum(r * z, axis=0)  # (t,) per-column <r, z>
    if ext_frozen.any():
        gate = jnp.asarray(~ext_frozen, rz.dtype)
        p = p * gate
        rz = rz * gate
    it = 0
    for it in range(1, max_iters + 1):
        ap = matvec(p)  # one fused pass for all t columns
        pap = jnp.sum(p * ap, axis=0)
        # frozen (converged or external) columns get alpha = 0 and stop moving
        active = rz > 0
        alpha = jnp.where(active, rz / jnp.where(active, pap, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        # ONE device->host transfer per iteration: column norms; the
        # aggregate Frobenius residual derives from them on the host
        col_norms = np.asarray(jnp.linalg.norm(r, axis=0))
        # zero-RHS columns have exactly-zero residuals (their rhs/x0 were
        # zeroed above), so they report rel = 0 without special-casing;
        # externally PRUNED columns keep their true (stale) residual
        rel_heads_np = col_norms / rhs_norm_np
        rel = float(np.sqrt((col_norms**2).sum())) / rhs_norm_f
        recorder.add(
            it, rel,
            rel_residual_per_head=rel_heads_np.tolist(),
            time_s=time.perf_counter() - t0,
        )
        below = rel_heads_np < tol
        if bool(below.all()):
            converged = True
            break
        if freeze_callback is not None and it in rungs:
            new_frozen = freeze_callback(it, x, rel_heads_np, ext_frozen)
            if new_frozen is not None:
                ext_frozen = ext_frozen | np.asarray(new_frozen, bool)
        # a frozen column (converged or external) is done; exit when none left
        if bool((below | ext_frozen).all()):
            break
        z = pinv(r) if pinv is not None else r
        rz_new = jnp.sum(r * z, axis=0)
        # zero the search direction of columns below tol or frozen externally
        keep = jnp.asarray((rel_heads_np >= tol) & ~ext_frozen, rz_new.dtype)
        beta = jnp.where(active, rz_new / jnp.where(active, rz, 1.0), 0.0)
        p = (z + beta * p) * keep
        rz = rz_new * keep
        if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
            break
    if it:
        _obs_counter(
            "repro_cg_iterations_total",
            help="blocked-CG iterations executed (all callers)",
        ).inc(it)
    return BlockedCGResult(
        x=x, iters=it, history=history, converged=converged,
        frozen=ext_frozen if ext_frozen.any() else None,
    )
