"""Blocked (multi-RHS) preconditioned conjugate gradient.

One CG loop shared by ``pcg.solve_pcg`` (full-K system, Nystrom/RPCholesky
preconditioners) and ``falkon.solve_falkon`` (inducing-point system, plain CG
on the Falkon-preconditioned operator).  Each of the t right-hand-side
columns carries its own alpha/beta/residual; columns whose relative residual
reaches ``tol`` are frozen (their search direction zeroed) while the rest
continue — trajectories are identical to t independent CG runs, but every
``matvec`` is one fused pass over all t columns.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class BlockedCGResult:
    x: jax.Array  # (p, t) solution block
    iters: int
    history: list[dict]
    converged: bool


def blocked_cg(
    matvec: Callable[[jax.Array], jax.Array],
    rhs: jax.Array,
    pinv: Callable[[jax.Array], jax.Array] | None = None,
    *,
    x0: jax.Array | None = None,
    max_iters: int = 200,
    tol: float = 1e-8,
    t0: float | None = None,
    time_budget_s: float | None = None,
) -> BlockedCGResult:
    """Solve A X = RHS column-blocked, RHS of shape (p, t).

    ``x0`` warm-starts the iteration (one extra ``matvec`` to form the
    initial residual; default is the zero start, which costs none).  History
    records carry ``rel_residual`` (aggregate ||R||_F / ||RHS||_F) and
    ``rel_residual_per_head``; convergence requires every column below
    ``tol`` (relative to its own RHS column norm).
    """
    t0 = time.perf_counter() if t0 is None else t0
    tiny = jnp.finfo(rhs.dtype).tiny
    rhs_norm = jnp.maximum(jnp.linalg.norm(rhs, axis=0), tiny)  # (t,)
    rhs_norm_np = np.asarray(rhs_norm)
    rhs_norm_f = max(float(np.sqrt((rhs_norm_np**2).sum())), float(tiny))
    if x0 is None:
        x = jnp.zeros_like(rhs)
        r = rhs  # residual for x0 = 0
    else:
        x = x0
        r = rhs - matvec(x0)
    z = pinv(r) if pinv is not None else r
    p = z
    rz = jnp.sum(r * z, axis=0)  # (t,) per-column <r, z>
    history: list[dict] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        ap = matvec(p)  # one fused pass for all t columns
        pap = jnp.sum(p * ap, axis=0)
        # frozen (converged) columns get alpha = 0 and stop moving
        active = rz > 0
        alpha = jnp.where(active, rz / jnp.where(active, pap, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        # ONE device->host transfer per iteration: column norms; the
        # aggregate Frobenius residual derives from them on the host
        col_norms = np.asarray(jnp.linalg.norm(r, axis=0))
        rel_heads_np = col_norms / rhs_norm_np
        rel = float(np.sqrt((col_norms**2).sum())) / rhs_norm_f
        history.append({
            "iter": it,
            "rel_residual": rel,
            "rel_residual_per_head": rel_heads_np.tolist(),
            "time_s": time.perf_counter() - t0,
        })
        if bool((rel_heads_np < tol).all()):
            converged = True
            break
        z = pinv(r) if pinv is not None else r
        rz_new = jnp.sum(r * z, axis=0)
        # zero the search direction of columns already below tol
        keep = jnp.asarray(rel_heads_np >= tol, rz_new.dtype)
        beta = jnp.where(active, rz_new / jnp.where(active, rz, 1.0), 0.0)
        p = (z + beta * p) * keep
        rz = rz_new * keep
        if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
            break
    return BlockedCGResult(x=x, iters=it, history=history, converged=converged)
