"""Kernel functions for KRR (paper §6 / Appendix C.1).

Three kernels are used by the paper's testbed: RBF, Laplacian, Matern-5/2.
All are shift-invariant with unit diagonal k(x, x) = 1, a fact exploited by
the randomly-pivoted-Cholesky baseline and the Nystrom shift heuristics.

The canonical (materializing) implementations live here; the fused streaming
implementations (never materializing K) live in ``repro.kernels`` (Pallas for
TPU, chunked-XLA fallback) and are validated against these.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

KERNEL_NAMES = ("rbf", "laplacian", "matern52")


def _sq_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise squared Euclidean distances via the matmul expansion.

    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 <x, y>.  This is the MXU-friendly
    form used by the Pallas kernel as well.  Inputs are promoted to at least
    f32 (so bf16 chunks accumulate in f32, matching the fused-op contract)
    but f64 operands stay f64 — the machine-precision convergence benchmark
    (benchmarks/bench_fig9_convergence.py) depends on a true double path.
    """
    dt = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dt)
    y = y.astype(dt)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def _l1_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise L1 distances.  O(m*n*d) memory if broadcast naively — callers
    with large operands must go through the chunked/streaming ops.  Same
    promote-to-at-least-f32 contract as :func:`_sq_dists`."""
    dt = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dt)
    y = y.astype(dt)
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def rbf(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """k(x, x') = exp(-||x - x'||^2 / (2 sigma^2))."""
    return jnp.exp(-_sq_dists(x, y) / (2.0 * sigma**2))


def laplacian(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """k(x, x') = exp(-||x - x'||_1 / sigma)."""
    return jnp.exp(-_l1_dists(x, y) / sigma)


def matern52(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Matern-5/2: (1 + sqrt(5) d / sigma + 5 d^2 / (3 sigma^2)) exp(-sqrt(5) d / sigma)."""
    d2 = _sq_dists(x, y)
    d = jnp.sqrt(d2 + 1e-20)
    s5 = jnp.sqrt(5.0) * d / sigma
    return (1.0 + s5 + 5.0 * d2 / (3.0 * sigma**2)) * jnp.exp(-s5)


_KERNELS: dict[str, Callable[[jax.Array, jax.Array, float], jax.Array]] = {
    "rbf": rbf,
    "laplacian": laplacian,
    "matern52": matern52,
}


def kernel_fn(name: str) -> Callable[[jax.Array, jax.Array, float], jax.Array]:
    try:
        return _KERNELS[name]
    except KeyError:
        raise ValueError(f"unknown kernel {name!r}; available: {KERNEL_NAMES}") from None


def kernel_matrix(name: str, x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Materialize K(x, y).  Small operands only (tests, b x b blocks)."""
    return kernel_fn(name)(x, y, sigma)


@functools.partial(jax.jit, static_argnames=("name",))
def kernel_block(name: str, x: jax.Array, y: jax.Array, sigma: jax.Array) -> jax.Array:
    """Jitted block materialization used for K_BB inside solver steps."""
    return kernel_fn(name)(x, y, sigma)


def median_heuristic(x: jax.Array, max_points: int = 2048, seed: int = 0) -> float:
    """Median pairwise distance bandwidth heuristic (Gretton et al. 2012),
    used by the paper for several datasets (Table 3)."""
    n = x.shape[0]
    if n > max_points:
        idx = jax.random.permutation(jax.random.PRNGKey(seed), n)[:max_points]
        x = x[idx]
    d2 = _sq_dists(x, x)
    iu = jnp.triu_indices(x.shape[0], k=1)
    med = jnp.median(jnp.sqrt(d2[iu]))
    return float(med)
