"""Kernel functions for KRR (paper §6 / Appendix C.1) plus the zoo extension.

The paper's testbed uses RBF, Laplacian and Matern-5/2 — shift-invariant with
unit diagonal k(x, x) = 1, a fact exploited by the randomly-pivoted-Cholesky
baseline and the Nystrom shift heuristics.  The estimator front end adds the
dot-product family (linear / polynomial / sigmoid) and cosine similarity;
those have data-dependent diagonals, so trace estimates go through
:func:`kernel_diag` instead of assuming ``tr K = n``.

Every kernel is parameterized by ONE bandwidth ``sigma`` so the fused tile
pipeline's (hashable, static) sigma threading is unchanged:

  ========== ============================================  sklearn equivalent
  rbf        exp(-||x-y||^2 / (2 sigma^2))                 gamma = 1/(2 sigma^2)
  laplacian  exp(-||x-y||_1 / sigma)                       gamma = 1/sigma
  matern52   (1 + s5 + 5 d^2/(3 sigma^2)) exp(-s5)         length_scale = sigma
  linear     <x, y> / sigma^2                              gamma-free (sigma=1)
  polynomial (<x, y> / sigma^2 + 1)^3                      gamma = 1/sigma^2
  sigmoid    tanh(<x, y> / sigma^2 + 1)                    gamma = 1/sigma^2
  cosine     <x, y> / (||x|| ||y||)                        scale-free
  ========== ============================================

Each kernel belongs to a distance/base-tile FAMILY (:data:`KERNEL_FAMILIES`):
"l2" (squared Euclidean), "l1" (Manhattan), "dot" (inner product), "cos"
(normalized inner product).  The fused streaming ops compute each family's
tile at most once per chunk pair and apply every kernel map to the shared
tile — the dot/cos families reuse the same MXU matmul the L2 expansion uses,
minus the norm terms.

The canonical (materializing) implementations live here; the fused streaming
implementations (never materializing K) live in ``repro.kernels`` (Pallas for
TPU, chunked-XLA fallback) and are validated against these.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

KERNEL_NAMES = (
    "rbf", "laplacian", "matern52", "linear", "polynomial", "sigmoid",
    "cosine",
)

#: distance/base-tile family per kernel — the fused ops compute one shared
#: tile per family per chunk pair ("l2" squared-L2, "l1" Manhattan, "dot"
#: inner product, "cos" cosine similarity)
KERNEL_FAMILIES: dict[str, str] = {
    "rbf": "l2",
    "laplacian": "l1",
    "matern52": "l2",
    "linear": "dot",
    "polynomial": "dot",
    "sigmoid": "dot",
    "cosine": "cos",
}

#: kernels with k(x, x) = 1 for every x (tr K = n exactly); the rest have
#: data-dependent diagonals handled by :func:`kernel_diag`
UNIT_DIAG_KERNELS = ("rbf", "laplacian", "matern52", "cosine")


def _sq_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise squared Euclidean distances via the matmul expansion.

    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 <x, y>.  This is the MXU-friendly
    form used by the Pallas kernel as well.  Inputs are promoted to at least
    f32 (so bf16 chunks accumulate in f32, matching the fused-op contract)
    but f64 operands stay f64 — the machine-precision convergence benchmark
    (benchmarks/bench_fig9_convergence.py) depends on a true double path.
    """
    dt = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dt)
    y = y.astype(dt)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def _l1_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise L1 distances.  O(m*n*d) memory if broadcast naively — callers
    with large operands must go through the chunked/streaming ops.  Same
    promote-to-at-least-f32 contract as :func:`_sq_dists`."""
    dt = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dt)
    y = y.astype(dt)
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def rbf(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """k(x, x') = exp(-||x - x'||^2 / (2 sigma^2))."""
    return jnp.exp(-_sq_dists(x, y) / (2.0 * sigma**2))


def laplacian(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """k(x, x') = exp(-||x - x'||_1 / sigma)."""
    return jnp.exp(-_l1_dists(x, y) / sigma)


def matern52(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Matern-5/2: (1 + sqrt(5) d / sigma + 5 d^2 / (3 sigma^2)) exp(-sqrt(5) d / sigma)."""
    d2 = _sq_dists(x, y)
    d = jnp.sqrt(d2 + 1e-20)
    s5 = jnp.sqrt(5.0) * d / sigma
    return (1.0 + s5 + 5.0 * d2 / (3.0 * sigma**2)) * jnp.exp(-s5)


def _dots(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise inner products <x_i, y_j>, same promote-to-at-least-f32
    contract as :func:`_sq_dists` (bf16 operands accumulate in f32, f64
    operands stay f64)."""
    dt = jnp.promote_types(x.dtype, jnp.float32)
    return x.astype(dt) @ y.astype(dt).T


def _cos_sims(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise cosine similarities with sklearn's zero-norm convention (a
    zero row divides by 1, so its similarities are exactly 0)."""
    dt = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dt)
    y = y.astype(dt)
    xn = jnp.linalg.norm(x, axis=-1, keepdims=True)
    yn = jnp.linalg.norm(y, axis=-1, keepdims=True)
    x = x / jnp.where(xn == 0.0, 1.0, xn)
    y = y / jnp.where(yn == 0.0, 1.0, yn)
    return x @ y.T


def linear(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """k(x, x') = <x, x'> / sigma^2 (sigma = 1 matches sklearn's linear)."""
    return _dots(x, y) / sigma**2


def polynomial(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Cubic polynomial kernel (<x, x'> / sigma^2 + 1)^3 — sklearn's default
    degree-3 / coef0 = 1 polynomial with gamma = 1/sigma^2."""
    return (_dots(x, y) / sigma**2 + 1.0) ** 3


def sigmoid(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """tanh(<x, x'> / sigma^2 + 1) — sklearn's sigmoid with gamma = 1/sigma^2,
    coef0 = 1.  NOTE: indefinite (not PSD) in general."""
    return jnp.tanh(_dots(x, y) / sigma**2 + 1.0)


def cosine(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Cosine similarity <x, x'> / (||x|| ||x'||); scale-free (sigma ignored)."""
    del sigma
    return _cos_sims(x, y)


_KERNELS: dict[str, Callable[[jax.Array, jax.Array, float], jax.Array]] = {
    "rbf": rbf,
    "laplacian": laplacian,
    "matern52": matern52,
    "linear": linear,
    "polynomial": polynomial,
    "sigmoid": sigmoid,
    "cosine": cosine,
}


def kernel_fn(name: str) -> Callable[[jax.Array, jax.Array, float], jax.Array]:
    try:
        return _KERNELS[name]
    except KeyError:
        raise ValueError(f"unknown kernel {name!r}; available: {KERNEL_NAMES}") from None


def kernel_family(name: str) -> str:
    """Base-tile family of a kernel ("l2" | "l1" | "dot" | "cos") — what the
    fused ops share between kernel maps (see :data:`KERNEL_FAMILIES`)."""
    try:
        return KERNEL_FAMILIES[name]
    except KeyError:
        raise ValueError(f"unknown kernel {name!r}; available: {KERNEL_NAMES}") from None


def kernel_diag(name: str, x: jax.Array, sigma: float) -> jax.Array:
    """The (n,) diagonal k(x_i, x_i) without forming K.

    Unit for the shift-invariant kernels and cosine; ||x||^2-dependent for the
    dot-product family.  This is what keeps ``KernelOperator.trace_est`` exact
    across the whole zoo (the Nystrom rho heuristics depend on it).
    """
    n = x.shape[0]
    if name in UNIT_DIAG_KERNELS:
        return jnp.ones((n,), jnp.float32)
    dt = jnp.promote_types(x.dtype, jnp.float32)
    sq = jnp.sum(x.astype(dt) * x.astype(dt), axis=-1)
    if name == "linear":
        return (sq / float(sigma) ** 2).astype(jnp.float32)
    if name == "polynomial":
        return ((sq / float(sigma) ** 2 + 1.0) ** 3).astype(jnp.float32)
    if name == "sigmoid":
        return jnp.tanh(sq / float(sigma) ** 2 + 1.0).astype(jnp.float32)
    raise ValueError(f"unknown kernel {name!r}; available: {KERNEL_NAMES}")


def kernel_matrix(name: str, x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Materialize K(x, y).  Small operands only (tests, b x b blocks)."""
    return kernel_fn(name)(x, y, sigma)


@functools.partial(jax.jit, static_argnames=("name",))
def kernel_block(name: str, x: jax.Array, y: jax.Array, sigma: jax.Array) -> jax.Array:
    """Jitted block materialization used for K_BB inside solver steps."""
    return kernel_fn(name)(x, y, sigma)


def median_heuristic(x: jax.Array, max_points: int = 2048, seed: int = 0) -> float:
    """Median pairwise distance bandwidth heuristic (Gretton et al. 2012),
    used by the paper for several datasets (Table 3)."""
    n = x.shape[0]
    if n > max_points:
        idx = jax.random.permutation(jax.random.PRNGKey(seed), n)[:max_points]
        x = x[idx]
    d2 = _sq_dists(x, x)
    iu = jnp.triu_indices(x.shape[0], k=1)
    med = jnp.median(jnp.sqrt(d2[iu]))
    return float(med)
