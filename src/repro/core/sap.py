"""Exact sketch-and-project methods (paper §2.1): randomized Kaczmarz,
randomized coordinate descent, randomized (block) Newton, and NSAP
(Algorithm 1, Nesterov-accelerated SAP).

These use exact block solves ((K_BB + lam I)^{-1}, O(b^3)) and exist as
(a) theory-faithful references for tests — Skotch/ASkotch must track their
behaviour while being much cheaper per iteration — and (b) the SAP ablation
arm.  Small/medium n only (they materialize b x n row blocks exactly like
Skotch, but factorize the b x b block densely).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem


class SAPState(NamedTuple):
    w: jax.Array
    v: jax.Array
    z: jax.Array
    key: jax.Array


def _block_residual(problem: KRRProblem, idx: jax.Array, w: jax.Array) -> jax.Array:
    """(K_lam)_{B,:} w - y_B via the fused streaming op (w: (n,) or (n, t))."""
    xb = jnp.take(problem.x, idx, axis=0)
    return (
        problem.op.row_block_matvec(xb, w)
        + problem.lam * jnp.take(w, idx, axis=0)
        - jnp.take(problem.y, idx, axis=0)
    )


def make_randomized_newton_step(problem: KRRProblem, b: int):
    """Example 3 / Eq. (8): exact block projection with Q = K_lam."""
    n = problem.n
    lam = jnp.float32(problem.lam)

    def step(state: SAPState) -> SAPState:
        key, kb = jax.random.split(state.key)
        idx = jax.random.choice(kb, n, (b,), replace=False)
        kbb = problem.op.block_idx(idx)
        g = _block_residual(problem, idx, state.w)
        d = jnp.linalg.solve(kbb + lam * jnp.eye(b, dtype=kbb.dtype), g)
        w = state.w.at[idx].add(-d)
        return SAPState(w=w, v=w, z=w, key=key)

    return step


def make_nsap_step(problem: KRRProblem, b: int, mu: float, nu: float):
    """Algorithm 1 (NSAP) with block (randomized Newton) sketches."""
    n = problem.n
    lam = jnp.float32(problem.lam)
    beta = 1.0 - math.sqrt(mu / nu)
    gamma = 1.0 / math.sqrt(mu * nu)
    alpha = 1.0 / (1.0 + gamma * nu)

    def step(state: SAPState) -> SAPState:
        key, kb = jax.random.split(state.key)
        idx = jax.random.choice(kb, n, (b,), replace=False)
        kbb = problem.op.block_idx(idx)
        g = _block_residual(problem, idx, state.z)
        d = jnp.linalg.solve(kbb + lam * jnp.eye(b, dtype=kbb.dtype), g)
        w = state.z.at[idx].add(-d)
        v = (beta * state.v + (1.0 - beta) * state.z).at[idx].add(-gamma * d)
        z = alpha * v + (1.0 - alpha) * w
        return SAPState(w=w, v=v, z=z, key=key)

    return step


def make_kaczmarz_step(problem: KRRProblem):
    """Example 1: Q = I, single-row sketches."""
    n = problem.n
    lam = jnp.float32(problem.lam)

    def step(state: SAPState) -> SAPState:
        key, kb = jax.random.split(state.key)
        j = jax.random.randint(kb, (), 0, n)
        row = _klam_row(problem, j, lam)
        resid = row @ state.w - problem.y[j]  # scalar or (t,)
        coef = resid / jnp.sum(row * row)
        upd = jnp.outer(row, coef) if state.w.ndim == 2 else coef * row
        w = state.w - upd
        return SAPState(w=w, v=w, z=w, key=key)

    return step


def make_cd_step(problem: KRRProblem):
    """Example 2: Q = K_lam, single-coordinate sketches."""
    n = problem.n
    lam = jnp.float32(problem.lam)

    def step(state: SAPState) -> SAPState:
        key, kb = jax.random.split(state.key)
        j = jax.random.randint(kb, (), 0, n)
        row = _klam_row(problem, j, lam)
        resid = row @ state.w - problem.y[j]
        w = state.w.at[j].add(-resid / row[j])
        return SAPState(w=w, v=w, z=w, key=key)

    return step


def _klam_row(problem: KRRProblem, j: jax.Array, lam: jax.Array) -> jax.Array:
    xj = jax.lax.dynamic_slice_in_dim(problem.x, j, 1, axis=0)
    row = problem.op.block(xj, problem.x)[0]
    return row.at[j].add(lam)


def run(problem: KRRProblem, step, num_iters: int, seed: int = 0) -> jax.Array:
    w0 = jnp.zeros(problem.y.shape, jnp.float32)
    state = SAPState(w=w0, v=w0, z=w0, key=jax.random.PRNGKey(seed))
    step = jax.jit(step)
    for _ in range(num_iters):
        state = step(state)
    return state.w
