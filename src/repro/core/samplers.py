"""Coordinate-block sampling distributions (paper §2.4, §3.1, Def. 9).

Two schemes, matching the paper's implementation:
  * uniform  — the recommended default (§3.2).
  * ARLS     — approximate ridge-leverage-score sampling; scores come from a
               BLESS-style multi-round estimator (Rudi et al. 2018) capped at
               dictionary size k = O(sqrt(n)) so estimation stays o(n^2).

Samplers are closures ``key -> idx (b,)`` so solver steps stay jit-able.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.operator import KernelOperator

Sampler = Callable[[jax.Array], jax.Array]


def uniform_sampler(n: int, b: int) -> Sampler:
    """b distinct indices uniformly at random."""

    def sample(key: jax.Array) -> jax.Array:
        return jax.random.choice(key, n, (b,), replace=False)

    return sample


def arls_sampler(probs: jax.Array, b: int) -> Sampler:
    """ARLS_c sampling (Def. 9): i.i.d. draws by rounded leverage scores.

    We draw without replacement (the paper discards duplicates; fixed-shape
    no-replacement sampling is the jit-friendly equivalent).
    """
    n = probs.shape[0]

    def sample(key: jax.Array) -> jax.Array:
        return jax.random.choice(key, n, (b,), replace=False, p=probs)

    return sample


def arls_probs(scores: jax.Array) -> jax.Array:
    """Def. 9 rounding: p_i ∝ (l/n) * ceil(n * l_i / l), l = sum l_i."""
    total = jnp.sum(scores)
    p = jnp.ceil(scores * scores.shape[0] / jnp.maximum(total, 1e-30))
    return p / jnp.sum(p)


def exact_rls(k_mat: jax.Array, lam: jax.Array) -> jax.Array:
    """Exact lambda-ridge leverage scores diag(K (K + lam I)^{-1}) — tests."""
    n = k_mat.shape[0]
    sol = jnp.linalg.solve(k_mat + lam * jnp.eye(n, dtype=k_mat.dtype), k_mat)
    return jnp.diag(sol)


def approx_rls_bless(
    key: jax.Array,
    op: KernelOperator,
    *,
    lam: jax.Array,
    k_cap: int | None = None,
    rounds: int = 4,
) -> jax.Array:
    """BLESS-style approximate ridge leverage scores for all n points.

    ``op`` owns the kernel/sigma/backend configuration; dictionaries are
    derived sub-operators (``op.restrict``), so no kernel plumbing leaks in.

    Multi-round coarse-to-fine estimation: round h uses regularization
    lam_h = lam_0 / 4^h (geometric descent to the target lam) and a
    dictionary resampled proportionally to the previous round's scores,
    capped at k_cap = O(sqrt(n)) columns (paper §2.4 / §3.2 cap the same
    way so BLESS stays ~O(n^2) overall).

    Estimator with dictionary S (|S| = s, sampling probs q):
        l_i(lam_h) ≈ (K_ii - k_iS (K_SS + s * lam_h * diag(q_S))^{-1} k_Si) / lam_h
    clipped to [0, 1].  Shift-invariant kernels here have K_ii = 1.
    """
    n = op.n
    if k_cap is None:
        k_cap = max(16, int(math.sqrt(n)))
    k_cap = min(k_cap, n)

    lam = jnp.asarray(lam, jnp.float32)
    lam0 = jnp.asarray(float(n), jnp.float32)
    # geometric path lam0 -> lam over `rounds` rounds
    ratio = (lam / lam0) ** (1.0 / max(rounds - 1, 1))

    scores = jnp.full((n,), 1.0, jnp.float32)  # trivial overestimate l_i <= 1
    keys = jax.random.split(key, rounds)
    for h in range(rounds):
        lam_h = lam0 * ratio**h if rounds > 1 else lam
        q = scores / jnp.sum(scores)
        idx = jax.random.choice(keys[h], n, (k_cap,), replace=False, p=q)
        xs = op.x[idx]
        q_s = q[idx] * k_cap  # inclusion-rate normalization
        k_ss = op.block(xs)
        reg = lam_h * jnp.diag(jnp.maximum(q_s, 1e-12))
        chol = jnp.linalg.cholesky(
            k_ss + reg + 1e-6 * jnp.eye(k_cap, dtype=k_ss.dtype)
        )
        # k_nS in chunks via the fused block op
        k_ns = op.block(op.x, xs)
        sol = jax.scipy.linalg.cho_solve((chol, True), k_ns.T)  # (s, n)
        quad = jnp.sum(k_ns.T * sol, axis=0)
        scores = jnp.clip((1.0 - quad) / lam_h, 1e-12, 1.0)
    return scores
