"""EigenPro 2.0-style preconditioned stochastic gradient for full KRR
(Ma & Belkin 2019) — full-KRR baseline, run with lam = 0 as the original
authors recommend (paper §6, "Optimizer hyperparameters").

Coefficient-space formulation: maintain w in R^n with f = sum_i w_i k(., x_i).
Preconditioner from the top-q eigensystem of the subsampled kernel (1/s) K_SS:
a stochastic-gradient step on batch B plus the EigenPro correction on the
subsample S that suppresses the top-q spectral components,

  w_B <- w_B - eta g,
  w_S <- w_S + eta V diag((1 - lam_{q+1}/lam_j) / (s lam_j)) V^T K_SB g,

with stepsize eta = lr_scale / lam_{q+1} (the preconditioned smoothness is
~lam_{q+1}).  The paper finds EigenPro's fixed defaults can diverge on hard
datasets; we keep the defaults fixed for the same reason (Table 1 claims are
about default behaviour, not tuned behaviour).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem
from repro.kernels import ops


@dataclasses.dataclass
class EigenProResult:
    w: jax.Array
    iters: int
    history: list[dict]
    wall_time_s: float


def solve_eigenpro(
    problem: KRRProblem,
    *,
    rank: int = 100,
    subsample: int | None = None,
    batch_size: int | None = None,
    lr_scale: float = 1.5,
    epochs: int = 10,
    seed: int = 0,
    eval_every: int = 100,
    time_budget_s: float | None = None,
) -> EigenProResult:
    t0 = time.perf_counter()
    n = problem.n
    s = min(subsample or max(1000, 2 * rank), n)
    bs = min(batch_size or max(n // 100, 32), n)
    key = jax.random.PRNGKey(seed)
    ks, kperm = jax.random.split(key)

    # --- top-q eigensystem of the subsampled kernel ------------------------
    sub_idx = jax.random.choice(ks, n, (s,), replace=False)
    xs = jnp.take(problem.x, sub_idx, axis=0)
    kss = ops.kernel_block(
        xs, xs, kernel=problem.kernel, sigma=problem.sigma, backend=problem.backend
    )
    evals, evecs = jnp.linalg.eigh(kss / s)
    evals, evecs = evals[::-1], evecs[:, ::-1]
    q = min(rank, s - 1)
    lam_q, lam_tail = evals[:q], jnp.maximum(evals[q], 1e-12)
    d_corr = (1.0 - lam_tail / lam_q) / (s * lam_q)  # (q,)
    vq = evecs[:, :q]
    eta = lr_scale / float(lam_tail) / n  # per-sample scaling

    x, y = problem.x, problem.y

    @jax.jit
    def epoch_step(w, batch_idx):
        xb = jnp.take(x, batch_idx, axis=0)
        g = (
            ops.kernel_matvec(
                xb, x, w, kernel=problem.kernel, sigma=problem.sigma,
                backend=problem.backend,
            )
            - jnp.take(y, batch_idx, axis=0)
        )  # lam = 0 per EigenPro
        w = w.at[batch_idx].add(-eta * g)
        ksb_g = ops.kernel_matvec(
            xs, xb, g, kernel=problem.kernel, sigma=problem.sigma,
            backend=problem.backend,
        )
        corr = vq @ (d_corr * (vq.T @ ksb_g))
        w = w.at[sub_idx].add(eta * corr)
        return w

    w = jnp.zeros((n,), jnp.float32)
    history: list[dict] = []
    steps_per_epoch = n // bs
    it = 0
    for ep in range(epochs):
        kperm, kp = jax.random.split(kperm)
        perm = jax.random.permutation(kp, n)
        for sidx in range(steps_per_epoch):
            batch_idx = jax.lax.dynamic_slice_in_dim(perm, sidx * bs, bs)
            w = epoch_step(w, batch_idx)
            it += 1
            if it % eval_every == 0:
                rel = float(problem.relative_residual(w))
                history.append(
                    {"iter": it, "rel_residual": rel, "time_s": time.perf_counter() - t0}
                )
            if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
                return EigenProResult(w, it, history, time.perf_counter() - t0)
    return EigenProResult(w, it, history, time.perf_counter() - t0)
