"""EigenPro 2.0-style preconditioned stochastic gradient for full KRR
(Ma & Belkin 2019) — full-KRR baseline, run with lam = 0 as the original
authors recommend (paper §6, "Optimizer hyperparameters").

Coefficient-space formulation: maintain W in R^{n x t} with
f_j = sum_i W_ij k(., x_i).  Preconditioner from the top-q eigensystem of the
subsampled kernel (1/s) K_SS: a stochastic-gradient step on batch B plus the
EigenPro correction on the subsample S that suppresses the top-q spectral
components,

  W_B <- W_B - eta G,
  W_S <- W_S + eta V diag((1 - lam_{q+1}/lam_j) / (s lam_j)) V^T K_SB G,

with stepsize eta = lr_scale / lam_{q+1} (the preconditioned smoothness is
~lam_{q+1}).  The eigensystem and every streamed kernel pass are shared
across the t heads; a 1-D y is the t = 1 special case.  The paper finds
EigenPro's fixed defaults can diverge on hard datasets; we keep the defaults
fixed for the same reason (Table 1 claims are about default behaviour, not
tuned behaviour).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem
from repro.core.operator import as_multirhs, maybe_squeeze
from repro.obs.metrics import record_tile_work
from repro.obs.telemetry import as_telemetry


@dataclasses.dataclass
class EigenProResult:
    w: jax.Array
    iters: int
    history: list[dict]
    wall_time_s: float


def solve_eigenpro(
    problem: KRRProblem,
    *,
    rank: int = 100,
    subsample: int | None = None,
    batch_size: int | None = None,
    lr_scale: float = 1.5,
    epochs: int = 10,
    seed: int = 0,
    eval_every: int = 100,
    time_budget_s: float | None = None,
    telemetry=None,
) -> EigenProResult:
    """EigenPro 2.0 SGD solve (module docstring has the update rule);
    ``telemetry`` adds a span, trace events, and per-batch tile metrics."""
    tel = as_telemetry(telemetry)
    t0 = time.perf_counter()
    n = problem.n
    op = problem.op
    s = min(subsample or max(1000, 2 * rank), n)
    bs = min(batch_size or max(n // 100, 32), n)
    key = jax.random.PRNGKey(seed)
    ks, kperm = jax.random.split(key)

    # --- top-q eigensystem of the subsampled kernel ------------------------
    sub_idx = jax.random.choice(ks, n, (s,), replace=False)
    op_s = op.restrict(sub_idx)
    kss = op_s.block(op_s.x)
    evals, evecs = jnp.linalg.eigh(kss / s)
    evals, evecs = evals[::-1], evecs[:, ::-1]
    q = min(rank, s - 1)
    lam_q, lam_tail = evals[:q], jnp.maximum(evals[q], 1e-12)
    d_corr = (1.0 - lam_tail / lam_q) / (s * lam_q)  # (q,)
    vq = evecs[:, :q]
    eta = lr_scale / float(lam_tail) / n  # per-sample scaling

    x = problem.x
    y, squeeze = as_multirhs(problem.y)

    @jax.jit
    def epoch_step(w, batch_idx):
        xb = jnp.take(x, batch_idx, axis=0)
        # one fused kernel pass per batch serves all t heads
        g = op.row_block_matvec(xb, w) - jnp.take(y, batch_idx, axis=0)  # lam = 0
        w = w.at[batch_idx].add(-eta * g)
        ksb_g = op.with_points(xb).row_block_matvec(op_s.x, g)  # K_SB @ g
        corr = vq @ (d_corr[:, None] * (vq.T @ ksb_g))
        w = w.at[sub_idx].add(eta * corr)
        return w

    w = jnp.zeros_like(y)
    recorder = tel.recorder("eigenpro", n=n)
    history = recorder.history
    tel_enabled = tel.enabled
    d = x.shape[1]
    steps_per_epoch = n // bs
    it = 0
    with tel.span("solve/eigenpro", n=n, t=problem.t, rank=rank, bs=bs,
                  epochs=epochs):
        for ep in range(epochs):
            kperm, kp = jax.random.split(kperm)
            perm = jax.random.permutation(kp, n)
            for sidx in range(steps_per_epoch):
                batch_idx = jax.lax.dynamic_slice_in_dim(perm, sidx * bs, bs)
                w = epoch_step(w, batch_idx)
                it += 1
                if tel_enabled:
                    # fused (bs, n) gradient pass + (s, bs) correction pass
                    record_tile_work(bs, n, d)
                    record_tile_work(s, bs, d)
                if it % eval_every == 0:
                    rel_agg, rel_heads = problem.residual_report(w)
                    recorder.add(
                        it, float(rel_agg),
                        rel_residual_per_head=[float(v) for v in rel_heads],
                        time_s=time.perf_counter() - t0,
                    )
                if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
                    return EigenProResult(
                        maybe_squeeze(w, squeeze), it, history, time.perf_counter() - t0
                    )
    return EigenProResult(maybe_squeeze(w, squeeze), it, history, time.perf_counter() - t0)
