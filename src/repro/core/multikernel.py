"""WeightedSumKernelOperator — the KernelOperator contract over a convex
combination of base kernels.

No single kernel family wins across the paper's 23-task testbed; himalaya's
multiple-kernel ridge (``solve_multiple_kernel_ridge_random_search``) shows
convex combinations ``K_w = sum_i w_i K_i`` with ``w`` on the simplex
routinely beating the best single kernel.  This module is the operator layer
of that capability: a drop-in :class:`~repro.core.operator.KernelOperator`
whose every primitive dispatches through the fused multi-kernel ops
(``repro.kernels.ops.kernel_*_multi``) — ONE data sweep computes the pairwise
distance tile once and applies all q kernel maps, so a q-kernel operator
costs ~1 kernel sweep instead of q.

Because the full contract (``matvec`` / ``row_block_matvec`` / ``block`` /
``block_idx`` / ``trace_est`` / ``restrict`` / ``with_points``) is satisfied,
every solver in the stack — ASkotch, the CG family, Falkon, EigenPro,
direct — and the serving layer run multi-kernel unchanged; a
``KRRProblem`` with a kernel *tuple* builds one automatically, and
``ShardedKernelOperator`` composes with it for mesh runs (its per-shard
``local_op`` goes through :func:`make_operator`).

Two extra primitives serve the multi-kernel tuner (``repro.core.tune.
tune_multikernel``):

  * ``matvec_cols(v, w_cols)`` — per-COLUMN weight vectors (q, t): column c
    applies ``sum_i w_cols[i, c] K_i``.  Every weight candidate of a random
    search becomes one more column of the same stacked solve.
  * ``sketch_components(omega)`` — stacked per-kernel sketches ``K_i Omega``
    (q, n, r) from one data sweep; a weight candidate's Nystrom
    preconditioner is the candidate's weighted combination of these sketches
    (``K_w Omega = sum_i w_i K_i Omega``), so preconditioning a whole weight
    search costs ONE sweep.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.kernels import KERNEL_NAMES, kernel_diag
from repro.core.operator import KernelOperator, PrecomputedKernelOperator, widen_gram
from repro.kernels import ops


def canonical_kernels(
    kernels, sigma, weights=None
) -> tuple[tuple[str, ...], tuple[float, ...], tuple[float, ...]]:
    """Validate and normalize a multi-kernel spec.

    Args:
      kernels: sequence of q base-kernel names (each in ``KERNEL_NAMES``).
      sigma: one shared bandwidth (float) or a per-kernel sequence of q.
      weights: optional q nonnegative weights (``None`` -> uniform ``1/q``);
        NOT renormalized — callers own the simplex constraint.

    Returns:
      ``(kernels, sigmas, weights)`` as plain tuples of length q.
    """
    kernels = tuple(str(k) for k in kernels)
    if not kernels:
        raise ValueError("multi-kernel spec needs at least one kernel")
    for k in kernels:
        if k not in KERNEL_NAMES:
            raise ValueError(
                f"unknown kernel {k!r} in multi-kernel spec; available: "
                f"{KERNEL_NAMES}"
            )
    q = len(kernels)
    if isinstance(sigma, (tuple, list)):
        sigmas = tuple(float(s) for s in sigma)
        if len(sigmas) != q:
            raise ValueError(
                f"sigma has {len(sigmas)} entries for {q} kernels; pass one "
                f"shared float or exactly one per kernel"
            )
    else:
        sigmas = (float(sigma),) * q
    if any(s <= 0 for s in sigmas):
        raise ValueError(f"sigmas must be positive; got {sigmas}")
    if weights is None:
        w = (1.0 / q,) * q
    else:
        w = tuple(float(x) for x in weights)
        if len(w) != q:
            raise ValueError(
                f"weights has {len(w)} entries for {q} kernels"
            )
        if any(x < 0 for x in w) or sum(w) <= 0:
            raise ValueError(
                f"weights must be nonnegative with a positive sum; got {w}"
            )
    return kernels, sigmas, w


@dataclasses.dataclass(frozen=True)
class WeightedSumKernelOperator:
    """Linear-operator view of ``K_w = sum_i w_i K_i(x, x)``.

    ``sigma`` may be one shared bandwidth or a per-kernel tuple; ``weights``
    defaults to uniform ``1/q``.  All primitives are multi-RHS exactly like
    :class:`~repro.core.operator.KernelOperator`.
    """

    x: jax.Array  # (n, d) row points
    kernels: tuple[str, ...] = ("rbf", "laplacian")
    sigma: float | tuple[float, ...] = 1.0
    weights: tuple[float, ...] | None = None
    backend: str = "auto"
    chunk_a: int = 4096
    chunk_b: int = 8192
    precision: str = "f32"  # tile-compute policy: "f32" | "bf16"

    def __post_init__(self) -> None:
        ks, sg, w = canonical_kernels(self.kernels, self.sigma, self.weights)
        object.__setattr__(self, "kernels", ks)
        object.__setattr__(
            self, "sigma",
            sg[0] if all(s == sg[0] for s in sg) else sg,
        )
        object.__setattr__(self, "weights", w)

    # -- structure -----------------------------------------------------------

    @property
    def q(self) -> int:
        """Number of base kernels in the combination."""
        return len(self.kernels)

    @property
    def sigmas(self) -> tuple[float, ...]:
        """Per-kernel bandwidths (a shared float expands to length q)."""
        if isinstance(self.sigma, tuple):
            return self.sigma
        return (float(self.sigma),) * self.q

    @property
    def n(self) -> int:
        """Number of rows (training points) the operator spans."""
        return self.x.shape[0]

    @property
    def d(self) -> int:
        """Feature dimension of the row points."""
        return self.x.shape[1]

    @property
    def shape(self) -> tuple[int, int]:
        """(n, n) — the shape of K_w(x, x) this operator applies."""
        return (self.n, self.n)

    def components(self) -> tuple[KernelOperator, ...]:
        """The q single-kernel operators of the combination (tests, naive
        reference paths; the fused ops never build these internally)."""
        return tuple(
            KernelOperator(
                x=self.x, kernel=k, sigma=s, backend=self.backend,
                chunk_a=self.chunk_a, chunk_b=self.chunk_b,
                precision=self.precision,
            )
            for k, s in zip(self.kernels, self.sigmas)
        )

    # -- derived operators ---------------------------------------------------

    def with_points(self, x_new: jax.Array) -> "WeightedSumKernelOperator":
        """Same kernel combination over a different row set."""
        return dataclasses.replace(self, x=x_new)

    def restrict(self, idx: jax.Array) -> "WeightedSumKernelOperator":
        """Operator over the sub-row-set ``x[idx]`` (centers, folds)."""
        return self.with_points(jnp.take(self.x, idx, axis=0))

    def with_weights(self, weights) -> "WeightedSumKernelOperator":
        """Same kernels/bandwidths under a different weight vector."""
        return dataclasses.replace(self, weights=tuple(float(w) for w in weights))

    # -- the four primitives -------------------------------------------------

    def matvec(self, v: jax.Array) -> jax.Array:
        """K_w(x, x) @ v; v: (n,) or (n, t) -> same leading-dim shape."""
        return self.row_block_matvec(self.x, v)

    def row_block_matvec(self, a: jax.Array, v: jax.Array) -> jax.Array:
        """K_w(a, x) @ v streamed over x — one data sweep for all q kernels."""
        return ops.kernel_matvec_multi(
            a, self.x, v, kernels=self.kernels, sigmas=self.sigmas,
            weights=jnp.asarray(self.weights, jnp.float32),
            backend=self.backend, chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            precision=self.precision,
        )

    def block(self, a: jax.Array, b: jax.Array | None = None) -> jax.Array:
        """Materialize K_w(a, b) (b defaults to a).  Small tiles only."""
        b = a if b is None else b
        return ops.kernel_block_multi(
            a, b, kernels=self.kernels, sigmas=self.sigmas,
            weights=self.weights, backend=self.backend,
            precision=self.precision,
        )

    def block_idx(self, idx: jax.Array) -> jax.Array:
        """(K_w)_BB for a row-index block (Skotch/ASkotch step)."""
        xb = jnp.take(self.x, idx, axis=0)
        return self.block(xb, xb)

    def trace_est(self) -> jax.Array:
        """tr K_w = sum_i w_i tr K_i, each exact via ``kernel_diag`` (= w_i n
        for the unit-diagonal kernels)."""
        return jnp.sum(
            jnp.stack([
                w * jnp.sum(kernel_diag(k, self.x, s))
                for k, s, w in zip(self.kernels, self.sigmas, self.weights)
            ])
        )

    # -- composites shared by several solvers --------------------------------

    def k_lam_matvec(self, v: jax.Array, lam: jax.Array | float) -> jax.Array:
        """(K_w + lam I) @ v."""
        return self.matvec(v) + lam * v

    def sketch(self, omega: jax.Array) -> jax.Array:
        """K_w @ omega for a (n, r) test matrix (Nystrom sketches)."""
        return self.matvec(omega)

    # -- tuning-engine primitives --------------------------------------------

    def matvec_cols(self, v: jax.Array, w_cols: jax.Array) -> jax.Array:
        """Per-column-weighted matvec: out[:, c] = (sum_i w_cols[i, c] K_i) @ v[:, c].

        ``v``: (n, t), ``w_cols``: (q, t).  This is how every weight
        candidate of a random search rides ONE stacked solve: each column
        carries its own weight vector, the data sweep is shared.
        """
        return self.row_block_matvec_cols(self.x, v, w_cols)

    def row_block_matvec_cols(
        self, a: jax.Array, v: jax.Array, w_cols: jax.Array
    ) -> jax.Array:
        """Per-column-weighted K(a, x) @ v for an arbitrary row block ``a``
        (the sharded operator's per-shard partial of :meth:`matvec_cols`)."""
        return ops.kernel_matvec_multi(
            a, self.x, v, kernels=self.kernels, sigmas=self.sigmas,
            weights=w_cols, backend=self.backend,
            chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            precision=self.precision,
        )

    def sketch_components(self, omega: jax.Array) -> jax.Array:
        """Stacked per-kernel sketches (q, n, r): out[i] = K_i @ omega.

        One data sweep; a weight candidate's Nystrom sketch is then the
        weighted combination ``sum_i w_i out[i]`` — zero extra kernel work.
        """
        return self.row_block_components(self.x, omega)

    def row_block_components(self, a: jax.Array, v: jax.Array) -> jax.Array:
        """Stacked per-kernel K_i(a, x) @ v (q, b[, t]) for a row block."""
        return ops.kernel_matvec_components(
            a, self.x, v, kernels=self.kernels, sigmas=self.sigmas,
            backend=self.backend, chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            precision=self.precision,
        )


def make_operator(
    x: jax.Array,
    *,
    kernel: str | tuple[str, ...] = "rbf",
    sigma: float | tuple[float, ...] = 1.0,
    weights=None,
    backend: str = "auto",
    chunk_a: int = 4096,
    chunk_b: int = 8192,
    precision: str = "f32",
):
    """Build the right operator for a kernel spec — the ONE dispatch point.

    A string ``kernel`` yields a plain :class:`KernelOperator`; a tuple/list
    yields a :class:`WeightedSumKernelOperator`; ``kernel="precomputed"``
    treats ``x`` as a user-supplied Gram matrix (raw square or already
    widened) and yields a :class:`PrecomputedKernelOperator` (``sigma`` is
    ignored — the Gram already encodes it).  ``KRRProblem.op`` and
    ``ShardedKernelOperator.local_op`` both route through here, which is what
    makes multi-kernel solves work across the whole solver stack and on a
    mesh without any solver changes.
    """
    if kernel == "precomputed":
        if weights is not None:
            raise ValueError(
                "weights= does not apply to kernel='precomputed'; pre-combine "
                "the Gram matrices instead"
            )
        return PrecomputedKernelOperator(
            x=widen_gram(x), backend=backend, chunk_a=chunk_a,
            chunk_b=chunk_b, precision=precision,
        )
    if isinstance(kernel, (tuple, list)):
        return WeightedSumKernelOperator(
            x=x, kernels=tuple(kernel), sigma=sigma, weights=weights,
            backend=backend, chunk_a=chunk_a, chunk_b=chunk_b,
            precision=precision,
        )
    if weights is not None:
        raise ValueError(
            "weights= only applies to a multi-kernel spec (a tuple of kernel "
            f"names); got kernel={kernel!r}"
        )
    if isinstance(sigma, (tuple, list)):
        raise ValueError(
            "per-kernel sigma tuples only apply to a multi-kernel spec; got "
            f"kernel={kernel!r} with sigma={sigma!r}"
        )
    return KernelOperator(
        x=x, kernel=kernel, sigma=float(sigma), backend=backend,
        chunk_a=chunk_a, chunk_b=chunk_b, precision=precision,
    )
