"""Random Fourier features (Rahimi & Recht 2007) for the Gaussian kernel and
the RFF-based PCG preconditioner factors built from them.

Bochner's theorem writes a shift-invariant kernel as the expectation of
cosine features; for the rbf kernel ``k(x, y) = exp(-||x-y||^2 / (2 sigma^2))``
the spectral measure is Gaussian, so with

  ``z(x) = sqrt(2 / r) * cos(x @ W.T + b)``,  ``W ~ N(0, 1/sigma^2)^{r x d}``,
  ``b ~ U[0, 2 pi)^r``,

the feature Gram ``Z Z^T`` (Z of shape (n, r)) is an unbiased rank-r
approximation of K.  A thin SVD ``Z = U S V^T`` then gives the same
``(U, lam = S^2)`` eigen-factor pair as the Nystrom sketch
(:class:`~repro.core.nystrom.NystromFactors`), so the existing damped-rho
Woodbury apply in :func:`repro.core.pcg.make_preconditioner` serves RFF
unchanged — only the factor construction differs: one streamed pass over the
data (a chunked (n, d) x (d, r) matmul + elementwise cosine) instead of a
kernel sketch, i.e. O(n d r) with no kernel tiles at all.

RFF is the natural preconditioner companion of the bf16 tile policy: when the
kernel matvecs are already approximate, an approximate-spectrum
preconditioner built without kernel sweeps is essentially free.  Per the
f32-islands rule (docs/architecture.md, "Precision policy") the features and
factors are always computed in f32 regardless of the solve's tile precision.

rbf-only: the laplacian/matern52 spectral measures are Cauchy/Student-t and
are not implemented — ``kind="rff"`` raises for non-rbf problems.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.nystrom import NystromFactors


def rff_features(
    key: jax.Array,
    x: jax.Array,
    rank: int,
    sigma: float,
    chunk: int = 8192,
) -> jax.Array:
    """The (n, r) rbf random-Fourier feature matrix Z with E[Z Z^T] = K.

    Args:
      key: PRNG key for the frequency matrix W and phases b.
      x: (n, d) data points.
      rank: number of features r.
      sigma: rbf bandwidth (``k(x, y) = exp(-||x-y||^2 / (2 sigma^2))``).
      chunk: row-chunk size for the streamed (n, d) x (d, r) pass.

    Returns:
      Z of shape (n, r), float32: ``sqrt(2/r) cos(x @ W.T + b)``.
    """
    n, d = x.shape
    kw, kb = jax.random.split(key)
    w = jax.random.normal(kw, (rank, d), jnp.float32) / jnp.float32(sigma)
    b = jax.random.uniform(
        kb, (rank,), jnp.float32, minval=0.0, maxval=2.0 * jnp.pi
    )
    scale = jnp.sqrt(jnp.float32(2.0 / rank))
    x = x.astype(jnp.float32)

    chunk = min(chunk, max(n, 1))
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    xc = xp.reshape(-1, chunk, d)

    def row_block(xb):
        return scale * jnp.cos(
            lax.dot_general(
                xb, w, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + b[None, :]
        )

    z = lax.map(row_block, xc).reshape(-1, rank)[:n]
    return z


def rff_factors(
    key: jax.Array,
    x: jax.Array,
    rank: int,
    sigma: float,
    chunk: int = 8192,
    oversample: int = 4,
) -> NystromFactors:
    """Rank-r eigen-factors (U, lam) of the RFF Gram ``Z Z^T ~= K``.

    Builds ``oversample * rank`` features, takes a thin SVD (one
    O(n (c r)^2) factorization, no kernel sweeps) and keeps the top ``rank``
    eigenpairs: ``Z Z^T ~= U diag(S^2) U^T`` — the same factor layout as a
    Nystrom sketch, so the damped-rho Woodbury preconditioner apply is shared
    verbatim.

    Oversampling matters: a Monte-Carlo feature Gram estimates its TOP
    eigenpairs far better than its tail, and the Woodbury damping uses the
    smallest retained eigenvalue as its shift — keeping the noisy tail of an
    exactly-rank-r feature set over-trusts eigenpairs that barely exist in K
    and roughly doubles PCG iterations.  c=4 costs one streamed O(n d c r)
    feature pass and brings the iteration count within ~1.25x of a Nystrom
    preconditioner of the same rank on moderate-bandwidth rbf problems.
    """
    c = max(int(oversample), 1)
    z = rff_features(key, x, c * rank, sigma, chunk)
    u, s, _ = jnp.linalg.svd(z, full_matrices=False)
    return NystromFactors(u=u[:, :rank], lam=(s * s)[:rank])
