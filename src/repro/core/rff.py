"""Random Fourier features (Rahimi & Recht 2007) for the shift-invariant
kernels and the RFF-based PCG preconditioner factors built from them.

Bochner's theorem writes a shift-invariant kernel as the expectation of
cosine features: with ``z(x) = sqrt(2 / r) * cos(x @ W.T + b)``,
``b ~ U[0, 2 pi)^r``, and W's rows drawn from the kernel's spectral
measure, the feature Gram ``Z Z^T`` (Z of shape (n, r)) is an unbiased
rank-r approximation of K.  The three measures implemented
(:data:`RFF_KERNELS`):

  rbf        k = exp(-||x-y||^2 / (2 sigma^2))   W_ij ~ N(0, 1/sigma^2)
  laplacian  k = exp(-||x-y||_1 / sigma)          W_ij ~ Cauchy(0, 1/sigma)
             (the kernel is a product of 1-D exponentials, whose Fourier
             transform is the per-coordinate Cauchy density)
  matern52   Matern nu=5/2, length scale sigma    W_i ~ t_5(0, I/sigma^2)
             (spectral density ~ (2 nu/sigma^2 + ||w||^2)^-(nu + d/2),
             i.e. multivariate Student-t with df = 2 nu = 5, sampled as
             ``(z / sigma) / sqrt(u / 5)`` with z ~ N(0, I), u ~ chi^2_5)

A thin SVD ``Z = U S V^T`` then gives the same ``(U, lam = S^2)``
eigen-factor pair as the Nystrom sketch
(:class:`~repro.core.nystrom.NystromFactors`), so the existing damped-rho
Woodbury apply in :func:`repro.core.pcg.make_preconditioner` serves RFF
unchanged — only the factor construction differs: one streamed pass over the
data (a chunked (n, d) x (d, r) matmul + elementwise cosine) instead of a
kernel sketch, i.e. O(n d r) with no kernel tiles at all.

RFF is the natural preconditioner companion of the bf16 tile policy: when the
kernel matvecs are already approximate, an approximate-spectrum
preconditioner built without kernel sweeps is essentially free.  Per the
f32-islands rule (docs/architecture.md, "Precision policy") the features and
factors are always computed in f32 regardless of the solve's tile precision.

The heavy-tailed measures (Cauchy especially) estimate K more noisily per
feature than the Gaussian; the oversampled-SVD truncation in
:func:`rff_factors` absorbs this — tests pin each measure's PCG iteration
count within 1.5x of a same-rank Nystrom preconditioner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.nystrom import NystromFactors

#: shift-invariant kernels with an implemented spectral measure — the
#: vocabulary of ``kind="rff"`` / ``method="pcg-rff"``
RFF_KERNELS = ("rbf", "laplacian", "matern52")


def sample_freqs(
    key: jax.Array, kernel: str, rank: int, d: int, sigma: float
) -> jax.Array:
    """Draw the (rank, d) frequency matrix W from ``kernel``'s spectral
    measure (see module docstring for the three measures)."""
    sig = jnp.float32(sigma)
    if kernel == "rbf":
        return jax.random.normal(key, (rank, d), jnp.float32) / sig
    if kernel == "laplacian":
        return jax.random.cauchy(key, (rank, d), jnp.float32) / sig
    if kernel == "matern52":
        kz, ku = jax.random.split(key)
        z = jax.random.normal(kz, (rank, d), jnp.float32)
        u = jax.random.chisquare(ku, 5.0, (rank, 1), jnp.float32)
        return (z / sig) / jnp.sqrt(u / 5.0)
    raise ValueError(
        f"kernel {kernel!r} has no RFF spectral measure; "
        f"implemented: {RFF_KERNELS}"
    )


def rff_features(
    key: jax.Array,
    x: jax.Array,
    rank: int,
    sigma: float,
    chunk: int = 8192,
    kernel: str = "rbf",
) -> jax.Array:
    """The (n, r) random-Fourier feature matrix Z with E[Z Z^T] = K.

    Args:
      key: PRNG key for the frequency matrix W and phases b.
      x: (n, d) data points.
      rank: number of features r.
      sigma: kernel bandwidth / length scale.
      chunk: row-chunk size for the streamed (n, d) x (d, r) pass.
      kernel: one of :data:`RFF_KERNELS` — selects the spectral measure W
        is drawn from (Gaussian / Cauchy / Student-t).

    Returns:
      Z of shape (n, r), float32: ``sqrt(2/r) cos(x @ W.T + b)``.
    """
    n, d = x.shape
    kw, kb = jax.random.split(key)
    w = sample_freqs(kw, kernel, rank, d, sigma)
    b = jax.random.uniform(
        kb, (rank,), jnp.float32, minval=0.0, maxval=2.0 * jnp.pi
    )
    scale = jnp.sqrt(jnp.float32(2.0 / rank))
    x = x.astype(jnp.float32)

    chunk = min(chunk, max(n, 1))
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    xc = xp.reshape(-1, chunk, d)

    def row_block(xb):
        return scale * jnp.cos(
            lax.dot_general(
                xb, w, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            + b[None, :]
        )

    z = lax.map(row_block, xc).reshape(-1, rank)[:n]
    return z


#: default feature oversampling per spectral measure: the heavier the tail
#: of the frequency distribution, the noisier each feature's contribution
#: to the Gram estimate, and the more features the SVD truncation needs
#: before the retained eigenpairs stabilize (measured in
#: tests/test_precision.py's 1.5x parity gates)
DEFAULT_OVERSAMPLE = {"rbf": 4, "laplacian": 6, "matern52": 8}


def rff_factors(
    key: jax.Array,
    x: jax.Array,
    rank: int,
    sigma: float,
    chunk: int = 8192,
    oversample: int | None = None,
    kernel: str = "rbf",
) -> NystromFactors:
    """Rank-r eigen-factors (U, lam) of the RFF Gram ``Z Z^T ~= K``.

    Builds ``oversample * rank`` features, takes a thin SVD (one
    O(n (c r)^2) factorization, no kernel sweeps) and keeps the top ``rank``
    eigenpairs: ``Z Z^T ~= U diag(S^2) U^T`` — the same factor layout as a
    Nystrom sketch, so the damped-rho Woodbury preconditioner apply is shared
    verbatim.

    Oversampling matters: a Monte-Carlo feature Gram estimates its TOP
    eigenpairs far better than its tail, and the Woodbury damping uses the
    smallest retained eigenvalue as its shift — keeping the noisy tail of an
    exactly-rank-r feature set over-trusts eigenpairs that barely exist in K
    and roughly doubles PCG iterations.  c=4 costs one streamed O(n d c r)
    feature pass and brings the iteration count within ~1.25x of a Nystrom
    preconditioner of the same rank on moderate-bandwidth rbf problems; the
    heavy-tailed Cauchy/Student-t measures default higher
    (:data:`DEFAULT_OVERSAMPLE`) because each of their features carries more
    variance into the Gram estimate.
    """
    if oversample is None:
        oversample = DEFAULT_OVERSAMPLE.get(kernel, 4)
    c = max(int(oversample), 1)
    z = rff_features(key, x, c * rank, sigma, chunk, kernel)
    u, s, _ = jnp.linalg.svd(z, full_matrices=False)
    return NystromFactors(u=u[:, :rank], lam=(s * s)[:rank])
