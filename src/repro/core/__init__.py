"""The paper's primary contribution: Skotch/ASkotch approximate sketch-and-
project solvers for full KRR, plus every baseline the paper compares against.
"""

from repro.core.askotch import ASkotchConfig, SolveResult, solve, solve_scan
from repro.core.krr import KRRProblem, evaluate
from repro.core.skotch import solve_skotch
from repro.core.solver_api import METHODS, SolveOutput
from repro.core.solver_api import solve as solve_any

__all__ = [
    "ASkotchConfig",
    "KRRProblem",
    "METHODS",
    "SolveOutput",
    "SolveResult",
    "evaluate",
    "solve",
    "solve_any",
    "solve_scan",
    "solve_skotch",
]
