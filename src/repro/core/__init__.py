"""The paper's primary contribution: Skotch/ASkotch approximate sketch-and-
project solvers for full KRR, plus every baseline the paper compares against.
"""

from repro.core.askotch import ASkotchConfig, SolveResult, solve, solve_scan
from repro.core.krr import KRRProblem, evaluate, evaluate_per_head
from repro.core.operator import KernelOperator
from repro.core.skotch import solve_skotch
from repro.core.solver_api import METHOD_OPTIONS, METHODS, SolveOutput
from repro.core.solver_api import solve as solve_any

__all__ = [
    "ASkotchConfig",
    "KRRProblem",
    "KernelOperator",
    "METHODS",
    "METHOD_OPTIONS",
    "SolveOutput",
    "SolveResult",
    "evaluate",
    "evaluate_per_head",
    "solve",
    "solve_any",
    "solve_scan",
    "solve_skotch",
]
