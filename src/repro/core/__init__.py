"""The paper's primary contribution: Skotch/ASkotch approximate sketch-and-
project solvers for full KRR, plus every baseline the paper compares against
and the policy-driven (sigma, lam) tuning subsystem (``repro.core.tune``)
that picks their hyperparameters.
"""

from repro.core.askotch import ASkotchConfig, SolveResult, solve, solve_scan
from repro.core.krr import KRRProblem, evaluate, evaluate_per_head
from repro.core.multikernel import WeightedSumKernelOperator, make_operator
from repro.core.operator import KernelOperator
from repro.core.skotch import solve_skotch
from repro.core.solver_api import (
    METHOD_OPTIONS,
    METHODS,
    MULTIKERNEL_TUNE_OPTIONS,
    TUNE_OPTIONS,
    SolveOutput,
)
from repro.core.solver_api import solve as solve_any
from repro.core.tune import TuneResult, apply_best, tune_multikernel

# Importing the repro.core.tune PACKAGE above binds the module object to the
# ``tune`` attribute of this package; rebind the solver-API entry point last
# so ``from repro.core import tune`` keeps meaning the function.  The package
# stays importable through FROM-imports (``from repro.core.tune import X``,
# resolved via sys.modules); attribute access after a plain ``import
# repro.core.tune`` yields this function instead — use from-imports.
from repro.core.solver_api import tune  # noqa: E402  (must stay below)

__all__ = [
    "ASkotchConfig",
    "KRRProblem",
    "KernelOperator",
    "METHODS",
    "METHOD_OPTIONS",
    "MULTIKERNEL_TUNE_OPTIONS",
    "SolveOutput",
    "SolveResult",
    "TUNE_OPTIONS",
    "TuneResult",
    "WeightedSumKernelOperator",
    "apply_best",
    "evaluate",
    "evaluate_per_head",
    "make_operator",
    "solve",
    "solve_any",
    "solve_scan",
    "solve_skotch",
    "tune",
    "tune_multikernel",
]
