"""The paper's primary contribution: Skotch/ASkotch approximate sketch-and-
project solvers for full KRR, plus every baseline the paper compares against
and the (sigma, lam) tuning subsystem that picks their hyperparameters.
"""

from repro.core.askotch import ASkotchConfig, SolveResult, solve, solve_scan
from repro.core.krr import KRRProblem, evaluate, evaluate_per_head
from repro.core.multikernel import WeightedSumKernelOperator, make_operator
from repro.core.operator import KernelOperator
from repro.core.skotch import solve_skotch
from repro.core.solver_api import (
    METHOD_OPTIONS,
    METHODS,
    MULTIKERNEL_TUNE_OPTIONS,
    TUNE_OPTIONS,
    SolveOutput,
    tune,
)
from repro.core.solver_api import solve as solve_any
from repro.core.tuning import TuneResult, apply_best, tune_multikernel

__all__ = [
    "ASkotchConfig",
    "KRRProblem",
    "KernelOperator",
    "METHODS",
    "METHOD_OPTIONS",
    "MULTIKERNEL_TUNE_OPTIONS",
    "SolveOutput",
    "SolveResult",
    "TUNE_OPTIONS",
    "TuneResult",
    "WeightedSumKernelOperator",
    "apply_best",
    "evaluate",
    "evaluate_per_head",
    "make_operator",
    "solve",
    "solve_any",
    "solve_scan",
    "solve_skotch",
    "tune",
    "tune_multikernel",
]
