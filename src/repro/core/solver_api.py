"""Unified solver entry point — one `solve()` for every method the paper
benchmarks (ASkotch / Skotch / PCG variants / Falkon / EigenPro / direct),
so the benchmark harness and examples treat them interchangeably.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core import askotch, direct, eigenpro, falkon, pcg
from repro.core.krr import KRRProblem

METHODS = (
    "askotch",
    "skotch",
    "pcg-nystrom",
    "pcg-rpcholesky",
    "cg",
    "falkon",
    "eigenpro",
    "direct",
)


@dataclasses.dataclass
class SolveOutput:
    method: str
    w: jax.Array
    history: list[dict]
    info: dict[str, Any]
    predict_fn: Any  # (x_test) -> predictions


def solve(problem: KRRProblem, method: str = "askotch", **kw) -> SolveOutput:
    if method in ("askotch", "skotch"):
        cfg_kw = {
            k: kw.pop(k)
            for k in (
                "block_size", "rank", "rho_mode", "sampling", "precond",
                "mu", "nu", "stable_inv", "backend", "powering_iters",
            )
            if k in kw
        }
        cfg = askotch.ASkotchConfig(accelerated=(method == "askotch"), **cfg_kw)
        res = askotch.solve(problem, cfg, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "converged": res.converged, "wall_time_s": res.wall_time_s},
            predict_fn=lambda xt: problem.predict(res.w, xt),
        )
    if method in ("pcg-nystrom", "pcg-rpcholesky", "cg"):
        precond = {"pcg-nystrom": "nystrom", "pcg-rpcholesky": "rpcholesky", "cg": "identity"}[method]
        res = pcg.solve_pcg(problem, precond=precond, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "converged": res.converged, "wall_time_s": res.wall_time_s},
            predict_fn=lambda xt: problem.predict(res.w, xt),
        )
    if method == "falkon":
        res = falkon.solve_falkon(problem, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "wall_time_s": res.wall_time_s, "m": res.w.shape[0]},
            predict_fn=lambda xt: falkon.falkon_predict(problem, res, xt),
        )
    if method == "eigenpro":
        res = eigenpro.solve_eigenpro(problem, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "wall_time_s": res.wall_time_s},
            predict_fn=lambda xt: problem.predict(res.w, xt),
        )
    if method == "direct":
        w = direct.solve_direct(problem)
        return SolveOutput(
            method=method,
            w=w,
            history=[],
            info={},
            predict_fn=lambda xt: problem.predict(w, xt),
        )
    raise ValueError(f"unknown method {method!r}; available: {METHODS}")
