"""Unified solver entry point — one `solve()` for every method the paper
benchmarks (ASkotch / Skotch / PCG variants / Falkon / EigenPro / direct),
so the benchmark harness and examples treat them interchangeably.

Every method is multi-RHS: a (n, t) problem.y (one-vs-all heads) yields a
(n, t) (or (m, t) for Falkon) weight matrix, per-head convergence in the
history records (``rel_residual_per_head``), and a predict_fn returning
(n_test, t) scores.  Unknown keyword options fail fast with the accepted
option list for the method instead of leaking into a bare TypeError.

A distributed solve is the SAME call: pass ``mesh=`` (a ``jax.sharding``
Mesh whose non-"model" axes shard rows — see ``distributed.meshes.
make_solver_mesh``) and the ASkotch/Skotch/PCG/CG methods run through the
``ShardedKernelOperator`` path (``distributed/krr_dist.py``) with W
row-sharded and a mesh-aware predict_fn; everything else about the contract
(multi-RHS, history records, option validation) is unchanged.  A 1-device
mesh is valid and runs the distributed code with no-op collectives.

``method="dc"`` is the communication-avoiding alternative: partition the
rows into ``dc_shards`` shards, run a full LOCAL solve per shard (any
inner method via ``dc_method=``), and combine predictions
(``dc_combiner=``) — near-zero collective traffic at a bounded accuracy
cost (``distributed/dc.py``; docs/distributed.md has the cost model).
With ``mesh=`` the shards run device-parallel; without one, sequentially.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax

from repro.core import askotch, direct, eigenpro, falkon, pcg
from repro.core.krr import KRRProblem
from repro.kernels.precision import check_precision
from repro.obs.telemetry import as_telemetry

METHODS = (
    "askotch",
    "skotch",
    "pcg-nystrom",
    "pcg-rpcholesky",
    "pcg-rff",
    "cg",
    "falkon",
    "eigenpro",
    "direct",
    "dc",
)

#: tolerances below this are unreachable with bf16 kernel tiles (unit
#: roundoff 2^-8 per operand; the f32 accumulation keeps residuals near
#: ~1e-6-1e-7 relative, not machine-f32/f64) — solve() warns, it does not
#: silently stall
BF16_TOL_FLOOR = 1e-6

_ASKOTCH_CFG_KEYS = (
    "block_size", "rank", "rho_mode", "sampling", "precond",
    "mu", "nu", "stable_inv", "backend", "powering_iters",
)
_ASKOTCH_SOLVE_KEYS = (
    "max_iters", "tol", "eval_every", "seed", "time_budget_s", "callback", "w0",
)
_PCG_KEYS = (
    "rank", "rho_mode", "max_iters", "tol", "seed", "time_budget_s", "w0",
)
_FALKON_KEYS = ("m", "max_iters", "tol", "seed", "jitter", "time_budget_s")
_EIGENPRO_KEYS = (
    "rank", "subsample", "batch_size", "lr_scale", "epochs", "seed",
    "eval_every", "time_budget_s",
)

#: options of the divide-and-conquer tier itself (``method="dc"``); the
#: INNER solver's options (``METHOD_OPTIONS[dc_method]``) ride along
#: un-prefixed and are validated fail-fast by the per-shard solve —
#: ``solve(p, "dc", dc_shards=4, dc_method="pcg-nystrom", rank=50)``
DC_METHOD_OPTIONS: tuple[str, ...] = (
    "dc_shards", "dc_partition", "dc_combiner", "dc_method",
    "dc_softmax_temp",
)

#: accepted keyword options per method (satellite of the solve() contract —
#: anything else raises ValueError instead of leaking into a TypeError)
METHOD_OPTIONS: dict[str, tuple[str, ...]] = {
    "askotch": _ASKOTCH_CFG_KEYS + _ASKOTCH_SOLVE_KEYS,
    "skotch": _ASKOTCH_CFG_KEYS + _ASKOTCH_SOLVE_KEYS,
    "pcg-nystrom": _PCG_KEYS,
    "pcg-rpcholesky": _PCG_KEYS,
    "pcg-rff": _PCG_KEYS,
    "cg": _PCG_KEYS,
    "falkon": _FALKON_KEYS,
    "eigenpro": _EIGENPRO_KEYS,
    "direct": (),
    "dc": DC_METHOD_OPTIONS,
}

_DIST_ASKOTCH_KEYS = (
    "block_size", "rank", "mu", "nu", "powering_iters", "backend",
    "max_iters", "tol", "eval_every", "seed", "time_budget_s",
)
_DIST_PCG_KEYS = (
    "rank", "rho_mode", "backend", "max_iters", "tol", "seed", "time_budget_s",
)

#: methods (and their accepted options) reachable through solve(..., mesh=...)
DIST_METHOD_OPTIONS: dict[str, tuple[str, ...]] = {
    "askotch": _DIST_ASKOTCH_KEYS,
    "skotch": _DIST_ASKOTCH_KEYS,
    "pcg-nystrom": _DIST_PCG_KEYS,
    "cg": _DIST_PCG_KEYS,
}

#: accepted keyword options of tune() — same fail-fast contract as
#: METHOD_OPTIONS (unknown options raise with the accepted list).
#: ``policy`` ("grid" | "random" | "halving" or a SearchPolicy instance),
#: ``halving_eta`` and ``sigma_continuation`` select the search policy over
#: the stacked engine (repro.core.tune); ``search``/``num_samples`` remain
#: the legacy grid/random spelling.
TUNE_OPTIONS: tuple[str, ...] = (
    "sigmas", "lams", "folds", "search", "num_samples", "policy",
    "halving_eta", "sigma_continuation", "strategy",
    "rank", "max_iters", "tol", "seed", "warm_start", "precision",
    "telemetry",
)

#: accepted keyword options of tune() on the multi-kernel (weight-axis)
#: path — selected when ``kernels``/``n_weight_samples``/``weights`` is
#: passed or the problem's kernel is a tuple
MULTIKERNEL_TUNE_OPTIONS: tuple[str, ...] = (
    "kernels", "sigmas", "lams", "folds", "n_weight_samples", "weights",
    "dirichlet_alpha", "policy", "halving_eta", "sigma_continuation",
    "strategy", "rank", "max_iters", "tol", "seed", "warm_start", "precision",
    "telemetry",
)


@dataclasses.dataclass
class SolveOutput:
    method: str
    w: jax.Array
    history: list[dict]
    info: dict[str, Any]
    predict_fn: Any  # (x_test) -> predictions ((m,) or (m, t))


def _validate_options(method: str, kw: dict) -> None:
    accepted = METHOD_OPTIONS[method]
    unknown = sorted(set(kw) - set(accepted))
    if unknown:
        raise ValueError(
            f"unknown option(s) {unknown} for method {method!r}; "
            f"accepted: {sorted(accepted) or '(none)'}"
        )


def _head_info(problem: KRRProblem, history: list[dict]) -> dict[str, Any]:
    info: dict[str, Any] = {"t": problem.t}
    if history and "rel_residual_per_head" in history[-1]:
        info["rel_residual_per_head"] = history[-1]["rel_residual_per_head"]
    return info


def _solve_dist(problem: KRRProblem, method: str, mesh, kw: dict) -> SolveOutput:
    # imported lazily: the single-device path stays free of the distributed
    # stack, and distributed.krr_dist itself imports repro.core
    from repro.distributed import krr_dist
    from repro.serving.krr_serve import make_krr_predict_fn

    if method not in DIST_METHOD_OPTIONS:
        raise ValueError(
            f"method {method!r} has no distributed path; mesh= supports "
            f"{sorted(DIST_METHOD_OPTIONS)}"
        )
    unknown = sorted(set(kw) - set(DIST_METHOD_OPTIONS[method]))
    if unknown:
        raise ValueError(
            f"unknown option(s) {unknown} for method {method!r} with mesh=; "
            f"accepted: {sorted(DIST_METHOD_OPTIONS[method])}"
        )
    if method in ("askotch", "skotch"):
        res = krr_dist.solve_askotch_dist(
            problem, mesh, accelerated=(method == "askotch"), **kw
        )
    else:
        precond = {"pcg-nystrom": "nystrom", "cg": "identity"}[method]
        res = krr_dist.solve_pcg_dist(problem, mesh, precond=precond, **kw)
    return SolveOutput(
        method=method,
        w=res.w,
        history=res.history,
        info={"iters": res.iters, "converged": res.converged,
              "wall_time_s": res.wall_time_s, "mesh": dict(mesh.shape),
              **_head_info(problem, res.history)},
        predict_fn=make_krr_predict_fn(res.op, res.w),
    )


def _solve_dc(problem: KRRProblem, mesh, telemetry, kw: dict) -> SolveOutput:
    # imported lazily, mirroring _solve_dist: the plain path never loads
    # the distributed stack
    from repro.distributed.dc import solve_dc

    bad = sorted(
        k for k in kw if k.startswith("dc_") and k not in DC_METHOD_OPTIONS
    )
    if bad:
        raise ValueError(
            f"unknown option(s) {bad} for method 'dc'; accepted: "
            f"{sorted(DC_METHOD_OPTIONS)} plus the inner method's options "
            f"(METHOD_OPTIONS[dc_method])"
        )
    res = solve_dc(
        problem,
        shards=kw.pop("dc_shards", 2),
        partition=kw.pop("dc_partition", "random"),
        combiner=kw.pop("dc_combiner", "uniform"),
        method=kw.pop("dc_method", "askotch"),
        softmax_temp=kw.pop("dc_softmax_temp", None),
        mesh=mesh,
        telemetry=telemetry,
        **kw,
    )
    return SolveOutput(
        method="dc",
        w=res.w,
        history=res.history,
        info=res.info,
        predict_fn=res.predict_fn,
    )


def tune(problem: KRRProblem, *, mesh=None, **kw):
    """Hyperparameter search over (sigma, lam) with k-fold CV — the
    policy-driven tile-sharing sweep of ``repro.core.tune`` behind the
    solver-API contract.

    The search grows a WEIGHT axis when the problem is multi-kernel: pass
    ``kernels=("rbf", "laplacian", ...)`` (or a problem whose ``kernel`` is
    already a tuple) and the sweep becomes himalaya-style random search over
    convex kernel combinations — every (weight, lam, fold, head) candidate
    rides the same stacked solve (``repro.core.tune.tune_multikernel``).
    ``policy="halving"`` prunes losing candidates at rungs mid-solve and
    ``sigma_continuation=True`` seeds each sigma group from the previous
    one — both run unchanged over a mesh.

    Args:
      problem: data container (``x``/``y``/``kernel``/``backend`` used;
        ``sigma``/``lam_unscaled`` are the quantities being tuned).
      mesh: optional ``jax.sharding.Mesh``; candidates then run over the
        ``ShardedKernelOperator`` path, same as ``solve(..., mesh=...)``.
      **kw: any of :data:`TUNE_OPTIONS` (``sigmas``, ``lams``, ``folds``,
        ``search``, ``num_samples``, ``policy``, ``halving_eta``,
        ``sigma_continuation``, ``strategy``, ``rank``, ``max_iters``,
        ``tol``, ``seed``, ``warm_start``, ``telemetry`` — a
        ``repro.obs.Telemetry`` session recording spans/traces/metrics for
        the whole search) — or, on the multi-kernel path,
        :data:`MULTIKERNEL_TUNE_OPTIONS` (adds ``kernels``,
        ``n_weight_samples``, ``weights``, ``dirichlet_alpha``; drops
        ``search``/``num_samples``).  Unknown options raise ValueError with
        the accepted list.

    Returns:
      A :class:`repro.core.tune.TuneResult` (``trace`` carries the
      per-candidate audit trail); refit with
      ``solve(apply_best(problem, result), method)`` and serve the
      exported ``result.best`` config via ``serving.krr_serve.
      make_krr_predict_fn_from_config``.
    """
    multikernel = (
        isinstance(problem.kernel, tuple)
        or any(k in kw for k in ("kernels", "n_weight_samples", "weights"))
    )
    accepted = MULTIKERNEL_TUNE_OPTIONS if multikernel else TUNE_OPTIONS
    unknown = sorted(set(kw) - set(accepted))
    if unknown:
        kind = "multi-kernel tune()" if multikernel else "tune()"
        raise ValueError(
            f"unknown option(s) {unknown} for {kind}; "
            f"accepted: {sorted(accepted)}"
        )
    if "precision" in kw:
        # universal precision override, mirroring solve(): the policy lives
        # on the problem and rides into every candidate operator
        problem = dataclasses.replace(
            problem, precision=check_precision(kw.pop("precision"))
        )
    if mesh is not None and problem.kernel == "precomputed":
        raise ValueError(
            "kernel='precomputed' cannot run over a mesh: the Gram matrix is "
            "a single-host array with no row-sharded kernel evaluation path — "
            "drop mesh= or pass the raw features with a kernel name"
        )
    # lazy: keeps solve()-only imports light (imports the tune PACKAGE —
    # ``repro.core.tune`` the attribute is this very function)
    from repro.core.tune import tune as _tune
    from repro.core.tune import tune_multikernel as _tune_multikernel

    if multikernel:
        return _tune_multikernel(problem, mesh=mesh, **kw)
    return _tune(problem, mesh=mesh, **kw)


def solve(problem: KRRProblem, method: str = "askotch", *, mesh=None, **kw) -> SolveOutput:
    """Solve (K + lam I) W = Y with any method the paper benchmarks.

    Args:
      problem: the :class:`~repro.core.krr.KRRProblem`; ``problem.y`` may be
        (n,) or (n, t) one-vs-all heads — every method runs all t heads in
        one multi-RHS solve.
      method: one of :data:`METHODS` (see docs/solvers.md for the per-method
        matrix).
      mesh: optional ``jax.sharding.Mesh``; methods in
        :data:`DIST_METHOD_OPTIONS` then run distributed over a
        ``ShardedKernelOperator`` with W row-sharded.  A 1-device mesh is
        valid and runs the distributed code with no-op collectives.
      **kw: method-specific options — exactly :data:`METHOD_OPTIONS[method]`
        (:data:`DIST_METHOD_OPTIONS[method]` with ``mesh=``); anything else
        raises ValueError with the accepted list.  ``method="dc"`` accepts
        :data:`DC_METHOD_OPTIONS` (``dc_shards``, ``dc_partition``,
        ``dc_combiner``, ``dc_method``, ``dc_softmax_temp``) plus the inner
        method's own options un-prefixed.  Three universal overrides
        are accepted for every method: ``kernel=`` (a name, or a TUPLE of
        names for a weighted-sum multi-kernel solve), ``weights=`` (the
        combination weights) and ``precision=`` ("f32" | "bf16" kernel-tile
        policy) re-parameterize the problem before solving —
        ``solve(p, "pcg-nystrom", kernel=("rbf", "matern52"), weights=(0.7,
        0.3))`` runs the convex kernel combination through the same solver,
        and ``solve(p, "askotch", precision="bf16")`` runs every kernel
        sweep with bf16 tiles + f32 accumulation (solver internals stay f32;
        a ``tol`` below ~1e-6 triggers a warning since bf16 tiles cannot
        reach machine-precision residuals).  A fourth universal option,
        ``telemetry=`` (a ``repro.obs.Telemetry``), records a solve span,
        canonical per-iteration trace events, and tile-work metrics for any
        method; the default ``None`` costs a single identity check.

    Returns:
      A :class:`SolveOutput`: ``w`` ((n,), (n, t), or (m[, t]) for Falkon's
      inducing-point weights), per-iteration ``history`` records
      (``rel_residual``, ``rel_residual_per_head``), an ``info`` dict, and a
      ``predict_fn`` mapping (q, d) queries to (q[, t]) scores.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; available: {METHODS}")
    telemetry = kw.pop("telemetry", None)
    if "kernel" in kw or "weights" in kw or "precision" in kw:
        # universal overrides: rebuild the problem, then solve through the
        # unchanged per-method path (the operator layer absorbs the weighted
        # combination and the tile-precision policy)
        problem = dataclasses.replace(
            problem,
            **{
                k: kw.pop(k)
                for k in ("kernel", "weights", "precision")
                if k in kw
            },
        )
    check_precision(problem.precision)
    if problem.precision == "bf16" and kw.get("tol", 1.0) < BF16_TOL_FLOOR:
        warnings.warn(
            f"tol={kw['tol']:g} is below the bf16 kernel-tile resolution "
            f"(~{BF16_TOL_FLOOR:g} relative residual); the solve will stall "
            'short of it — use precision="f32" for machine-precision targets',
            stacklevel=2,
        )
    if method == "dc":
        # the divide-and-conquer tier owns its own mesh handling (explicit
        # per-device placement, zero collectives) — routed BEFORE the
        # ShardedKernelOperator dispatch below
        return _solve_dc(problem, mesh, telemetry, kw)
    if mesh is not None:
        if problem.kernel == "precomputed":
            raise ValueError(
                "kernel='precomputed' cannot run over a mesh: the Gram "
                "matrix is a single-host array with no row-sharded kernel "
                "evaluation path — drop mesh= or pass the raw features with "
                "a kernel name"
            )
        tel = as_telemetry(telemetry)
        with tel.span(f"solve/dist-{method}", n=problem.n, t=problem.t,
                      mesh=dict(mesh.shape)):
            return _solve_dist(problem, method, mesh, kw)
    _validate_options(method, kw)
    if method in ("askotch", "skotch"):
        cfg_kw = {k: kw.pop(k) for k in _ASKOTCH_CFG_KEYS if k in kw}
        cfg = askotch.ASkotchConfig(accelerated=(method == "askotch"), **cfg_kw)
        res = askotch.solve(problem, cfg, telemetry=telemetry, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "converged": res.converged,
                  "wall_time_s": res.wall_time_s, **_head_info(problem, res.history)},
            predict_fn=lambda xt: problem.predict(res.w, xt),
        )
    if method in ("pcg-nystrom", "pcg-rpcholesky", "pcg-rff", "cg"):
        precond = {
            "pcg-nystrom": "nystrom", "pcg-rpcholesky": "rpcholesky",
            "pcg-rff": "rff", "cg": "identity",
        }[method]
        res = pcg.solve_pcg(problem, precond=precond, telemetry=telemetry, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "converged": res.converged,
                  "wall_time_s": res.wall_time_s, **_head_info(problem, res.history)},
            predict_fn=lambda xt: problem.predict(res.w, xt),
        )
    if method == "falkon":
        res = falkon.solve_falkon(problem, telemetry=telemetry, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "wall_time_s": res.wall_time_s,
                  "m": res.w.shape[0], **_head_info(problem, res.history)},
            predict_fn=lambda xt: falkon.falkon_predict(problem, res, xt),
        )
    if method == "eigenpro":
        res = eigenpro.solve_eigenpro(problem, telemetry=telemetry, **kw)
        return SolveOutput(
            method=method,
            w=res.w,
            history=res.history,
            info={"iters": res.iters, "wall_time_s": res.wall_time_s,
                  **_head_info(problem, res.history)},
            predict_fn=lambda xt: problem.predict(res.w, xt),
        )
    # direct
    with as_telemetry(telemetry).span("solve/direct", n=problem.n,
                                      t=problem.t):
        w = direct.solve_direct(problem)
    return SolveOutput(
        method=method,
        w=w,
        history=[],
        info=_head_info(problem, []),
        predict_fn=lambda xt: problem.predict(w, xt),
    )
