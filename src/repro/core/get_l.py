"""Automatic stepsize: preconditioned smoothness constant via randomized
powering (paper Algorithm 5, §2.3, App. A.2).

Estimates  L_PB = lambda_1( (K_hat+rho I)^{-1/2} (K_BB + lam I) (K_hat+rho I)^{-1/2} )
using matvecs only:  (K_hat+rho I)^{-1/2} comes from the Woodbury identity
(Eq. (16)); (K_BB + lam I) v is either a dense matvec with the materialized
block or a fused streaming kernel matvec for huge blocks.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.nystrom import NystromFactors, woodbury_invsqrt_apply


def get_l(
    key: jax.Array,
    kbb_lam_matvec: Callable[[jax.Array], jax.Array],
    factors: NystromFactors,
    rho: jax.Array,
    num_iters: int = 10,
    num_probes: int = 1,
) -> jax.Array:
    """Algorithm 5: randomized (block) powering; returns L_PB (scalar).

    kbb_lam_matvec(v) must compute (K_BB + lam I) v for v of shape (p, q) —
    the same multi-RHS contract as the solver hot path, so the probe block
    rides one fused pass.  num_probes > 1 runs subspace iteration (probes
    re-orthonormalized by QR each round), which converges in fewer rounds
    when the top of the preconditioned spectrum is clustered.
    """
    p = factors.u.shape[0]
    q = max(1, min(num_probes, p))
    v0 = jax.random.normal(key, (p, q), dtype=factors.u.dtype)
    v0, _ = jnp.linalg.qr(v0)

    def body(carry, _):
        v, _ = carry
        u = woodbury_invsqrt_apply(factors, rho, v)
        u = kbb_lam_matvec(u)
        u = woodbury_invsqrt_apply(factors, rho, u)
        # Rayleigh quotients against the orthonormal probe columns
        lam_est = jnp.max(jnp.sum(v * u, axis=0))
        v_next, _ = jnp.linalg.qr(u)
        return (v_next, lam_est), None

    (v, lam_est), _ = jax.lax.scan(
        body, (v0, jnp.array(1.0, v0.dtype)), None, length=num_iters
    )
    # Power iteration under-estimates lambda_1 from below; the solver guards
    # with eta = 1/max(L, 1) anyway (hat-L in Lemma 8).
    return lam_est


def get_l_dense(
    key: jax.Array,
    kbb: jax.Array,
    lam: jax.Array,
    factors: NystromFactors,
    rho: jax.Array,
    num_iters: int = 10,
    num_probes: int = 1,
) -> jax.Array:
    """Convenience wrapper for a materialized block."""

    def mv(v):
        return kbb @ v + lam * v

    return get_l(key, mv, factors, rho, num_iters=num_iters, num_probes=num_probes)
