"""Randomized Nystrom approximation (paper Algorithm 4, App. A.1) and the
Woodbury solves used to apply it (Eqs. (15)/(16), App. A.1.1).

``nystrom`` returns factors (U, lam) with U in R^{p x r} orthonormal and
lam in R^r_{>=0} such that  K_hat = U diag(lam) U^T  approximates the psd
input.  The approximation is never formed as a matrix.

Two inverse-apply paths are provided, matching the paper:
  * ``woodbury_inv_apply``       — Eq. (15), O(pr); fine in f64.
  * ``stable_inv_apply``         — App. A.1.1 Cholesky variant, O(pr^2) setup
                                   then O(pr) per apply; robust in f32 where
                                   U^T U = I no longer holds after roundoff.
  * ``woodbury_invsqrt_apply``   — Eq. (16), used inside get_L.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class NystromFactors(NamedTuple):
    u: jax.Array  # (p, r) approximate top-r eigenvectors
    lam: jax.Array  # (r,)  approximate top-r eigenvalues (>= 0, descending)


def nystrom(key: jax.Array, m: jax.Array, rank: int) -> NystromFactors:
    """Algorithm 4: randomized Nystrom approximation of a psd matrix m (p x p).

    Cost O(p^2 r + p r^2); returns factors only.
    """
    p = m.shape[0]
    omega = jax.random.normal(key, (p, rank), dtype=m.dtype)
    omega, _ = jnp.linalg.qr(omega)  # orthonormal test matrix
    y = m @ omega
    return nystrom_from_sketch(y, omega, trace_hint=jnp.trace(m))


def nystrom_from_sketch(
    y: jax.Array, omega: jax.Array, trace_hint: jax.Array
) -> NystromFactors:
    """Algorithm 4 given a precomputed sketch y = M @ omega.

    Split out so the sketch can come from the fused streaming kernel op
    (never materializing M = K_BB) on huge blocks.
    """
    shift = jnp.finfo(y.dtype).eps * trace_hint
    y_shift = y + shift * omega
    gram = omega.T @ y_shift
    gram = 0.5 * (gram + gram.T)
    # Cholesky with escalating jitter: f32 sketches of nearly-singular blocks
    # occasionally need more than the eps*tr(M) shift.  lax.cond keeps it jit-able.
    chol = jnp.linalg.cholesky(gram)

    def _retry(_):
        jitter = 10.0 * jnp.finfo(y.dtype).eps * (jnp.trace(gram) + 1.0)
        return jnp.linalg.cholesky(gram + jitter * jnp.eye(gram.shape[0], dtype=y.dtype))

    chol = jax.lax.cond(
        jnp.any(jnp.isnan(chol)), _retry, lambda _: chol, operand=None
    )
    b = jax.scipy.linalg.solve_triangular(chol, y_shift.T, lower=True).T
    u, s, _ = jnp.linalg.svd(b, full_matrices=False)
    lam = jnp.maximum(s * s - shift, 0.0)
    return NystromFactors(u=u, lam=lam)


def _scale_rows(m: jax.Array, coeff: jax.Array) -> jax.Array:
    """diag(coeff) @ m for m of shape (r,) or (r, t)."""
    return m * coeff[:, None] if m.ndim == 2 else m * coeff


def woodbury_inv_apply(f: NystromFactors, rho: jax.Array, g: jax.Array) -> jax.Array:
    """(U diag(lam) U^T + rho I)^{-1} g in O(p r t)  (Eq. (15)).

    g may be a single vector (p,) or a block of t right-hand sides (p, t);
    the factor products are shared across columns either way.
    """
    utg = f.u.T @ g
    core = _scale_rows(utg, 1.0 / (f.lam + rho))
    return f.u @ core + (g - f.u @ utg) / rho


def stable_inv_apply_setup(f: NystromFactors, rho: jax.Array) -> jax.Array:
    """Cholesky factor L of (rho diag(lam^{-1}) + U^T U) — App. A.1.1.

    lam entries equal to zero are floored: a zero Nystrom eigenvalue means the
    corresponding direction contributes nothing, so flooring to a huge inverse
    is equivalent to dropping it.
    """
    lam_safe = jnp.maximum(f.lam, jnp.finfo(f.lam.dtype).tiny * 1e8)
    gram = rho * jnp.diag(1.0 / lam_safe) + f.u.T @ f.u
    return jnp.linalg.cholesky(0.5 * (gram + gram.T))


def stable_inv_apply(
    f: NystromFactors, rho: jax.Array, chol_l: jax.Array, g: jax.Array
) -> jax.Array:
    """(K_hat + rho I)^{-1} g via the f32-stable Cholesky path (App. A.1.1).

    Accepts g of shape (p,) or (p, t) — the triangular solves batch over
    columns, so a t-head block costs one factorization plus O(p r t).
    """
    utg = f.u.T @ g
    z = jax.scipy.linalg.solve_triangular(chol_l, utg, lower=True)
    z = jax.scipy.linalg.solve_triangular(chol_l.T, z, lower=False)
    return (g - f.u @ z) / rho


def woodbury_invsqrt_apply(f: NystromFactors, rho: jax.Array, v: jax.Array) -> jax.Array:
    """(U diag(lam) U^T + rho I)^{-1/2} v in O(p r t)  (Eq. (16)); v may be
    (p,) or a (p, t) block (e.g. get_L block powering probes)."""
    utv = f.u.T @ v
    core = _scale_rows(utv, 1.0 / jnp.sqrt(f.lam + rho))
    return f.u @ core + (v - f.u @ utv) / jnp.sqrt(rho)


def nystrom_dense(f: NystromFactors) -> jax.Array:
    """Materialize K_hat (tests only)."""
    return (f.u * f.lam) @ f.u.T
