"""Search POLICIES: who proposes candidates, and when to stop paying for them.

The engine (``core/tune/engine.py``) can solve one sigma group's worth of
candidates in one stacked blocked-CG; a :class:`SearchPolicy` drives it
through three hooks:

  * ``propose(space, rng)`` — turn the search space into the ordered list of
    :class:`~repro.core.tune.engine.SigmaGroup` the engine will solve.
  * ``rungs(group, max_iters)`` — iteration checkpoints at which the engine
    scores every in-flight candidate mid-solve (one kernel sweep each).
  * ``prune(group, rung_index, it, scores, active)`` — given those scores,
    a bool mask of candidates to freeze (their columns stop iterating via
    ``blocked_cg``'s external freeze hook); None keeps everyone.
  * ``observe(group, records)`` — the group's final CV records, for policies
    that adapt later proposals.

:class:`GridSearch` and :class:`RandomSearch` reproduce the pre-PR-5
``tune``/``tune_multikernel`` behavior exactly (same candidate sets, same
rng stream, never pruning).  :class:`SuccessiveHalving` prunes losing
(lam[, weight]) candidates at geometric rungs mid-solve — the stacked solve
then ends as soon as the *survivors* converge instead of waiting for the
slowest loser, which is where the kernel-sweep savings come from
(``benchmarks/bench_tuning.py`` enforces halving < grid at equal best
config).  The same policy objects drive local and mesh runs unchanged: they
only ever see host-side score arrays.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Protocol, runtime_checkable

import numpy as np

from repro.core.tune.engine import SigmaGroup

__all__ = [
    "POLICIES",
    "GridSearch",
    "RandomSearch",
    "SearchPolicy",
    "SuccessiveHalving",
    "TuneSpace",
    "make_policy",
]

#: the built-in policy names ``tune(policy=...)`` accepts
POLICIES = ("grid", "random", "halving")


@dataclasses.dataclass(frozen=True)
class TuneSpace:
    """The search space a policy turns into sigma groups.

    ``weight_samples`` (an (M, q) matrix) marks the multi-kernel weight
    axis — every sigma group then carries all M weight candidates;
    ``num_samples`` is the single-kernel random-search budget over the
    (sigma, lam) grid.
    """

    sigmas: tuple[float, ...]
    lams: tuple[float, ...]
    num_samples: int | None = None
    weight_samples: Any = None  # np.ndarray (M, q) | None


@runtime_checkable
class SearchPolicy(Protocol):
    """The propose/observe/prune contract the tuning driver runs against."""

    name: str

    def propose(
        self, space: TuneSpace, rng: np.random.Generator
    ) -> list[SigmaGroup]:
        """Ordered sigma groups to solve (each = one stacked blocked-CG)."""
        ...

    def rungs(self, group: SigmaGroup, max_iters: int) -> tuple[int, ...]:
        """Iteration checkpoints for mid-solve scoring (empty = none)."""
        ...

    def prune(
        self,
        group: SigmaGroup,
        rung_index: int,
        it: int,
        scores: np.ndarray,
        active: np.ndarray,
    ) -> "np.ndarray | None":
        """(n_cand,) bool mask of candidates to freeze now, or None."""
        ...

    def observe(self, group: SigmaGroup, records: list[dict]) -> None:
        """Final CV records of a solved group (hook for adaptive policies)."""
        ...


def _grid_groups(space: TuneSpace) -> list[SigmaGroup]:
    """Full cross product, grouped by sigma in first-seen order.  A sigma
    may be a scalar or a per-kernel tuple (multi-kernel bandwidth vectors) —
    ``canon_sigma`` makes either a hashable group key."""
    from repro.core.tune.engine import canon_sigma

    by_sigma: dict[Any, list[float]] = {}
    if space.weight_samples is None:
        # single-kernel legacy grouping: a repeated sigma repeats its lams
        for s in space.sigmas:
            for lv in space.lams:
                by_sigma.setdefault(canon_sigma(s), []).append(float(lv))
    else:
        # multi-kernel legacy grouping: sigmas dedup (dict.fromkeys)
        for s in dict.fromkeys(canon_sigma(s) for s in space.sigmas):
            by_sigma[s] = [float(lv) for lv in space.lams]
    return [
        SigmaGroup(sigma=s, lam_list=tuple(lams),
                   weight_samples=space.weight_samples)
        for s, lams in by_sigma.items()
    ]


@dataclasses.dataclass
class GridSearch:
    """Exhaustive search: every (sigma, lam[, weight]) candidate runs to the
    stacked solve's convergence; nothing is ever pruned.  Reproduces the
    pre-PR-5 ``search="grid"`` behavior exactly."""

    name: str = "grid"

    def propose(
        self, space: TuneSpace, rng: np.random.Generator
    ) -> list[SigmaGroup]:
        """All sigma groups with the full lam list (and all weight rows)."""
        if space.num_samples is not None:
            raise ValueError(
                "num_samples only applies to search='random'; grid search "
                "always runs the full cross product"
            )
        return _grid_groups(space)

    def rungs(self, group: SigmaGroup, max_iters: int) -> tuple[int, ...]:
        """No mid-solve scoring."""
        return ()

    def prune(self, group, rung_index, it, scores, active):
        """Never prunes."""
        return None

    def observe(self, group: SigmaGroup, records: list[dict]) -> None:
        """Stateless — nothing to adapt."""


@dataclasses.dataclass
class RandomSearch:
    """Random subset of the (sigma, lam) grid (``num_samples`` draws without
    replacement, same rng stream as the pre-PR-5 ``search="random"``); on the
    multi-kernel path the weight matrix IS the random axis and every sigma
    group carries it whole."""

    name: str = "random"

    def propose(
        self, space: TuneSpace, rng: np.random.Generator
    ) -> list[SigmaGroup]:
        """Sampled (sigma, lam) grid points grouped by sigma (single-kernel);
        the full sigma x weight-sample cross product otherwise."""
        if space.weight_samples is not None:
            # the weight matrix was already randomly drawn — the sigma/lam
            # axes stay exhaustive, exactly like tune_multikernel always did
            return _grid_groups(space)
        from repro.core.tune.engine import canon_sigma

        grid = [
            (canon_sigma(s), float(lv))
            for s in space.sigmas
            for lv in space.lams
        ]
        k = (len(grid) if space.num_samples is None
             else min(int(space.num_samples), len(grid)))
        if k < 1:
            raise ValueError("random search needs num_samples >= 1")
        picks = rng.choice(len(grid), size=k, replace=False)
        cands = [grid[i] for i in sorted(picks)]
        by_sigma: dict[Any, list[float]] = {}
        for s, lv in cands:
            by_sigma.setdefault(s, []).append(lv)
        return [
            SigmaGroup(sigma=s, lam_list=tuple(lams))
            for s, lams in by_sigma.items()
        ]

    def rungs(self, group: SigmaGroup, max_iters: int) -> tuple[int, ...]:
        """No mid-solve scoring."""
        return ()

    def prune(self, group, rung_index, it, scores, active):
        """Never prunes."""
        return None

    def observe(self, group: SigmaGroup, records: list[dict]) -> None:
        """Stateless — nothing to adapt."""


@dataclasses.dataclass
class SuccessiveHalving:
    """Successive halving over each sigma group's candidates, pruned
    MID-SOLVE.

    With n candidates and reduction factor ``eta``, the group's stacked
    blocked-CG hits ``R = ceil(log_eta n)`` rungs at iterations
    ``max_iters / eta^(R - j)`` (j = 0..R-1).  At rung j the engine scores
    every candidate from the current block (one kernel sweep) and this
    policy keeps the best ``ceil(n / eta^(j+1))``, freezing the columns of
    the rest via ``blocked_cg``'s external freeze hook.  The solve then runs
    only until the survivors converge — pruning the slow, losing tail
    (typically the smallest lams: worst-conditioned AND overfit) is what
    turns into kernel-sweep savings.  The top candidate at every rung is
    never pruned, so when the winner is separable by the first rung the
    halving search returns the exhaustive grid's best config at a strict
    sweep discount (the acceptance claim ``benchmarks/bench_tuning.py``
    enforces).
    """

    eta: float = 3.0
    name: str = "halving"

    def __post_init__(self) -> None:
        if not self.eta > 1.0:
            raise ValueError(f"halving_eta must be > 1; got {self.eta}")

    def propose(
        self, space: TuneSpace, rng: np.random.Generator
    ) -> list[SigmaGroup]:
        """The full grid — halving prunes instead of subsampling."""
        if space.num_samples is not None:
            raise ValueError(
                "num_samples does not apply to policy='halving'; halving "
                "starts from the full grid and prunes at rungs"
            )
        return _grid_groups(space)

    def n_rungs(self, n_candidates: int) -> int:
        """Halvings needed to reach one survivor."""
        if n_candidates <= 1:
            return 0
        return int(math.ceil(math.log(n_candidates) / math.log(self.eta)))

    def rungs(self, group: SigmaGroup, max_iters: int) -> tuple[int, ...]:
        """Geometric iteration checkpoints ``max_iters / eta^(R - j)``."""
        n_r = self.n_rungs(group.n_candidates)
        marks = sorted({
            max(1, int(max_iters / self.eta ** (n_r - j)))
            for j in range(n_r)
        })
        return tuple(m for m in marks if m < max_iters)

    def prune(
        self,
        group: SigmaGroup,
        rung_index: int,
        it: int,
        scores: np.ndarray,
        active: np.ndarray,
    ) -> "np.ndarray | None":
        """Keep the best ``ceil(n / eta^(rung_index + 1))`` active
        candidates; freeze the rest."""
        n_cand = len(scores)
        n_keep = max(1, int(math.ceil(n_cand / self.eta ** (rung_index + 1))))
        act_idx = np.nonzero(active)[0]
        if len(act_idx) <= n_keep:
            return None
        order = act_idx[np.argsort(scores[act_idx], kind="stable")]
        mask = np.zeros(n_cand, bool)
        mask[order[n_keep:]] = True
        return mask

    def observe(self, group: SigmaGroup, records: list[dict]) -> None:
        """Stateless across groups (rung state lives in the engine)."""


def make_policy(name_or_policy, *, halving_eta: float = 3.0) -> SearchPolicy:
    """Resolve ``tune(policy=...)``: a name from :data:`POLICIES` or an
    object already implementing :class:`SearchPolicy`."""
    if not isinstance(name_or_policy, str):
        if isinstance(name_or_policy, SearchPolicy):
            return name_or_policy
        raise ValueError(
            f"policy must be one of {POLICIES} or a SearchPolicy instance; "
            f"got {name_or_policy!r}"
        )
    if name_or_policy == "grid":
        return GridSearch()
    if name_or_policy == "random":
        return RandomSearch()
    if name_or_policy == "halving":
        return SuccessiveHalving(eta=float(halving_eta))
    raise ValueError(
        f"unknown policy {name_or_policy!r}; accepted: {POLICIES}"
    )
