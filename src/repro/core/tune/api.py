"""Tuning entry points: policy-driven searches over the stacked engine.

``tune`` / ``tune_multikernel`` keep their pre-PR-5 signatures and defaults
(grid / random search, shared vs naive strategy) and grow three knobs:

  * ``policy=`` — "grid" | "random" | "halving" (or a ``SearchPolicy``
    object): who proposes candidates and when to prune them.
  * ``halving_eta=`` — the successive-halving reduction factor.
  * ``sigma_continuation=`` — seed each sigma group's stacked solve and
    sketch from the previous group's result instead of from zero.

One driver (:func:`run_search`) serves both entry points: the single-kernel
sweep is literally the multi-kernel sweep without a weight matrix (the
engine's q = 1 degenerate case), which is what deleted the duplicated
``_tune_one_sigma_shared`` / ``_tune_one_sigma_multi_shared`` pair.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core.krr import KRRProblem
from repro.core.operator import as_multirhs
from repro.core.tune.engine import (
    Continuation,
    SigmaGroup,
    SweepCounter,
    canon_sigma,
    fold_avg_w0,
    make_folds,
    naive_candidate_solve,
    operator_for,
    score_fold,
    solve_sigma_group,
)
from repro.core.tune.policies import (
    POLICIES,
    SearchPolicy,
    TuneSpace,
    make_policy,
)
from repro.obs.telemetry import as_telemetry

SEARCHES = ("grid", "random")
STRATEGIES = ("shared", "naive")

__all__ = [
    "SEARCHES",
    "STRATEGIES",
    "TuneResult",
    "apply_best",
    "run_search",
    "tune",
    "tune_multikernel",
]


@dataclasses.dataclass
class TuneResult:
    """Outcome of a (sigma[, weight], lam) sweep with k-fold CV.

    Attributes:
      best: JSON-able best-config dict — ``kernel``, ``sigma``,
        ``lam_unscaled``, ``backend``, ``folds``, ``cv_mse`` (plus
        ``weights`` for a multi-kernel sweep) — consumable by
        :func:`repro.serving.krr_serve.make_krr_predict_fn_from_config` and
        :func:`apply_best`.
      best_score: the winning mean CV validation MSE (lower is better).
      records: one dict per evaluated candidate: ``sigma``, ``lam_unscaled``,
        ``cv_mse``, ``fold_mse`` (length-k list), ``cv_acc`` (top-1
        one-vs-all accuracy) when the problem has t > 1 heads, ``weights``
        on the multi-kernel path, and ``pruned_at_rung`` when a halving
        policy froze the candidate mid-solve.
      folds / search / strategy: the sweep configuration actually run
        (``search`` is the policy name: "grid", "random", or "halving").
      sweeps: kernel-tile sweep equivalents consumed (see
        :class:`~repro.core.tune.engine.SweepCounter`); the tile-sharing
        claim is ``sweeps`` staying ~s solves' worth for an s-sigma grid.
      info: extras — ``pairs``, ``n``, ``t``, ``candidates``, ``policy``,
        ``naive_sweep_estimate`` (what the per-candidate loop would cost),
        per-sigma iteration counts, ``sigma_continuation``.
      best_w0: fold-averaged weights of the winning candidate (the
        mask-supported mean of its k CV fold solutions; (n,) or (n, t)) —
        the refit warm start ``apply_best`` can thread to the solver.  None
        for the naive strategy (its fold solves are discarded).
      trace: the audit trail — one dict per candidate (aligned with
        ``records``): ``sigma``, ``lam_unscaled`` (+ ``weights``),
        ``scores`` (its CV score at every rung it was alive for, ending
        with the final score), ``iters`` (the iteration each score was
        taken at), and ``pruned_at_rung`` (0-based rung index, or None if
        it survived to the end).  ``launch/krr_tune.py --export`` includes
        it so searches are auditable.
    """

    best: dict[str, Any]
    best_score: float
    records: list[dict[str, Any]]
    folds: int
    search: str
    strategy: str
    sweeps: float
    info: dict[str, Any]
    best_w0: np.ndarray | None = None
    trace: list[dict[str, Any]] | None = None


def apply_best(problem: KRRProblem, result: TuneResult, *, with_w0: bool = False):
    """Return ``problem`` re-parameterized with the tuned best config —
    the refit step of tune -> refit -> serve.

    For a multi-kernel sweep (``result.best`` carries ``weights``) the
    returned problem gets the kernel tuple and winning weight vector too.
    With ``with_w0=True`` returns ``(problem, w0)`` where ``w0`` is the
    fold-averaged CV solution of the winning candidate ((n,) or (n, t), or
    None under the naive strategy) — pass it as the solver's warm start
    (``solve(..., w0=w0)``) instead of starting from zero (ROADMAP item).
    """
    rep: dict[str, Any] = {
        "sigma": result.best["sigma"],
        "lam_unscaled": float(result.best["lam_unscaled"]),
    }
    if isinstance(rep["sigma"], (tuple, list)):
        rep["sigma"] = tuple(float(s) for s in rep["sigma"])
    else:
        rep["sigma"] = float(rep["sigma"])
    if "weights" in result.best:
        rep["kernel"] = tuple(result.best["kernel"])
        rep["weights"] = tuple(float(w) for w in result.best["weights"])
    refit = dataclasses.replace(problem, **rep)
    if with_w0:
        return refit, result.best_w0
    return refit


def _weight_candidates(
    q: int,
    n_weight_samples: int,
    weights,
    dirichlet_alpha: float,
    rng: np.random.Generator,
) -> np.ndarray:
    """The (M, q) weight-candidate matrix: explicit rows, or Dirichlet draws
    from the simplex (himalaya's ``solve_multiple_kernel_ridge_random_search``
    sampling scheme)."""
    if weights is not None:
        w = np.atleast_2d(np.asarray(weights, np.float32))
        if w.shape[1] != q:
            raise ValueError(
                f"weight candidates have {w.shape[1]} entries per row for "
                f"{q} kernels"
            )
        if (w < 0).any() or (w.sum(axis=1) <= 0).any():
            raise ValueError(
                "weight candidates must be nonnegative with positive row sums"
            )
        return w
    if n_weight_samples < 1:
        raise ValueError("n_weight_samples must be >= 1")
    if dirichlet_alpha <= 0:
        raise ValueError("dirichlet_alpha must be positive")
    return rng.dirichlet(
        np.full(q, float(dirichlet_alpha)), size=int(n_weight_samples)
    ).astype(np.float32)


# ---------------------------------------------------------------------------
# the one driver behind tune() and tune_multikernel()
# ---------------------------------------------------------------------------


def run_search(
    problem: KRRProblem,
    base_problem: KRRProblem,
    space: TuneSpace,
    policy: SearchPolicy,
    *,
    folds: int,
    strategy: str,
    rank: int,
    max_iters: int,
    tol: float,
    seed: int,
    warm_start: bool,
    sigma_continuation: bool,
    mesh,
    extra_info: dict[str, Any] | None = None,
    telemetry=None,
) -> TuneResult:
    """Drive ``policy`` over the stacked engine and assemble a TuneResult.

    ``base_problem`` is what operators are built from (the multi-kernel
    entry point re-states the problem as the kernel tuple being searched);
    ``problem`` supplies ``y`` and the best-config ``backend``.  Single- and
    multi-kernel searches, all three policies, shared and naive strategies,
    local and mesh runs all flow through here.  ``telemetry`` adds a search
    span, a per-group span, and canonical trace events (solver ``"tune"``,
    with running ``sweeps``) from every stacked solve.
    """
    tel = as_telemetry(telemetry)
    n = problem.n
    # single-kernel random search consumes this stream exactly like the
    # pre-PR-5 _candidates() did; the multi-kernel weight matrix was already
    # drawn from its own default_rng(seed) before this call
    groups = policy.propose(space, np.random.default_rng(seed))
    val_folds = make_folds(n, folds, np.random.default_rng(seed + 1))
    k = len(val_folds)
    y2, _ = as_multirhs(problem.y)
    y_np = np.asarray(y2)
    t = y_np.shape[1]
    counter = SweepCounter()
    squeeze_w0 = problem.y.ndim == 1

    records: list[dict[str, Any]] = []
    trace: list[dict[str, Any]] = []
    iters_by_sigma: dict[float, int] = {}
    best_w0: np.ndarray | None = None
    best_mse_so_far = np.inf
    cont: Continuation | None = None

    for group in groups:
        params = group.candidate_params()
        if strategy == "shared":
            op = operator_for(base_problem, group.sigma, mesh)
            rung_iters = policy.rungs(group, max_iters)
            with tel.span("tune/group", sigma=group.sigma,
                          candidates=group.n_candidates):
                gr = solve_sigma_group(
                    op, y_np, group, val_folds, rank=min(rank, n),
                    max_iters=max_iters, tol=tol, seed=seed,
                    warm_start=warm_start, counter=counter,
                    rung_iters=rung_iters,
                    prune_fn=(
                        lambda ri, it, scores, active, g=group: policy.prune(
                            g, ri, it, scores, active
                        )
                    ),
                    continuation=cont,
                    want_continuation=sigma_continuation,
                    recorder=tel.recorder(
                        "tune", sweep_counter=counter, n=n
                    ) if tel.enabled else None,
                )
            iters_by_sigma[group.sigma] = gr.iters
            cont = gr.continuation  # None unless sigma_continuation
            group_records: list[dict[str, Any]] = []
            for c, p in enumerate(params):
                col0 = c * k * t
                fold_mse, fold_acc = [], []
                for j, val in enumerate(val_folds):
                    cols = slice(col0 + j * t, col0 + (j + 1) * t)
                    mse, acc = score_fold(gr.preds[val, cols], y_np[val])
                    fold_mse.append(mse)
                    fold_acc.append(acc)
                rec = _record(p, fold_mse, fold_acc, t)
                pruned = gr.pruned_at_rung.get(c)
                if pruned is not None:
                    rec["pruned_at_rung"] = pruned
                group_records.append(rec)
                records.append(rec)
                trace.append({
                    **p,
                    "scores": [
                        r["cv_mse"][c]
                        for ri, r in enumerate(gr.rung_history)
                        if pruned is None or ri <= pruned
                    ] + [rec["cv_mse"]],
                    "iters": [
                        r["iter"]
                        for ri, r in enumerate(gr.rung_history)
                        if pruned is None or ri <= pruned
                    ] + [gr.iters],
                    "pruned_at_rung": pruned,
                })
                if pruned is None and rec["cv_mse"] < best_mse_so_far:
                    # the winner's refit warm start: mask-supported mean of
                    # its k fold solutions (computed lazily — slicing w_cols
                    # is free, keeping every candidate's block would not be).
                    # Pruned candidates are excluded: their frozen blocks are
                    # partially-converged by design
                    best_mse_so_far = rec["cv_mse"]
                    best_w0 = fold_avg_w0(gr.w_cols, col0, k, t, squeeze_w0)
            policy.observe(group, group_records)
        else:  # naive reference loop
            group_records = []
            for p in params:
                fold_mse, fold_acc = [], []
                per_fold, fold_iters = naive_candidate_solve(
                    base_problem, group.sigma, p["lam_unscaled"], val_folds,
                    rank=rank, max_iters=max_iters, tol=tol, seed=seed,
                    counter=counter, mesh=mesh, weights=p.get("weights"),
                )
                for pred, val in zip(per_fold, val_folds):
                    mse, acc = score_fold(pred, y_np[val])
                    fold_mse.append(mse)
                    fold_acc.append(acc)
                rec = _record(p, fold_mse, fold_acc, t)
                group_records.append(rec)
                records.append(rec)
                trace.append({**p, "scores": [rec["cv_mse"]],
                              "iters": [max(fold_iters)],
                              "pruned_at_rung": None})
            policy.observe(group, group_records)

    # best = argmin over SURVIVORS only: a pruned candidate's final score is
    # an early-stopped (implicitly regularized) snapshot that a converged
    # refit would not reproduce — the policy deliberately abandoned it, so it
    # cannot be the search's answer.  Every group keeps >= 1 survivor, so the
    # pool is never empty (grid/random never prune: identical to a plain
    # argmin there).
    survivor_scores = [
        r["cv_mse"] if "pruned_at_rung" not in r else np.inf for r in records
    ]
    best_i = int(np.argmin(survivor_scores))
    best_rec = records[best_i]
    best: dict[str, Any] = {
        "kernel": (
            list(base_problem.kernel)
            if isinstance(base_problem.kernel, tuple)
            else base_problem.kernel
        ),
        "sigma": best_rec["sigma"],
        "lam_unscaled": best_rec["lam_unscaled"],
        "backend": problem.backend,
        "precision": problem.precision,
        "folds": folds,
        "cv_mse": best_rec["cv_mse"],
    }
    if "weights" in best_rec:
        best["weights"] = best_rec["weights"]
        # keep the historical multi-kernel key order (weights after sigma)
        best = {
            "kernel": best["kernel"], "sigma": best["sigma"],
            "weights": best["weights"],
            "lam_unscaled": best["lam_unscaled"], "backend": best["backend"],
            "precision": best["precision"],
            "folds": best["folds"], "cv_mse": best["cv_mse"],
        }
    # what the per-candidate loop would have cost, in full-K sweeps: each of
    # the |cands| * k fold solves pays its own sketch + iteration sweeps over
    # ((k-1)/k * n)^2 tiles
    n_cands = sum(g.n_candidates for g in groups)
    frac = ((folds - 1) / folds) ** 2
    est_iters = max(iters_by_sigma.values()) if iters_by_sigma else max_iters
    naive_est = n_cands * folds * frac * (est_iters + 1)
    info: dict[str, Any] = {
        "pairs": counter.pairs,
        "n": n,
        "t": t,
        "candidates": n_cands,
        "policy": policy.name,
        "sigma_continuation": bool(sigma_continuation),
        "iters_by_sigma": {str(k_): v for k_, v in iters_by_sigma.items()},
        "naive_sweep_estimate": naive_est,
    }
    if extra_info:
        info.update(extra_info)
    return TuneResult(
        best=best,
        best_score=best_rec["cv_mse"],
        records=records,
        folds=folds,
        search=policy.name,
        strategy=strategy,
        sweeps=counter.sweeps(n),
        info=info,
        best_w0=best_w0,
        trace=trace,
    )


def _record(
    params: dict[str, Any], fold_mse: list[float], fold_acc: list[float], t: int
) -> dict[str, Any]:
    rec: dict[str, Any] = {
        "sigma": params["sigma"],
        "lam_unscaled": params["lam_unscaled"],
        "cv_mse": float(np.mean(fold_mse)),
        "fold_mse": fold_mse,
    }
    if t > 1:
        rec["cv_acc"] = float(np.mean(fold_acc))
    if "weights" in params:
        rec["weights"] = list(params["weights"])
    return rec


def _common_validation(
    problem: KRRProblem,
    sigmas: Sequence[float],
    lams: Sequence[float],
    folds: int,
    strategy: str,
    mesh,
    halving_eta: float,
    sigma_continuation: bool,
) -> None:
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; accepted: {STRATEGIES}")
    if not sigmas or not lams:
        raise ValueError("sigmas and lams must be non-empty")
    # a sigma candidate may itself be a per-kernel bandwidth tuple
    flat_sigmas = [
        v
        for s in sigmas
        for v in (s if isinstance(s, (tuple, list)) else (s,))
    ]
    if any(s <= 0 for s in flat_sigmas) or any(lv <= 0 for lv in lams):
        raise ValueError("sigmas and lams must be positive")
    n = problem.n
    if not 2 <= folds <= n:
        raise ValueError(f"folds must be in [2, n={n}]; got {folds}")
    if not halving_eta > 1.0:
        raise ValueError(f"halving_eta must be > 1; got {halving_eta}")
    if strategy == "naive" and sigma_continuation:
        raise ValueError(
            "sigma_continuation requires strategy='shared' (the naive loop "
            "has no stacked solve to continue)"
        )
    if strategy == "naive" and mesh is not None and mesh.devices.size > 1:
        # the naive loop restricts to (k-1)/k * n rows per fold, which the
        # sharded operator would gather fully replicated onto every device —
        # anti-scalable by construction; the reference loop is single-device
        raise ValueError(
            "strategy='naive' is a single-device reference loop; it supports "
            "at most a 1-device mesh (use strategy='shared' for mesh runs)"
        )


def _resolve_policy(policy, legacy_search, strategy, halving_eta) -> SearchPolicy:
    """``legacy_search`` is tune()'s old search= spelling (None when the
    entry point has no such knob); policy= supersedes it but conflicting
    explicit values are rejected."""
    if policy is None:
        policy = legacy_search
    elif isinstance(policy, str) and policy not in POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}; accepted: {POLICIES}"
        )
    resolved = make_policy(policy, halving_eta=halving_eta)
    # the conflict check covers SearchPolicy instances too (their .name is
    # the policy identity) — an explicit non-default search= must not be
    # silently overridden
    if legacy_search not in (None, "grid") and resolved.name != legacy_search:
        raise ValueError(
            f"pass either search={legacy_search!r} or "
            f"policy={resolved.name!r}, not conflicting values of both"
        )
    if strategy == "naive" and resolved.name == "halving":
        raise ValueError(
            "policy='halving' prunes columns of the stacked solve; it "
            "requires strategy='shared' (the naive loop has no shared "
            "solve to prune)"
        )
    return resolved


def tune(
    problem: KRRProblem,
    *,
    sigmas: Sequence[float] = (0.5, 1.0, 2.0),
    lams: Sequence[float] = (1e-6, 1e-4, 1e-2),
    folds: int = 5,
    search: str = "grid",
    num_samples: int | None = None,
    policy: "str | SearchPolicy | None" = None,
    halving_eta: float = 3.0,
    sigma_continuation: bool = False,
    strategy: str = "shared",
    rank: int = 100,
    max_iters: int = 200,
    tol: float = 1e-5,
    seed: int = 0,
    warm_start: bool = True,
    mesh=None,
    telemetry=None,
) -> TuneResult:
    """Policy-driven search over (sigma, lam_unscaled) with k-fold CV.

    Args:
      problem: the data container; its ``x``/``y``/``kernel``/``backend`` are
        used, its ``sigma``/``lam_unscaled`` are ignored (they are what is
        being tuned).  ``y`` may be (n,) or (n, t) one-vs-all heads — all t
        heads ride the same stacked solve.
      sigmas / lams: candidate kernel bandwidths and *unscaled* regularizers
        (the solved shift is ``n_train_fold * lam_unscaled``, the paper's
        App. C.2.1 scaling — same rule :class:`KRRProblem` applies).
      folds: k for k-fold CV (2 <= k <= n); folds are a seeded shuffle-split
        shared by every candidate and both strategies.
      search: "grid" (full cross product) or "random" (``num_samples``
        candidates drawn from the grid without replacement) — the legacy
        spelling of ``policy``; still honored when ``policy`` is None.
      policy: "grid" | "random" | "halving", or a
        :class:`~repro.core.tune.policies.SearchPolicy` instance.
        "halving" runs :class:`~repro.core.tune.policies.SuccessiveHalving`:
        losing (lam) candidates are frozen at geometric rungs MID-SOLVE and
        the stacked solve ends when the survivors converge — strictly fewer
        kernel sweeps than the grid at equal best config when the winner
        separates early.
      halving_eta: successive-halving reduction factor (> 1; keep the best
        ~1/eta of the surviving candidates at each rung).
      sigma_continuation: seed each sigma group's sketch test matrix and
        iterate block from the previous group's Nystrom basis and solution
        instead of a fresh Gaussian / zero start — kernel matrices at nearby
        sigmas share eigenstructure, so this cuts stacked-CG iterations on
        multi-sigma grids (shared strategy only).
      strategy: "shared" — per sigma, ONE stacked blocked-CG over all
        (lam, fold, head) columns (the tile-sharing path); "naive" — an
        independent PCG solve per (sigma, lam, fold), the reference loop the
        benchmark compares against.
      rank: Nystrom sketch rank for the preconditioner (and warm start).
      max_iters / tol: blocked-CG budget per stacked (or per-candidate) solve.
      warm_start: start each column from the Woodbury apply of the shared
        sketch instead of zero ("shared" strategy only; costs no kernel
        sweeps).
      mesh: optional ``jax.sharding.Mesh`` — candidates then run over a
        :class:`~repro.distributed.sharded_operator.ShardedKernelOperator`
        with x/iterates row-sharded (a 1-device mesh is valid everywhere);
        every policy runs unchanged over a mesh.
      telemetry: optional ``repro.obs.Telemetry`` — records a search span,
        per-sigma-group spans, canonical trace events from every stacked
        solve, and the kernel-pair counter the sweep accounting feeds.

    Returns:
      A :class:`TuneResult`; ``result.best`` is the serving-ready config,
      ``result.sweeps`` the kernel-tile work consumed, and ``result.trace``
      the per-candidate audit trail (rung scores + prune points).
    """
    if search not in SEARCHES:
        raise ValueError(f"unknown search {search!r}; accepted: {SEARCHES}")
    _common_validation(
        problem, sigmas, lams, folds, strategy, mesh, halving_eta,
        sigma_continuation,
    )
    resolved = _resolve_policy(policy, search, strategy, halving_eta)
    space = TuneSpace(
        sigmas=tuple(float(s) for s in sigmas),
        lams=tuple(float(lv) for lv in lams),
        num_samples=num_samples,
    )
    with as_telemetry(telemetry).span(
        "tune/search", n=problem.n, folds=folds, policy=resolved.name,
        strategy=strategy,
    ):
        return run_search(
            problem, problem, space, resolved,
            folds=folds, strategy=strategy, rank=rank, max_iters=max_iters,
            tol=tol, seed=seed, warm_start=warm_start,
            sigma_continuation=sigma_continuation, mesh=mesh,
            telemetry=telemetry,
        )


def tune_multikernel(
    problem: KRRProblem,
    *,
    kernels: Sequence[str] | None = None,
    sigmas: Sequence[float] = (0.5, 1.0, 2.0),
    lams: Sequence[float] = (1e-6, 1e-4, 1e-2),
    folds: int = 5,
    n_weight_samples: int = 8,
    weights=None,
    dirichlet_alpha: float = 1.0,
    policy: "str | SearchPolicy | None" = None,
    halving_eta: float = 3.0,
    sigma_continuation: bool = False,
    strategy: str = "shared",
    rank: int = 100,
    max_iters: int = 200,
    tol: float = 1e-5,
    seed: int = 0,
    warm_start: bool = True,
    mesh=None,
    telemetry=None,
) -> TuneResult:
    """Search over convex kernel combinations with k-fold CV.

    himalaya's ``solve_multiple_kernel_ridge_random_search`` draws weight
    vectors from the simplex and scores the banded per-candidate systems;
    here every (weight, lam, fold, head) candidate becomes one more COLUMN
    of the same stacked blocked-CG the (sigma, lam) tuner runs — per sigma,
    the whole c-candidate search costs ~1 solve's kernel work (the
    acceptance claim ``benchmarks/bench_multikernel.py`` measures).

    Args:
      problem: data container; ``kernels`` defaults to ``problem.kernel``
        when that is already a tuple.  ``y`` may be (n,) or (n, t).
      kernels: the q base-kernel names of the combination.
      sigmas: candidate bandwidths, shared by all q kernels per sigma group.
      lams: candidate *unscaled* regularizers (paper App. C.2.1 scaling).
      folds: k for k-fold CV (same seeded shuffle-split as :func:`tune`).
      n_weight_samples: number of Dirichlet(``dirichlet_alpha``) weight
        draws from the simplex.
      weights: explicit (M, q) weight-candidate rows (overrides sampling;
        e.g. one-hot rows reproduce single-kernel tuning exactly).
      policy: None / "random" (the Dirichlet draws ARE the random axis) or
        "halving" — prune losing (weight, lam) candidates at rungs
        mid-solve.  "grid" is rejected: the weight axis is sampled, not
        gridded (pass explicit ``weights=`` rows for an exhaustive sweep).
      halving_eta / sigma_continuation: as in :func:`tune`.
      strategy: "shared" (the stacked engine) or "naive" (independent
        Nystrom-PCG per (sigma, weight, lam, fold) — the reference loop).
      rank / max_iters / tol / warm_start / seed / mesh / telemetry: as in
        :func:`tune`.

    Returns:
      A :class:`TuneResult`; ``best`` carries ``kernel`` (the q names),
      ``weights``, ``sigma``, ``lam_unscaled`` — serving-ready via
      ``make_krr_predict_fn_from_config`` — ``best_w0`` the winner's
      fold-averaged warm start, and ``trace`` the per-candidate audit trail.
    """
    from repro.core.multikernel import canonical_kernels

    if kernels is None:
        if not isinstance(problem.kernel, tuple):
            raise ValueError(
                "tune_multikernel needs kernels=(...) or a problem whose "
                f"kernel is a tuple; got kernel={problem.kernel!r}"
            )
        kernels = problem.kernel
    kernels, _, _ = canonical_kernels(kernels, 1.0, None)
    q = len(kernels)
    _common_validation(
        problem, sigmas, lams, folds, strategy, mesh, halving_eta,
        sigma_continuation,
    )
    if policy is None:
        policy = "random"
    if policy == "grid":
        raise ValueError(
            "policy='grid' does not apply to the multi-kernel weight axis "
            "(it is sampled, not gridded); use policy='random' or "
            "'halving', or pass explicit weights= rows"
        )
    resolved = _resolve_policy(policy, None, strategy, halving_eta)

    rng = np.random.default_rng(seed)
    w_cands = _weight_candidates(q, n_weight_samples, weights, dirichlet_alpha, rng)
    # a sigma candidate may be one shared bandwidth (scalar) or a per-kernel
    # bandwidth vector of length q (canon_sigma keeps both hashable)
    canon_sigmas = tuple(canon_sigma(s) for s in sigmas)
    for s in canon_sigmas:
        if isinstance(s, tuple) and len(s) != q:
            raise ValueError(
                f"per-kernel sigma candidate {s} has {len(s)} entries for "
                f"{q} kernels"
            )
    space = TuneSpace(
        sigmas=canon_sigmas,
        lams=tuple(float(lv) for lv in lams),
        weight_samples=w_cands,
    )
    # the problem restated as the multi-kernel combination being searched
    mk_problem = dataclasses.replace(
        problem, kernel=kernels, sigma=1.0, weights=None
    )
    with as_telemetry(telemetry).span(
        "tune/search-multikernel", n=problem.n, folds=folds, q=q,
        policy=resolved.name, strategy=strategy,
    ):
        return run_search(
            problem, mk_problem, space, resolved,
            folds=folds, strategy=strategy, rank=rank, max_iters=max_iters,
            tol=tol, seed=seed, warm_start=warm_start,
            sigma_continuation=sigma_continuation, mesh=mesh,
            extra_info={
                "q": q,
                "kernels": list(kernels),
                "weight_samples": int(w_cands.shape[0]),
            },
            telemetry=telemetry,
        )
