"""Tile-sharing hyperparameter tuning: policy-driven (sigma[, weight], lam)
search with k-fold CV.

ASkotch's headline results all sit behind a (kernel, sigma, lam) choice;
this package is the machinery that makes it, split into two layers
(docs/tuning.md):

  * **Engine** (``engine.py``) — one stacked blocked-CG per sigma group.
    Folds are column masks, lambdas are per-column diagonal shifts, one
    Nystrom sketch per sigma preconditions and warm-starts every column
    (Diaz et al. 2023's shift-invariant observation), and multi-kernel
    weight candidates are per-column weight vectors on the fused
    multi-kernel matvec.  The single-kernel path is the q = 1 degenerate
    case of the multi-kernel one — one code path for both.
  * **Policies** (``policies.py``) — ``GridSearch`` / ``RandomSearch``
    (reproduce the classic sweeps exactly) and ``SuccessiveHalving``
    (prunes losing candidates at rungs MID-SOLVE via ``blocked_cg``'s
    external column freezing, so the stacked solve ends when the survivors
    converge).  ``sigma_continuation=`` additionally seeds each sigma
    group's sketch and iterate block from the previous group's result.

So for s sigmas, l lambdas, k folds, and t one-vs-all heads, the whole sweep
runs s stacked solves over ``l*k*t`` columns each: total kernel-tile work is
~s solves' worth instead of the naive ``s*l*k`` (``benchmarks/
bench_tuning.py`` measures it, and measures halving below grid; ``TuneResult.
sweeps`` carries the count).

Quickstart (the full walkthrough lives in docs/tuning.md):

>>> import numpy as np
>>> import jax.numpy as jnp
>>> from repro.core.krr import KRRProblem
>>> from repro.core.tune import tune
>>> r = np.random.default_rng(0)
>>> x = jnp.asarray(r.standard_normal((64, 3)).astype(np.float32))
>>> y = jnp.sin(2.0 * x[:, 0]) + 0.1 * x[:, 1]
>>> res = tune(KRRProblem(x=x, y=y), sigmas=(0.5, 2.0),
...            lams=(1e-3, 1e-2, 1e-1), folds=3, rank=16, max_iters=60, seed=0)
>>> sorted(res.best)
['backend', 'cv_mse', 'folds', 'kernel', 'lam_unscaled', 'precision', 'sigma']
>>> res.best["sigma"] in (0.5, 2.0) and res.best["lam_unscaled"] in (1e-3, 1e-2, 1e-1)
True
>>> len(res.records)  # one record per (sigma, lam) candidate
6
>>> res.sweeps < res.info["naive_sweep_estimate"]  # shared < the l*k loop
True
>>> len(res.trace) == len(res.records)  # the audit trail rides along
True

The same entry points drive successive halving and sigma-continuation:

>>> res_h = tune(KRRProblem(x=x, y=y), sigmas=(0.5, 2.0),
...              lams=(1e-3, 1e-2, 1e-1), folds=3, rank=16, max_iters=60,
...              seed=0, policy="halving", sigma_continuation=True)
>>> res_h.search
'halving'
"""

from repro.core.tune.api import (
    SEARCHES,
    STRATEGIES,
    TuneResult,
    apply_best,
    run_search,
    tune,
    tune_multikernel,
)
from repro.core.tune.engine import (
    Continuation,
    GroupResult,
    SigmaGroup,
    SweepCounter,
    solve_sigma_group,
)
from repro.core.tune.policies import (
    POLICIES,
    GridSearch,
    RandomSearch,
    SearchPolicy,
    SuccessiveHalving,
    TuneSpace,
    make_policy,
)

__all__ = [
    "Continuation",
    "GridSearch",
    "GroupResult",
    "POLICIES",
    "RandomSearch",
    "SEARCHES",
    "STRATEGIES",
    "SearchPolicy",
    "SigmaGroup",
    "SuccessiveHalving",
    "SweepCounter",
    "TuneResult",
    "TuneSpace",
    "apply_best",
    "make_policy",
    "run_search",
    "solve_sigma_group",
    "tune",
    "tune_multikernel",
]
