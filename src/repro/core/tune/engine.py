"""The tuning ENGINE: one stacked blocked-CG per sigma group.

This module owns the mechanics every search policy shares (docs/tuning.md):
fold masks, stacked-column assembly, the per-sigma Nystrom sketch with
lam-damped preconditioning, sweep accounting, and CV scoring.  Policies
(``core/tune/policies.py``) decide WHICH candidates exist and WHEN to stop
paying for them; the engine decides how cheaply a sigma group's worth of
candidates can be solved together.

The single-kernel path is the q = 1 degenerate case of the multi-kernel one:
a :class:`SigmaGroup` without ``weight_samples`` solves the same stacked
system with an implicit weight matrix ``[[1.0]]`` — one code path, so the
``(lam, fold, head)`` and ``(weight, lam, fold, head)`` sweeps can never
drift apart again (they were near-duplicate functions before PR 5).

Column layout of one group's stacked solve (head innermost):

    candidate c = m * len(lam_list) + lam_i          (m = weight sample)
    column   of (c, fold_j, head_h) = (c * k + j) * t + h
    A_col v  = M_j (sum_i W[m, i] K_i) M_j v + lam_c v

Mid-solve rungs: the engine wires a policy's prune decision into
``blocked_cg``'s external freeze hook — at each rung iteration it spends ONE
kernel sweep scoring every candidate from the current block, hands the
scores to the policy, and freezes the columns of the candidates the policy
prunes.  Sigma-continuation: a group may seed its sketch test matrix from
the previous group's Nystrom basis and its iterate block from the previous
group's solution (``Continuation``) — kernel matrices at nearby sigmas share
eigenstructure (the same observation behind Diaz et al.'s shift-invariant
preconditioning), so the previous winner is a far better start than zero.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked_cg import blocked_cg
from repro.core.krr import KRRProblem, scaled_lam
from repro.core.nystrom import nystrom_from_sketch
from repro.core.operator import as_multirhs
from repro.obs.metrics import counter as _obs_counter

__all__ = [
    "Continuation",
    "GroupResult",
    "SigmaGroup",
    "SweepCounter",
    "naive_candidate_solve",
    "make_folds",
    "fold_avg_w0",
    "operator_for",
    "place",
    "score_fold",
    "solve_sigma_group",
]


@dataclasses.dataclass
class SweepCounter:
    """Kernel-pair-evaluation tally.

    ``pairs`` counts (row, col) kernel evaluations touched by matvec work; a
    multi-RHS matvec touches the same tiles as a single-RHS one, so the
    natural unit is a *sweep* = one full pass over the n x n tile grid
    (``pairs / n**2``).  This is the cost model docs/tuning.md accounts in.

    Every ``add_matvec`` also feeds the identical quantity into the global
    ``repro_kernel_pairs_total`` telemetry counter (``repro.obs.metrics``),
    so per-search accounting (``TuneResult.sweeps`` — unchanged, the local
    ``pairs`` float) and the process-wide metric can never disagree.
    """

    pairs: float = 0.0

    def add_matvec(self, rows: int, cols: int, count: int = 1) -> None:
        """Tally ``count`` matvec passes over a (rows, cols) tile grid."""
        q = float(rows) * float(cols) * count
        self.pairs += q
        _obs_counter(
            "repro_kernel_pairs_total",
            help="kernel pair evaluations tallied by tuning sweep accounting",
        ).inc(q)

    def sweeps(self, n: int) -> float:
        """Pair tally in full-K sweep units (``pairs / n**2``)."""
        return self.pairs / float(n) ** 2


@dataclasses.dataclass(frozen=True)
class SigmaGroup:
    """One sigma's worth of candidates — the unit of stacked solving.

    ``weight_samples`` is the (M, q) weight-candidate matrix of a
    multi-kernel search, or None for the single-kernel path (the q = 1
    degenerate case: an implicit ``[[1.0]]``).
    """

    sigma: float
    lam_list: tuple[float, ...]
    weight_samples: Any = None  # np.ndarray (M, q) | None

    @property
    def n_weight(self) -> int:
        return 1 if self.weight_samples is None else int(self.weight_samples.shape[0])

    @property
    def n_candidates(self) -> int:
        return self.n_weight * len(self.lam_list)

    def candidate_params(self) -> list[dict[str, Any]]:
        """Per-candidate parameter dicts in column-block order
        (weight outer, lam inner)."""
        out = []
        for m in range(self.n_weight):
            for lam_u in self.lam_list:
                p: dict[str, Any] = {"sigma": self.sigma, "lam_unscaled": lam_u}
                if self.weight_samples is not None:
                    p["weights"] = [float(w) for w in self.weight_samples[m]]
                out.append(p)
        return out


@dataclasses.dataclass
class Continuation:
    """Sigma-continuation state handed from one group's solve to the next.

    ``omega`` is the previous group's rank-r Nystrom basis (orthonormal —
    reused as the next sketch's test matrix instead of a fresh Gaussian);
    ``x0`` the previous solution block, valid as a warm start when the next
    group has the same column layout (``layout`` guards it).
    """

    omega: np.ndarray  # (n, r)
    x0: np.ndarray  # (n, C)
    layout: tuple  # (lam_list, weight-matrix bytes) identity of the columns


@dataclasses.dataclass
class GroupResult:
    """Everything one stacked solve produced, host-side."""

    group: SigmaGroup
    preds: np.ndarray  # (n, C) — K @ W, scores every candidate
    w_cols: np.ndarray  # (n, C) — the solution block (mask-supported)
    iters: int
    rung_history: list[dict]  # per rung: {"iter", "cv_mse": (n_cand,) list}
    pruned_at_rung: dict[int, int]  # candidate idx -> rung index
    continuation: "Continuation | None"  # only when asked for (host copies)


def _group_layout(group: SigmaGroup) -> tuple:
    w = group.weight_samples
    return (
        tuple(group.lam_list),
        None if w is None else np.asarray(w, np.float32).tobytes(),
    )


# ---------------------------------------------------------------------------
# shared helpers (folds, placement, scoring)
# ---------------------------------------------------------------------------


def make_folds(n: int, folds: int, rng: np.random.Generator) -> list[np.ndarray]:
    """Shuffled index sets of the k validation folds (near-equal sizes)."""
    perm = rng.permutation(n)
    return [np.sort(f) for f in np.array_split(perm, folds)]


def canon_sigma(sigma) -> float | tuple[float, ...]:
    """Hashable canonical form of a sigma candidate: ``float`` for a scalar,
    tuple of floats for a per-kernel bandwidth vector (dict keys, group
    identity, and ``dataclasses.replace`` all use this spelling)."""
    if isinstance(sigma, (tuple, list)):
        return tuple(float(s) for s in sigma)
    return float(sigma)


def operator_for(problem: KRRProblem, sigma, mesh, weights=None) -> Any:
    """Operator for one sigma candidate — local or mesh-bound; ``weights``
    re-weights a multi-kernel problem's combination (naive reference loop).
    ``sigma`` may be a scalar or a per-kernel tuple (multi-kernel problems);
    a precomputed-Gram problem has no sigma axis, so its operator is
    returned unchanged."""
    if mesh is None:
        if problem.kernel == "precomputed":
            return problem.op
        rep: dict[str, Any] = {"sigma": canon_sigma(sigma)}
        if weights is not None:
            rep["weights"] = tuple(float(w) for w in weights)
        return dataclasses.replace(problem.op, **rep)
    from repro.distributed.sharded_operator import ShardedKernelOperator

    return ShardedKernelOperator.bind(
        mesh, problem.x, kernel=problem.kernel, sigma=canon_sigma(sigma),
        backend=problem.backend, weights=weights,
        precision=problem.precision,
    )


def place(op: Any, arr: np.ndarray) -> jax.Array:
    """Device-put row-aligned host data, row-sharded when ``op`` is mesh-aware."""
    a = jnp.asarray(arr)
    if hasattr(op, "sharding"):
        return jax.device_put(a, op.sharding(a.ndim))
    return a


def score_fold(pred: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """(mse, top1-accuracy) of validation predictions vs targets, all heads."""
    mse = float(np.mean((pred - truth) ** 2))
    if truth.ndim == 2 and truth.shape[1] > 1:
        acc = float(np.mean(pred.argmax(axis=1) == truth.argmax(axis=1)))
    else:
        acc = float(np.mean(np.sign(pred) == np.sign(truth)))
    return mse, acc


def fold_avg_w0(
    w_cols: np.ndarray, col0: int, folds: int, t: int, squeeze: bool
) -> np.ndarray:
    """Mask-supported mean of one candidate's k fold solutions.

    ``w_cols`` is the stacked solve's (n, C) solution block; the candidate's
    fold-j/head-h column sits at ``col0 + j*t + h``.  Off-mask rows of each
    column are exactly zero (the masked system decouples to ``lam w = 0``),
    and every row is on-mask in exactly ``k - 1`` folds, so the mean over its
    supporting folds is the column sum divided by ``k - 1``.
    """
    block = w_cols[:, col0 : col0 + folds * t]
    w0 = block.reshape(block.shape[0], folds, t).sum(axis=1) / max(folds - 1, 1)
    return w0[:, 0] if squeeze else w0


def candidate_scores(
    preds: np.ndarray,
    y2: np.ndarray,
    val_folds: list[np.ndarray],
    n_candidates: int,
) -> np.ndarray:
    """(n_cand,) mean CV validation MSE per candidate from a (n, C) pred
    block laid out candidate-major (k*t columns per candidate)."""
    k = len(val_folds)
    t = y2.shape[1]
    scores = np.empty(n_candidates, np.float64)
    for c in range(n_candidates):
        col0 = c * k * t
        fold_mse = [
            score_fold(preds[val, col0 + j * t : col0 + (j + 1) * t], y2[val])[0]
            for j, val in enumerate(val_folds)
        ]
        scores[c] = float(np.mean(fold_mse))
    return scores


# ---------------------------------------------------------------------------
# the unified stacked engine — one solve per sigma group
# ---------------------------------------------------------------------------


def solve_sigma_group(
    op: Any,
    y2: np.ndarray,
    group: SigmaGroup,
    val_folds: list[np.ndarray],
    *,
    rank: int,
    max_iters: int,
    tol: float,
    seed: int,
    warm_start: bool,
    counter: SweepCounter,
    rung_iters: Sequence[int] = (),
    prune_fn: Callable[[int, int, np.ndarray, np.ndarray], "np.ndarray | None"]
    | None = None,
    continuation: Continuation | None = None,
    want_continuation: bool = False,
    recorder=None,
) -> GroupResult:
    """Solve ALL (weight, lam, fold, head) systems of one sigma group in ONE
    stacked blocked-CG.

    Column c's operator is ``M_j (sum_i W[m, i] K_i) M_j + lam_c I``; the
    single-kernel path is the same code with an implicit W = [[1.0]] (the
    operator's own ``matvec``).  The per-column weight vector rides the fused
    multi-kernel matvec (``op.matvec_cols``), so kernel-tile work per
    iteration is ONE data sweep no matter how many candidates are in flight.
    The per-kernel Nystrom sketches come from one ``sketch_components``
    sweep (plain ``sketch`` at q = 1); candidate m's preconditioner and warm
    start are its weighted sketch combination — zero extra sweeps (Diaz et
    al.'s shift-invariant observation, extended along the weight axis).

    ``rung_iters`` + ``prune_fn`` wire a policy's mid-solve pruning into
    ``blocked_cg``'s external freeze hook: at each rung the engine spends one
    kernel sweep scoring every candidate from the current block, calls
    ``prune_fn(rung_index, it, scores, active)`` and freezes the columns of
    pruned candidates.  ``continuation`` seeds the sketch test matrix and the
    iterate block from the previous sigma group (see :class:`Continuation`);
    ``want_continuation`` asks for this group's own continuation state in
    the result (a host copy of the Nystrom basis — skipped when the caller
    will not use it).

    Returns a :class:`GroupResult`; ``preds`` (n, C) = K @ W host-side — row
    i of a fold-j column is the fold-j model's prediction at x[i] (exact at
    validation rows, where w is zero by the mask).

    ``recorder`` (a ``repro.obs.trace.TraceRecorder``) streams the stacked
    CG's per-iteration residuals as canonical trace events when telemetry is
    enabled.
    """
    n, t = y2.shape
    k = len(val_folds)
    l = len(group.lam_list)
    m_w = group.n_weight
    c_m = l * k * t  # columns per weight sample
    cand_cols = k * t  # columns per candidate

    fold_mask = np.ones((n, k), np.float32)
    for j, val in enumerate(val_folds):
        fold_mask[val, j] = 0.0
    n_train = [n - len(val) for val in val_folds]

    # columns: weight outer, then lam, fold, head (head innermost)
    fh_mask = np.repeat(fold_mask, t, axis=1)  # (n, k*t)
    fh_rhs = (fold_mask[:, :, None] * y2[:, None, :]).reshape(n, k * t)
    masks_cols = np.tile(fh_mask, (1, m_w * l))
    rhs = np.tile(fh_rhs, (1, m_w * l))
    lam_block = np.repeat(
        np.asarray(
            [scaled_lam(n_train[j], lam_u) for lam_u in group.lam_list
             for j in range(k)],
            np.float32,
        ),
        t,
    )  # (l*k*t,)
    lam_cols = np.tile(lam_block, m_w)  # (C,)

    masks_d = place(op, masks_cols)
    rhs_d = place(op, rhs)
    lam_d = jnp.asarray(lam_cols)

    # -- sketch: ONE data sweep (q per-kernel sketches; q = 1 degenerates to
    # the plain operator sketch).  Sigma-continuation reuses the previous
    # group's Nystrom basis as the test matrix (already orthonormal).
    cont_omega = None
    if continuation is not None and continuation.omega.shape == (n, rank):
        cont_omega = continuation.omega
    if cont_omega is not None:
        omega = place(op, np.asarray(cont_omega, np.float32))
    else:
        rng = np.random.default_rng(seed)
        omega = place(op, rng.standard_normal((n, rank)).astype(np.float32))
        omega, _ = jnp.linalg.qr(omega)
    if group.weight_samples is None:
        y_stack = op.sketch(omega)[None]  # (1, n, r)
        w_mat = np.ones((1, 1), np.float32)
    else:
        y_stack = op.sketch_components(omega)  # (q, n, r)
        w_mat = np.asarray(group.weight_samples, np.float32)
    counter.add_matvec(n, n)

    # per weight sample: Nystrom factors of K_w from the combined sketch
    us, lams_ny = [], []
    for m in range(m_w):
        w_m = jnp.asarray(w_mat[m])
        f_m = nystrom_from_sketch(
            jnp.tensordot(w_m, y_stack, axes=1), omega,
            float(w_mat[m].sum()) * op.trace_est(),
        )
        us.append(f_m.u)
        lams_ny.append(f_m.lam)
    u_st = jnp.stack(us)  # (M, n, r)
    lam_st = jnp.stack(lams_ny)  # (M, r)

    lam3 = lam_d.reshape(m_w, c_m)  # (M, Cm) per-column shifts
    rho = lam3 + lam_st[:, -1:]  # damped rho per column
    coeff = (lam_st[:, -1:][:, :, None] + rho[:, None, :]) / (
        lam_st[:, :, None] + rho[:, None, :]
    )  # (M, r, Cm)

    if group.weight_samples is None:
        apply_k = op.matvec
    else:
        wc_d = jnp.asarray(np.repeat(w_mat.T, c_m, axis=1))  # (q, C)

        def apply_k(v: jax.Array) -> jax.Array:
            return op.matvec_cols(v, wc_d)

    @jax.jit
    def matvec(v: jax.Array) -> jax.Array:
        # one fused kernel pass over ALL columns; the per-column weight
        # vector, mask and shift are elementwise
        return masks_d * apply_k(masks_d * v) + lam_d * v

    @jax.jit
    def pinv(r_blk: jax.Array) -> jax.Array:
        # residuals are mask-supported by construction, so masking the output
        # makes this exactly the restricted (SPD) Nystrom preconditioner
        r3 = r_blk.reshape(n, m_w, c_m)
        utv = jnp.einsum("mnr,nmc->mrc", u_st, r3)
        uutv = jnp.einsum("mnr,mrc->nmc", u_st, utv)
        out3 = jnp.einsum("mnr,mrc->nmc", u_st, coeff * utv) + (r3 - uutv)
        return masks_d * out3.reshape(n, m_w * c_m)

    x0 = None
    if continuation is not None and continuation.layout == _group_layout(group):
        # seed the whole block from the previous sigma's solution — for
        # nearby sigmas the minimizers are close, so the initial residual is
        # far below the zero (or Woodbury) start's
        x0 = place(op, np.asarray(continuation.x0, np.float32))
    elif warm_start:

        @jax.jit
        def _warm(rhs_in: jax.Array) -> jax.Array:
            # per-column Woodbury apply of candidate m's Nystrom inverse
            # (Eq. (15)), per-column rho = lam_c — zero extra kernel sweeps
            rhs3 = rhs_in.reshape(n, m_w, c_m)
            utg = jnp.einsum("mnr,nmc->mrc", u_st, rhs3)
            core = utg / (lam_st[:, :, None] + lam3[:, None, :])
            out3 = jnp.einsum("mnr,mrc->nmc", u_st, core) + (
                rhs3 - jnp.einsum("mnr,mrc->nmc", u_st, utg)
            ) / lam3[None, :, :]
            return masks_d * out3.reshape(n, m_w * c_m)

        x0 = _warm(rhs_d)

    # -- mid-solve rungs: score -> policy prune -> external column freeze
    n_cand = group.n_candidates
    rung_history: list[dict] = []
    pruned_at: dict[int, int] = {}
    active = np.ones(n_cand, bool)

    def _freeze_cb(it, x, rel_heads, frozen):
        preds_now = np.asarray(apply_k(x))  # ONE sweep scores every candidate
        counter.add_matvec(n, n)
        scores = candidate_scores(preds_now, y2, val_folds, n_cand)
        rung_index = len(rung_history)
        rung_history.append(
            {"iter": int(it), "cv_mse": [float(s) for s in scores]}
        )
        if prune_fn is None:
            return None
        prune = prune_fn(rung_index, int(it), scores, active.copy())
        if prune is None:
            return None
        prune = np.asarray(prune, bool) & active
        if not prune.any():
            return None
        for c in np.nonzero(prune)[0]:
            pruned_at[int(c)] = rung_index
        active[prune] = False
        return np.repeat(prune, cand_cols)

    res = blocked_cg(
        matvec, rhs_d, pinv, x0=x0, max_iters=max_iters, tol=tol,
        freeze_at=tuple(rung_iters) if rung_iters else None,
        freeze_callback=_freeze_cb if rung_iters else None,
        recorder=recorder,
    )
    counter.add_matvec(n, n, res.iters + (1 if x0 is not None else 0))

    preds = apply_k(res.x)  # scoring: ONE more sweep serves every candidate
    counter.add_matvec(n, n)
    w_cols = np.asarray(res.x)
    return GroupResult(
        group=group,
        preds=np.asarray(preds),
        w_cols=w_cols,
        iters=res.iters,
        rung_history=rung_history,
        pruned_at_rung=pruned_at,
        continuation=(
            Continuation(
                omega=np.asarray(us[0]), x0=w_cols, layout=_group_layout(group)
            )
            if want_continuation
            else None
        ),
    )


# ---------------------------------------------------------------------------
# naive reference engine — one solve per (sigma[, weights], lam, fold)
# ---------------------------------------------------------------------------


def naive_candidate_solve(
    problem: KRRProblem,
    sigma: float,
    lam_u: float,
    val_folds: list[np.ndarray],
    *,
    rank: int,
    max_iters: int,
    tol: float,
    seed: int,
    counter: SweepCounter,
    mesh=None,
    weights=None,
) -> tuple[list[np.ndarray], list[int]]:
    """The loop the shared path replaces: an independent Nystrom-PCG solve
    per fold, each with its own sketch.  Returns per-fold validation
    predictions (len(val), t) and the per-fold CG iteration counts (the
    audit trail records the real cost, not the budget).  ``weights`` makes
    the candidate a weighted kernel combination (the multi-kernel naive
    reference)."""
    n = problem.n
    x_np = np.asarray(problem.x)
    y2, _ = as_multirhs(problem.y)
    y_np = np.asarray(y2)
    base_op = operator_for(problem, sigma, mesh, weights=weights)
    out = []
    fold_iters: list[int] = []
    for j, val in enumerate(val_folds):
        train = np.setdiff1d(np.arange(n), val)
        op_f = base_op.restrict(jnp.asarray(train))
        n_f = len(train)
        lam_f = scaled_lam(n_f, lam_u)
        f = _naive_sketch(op_f, min(rank, n_f), seed)
        counter.add_matvec(n_f, n_f)  # the per-candidate sketch is NOT shared
        rho = lam_f + f.lam[-1]
        coeff = (f.lam[-1] + rho) / (f.lam + rho)

        @jax.jit
        def matvec(v, op_f=op_f, lam_f=lam_f):
            return op_f.matvec(v) + lam_f * v

        @jax.jit
        def pinv(r_blk, f=f, coeff=coeff):
            utv = f.u.T @ r_blk
            return f.u @ (coeff[:, None] * utv) + (r_blk - f.u @ utv)

        rhs = jnp.asarray(y_np[train])
        res = blocked_cg(matvec, rhs, pinv, max_iters=max_iters, tol=tol)
        counter.add_matvec(n_f, n_f, res.iters)
        fold_iters.append(res.iters)
        pred_val = op_f.row_block_matvec(jnp.asarray(x_np[val]), res.x)
        counter.add_matvec(len(val), n_f)
        out.append(np.asarray(pred_val))
    return out, fold_iters


def _naive_sketch(op: Any, rank: int, seed: int):
    """Per-fold rank-r Nystrom sketch for the naive reference loop."""
    rng = np.random.default_rng(seed)
    omega = place(op, rng.standard_normal((op.n, rank)).astype(np.float32))
    omega, _ = jnp.linalg.qr(omega)
    sketch = op.sketch(omega)
    return nystrom_from_sketch(sketch, omega, op.trace_est())
