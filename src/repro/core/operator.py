"""KernelOperator — the single owner of (kernel, sigma, backend, chunking).

Every solver used to re-thread the ``(kernel, sigma, backend)`` triple into
each ``ops.*`` call; this layer centralizes that plumbing (docs/
architecture.md, layer 2).
An operator is a frozen view over a row set ``x`` exposing the four
primitives the whole stack is built from:

  * ``matvec(v)``            — K(x, x) @ v, fused/streamed, never forms K.
  * ``row_block_matvec(a, v)`` — K(a, x) @ v for an arbitrary row block
                               ``a`` (ASkotch's O(n b d) hot spot, Falkon's
                               K_nm products, prediction).
  * ``block(a, b)``          — materialize a K(a, b) tile (small blocks only).
  * ``trace_est()``          — tr K(x, x); exact (= n) for the unit-diagonal
                               shift-invariant kernels in the testbed.

Everything is multi-RHS by construction: ``v`` may be ``(n,)`` or ``(n, t)``
and a single fused kernel-tile pass serves all ``t`` columns — this is what
makes one-vs-all solves cost one kernel sweep per iteration instead of ``t``.

``restrict(idx)`` / ``with_points(xm)`` derive operators over sub-row-sets
(inducing centers, BLESS dictionaries, sampled blocks) without re-threading
configuration.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class KernelOperator:
    """Linear-operator view of K = K(x, x) for a fixed kernel configuration."""

    x: jax.Array  # (n, d) row points
    kernel: str = "rbf"
    sigma: float = 1.0
    backend: str = "auto"
    chunk_a: int = 4096
    chunk_b: int = 8192
    precision: str = "f32"  # tile-compute policy: "f32" | "bf16"

    @property
    def n(self) -> int:
        """Number of rows (training points) the operator spans."""
        return self.x.shape[0]

    @property
    def d(self) -> int:
        """Feature dimension of the row points."""
        return self.x.shape[1]

    @property
    def shape(self) -> tuple[int, int]:
        """(n, n) — the shape of the kernel matrix K(x, x) this operator
        applies without materializing."""
        return (self.n, self.n)

    # -- derived operators --------------------------------------------------

    def with_points(self, x_new: jax.Array) -> "KernelOperator":
        """Same kernel configuration over a different row set."""
        return dataclasses.replace(self, x=x_new)

    def restrict(self, idx: jax.Array) -> "KernelOperator":
        """Operator over the sub-row-set ``x[idx]`` (centers, dictionaries)."""
        return self.with_points(jnp.take(self.x, idx, axis=0))

    # -- the four primitives -------------------------------------------------

    def matvec(self, v: jax.Array) -> jax.Array:
        """K(x, x) @ v; v: (n,) or (n, t) -> same leading-dim shape."""
        return self.row_block_matvec(self.x, v)

    def row_block_matvec(self, a: jax.Array, v: jax.Array) -> jax.Array:
        """K(a, x) @ v streamed over x; a: (b, d), v: (n,)|(n, t)."""
        return ops.kernel_matvec(
            a, self.x, v, kernel=self.kernel, sigma=self.sigma,
            backend=self.backend, chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            precision=self.precision,
        )

    def block(self, a: jax.Array, b: jax.Array | None = None) -> jax.Array:
        """Materialize K(a, b) (b defaults to a).  Small/medium tiles only."""
        b = a if b is None else b
        return ops.kernel_block(
            a, b, kernel=self.kernel, sigma=self.sigma, backend=self.backend,
            precision=self.precision,
        )

    def block_idx(self, idx: jax.Array) -> jax.Array:
        """K_BB for a row-index block (Skotch/ASkotch step)."""
        xb = jnp.take(self.x, idx, axis=0)
        return self.block(xb, xb)

    def trace_est(self) -> jax.Array:
        """tr K.  The testbed kernels (rbf/laplacian/matern52) all have
        k(x, x) = 1, so the trace is exactly n."""
        return jnp.float32(self.n)

    # -- composites shared by several solvers --------------------------------

    def k_lam_matvec(self, v: jax.Array, lam: jax.Array | float) -> jax.Array:
        """(K + lam I) @ v."""
        return self.matvec(v) + lam * v

    def sketch(self, omega: jax.Array) -> jax.Array:
        """K @ omega for a (n, r) test matrix — Nystrom sketches over the
        full kernel without materializing it."""
        return self.matvec(omega)


def as_multirhs(v: jax.Array) -> tuple[jax.Array, bool]:
    """Canonicalize a RHS/iterate to (n, t); returns (v2d, was_1d).

    The whole solver stack runs blocked over (n, t) internally; a 1-D input
    is the t = 1 special case and is squeezed back on the way out.
    """
    if v.ndim == 1:
        return v[:, None], True
    return v, False


def maybe_squeeze(v: jax.Array, was_1d: bool) -> jax.Array:
    """Undo :func:`as_multirhs` on outputs."""
    return v[:, 0] if was_1d else v
