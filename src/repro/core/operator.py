"""KernelOperator — the single owner of (kernel, sigma, backend, chunking).

Every solver used to re-thread the ``(kernel, sigma, backend)`` triple into
each ``ops.*`` call; this layer centralizes that plumbing (docs/
architecture.md, layer 2).
An operator is a frozen view over a row set ``x`` exposing the four
primitives the whole stack is built from:

  * ``matvec(v)``            — K(x, x) @ v, fused/streamed, never forms K.
  * ``row_block_matvec(a, v)`` — K(a, x) @ v for an arbitrary row block
                               ``a`` (ASkotch's O(n b d) hot spot, Falkon's
                               K_nm products, prediction).
  * ``block(a, b)``          — materialize a K(a, b) tile (small blocks only).
  * ``trace_est()``          — tr K(x, x); exact across the zoo via
                               ``core.kernels.kernel_diag`` (= n for the
                               unit-diagonal shift-invariant kernels).

:class:`PrecomputedKernelOperator` implements the same contract over a
user-supplied Gram matrix (``kernel="precomputed"``) — no kernel evaluations
at all, which also makes it the cheapest oracle when testing new kernels.

Everything is multi-RHS by construction: ``v`` may be ``(n,)`` or ``(n, t)``
and a single fused kernel-tile pass serves all ``t`` columns — this is what
makes one-vs-all solves cost one kernel sweep per iteration instead of ``t``.

``restrict(idx)`` / ``with_points(xm)`` derive operators over sub-row-sets
(inducing centers, BLESS dictionaries, sampled blocks) without re-threading
configuration.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.kernels import kernel_diag
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class KernelOperator:
    """Linear-operator view of K = K(x, x) for a fixed kernel configuration."""

    x: jax.Array  # (n, d) row points
    kernel: str = "rbf"
    sigma: float = 1.0
    backend: str = "auto"
    chunk_a: int = 4096
    chunk_b: int = 8192
    precision: str = "f32"  # tile-compute policy: "f32" | "bf16"

    @property
    def n(self) -> int:
        """Number of rows (training points) the operator spans."""
        return self.x.shape[0]

    @property
    def d(self) -> int:
        """Feature dimension of the row points."""
        return self.x.shape[1]

    @property
    def shape(self) -> tuple[int, int]:
        """(n, n) — the shape of the kernel matrix K(x, x) this operator
        applies without materializing."""
        return (self.n, self.n)

    # -- derived operators --------------------------------------------------

    def with_points(self, x_new: jax.Array) -> "KernelOperator":
        """Same kernel configuration over a different row set."""
        return dataclasses.replace(self, x=x_new)

    def restrict(self, idx: jax.Array) -> "KernelOperator":
        """Operator over the sub-row-set ``x[idx]`` (centers, dictionaries)."""
        return self.with_points(jnp.take(self.x, idx, axis=0))

    # -- the four primitives -------------------------------------------------

    def matvec(self, v: jax.Array) -> jax.Array:
        """K(x, x) @ v; v: (n,) or (n, t) -> same leading-dim shape."""
        return self.row_block_matvec(self.x, v)

    def row_block_matvec(self, a: jax.Array, v: jax.Array) -> jax.Array:
        """K(a, x) @ v streamed over x; a: (b, d), v: (n,)|(n, t)."""
        return ops.kernel_matvec(
            a, self.x, v, kernel=self.kernel, sigma=self.sigma,
            backend=self.backend, chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            precision=self.precision,
        )

    def block(self, a: jax.Array, b: jax.Array | None = None) -> jax.Array:
        """Materialize K(a, b) (b defaults to a).  Small/medium tiles only."""
        b = a if b is None else b
        return ops.kernel_block(
            a, b, kernel=self.kernel, sigma=self.sigma, backend=self.backend,
            precision=self.precision,
        )

    def block_idx(self, idx: jax.Array) -> jax.Array:
        """K_BB for a row-index block (Skotch/ASkotch step)."""
        xb = jnp.take(self.x, idx, axis=0)
        return self.block(xb, xb)

    def trace_est(self) -> jax.Array:
        """tr K, exact: sum of ``kernel_diag``.  The shift-invariant kernels
        and cosine have k(x, x) = 1 (trace exactly n); the dot-product family
        has a ||x||^2-dependent diagonal."""
        return jnp.sum(kernel_diag(self.kernel, self.x, self.sigma))

    # -- composites shared by several solvers --------------------------------

    def k_lam_matvec(self, v: jax.Array, lam: jax.Array | float) -> jax.Array:
        """(K + lam I) @ v."""
        return self.matvec(v) + lam * v

    def sketch(self, omega: jax.Array) -> jax.Array:
        """K @ omega for a (n, r) test matrix — Nystrom sketches over the
        full kernel without materializing it."""
        return self.matvec(omega)


@dataclasses.dataclass(frozen=True)
class PrecomputedKernelOperator:
    """The ``KernelOperator`` contract over a user-supplied Gram matrix.

    ``kernel="precomputed"`` — no kernel evaluations anywhere: every
    primitive is a gather/matmul over stored Gram entries, so a solve through
    this operator is bit-identical to the same solve through an in-memory
    kernel operator fed the identical Gram (the cheapest oracle for new
    kernels, and sklearn's ``kernel="precomputed"`` escape hatch).

    Representation — "widened rows": ``x`` is ``(n, n0 + 1)`` where row i is
    ``[K(point_i, original train set) | original index of point_i]``.  The
    trailing index column is what lets ``restrict``/``with_points`` (inducing
    centers, sampled blocks, CV folds) stay plain row slicing while
    ``block(a, b)`` recovers exact Gram entries: K(a_i, b_j) is simply
    ``a``'s stored profile evaluated at ``b_j``'s original index.  An f32
    index column is exact up to 2**24 rows — far beyond any Gram a user can
    materialize.  Raw (un-widened) row blocks of width n0 — e.g. the
    K(test, train) cross matrix at prediction time — are accepted too: their
    profiles already cover every original index.
    """

    x: jax.Array  # (n, n0 + 1) widened rows: [Gram profile | original index]
    backend: str = "auto"  # accepted for replace() compatibility; unused
    chunk_a: int = 4096
    chunk_b: int = 8192
    precision: str = "f32"

    kernel = "precomputed"

    @property
    def n(self) -> int:
        """Number of rows this operator currently spans (after restriction)."""
        return self.x.shape[0]

    @property
    def n0(self) -> int:
        """Number of columns in the original Gram (the full train-set size)."""
        return self.x.shape[1] - 1

    @property
    def d(self) -> int:
        """Width of a RAW row block callers feed in (= n0): prediction-time
        rows are K(test point, original train set) profiles."""
        return self.n0

    @property
    def shape(self) -> tuple[int, int]:
        """(n, n) — the restricted Gram this operator applies."""
        return (self.n, self.n)

    # -- derived operators ----------------------------------------------------

    def with_points(self, x_new: jax.Array) -> "PrecomputedKernelOperator":
        """Same Gram over a different widened row set (``restrict`` output,
        CV-fold row subsets, serving rebinds)."""
        return dataclasses.replace(self, x=x_new)

    def restrict(self, idx: jax.Array) -> "PrecomputedKernelOperator":
        """Operator over the sub-row-set ``x[idx]`` — plain row slicing; the
        trailing index column keeps Gram lookups exact."""
        return self.with_points(jnp.take(self.x, idx, axis=0))

    # -- the four primitives --------------------------------------------------

    def _profile(self, a: jax.Array) -> jax.Array:
        """Gram profile part of a row block: widened (b, n0+1) rows drop the
        index column, raw (b, n0) rows pass through."""
        if a.ndim != 2:
            raise ValueError(
                f"precomputed row block must be 2-D, got shape {a.shape}"
            )
        if a.shape[1] == self.n0 + 1:
            return a[:, :-1]
        if a.shape[1] == self.n0:
            return a
        raise ValueError(
            f"precomputed row block has {a.shape[1]} columns; expected "
            f"{self.n0} (raw Gram rows over the original train set) or "
            f"{self.n0 + 1} (widened rows)"
        )

    def _cols(self) -> jax.Array:
        """Original-train-set indices of this operator's rows."""
        return self.x[:, -1].astype(jnp.int32)

    def matvec(self, v: jax.Array) -> jax.Array:
        """K(x, x) @ v over the stored Gram; v: (n,) or (n, t)."""
        return self.row_block_matvec(self.x, v)

    def row_block_matvec(self, a: jax.Array, v: jax.Array) -> jax.Array:
        """K(a, x) @ v: gather ``a``'s profiles at this operator's original
        indices, one matmul.  ``a`` may be widened or raw (see class doc)."""
        v2, was_1d = as_multirhs(v)
        out = jnp.take(self._profile(a), self._cols(), axis=1) @ v2
        return maybe_squeeze(out, was_1d)

    def block(self, a: jax.Array, b: jax.Array | None = None) -> jax.Array:
        """Materialize K(a, b) from stored Gram entries (b defaults to a);
        ``b`` must carry its index column (widened)."""
        b = a if b is None else b
        if b.shape[1] != self.n0 + 1:
            raise ValueError(
                "precomputed block() needs widened rows for the column "
                f"operand (index column present); got width {b.shape[1]}"
            )
        cols = b[:, -1].astype(jnp.int32)
        return jnp.take(self._profile(a), cols, axis=1)

    def block_idx(self, idx: jax.Array) -> jax.Array:
        """K_BB for a row-index block (Skotch/ASkotch step)."""
        xb = jnp.take(self.x, idx, axis=0)
        return self.block(xb, xb)

    def trace_est(self) -> jax.Array:
        """tr K(x, x), exact: gather each row's own diagonal entry."""
        diag = jnp.take_along_axis(
            self.x[:, :-1], self._cols()[:, None], axis=1
        )[:, 0]
        return jnp.sum(diag.astype(jnp.float32))

    # -- composites shared by several solvers ---------------------------------

    def k_lam_matvec(self, v: jax.Array, lam: jax.Array | float) -> jax.Array:
        """(K + lam I) @ v."""
        return self.matvec(v) + lam * v

    def sketch(self, omega: jax.Array) -> jax.Array:
        """K @ omega for a (n, r) test matrix."""
        return self.matvec(omega)


def widen_gram(gram: jax.Array) -> jax.Array:
    """Attach the index column that turns a raw (n, n) Gram into
    :class:`PrecomputedKernelOperator` rows (idempotent on widened input)."""
    gram = jnp.asarray(gram)
    if gram.ndim != 2:
        raise ValueError(
            f"precomputed kernel expects a 2-D Gram matrix, got shape {gram.shape}"
        )
    n, c = gram.shape
    if c == n + 1:
        return gram  # already widened (replace() re-entry)
    if c != n:
        raise ValueError(
            f"precomputed Gram must be square, got shape {gram.shape}"
        )
    idx = jnp.arange(n, dtype=gram.dtype)[:, None]
    return jnp.concatenate([gram, idx], axis=1)


def as_multirhs(v: jax.Array) -> tuple[jax.Array, bool]:
    """Canonicalize a RHS/iterate to (n, t); returns (v2d, was_1d).

    The whole solver stack runs blocked over (n, t) internally; a 1-D input
    is the t = 1 special case and is squeezed back on the way out.
    """
    if v.ndim == 1:
        return v[:, None], True
    return v, False


def maybe_squeeze(v: jax.Array, was_1d: bool) -> jax.Array:
    """Undo :func:`as_multirhs` on outputs."""
    return v[:, 0] if was_1d else v
