"""Preconditioned conjugate gradient for full KRR (baseline, paper §4.1/§6).

Preconditioners:
  * "nystrom"    — rank-r Gaussian-Nystrom of the full K (Frangella et al.
                   2023), sketch computed with the fused streaming matvec;
                   supports the paper's "damped"/"regularization" rho modes.
  * "rpcholesky" — rank-r randomly-pivoted-Cholesky factor (Diaz et al. 2023).
  * "identity"   — plain CG.

Per-iteration cost is the O(n^2 d) streamed K matvec — this is exactly the
scaling wall the paper documents (Fig. 1: no PCG iteration finishes at
n = 1e8), reproduced in benchmarks/bench_table2_scaling.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem
from repro.core.nystrom import NystromFactors, nystrom_from_sketch
from repro.core.rpcholesky import rp_cholesky
from repro.kernels import ops


@dataclasses.dataclass
class PCGResult:
    w: jax.Array
    iters: int
    history: list[dict]
    converged: bool
    wall_time_s: float


def _nystrom_full(problem: KRRProblem, rank: int, key: jax.Array) -> NystromFactors:
    n = problem.n
    omega = jax.random.normal(key, (n, rank), jnp.float32)
    omega, _ = jnp.linalg.qr(omega)
    sketch = ops.kernel_matvec(
        problem.x,
        problem.x,
        omega,
        kernel=problem.kernel,
        sigma=problem.sigma,
        backend=problem.backend,
    )
    # trace of a unit-diagonal kernel matrix is exactly n
    return nystrom_from_sketch(sketch, omega, jnp.float32(n))


def make_preconditioner(
    problem: KRRProblem,
    kind: str = "nystrom",
    rank: int = 100,
    rho_mode: str = "damped",
    seed: int = 0,
) -> Callable[[jax.Array], jax.Array]:
    """Returns P^{-1} apply.  For Nystrom-type preconditioners:
    P^{-1} v = U diag((lam_r + lam)/(lam_j + lam)) U^T v + (v - U U^T v)."""
    lam = jnp.float32(problem.lam)
    if kind == "identity":
        return lambda v: v
    if kind == "nystrom":
        f = _nystrom_full(problem, rank, jax.random.PRNGKey(seed))
    elif kind == "rpcholesky":
        fmat, _ = rp_cholesky(
            jax.random.PRNGKey(seed),
            problem.x,
            rank,
            kernel=problem.kernel,
            sigma=problem.sigma,
            backend=problem.backend,
        )
        u, s, _ = jnp.linalg.svd(fmat, full_matrices=False)
        f = NystromFactors(u=u, lam=s * s)
    else:
        raise ValueError(f"unknown preconditioner {kind!r}")

    rho = lam + f.lam[-1] if rho_mode == "damped" else lam

    def apply(v: jax.Array) -> jax.Array:
        utv = f.u.T @ v
        scaled = utv * ((f.lam[-1] + rho) / (f.lam + rho))
        return f.u @ scaled + (v - f.u @ utv)

    return apply


def solve_pcg(
    problem: KRRProblem,
    *,
    precond: str = "nystrom",
    rank: int = 100,
    rho_mode: str = "damped",
    max_iters: int = 200,
    tol: float = 1e-8,
    seed: int = 0,
    time_budget_s: float | None = None,
) -> PCGResult:
    t0 = time.perf_counter()
    pinv = make_preconditioner(problem, precond, rank, rho_mode, seed)
    matvec = jax.jit(problem.k_lam_matvec)
    pinv = jax.jit(pinv)

    y = problem.y
    w = jnp.zeros_like(y)
    r = y  # residual for w0 = 0
    z = pinv(r)
    p = z
    rz = jnp.vdot(r, z)
    ynorm = float(jnp.linalg.norm(y))
    history: list[dict] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        kp = matvec(p)
        alpha = rz / jnp.vdot(p, kp)
        w = w + alpha * p
        r = r - alpha * kp
        rel = float(jnp.linalg.norm(r)) / ynorm
        history.append({"iter": it, "rel_residual": rel, "time_s": time.perf_counter() - t0})
        if rel < tol:
            converged = True
            break
        z = pinv(r)
        rz_new = jnp.vdot(r, z)
        p = z + (rz_new / rz) * p
        rz = rz_new
        if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
            break
    return PCGResult(
        w=w, iters=it, history=history, converged=converged,
        wall_time_s=time.perf_counter() - t0,
    )
