"""Preconditioned conjugate gradient for full KRR (baseline, paper §4.1/§6).

Preconditioners:
  * "nystrom"    — rank-r Gaussian-Nystrom of the full K (Frangella et al.
                   2023), sketch computed with the fused streaming matvec;
                   supports the paper's "damped"/"regularization" rho modes.
  * "rpcholesky" — rank-r randomly-pivoted-Cholesky factor (Diaz et al. 2023).
  * "rff"        — rank-r random-Fourier-feature factors (``core/rff.py``);
                   rbf-only, built from one streamed feature pass with NO
                   kernel sweeps, applied through the same damped-rho
                   Woodbury formula as Nystrom.
  * "identity"   — plain CG.

The iteration is blocked CG over (n, t) right-hand sides (Diaz et al. 2023
formulate randomized-preconditioned PCG over block RHS the same way): each
column carries its own alpha/beta/residual, columns that hit ``tol`` are
frozen, and the O(n^2 d) streamed K matvec — exactly the scaling wall the
paper documents (Fig. 1: no PCG iteration finishes at n = 1e8, reproduced in
benchmarks/bench_table2_scaling.py) — is shared by all t columns per
iteration.  A 1-D y is the t = 1 special case.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.blocked_cg import blocked_cg
from repro.core.krr import KRRProblem
from repro.core.nystrom import NystromFactors, nystrom_from_sketch
from repro.core.operator import as_multirhs, maybe_squeeze
from repro.core.rpcholesky import rp_cholesky
from repro.obs.metrics import record_tile_work
from repro.obs.telemetry import as_telemetry


@dataclasses.dataclass
class PCGResult:
    w: jax.Array
    iters: int
    history: list[dict]
    converged: bool
    wall_time_s: float


def _nystrom_full(problem: KRRProblem, rank: int, key: jax.Array) -> NystromFactors:
    op = problem.op
    omega = jax.random.normal(key, (op.n, rank), jnp.float32)
    omega, _ = jnp.linalg.qr(omega)
    sketch = op.sketch(omega)
    return nystrom_from_sketch(sketch, omega, op.trace_est())


def _rff_full(problem: KRRProblem, rank: int, key: jax.Array) -> NystromFactors:
    from repro.core.rff import RFF_KERNELS, rff_factors  # local: keep pcg import-light

    if problem.kernel not in RFF_KERNELS:
        raise ValueError(
            'kind="rff" preconditioning needs a shift-invariant kernel with '
            f"an implemented spectral measure ({RFF_KERNELS}); got "
            f"kernel={problem.kernel!r} — use kind=\"nystrom\""
        )
    return rff_factors(
        key, problem.x, rank, float(problem.sigma), kernel=problem.kernel
    )


def make_preconditioner(
    problem: KRRProblem,
    kind: str = "nystrom",
    rank: int = 100,
    rho_mode: str = "damped",
    seed: int = 0,
) -> Callable[[jax.Array], jax.Array]:
    """Returns P^{-1} apply over a (n, t) residual block.  For Nystrom-type
    preconditioners:
    P^{-1} V = U diag((lam_r + rho)/(lam_j + rho)) U^T V + (V - U U^T V)."""
    lam = jnp.float32(problem.lam)
    if kind == "identity":
        return lambda v: v
    if kind == "nystrom":
        f = _nystrom_full(problem, rank, jax.random.PRNGKey(seed))
    elif kind == "rff":
        f = _rff_full(problem, rank, jax.random.PRNGKey(seed))
    elif kind == "rpcholesky":
        fmat, _ = rp_cholesky(jax.random.PRNGKey(seed), problem.op, rank)
        u, s, _ = jnp.linalg.svd(fmat, full_matrices=False)
        f = NystromFactors(u=u, lam=s * s)
    else:
        raise ValueError(f"unknown preconditioner {kind!r}")

    rho = lam + f.lam[-1] if rho_mode == "damped" else lam
    coeff = (f.lam[-1] + rho) / (f.lam + rho)

    def apply(v: jax.Array) -> jax.Array:
        utv = f.u.T @ v
        scaled = utv * (coeff[:, None] if v.ndim == 2 else coeff)
        return f.u @ scaled + (v - f.u @ utv)

    return apply


def solve_pcg(
    problem: KRRProblem,
    *,
    precond: str = "nystrom",
    rank: int = 100,
    rho_mode: str = "damped",
    max_iters: int = 200,
    tol: float = 1e-8,
    seed: int = 0,
    time_budget_s: float | None = None,
    w0: jax.Array | None = None,
    telemetry=None,
) -> PCGResult:
    """Blocked PCG on (K + lam I) W = Y with per-column residual tracking.

    History records carry ``rel_residual`` (aggregate ||R||_F / ||Y||_F) and
    ``rel_residual_per_head``; convergence requires every column below tol.
    ``w0`` warm-starts the iteration (e.g. the fold-averaged CV solution a
    tuning sweep hands back, ``TuneResult.best_w0``) at the cost of one
    extra matvec for the initial residual.  ``telemetry`` adds a solve span,
    canonical per-iteration trace events, and tile-work metrics.
    """
    tel = as_telemetry(telemetry)
    n = problem.n
    d = problem.x.shape[1]
    precision = getattr(problem.op, "precision", "f32")
    recorder = tel.recorder("pcg", precision=precision, n=n)
    with tel.span("solve/pcg", n=n, t=problem.t, precond=precond, rank=rank,
                  max_iters=max_iters, tol=tol):
        t0 = time.perf_counter()
        pinv = make_preconditioner(problem, precond, rank, rho_mode, seed)
        matvec = jax.jit(problem.k_lam_matvec)
        pinv = jax.jit(pinv)

        y, squeeze = as_multirhs(problem.y)
        x0 = None
        if w0 is not None:
            x0, _ = as_multirhs(jnp.asarray(w0))
        res = blocked_cg(
            matvec, y, pinv, x0=x0, max_iters=max_iters, tol=tol, t0=t0,
            time_budget_s=time_budget_s, recorder=recorder,
        )
        if tel.enabled:
            # each CG iteration streams one full (n, n) K matvec; the warm
            # start costs one extra for the initial residual
            record_tile_work(n, n, d, precision,
                             count=res.iters + (1 if x0 is not None else 0))
    return PCGResult(
        w=maybe_squeeze(res.x, squeeze), iters=res.iters, history=res.history,
        converged=res.converged, wall_time_s=time.perf_counter() - t0,
    )
