"""Falkon (Rudi et al. 2017; Meanti et al. 2020): inducing-points KRR baseline.

Solves Eq. (5):  (K_nm^T K_nm + lam K_mm) w = K_nm^T y  with m uniformly
sampled centers, via CG in the Falkon-preconditioned variable
w = L^{-T} R^{-T} beta where

  L = chol(K_mm),   R = chol((1/m) L^T L + lam I).

All K_nm products are streamed through the fused kernel ops (O(n m d) per CG
iteration, O(m^2) storage) — the same structural costs as the reference
implementation, and the same m^2-storage wall the paper documents.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.krr import KRRProblem
from repro.kernels import ops


@dataclasses.dataclass
class FalkonResult:
    w: jax.Array  # (m,) inducing-point weights
    centers_idx: jax.Array  # (m,) indices into the training set
    iters: int
    history: list[dict]
    wall_time_s: float


def solve_falkon(
    problem: KRRProblem,
    m: int,
    *,
    max_iters: int = 100,
    tol: float = 1e-10,
    seed: int = 0,
    jitter: float = 1e-7,
    time_budget_s: float | None = None,
) -> FalkonResult:
    t0 = time.perf_counter()
    n = problem.n
    key = jax.random.PRNGKey(seed)
    centers_idx = jax.random.choice(key, n, (m,), replace=False)
    xm = jnp.take(problem.x, centers_idx, axis=0)
    lam = jnp.float32(problem.lam)

    kmm = ops.kernel_block(
        xm, xm, kernel=problem.kernel, sigma=problem.sigma, backend=problem.backend
    )
    kmm = kmm + jitter * m * jnp.eye(m, dtype=kmm.dtype)
    l = jnp.linalg.cholesky(kmm)
    inner = (l.T @ l) / m + lam * jnp.eye(m, dtype=kmm.dtype)
    r = jnp.linalg.cholesky(inner)

    def knm_t_knm(v: jax.Array) -> jax.Array:
        """K_nm^T (K_nm v) streamed over n."""
        tmp = ops.kernel_matvec(
            problem.x, xm, v, kernel=problem.kernel, sigma=problem.sigma,
            backend=problem.backend,
        )
        return ops.kernel_matvec(
            xm, problem.x, tmp, kernel=problem.kernel, sigma=problem.sigma,
            backend=problem.backend,
        )

    def from_beta(beta: jax.Array) -> jax.Array:
        return solve_triangular(l.T, solve_triangular(r.T, beta, lower=False), lower=False)

    def to_precond(v: jax.Array) -> jax.Array:
        return solve_triangular(r, solve_triangular(l, v, lower=True), lower=True)

    @jax.jit
    def operator(beta: jax.Array) -> jax.Array:
        wv = from_beta(beta)
        return to_precond(knm_t_knm(wv)) + lam * solve_triangular(
            r, solve_triangular(r.T, beta, lower=False), lower=True
        )

    rhs = to_precond(
        ops.kernel_matvec(
            xm, problem.x, problem.y, kernel=problem.kernel, sigma=problem.sigma,
            backend=problem.backend,
        )
    )

    beta = jnp.zeros((m,), jnp.float32)
    resid = rhs
    p = resid
    rs = jnp.vdot(resid, resid)
    rhs_norm = float(jnp.linalg.norm(rhs))
    history: list[dict] = []
    it = 0
    for it in range(1, max_iters + 1):
        hp = operator(p)
        alpha = rs / jnp.vdot(p, hp)
        beta = beta + alpha * p
        resid = resid - alpha * hp
        rel = float(jnp.linalg.norm(resid)) / max(rhs_norm, 1e-30)
        history.append({"iter": it, "rel_residual": rel, "time_s": time.perf_counter() - t0})
        if rel < tol:
            break
        rs_new = jnp.vdot(resid, resid)
        p = resid + (rs_new / rs) * p
        rs = rs_new
        if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
            break

    return FalkonResult(
        w=from_beta(beta),
        centers_idx=centers_idx,
        iters=it,
        history=history,
        wall_time_s=time.perf_counter() - t0,
    )


def falkon_predict(problem: KRRProblem, result: FalkonResult, x_test: jax.Array) -> jax.Array:
    xm = jnp.take(problem.x, result.centers_idx, axis=0)
    return ops.kernel_matvec(
        x_test, xm, result.w, kernel=problem.kernel, sigma=problem.sigma,
        backend=problem.backend,
    )
