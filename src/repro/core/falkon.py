"""Falkon (Rudi et al. 2017; Meanti et al. 2020): inducing-points KRR baseline.

Solves Eq. (5):  (K_nm^T K_nm + lam K_mm) W = K_nm^T Y  with m uniformly
sampled centers, via blocked CG in the Falkon-preconditioned variable
W = L^{-T} R^{-T} beta where

  L = chol(K_mm),   R = chol((1/m) L^T L + lam I).

All K_nm products go through the center/train KernelOperators (O(n m d) per
CG iteration, O(m^2) storage) — the same structural costs as the reference
implementation, and the same m^2-storage wall the paper documents.  A (n, t)
Y runs one CG over t columns sharing every streamed kernel pass; a 1-D y is
the t = 1 special case.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.blocked_cg import blocked_cg
from repro.core.krr import KRRProblem
from repro.core.operator import as_multirhs, maybe_squeeze
from repro.obs.metrics import record_tile_work
from repro.obs.telemetry import as_telemetry


@dataclasses.dataclass
class FalkonResult:
    w: jax.Array  # (m,) or (m, t) inducing-point weights
    centers_idx: jax.Array  # (m,) indices into the training set
    iters: int
    history: list[dict]
    wall_time_s: float


def solve_falkon(
    problem: KRRProblem,
    m: int,
    *,
    max_iters: int = 100,
    tol: float = 1e-10,
    seed: int = 0,
    jitter: float = 1e-7,
    time_budget_s: float | None = None,
    telemetry=None,
) -> FalkonResult:
    """Falkon solve with ``m`` uniformly sampled centers (module docstring
    has the math); ``telemetry`` adds a span + canonical trace events."""
    tel = as_telemetry(telemetry)
    t0 = time.perf_counter()
    n = problem.n
    key = jax.random.PRNGKey(seed)
    centers_idx = jax.random.choice(key, n, (m,), replace=False)
    op = problem.op
    op_m = op.restrict(centers_idx)  # operator over the center rows
    lam = jnp.float32(problem.lam)

    kmm = op_m.block(op_m.x)
    kmm = kmm + jitter * m * jnp.eye(m, dtype=kmm.dtype)
    l = jnp.linalg.cholesky(kmm)
    inner = (l.T @ l) / m + lam * jnp.eye(m, dtype=kmm.dtype)
    r = jnp.linalg.cholesky(inner)

    def knm_t_knm(v: jax.Array) -> jax.Array:
        """K_nm^T (K_nm v) streamed over n; v (m, t)."""
        tmp = op_m.row_block_matvec(op.x, v)  # K(x, xm) @ v
        return op.row_block_matvec(op_m.x, tmp)  # K(xm, x) @ tmp

    def from_beta(beta: jax.Array) -> jax.Array:
        return solve_triangular(l.T, solve_triangular(r.T, beta, lower=False), lower=False)

    def to_precond(v: jax.Array) -> jax.Array:
        return solve_triangular(r, solve_triangular(l, v, lower=True), lower=True)

    @jax.jit
    def operator(beta: jax.Array) -> jax.Array:
        wv = from_beta(beta)
        return to_precond(knm_t_knm(wv)) + lam * solve_triangular(
            r, solve_triangular(r.T, beta, lower=False), lower=True
        )

    y, squeeze = as_multirhs(problem.y)
    rhs = to_precond(op.row_block_matvec(op_m.x, y))  # (m, t)

    # plain blocked CG on the Falkon-preconditioned operator (pinv = None)
    with tel.span("solve/falkon", n=n, m=m, t=problem.t, max_iters=max_iters,
                  tol=tol):
        res = blocked_cg(
            operator, rhs, max_iters=max_iters, tol=tol, t0=t0,
            time_budget_s=time_budget_s,
            recorder=tel.recorder("falkon", n=n),
        )
        if tel.enabled:
            # each CG iteration streams K_nm and K_mn (plus one K_mn for the
            # RHS setup and the m^2 block build)
            d = problem.x.shape[1]
            record_tile_work(n, m, d, count=res.iters)
            record_tile_work(m, n, d, count=res.iters + 1)
            record_tile_work(m, m, d)

    return FalkonResult(
        w=maybe_squeeze(from_beta(res.x), squeeze),
        centers_idx=centers_idx,
        iters=res.iters,
        history=res.history,
        wall_time_s=time.perf_counter() - t0,
    )


def falkon_predict(problem: KRRProblem, result: FalkonResult, x_test: jax.Array) -> jax.Array:
    op_m = problem.op.restrict(result.centers_idx)
    return op_m.row_block_matvec(x_test, result.w)
