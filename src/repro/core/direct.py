"""Direct Cholesky solve of (K + lam I) W = Y — O(n^3)/O(n^2).

Ground truth for tests and the small-n end of the baselines (paper §1 notes
it stops being viable at n >~ 1e4, which our scaling benchmark reproduces).
Multi-RHS for free: one factorization back-substitutes all t columns of a
(n, t) Y (the one-vs-all case), a (n,) y returns a (n,) w.

The same factorization yields closed-form leave-one-out residuals
(:func:`loo_residuals`) — the exact small-n cross-check of the tuning
subsystem's k-fold CV scores (``tune(folds=n)`` IS leave-one-out, and its
scores must match this formula to solver tolerance; single- and multi-kernel
problems alike, since everything goes through ``problem.op.block``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem, scaled_lam
from repro.core.operator import as_multirhs, maybe_squeeze


def _chol_k_lam(problem: KRRProblem, lam: float) -> jax.Array:
    k = problem.op.block(problem.x)
    k_lam = k + lam * jnp.eye(problem.n, dtype=k.dtype)
    return jnp.linalg.cholesky(k_lam)


def solve_direct(problem: KRRProblem) -> jax.Array:
    """Dense Cholesky solve of (K + lam I) W = Y; W (n,) or (n, t)."""
    chol = _chol_k_lam(problem, problem.lam)
    return jax.scipy.linalg.cho_solve((chol, True), problem.y)


def loo_residuals(problem: KRRProblem, *, lam: float | None = None) -> jax.Array:
    """Closed-form leave-one-out residuals from ONE Cholesky.

    For C = K + lam I and alpha = C^{-1} y, the model trained without point
    i predicts it with residual  y_i - f_{-i}(x_i) = alpha_i / (C^{-1})_{ii}
    (the classic kernel-ridge LOO identity; one factorization serves all n
    leave-outs and all t heads).

    ``lam`` defaults to ``scaled_lam(n - 1, lam_unscaled)`` — each LOO model
    trains on n - 1 rows, and the paper's App. C.2.1 rule scales the shift
    by the TRAINING size, exactly as ``tune(folds=n)`` solves its fold
    systems.  Pass ``lam=problem.lam`` for the fixed-shift variant.

    Returns residuals shaped like ``problem.y``; mean squared entries are
    the exact LOO CV score.
    """
    lam_f = scaled_lam(problem.n - 1, problem.lam_unscaled) if lam is None else lam
    chol = _chol_k_lam(problem, float(lam_f))
    y2, squeeze = as_multirhs(problem.y)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y2)
    c_inv = jax.scipy.linalg.cho_solve(
        (chol, True), jnp.eye(problem.n, dtype=y2.dtype)
    )
    resid = alpha / jnp.diag(c_inv)[:, None]
    return maybe_squeeze(resid, squeeze)


def loo_mse(problem: KRRProblem, *, lam: float | None = None) -> float:
    """Exact leave-one-out CV mean-squared-error (see :func:`loo_residuals`)."""
    return float(jnp.mean(loo_residuals(problem, lam=lam) ** 2))
