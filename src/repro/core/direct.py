"""Direct Cholesky solve of (K + lam I) w = y — O(n^3)/O(n^2).

Ground truth for tests and the small-n end of the baselines (paper §1 notes
it stops being viable at n >~ 1e4, which our scaling benchmark reproduces).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem
from repro.kernels import ops


def solve_direct(problem: KRRProblem) -> jax.Array:
    k = ops.kernel_block(
        problem.x,
        problem.x,
        kernel=problem.kernel,
        sigma=problem.sigma,
        backend=problem.backend,
    )
    k_lam = k + problem.lam * jnp.eye(problem.n, dtype=k.dtype)
    chol = jnp.linalg.cholesky(k_lam)
    return jax.scipy.linalg.cho_solve((chol, True), problem.y)
