"""Direct Cholesky solve of (K + lam I) W = Y — O(n^3)/O(n^2).

Ground truth for tests and the small-n end of the baselines (paper §1 notes
it stops being viable at n >~ 1e4, which our scaling benchmark reproduces).
Multi-RHS for free: one factorization back-substitutes all t columns of a
(n, t) Y (the one-vs-all case), a (n,) y returns a (n,) w.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.krr import KRRProblem


def solve_direct(problem: KRRProblem) -> jax.Array:
    k = problem.op.block(problem.x)
    k_lam = k + problem.lam * jnp.eye(problem.n, dtype=k.dtype)
    chol = jnp.linalg.cholesky(k_lam)
    return jax.scipy.linalg.cho_solve((chol, True), problem.y)
