"""Batched KRR prediction serving.

Standalone module (no dependency on the LM model stack): wraps a trained
weight matrix behind a kernel operator so solved KRR models can serve request
traffic.  Requests are padded to power-of-two buckets (bounded jit cache) and
each bucket is one fused K(x_query, X_train) pass serving all t one-vs-all
heads at once.

The operator may be a single-device ``KernelOperator`` or a mesh-aware
``ShardedKernelOperator`` — both expose the same ``row_block_matvec(a, v)``
contract, so the SAME serving closure drives a sharded fleet: queries stay
replicated, the training rows and the weight matrix stay row-sharded, and
each bucket costs one psum of (bucket, t) partial scores
(``make_sharded_krr_predict_fn`` wires this up from host arrays).

``make_krr_predict_fn_from_config`` builds either flavor straight from the
best-config dict ``solver_api.tune()`` exports (docs/tuning.md), closing the
tune -> refit -> serve loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operator import KernelOperator


def make_krr_predict_fn(op, w: jax.Array, *, max_batch: int = 4096):
    """Batched KRR scorer: (q, d) queries -> (q,) or (q, t) scores.

    ``op`` is a KernelOperator or ShardedKernelOperator over the training
    rows; ``w`` the solved weights ((n,) or (n, t)), row-sharded to match a
    sharded ``op``.  The returned closure pads each request up to the next
    power-of-two bucket (>= 8, <= max_batch) so the jit cache stays
    O(log max_batch) deep under arbitrary traffic shapes; oversize requests
    stream in max_batch chunks.  One fused kernel pass serves all heads.
    """

    @jax.jit
    def _score(xq: jax.Array) -> jax.Array:
        return op.row_block_matvec(xq, w)

    def _bucket(q: int) -> int:
        b = 8
        while b < q:
            b <<= 1
        return min(b, max_batch)

    def predict(xq: jax.Array) -> jax.Array:
        q = xq.shape[0]
        if q == 0:  # empty request: (0,) / (0, t) without tracing a bucket
            return jnp.zeros((0,) + w.shape[1:], w.dtype)
        outs = []
        start = 0
        while start < q:
            stop = min(start + max_batch, q)
            chunk = xq[start:stop]
            b = _bucket(stop - start)
            padded = jnp.pad(chunk, ((0, b - chunk.shape[0]),) + ((0, 0),) * (xq.ndim - 1))
            outs.append(_score(padded)[: chunk.shape[0]])
            start = stop
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    return predict


def make_sharded_krr_predict_fn(
    mesh,
    x_train: jax.Array,
    w: jax.Array,
    *,
    kernel: str | tuple[str, ...] = "rbf",
    sigma: float | tuple[float, ...] = 1.0,
    weights=None,
    backend: str = "auto",
    precision: str = "f32",
    max_batch: int = 4096,
):
    """Serve all t heads from row-sharded training points on ``mesh``.

    Places ``x_train`` and ``w`` row-sharded (non-"model" mesh axes) and
    returns the same batched predict closure as :func:`make_krr_predict_fn`;
    per bucket the only wire traffic is the (bucket, t) psum of partial
    scores.  On a 1-device mesh this is exactly the single-device server.
    A kernel TUPLE (+ ``weights``) serves the weighted-sum multi-kernel
    predictor — still one fused pass per bucket.  ``precision="bf16"`` scores
    with bf16 kernel tiles + f32 accumulation (the solve-side policy applies
    to serving too).
    """
    from repro.distributed.sharded_operator import ShardedKernelOperator

    op = ShardedKernelOperator.bind(
        mesh, x_train, kernel=kernel, sigma=sigma, backend=backend,
        weights=weights, precision=precision,
    )
    w_sh = jax.device_put(jnp.asarray(w), op.sharding(jnp.ndim(w)))
    return make_krr_predict_fn(op, w_sh, max_batch=max_batch)


def bind_operator_from_config(
    config: dict,
    x_train: jax.Array,
    w: jax.Array,
    *,
    mesh=None,
):
    """Resolve a ``tune()`` best-config export into ``(operator, w)``.

    The shared reconstruction step behind :func:`make_krr_predict_fn_from_config`
    and the serving engine's model registry (``serving.engine``): parses the
    kernel/sigma/weights triple (single- or multi-kernel), validates the
    ``precision`` string via :func:`repro.kernels.precision.check_precision`
    (a hand-edited export with an unknown policy fails HERE with the accepted
    list, not deep inside a jit trace), and binds either a single-device
    ``KernelOperator`` (a weighted-sum one for kernel lists) or, with
    ``mesh=``, a row-sharded ``ShardedKernelOperator`` with ``w`` placed to
    match.  Returns the operator and the (possibly re-placed) weights.
    """
    from repro.kernels.precision import check_precision

    from repro.core.kernels import KERNEL_NAMES

    kernel = config["kernel"]
    sigma = config["sigma"]
    weights = config.get("weights")
    # fail fast on kernel names HERE (a hand-edited export, or an export from
    # a newer zoo than this server) rather than deep inside a jit trace;
    # "precomputed" is valid — x_train is then the train Gram
    names = kernel if isinstance(kernel, (tuple, list)) else (kernel,)
    for k in names:
        if k not in KERNEL_NAMES and k != "precomputed":
            raise ValueError(
                f"unknown kernel {k!r} in serving config; available: "
                f"{KERNEL_NAMES + ('precomputed',)}"
            )
    if isinstance(kernel, (tuple, list)):
        kernel = tuple(kernel)
        sigma = (
            tuple(float(s) for s in sigma)
            if isinstance(sigma, (tuple, list)) else float(sigma)
        )
        if weights is not None:
            weights = tuple(float(v) for v in weights)
    else:
        sigma = float(sigma)
    backend = config.get("backend", "auto")
    precision = check_precision(config.get("precision", "f32"))
    if mesh is not None:
        if kernel == "precomputed":
            raise ValueError(
                "kernel='precomputed' cannot serve over a mesh: the Gram "
                "matrix has no row-sharded kernel evaluation path"
            )
        from repro.distributed.sharded_operator import ShardedKernelOperator

        op = ShardedKernelOperator.bind(
            mesh, jnp.asarray(x_train), kernel=kernel, sigma=sigma,
            backend=backend, weights=weights, precision=precision,
        )
        w_sh = jax.device_put(jnp.asarray(w), op.sharding(jnp.ndim(w)))
        return op, w_sh
    from repro.core.multikernel import make_operator

    op = make_operator(
        jnp.asarray(x_train), kernel=kernel, sigma=sigma, weights=weights,
        backend=backend, precision=precision,
    )
    return op, jnp.asarray(w)


def make_krr_predict_fn_from_config(
    config: dict,
    x_train: jax.Array,
    w: jax.Array,
    *,
    mesh=None,
    max_batch: int = 4096,
):
    """Serve a refit model from a ``tune()`` best-config export.

    Args:
      config: the JSON-able dict ``TuneResult.best`` carries (or a CLI
        ``--export`` file re-read): requires ``kernel`` and ``sigma``;
        ``backend`` and ``precision`` (the "f32" | "bf16" tile policy the
        model was tuned under) are honored when present — an unknown
        ``precision`` string (e.g. from a hand-edited export) raises
        ValueError with the accepted list.  A multi-kernel export carries
        ``kernel`` as a LIST of names plus ``weights`` (and possibly a
        per-kernel ``sigma`` list) — the weighted-sum predictor is
        reconstructed exactly.  Extra keys (``lam_unscaled``, ``cv_mse``,
        ``folds``) are ignored here — regularization lives in the solve, not
        the scorer.
      x_train: (n, d) training rows the weights were fit on.
      w: the refit weights, (n,) or (n, t).
      mesh: optional Mesh — serve from row-sharded training rows via
        :func:`make_sharded_krr_predict_fn` instead of one device.

    Returns:
      The same batched predict closure as :func:`make_krr_predict_fn`.
    """
    op, w = bind_operator_from_config(config, x_train, w, mesh=mesh)
    return make_krr_predict_fn(op, w, max_batch=max_batch)


__all__ = [
    "KernelOperator",
    "bind_operator_from_config",
    "make_krr_predict_fn",
    "make_krr_predict_fn_from_config",
    "make_sharded_krr_predict_fn",
]
