"""Batched KRR prediction serving.

Standalone module (no dependency on the LM model stack): wraps a trained
weight matrix behind a KernelOperator so solved KRR models can serve request
traffic.  Requests are padded to power-of-two buckets (bounded jit cache) and
each bucket is one fused K(x_query, X_train) pass serving all t one-vs-all
heads at once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operator import KernelOperator


def make_krr_predict_fn(op: KernelOperator, w: jax.Array, *, max_batch: int = 4096):
    """Batched KRR scorer: (q, d) queries -> (q,) or (q, t) scores.

    The returned closure pads each request up to the next power-of-two bucket
    (>= 8, <= max_batch) so the jit cache stays O(log max_batch) deep under
    arbitrary traffic shapes; oversize requests stream in max_batch chunks.
    One fused kernel pass serves all heads of a (n, t) weight matrix.
    """

    @jax.jit
    def _score(xq: jax.Array) -> jax.Array:
        return op.row_block_matvec(xq, w)

    def _bucket(q: int) -> int:
        b = 8
        while b < q:
            b <<= 1
        return min(b, max_batch)

    def predict(xq: jax.Array) -> jax.Array:
        q = xq.shape[0]
        if q == 0:  # empty request: (0,) / (0, t) without tracing a bucket
            return jnp.zeros((0,) + w.shape[1:], jnp.float32)
        outs = []
        start = 0
        while start < q:
            stop = min(start + max_batch, q)
            chunk = xq[start:stop]
            b = _bucket(stop - start)
            padded = jnp.pad(chunk, ((0, b - chunk.shape[0]),) + ((0, 0),) * (xq.ndim - 1))
            outs.append(_score(padded)[: chunk.shape[0]])
            start = stop
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    return predict
