"""High-throughput KRR serving engine — request coalescing over the bucketed
batch path, a hot-loadable multi-model registry, and per-model latency stats.

``serving.krr_serve`` gives one model a batched predict closure; this module
turns that closure's cost model into a *server*.  Three layers:

* **Coalescing batcher** — clients submit (q_i, d) query blocks from any
  thread (:meth:`ServingEngine.submit` returns a future); a single worker
  loop drains the shared queue under a ``max_wait_ms`` deadline, concatenates
  every queued request for the same model, pads the union to the next
  power-of-two bucket and runs ONE fused ``row_block_matvec`` for all
  requests and all t heads, then scatters per-request row slices back to the
  futures.  k small requests cost ~one kernel pass over the training rows
  instead of k passes — the same batching discipline that makes the solvers
  fast (docs/serving.md has the cost model).

* **Model registry** — :meth:`ServingEngine.register` (or
  :meth:`ServingEngine.load_model` straight from a
  :func:`save_model_artifact` directory: the ``krr_tune --export`` JSON plus
  a weights ``.npz``) binds the operator via
  ``krr_serve.bind_operator_from_config`` — single-device, weighted-sum
  multi-kernel, or row-sharded on a mesh behind the SAME front end — and
  **pre-warms every bucket** so no client ever pays a jit compile.
  Re-registering a name hot-swaps it: requests already submitted finish on
  the old model (they hold a reference), new submissions see the new
  version.  A ``max_bytes`` budget LRU-evicts idle models.

* **Per-model stats** — request count, qps, p50/p99 latency, a
  batch-occupancy histogram per bucket, and the compile-cache depth, exposed
  as a plain dict (:meth:`ServingEngine.stats`) for ``bench_serving`` and
  the ``krr_serve`` CLI.

Results are bitwise-identical to per-request ``predict`` calls at f32: each
output row of a fused kernel pass depends only on its own query row, so
coalescing changes throughput, never values (enforced by
``tests/test_serving_engine.py`` and the bench).
"""

from __future__ import annotations

import json
import os
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import (
    LATENCY_BUCKETS_MS,
    Histogram,
    _render_series,
    counter as _obs_counter,
    gauge as _obs_gauge,
    record_tile_work,
)
from repro.obs.telemetry import as_telemetry
from repro.serving.krr_serve import bind_operator_from_config

ARTIFACT_CONFIG = "config.json"
ARTIFACT_WEIGHTS = "weights.npz"

#: smallest jit bucket — requests are padded up to at least this many rows
MIN_BUCKET = 8


def bucket_sizes(max_batch: int) -> tuple[int, ...]:
    """The power-of-two bucket ladder for ``max_batch``: 8, 16, ... capped at
    (and always including) ``max_batch`` — the full jit-cache footprint a
    pre-warmed model compiles, O(log max_batch) entries."""
    sizes = []
    b = MIN_BUCKET
    while b < max_batch:
        sizes.append(b)
        b <<= 1
    sizes.append(max_batch)
    return tuple(sizes)


def bucket_for(q: int, max_batch: int) -> int:
    """Bucket (padded row count) serving a ``q``-row block: the next power of
    two >= max(q, 8), capped at ``max_batch``."""
    b = MIN_BUCKET
    while b < q:
        b <<= 1
    return min(b, max_batch)


def save_model_artifact(path: str, config: dict, x_train, w) -> str:
    """Write a serving artifact directory: ``config.json`` + ``weights.npz``.

    ``config`` is the ``tune()`` best-config dict (what ``krr_tune --export``
    writes — extra keys like ``trace`` ride along untouched); ``x_train`` the
    (n, d) training rows and ``w`` the refit weights ((n,) or (n, t)).  This
    closes the tune -> refit -> export -> serve loop as files on disk:
    :meth:`ServingEngine.load_model` consumes the directory.  Returns
    ``path``.
    """
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, ARTIFACT_CONFIG), "w") as fh:
        json.dump(config, fh, indent=2, default=float)
    np.savez(
        os.path.join(path, ARTIFACT_WEIGHTS),
        x_train=np.asarray(x_train),
        w=np.asarray(w),
    )
    return path


def load_model_artifact(path: str) -> tuple[dict, np.ndarray, np.ndarray]:
    """Read a :func:`save_model_artifact` directory -> (config, x_train, w)."""
    with open(os.path.join(path, ARTIFACT_CONFIG)) as fh:
        config = json.load(fh)
    with np.load(os.path.join(path, ARTIFACT_WEIGHTS)) as npz:
        x_train, w = npz["x_train"], npz["w"]
    return config, x_train, w


class _ModelEntry:
    """One registered model: bound operator + weights + jitted bucket scorer
    + its slice of the stats.  Requests hold a direct reference, so an entry
    keeps serving its in-flight traffic even after being swapped or evicted
    from the registry."""

    def __init__(self, name: str, version: int, config: dict, op, w,
                 max_batch: int):
        self.name = name
        self.version = version
        self.config = config
        self.op = op
        self.w = w
        self.max_batch = max_batch
        self.d = int(op.d)
        self.out_trailing = tuple(w.shape[1:])
        self.dtype = w.dtype
        self.x_dtype = jnp.asarray(op.x).dtype
        self.nbytes = (
            int(op.n) * self.d * self.x_dtype.itemsize
            + int(np.prod(w.shape)) * w.dtype.itemsize
        )
        # one jitted scorer; the jit cache holds one executable per bucket
        import jax

        self._score = jax.jit(lambda xq: op.row_block_matvec(xq, w))
        self.warmed: set[int] = set()
        # stats (mutated by the worker thread only; read under the engine lock)
        self.n_requests = 0
        self.n_rows = 0
        # bounded log-spaced latency histogram — O(buckets) memory however
        # long the server runs (the unbounded raw-latency list it replaced
        # capped out at 100k floats per model); a LOCAL instance, not the
        # global registry, so two engines serving a same-named model never
        # mix latencies
        self.latency_hist = Histogram(
            "repro_serving_latency_ms", labels=(("model", name),),
            help="request latency, submit to scatter (ms)",
            buckets=LATENCY_BUCKETS_MS,
        )
        self.occupancy: dict[int, list[int]] = {}  # bucket -> [runs, rows]
        self.t_first: float | None = None
        self.t_last: float | None = None
        self.last_used = time.monotonic()
        self.loaded_at = time.time()

    def score(self, padded):
        """Run the fused bucket pass; tracks the jit-cache (bucket) depth."""
        self.warmed.add(padded.shape[0])
        return self._score(padded)

    def warm(self) -> tuple[int, ...]:
        """Compile every bucket in the ladder so no client pays a jit trace:
        one zeros pass per power-of-two size, blocked to completion."""
        for b in bucket_sizes(self.max_batch):
            z = jnp.zeros((b, self.d), self.x_dtype)
            self.score(z).block_until_ready()
        return bucket_sizes(self.max_batch)

    def stats(self) -> dict[str, Any]:
        """The per-model stats dict (see :meth:`ServingEngine.stats`).

        p50/p99 are bucket-interpolated estimates from the bounded latency
        histogram; ``mean_ms`` stays exact (the histogram keeps exact
        sum/count).
        """
        span = (
            (self.t_last - self.t_first)
            if (self.t_first is not None and self.t_last is not None)
            else 0.0
        )
        return {
            "model": self.name,
            "version": self.version,
            "n_requests": self.n_requests,
            "n_rows": self.n_rows,
            "qps": (self.n_requests / span) if span > 0 else 0.0,
            "p50_ms": self.latency_hist.quantile(0.50),
            "p99_ms": self.latency_hist.quantile(0.99),
            "mean_ms": self.latency_hist.mean,
            "occupancy": {
                b: {"runs": r, "rows": rows,
                    "fill": rows / (r * b) if r else 0.0}
                for b, (r, rows) in sorted(self.occupancy.items())
            },
            "compile_cache_depth": len(self.warmed),
            "bytes": self.nbytes,
        }

    def reset_stats(self) -> None:
        """Zero this model's traffic stats (latency histogram, counts,
        occupancy, qps span) — warmed buckets and the registry entry stay."""
        self.n_requests = 0
        self.n_rows = 0
        self.latency_hist.reset()
        self.occupancy = {}
        self.t_first = None
        self.t_last = None


class _Request:
    __slots__ = ("entry", "xq", "future", "t_arrival")

    def __init__(self, entry: _ModelEntry, xq, t_arrival: float):
        self.entry = entry
        self.xq = xq
        self.future: Future = Future()
        self.t_arrival = t_arrival


class ServingEngine:
    """Multi-model KRR serving engine (see the module docstring for the
    three layers).  Thread-safe: any number of client threads may
    ``submit``/``predict`` concurrently; one worker thread owns the device.

    Args:
      max_batch: largest fused bucket (and the coalescing drain cap).
      max_wait_ms: how long the worker holds the FIRST queued request open
        for co-travellers before closing the batch.  0 disables coalescing
        in all but bursts already queued (the "naive-ish" limit); a few ms
        buys large fusion under concurrent traffic for a bounded latency tax.
      max_bytes: optional registry memory budget over (x_train + w) bytes;
        registering past it LRU-evicts idle models.  A single model larger
        than the budget is rejected outright.
      telemetry: optional ``repro.obs.Telemetry`` — the worker then emits a
        span per fused batch and tile-work metrics per bucket pass (latency
        histograms, queue-depth gauge, and bucket-fill counters are always
        on; they are bounded and cost O(1) per batch).
    """

    def __init__(self, *, max_batch: int = 4096, max_wait_ms: float = 5.0,
                 max_bytes: int | None = None, telemetry=None):
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_bytes = max_bytes
        self._tel = as_telemetry(telemetry)
        self._queue_gauge = _obs_gauge(
            "repro_serving_queue_depth",
            help="requests waiting in the coalescing queue",
        )
        self._models: dict[str, _ModelEntry] = {}
        self._lock = threading.Lock()
        self._queue: queue_mod.Queue[_Request] = queue_mod.Queue()
        self._inflight = 0
        self._evictions = 0
        self._stop = threading.Event()
        self._worker = threading.Thread(
            target=self._run, name="krr-serving-worker", daemon=True
        )
        self._worker.start()

    # -- registry -------------------------------------------------------------

    def register(self, name: str, config: dict, x_train, w, *, mesh=None,
                 warm: bool = True) -> dict[str, Any]:
        """Bind and (hot-)register a model under ``name``.

        ``config``/``x_train``/``w`` are exactly the
        ``make_krr_predict_fn_from_config`` inputs; ``mesh=`` serves from
        row-sharded training rows.  ``warm=True`` compiles every bucket
        before the model becomes visible, so the first real request runs at
        steady-state latency.  If ``name`` exists the new version replaces it
        atomically — in-flight requests finish on the old model.  Returns an
        info dict (version, bytes, warmed buckets, evicted names).
        """
        op, w_bound = bind_operator_from_config(config, x_train, w, mesh=mesh)
        with self._lock:
            version = (
                self._models[name].version + 1 if name in self._models else 1
            )
        entry = _ModelEntry(name, version, dict(config), op, w_bound,
                            self.max_batch)
        if self.max_bytes is not None and entry.nbytes > self.max_bytes:
            raise ValueError(
                f"model {name!r} needs {entry.nbytes} bytes, above the "
                f"registry budget max_bytes={self.max_bytes}"
            )
        warmed: tuple[int, ...] = ()
        if warm:
            warmed = entry.warm()
        evicted = []
        with self._lock:
            self._models[name] = entry
            evicted = self._evict_to_budget_locked(keep=name)
        return {
            "model": name,
            "version": version,
            "d": entry.d,
            "bytes": entry.nbytes,
            "warmed_buckets": list(warmed),
            "evicted": evicted,
        }

    def load_model(self, name: str, path: str, *, mesh=None,
                   warm: bool = True) -> dict[str, Any]:
        """:func:`load_model_artifact` + :meth:`register` in one call — the
        disk-to-serving path the ``krr_serve`` CLI uses."""
        config, x_train, w = load_model_artifact(path)
        return self.register(name, config, x_train, w, mesh=mesh, warm=warm)

    def load_artifacts_dir(self, path: str, *, mesh=None,
                           warm: bool = True) -> dict[str, dict[str, Any]]:
        """Re-register every artifact under ``path`` — registry persistence.

        Scans the immediate subdirectories of ``path`` for the
        :func:`save_model_artifact` layout (``config.json`` +
        ``weights.npz``) and :meth:`register`s each under its directory
        name, in sorted order; anything else in ``path`` is ignored.  Run
        at startup this restores the registry a previous process built by
        exporting models into one directory tree — the restart-survival
        story of the artifact format.  Returns ``{name: register-info}``;
        raises if ``path`` holds no artifacts at all (an empty restore is
        almost always a wrong path).
        """
        loaded: dict[str, dict[str, Any]] = {}
        for entry in sorted(os.listdir(path)):
            sub = os.path.join(path, entry)
            if not os.path.isdir(sub):
                continue
            if not (
                os.path.isfile(os.path.join(sub, ARTIFACT_CONFIG))
                and os.path.isfile(os.path.join(sub, ARTIFACT_WEIGHTS))
            ):
                continue
            loaded[entry] = self.load_model(entry, sub, mesh=mesh, warm=warm)
        if not loaded:
            raise FileNotFoundError(
                f"no model artifacts ({ARTIFACT_CONFIG} + {ARTIFACT_WEIGHTS} "
                f"subdirectories) under {path!r}"
            )
        return loaded

    def unregister(self, name: str) -> None:
        """Drop ``name`` from the registry (in-flight requests finish)."""
        with self._lock:
            self._models.pop(name, None)

    def models(self) -> list[str]:
        """Currently registered model names (sorted)."""
        with self._lock:
            return sorted(self._models)

    def _evict_to_budget_locked(self, keep: str) -> list[str]:
        evicted = []
        if self.max_bytes is None:
            return evicted
        total = sum(e.nbytes for e in self._models.values())
        while total > self.max_bytes and len(self._models) > 1:
            victim = min(
                (n for n in self._models if n != keep),
                key=lambda n: self._models[n].last_used,
                default=None,
            )
            if victim is None:
                break
            total -= self._models[victim].nbytes
            del self._models[victim]
            evicted.append(victim)
            self._evictions += 1
        return evicted

    # -- the client surface ---------------------------------------------------

    def submit(self, name: str, xq) -> Future:
        """Enqueue a (q, d) query block for ``name``; returns a
        ``concurrent.futures.Future`` resolving to the (q,) or (q, t) host
        scores (numpy).  The worker stamps ``future.latency_ms`` (submit to
        scatter, device-synced) before resolving it.  Safe from any thread;
        shape/model errors raise immediately."""
        if self._stop.is_set():
            raise RuntimeError("ServingEngine is shut down")
        with self._lock:
            entry = self._models.get(name)
            if entry is None:
                raise KeyError(
                    f"unknown model {name!r}; registered: {sorted(self._models)}"
                )
            entry.last_used = time.monotonic()
            self._inflight += 1
        # requests stay HOST-side numpy until the bucket pass: assembly and
        # scatter never touch the device, so the only compiled shapes are the
        # O(log max_batch) warmed buckets — never a per-traffic-mix
        # concatenate/pad/slice executable
        xq = np.asarray(xq)
        if xq.ndim != 2 or xq.shape[1] != entry.d:
            with self._lock:
                self._inflight -= 1
            raise ValueError(
                f"expected a (q, {entry.d}) query block for model {name!r}, "
                f"got shape {tuple(xq.shape)}"
            )
        req = _Request(entry, xq, time.monotonic())
        if xq.shape[0] == 0:  # empty request: resolve without queueing
            req.future.latency_ms = 0.0
            req.future.set_result(
                np.zeros((0,) + entry.out_trailing, entry.dtype)
            )
            with self._lock:
                self._inflight -= 1
            return req.future
        self._queue.put(req)
        return req.future

    def predict(self, name: str, xq):
        """Blocking convenience wrapper: ``submit(name, xq).result()``."""
        return self.submit(name, xq).result()

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every submitted request has been served (tests and
        clean CLI shutdown; raises TimeoutError after ``timeout`` s)."""
        t0 = time.monotonic()
        while True:
            with self._lock:
                if self._inflight == 0:
                    return
            if time.monotonic() - t0 > timeout:
                raise TimeoutError("serving queue did not drain in time")
            time.sleep(0.001)

    def shutdown(self) -> None:
        """Stop the worker loop (idempotent).  Queued requests are served
        first; the engine cannot be restarted."""
        self._stop.set()
        self._worker.join(timeout=10.0)

    def __enter__(self) -> "ServingEngine":
        """Context-manager support: ``with ServingEngine() as eng: ...``."""
        return self

    def __exit__(self, *exc) -> None:
        """Drain outstanding work, then shut the worker down."""
        try:
            self.drain()
        finally:
            self.shutdown()

    # -- stats ----------------------------------------------------------------

    def stats(self, name: str | None = None) -> dict[str, Any]:
        """Per-model serving stats.

        With ``name``: that model's dict — ``n_requests``, ``qps`` (completed
        requests over the first->last completion span), ``p50_ms``/``p99_ms``
        latency (submit to scatter, device-synced), the per-bucket occupancy
        histogram ``{bucket: {runs, rows, fill}}``, ``compile_cache_depth``
        (warmed + traffic-compiled bucket count) and ``bytes``.  Without:
        ``{"models": {name: ...}, "evictions", "bytes", "max_bytes"}``.
        """
        with self._lock:
            if name is not None:
                return self._models[name].stats()
            return {
                "models": {n: e.stats() for n, e in self._models.items()},
                "evictions": self._evictions,
                "bytes": sum(e.nbytes for e in self._models.values()),
                "max_bytes": self.max_bytes,
            }

    def reset_stats(self, name: str | None = None) -> None:
        """Zero traffic stats (latency histogram, request/row counts, bucket
        occupancy, qps span) for one model, or for every registered model
        when ``name`` is None.  Registered models, warmed buckets, and the
        eviction count are untouched — this is the long-running server's
        "start a fresh measurement window" knob."""
        with self._lock:
            entries = (
                [self._models[name]] if name is not None
                else list(self._models.values())
            )
        for e in entries:
            e.reset_stats()

    def prometheus_text(self) -> str:
        """Per-model latency histograms and request/row totals in the
        Prometheus text exposition format (``_bucket{le=}`` cumulative
        series + ``_sum``/``_count``), rendered from the same bounded
        histograms :meth:`stats` reads."""
        with self._lock:
            entries = sorted(self._models.items())
        lines: list[str] = []
        if entries:
            lines.append("# HELP repro_serving_latency_ms request latency, "
                         "submit to scatter (ms)")
            lines.append("# TYPE repro_serving_latency_ms histogram")
            for name, e in entries:
                lines.extend(_render_series(
                    "repro_serving_latency_ms", (("model", name),),
                    e.latency_hist,
                ))
            lines.append("# TYPE repro_serving_requests_total counter")
            for name, e in entries:
                lines.append(
                    f'repro_serving_requests_total{{model="{name}"}} '
                    f"{float(e.n_requests)}"
                )
            lines.append("# TYPE repro_serving_rows_total counter")
            for name, e in entries:
                lines.append(
                    f'repro_serving_rows_total{{model="{name}"}} '
                    f"{float(e.n_rows)}"
                )
        return "\n".join(lines) + ("\n" if lines else "")

    # -- the worker loop ------------------------------------------------------

    def _run(self) -> None:
        while True:
            try:
                req = self._queue.get(timeout=0.05)
            except queue_mod.Empty:
                if self._stop.is_set():
                    return
                continue
            batch = [req]
            rows = req.xq.shape[0]
            # hold the batch open for co-travellers until the deadline (or
            # until one max_batch bucket is already full).  An idle gap of
            # max_wait/5 flushes early: under sustained load arrivals are
            # closer than the gap and the batch fills to the deadline; under
            # light load the lone request doesn't pay the full wait.
            deadline = time.monotonic() + self.max_wait_ms / 1e3
            idle_gap = self.max_wait_ms / 5e3
            while rows < self.max_batch:
                try:  # drain whatever is already queued without blocking
                    nxt = self._queue.get_nowait()
                except queue_mod.Empty:
                    wait = min(deadline - time.monotonic(), idle_gap)
                    if wait <= 0:
                        break
                    try:
                        nxt = self._queue.get(timeout=wait)
                    except queue_mod.Empty:
                        break
                batch.append(nxt)
                rows += nxt.xq.shape[0]
            self._queue_gauge.set(self._queue.qsize())
            by_entry: dict[int, list[_Request]] = {}
            for r in batch:
                by_entry.setdefault(id(r.entry), []).append(r)
            for reqs in by_entry.values():
                try:
                    self._serve_entry(reqs[0].entry, reqs)
                except Exception as exc:  # keep the worker alive
                    for r in reqs:
                        if not r.future.done():
                            r.future.set_exception(exc)
                            with self._lock:
                                self._inflight -= 1

    def _serve_entry(self, entry: _ModelEntry, reqs: list[_Request]) -> None:
        lens = [r.xq.shape[0] for r in reqs]
        flat = reqs[0].xq if len(reqs) == 1 else np.concatenate(
            [r.xq for r in reqs], axis=0
        )
        total = flat.shape[0]
        tel_enabled = self._tel.enabled
        precision = getattr(entry.op, "precision", "f32")
        outs = []
        start = 0
        with self._tel.span("serve/batch", model=entry.name,
                            requests=len(reqs), rows=total):
            while start < total:
                stop = min(start + entry.max_batch, total)
                b = bucket_for(stop - start, entry.max_batch)
                padded = np.zeros((b, entry.d), flat.dtype)
                padded[: stop - start] = flat[start:stop]
                # the ONE device round trip: a warmed bucket shape in, host
                # scores out (np.asarray blocks on the device computation)
                out = np.asarray(entry.score(padded))[: stop - start]
                entry.occupancy.setdefault(b, [0, 0])
                entry.occupancy[b][0] += 1
                entry.occupancy[b][1] += stop - start
                labels = {"model": entry.name, "bucket": str(b)}
                _obs_counter("repro_serving_bucket_runs_total", labels,
                             help="fused bucket passes").inc()
                _obs_counter("repro_serving_bucket_rows_total", labels,
                             help="query rows served per bucket").inc(
                                 stop - start)
                if tel_enabled:
                    # one fused (bucket, n_train) kernel pass per run
                    record_tile_work(b, int(entry.op.n), entry.d, precision)
                outs.append(out)
                start = stop
        out = outs[0] if len(outs) == 1 else np.concatenate(outs, axis=0)
        t_done = time.monotonic()
        ofs = 0
        for r, ln in zip(reqs, lens):
            lat_ms = (t_done - r.t_arrival) * 1e3
            # stamp the measured submit->scatter latency on the future so
            # clients (the bench, the CLI) get per-request numbers for free
            r.future.latency_ms = lat_ms
            r.future.set_result(out[ofs: ofs + ln])
            ofs += ln
            entry.latency_hist.observe(lat_ms)
        entry.n_requests += len(reqs)
        entry.n_rows += total
        if entry.t_first is None:
            entry.t_first = reqs[0].t_arrival
        entry.t_last = t_done
        with self._lock:
            self._inflight -= len(reqs)


__all__ = [
    "ServingEngine",
    "bucket_for",
    "bucket_sizes",
    "load_model_artifact",
    "save_model_artifact",
]
