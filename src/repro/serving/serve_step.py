"""Serving steps: prefill + batched single-token decode for the LM stack,
plus the batched KRR prediction server for solved kernel models.

``make_serve_fns`` returns jit-ready (prefill, decode_step) closures over a
config; the decode step donates the cache so the KV buffers update in place.
``greedy_generate`` is the simple batched driver used by the serving example
and the smoke tests (temperature-0).

KRR serving does NOT live here: the batched predict closures are in
``repro.serving.krr_serve`` and the coalescing multi-model engine (request
batcher + registry + stats, docs/serving.md) in ``repro.serving.engine`` —
neither depends on the model stack.  ``make_krr_predict_fn`` is re-exported
here for convenience.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model_api import ArchConfig, get_model
from repro.serving.krr_serve import make_krr_predict_fn  # noqa: F401  (re-export)


def make_serve_fns(cfg: ArchConfig, jit: bool = True):
    impl = get_model(cfg)

    def prefill(params, batch):
        return impl.prefill(params, batch, cfg)

    def decode(params, cache, tokens):
        return impl.decode_step(params, cache, {"tokens": tokens}, cfg)

    if jit:
        prefill = jax.jit(prefill)
        decode = jax.jit(decode, donate_argnums=(1,))
    return prefill, decode


def greedy_generate(cfg: ArchConfig, params, batch: dict, max_new: int,
                    cache_len: int | None = None):
    """Prefill on `batch`, then greedy-decode `max_new` tokens."""
    impl = get_model(cfg)
    prefill, decode = make_serve_fns(cfg)
    logits, cache = prefill(params, batch)
    b = logits.shape[0]
    # cache["pos"] is the true prefill length (includes VLM/audio prefixes)
    total = cache_len or (int(cache["pos"]) + max_new)
    # re-home the prefill cache into a cache sized for generation
    big = impl.init_cache(cfg, b, total)
    big = _copy_cache(cache, big)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(max_new):
        out.append(tok)
        logits, big = decode(params, big, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def _copy_cache(src: dict, dst: dict) -> dict:
    out: dict[str, Any] = {}
    for k, v in dst.items():
        s = src.get(k)
        if s is None:
            out[k] = v
        elif hasattr(s, "shape") and s.shape == getattr(v, "shape", None):
            out[k] = s
        elif hasattr(s, "ndim") and s.ndim >= 3 and s.shape[:2] == v.shape[:2]:
            # sequence-extending copy: src fills the prefix of dst on axis 2
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                v, s.astype(v.dtype), 0, axis=2
            )
        else:
            out[k] = s
    return out
