"""Multi-pod distributed ASkotch — the paper's technique on the production
mesh, written with shard_map so every collective is explicit (DESIGN.md §4).

Layout: rows of X / y / iterates shard over the "rows" axes (("pod","data")
on the multi-pod mesh); the sampled block's b rows additionally shard over
"model", so one solver iteration runs 512-way parallel:

  per iteration (b = 50k, r = 100, n = 1e8, d = 9):
    psum      x_B gather            b*d f32        ~1.8 MB
    psum      z_B / y_B gathers     2*b f32        ~0.4 MB
    psum      Omega^T Y, B^T B      2*r^2 f32      ~80 KB
    allgather powering vectors      ~2*iters*b f32 ~4 MB
    psum      fused matvec partials b f32          ~0.2 MB
    allgather d_B                   b f32          ~0.2 MB
  local compute: O(n*b*d / 512) fused kernel-matvec  (~90 GFLOP/chip)

i.e. ~7 MB of wire traffic against ~90 GFLOP of MXU work per iteration —
the method is compute-bound by construction, which is exactly the property
the paper exploits on GPUs (§4.2) restated for a TPU pod.

The block's b x b Nystrom approximation is computed fully distributed:
sketch rows over "model", r x r Gram psums, eigh of B^T B replicated
(r=100 — trivial).  Sampling is i.i.d. uniform (with replacement) as in
Def. 9 — distinct-index sampling of 5e4 from 1e8 would cost an O(n log n)
sort per iteration for a ~1e-5 collision rate.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


class DistState(NamedTuple):
    w: jax.Array  # (n,) row-sharded
    v: jax.Array
    z: jax.Array
    key: jax.Array  # replicated
    sketch_res: jax.Array
    pv: jax.Array  # (b,) replicated — warm-start vector for the powering


@dataclasses.dataclass(frozen=True)
class DistKRRConfig:
    n: int
    d: int
    kernel: str = "rbf"
    sigma: float = 1.0
    lam_unscaled: float = 2e-7
    block_size: int = 50_000
    rank: int = 100
    accelerated: bool = True
    mu: float | None = None
    nu: float | None = None
    powering_iters: int = 10
    powering_warm_start: bool = False  # beyond-paper (§Perf): warm-start the
    #   powering with the previous block's eigenvector and run
    #   powering_warm_iters instead of powering_iters — blocks are
    #   statistically exchangeable under uniform sampling, so the top
    #   preconditioned eigenvector varies little between iterations
    powering_warm_iters: int = 3
    backend: str = "xla"  # local compute backend inside shards

    @property
    def lam(self) -> float:
        return self.n * self.lam_unscaled


def _axes(mesh: Mesh) -> tuple[tuple[str, ...], str]:
    rows = tuple(a for a in mesh.axis_names if a != "model")
    return rows, "model"


def make_dist_askotch_step(mesh: Mesh, cfg: DistKRRConfig):
    """Returns (step_fn, shardings) with step_fn jit-able under `mesh`.

    step_fn(state, x, y) -> state.  x: (n, d) f32, y: (n,) f32.
    """
    rows, model = _axes(mesh)
    n, b, r, d = cfg.n, cfg.block_size, cfg.rank, cfg.d
    lam = jnp.float32(cfg.lam)
    n_rows_shards = 1
    for a in rows:
        n_rows_shards *= mesh.shape[a]
    n_model = mesh.shape[model]
    assert n % n_rows_shards == 0 and b % n_model == 0
    n_loc, b_loc = n // n_rows_shards, b // n_model

    if cfg.accelerated:
        nu = cfg.nu if cfg.nu is not None else n / b
        mu = cfg.mu if cfg.mu is not None else min(float(lam), nu, 1.0 / nu)
        beta = 1.0 - (mu / nu) ** 0.5
        gamma = 1.0 / (mu * nu) ** 0.5
        alpha = 1.0 / (1.0 + gamma * nu)

    def local(state: DistState, x_l, y_l):
        row_id = jnp.float32(0)
        for i, a in enumerate(rows):  # linearized row-shard index
            stride = 1
            for a2 in rows[i + 1 :]:
                stride *= mesh.shape[a2]
            row_id = row_id + jax.lax.axis_index(a) * stride
        row_id = row_id.astype(jnp.int32)
        m_id = jax.lax.axis_index(model)
        lo = row_id * n_loc

        key, kb, knys, kl = jax.random.split(state.key, 4)
        idx = jax.random.randint(kb, (b,), 0, n)  # replicated draw

        # ---- gather x_B, y_B, z_B from the row shards ------------------------
        # One PACKED psum instead of three: fewer collective launches, and a
        # strict dependency chain (independent collectives can deadlock
        # thread-starved executors and serialize on real ICI anyway).
        local_pos = jnp.clip(idx - lo, 0, n_loc - 1)
        owned = ((idx >= lo) & (idx < lo + n_loc)).astype(jnp.float32)
        zref = state.z if cfg.accelerated else state.w
        packed = jnp.concatenate(
            [x_l[local_pos], y_l[local_pos, None], zref[local_pos, None]], axis=1
        )
        packed = jax.lax.psum(packed * owned[:, None], rows)  # (b, d+2)
        xb, yb, zb = packed[:, :d], packed[:, d], packed[:, d + 1]

        xb_l = jax.lax.dynamic_slice_in_dim(xb, m_id * b_loc, b_loc)  # (b/16, d)
        yb_l = jax.lax.dynamic_slice_in_dim(yb, m_id * b_loc, b_loc)
        zb_l = jax.lax.dynamic_slice_in_dim(zb, m_id * b_loc, b_loc)

        # ---- distributed Nystrom of K_BB (rows over "model") ----------------
        omega = jax.random.normal(knys, (b, r), jnp.float32)
        omega, _ = jnp.linalg.qr(omega)  # replicated (b x r, r = 100)
        omega_l = jax.lax.dynamic_slice_in_dim(omega, m_id * b_loc, b_loc)
        y_sketch = ops.kernel_matvec(
            xb_l, xb, omega, kernel=cfg.kernel, sigma=cfg.sigma, backend=cfg.backend
        )  # (b/16, r) local rows of K_BB @ Omega
        shift = jnp.float32(1.19e-7) * b  # eps * tr(K_BB); unit-diag kernels
        y_sketch = y_sketch + shift * omega_l
        gram = jax.lax.psum(omega_l.T @ y_sketch, model)  # (r, r)
        gram = 0.5 * (gram + gram.T)
        chol = jnp.linalg.cholesky(gram + 1e-6 * jnp.eye(r))
        b_mat = jax.scipy.linalg.solve_triangular(chol, y_sketch.T, lower=True).T
        btb = jax.lax.psum(b_mat.T @ b_mat, model)  # (r, r)
        evals, evecs = jnp.linalg.eigh(btb)
        evals, evecs = evals[::-1], evecs[:, ::-1]
        s_vals = jnp.sqrt(jnp.maximum(evals, 1e-30))
        u_l = b_mat @ (evecs / s_vals[None, :])  # (b/16, r) local rows of U
        lam_ny = jnp.maximum(evals - shift, 0.0)  # (r,)
        rho = lam + lam_ny[-1]  # damped (paper default)

        # ---- Woodbury applies (U rows sharded over "model") -----------------
        def inv_apply(g_l):  # (b/16,) -> (b/16,)
            utg = jax.lax.psum(u_l.T @ g_l, model)  # (r,)
            return u_l @ (utg / (lam_ny + rho)) + (g_l - u_l @ utg) / rho

        def invsqrt_apply(g_l):
            utg = jax.lax.psum(u_l.T @ g_l, model)
            return u_l @ (utg / jnp.sqrt(lam_ny + rho)) + (
                g_l - u_l @ utg
            ) / jnp.sqrt(rho)

        # ---- get_L: randomized powering (Algorithm 5) ------------------------
        def kbb_lam_mv(v_full):  # (b,) replicated -> (b/16,) local
            part = ops.kernel_matvec(
                xb_l, xb, v_full, kernel=cfg.kernel, sigma=cfg.sigma,
                backend=cfg.backend,
            )
            v_l = jax.lax.dynamic_slice_in_dim(v_full, m_id * b_loc, b_loc)
            return part + lam * v_l

        def power_body(carry, _):
            v_full, _last = carry
            v_l = jax.lax.dynamic_slice_in_dim(v_full, m_id * b_loc, b_loc)
            u1 = invsqrt_apply(v_l)
            u1_full = jax.lax.all_gather(u1, model, tiled=True)  # (b,)
            u2 = kbb_lam_mv(u1_full)
            u3 = invsqrt_apply(u2)
            stats = jax.lax.psum(jnp.stack([v_l @ u3, u3 @ u3]), model)  # packed
            lam_est, nrm = stats[0], jnp.sqrt(stats[1])
            v_new = jax.lax.all_gather(u3 / jnp.maximum(nrm, 1e-30), model, tiled=True)
            return (v_new, lam_est), None

        if cfg.powering_warm_start:
            v0 = state.pv
            n_power = cfg.powering_warm_iters
        else:
            v0 = jax.random.normal(kl, (b,), jnp.float32)
            n_power = cfg.powering_iters
        v0 = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-30)
        # unrolled powering: collectives inside a lax.scan share one HLO
        # channel id, which the in-process CPU communicator cannot
        # disambiguate across loop iterations; unrolling gives each collective
        # its own channel (and lets XLA pipeline them on real hardware)
        carry = (v0, jnp.float32(1.0))
        for _ in range(n_power):
            carry, _ = power_body(carry, None)
        v_last, step_l = carry
        eta = 1.0 / jnp.maximum(step_l, 1.0)

        # ---- the O(nb) fused matvec: g_B = (K_lam)_{B,:} z - y_B -------------
        part = ops.kernel_matvec(
            xb_l, x_l, zref, kernel=cfg.kernel, sigma=cfg.sigma, backend=cfg.backend
        )  # (b/16,) partial over this row shard
        g_l = jax.lax.psum(part, rows) + lam * zb_l - yb_l
        d_l = inv_apply(g_l)
        # packed gather: [d | g] in one collective, residual norm locally
        dg = jax.lax.all_gather(
            jnp.stack([d_l, g_l], axis=1), model, tiled=True
        )  # (b, 2)
        d_full = dg[:, 0]
        sk_res = jnp.linalg.norm(dg[:, 1])

        # ---- scatter updates on the owned rows -------------------------------
        upd = jnp.where(owned > 0, -eta * d_full, 0.0)
        if cfg.accelerated:
            w_new = state.z.at[local_pos].add(upd)
            v_new = (beta * state.v + (1.0 - beta) * state.z).at[local_pos].add(
                gamma * upd
            )
            z_new = alpha * v_new + (1.0 - alpha) * w_new
        else:
            w_new = state.w.at[local_pos].add(upd)
            v_new = w_new
            z_new = w_new
        return DistState(w=w_new, v=v_new, z=z_new, key=key, sketch_res=sk_res,
                         pv=v_last)

    vec = P(rows)
    state_specs = DistState(w=vec, v=vec, z=vec, key=P(), sketch_res=P(), pv=P())
    step = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(state_specs, P(rows, None), P(rows)),
        out_specs=state_specs,
        check_vma=False,
    )
    shardings = {
        "state": jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                              is_leaf=lambda s: isinstance(s, P)),
        "x": NamedSharding(mesh, P(rows, None)),
        "y": NamedSharding(mesh, P(rows)),
    }
    return step, shardings


def init_dist_state(cfg: DistKRRConfig, seed: int = 0) -> DistState:
    z = jnp.zeros((cfg.n,), jnp.float32)
    pv = jax.random.normal(jax.random.PRNGKey(seed + 7), (cfg.block_size,), jnp.float32)
    return DistState(
        w=z, v=z, z=z, key=jax.random.PRNGKey(seed),
        sketch_res=jnp.array(jnp.inf, jnp.float32), pv=pv,
    )


def abstract_dist_inputs(cfg: DistKRRConfig):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    state = DistState(
        w=jax.ShapeDtypeStruct((cfg.n,), jnp.float32),
        v=jax.ShapeDtypeStruct((cfg.n,), jnp.float32),
        z=jax.ShapeDtypeStruct((cfg.n,), jnp.float32),
        key=jax.ShapeDtypeStruct((2,), jnp.uint32),
        sketch_res=jax.ShapeDtypeStruct((), jnp.float32),
        pv=jax.ShapeDtypeStruct((cfg.block_size,), jnp.float32),
    )
    x = jax.ShapeDtypeStruct((cfg.n, cfg.d), jnp.float32)
    y = jax.ShapeDtypeStruct((cfg.n,), jnp.float32)
    return state, x, y
