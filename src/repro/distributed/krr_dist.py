"""Distributed KRR solvers — the paper's methods on a production mesh,
built entirely from :class:`~repro.distributed.sharded_operator.
ShardedKernelOperator` composites (docs/architecture.md, layer 3).

Two solve paths share the operator layer and the mesh:

  * **ASkotch** (``make_dist_askotch_step`` / ``solve_askotch_dist``) — one
    fused shard_map per iteration whose body is operator composites: packed-
    psum block gather, distributed Nystrom, Woodbury applies, powering.
  * **PCG** (``solve_pcg_dist``) — the existing blocked multi-RHS CG loop
    (``core/blocked_cg.py``) driven by the operator's distributed
    ``k_lam_matvec``; the Nystrom preconditioner sketch is one distributed
    ``op.sketch`` pass.

Both are multi-RHS: a ``(n, t)`` Y (one-vs-all heads) yields a row-sharded
``(n, t)`` W, sharing block samples / preconditioners / kernel tiles across
heads exactly like the single-device stack.  Layout: rows of X / Y / iterates
shard over the non-"model" mesh axes (("pod", "data") on the multi-pod
mesh); block rows additionally shard over "model", so one ASkotch iteration
runs 512-way parallel:

  per iteration (b = 50k, r = 100, n = 1e8, d = 9, t heads):
    psum      packed x_B|y_B|z_B gather  b*(d+2t) f32   ~2.2 MB
    psum      Omega^T Y, B^T B           2*r^2 f32      ~80 KB
    allgather powering vectors           ~2*iters*b f32 ~4 MB
    psum      fused matvec partials      b*t f32        ~0.2 MB
    allgather packed [d_B | g_B]         2*b*t f32      ~0.4 MB
  local compute: O(n*b*d / 512) fused kernel-matvec  (~90 GFLOP/chip)

i.e. ~7 MB of wire traffic against ~90 GFLOP of MXU work per iteration —
the method is compute-bound by construction, which is exactly the property
the paper exploits on GPUs (§4.2) restated for a TPU pod.

Sampling is i.i.d. uniform (with replacement) as in Def. 9 — distinct-index
sampling of 5e4 from 1e8 would cost an O(n log n) sort per iteration for a
~1e-5 collision rate.  A mesh of total size 1 runs every code path with
no-op collectives, so the whole module is exercised by plain pytest.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.blocked_cg import blocked_cg
from repro.core.kernels import KERNEL_NAMES
from repro.core.krr import KRRProblem, residual_report, scaled_lam
from repro.core.nystrom import nystrom_from_sketch
from repro.core.operator import as_multirhs, maybe_squeeze
from repro.distributed.jax_compat import shard_map
from repro.distributed.sharded_operator import ShardedKernelOperator
from repro.kernels.precision import PRECISIONS

BACKENDS = ("auto", "xla", "pallas", "interpret")


class DistState(NamedTuple):
    w: jax.Array  # (n,) or (n, t) row-sharded
    v: jax.Array
    z: jax.Array
    key: jax.Array  # replicated
    sketch_res: jax.Array
    pv: jax.Array  # (b,) replicated — warm-start vector for the powering


@dataclasses.dataclass(frozen=True)
class DistKRRConfig:
    n: int
    d: int
    kernel: str | tuple[str, ...] = "rbf"
    sigma: float | tuple[float, ...] = 1.0
    weights: tuple[float, ...] | None = None  # multi-kernel combination
    lam_unscaled: float = 2e-7
    block_size: int = 50_000
    rank: int = 100
    heads: int = 1  # t right-hand sides (one-vs-all); 1 -> 1-D iterates
    accelerated: bool = True
    mu: float | None = None
    nu: float | None = None
    powering_iters: int = 10
    powering_warm_start: bool = False  # beyond-paper (§Perf): warm-start the
    #   powering with the previous block's eigenvector and run
    #   powering_warm_iters instead of powering_iters — blocks are
    #   statistically exchangeable under uniform sampling, so the top
    #   preconditioned eigenvector varies little between iterations
    powering_warm_iters: int = 3
    backend: str = "xla"  # local compute backend inside shards
    precision: str = "f32"  # kernel tile-compute policy: "f32" | "bf16"

    def __post_init__(self) -> None:
        # fail fast with the accepted values, in the solver_api
        # METHOD_OPTIONS style, instead of leaking into shape/key errors
        for field, minimum in (("n", 1), ("d", 1), ("block_size", 1),
                               ("rank", 1), ("heads", 1),
                               ("powering_iters", 1), ("powering_warm_iters", 1)):
            v = getattr(self, field)
            if not isinstance(v, int) or v < minimum:
                raise ValueError(
                    f"DistKRRConfig.{field} = {v!r} invalid; accepted: "
                    f"integers >= {minimum}"
                )
        if isinstance(self.kernel, tuple):
            # a kernel tuple is a weighted-sum combination; validation of the
            # names/sigmas/weights triple lives in ONE place
            from repro.core.multikernel import canonical_kernels

            canonical_kernels(self.kernel, self.sigma, self.weights)
        elif self.kernel not in KERNEL_NAMES:
            raise ValueError(
                f"DistKRRConfig.kernel = {self.kernel!r} invalid; accepted: "
                f"{KERNEL_NAMES} or a tuple of them"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"DistKRRConfig.backend = {self.backend!r} invalid; "
                f"accepted: {BACKENDS}"
            )
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"DistKRRConfig.precision = {self.precision!r} invalid; "
                f"accepted: {PRECISIONS}"
            )
        sig = self.sigma if isinstance(self.sigma, tuple) else (self.sigma,)
        if not all(s > 0 for s in sig):
            raise ValueError(
                f"DistKRRConfig.sigma = {self.sigma!r} invalid; accepted: "
                f"positive floats (or a per-kernel tuple of them)"
            )
        if not self.lam_unscaled > 0:
            raise ValueError(
                f"DistKRRConfig.lam_unscaled = {self.lam_unscaled!r} invalid; "
                f"accepted: positive floats"
            )
        if self.rank > self.block_size:
            raise ValueError(
                f"DistKRRConfig.rank = {self.rank} invalid; accepted: "
                f"rank <= block_size (= {self.block_size})"
            )
        for field in ("mu", "nu"):
            v = getattr(self, field)
            if v is not None and not v > 0:
                raise ValueError(
                    f"DistKRRConfig.{field} = {v!r} invalid; accepted: "
                    f"None or positive floats"
                )

    @property
    def lam(self) -> float:
        return scaled_lam(self.n, self.lam_unscaled)


def _operator_for(mesh: Mesh, cfg: DistKRRConfig) -> ShardedKernelOperator:
    """Unbound operator carrying (mesh, kernel config) for the step body."""
    return ShardedKernelOperator(
        mesh=mesh, kernel=cfg.kernel, sigma=cfg.sigma, backend=cfg.backend,
        weights=cfg.weights, precision=cfg.precision,
    )


def make_dist_askotch_step(mesh: Mesh, cfg: DistKRRConfig):
    """Returns (step_fn, shardings) with step_fn jit-able under `mesh`.

    step_fn(state, x, y) -> state.  x: (n, d) f32; y: (n,) f32 when
    cfg.heads == 1, else (n, t).  The body is ONE shard_map composed of
    ShardedKernelOperator shard-level composites — no hand-rolled
    collectives, no direct kernel dispatch.
    """
    op = _operator_for(mesh, cfg)
    rows = op.rows
    n, b, r, t = cfg.n, cfg.block_size, cfg.rank, cfg.heads
    lam = jnp.float32(cfg.lam)
    if n % op.n_row_shards:
        raise ValueError(
            f"n = {n} does not shard over {op.n_row_shards} row shard(s) of "
            f"mesh axes {rows}; accepted: n divisible by the row-axis product"
        )
    if b % op.n_model:
        raise ValueError(
            f"block_size = {b} does not shard over {op.n_model} model "
            f"shard(s); accepted: multiples of {op.n_model}"
        )

    if cfg.accelerated:
        nu = cfg.nu if cfg.nu is not None else n / b
        mu = cfg.mu if cfg.mu is not None else min(float(lam), nu, 1.0 / nu)
        beta = 1.0 - (mu / nu) ** 0.5
        gamma = 1.0 / (mu * nu) ** 0.5
        alpha = 1.0 / (1.0 + gamma * nu)

    as2d = (lambda a: a) if t > 1 else (lambda a: a[:, None])
    like_y = (lambda a: a) if t > 1 else (lambda a: a[:, 0])

    def local(state: DistState, x_l, y_l):
        key, kb, knys, kl = jax.random.split(state.key, 4)
        idx = jax.random.randint(kb, (b,), 0, n)  # replicated draw
        zref = state.z if cfg.accelerated else state.w

        # ---- gather x_B, y_B, z_B from the row shards (ONE packed psum) ----
        (xb, yb, zb), owned, local_pos = op.shard_gather_rows(
            x_l, idx, (y_l, zref)
        )
        yb_l = op.shard_block_slice(as2d(yb))  # (b/M, t)
        zb_l = op.shard_block_slice(as2d(zb))

        # ---- distributed Nystrom of K_BB (U rows over "model") -------------
        u_l, lam_ny = op.shard_block_nystrom(xb, r, knys)
        rho = lam + lam_ny[-1]  # damped (paper default)

        # ---- get_L: randomized powering (Algorithm 5) -----------------------
        if cfg.powering_warm_start:
            v0 = state.pv
            n_power = cfg.powering_warm_iters
        else:
            v0 = jax.random.normal(kl, (b,), jnp.float32)
            n_power = cfg.powering_iters
        pv, step_l = op.shard_block_powering(
            xb, u_l, lam_ny, rho, lam, v0, n_power
        )
        eta = 1.0 / jnp.maximum(step_l, 1.0)

        # ---- the O(nbt) fused matvec: G_B = (K_lam)_{B,:} Z - Y_B -----------
        # one kernel-tile pass over this row shard serves all t heads
        xb_l = op.shard_block_slice(xb)
        part = op.shard_row_block_matvec(x_l, xb_l, zref)  # (b/M[, t])
        g_l = as2d(part) + lam * zb_l - yb_l
        d_l = op.shard_woodbury_apply(u_l, lam_ny, rho, g_l)  # (b/M, t)
        # packed gather: [D | G] in one collective, residual norm locally
        dg = op.model_all_gather(jnp.concatenate([d_l, g_l], axis=1))
        d_full = dg[:, :t]  # (b, t)
        sk_res = jnp.linalg.norm(dg[:, t:])

        # ---- scatter updates on the owned rows -------------------------------
        upd = like_y(jnp.where(owned[:, None] > 0, -eta * d_full, 0.0))
        if cfg.accelerated:
            w_new = state.z.at[local_pos].add(upd)
            v_new = (beta * state.v + (1.0 - beta) * state.z).at[local_pos].add(
                gamma * upd
            )
            z_new = alpha * v_new + (1.0 - alpha) * w_new
        else:
            w_new = state.w.at[local_pos].add(upd)
            v_new = w_new
            z_new = w_new
        return DistState(w=w_new, v=v_new, z=z_new, key=key, sketch_res=sk_res,
                         pv=pv)

    vec = op.vec_spec(1 if t == 1 else 2)
    state_specs = DistState(w=vec, v=vec, z=vec, key=P(), sketch_res=P(), pv=P())
    step = shard_map(
        local,
        mesh=mesh,
        in_specs=(state_specs, P(rows, None), vec),
        out_specs=state_specs,
    )
    shardings = {
        "state": jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                              is_leaf=lambda s: isinstance(s, P)),
        "x": NamedSharding(mesh, P(rows, None)),
        "y": NamedSharding(mesh, vec),
    }
    return step, shardings


def init_dist_state(cfg: DistKRRConfig, seed: int = 0) -> DistState:
    shape = (cfg.n,) if cfg.heads == 1 else (cfg.n, cfg.heads)
    z = jnp.zeros(shape, jnp.float32)
    pv = jax.random.normal(jax.random.PRNGKey(seed + 7), (cfg.block_size,), jnp.float32)
    return DistState(
        w=z, v=z, z=z, key=jax.random.PRNGKey(seed),
        sketch_res=jnp.array(jnp.inf, jnp.float32), pv=pv,
    )


def abstract_dist_inputs(cfg: DistKRRConfig):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    shape = (cfg.n,) if cfg.heads == 1 else (cfg.n, cfg.heads)
    vec = jax.ShapeDtypeStruct(shape, jnp.float32)
    state = DistState(
        w=vec, v=vec, z=vec,
        key=jax.ShapeDtypeStruct((2,), jnp.uint32),
        sketch_res=jax.ShapeDtypeStruct((), jnp.float32),
        pv=jax.ShapeDtypeStruct((cfg.block_size,), jnp.float32),
    )
    x = jax.ShapeDtypeStruct((cfg.n, cfg.d), jnp.float32)
    return state, x, vec


# ---------------------------------------------------------------------------
# solve drivers (the mesh= path behind core.solver_api.solve)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistSolveResult:
    w: jax.Array  # (n,) or (n, t) global array, row-sharded on op.mesh
    iters: int
    history: list[dict]
    converged: bool
    wall_time_s: float
    op: ShardedKernelOperator  # bound operator — serving/predict reuse it


def _bind(problem: KRRProblem, mesh: Mesh, backend: str) -> ShardedKernelOperator:
    return ShardedKernelOperator.bind(
        mesh, problem.x, kernel=problem.kernel, sigma=problem.sigma,
        backend=backend, weights=problem.weights,
        precision=problem.precision,
    )


def solve_askotch_dist(
    problem: KRRProblem,
    mesh: Mesh,
    *,
    accelerated: bool = True,
    block_size: int | None = None,
    rank: int = 100,
    mu: float | None = None,
    nu: float | None = None,
    powering_iters: int = 10,
    backend: str = "xla",
    max_iters: int = 500,
    tol: float = 1e-8,
    eval_every: int = 25,
    seed: int = 0,
    time_budget_s: float | None = None,
) -> DistSolveResult:
    """Mesh-distributed (A)Skotch with the same driver contract as
    ``core.askotch.solve``: jitted steps + periodic full-residual evaluation,
    multi-RHS throughout.  W stays row-sharded; predictions flow through the
    returned bound operator."""
    t0 = time.perf_counter()
    op0 = ShardedKernelOperator(mesh=mesh, backend=backend)
    b = block_size if block_size is not None else max(problem.n // 100, 1)
    b = int(min(max(b, rank + 8), problem.n))
    b += (-b) % op0.n_model  # round up so block rows shard over "model"
    cfg = DistKRRConfig(
        n=problem.n, d=problem.x.shape[1], kernel=problem.kernel,
        sigma=problem.sigma, weights=problem.weights,
        lam_unscaled=problem.lam_unscaled,
        block_size=b, rank=min(rank, b), heads=problem.t,
        accelerated=accelerated, mu=mu, nu=nu, powering_iters=powering_iters,
        backend=backend, precision=problem.precision,
    )
    step, sh = make_dist_askotch_step(mesh, cfg)
    bound = _bind(problem, mesh, backend)
    # the step's iterates follow cfg.heads: a (n, 1) y is the t = 1 case and
    # solves as 1-D (the column is restored on the way out)
    y_in = problem.y[:, 0] if (problem.y.ndim == 2 and problem.t == 1) else problem.y
    y = jax.device_put(y_in, sh["y"])
    x = bound.x
    state = jax.device_put(init_dist_state(cfg, seed), sh["state"])
    jstep = jax.jit(step)

    history: list[dict] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        state = jstep(state, x, y)
        if it % eval_every == 0 or it == max_iters:
            rel_agg, rel_heads = residual_report(bound, y, cfg.lam, state.w)
            history.append({
                "iter": it,
                "rel_residual": float(rel_agg),
                "rel_residual_per_head": [float(v) for v in rel_heads],
                "sketch_res": float(state.sketch_res),
                "time_s": time.perf_counter() - t0,
            })
            if bool(jnp.all(rel_heads < tol)):
                converged = True
                break
        if time_budget_s is not None and time.perf_counter() - t0 > time_budget_s:
            break
    w = state.w if y_in is problem.y else state.w[:, None]
    return DistSolveResult(
        w=w, iters=it, history=history, converged=converged,
        wall_time_s=time.perf_counter() - t0, op=bound,
    )


def solve_pcg_dist(
    problem: KRRProblem,
    mesh: Mesh,
    *,
    precond: str = "nystrom",
    rank: int = 100,
    rho_mode: str = "damped",
    backend: str = "xla",
    max_iters: int = 200,
    tol: float = 1e-8,
    seed: int = 0,
    time_budget_s: float | None = None,
) -> DistSolveResult:
    """Mesh-distributed blocked PCG on (K + lam I) W = Y.

    The iteration is the SAME ``core.blocked_cg`` loop every single-device
    CG-family solver uses — the only distributed pieces are the operator's
    ``k_lam_matvec`` (explicit collectives inside) and the one ``op.sketch``
    pass that builds the Nystrom preconditioner.  Columns that reach ``tol``
    freeze exactly as on one device.
    """
    t0 = time.perf_counter()
    if precond not in ("nystrom", "identity"):
        raise ValueError(
            f"unknown distributed preconditioner {precond!r}; accepted: "
            f"('nystrom', 'identity')"
        )
    lam = jnp.float32(problem.lam)
    bound = _bind(problem, mesh, backend)
    y2, squeeze = as_multirhs(problem.y)
    y_sh = jax.device_put(y2, bound.sharding(2))

    pinv = None
    if precond == "nystrom":
        r = min(rank, problem.n)
        omega = jax.random.normal(jax.random.PRNGKey(seed), (problem.n, r),
                                  jnp.float32)
        omega, _ = jnp.linalg.qr(omega)
        omega = jax.device_put(omega, bound.sharding(2))
        f = nystrom_from_sketch(bound.sketch(omega), omega, bound.trace_est())
        rho = lam + f.lam[-1] if rho_mode == "damped" else lam
        coeff = (f.lam[-1] + rho) / (f.lam + rho)

        def apply(v: jax.Array) -> jax.Array:
            utv = f.u.T @ v
            return f.u @ (utv * coeff[:, None]) + (v - f.u @ utv)

        pinv = jax.jit(apply)

    matvec = jax.jit(lambda v: bound.k_lam_matvec(v, lam))
    res = blocked_cg(
        matvec, y_sh, pinv, max_iters=max_iters, tol=tol, t0=t0,
        time_budget_s=time_budget_s,
    )
    return DistSolveResult(
        w=maybe_squeeze(res.x, squeeze), iters=res.iters, history=res.history,
        converged=res.converged, wall_time_s=time.perf_counter() - t0,
        op=bound,
    )
