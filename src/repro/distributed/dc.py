"""Divide-and-conquer KRR: full local solves per shard, zero collectives.

The communication-avoiding tier (DC-KRR / BKRR — You, Demmel, Hsieh &
Vuduc 2018).  Where the ``ShardedKernelOperator`` path pays a psum +
all_gather on EVERY matvec of an iterative solve, :func:`solve_dc`
partitions the training set into k shards (``distributed.partition``),
runs a complete, unmodified local solve per shard through a plain
per-shard ``KernelOperator`` — every ``solve()`` method, kernel tuple,
and precision policy works unchanged — and combines the per-shard
predictions at query time.  The shards never exchange a byte during
iteration: the only cross-device event is the final host gather of k
weight vectors.  ``info["collective_dispatches"]`` records the measured
``repro_collective_dispatch_total`` delta across the solve (asserted
== 0 in tests and reported by ``bench_dist_scaling.py``).

Cost model: a local solve is O((n/k)^2) kernel work per shard — k shards
in parallel on k devices is O(n^2 / k^2) critical-path work and ZERO
collective traffic, vs the sharded path's O(n^2 / D) per-device work
PLUS two collectives per iteration.  The price is approximation: local
models never see cross-shard interactions, so test error degrades as k
grows — the accuracy/communication frontier ``bench_dist_scaling.py``
measures.  At k = 1 the tier degenerates EXACTLY (bit-for-bit) to the
plain solver.

Device parallelism: with ``mesh=``, shard s is pinned to mesh device
``s % D`` (inputs ``device_put`` there, one host thread per device
driving its local solves).  A shard_map would buy nothing here — the
body of a local solve is a host-driven adaptive loop (stopping tests,
telemetry, per-iteration traces), not a single traceable computation,
and with zero cross-shard communication a mapped axis has no collectives
to fuse; explicit placement gives the same device parallelism while
keeping every solver feature intact.  Without a mesh the shards run
sequentially on the default device — same results, keyed by shard index
(a 1-device mesh is bit-identical to the sequential fallback; tested).

Combiners (``dc_combiner=``):

  * ``"uniform"`` — plain average, weight 1/k per shard (BKRR).
  * ``"softmax"`` — per-query weights ``softmax_s(-||x - c_s||^2 /
    (2 tau^2))`` over the partition centers c_s: queries trust the local
    model whose region they fall in.  ``tau`` defaults to the mean
    pairwise center distance.

Both produce weights that sum to 1 per query; k = 1 short-circuits to
the single shard's prediction verbatim.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.partition import (
    PARTITION_KINDS,
    Partition,
    chunked_sq_dists,
    make_partition,
)
from repro.obs import metrics as obs_metrics
from repro.obs.telemetry import as_telemetry

#: accepted prediction combiners (the ``dc_combiner=`` vocabulary)
COMBINERS = ("uniform", "softmax")

_COLLECTIVE_METRIC = "repro_collective_dispatch_total"


def collective_dispatch_delta(
    before: dict[str, float], after: dict[str, float]
) -> float:
    """Total ``repro_collective_dispatch_total`` growth between two metric
    :func:`repro.obs.metrics.snapshot` dicts — the DC tier's headline
    number (it stays 0.0; the sharded path pays two per iteration)."""
    return sum(
        v
        for k, v in obs_metrics.diff(before, after).items()
        if k.startswith(_COLLECTIVE_METRIC)
    )


def combiner_weights(
    part: Partition,
    xq,
    combiner: str = "uniform",
    softmax_temp: float | None = None,
) -> np.ndarray:
    """Per-query shard weights, a (q, k) row-stochastic array.

    ``"uniform"`` ignores the queries (every row is 1/k).  ``"softmax"``
    weights shard s by ``softmax_s(-||x - c_s||^2 / (2 tau^2))`` with
    ``tau = softmax_temp`` (default: mean pairwise distance between the
    partition centers — the natural length scale of the partition).
    """
    if combiner not in COMBINERS:
        raise ValueError(
            f"unknown combiner {combiner!r}; accepted: {COMBINERS}"
        )
    xq = np.asarray(xq, np.float32)
    q, k = xq.shape[0], part.k
    if combiner == "uniform" or k == 1:
        return np.full((q, k), 1.0 / k, np.float32)
    if softmax_temp is None:
        c2 = chunked_sq_dists(part.centers, part.centers)
        off = c2[~np.eye(k, dtype=bool)]
        softmax_temp = float(np.sqrt(np.maximum(off, 0.0)).mean()) or 1.0
    logits = -chunked_sq_dists(xq, part.centers) / (
        2.0 * float(softmax_temp) ** 2
    )
    logits -= logits.max(axis=1, keepdims=True)
    w = np.exp(logits)
    return (w / w.sum(axis=1, keepdims=True)).astype(np.float32)


@dataclasses.dataclass
class DCSolveResult:
    """Everything :func:`solve_dc` produced: the partition, the per-shard
    ``SolveOutput``s (each a full local solve), and the combined
    ``predict_fn``.  ``w`` scatters per-shard weights back to the original
    row order — zeros never mix across shards because row i's weight lives
    only in shard ``assignments[i]``."""

    partition: Partition
    shard_outputs: list
    w: jax.Array | None
    predict_fn: Any
    info: dict[str, Any]
    history: list[dict]


def _shard_problem(problem, idx: np.ndarray, device=None):
    take = jnp.asarray(idx)
    x, y = problem.x[take], problem.y[take]
    if device is not None:
        x, y = jax.device_put(x, device), jax.device_put(y, device)
    return dataclasses.replace(problem, x=x, y=y)


def solve_dc(
    problem,
    *,
    shards: int = 2,
    partition: str | Partition = "random",
    combiner: str = "uniform",
    method: str = "askotch",
    softmax_temp: float | None = None,
    mesh=None,
    telemetry=None,
    **kw,
) -> DCSolveResult:
    """Divide-and-conquer solve: k independent local solves, combined at
    query time, with zero collective traffic in between.

    Args:
      problem: the :class:`~repro.core.krr.KRRProblem` (multi-RHS heads,
        kernel tuples, and ``precision`` all ride through unchanged —
        each shard is just a smaller problem of the same shape).
      shards: shard count k (ignored when ``partition`` is already a
        :class:`Partition`); k = 1 reproduces the plain solver bit-for-bit.
      partition: a :data:`~repro.distributed.partition.PARTITION_KINDS`
        name or a prebuilt :class:`Partition` (e.g. round-tripped through
        ``Partition.from_json``).
      combiner: one of :data:`COMBINERS`.
      method: the INNER solver run per shard — any single-device
        ``solve()`` method except ``"dc"`` itself.
      softmax_temp: temperature for the softmax combiner (default: mean
        pairwise center distance).
      mesh: optional ``jax.sharding.Mesh``; shard s runs on device
        ``s % D`` (explicit placement, no collectives — see module
        docstring for why this is not a shard_map).
      telemetry: optional ``repro.obs.Telemetry`` — records a ``solve/dc``
        span around the tier and a ``dc/shard`` span per local solve.
      **kw: inner-method options, validated fail-fast by the inner
        ``solve()`` against ``METHOD_OPTIONS[method]``.

    Returns:
      A :class:`DCSolveResult`; ``info["collective_dispatches"]`` is the
      measured collective-dispatch delta (0.0 — the point of the tier).
    """
    from repro.core.solver_api import METHODS, solve  # lazy: avoids cycle

    if method == "dc" or method not in METHODS:
        inner = sorted(set(METHODS) - {"dc"})
        raise ValueError(
            f"dc_method {method!r} is not a valid inner solver; accepted: "
            f"{inner}"
        )
    if problem.kernel == "precomputed":
        raise ValueError(
            "kernel='precomputed' cannot run through method='dc': a shard's "
            "subproblem re-slices raw features into a local KernelOperator — "
            "pass the features with a kernel name instead"
        )
    if isinstance(partition, Partition):
        part = partition
        if part.n != problem.n:
            raise ValueError(
                f"partition covers {part.n} rows but the problem has "
                f"{problem.n}"
            )
    elif partition in PARTITION_KINDS:
        part = make_partition(
            problem.x, shards, kind=partition, seed=int(kw.get("seed", 0) or 0)
        )
    else:
        raise ValueError(
            f"unknown partition {partition!r}; accepted: {PARTITION_KINDS} "
            f"or a Partition instance"
        )
    if combiner not in COMBINERS:
        raise ValueError(
            f"unknown combiner {combiner!r}; accepted: {COMBINERS}"
        )

    tel = as_telemetry(telemetry)
    shard_idx = part.shard_indices()
    k = part.k
    devices = list(mesh.devices.flat) if mesh is not None else [None]

    def run_shard(s: int):
        sub = _shard_problem(problem, shard_idx[s], devices[s % len(devices)])
        with tel.span("dc/shard", shard=s, n=sub.n, method=method):
            return solve(sub, method, telemetry=telemetry, **kw)

    before = obs_metrics.snapshot()
    t0 = time.perf_counter()
    with tel.span("solve/dc", n=problem.n, t=problem.t, shards=k,
                  partition=part.kind, combiner=combiner, method=method,
                  mesh=dict(mesh.shape) if mesh is not None else None):
        if mesh is not None and len(devices) > 1 and k > 1:
            # one host thread per device drives its shards' local solves
            with ThreadPoolExecutor(
                max_workers=min(len(devices), k)
            ) as pool:
                outputs = list(pool.map(run_shard, range(k)))
        else:
            outputs = [run_shard(s) for s in range(k)]
    wall = time.perf_counter() - t0
    collectives = collective_dispatch_delta(before, obs_metrics.snapshot())

    # scatter per-shard weights back to original row order when the inner
    # method produces one weight per training row (everything but falkon,
    # whose w lives on m inducing points — predictions still combine fine)
    w_global = None
    if all(
        np.ndim(out.w) >= 1 and out.w.shape[0] == len(idx)
        for out, idx in zip(outputs, shard_idx)
    ):
        wg = np.zeros((problem.n,) + tuple(np.shape(outputs[0].w)[1:]),
                      np.float32)
        for out, idx in zip(outputs, shard_idx):
            wg[idx] = np.asarray(out.w, np.float32)
        w_global = jnp.asarray(wg)

    shard_predict = [out.predict_fn for out in outputs]

    def predict_fn(xt):
        if k == 1:  # exact single-shard degeneracy: the plain prediction
            return shard_predict[0](xt)
        wgt = combiner_weights(part, xt, combiner, softmax_temp)
        preds = [np.asarray(fn(xt), np.float32) for fn in shard_predict]
        extra = (1,) * (preds[0].ndim - 1)
        combined = sum(
            wgt[:, s].reshape((-1,) + extra) * preds[s] for s in range(k)
        )
        return jnp.asarray(combined)

    per_shard_iters = [int(out.info.get("iters", 0)) for out in outputs]
    history: list[dict] = []
    for s, out in enumerate(outputs):
        rec = {"shard": s, "n": int(len(shard_idx[s])),
               "iters": per_shard_iters[s]}
        if out.history:
            rec["rel_residual"] = out.history[-1].get("rel_residual")
        history.append(rec)
    shard_rels = [
        r["rel_residual"] for r in history if r.get("rel_residual") is not None
    ]
    # aggregate record last: consumers that read history[-1]["rel_residual"]
    # (krr_solve's summary line) see the worst local residual
    history.append({
        "shard": None, "iters": max(per_shard_iters, default=0),
        "rel_residual": max(shard_rels) if shard_rels else None,
    })
    info = {
        "shards": k,
        "partition": part.kind,
        "combiner": combiner,
        "inner_method": method,
        "per_shard_iters": per_shard_iters,
        "converged": all(
            bool(out.info.get("converged", True)) for out in outputs
        ),
        "wall_time_s": wall,
        "collective_dispatches": collectives,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "t": problem.t,
    }
    return DCSolveResult(
        partition=part,
        shard_outputs=outputs,
        w=w_global,
        predict_fn=predict_fn,
        info=info,
        history=history,
    )
