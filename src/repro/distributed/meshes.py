"""Mesh helpers + logical-axis translation.

Models annotate arrays with *logical* axes ("dp", "fsdp", "tp"); the launcher
installs a rule set mapping them onto whatever physical mesh is live:

  single pod (16, 16) ("data", "model"):   dp=("data",), fsdp="data", tp="model"
  multi-pod (2, 16, 16) ("pod","data","model"):
                                            dp=("pod","data"), fsdp="data", tp="model"

Keeping models in logical axes is what makes elastic re-meshing (checkpoint
restore onto a different topology) a pure launcher concern.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import jax_compat

_STATE = threading.local()


def parse_mesh_spec(spec: str) -> tuple[int, int]:
    """Parse a ``--mesh ROWSxMODEL`` spec ("4x2" -> (4, 2)).

    "auto" (or "") puts every local device on the row axis — the right
    default for KRR, whose workhorse parallelism is row sharding.
    """
    if spec in ("auto", ""):
        return (len(jax.devices()), 1)
    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() and int(p) > 0 for p in parts):
        raise ValueError(
            f"mesh spec {spec!r} invalid; accepted: 'ROWSxMODEL' with "
            f"positive integers (e.g. '4x2') or 'auto'"
        )
    return (int(parts[0]), int(parts[1]))


def make_solver_mesh(spec: str | tuple[int, int] | None = None) -> Mesh:
    """("data", "model") mesh for distributed KRR solves.

    ``spec``: "ROWSxMODEL" string, (rows, model) tuple, or None/"auto" for
    all local devices on rows.  A (1, 1) mesh is always valid — size-1 axes
    make every collective a no-op, so the distributed code path runs in a
    plain single-device process (the pytest fallback).
    """
    if spec is None or isinstance(spec, str):
        rows, model = parse_mesh_spec(spec if isinstance(spec, str) else "auto")
    else:
        rows, model = spec
    return jax_compat.make_mesh((rows, model), ("data", "model"))


def default_rules(mesh: Mesh) -> dict[str, Any]:
    axes = mesh.axis_names
    if "pod" in axes:
        return {"dp": ("pod", "data"), "fsdp": "data", "tp": "model"}
    if "data" in axes:
        return {"dp": ("data",), "fsdp": "data", "tp": "model"}
    # degenerate single-axis test meshes
    ax = axes[0]
    return {"dp": (ax,), "fsdp": ax, "tp": None}


@contextlib.contextmanager
def logical_rules(rules: dict[str, Any]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def current_rules() -> dict[str, Any] | None:
    return getattr(_STATE, "rules", None)


def to_physical(spec: P, rules: dict[str, Any] | None = None) -> P:
    """Translate a logical PartitionSpec to physical mesh axes."""
    rules = rules or current_rules()
    if rules is None:
        return spec
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            phys: list[str] = []
            for e in entry:
                r = rules.get(e, e)
                if r is None:
                    continue
                phys.extend(r if isinstance(r, (tuple, list)) else (r,))
            out.append(tuple(phys) if phys else None)
        else:
            r = rules.get(entry, entry)
            if r is None:
                out.append(None)
            elif isinstance(r, (tuple, list)):
                out.append(tuple(r))
            else:
                out.append(r)
    return P(*out)


def logical_constraint(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint in logical axes; no-op without rules/mesh."""
    rules = current_rules()
    if rules is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, to_physical(spec, rules))
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (unit tests on 1 device)


def tree_to_physical(spec_tree, rules: dict[str, Any] | None = None):
    return jax.tree.map(
        lambda s: to_physical(s, rules),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def named_shardings(mesh: Mesh, spec_tree, rules: dict[str, Any] | None = None):
    rules = rules or default_rules(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, to_physical(s, rules)),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def sanitize_pspec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharded axes that don't divide the dim (e.g. batch=1 decode)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def sanitized_shardings(mesh: Mesh, spec_tree, struct_tree,
                        rules: dict[str, Any] | None = None):
    """named_shardings + per-dim divisibility sanitation vs. struct shapes."""
    rules = rules or default_rules(mesh)

    def one(spec, struct):
        phys = to_physical(spec, rules)
        phys = sanitize_pspec(phys, tuple(struct.shape), mesh)
        return NamedSharding(mesh, phys)

    return jax.tree.map(
        one, spec_tree, struct_tree, is_leaf=lambda s: isinstance(s, P)
    )
