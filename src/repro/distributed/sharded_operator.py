"""ShardedKernelOperator — the KernelOperator contract over a row-sharded x.

``core.operator.KernelOperator`` made one object the owner of
``(kernel, sigma, backend, chunking)`` for every single-device solver.  This
layer restates the same four primitives — ``matvec``, ``row_block_matvec``,
``block``/``block_idx``, ``trace_est``, plus ``restrict``/``with_points`` —
over an ``x`` whose rows are sharded across the non-"model" axes of a
``jax.sharding.Mesh``.  Every collective is explicit (``psum`` /
``all_gather`` inside ``shard_map``); all local compute dispatches through a
plain per-shard :class:`KernelOperator`, so the xla/pallas/interpret kernel
backends — multi-RHS ``(n, t)`` included — come for free (docs/
architecture.md, layer 3).

Sharding contract (rows = every mesh axis except "model"):

  * ``x`` (n, d), iterates/RHS (n,) or (n, t)  — row-sharded ``P(rows, ...)``
  * block points ``a``/``b``, indices ``idx``, outputs of
    ``row_block_matvec``/``block``/``gather_rows``  — replicated ``P()``

Per-primitive collective cost (t RHS columns, S row shards, M model shards):

  primitive                 collectives                      wire bytes
  ------------------------  -------------------------------  -----------------
  matvec                    allgather x, v over rows;        n(d + t) + n_loc t
                            psum over model
  row_block_matvec          psum over rows (+ allgather      b t  (+ b t)
                            over model when M | b)
  block                     allgather over model             b_a b_b
  gather_rows / block_idx   ONE packed psum over rows        b (d + extras)
  trace_est                 none (unit-diagonal kernels)     0

The ``shard_*`` methods are the same composites exposed for use INSIDE an
ambient ``shard_map`` over ``mesh`` — ``distributed/krr_dist.py`` fuses a
whole ASkotch iteration into one shard_map body built from them (block
gather, distributed Nystrom, Woodbury applies, powering) without touching
``kernels.ops`` or hand-rolling collectives.

The tuning engine (``core/tune/engine.py``) runs its stacked per-sigma
solves against this operator through the same primitives a local
``KernelOperator`` exposes — ``matvec``/``matvec_cols`` for the fused
column block, ``sketch``/``sketch_components`` for the per-sigma Nystrom
factors — so every search policy (grid / random / successive halving,
with or without sigma-continuation) runs unchanged over a mesh: policies
only ever see host-side score arrays, and the engine's mid-solve rung
scoring is one more distributed ``matvec``.

A mesh of total size 1 degrades gracefully: every collective is a no-op and
all code paths run in a plain single-device pytest process.

Observability: each global-array primitive counts its collectives into
``repro_collective_dispatch_total{primitive=..., collective=...}``
(``repro.obs.metrics``).  Counts are **dispatch-level** — tallied in the
host-side ``call`` wrappers per primitive invocation, so a jitted caller
that traces a wrapper once still counts every dispatch, but collectives
fused inside someone else's shard_map body (the ``shard_*`` composites)
are not tallied here.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.operator import KernelOperator
from repro.distributed.jax_compat import shard_map
from repro.obs.metrics import counter as _obs_counter

MODEL_AXIS = "model"


def _count_collective(primitive: str, collective: str, count: int = 1) -> None:
    """Tally ``count`` dispatches of a collective inside ``primitive``."""
    if count:
        _obs_counter(
            "repro_collective_dispatch_total",
            labels={"primitive": primitive, "collective": collective},
            help="host-side dispatches of mesh collectives by primitive",
        ).inc(count)


def row_axes(mesh: Mesh) -> tuple[str, ...]:
    """Every mesh axis except "model" shards rows (("pod", "data") on the
    multi-pod mesh, ("data",) on solver meshes)."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


@dataclasses.dataclass(frozen=True)
class ShardedKernelOperator:
    """Mesh-aware linear-operator view of K = K(x, x).

    ``x`` is a global ``(n, d)`` array placed row-sharded on ``mesh`` (use
    :meth:`bind` to place a host array).  ``x`` may also be ``None`` — an
    *unbound* operator is the (mesh, kernel-config) view whose ``shard_*``
    composites serve solver-owned shard_map bodies that receive their x shard
    as an argument (``krr_dist.make_dist_askotch_step``).
    """

    mesh: Mesh
    x: jax.Array | None = None
    kernel: str | tuple[str, ...] = "rbf"
    sigma: float | tuple[float, ...] = 1.0
    backend: str = "auto"
    chunk_a: int = 4096
    chunk_b: int = 8192
    weights: tuple[float, ...] | None = None  # multi-kernel combination
    precision: str = "f32"  # tile-compute policy: "f32" | "bf16"

    def __post_init__(self) -> None:
        if isinstance(self.kernel, list):
            object.__setattr__(self, "kernel", tuple(self.kernel))
        if isinstance(self.sigma, list):
            object.__setattr__(self, "sigma", tuple(self.sigma))
        if self.weights is not None:
            object.__setattr__(
                self, "weights", tuple(float(w) for w in self.weights)
            )

    # -- construction --------------------------------------------------------

    @classmethod
    def bind(cls, mesh: Mesh, x: jax.Array, **cfg) -> "ShardedKernelOperator":
        """Place ``x`` row-sharded on ``mesh`` and return a bound operator."""
        op = cls(mesh=mesh, x=None, **cfg)
        n = x.shape[0]
        if n % op.n_row_shards != 0:
            raise ValueError(
                f"n = {n} rows do not shard evenly over {op.n_row_shards} row "
                f"shard(s) of mesh axes {op.rows}; pad the dataset or pick a "
                f"mesh whose row-axis product divides n"
            )
        x_sh = jax.device_put(x, NamedSharding(mesh, P(op.rows, None)))
        return dataclasses.replace(op, x=x_sh)

    # -- mesh/axis structure -------------------------------------------------

    @property
    def rows(self) -> tuple[str, ...]:
        """The mesh axes sharding rows (every axis except "model")."""
        return row_axes(self.mesh)

    @property
    def model(self) -> str | None:
        """The "model" axis name if the mesh has one, else None."""
        return MODEL_AXIS if MODEL_AXIS in self.mesh.axis_names else None

    @property
    def n_row_shards(self) -> int:
        """Total number of row shards S (product of the non-"model" axes)."""
        s = 1
        for a in self.rows:
            s *= self.mesh.shape[a]
        return s

    @property
    def n_model(self) -> int:
        """Size M of the "model" axis (1 when the mesh has none)."""
        return self.mesh.shape[MODEL_AXIS] if self.model else 1

    @property
    def n(self) -> int:
        """Global row count of the bound (row-sharded) x."""
        self._require_bound()
        return self.x.shape[0]

    @property
    def d(self) -> int:
        """Feature dimension of the row points."""
        self._require_bound()
        return self.x.shape[1]

    @property
    def shape(self) -> tuple[int, int]:
        """(n, n) — the global kernel matrix shape this operator applies."""
        return (self.n, self.n)

    @property
    def n_loc(self) -> int:
        """Rows per shard, n / S (bind() guarantees the division is exact)."""
        return self.n // self.n_row_shards

    def _require_bound(self) -> None:
        if self.x is None:
            raise ValueError(
                "operator is unbound (x=None); global-array primitives need "
                "a bound operator — use ShardedKernelOperator.bind(mesh, x)"
            )

    def vec_spec(self, ndim: int) -> P:
        """PartitionSpec of a row-sharded iterate/RHS: (n,) or (n, t)."""
        return P(self.rows) if ndim == 1 else P(self.rows, *([None] * (ndim - 1)))

    def sharding(self, ndim: int) -> NamedSharding:
        """NamedSharding for placing a (n, ...) row-aligned array."""
        return NamedSharding(self.mesh, self.vec_spec(ndim))

    def replicated(self) -> NamedSharding:
        """NamedSharding for fully-replicated (block-level) arrays."""
        return NamedSharding(self.mesh, P())

    # -- local views ---------------------------------------------------------

    def local_op(self, pts: jax.Array) -> KernelOperator:
        """Per-shard operator over ``pts`` — the ONLY kernel dispatch point
        in the distributed stack (kernels.ops via core.operator /
        core.multikernel).  A kernel TUPLE yields a per-shard
        ``WeightedSumKernelOperator``, which is how multi-kernel solves run
        on a mesh without any collective changes."""
        from repro.core.multikernel import make_operator

        return make_operator(
            pts, kernel=self.kernel, sigma=self.sigma, weights=self.weights,
            backend=self.backend, chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            precision=self.precision,
        )

    # -- derived operators ---------------------------------------------------

    def with_points(self, x_new: jax.Array) -> "ShardedKernelOperator":
        """Same configuration over a different (row-shardable) row set."""
        return ShardedKernelOperator.bind(
            self.mesh, x_new, kernel=self.kernel, sigma=self.sigma,
            backend=self.backend, chunk_a=self.chunk_a, chunk_b=self.chunk_b,
            weights=self.weights, precision=self.precision,
        )

    def restrict(self, idx: jax.Array) -> KernelOperator:
        """Operator over ``x[idx]`` (centers, dictionaries, sampled blocks).

        Sub-row-sets are small by construction, so the restriction is
        gathered (one packed psum) and returned as a *replicated* plain
        KernelOperator — downstream code is mesh-free from here on.
        """
        (xb,), _owned = self.gather_rows(idx)
        return self.local_op(xb)

    # -- shard-level composites (call INSIDE a shard_map over self.mesh) -----

    def shard_row_id(self) -> jax.Array:
        """Linearized row-shard index of the calling device."""
        rid = jnp.int32(0)
        for a in self.rows:
            rid = rid * self.mesh.shape[a] + jax.lax.axis_index(a)
        return rid.astype(jnp.int32)

    def shard_model_id(self) -> jax.Array:
        """"model"-axis index of the calling device (0 without the axis)."""
        return jax.lax.axis_index(self.model) if self.model else jnp.int32(0)

    def model_slice(self, arr: jax.Array, loc: int) -> jax.Array:
        """This model shard's row slice of a replicated (b, ...) array."""
        if self.n_model == 1:
            return arr
        return jax.lax.dynamic_slice_in_dim(arr, self.shard_model_id() * loc, loc)

    def model_all_gather(self, arr: jax.Array) -> jax.Array:
        """all_gather over "model" (no-op when the axis is absent/size 1)."""
        if self.n_model == 1:
            return arr
        return jax.lax.all_gather(arr, self.model, tiled=True)

    def model_psum(self, arr: jax.Array) -> jax.Array:
        """psum over "model" (no-op when the axis is absent/size 1)."""
        if self.n_model == 1:
            return arr
        return jax.lax.psum(arr, self.model)

    def shard_gather_rows(
        self, x_l: jax.Array, idx: jax.Array, extras: tuple[jax.Array, ...] = ()
    ) -> tuple[tuple[jax.Array, ...], jax.Array, jax.Array]:
        """Packed-psum gather of global rows ``idx`` from the row shards.

        ``x_l`` is this shard's (n_loc, d) rows; each extra is a row-aligned
        (n_loc,) or (n_loc, t) shard.  ONE psum moves x and every extra
        together (b * (d + sum t_i) f32): fewer collective launches, and a
        strict dependency chain (independent collectives can deadlock
        thread-starved executors and serialize on real ICI anyway).

        Returns ``((x_B, *extras_B), owned, local_pos)`` — the gathered rows
        replicated across the mesh, plus this shard's ownership mask and
        clipped local positions (the scatter-back coordinates).
        """
        n_loc = x_l.shape[0]
        lo = self.shard_row_id() * n_loc
        local_pos = jnp.clip(idx - lo, 0, n_loc - 1)
        owned = ((idx >= lo) & (idx < lo + n_loc)).astype(x_l.dtype)
        cols = [x_l[local_pos]]
        widths = [x_l.shape[1]]
        for e in extras:
            tile = e[local_pos]
            cols.append(tile[:, None] if tile.ndim == 1 else tile)
            widths.append(cols[-1].shape[1])
        packed = jnp.concatenate(cols, axis=1) * owned[:, None]
        packed = jax.lax.psum(packed, self.rows)
        outs, off = [], 0
        for e, w in zip((x_l, *extras), widths):
            piece = packed[:, off : off + w]
            outs.append(piece[:, 0] if e.ndim == 1 else piece)
            off += w
        return tuple(outs), owned, local_pos

    def shard_row_block_matvec(
        self, x_l: jax.Array, a_l: jax.Array, v_l: jax.Array
    ) -> jax.Array:
        """K(a_l, x) @ v — this shard's partial, psum'd over rows.

        ``a_l``: this model shard's (b_loc, d) block rows (replicated block
        pre-sliced with :meth:`shard_block_slice`); ``v_l``: the (n_loc[, t])
        row shard.  Output: (b_loc[, t]) replicated over rows, still sharded
        over model — ``model_all_gather`` assembles the full block.
        """
        part = self.local_op(x_l).row_block_matvec(a_l, v_l)
        return jax.lax.psum(part, self.rows)

    def shard_block_slice(self, arr: jax.Array) -> jax.Array:
        """This model shard's rows of a replicated block array (b, ...)."""
        if self.n_model == 1:
            return arr
        b = arr.shape[0]
        if b % self.n_model:
            raise ValueError(
                f"block of {b} rows does not shard over {self.n_model} model "
                f"shard(s); round the block size up to a multiple of "
                f"{self.n_model}"
            )
        return self.model_slice(arr, b // self.n_model)

    def shard_block_nystrom(
        self, xb: jax.Array, rank: int, key: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        """Distributed rank-r Nystrom of K_BB, U rows sharded over "model".

        ``xb``: the replicated (b, d) block.  The sketch rows are computed by
        this model shard ((b/M, r) local kernel matvec), the two r x r Grams
        are psum'd over "model", and the eigh of B^T B is replicated (r is
        ~100 — trivial).  Returns ``(u_l, lam)``: this shard's (b/M, r) rows
        of U and the replicated (r,) Nystrom eigenvalues.
        """
        b = xb.shape[0]
        xb_l = self.shard_block_slice(xb)
        omega = jax.random.normal(key, (b, rank), jnp.float32)
        omega, _ = jnp.linalg.qr(omega)  # replicated (b x r)
        omega_l = self.shard_block_slice(omega)
        y_sketch = self.local_op(xb).row_block_matvec(xb_l, omega)  # (b/M, r)
        shift = jnp.float32(1.19e-7) * b  # eps * tr(K_BB); unit-diag kernels
        y_sketch = y_sketch + shift * omega_l
        gram = self.model_psum(omega_l.T @ y_sketch)  # (r, r)
        gram = 0.5 * (gram + gram.T)
        chol = jnp.linalg.cholesky(gram + 1e-6 * jnp.eye(rank))
        b_mat = jax.scipy.linalg.solve_triangular(chol, y_sketch.T, lower=True).T
        btb = self.model_psum(b_mat.T @ b_mat)  # (r, r)
        evals, evecs = jnp.linalg.eigh(btb)
        evals, evecs = evals[::-1], evecs[:, ::-1]
        s_vals = jnp.sqrt(jnp.maximum(evals, 1e-30))
        u_l = b_mat @ (evecs / s_vals[None, :])  # (b/M, r) local rows of U
        lam_ny = jnp.maximum(evals - shift, 0.0)
        return u_l, lam_ny

    def shard_woodbury_apply(
        self, u_l: jax.Array, lam_ny: jax.Array, rho: jax.Array, g_l: jax.Array
    ) -> jax.Array:
        """(U diag(lam) U^T + rho I)^{-1} g with U rows sharded over "model".

        ``g_l``: (b/M,) or (b/M, t).  One r[ x t] psum over "model" serves
        all t columns.
        """
        utg = self.model_psum(u_l.T @ g_l)  # (r[, t])
        scale = lam_ny + rho
        scaled = utg / (scale[:, None] if utg.ndim == 2 else scale)
        return u_l @ scaled + (g_l - u_l @ utg) / rho

    def shard_woodbury_invsqrt(
        self, u_l: jax.Array, lam_ny: jax.Array, rho: jax.Array, g_l: jax.Array
    ) -> jax.Array:
        """(U diag(lam) U^T + rho I)^{-1/2} g — Eq. (16) on model-sharded U."""
        utg = self.model_psum(u_l.T @ g_l)
        scale = jnp.sqrt(lam_ny + rho)
        scaled = utg / (scale[:, None] if utg.ndim == 2 else scale)
        return u_l @ scaled + (g_l - u_l @ utg) / jnp.sqrt(rho)

    def shard_block_powering(
        self,
        xb: jax.Array,
        u_l: jax.Array,
        lam_ny: jax.Array,
        rho: jax.Array,
        lam: jax.Array,
        v0: jax.Array,
        num_iters: int,
    ) -> tuple[jax.Array, jax.Array]:
        """get_L (Algorithm 5) on the preconditioned distributed block:
        top eigenvalue of P^{-1/2} (K_BB + lam I) P^{-1/2}.

        ``v0``: replicated (b,) start vector.  The loop is UNROLLED:
        collectives inside a lax.scan share one HLO channel id, which the
        in-process CPU communicator cannot disambiguate across iterations;
        unrolling gives each collective its own channel (and lets XLA
        pipeline them on real hardware).  Returns (v_last, L_estimate).
        """
        b = xb.shape[0]
        b_loc = b // self.n_model
        xb_l = self.shard_block_slice(xb)
        lop = self.local_op(xb)

        def kbb_lam_mv(v_full):  # (b,) replicated -> (b/M,) local
            part = lop.row_block_matvec(xb_l, v_full)
            return part + lam * self.model_slice(v_full, b_loc)

        v = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-30)
        lam_est = jnp.float32(1.0)
        for _ in range(num_iters):
            v_l = self.model_slice(v, b_loc)
            u1 = self.shard_woodbury_invsqrt(u_l, lam_ny, rho, v_l)
            u1_full = self.model_all_gather(u1)  # (b,)
            u2 = kbb_lam_mv(u1_full)
            u3 = self.shard_woodbury_invsqrt(u_l, lam_ny, rho, u2)
            stats = self.model_psum(jnp.stack([v_l @ u3, u3 @ u3]))  # packed
            lam_est, nrm = stats[0], jnp.sqrt(stats[1])
            v = self.model_all_gather(u3 / jnp.maximum(nrm, 1e-30))
        return v, lam_est

    # -- the four primitives over global arrays ------------------------------

    @cached_property
    def _matvec_fn(self):
        def local(x_l, v_l):
            x_full = jax.lax.all_gather(x_l, self.rows, tiled=True)
            v_full = jax.lax.all_gather(v_l, self.rows, tiled=True)
            n = x_full.shape[0]
            if self.n_model > 1 and n % self.n_model == 0:
                # split the contraction over "model": each shard applies a
                # column slice of K, psum assembles the full product
                sl = n // self.n_model
                xs = self.model_slice(x_full, sl)
                vs = self.model_slice(v_full, sl)
                part = self.local_op(xs).row_block_matvec(x_l, vs)
                return jax.lax.psum(part, self.model)
            return self.local_op(x_full).row_block_matvec(x_l, v_full)

        jitted: dict[int, object] = {}  # keyed on RHS ndim; jit caches shapes

        def call(v):
            if v.ndim not in jitted:
                spec = self.vec_spec(v.ndim)
                jitted[v.ndim] = jax.jit(shard_map(
                    local, mesh=self.mesh,
                    in_specs=(P(self.rows, None), spec), out_specs=spec,
                ))
            if self.n_row_shards > 1:
                _count_collective("matvec", "all_gather", 2)  # x and v
            if self.n_model > 1 and self.n % self.n_model == 0:
                _count_collective("matvec", "psum")
            return jitted[v.ndim](self.x, v)

        return call

    def matvec(self, v: jax.Array) -> jax.Array:
        """K(x, x) @ v; v row-sharded (n,) or (n, t) -> same sharding out."""
        self._require_bound()
        return self._matvec_fn(v)

    def _require_multikernel(self) -> None:
        if not isinstance(self.kernel, tuple):
            raise ValueError(
                "per-column-weighted primitives need a multi-kernel operator "
                f"(a kernel tuple); got kernel={self.kernel!r}"
            )

    @cached_property
    def _matvec_cols_fn(self):
        def local(x_l, v_l, wc):
            x_full = jax.lax.all_gather(x_l, self.rows, tiled=True)
            v_full = jax.lax.all_gather(v_l, self.rows, tiled=True)
            n = x_full.shape[0]
            if self.n_model > 1 and n % self.n_model == 0:
                sl = n // self.n_model
                xs = self.model_slice(x_full, sl)
                vs = self.model_slice(v_full, sl)
                part = self.local_op(xs).row_block_matvec_cols(x_l, vs, wc)
                return jax.lax.psum(part, self.model)
            return self.local_op(x_full).row_block_matvec_cols(x_l, v_full, wc)

        jitted = jax.jit(shard_map(
            local, mesh=self.mesh,
            in_specs=(P(self.rows, None), self.vec_spec(2), P()),
            out_specs=self.vec_spec(2),
        ))

        def call(v, w_cols):
            if self.n_row_shards > 1:
                _count_collective("matvec_cols", "all_gather", 2)
            if self.n_model > 1 and self.n % self.n_model == 0:
                _count_collective("matvec_cols", "psum")
            return jitted(self.x, v, w_cols)

        return call

    def matvec_cols(self, v: jax.Array, w_cols: jax.Array) -> jax.Array:
        """Per-column-weighted multi-kernel matvec: out[:, c] =
        (sum_i w_cols[i, c] K_i) @ v[:, c]; v row-sharded (n, t), ``w_cols``
        replicated (q, t).  One fused data sweep per shard — the mesh leg of
        the multi-kernel tuning engine."""
        self._require_bound()
        self._require_multikernel()
        return self._matvec_cols_fn(v, jnp.asarray(w_cols, jnp.float32))

    @cached_property
    def _sketch_components_fn(self):
        def local(x_l, v_l):
            x_full = jax.lax.all_gather(x_l, self.rows, tiled=True)
            v_full = jax.lax.all_gather(v_l, self.rows, tiled=True)
            n = x_full.shape[0]
            if self.n_model > 1 and n % self.n_model == 0:
                sl = n // self.n_model
                xs = self.model_slice(x_full, sl)
                vs = self.model_slice(v_full, sl)
                part = self.local_op(xs).row_block_components(x_l, vs)
                return jax.lax.psum(part, self.model)
            return self.local_op(x_full).row_block_components(x_l, v_full)

        return jax.jit(shard_map(
            local, mesh=self.mesh,
            in_specs=(P(self.rows, None), self.vec_spec(2)),
            out_specs=P(None, self.rows, None),
        ))

    def sketch_components(self, omega: jax.Array) -> jax.Array:
        """Stacked per-kernel sketches (q, n, r): out[i] = K_i @ omega, rows
        sharded on axis 1.  ONE data sweep serves all q Nystrom sketches of
        the multi-kernel tuner."""
        self._require_bound()
        self._require_multikernel()
        if self.n_row_shards > 1:
            _count_collective("sketch_components", "all_gather", 2)
        if self.n_model > 1 and self.n % self.n_model == 0:
            _count_collective("sketch_components", "psum")
        return self._sketch_components_fn(self.x, omega)

    @cached_property
    def _row_block_matvec_fn(self):
        def local(a, x_l, v_l):
            if self.n_model > 1 and a.shape[0] % self.n_model == 0:
                a_l = self.shard_block_slice(a)
                part = self.shard_row_block_matvec(x_l, a_l, v_l)
                return self.model_all_gather(part)
            return self.shard_row_block_matvec(x_l, a, v_l)

        jitted: dict[int, object] = {}

        def call(a, v):
            if v.ndim not in jitted:
                jitted[v.ndim] = jax.jit(shard_map(
                    local, mesh=self.mesh,
                    in_specs=(P(), P(self.rows, None), self.vec_spec(v.ndim)),
                    out_specs=P(),
                ))
            if self.n_row_shards > 1:
                _count_collective("row_block_matvec", "psum")
            if self.n_model > 1 and a.shape[0] % self.n_model == 0:
                _count_collective("row_block_matvec", "all_gather")
            return jitted[v.ndim](a, self.x, v)

        return call

    def row_block_matvec(self, a: jax.Array, v: jax.Array) -> jax.Array:
        """K(a, x) @ v for a replicated row block ``a`` (b, d); v row-sharded
        (n,)|(n, t) -> replicated (b,)|(b, t).  ASkotch's hot spot, Falkon's
        K_nm products, prediction/serving."""
        self._require_bound()
        return self._row_block_matvec_fn(jnp.asarray(a), v)

    @cached_property
    def _block_fn(self):
        def local(a, b):
            a_l = self.shard_block_slice(a)
            tile = self.local_op(b).block(a_l, b)
            return self.model_all_gather(tile)

        jitted = jax.jit(shard_map(
            local, mesh=self.mesh, in_specs=(P(), P()), out_specs=P(),
        ))

        def call(a, b):
            if self.n_model == 1 or a.shape[0] % self.n_model:
                return self.local_op(b).block(a, b)  # replicated compute
            _count_collective("block", "all_gather")
            return jitted(a, b)

        return call

    def block(self, a: jax.Array, b: jax.Array | None = None) -> jax.Array:
        """Materialize K(a, b) for replicated point sets (small tiles only);
        rows of ``a`` split over "model" when divisible."""
        b = a if b is None else b
        return self._block_fn(jnp.asarray(a), jnp.asarray(b))

    def block_idx(self, idx: jax.Array) -> jax.Array:
        """K_BB for a replicated global row-index block (ASkotch step)."""
        (xb,), _ = self.gather_rows(idx)
        return self.block(xb, xb)

    @cached_property
    def _gather_rows_fn(self):
        jitted: dict[tuple[int, ...], object] = {}

        def call(idx, extras):
            key = tuple(e.ndim for e in extras)
            if key not in jitted:

                def local(idx, x_l, *e_l):
                    outs, owned, _pos = self.shard_gather_rows(x_l, idx, e_l)
                    return outs, owned

                in_specs = (P(), P(self.rows, None)) + tuple(
                    self.vec_spec(nd) for nd in key
                )
                out_specs = (tuple(P() for _ in range(1 + len(key))),
                             P(self.rows))
                jitted[key] = jax.jit(shard_map(
                    local, mesh=self.mesh, in_specs=in_specs,
                    out_specs=out_specs,
                ))
            if self.n_row_shards > 1:
                _count_collective("gather_rows", "psum")  # one packed psum
            return jitted[key](idx, self.x, *extras)

        return call

    def gather_rows(
        self, idx: jax.Array, *extras: jax.Array
    ) -> tuple[tuple[jax.Array, ...], jax.Array]:
        """Gather ``x[idx]`` (+ row-aligned extras) to every device via ONE
        packed psum.  Returns ``((x_B, *extras_B), owned)`` with the gathered
        arrays replicated and ``owned`` the row-sharded ownership mask."""
        self._require_bound()
        return self._gather_rows_fn(jnp.asarray(idx), tuple(extras))

    def trace_est(self) -> jax.Array:
        """tr K — no collective.  n for the unit-diagonal testbed kernels;
        a weighted combination scales by its weight sum."""
        if isinstance(self.kernel, tuple) and self.weights is not None:
            return jnp.float32(sum(self.weights) * self.n)
        return jnp.float32(self.n)

    # -- composites shared by solvers ----------------------------------------

    def k_lam_matvec(self, v: jax.Array, lam: jax.Array | float) -> jax.Array:
        """(K + lam I) @ v, row-sharded in and out."""
        return self.matvec(v) + lam * v

    def sketch(self, omega: jax.Array) -> jax.Array:
        """K @ omega for a row-sharded (n, r) test matrix — distributed
        Nystrom sketches over the full kernel without materializing it."""
        return self.matvec(omega)
