"""Deterministic data partitioners for the divide-and-conquer solve tier.

DC-KRR (You, Demmel, Hsieh & Vuduc 2018) trades a bounded accuracy loss for
near-zero inter-device traffic by partitioning the training set into k
shards, solving full local KRR per shard, and combining predictions.  The
quality of that trade rests on the partition, so this module owns it as a
first-class, serializable object:

  * :func:`random_partition` — a seeded permutation split into k
    size-balanced shards (sizes differ by at most one row).  The baseline
    BKRR-style partition: shards are statistically exchangeable, so the
    uniform prediction average is unbiased.
  * :func:`kmeans_partition` — chunked Lloyd iterations over the SAME
    squared-distance expansion the kernel tiles use
    (``core.kernels._sq_dists``, streamed in row chunks so the (n, k)
    distance matrix is the only materialized object), followed by a
    capacity-constrained greedy assignment that restores exact size balance
    (most-confident points claim their nearest center first).  DC-KRR's
    locality-aware variant: each local model sees a coherent region, which
    tightens the softmax-weighted combiner.

Both are deterministic functions of ``(x, k, seed)``: the same inputs give
bit-identical assignments across processes, which is what lets a partition
be computed once, exported, and reused by serving replicas.
:meth:`Partition.to_json` / :meth:`Partition.from_json` round-trip the full
object (assignments + centers + provenance) through plain JSON.

At k = 1 every partitioner degenerates to the identity: one shard holding
rows ``0..n-1`` in original order, so a k = 1 divide-and-conquer solve is
bit-identical to the plain solver (tested).
"""

from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from repro.core.kernels import _sq_dists

#: accepted partitioner kinds (the ``dc_partition=`` vocabulary)
PARTITION_KINDS = ("random", "kmeans")


def balanced_sizes(n: int, k: int) -> np.ndarray:
    """Shard sizes for n rows over k shards, balanced to within one row:
    the first ``n % k`` shards get ``n // k + 1`` rows, the rest ``n // k``."""
    if not (isinstance(k, (int, np.integer)) and 1 <= k <= n):
        raise ValueError(
            f"shard count k = {k!r} invalid for n = {n}; accepted: "
            f"integers in [1, n]"
        )
    base, rem = divmod(n, k)
    return np.asarray([base + (j < rem) for j in range(k)], np.int64)


def chunked_sq_dists(x, centers, chunk: int = 4096) -> np.ndarray:
    """Pairwise squared distances ``||x_i - c_j||^2`` as a host (n, k) f32
    array, streamed in row chunks of ``x`` through the same matmul expansion
    the kernel tiles use (``core.kernels._sq_dists``) — k is small (the
    shard count), so (n, k) is the only materialized object."""
    x = np.asarray(x, np.float32)
    c = jnp.asarray(np.asarray(centers, np.float32))
    n = x.shape[0]
    out = np.empty((n, c.shape[0]), np.float32)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        out[lo:hi] = np.asarray(_sq_dists(jnp.asarray(x[lo:hi]), c))
    return out


@dataclasses.dataclass(frozen=True)
class Partition:
    """A size-balanced assignment of n rows to k shards, plus shard centers.

    ``assignments``: (n,) int32 shard ids; ``centers``: (k, d) f32 shard
    means (the softmax combiner's anchors); ``kind``/``seed``: provenance so
    an exported partition documents how to regenerate it.
    """

    assignments: np.ndarray
    centers: np.ndarray
    kind: str
    seed: int

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "assignments", np.asarray(self.assignments, np.int32)
        )
        object.__setattr__(self, "centers", np.asarray(self.centers, np.float32))
        if self.assignments.ndim != 1 or self.centers.ndim != 2:
            raise ValueError(
                f"Partition wants (n,) assignments and (k, d) centers; got "
                f"{self.assignments.shape} and {self.centers.shape}"
            )
        k = self.centers.shape[0]
        if self.assignments.size and not (
            0 <= int(self.assignments.min())
            and int(self.assignments.max()) < k
        ):
            raise ValueError(
                f"assignments reference shard ids outside [0, {k})"
            )

    @property
    def n(self) -> int:
        """Number of partitioned rows."""
        return int(self.assignments.shape[0])

    @property
    def k(self) -> int:
        """Number of shards."""
        return int(self.centers.shape[0])

    @property
    def sizes(self) -> np.ndarray:
        """(k,) rows per shard."""
        return np.bincount(self.assignments, minlength=self.k).astype(np.int64)

    def shard_indices(self) -> tuple[np.ndarray, ...]:
        """Per-shard row indices, each sorted ascending — so the k = 1
        partition reproduces the original row order exactly (the bit-parity
        degeneracy the DC tier's tests pin down)."""
        order = np.argsort(self.assignments, kind="stable")
        bounds = np.cumsum(self.sizes)[:-1]
        return tuple(np.sort(piece) for piece in np.split(order, bounds))

    def to_json(self) -> str:
        """Serialize to a JSON string (assignments, centers, kind, seed)."""
        return json.dumps({
            "kind": self.kind,
            "seed": int(self.seed),
            "assignments": self.assignments.tolist(),
            "centers": self.centers.tolist(),
        })

    @classmethod
    def from_json(cls, payload: str) -> "Partition":
        """Inverse of :meth:`to_json`; f32 centers survive the f64 JSON
        detour exactly (every f32 is representable as a double)."""
        obj = json.loads(payload)
        return cls(
            assignments=np.asarray(obj["assignments"], np.int32),
            centers=np.asarray(obj["centers"], np.float32),
            kind=obj["kind"],
            seed=int(obj["seed"]),
        )


def _centers_of(x: np.ndarray, assignments: np.ndarray, k: int) -> np.ndarray:
    centers = np.empty((k, x.shape[1]), np.float32)
    for j in range(k):
        centers[j] = x[assignments == j].mean(axis=0)
    return centers


def random_partition(x, k: int, seed: int = 0) -> Partition:
    """Seeded uniform partition into k size-balanced shards.

    A permutation of ``range(n)`` is split into the :func:`balanced_sizes`
    pieces; centers are the per-shard feature means.  k = 1 degenerates to
    the identity partition (all rows, original order).
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    sizes = balanced_sizes(n, k)
    assignments = np.empty(n, np.int32)
    perm = np.random.default_rng(seed).permutation(n)
    start = 0
    for j, s in enumerate(sizes):
        assignments[perm[start : start + s]] = j
        start += s
    return Partition(
        assignments=assignments, centers=_centers_of(x, assignments, k),
        kind="random", seed=seed,
    )


def kmeans_partition(
    x, k: int, seed: int = 0, *, iters: int = 10, chunk: int = 4096
) -> Partition:
    """Chunked, capacity-balanced k-means partition into k shards.

    Lloyd iterations run over :func:`chunked_sq_dists` (the streamed
    distance expansion — never an (n, n) object); centers seed from k
    distinct random rows.  The final assignment is capacity-constrained:
    every shard gets exactly its :func:`balanced_sizes` quota, points claim
    centers in decreasing order of assignment confidence (the margin between
    best and second-best center), each taking the nearest center with spare
    capacity.  Deterministic in ``(x, k, seed)``.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    sizes = balanced_sizes(n, k)
    rng = np.random.default_rng(seed)
    centers = x[np.sort(rng.choice(n, size=k, replace=False))].copy()
    for _ in range(max(int(iters), 0)):
        d2 = chunked_sq_dists(x, centers, chunk)
        assign = d2.argmin(axis=1)
        new_centers = centers.copy()
        for j in range(k):
            mask = assign == j
            if mask.any():  # empty clusters keep their previous center
                new_centers[j] = x[mask].mean(axis=0)
        if np.array_equal(new_centers, centers):
            break
        centers = new_centers

    d2 = chunked_sq_dists(x, centers, chunk)
    pref = np.argsort(d2, axis=1, kind="stable")  # (n, k) nearest-first
    if k > 1:
        top2 = np.sort(d2, axis=1)[:, :2]
        margin = top2[:, 1] - top2[:, 0]
    else:
        margin = np.zeros(n, np.float32)
    order = np.argsort(-margin, kind="stable")  # most-confident first
    remaining = sizes.copy()
    assignments = np.empty(n, np.int32)
    for i in order:
        for j in pref[i]:
            if remaining[j] > 0:
                assignments[i] = j
                remaining[j] -= 1
                break
    return Partition(
        assignments=assignments, centers=_centers_of(x, assignments, k),
        kind="kmeans", seed=seed,
    )


def make_partition(x, k: int, kind: str = "random", seed: int = 0) -> Partition:
    """Dispatch on :data:`PARTITION_KINDS` — the ``dc_partition=`` entry
    point behind ``solve(method="dc")``."""
    if kind == "random":
        return random_partition(x, k, seed)
    if kind == "kmeans":
        return kmeans_partition(x, k, seed)
    raise ValueError(
        f"unknown partition kind {kind!r}; accepted: {PARTITION_KINDS} "
        f"or a Partition instance"
    )
