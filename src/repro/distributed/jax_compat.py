"""Version-portable shard_map / mesh construction (jax 0.4.x ... 0.6+).

CI pins and some containers carry jax 0.4.x, where shard_map still lives in
``jax.experimental`` (with ``check_rep`` instead of ``check_vma``) and mesh
axes are untyped; on newer jax the ``Mesh`` constructor used here defaults
to Auto-typed axes, which is the behavior the distributed layer assumes.
Everything mesh-touching in ``repro.distributed`` and ``repro.launch`` goes
through these two helpers so the rest of the code never branches on the jax
version.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np

try:  # jax >= 0.6: public API, VMA-based replication checking
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

def shard_map(
    f: Callable,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
    check: bool = False,
) -> Callable:
    """``jax.shard_map`` with replication checking disabled by default.

    ``check=False`` maps to ``check_vma=False`` (new jax) / ``check_rep=False``
    (old jax); the distributed operator's out_specs are genuinely replicated
    where declared, but the old checker cannot always prove it through
    ``dynamic_slice`` + ``all_gather`` chains.
    """
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: check}
    )


def make_mesh(
    shape: tuple[int, ...], axes: tuple[str, ...]
) -> jax.sharding.Mesh:
    """Build a Mesh over the first ``prod(shape)`` devices.

    Unlike ``jax.make_mesh`` this accepts a shape smaller than the device
    count (it slices), which is what lets a size-1 solver mesh run inside a
    plain single-device pytest process.
    """
    size = int(np.prod(shape))
    devices = jax.devices()
    if size > len(devices):
        raise ValueError(
            f"mesh shape {shape} needs {size} devices; "
            f"only {len(devices)} available (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={size} for a "
            f"host-platform test mesh)"
        )
    arr = np.asarray(devices[:size]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)
