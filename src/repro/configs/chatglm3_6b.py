"""chatglm3-6b [arXiv:2406.12793; hf]: dense GQA with partial ("2d") RoPE.

28L, d_model=4096, 32H (GQA kv=2), d_ff=13696, vocab=65024; rotary applied
to half the head dim (rope_fraction=0.5); untied output layer.
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="chatglm3-6b", family="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=65024, rope_fraction=0.5, tie_embeddings=False,
        dtype="bfloat16", param_dtype="float32", optimizer="adamw",
        remat="full", microbatches_train=1, residual_shard="seq",
        source="arXiv:2406.12793; hf",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32", remat="none",
        residual_shard="none",
    )
