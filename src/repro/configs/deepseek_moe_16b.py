"""deepseek-moe-16b [arXiv:2401.06066; hf]: fine-grained MoE.

28L, d_model=2048, 16H (kv=16 = MHA), per-expert d_ff=1408, vocab=102400,
2 shared + 64 routed experts top-6.  64 experts shard expert-parallel over
the 16-way "model" axis (4 experts/chip).
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400, num_experts=64, top_k=6,
        num_shared_experts=2, tie_embeddings=False,
        dtype="bfloat16", param_dtype="float32", optimizer="adamw",
        remat="full", microbatches_train=2, residual_shard="seq",
        source="arXiv:2401.06066; hf",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256, num_experts=8, top_k=2, num_shared_experts=1,
        dtype="float32", remat="none", microbatches_train=1, residual_shard="none",
    )
