"""Architecture config registry.

Each assigned architecture lives in its own module exposing ``config()``
(the exact published numbers) and ``reduced()`` (a same-family miniature for
CPU smoke tests: few layers, small width, tiny vocab/experts).  Select with
``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import importlib

from repro.models.model_api import ArchConfig

ARCH_IDS = (
    "whisper-base",
    "grok-1-314b",
    "deepseek-moe-16b",
    "qwen2-1.5b",
    "chatglm3-6b",
    "command-r-plus-104b",
    "llama3-405b",
    "rwkv6-1.6b",
    "jamba-1.5-large-398b",
    "llava-next-mistral-7b",
)

_MODULES = {
    "whisper-base": "whisper_base",
    "grok-1-314b": "grok1_314b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-1.5b": "qwen2_1_5b",
    "chatglm3-6b": "chatglm3_6b",
    "command-r-plus-104b": "command_r_plus_104b",
    "llama3-405b": "llama3_405b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).config()


def get_reduced_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).reduced()
