"""The paper's own production workload: full KRR on taxi-scale data.

n = 1e8 rows, d = 9 features, RBF kernel (sigma=1), lam_unscaled = 2e-7,
blocksize b = n/2000 = 50_000, rank r = 100 — the §6.2 showcase settings.
Dry-run lowers one distributed ASkotch iteration on the production meshes
(rows over ("pod","data") x block rows over "model").
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class KRRRunConfig:
    name: str = "askotch-krr-taxi-100m"
    n: int = 100_000_000
    d: int = 9
    kernel: str = "rbf"
    sigma: float = 1.0
    lam_unscaled: float = 2e-7
    block_size: int = 50_000
    rank: int = 100
    rho_mode: str = "damped"
    accelerated: bool = True


def config() -> KRRRunConfig:
    return KRRRunConfig()


def reduced() -> KRRRunConfig:
    return dataclasses.replace(
        config(), name="askotch-krr-smoke", n=4096, d=9, block_size=256, rank=32
    )
