"""jamba-1.5-large-398b [arXiv:2403.19887; hf]: Mamba+attention hybrid MoE.

72L (9 periods x 8), d_model=8192, 64H (GQA kv=8), expert d_ff=24576,
vocab=65536, 16 experts top-2 on every other layer, attention:mamba = 1:7.
No positional encoding in attention (Mamba carries position).  Hybrid state
is O(1) for the 63 Mamba sublayers + a KV cache for the 9 attention
sublayers -> runs long_500k with the cache seq dim sharded over "tp".
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536, num_experts=16, top_k=2,
        attn_period=8, moe_every=2, d_state=16, ssm_expand=2, ssm_conv=4,
        rope_fraction=0.0, tie_embeddings=False,
        dtype="bfloat16", param_dtype="bfloat16", optimizer="adafactor",
        remat="full", microbatches_train=8, residual_shard="seq",
        grad_accum_dtype="bfloat16", fsdp_over_pod=True, sub_quadratic=True,
        source="arXiv:2403.19887; hf",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256, num_experts=4, top_k=2, attn_period=4,
        d_state=8, dtype="float32", param_dtype="float32", remat="none",
        microbatches_train=1, residual_shard="none",
        grad_accum_dtype="float32", fsdp_over_pod=False,
    )
