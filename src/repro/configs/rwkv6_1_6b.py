"""rwkv6-1.6b "Finch" [arXiv:2404.05892; unverified]: attention-free SSM.

24L, d_model=2048 (32 heads x 64), d_ff=7168, vocab=65536, data-dependent
per-channel decay.  O(1) recurrent state -> runs the long_500k cell.
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b", family="rwkv",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536, tie_embeddings=False,
        dtype="bfloat16", param_dtype="float32", optimizer="adamw",
        remat="full", microbatches_train=4, sub_quadratic=True,
        source="arXiv:2404.05892; unverified",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        d_ff=256, vocab_size=256, dtype="float32", remat="none",
    )
