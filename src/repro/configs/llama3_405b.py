"""llama3-405b [arXiv:2407.21783; unverified]: the heavyweight dense cell.

126L, d_model=16384, 128H (GQA kv=8), d_ff=53248, vocab=128256.
Memory posture (DESIGN.md §4 / EXPERIMENTS.md): bf16 params + Adafactor +
seq-sharded residual + 16 microbatches + bf16 grad accumulation; on the
multi-pod mesh FSDP spans ("pod","data") (fsdp_over_pod) which is what
brings the train_4k cell under 16 GB/chip — single-pod train is reported
as marginally over HBM (matches reality: 405B-class training needs >256
chips).
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        d_ff=53248, vocab_size=128256, tie_embeddings=False,
        dtype="bfloat16", param_dtype="bfloat16", optimizer="adafactor",
        remat="full", microbatches_train=16, residual_shard="seq",
        grad_accum_dtype="bfloat16", fsdp_over_pod=True,
        source="arXiv:2407.21783; unverified",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
        remat="none", microbatches_train=1, residual_shard="none",
        grad_accum_dtype="float32", fsdp_over_pod=False,
    )
