"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified].

64L, d_model=12288, 96H (GQA kv=8), d_ff=33792, vocab=256000, no biases.
Deviation (DESIGN.md §8): embedding/head storage untied — a tied 6.3 GB
table under 2D sharding forces SPMD to replicate it on gather; untied
storage keeps both the gather and the logits matmul cleanly partitioned.
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="command-r-plus-104b", family="dense",
        num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=33792, vocab_size=256000, tie_embeddings=False,
        dtype="bfloat16", param_dtype="bfloat16", optimizer="adafactor",
        remat="full", microbatches_train=4, residual_shard="seq",
        grad_accum_dtype="bfloat16", fsdp_over_pod=True,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
        remat="none", microbatches_train=1, residual_shard="none",
        grad_accum_dtype="float32", fsdp_over_pod=False,
    )
