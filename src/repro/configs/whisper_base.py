"""whisper-base [arXiv:2212.04356; unverified]: enc-dec audio transformer.

6L encoder + 6L decoder, d_model=512, 8 heads (kv=8), d_ff=2048,
vocab=51865 (padded to 51968 for 16-way TP x 128 lanes).  The conv audio
frontend is a stub: input_specs() provides precomputed frame embeddings.
Deviations (DESIGN.md §5/§8): sinusoidal decoder positions (the real learned
448-position table does not extend to the assigned 4k/32k shapes).
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="encdec",
        num_layers=6, encoder_layers=6, d_model=512, num_heads=8,
        num_kv_heads=8, d_ff=2048, vocab_size=51865,
        norm="layernorm", mlp_act="gelu", tie_embeddings=True,
        dtype="bfloat16", param_dtype="float32", optimizer="adamw",
        remat="full", microbatches_train=1,
        source="arXiv:2212.04356; unverified",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, dtype="float32", remat="none",
    )
