"""grok-1-314b [hf:xai-org/grok-1; unverified]: 314B MoE decoder-only.

64L, d_model=6144, 48H (GQA kv=8), expert d_ff=32768, vocab=131072,
8 experts top-2.  8 experts don't split over 16-way TP, so expert FFNs are
tensor-parallel on the ffn dim instead of expert-parallel (DESIGN.md §4).
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b", family="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=32768, vocab_size=131072, num_experts=8, top_k=2,
        tie_embeddings=False,
        dtype="bfloat16", param_dtype="bfloat16", optimizer="adafactor",
        remat="full", microbatches_train=8, residual_shard="seq",
        grad_accum_dtype="bfloat16", fsdp_over_pod=True,
        source="hf:xai-org/grok-1; unverified",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256, num_experts=4, top_k=2, dtype="float32",
        param_dtype="float32", remat="none", microbatches_train=1,
        residual_shard="none", grad_accum_dtype="float32", fsdp_over_pod=False,
    )
