"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Mistral-7B backbone: 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=32000.  The anyres vision tower is a stub: input_specs() provides
2880 precomputed patch embeddings (5 tiles x 576 patches) prepended to the
text tokens; seq_len counts prefix + text (DESIGN.md §5).
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b", family="vlm",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, num_prefix_tokens=2880,
        tie_embeddings=False,
        dtype="bfloat16", param_dtype="float32", optimizer="adamw",
        remat="full", microbatches_train=2, residual_shard="seq",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, num_prefix_tokens=8, dtype="float32",
        remat="none", microbatches_train=1, residual_shard="none",
    )
