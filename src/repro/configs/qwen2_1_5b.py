"""qwen2-1.5b [arXiv:2407.10671; hf]: dense GQA with QKV bias.

28L, d_model=1536, 12H (GQA kv=2), d_ff=8960, vocab=151936, tied embeddings.
12 heads don't split 16-way, so attention runs TP-replicated (DESIGN.md §4).
"""

import dataclasses

from repro.models.model_api import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
        dtype="bfloat16", param_dtype="float32", optimizer="adamw",
        remat="full", microbatches_train=1, residual_shard="seq",
        source="arXiv:2407.10671; hf",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=192, vocab_size=256, dtype="float32", remat="none",
        residual_shard="none",
    )
