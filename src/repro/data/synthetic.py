"""Deterministic synthetic data generators.

LM side: a Zipf-ish Markov token stream (structured enough that the loss
demonstrably falls during the example training runs) generated per-batch
from a counter-based PRNG — fully deterministic given (seed, step), which is
what makes checkpoint-resume bit-exact without storing data state beyond the
step counter.

KRR side: regression/classification problems of the paper's flavor (RBF-ish
smooth targets + noise; taxi-like low-dimensional feature blobs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------------
# LM tokens
# ----------------------------------------------------------------------------


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    """Deterministic (seed, step) -> {tokens, labels} int32 arrays.

    Tokens follow a noisy arithmetic progression per sequence so that a model
    can actually learn next-token structure (ppl drops quickly).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    stride = jax.random.randint(k2, (batch, 1), 1, 17)
    pos = jnp.arange(seq + 1)[None, :]
    clean = (start + stride * pos) % vocab
    noise_mask = jax.random.bernoulli(k3, 0.05, (batch, seq + 1))
    noise = jax.random.randint(jax.random.fold_in(k3, 1), (batch, seq + 1), 0, vocab)
    toks = jnp.where(noise_mask, noise, clean).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def vlm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
              prefix: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    base = lm_batch(seed, step, batch, seq - prefix, vocab)
    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), step)
    emb = 0.02 * jax.random.normal(key, (batch, prefix, d_model), jnp.float32)
    labels = jnp.concatenate(
        [-jnp.ones((batch, prefix), jnp.int32), base["labels"]], axis=1
    )
    return {
        "tokens": base["tokens"],
        "labels": labels,
        "prefix_embeds": emb.astype(dtype),
    }


def encdec_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                 d_model: int, dtype=jnp.bfloat16) -> dict:
    base = lm_batch(seed, step, batch, seq, vocab)
    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xF00D), step)
    frames = 0.1 * jax.random.normal(key, (batch, seq, d_model), jnp.float32)
    return {
        "frames": frames.astype(dtype),
        "tokens": base["tokens"],
        "labels": base["labels"],
    }


def batch_for(cfg, shape_or_dims, seed: int, step: int) -> dict:
    """Family-aware synthetic batch.  shape_or_dims: ShapeConfig or (B, T)."""
    if hasattr(shape_or_dims, "global_batch"):
        b, t = shape_or_dims.global_batch, shape_or_dims.seq_len
    else:
        b, t = shape_or_dims
    dt = cfg.activation_dtype()
    if cfg.family == "encdec":
        return encdec_batch(seed, step, b, t, cfg.vocab_size, cfg.d_model, dt)
    if cfg.num_prefix_tokens:
        return vlm_batch(seed, step, b, t, cfg.vocab_size, cfg.num_prefix_tokens,
                         cfg.d_model, dt)
    return lm_batch(seed, step, b, t, cfg.vocab_size)


# ----------------------------------------------------------------------------
# KRR datasets (paper-flavor)
# ----------------------------------------------------------------------------


def krr_regression(seed: int, n: int, d: int, n_test: int = 0, noise: float = 0.1):
    """Smooth nonlinear target + Gaussian noise (molecule-dataset flavor)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n + n_test, d)).astype(np.float32)
    w1 = rng.standard_normal((d,)).astype(np.float32) / np.sqrt(d)
    w2 = rng.standard_normal((d,)).astype(np.float32) / np.sqrt(d)
    f = np.sin(2.0 * (x @ w1)) + 0.5 * np.cos(x @ w2) + 0.2 * (x @ w1) ** 2
    y = (f + noise * rng.standard_normal(n + n_test)).astype(np.float32)
    return (
        jnp.asarray(x[:n]), jnp.asarray(y[:n]),
        jnp.asarray(x[n:]), jnp.asarray(y[n:]),
    )


def krr_classification(seed: int, n: int, d: int, n_test: int = 0):
    """Binary +-1 labels from a smooth score (covtype/susy flavor)."""
    x_tr, y_tr, x_te, y_te = krr_regression(seed, n, d, n_test, noise=0.05)
    return x_tr, jnp.sign(y_tr), x_te, jnp.sign(y_te)


def krr_one_vs_all(seed: int, n: int, d: int, num_classes: int = 4, n_test: int = 0):
    """Multi-class blobs encoded as (n, t) one-vs-all ±1 targets.

    Returns (x_tr, y_tr, labels_tr, x_te, y_te, labels_te): y is the ±1
    one-hot margin matrix the multi-RHS solvers consume (one column = one
    head), labels are the integer classes for top-1 evaluation.
    """
    rng = np.random.default_rng(seed)
    m = n + n_test
    centers = rng.standard_normal((num_classes, d)).astype(np.float32) * 1.5
    labels = rng.integers(0, num_classes, size=m)
    x = centers[labels] + 0.6 * rng.standard_normal((m, d)).astype(np.float32)
    y = -np.ones((m, num_classes), np.float32)
    y[np.arange(m), labels] = 1.0
    return (
        jnp.asarray(x[:n]), jnp.asarray(y[:n]), jnp.asarray(labels[:n].astype(np.int32)),
        jnp.asarray(x[n:]), jnp.asarray(y[n:]), jnp.asarray(labels[n:].astype(np.int32)),
    )


def taxi_like(seed: int, n: int, d: int = 9):
    """Low-dimensional trip-feature blobs with heavy-tailed targets
    (taxi ride-duration flavor, §6.2)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-2, 2, size=(16, d)).astype(np.float32)
    assign = rng.integers(0, 16, size=n)
    x = centers[assign] + 0.3 * rng.standard_normal((n, d)).astype(np.float32)
    base = np.linalg.norm(x[:, :2], axis=1) * 600.0
    y = base + 120.0 * rng.standard_normal(n) + 50.0 * np.abs(x[:, 2])
    return jnp.asarray(x), jnp.asarray(y.astype(np.float32))
