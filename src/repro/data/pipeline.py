"""Host data pipeline: deterministic, shardable, resumable.

The iterator is a pure function of (seed, step), so its "state" is just the
step counter — checkpoints store that one integer and resume is bit-exact.
``device_put``s each batch with the dp sharding so multi-controller runs feed
only their addressable shard (single-process here, same code path).
"""

from __future__ import annotations

from typing import Iterator

import jax

from repro.data import synthetic
from repro.models.model_api import ArchConfig


class LMDataPipeline:
    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                 start_step: int = 0, shardings=None):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = start_step
        self.shardings = shardings

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, cfg, batch, seq, state: dict, shardings=None):
        return cls(cfg, batch, seq, seed=state["seed"], start_step=state["step"],
                   shardings=shardings)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = synthetic.batch_for(self.cfg, (self.batch, self.seq), self.seed, self.step)
        self.step += 1
        if self.shardings is not None:
            b = jax.device_put(b, self.shardings)
        return b
