"""KRR serving launcher: load tuned artifacts, serve traffic, report stats.

    # tune + refit + export an artifact, then serve it
    PYTHONPATH=src python -m repro.launch.krr_tune --n 2000 --d 6 \
        --export-artifact /tmp/krr_model
    PYTHONPATH=src python -m repro.launch.krr_serve \
        --artifact demo=/tmp/krr_model --requests 200 --rate 500

    # several models behind one engine, row-sharded over a device mesh
    PYTHONPATH=src python -m repro.launch.krr_serve \
        --artifact a=/tmp/model_a --artifact b=/tmp/model_b --mesh auto

    # restore a whole registry from an artifact tree (restart survival)
    PYTHONPATH=src python -m repro.launch.krr_serve \
        --artifacts-dir /tmp/krr_models --requests 0

Each ``--artifact NAME=DIR`` hot-loads a :func:`repro.serving.engine.
save_model_artifact` directory (the ``krr_tune --export-artifact`` output)
into a :class:`repro.serving.engine.ServingEngine`; every bucket is
pre-warmed at load.  The launcher then replays simulated open-loop Poisson
traffic (mixed request sizes, models drawn uniformly) through the coalescing
worker and prints the engine stats JSON — per-model request counts, qps,
p50/p99 latency, batch-occupancy histogram and compile-cache depth.  With
``--requests 0`` it skips traffic and just prints the loaded registry (a
smoke check that artifacts bind).  See docs/serving.md.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", action="append", default=[],
                    metavar="NAME=DIR",
                    help="load a save_model_artifact directory as NAME "
                         "(repeatable)")
    ap.add_argument("--artifacts-dir", default=None, metavar="DIR",
                    help="restore the whole registry: register every "
                         "artifact subdirectory of DIR under its directory "
                         "name (ServingEngine.load_artifacts_dir)")
    ap.add_argument("--max-batch", type=int, default=1024,
                    help="largest fused bucket / coalescing drain cap")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="coalescing window the worker holds a batch open")
    ap.add_argument("--max-bytes", type=int, default=None,
                    help="registry memory budget (LRU-evicts past it)")
    ap.add_argument("--requests", type=int, default=200,
                    help="simulated requests to replay (0: just load + stats)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="offered load, requests/s (Poisson arrivals)")
    ap.add_argument("--max-rows", type=int, default=16,
                    help="largest simulated request (rows drawn 1..max-rows)")
    ap.add_argument("--mesh", default=None,
                    help="ROWSxMODEL device mesh (e.g. 4x1) or 'auto': serve "
                         "every model row-sharded behind the same front end")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write telemetry (serve/batch spans + metrics) "
                         "as JSONL to PATH (repro.obs)")
    ap.add_argument("--prometheus", action="store_true",
                    help="also print the Prometheus text exposition of the "
                         "per-model latency histograms + counters")
    args = ap.parse_args()
    if not args.artifact and args.artifacts_dir is None:
        ap.error("pass at least one --artifact NAME=DIR or --artifacts-dir")

    from repro.serving.engine import ServingEngine

    mesh = None
    if args.mesh is not None:
        from repro.distributed.meshes import make_solver_mesh

        mesh = make_solver_mesh(args.mesh)

    tel = None
    if args.telemetry:
        from repro.obs import Telemetry

        tel = Telemetry(jsonl=args.telemetry)

    engine = ServingEngine(max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms,
                           max_bytes=args.max_bytes,
                           telemetry=tel)
    report: dict = {"loaded": {}}
    try:
        if args.artifacts_dir is not None:
            report["loaded"].update(
                engine.load_artifacts_dir(args.artifacts_dir, mesh=mesh)
            )
        for spec in args.artifact:
            if "=" not in spec:
                ap.error(f"--artifact wants NAME=DIR, got {spec!r}")
            name, path = spec.split("=", 1)
            info = engine.load_model(name, path, mesh=mesh)
            report["loaded"][name] = info

        if args.requests > 0:
            r = np.random.default_rng(args.seed)
            names = engine.models()
            widths = {n: report["loaded"][n]["d"] for n in names}
            arrivals = np.cumsum(
                r.exponential(1.0 / args.rate, size=args.requests)
            )
            t0 = time.monotonic()
            futures = []
            for t_arr, name in zip(
                arrivals, (names[int(i)] for i in r.integers(
                    len(names), size=args.requests))
            ):
                lag = t_arr - (time.monotonic() - t0)
                if lag > 0:
                    time.sleep(lag)
                q = int(r.integers(1, args.max_rows + 1))
                xq = r.standard_normal((q, widths[name])).astype(np.float32)
                futures.append(engine.submit(name, xq))
            engine.drain()
            for f in futures:
                f.result()  # surface any serving error
            report["traffic"] = {
                "requests": args.requests,
                "offered_rps": args.rate,
                "seconds": round(time.monotonic() - t0, 3),
            }
        report["stats"] = engine.stats()
        if args.prometheus:
            report["prometheus"] = engine.prometheus_text()
    finally:
        engine.shutdown()
        if tel is not None:
            tel.close()  # flush metric events after the worker stops
            report["telemetry"] = args.telemetry
    print(json.dumps(report, indent=2, default=float))


if __name__ == "__main__":
    main()
