"""Serving launcher: batched prefill + greedy decode for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, get_reduced_config
from repro.data import synthetic
from repro.models.model_api import get_model, init_params
from repro.serving.serve_step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    batch = synthetic.batch_for(cfg, (args.batch, args.prompt_len), args.seed, 0)
    batch.pop("labels", None)

    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, batch, args.max_new)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(json.dumps({
        "arch": cfg.name,
        "generated_shape": list(out.shape),
        "tokens": toks,
        "seconds": round(dt, 3),
        "tok_per_s": round(toks / dt, 1),
        "sample": out[0, :8].tolist(),
    }))


if __name__ == "__main__":
    main()
