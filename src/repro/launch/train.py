"""Training launcher: config-driven, checkpoint/restart fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1 [--resume]

Fault tolerance (DESIGN.md §4):
  * atomic checkpoints every --ckpt-every steps (params, optimizer state,
    step, data-pipeline cursor); restore reshards onto the current mesh
    (elastic: a run checkpointed on N devices restarts on M).
  * the step loop runs under a supervised retry loop: on failure the process
    restores the latest checkpoint and continues (at true multi-pod scale the
    cluster scheduler restarts the job; the code path is identical).
  * --inject-failure N raises at step N once (tests/fault drill).
  * straggler watchdog: per-step wall time is tracked; steps slower than
    --straggler-factor x the running median are logged (at scale this feeds
    the controller's hot-spare logic).
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.configs.base import get_config, get_reduced_config
from repro.data.pipeline import LMDataPipeline
from repro.distributed.meshes import default_rules, logical_rules, named_shardings
from repro.models.model_api import abstract_params, get_model, init_params, param_pspecs
from repro.training.optimizers import make_optimizer
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step


class FailureInjected(RuntimeError):
    pass


def build(cfg, mesh, lr=3e-4, total_steps=10_000):
    rules = default_rules(mesh) if mesh is not None else None
    pspecs = param_pspecs(cfg)
    params_struct = abstract_params(cfg)
    params_sh = named_shardings(mesh, pspecs, rules) if mesh else None
    opt = make_optimizer(cfg.optimizer, warmup_cosine(lr, min(100, total_steps // 10 + 1), total_steps))
    train_step = make_train_step(cfg, opt)

    def stepfn(params, opt_state, batch, step):
        if rules is None:
            return train_step(params, opt_state, batch, step)
        with logical_rules(rules):
            return train_step(params, opt_state, batch, step)

    return opt, jax.jit(stepfn, donate_argnums=(0, 1)), params_sh, rules


def run(args) -> dict:
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = None
    if len(jax.devices()) > 1:
        import math

        n = len(jax.devices())
        dmodel = math.gcd(n, 4)
        mesh = jax.make_mesh(
            (n // dmodel, dmodel), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
    opt, stepfn, params_sh, rules = build(cfg, mesh, args.lr, args.steps)

    start_step = 0
    if args.resume and checkpointer.latest_step(args.ckpt_dir) is not None:
        state, extra, start_step = checkpointer.restore(args.ckpt_dir)
        params, opt_state = state["params"], state["opt_state"]
        params = jax.tree.map(lambda x: jnp.asarray(x), params)
        opt_state = jax.tree.map(lambda x: jnp.asarray(x), opt_state)
        data_state = extra.get("data", {"seed": args.seed, "step": start_step})
        print(f"[resume] step {start_step}")
    else:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        opt_state = opt.init(params)
        data_state = {"seed": args.seed, "step": 0}

    pipe = LMDataPipeline.from_state(cfg, args.batch, args.seq, data_state)
    history = []
    step_times: list[float] = []
    failed_once = False
    step = start_step
    while step < args.steps:
        try:
            t0 = time.perf_counter()
            batch = next(pipe)
            if args.inject_failure >= 0 and step == args.inject_failure and not failed_once:
                failed_once = True
                raise FailureInjected(f"injected failure at step {step}")
            params, opt_state, metrics = stepfn(
                params, opt_state, batch, jnp.int32(step)
            )
            dt = time.perf_counter() - t0
            step_times.append(dt)
            if len(step_times) > 8:
                med = statistics.median(step_times[-50:])
                if dt > args.straggler_factor * med:
                    print(f"[straggler] step {step}: {dt:.3f}s vs median {med:.3f}s")
            step += 1
            if step % args.log_every == 0 or step == args.steps:
                rec = {"step": step, "loss": float(metrics["loss"]), "sec": round(dt, 4)}
                history.append(rec)
                print(json.dumps(rec), flush=True)
            if args.ckpt_dir and step % args.ckpt_every == 0:
                checkpointer.save(
                    args.ckpt_dir, step,
                    {"params": params, "opt_state": opt_state},
                    extra={"data": pipe.state(), "arch": cfg.name},
                )
        except FailureInjected as e:
            print(f"[fault] {e}; restarting from checkpoint", flush=True)
            if checkpointer.latest_step(args.ckpt_dir) is None:
                # no checkpoint yet: restart from scratch
                params = init_params(jax.random.PRNGKey(args.seed), cfg)
                opt_state = opt.init(params)
                pipe = LMDataPipeline(cfg, args.batch, args.seq, seed=args.seed)
                step = 0
            else:
                state, extra, step = checkpointer.restore(args.ckpt_dir)
                params = jax.tree.map(jnp.asarray, state["params"])
                opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
                pipe = LMDataPipeline.from_state(cfg, args.batch, args.seq, extra["data"])
    return {"history": history, "final_step": step}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=-1)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
