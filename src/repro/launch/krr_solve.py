"""KRR solve launcher — the paper's workload end-to-end.

    PYTHONPATH=src python -m repro.launch.krr_solve --n 20000 --d 9 \
        --method askotch --iters 300 [--distributed]

    # one-vs-all multi-class: t heads solved in ONE multi-RHS pass
    PYTHONPATH=src python -m repro.launch.krr_solve --dataset one-vs-all \
        --classes 8 --method askotch

Single-device path uses repro.core (any solver from the paper's comparison
set); --distributed runs the shard_map multi-device ASkotch.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core.krr import KRRProblem, evaluate, evaluate_per_head
from repro.core.solver_api import solve as solve_any
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=9)
    ap.add_argument("--n-test", type=int, default=2_000)
    ap.add_argument("--kernel", default="rbf")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1e-6)
    ap.add_argument("--method", default="askotch")
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--dataset", default="regression",
                    choices=["regression", "classification", "one-vs-all", "taxi"])
    ap.add_argument("--classes", type=int, default=4,
                    help="number of one-vs-all heads (dataset=one-vs-all)")
    args = ap.parse_args()

    if args.distributed and args.dataset == "one-vs-all":
        ap.error("--distributed is single-RHS for now; it does not support "
                 "--dataset one-vs-all (run the heads through the "
                 "single-device multi-RHS path instead)")

    if args.dataset == "taxi":
        x, y = synthetic.taxi_like(args.seed, args.n + args.n_test, args.d)
        x_tr, y_tr = x[: args.n], y[: args.n]
        x_te, y_te = x[args.n :], y[args.n :]
    elif args.dataset == "one-vs-all":
        x_tr, y_tr, _, x_te, y_te, _labels = synthetic.krr_one_vs_all(
            args.seed, args.n, args.d, num_classes=args.classes,
            n_test=args.n_test,
        )
    else:
        gen = (synthetic.krr_classification if args.dataset == "classification"
               else synthetic.krr_regression)
        x_tr, y_tr, x_te, y_te = gen(args.seed, args.n, args.d, args.n_test)

    prob = KRRProblem(x=x_tr, y=y_tr, kernel=args.kernel, sigma=args.sigma,
                      lam_unscaled=args.lam, backend="xla")

    t0 = time.perf_counter()
    if args.distributed:
        from repro.distributed.krr_dist import (
            DistKRRConfig, init_dist_state, make_dist_askotch_step,
        )
        ndev = len(jax.devices())
        model = 2 if ndev % 2 == 0 and ndev > 1 else 1
        mesh = jax.make_mesh(
            (ndev // model, model), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
        dcfg = DistKRRConfig(
            n=args.n, d=args.d, kernel=args.kernel, sigma=args.sigma,
            lam_unscaled=args.lam,
            block_size=max(64, args.n // 100), rank=min(100, max(16, args.n // 200)),
        )
        step, sh = make_dist_askotch_step(mesh, dcfg)
        state = init_dist_state(dcfg, args.seed)
        with mesh:
            jstep = jax.jit(step)
            xs = jax.device_put(x_tr, sh["x"])
            ys = jax.device_put(y_tr, sh["y"])
            state = jax.device_put(state, sh["state"])
            for _ in range(args.iters):
                state = jstep(state, xs, ys)
                jax.block_until_ready(state.w)
        w = state.w
        info = {"method": "askotch-distributed", "iters": args.iters}
    else:
        if args.method == "direct":
            kw = {}
        elif args.method == "eigenpro":
            kw = {"epochs": max(1, args.iters // 100)}  # SGD epochs, not iters
        else:
            kw = {"max_iters": args.iters}
        if args.method == "falkon":
            # default center count, clamped so tiny-n runs stay sampleable
            kw["m"] = min(1000, max(50, args.n // 20), args.n)
        out = solve_any(prob, args.method, **kw)
        w, info = out.w, {"method": args.method, **out.info}

    if args.distributed or args.method != "falkon":
        rel_agg, rel_heads = prob.residual_report(w)
        rel = float(rel_agg)
    else:  # inducing-point weights (falkon): full-K residual is undefined
        rel, rel_heads = -1.0, None
    pred = prob.predict(w, x_te) if args.distributed else out.predict_fn(x_te)
    m = evaluate(pred, y_te)
    report = {
        **info,
        "n": args.n,
        "rel_residual": rel,
        "test_rmse": float(m.rmse),
        "test_mae": float(m.mae),
        "test_acc": float(m.accuracy),
        "seconds": round(time.perf_counter() - t0, 2),
    }
    if prob.t > 1:
        # test_acc above already IS top-1 accuracy: evaluate() decodes t > 1
        # predictions by argmax, and argmax of the ±1 one-hot targets is the
        # integer label by construction
        mh = evaluate_per_head(pred, y_te)
        if rel_heads is not None:
            report["rel_residual_per_head"] = [float(v) for v in rel_heads]
        report["test_acc_per_head"] = [float(v) for v in mh.accuracy]
    print(json.dumps(report))


if __name__ == "__main__":
    main()
