"""KRR solve launcher — the paper's workload end-to-end.

    PYTHONPATH=src python -m repro.launch.krr_solve --n 20000 --d 9 \
        --method askotch --iters 300 [--mesh 4x2]

    # one-vs-all multi-class: t heads solved in ONE multi-RHS pass
    PYTHONPATH=src python -m repro.launch.krr_solve --dataset one-vs-all \
        --classes 8 --method askotch

A distributed solve is the same call as a local one: ``--mesh ROWSxMODEL``
(e.g. ``--mesh 4x2``; ``--mesh auto`` = all devices on rows) routes the
askotch/skotch/pcg-nystrom/cg methods through ``solve(..., mesh=...)`` on a
ShardedKernelOperator — multi-RHS (one-vs-all) included.  ``--distributed``
is a deprecated alias for ``--mesh auto``.

``--method dc`` runs the communication-avoiding divide-and-conquer tier
(``--dc-shards/--dc-partition/--dc-combiner/--dc-method``, optionally with
``--mesh`` for device-parallel shards and zero collective traffic):

    PYTHONPATH=src python -m repro.launch.krr_solve --method dc \
        --dc-shards 4 --dc-method pcg-nystrom --mesh auto
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.kernels import KERNEL_NAMES
from repro.core.krr import KRRProblem, evaluate, evaluate_per_head
from repro.core.solver_api import solve as solve_any
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=9)
    ap.add_argument("--n-test", type=int, default=2_000)
    ap.add_argument("--kernel", default="rbf", choices=KERNEL_NAMES,
                    help="kernel zoo name (core.kernels.KERNEL_NAMES)")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1e-6)
    ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                    help="kernel tile-compute policy: bf16 tiles with f32 "
                         "accumulation, or full f32")
    ap.add_argument("--method", default="askotch")
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--dc-shards", type=int, default=4,
                    help="method=dc: shard count k (k=1 == the plain solver)")
    ap.add_argument("--dc-partition", default="random",
                    choices=["random", "kmeans"],
                    help="method=dc: partitioner (distributed.partition)")
    ap.add_argument("--dc-combiner", default="uniform",
                    choices=["uniform", "softmax"],
                    help="method=dc: prediction combiner (distributed.dc)")
    ap.add_argument("--dc-method", default="askotch",
                    help="method=dc: the inner solver run per shard")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="ROWSxMODEL device mesh (e.g. 4x2) or 'auto'; "
                         "runs the solve distributed via ShardedKernelOperator")
    ap.add_argument("--distributed", action="store_true",
                    help="deprecated alias for --mesh auto")
    ap.add_argument("--dataset", default="regression",
                    choices=["regression", "classification", "one-vs-all", "taxi"])
    ap.add_argument("--classes", type=int, default=4,
                    help="number of one-vs-all heads (dataset=one-vs-all)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write telemetry (spans + solver traces + metrics) "
                         "as JSONL to PATH (repro.obs)")
    args = ap.parse_args()

    mesh_spec = args.mesh if args.mesh is not None else (
        "auto" if args.distributed else None)

    if args.dataset == "taxi":
        x, y = synthetic.taxi_like(args.seed, args.n + args.n_test, args.d)
        x_tr, y_tr = x[: args.n], y[: args.n]
        x_te, y_te = x[args.n :], y[args.n :]
    elif args.dataset == "one-vs-all":
        x_tr, y_tr, _, x_te, y_te, _labels = synthetic.krr_one_vs_all(
            args.seed, args.n, args.d, num_classes=args.classes,
            n_test=args.n_test,
        )
    else:
        gen = (synthetic.krr_classification if args.dataset == "classification"
               else synthetic.krr_regression)
        x_tr, y_tr, x_te, y_te = gen(args.seed, args.n, args.d, args.n_test)

    prob = KRRProblem(x=x_tr, y=y_tr, kernel=args.kernel, sigma=args.sigma,
                      lam_unscaled=args.lam, backend="xla",
                      precision=args.precision)

    if args.method == "direct":
        kw = {}
    elif args.method == "eigenpro":
        kw = {"epochs": max(1, args.iters // 100)}  # SGD epochs, not iters
    else:
        kw = {"max_iters": args.iters}
    if args.method == "falkon":
        # default center count, clamped so tiny-n runs stay sampleable
        kw["m"] = min(1000, max(50, args.n // 20), args.n)
    if args.method == "dc":
        kw.update(dc_shards=args.dc_shards, dc_partition=args.dc_partition,
                  dc_combiner=args.dc_combiner, dc_method=args.dc_method)

    tel = None
    if args.telemetry:
        from repro.obs import Telemetry

        tel = Telemetry(jsonl=args.telemetry)
        kw["telemetry"] = tel

    t0 = time.perf_counter()
    if mesh_spec is not None:
        from repro.distributed.meshes import make_solver_mesh

        mesh = make_solver_mesh(mesh_spec)
        out = solve_any(prob, args.method, mesh=mesh, **kw)
        # gather the row-sharded weights for host-side reporting
        w = np.asarray(out.w) if out.w is not None else None
        info = {"method": f"{args.method}-distributed", **out.info}
    else:
        out = solve_any(prob, args.method, **kw)
        w, info = out.w, {"method": args.method, **out.info}
    if tel is not None:
        tel.close()  # flush metric events after the solve span closes

    if args.method == "falkon":  # inducing-point weights: full-K residual undefined
        rel, rel_heads = -1.0, None
    elif args.method == "dc":
        # the global residual is undefined for the combined local models;
        # history's aggregate record carries the worst LOCAL shard residual
        rel = out.history[-1].get("rel_residual")
        rel = float(rel) if rel is not None else -1.0
        rel_heads = None
    elif mesh_spec is not None and out.history:
        # the distributed solve already evaluated the residual on the mesh —
        # don't re-stream the O(n^2 d) kernel pass on one host device
        rel = out.history[-1]["rel_residual"]
        rel_heads = out.history[-1].get("rel_residual_per_head")
    else:
        rel_agg, rel_heads = prob.residual_report(w)
        rel = float(rel_agg)
    pred = np.asarray(out.predict_fn(x_te))  # gather (mesh path) / no-op copy
    m = evaluate(pred, y_te)
    report = {
        **info,
        "n": args.n,
        "rel_residual": rel,
        "test_rmse": float(m.rmse),
        "test_mae": float(m.mae),
        "test_acc": float(m.accuracy),
        "seconds": round(time.perf_counter() - t0, 2),
    }
    if args.telemetry:
        report["telemetry"] = args.telemetry
    if prob.t > 1:
        # test_acc above already IS top-1 accuracy: evaluate() decodes t > 1
        # predictions by argmax, and argmax of the ±1 one-hot targets is the
        # integer label by construction
        mh = evaluate_per_head(pred, y_te)
        if rel_heads is not None:
            report["rel_residual_per_head"] = [float(v) for v in rel_heads]
        report["test_acc_per_head"] = [float(v) for v in mh.accuracy]
    print(json.dumps(report))


if __name__ == "__main__":
    main()
