"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before the first jax call and only then builds meshes.

Mesh construction goes through ``distributed.jax_compat`` so the same code
runs on jax 0.4.x (no axis types) and 0.6+ (typed Auto axes).
"""

from __future__ import annotations

from repro.distributed.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CI-grade dry-run tests; (1, 1) runs on one device."""
    return make_mesh(shape, axes)
