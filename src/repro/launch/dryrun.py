import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# CPU-backend workaround (before any jax import): XLA's while-loop-invariant
# code motion hoists dtype converts out of scan bodies, materializing f32
# copies of whole parameter/activation stacks — a memory-accounting artifact
# of the host pipeline that the TPU scheduler doesn't exhibit.
os.environ["XLA_FLAGS"] += " --xla_disable_hlo_passes=while-loop-invariant-code-motion"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this records memory_analysis(), cost_analysis(), and the parsed
collective schedule into one JSON under --out (resumable; one file per cell).

Because XLA cost analysis counts while-loop bodies once, each single-pod cell
additionally compiles two small UNROLLED probes (L=1/L=2 layers — periods for
the hybrid — with microbatches=1 and unchunked attention) and extrapolates
per-layer FLOPs/bytes/collective-bytes to the full depth (§Roofline inputs).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh both --out results/dryrun [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import askotch_krr
from repro.configs.base import ARCH_IDS, get_config
from repro.distributed.krr_dist import (
    DistKRRConfig,
    abstract_dist_inputs,
    make_dist_askotch_step,
)
from repro.distributed.meshes import (
    default_rules,
    logical_rules,
    named_shardings,
    sanitized_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model_api import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    abstract_params,
    get_model,
    param_pspecs,
    shape_applicable,
)
from repro.roofline import analyze
from repro.training.optimizers import make_optimizer
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step

KRR_ARCH = "askotch-krr-taxi-100m"


def _batch_pspecs(binputs: dict) -> dict:
    return {
        name: P("dp", None, None) if s.ndim == 3 else P("dp", None)
        for name, s in binputs.items()
    }


def _rules_for(cfg, mesh):
    rules = default_rules(mesh)
    if getattr(cfg, "fsdp_over_pod", False) and "pod" in mesh.axis_names:
        rules = dict(rules)
        rules["fsdp"] = ("pod", "data")
    return rules


def lower_cell(cfg, shape, mesh):
    """Lower one (arch x shape) cell on `mesh`; returns (lowered, donate_info)."""
    impl = get_model(cfg)
    rules = _rules_for(cfg, mesh)
    params_struct = abstract_params(cfg)
    pspecs = param_pspecs(cfg)
    params_sh = sanitized_shardings(mesh, pspecs, params_struct, rules)
    binputs = impl.input_specs(cfg, shape)
    b_sh = sanitized_shardings(mesh, _batch_pspecs(binputs), binputs, rules)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, warmup_cosine(3e-4, 2000, 100_000))
        opt_struct = jax.eval_shape(opt.init, params_struct)
        opt_sh = sanitized_shardings(
            mesh, opt.state_specs(pspecs, params_struct), opt_struct, rules
        )
        train_step = make_train_step(cfg, opt)

        def stepfn(params, opt_state, batch, step):
            with logical_rules(rules):
                return train_step(params, opt_state, batch, step)

        with mesh:
            jitted = jax.jit(
                stepfn,
                in_shardings=(params_sh, opt_sh, b_sh, NamedSharding(mesh, P())),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            return jitted.lower(
                params_struct, opt_struct, binputs, jax.ShapeDtypeStruct((), jnp.int32)
            )

    if shape.kind == "prefill":

        def prefill(params, batch):
            with logical_rules(rules):
                return impl.prefill(params, batch, cfg)

        cache_struct = impl.init_cache(
            cfg, shape.global_batch, shape.seq_len, abstract=True
        )
        cache_sh = sanitized_shardings(
            mesh, impl.cache_specs(cfg, shape.global_batch, shape.seq_len),
            cache_struct, rules,
        )
        with mesh:
            jitted = jax.jit(
                prefill, in_shardings=(params_sh, b_sh), out_shardings=(None, cache_sh)
            )
            return jitted.lower(params_struct, binputs)

    # decode: one new token against a seq_len cache
    cache_struct = impl.init_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)
    cache_sh = sanitized_shardings(
        mesh, impl.cache_specs(cfg, shape.global_batch, shape.seq_len),
        cache_struct, rules,
    )

    def decode(params, cache, batch):
        with logical_rules(rules):
            return impl.decode_step(params, cache, batch, cfg)

    with mesh:
        jitted = jax.jit(
            decode,
            in_shardings=(params_sh, cache_sh, b_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        )
        return jitted.lower(params_struct, cache_struct, binputs)


def lower_krr_cell(mesh):
    kcfg = askotch_krr.config()
    dcfg = DistKRRConfig(
        n=kcfg.n, d=kcfg.d, kernel=kcfg.kernel, sigma=kcfg.sigma,
        lam_unscaled=kcfg.lam_unscaled, block_size=kcfg.block_size, rank=kcfg.rank,
    )
    step, sh = make_dist_askotch_step(mesh, dcfg)
    state, x, y = abstract_dist_inputs(dcfg)
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=(sh["state"], sh["x"], sh["y"]),
            out_shardings=sh["state"],
            donate_argnums=(0,),
        )
        return jitted.lower(state, x, y), dcfg


def _probe_cfg(cfg, units: int):
    """Small unrolled config for cost extrapolation."""
    fields = dict(
        microbatches_train=1, scan_unroll=True, attn_q_chunk=1 << 30,
        moe_dispatch_tokens=1 << 30, remat="none",
    )
    if cfg.family == "hybrid":
        fields["num_layers"] = units * cfg.attn_period
    elif cfg.family == "encdec":
        fields["num_layers"] = units
        fields["encoder_layers"] = units
    else:
        fields["num_layers"] = units
    return dataclasses.replace(cfg, **fields)


def _units(cfg) -> int:
    return cfg.num_layers // cfg.attn_period if cfg.family == "hybrid" else cfg.num_layers


def compile_and_measure(lowered) -> tuple[dict, analyze.CellCost]:
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    return mem, analyze.cell_cost(compiled)


def run_cell(arch: str, shape_name: str, mesh_name: str, probes: bool) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    if arch == KRR_ARCH:
        lowered, dcfg = lower_krr_cell(mesh)
        mem, cost = compile_and_measure(lowered)
        rec.update(status="ok", memory=mem, seconds=round(time.time() - t0, 1))
        rec["cost_raw"] = dataclasses.asdict(cost)
        # analytic FLOPs for the fused matvecs (inner chunk scans count once)
        chips = mesh.devices.size
        b, n, d, r, it = (dcfg.block_size, dcfg.n, dcfg.d, dcfg.rank,
                          10)  # powering iters
        flops = (
            n * b * (3 * d + 2)  # g_B fused matvec
            + b * b * (3 * d + 2 * r)  # Nystrom sketch
            + it * b * b * (3 * d + 2)  # powering matvecs
        )
        rec["cost_extrapolated"] = {
            "flops": flops / chips,
            "bytes_accessed": cost.bytes_accessed,
            "coll_bytes": cost.coll_bytes,
            "coll_breakdown": cost.coll_breakdown,
            "note": "flops analytic (fused matvec chunk-scan bodies count once)",
        }
        rec["model_flops_total"] = flops
        return rec

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    lowered = lower_cell(cfg, shape, mesh)
    mem, cost_raw = compile_and_measure(lowered)
    rec.update(
        status="ok",
        memory=mem,
        n_params=cfg.n_params(),
        n_active_params=cfg.n_active_params(),
        cost_raw=dataclasses.asdict(cost_raw),
    )

    if probes:
        try:
            c1 = _probe_cfg(cfg, 1)
            c2 = _probe_cfg(cfg, 2)
            _, p1 = compile_and_measure(lower_cell(c1, shape, mesh))
            _, p2 = compile_and_measure(lower_cell(c2, shape, mesh))
            full = analyze.extrapolate(p1, p2, 1, _units(cfg) - 1)
            rec["cost_extrapolated"] = dataclasses.asdict(full)
            rec["probe_raw"] = {
                "l1": dataclasses.asdict(p1), "l2": dataclasses.asdict(p2),
            }
        except Exception as e:  # probes are best-effort
            rec["probe_error"] = f"{type(e).__name__}: {e}"

    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    rec["tokens"] = tokens
    rec["model_flops_total"] = analyze.model_flops(
        cfg.n_params(), cfg.n_active_params(), tokens, shape.kind == "train"
    )
    rec["seconds"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) + [KRR_ARCH] if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in ALL_SHAPES] if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        arch_shapes = ["krr_step"] if arch == KRR_ARCH else shapes
        for shape_name in arch_shapes:
            for mesh_name in meshes:
                tag = f"{arch}__{shape_name}__{mesh_name}".replace("/", "_")
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}", flush=True)
                    continue
                try:
                    # probes only on the single-pod mesh (roofline table source)
                    rec = run_cell(
                        arch, shape_name, mesh_name,
                        probes=(not args.no_probes) and mesh_name == "single",
                    )
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "failed",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec.get("status")
                mem = rec.get("memory", {})
                gb = 1 / 2**30
                extra = (
                    f" arg={mem.get('argument_bytes', 0)*gb:.2f}G"
                    f" temp={mem.get('temp_bytes', 0)*gb:.2f}G"
                    if mem else f" ({rec.get('reason') or rec.get('error', '')[:80]})"
                )
                print(f"[{status}] {tag}{extra} {rec.get('seconds', 0)}s", flush=True)


if __name__ == "__main__":
    main()
