"""KRR hyperparameter tuning launcher — tune, refit, evaluate, export.

    PYTHONPATH=src python -m repro.launch.krr_tune --n 4000 --d 8 \
        --sigmas 0.5,1.0,2.0 --lams 1e-6,1e-4,1e-2 --folds 5

    # random search over the grid, distributed over a device mesh
    PYTHONPATH=src python -m repro.launch.krr_tune --search random --samples 6 \
        --mesh 4x1 --dataset one-vs-all --classes 8

    # multi-kernel: random search over convex kernel combinations
    PYTHONPATH=src python -m repro.launch.krr_tune \
        --kernels rbf,laplacian,matern52 --n-weight-samples 8

    # successive halving (prune losers mid-solve) + sigma-continuation
    PYTHONPATH=src python -m repro.launch.krr_tune --policy halving \
        --sigma-continuation --lams 1e-8,1e-6,1e-4,1e-2

The sweep is the tile-sharing path of ``repro.core.tune`` (``--strategy
naive`` runs the per-candidate reference loop for comparison); ``--kernels``
(a comma list) grows the weight axis — himalaya-style Dirichlet random
search over convex kernel combinations on the same stacked engine.
``--policy halving`` runs successive halving: losing (lam[, weight])
candidates are frozen at rungs MID-SOLVE (strictly fewer kernel sweeps than
the grid at equal best config when the winner separates early);
``--sigma-continuation`` seeds each sigma group's solve and sketch from the
previous group's result.  The report includes the kernel-sweep count so the
sharing is visible.  After the sweep the best config is refit on the full
training set with ``--method`` (warm-started from the winner's
fold-averaged CV solution when the method supports ``w0``) and scored on
held-out test data; ``--export PATH`` writes the serving-ready best-config
JSON — including the per-candidate ``trace`` (rung scores + prune points)
so the search is auditable — consumed by ``serving.krr_serve.
make_krr_predict_fn_from_config``; ``--export-artifact DIR`` additionally
writes a full serving artifact (config + training rows + refit weights)
that ``repro.launch.krr_serve``/``ServingEngine.load_model`` hot-load from
disk.  See docs/tuning.md and docs/serving.md for the walkthroughs.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.kernels import KERNEL_NAMES
from repro.core.krr import KRRProblem, evaluate
from repro.core.solver_api import solve as solve_any
from repro.core.solver_api import tune
from repro.core.tune import apply_best
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4_000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--n-test", type=int, default=1_000)
    ap.add_argument("--kernel", default="rbf", choices=KERNEL_NAMES,
                    help="kernel zoo name (core.kernels.KERNEL_NAMES)")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel zoo names: tune a convex "
                         "multi-kernel combination (weight random search)")
    ap.add_argument("--n-weight-samples", type=int, default=8,
                    help="Dirichlet weight draws for --kernels search")
    ap.add_argument("--dirichlet-alpha", type=float, default=1.0,
                    help="Dirichlet concentration of the weight draws")
    ap.add_argument("--sigmas", default="0.5,1.0,2.0",
                    help="comma-separated candidate bandwidths")
    ap.add_argument("--lams", default="1e-6,1e-4,1e-2",
                    help="comma-separated candidate unscaled regularizers")
    ap.add_argument("--folds", type=int, default=5)
    ap.add_argument("--search", default="grid", choices=["grid", "random"])
    ap.add_argument("--samples", type=int, default=None,
                    help="random-search candidate count (default: full grid)")
    ap.add_argument("--policy", default=None,
                    choices=["grid", "random", "halving"],
                    help="search policy (supersedes --search); 'halving' "
                         "prunes losing candidates at rungs mid-solve")
    ap.add_argument("--halving-eta", type=float, default=3.0,
                    help="successive-halving reduction factor (> 1)")
    ap.add_argument("--sigma-continuation", action="store_true",
                    help="seed each sigma group's solve + sketch from the "
                         "previous group instead of from zero")
    ap.add_argument("--strategy", default="shared", choices=["shared", "naive"])
    ap.add_argument("--rank", type=int, default=100,
                    help="Nystrom preconditioner rank")
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                    help="kernel tile-compute policy for the sweep AND the "
                         "refit: bf16 tiles with f32 accumulation, or f32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="ROWSxMODEL device mesh (e.g. 4x1) or 'auto'; runs "
                         "the sweep over a ShardedKernelOperator")
    ap.add_argument("--dataset", default="regression",
                    choices=["regression", "classification", "one-vs-all", "taxi"])
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--method", default="askotch",
                    help="refit method for the best config")
    ap.add_argument("--refit-iters", type=int, default=300)
    ap.add_argument("--no-refit", action="store_true",
                    help="report the sweep only; skip refit + test metrics")
    ap.add_argument("--export", default=None,
                    help="write the best-config JSON here (serving input)")
    ap.add_argument("--export-artifact", default=None,
                    help="write a full serving artifact directory here "
                         "(config.json + weights.npz with the refit "
                         "solution; loadable by ServingEngine.load_model)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write telemetry (spans + tune traces + metrics) "
                         "as JSONL to PATH (repro.obs)")
    args = ap.parse_args()
    if args.export_artifact and args.no_refit:
        ap.error("--export-artifact needs the refit weights; drop --no-refit")

    if args.dataset == "taxi":
        x, y = synthetic.taxi_like(args.seed, args.n + args.n_test, args.d)
        x_tr, y_tr, x_te, y_te = x[: args.n], y[: args.n], x[args.n :], y[args.n :]
    elif args.dataset == "one-vs-all":
        x_tr, y_tr, _, x_te, y_te, _labels = synthetic.krr_one_vs_all(
            args.seed, args.n, args.d, num_classes=args.classes,
            n_test=args.n_test,
        )
    else:
        gen = (synthetic.krr_classification if args.dataset == "classification"
               else synthetic.krr_regression)
        x_tr, y_tr, x_te, y_te = gen(args.seed, args.n, args.d, args.n_test)

    prob = KRRProblem(x=x_tr, y=y_tr, kernel=args.kernel, backend="xla",
                      precision=args.precision)
    mesh = None
    if args.mesh is not None:
        from repro.distributed.meshes import make_solver_mesh

        mesh = make_solver_mesh(args.mesh)

    t0 = time.perf_counter()
    tune_kw = dict(
        sigmas=tuple(float(s) for s in args.sigmas.split(",")),
        lams=tuple(float(l) for l in args.lams.split(",")),
        folds=args.folds,
        strategy=args.strategy,
        rank=args.rank,
        max_iters=args.iters,
        tol=args.tol,
        seed=args.seed,
        sigma_continuation=args.sigma_continuation,
    )
    tel = None
    if args.telemetry:
        from repro.obs import Telemetry

        tel = Telemetry(jsonl=args.telemetry)
        tune_kw["telemetry"] = tel
    if args.policy is not None:
        tune_kw.update(policy=args.policy, halving_eta=args.halving_eta)
    if args.kernels is not None:
        if args.search != "grid" or args.samples is not None:
            ap.error(
                "--search/--samples do not apply with --kernels; the weight "
                "axis IS the random search (use --n-weight-samples, or "
                "--policy halving to prune it)"
            )
        bad = [k for k in args.kernels.split(",") if k not in KERNEL_NAMES]
        if bad:
            ap.error(f"unknown kernel(s) {bad}; available: {KERNEL_NAMES}")
        # the weight axis: every (w, lam, fold, head) candidate rides the
        # same stacked solve (repro.core.tune.tune_multikernel)
        tune_kw.update(
            kernels=tuple(args.kernels.split(",")),
            n_weight_samples=args.n_weight_samples,
            dirichlet_alpha=args.dirichlet_alpha,
        )
    else:
        tune_kw.update(search=args.search, num_samples=args.samples)
    result = tune(prob, mesh=mesh, **tune_kw)
    report = {
        "best": result.best,
        "strategy": result.strategy,
        "search": result.search,
        "policy": result.info["policy"],
        "candidates": result.info["candidates"],
        "folds": result.folds,
        "kernel_sweeps": round(result.sweeps, 2),
        "naive_sweep_estimate": round(result.info["naive_sweep_estimate"], 2),
        "records": result.records,
        "trace": result.trace,
    }
    if args.kernels is not None:
        report["weight_samples"] = result.info["weight_samples"]
    if mesh is not None:
        report["mesh"] = dict(mesh.shape)

    if not args.no_refit:
        from repro.core.solver_api import METHOD_OPTIONS

        best_prob, w0 = apply_best(prob, result, with_w0=True)
        kw = {} if args.method == "direct" else {"max_iters": args.refit_iters}
        if args.method == "eigenpro":
            kw = {"epochs": max(1, args.refit_iters // 100)}
        if args.method == "falkon":
            kw["m"] = min(1000, max(50, args.n // 20), args.n)
        if tel is not None:
            kw["telemetry"] = tel  # refit rides the same JSONL stream
        if (w0 is not None and mesh is None
                and "w0" in METHOD_OPTIONS.get(args.method, ())):
            # warm-start the refit from the winner's fold-averaged CV
            # solution instead of zero
            kw["w0"] = w0
            report["refit_warm_start"] = True
        out = solve_any(best_prob, args.method, mesh=mesh, **kw)
        m = evaluate(np.asarray(out.predict_fn(x_te)), y_te)
        report["refit"] = {
            "method": args.method,
            "test_rmse": float(m.rmse),
            "test_mae": float(m.mae),
            "test_acc": float(m.accuracy),
        }
        if args.export_artifact:
            from repro.serving.engine import save_model_artifact

            # tune -> refit -> artifact: config + training rows + refit
            # weights as files on disk, hot-loadable by the serving engine
            save_model_artifact(args.export_artifact, result.best,
                                np.asarray(x_tr), np.asarray(out.w))
            report["exported_artifact"] = args.export_artifact
    report["seconds"] = round(time.perf_counter() - t0, 2)
    if tel is not None:
        tel.close()  # flush metric events after all spans close
        report["telemetry"] = args.telemetry

    if args.export:
        # the serving-ready best config PLUS the audit trail: serving
        # ignores unknown keys, so the same file feeds
        # make_krr_predict_fn_from_config and post-hoc search forensics
        with open(args.export, "w") as fh:
            json.dump({**result.best, "trace": result.trace}, fh, indent=2)
        report["exported"] = args.export
    print(json.dumps(report))


if __name__ == "__main__":
    main()
