"""Whisper-style encoder-decoder (arXiv:2212.04356) — family "encdec".

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T, d) directly (as if produced by
the two conv layers); sinusoidal positions are added on the fly (the real
model's learned 448-position table doesn't extend to the assigned 4k/32k
shapes — deviation noted in DESIGN.md).

Encoder: bidirectional self-attention + GELU MLP, pre-LayerNorm.
Decoder: causal self-attention + cross-attention over encoder output + GELU
MLP.  Decode step carries a self-attention KV cache plus fixed cross K/V
computed at prefill.  Whisper ties embedding and LM head.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.meshes import logical_constraint
from repro.models import layers as L
from repro.models.model_api import (
    ArchConfig,
    ModelImpl,
    ParamDefs,
    ShapeConfig,
    register_family,
)


def param_defs(cfg: ArchConfig) -> ParamDefs:
    d, h, kv, hd, ff = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd, cfg.d_ff
    ne, nd = cfg.encoder_layers, cfg.num_layers
    vp = cfg.padded_vocab()
    atp = "tp" if h % 16 == 0 else None  # whisper-base: 8 heads -> replicated
    defs: ParamDefs = {
        "embed": ((vp, d), P("tp", "fsdp")),  # tied: used for both ends
        "enc_final_scale": ((d,), P(None)),
        "enc_final_bias": ((d,), P(None)),
        "dec_final_scale": ((d,), P(None)),
        "dec_final_bias": ((d,), P(None)),
    }

    def attn_defs(n, prefix):
        return {
            f"{prefix}ln1_scale": ((n, d), P(None, None)),
            f"{prefix}ln1_bias": ((n, d), P(None, None)),
            f"{prefix}wq": ((n, d, h * hd), P(None, "fsdp", atp)),
            f"{prefix}wk": ((n, d, kv * hd), P(None, "fsdp", None)),
            f"{prefix}wv": ((n, d, kv * hd), P(None, "fsdp", None)),
            f"{prefix}wo": ((n, h * hd, d), P(None, atp, "fsdp")),
        }

    def mlp_defs(n, prefix):
        return {
            f"{prefix}lnm_scale": ((n, d), P(None, None)),
            f"{prefix}lnm_bias": ((n, d), P(None, None)),
            f"{prefix}w_up": ((n, d, ff), P(None, "fsdp", "tp")),
            f"{prefix}b_up": ((n, ff), P(None, "tp")),
            f"{prefix}w_down": ((n, ff, d), P(None, "tp", "fsdp")),
            f"{prefix}b_down": ((n, d), P(None, None)),
        }

    enc: ParamDefs = {}
    enc.update(attn_defs(ne, ""))
    enc.update(mlp_defs(ne, ""))
    for k, v in enc.items():
        defs[f"encoder.{k}"] = v

    dec: ParamDefs = {}
    dec.update(attn_defs(nd, ""))  # self-attention
    dec.update(
        {
            "ln2_scale": ((nd, d), P(None, None)),
            "ln2_bias": ((nd, d), P(None, None)),
            "xwq": ((nd, d, h * hd), P(None, "fsdp", atp)),
            "xwk": ((nd, d, kv * hd), P(None, "fsdp", None)),
            "xwv": ((nd, d, kv * hd), P(None, "fsdp", None)),
            "xwo": ((nd, h * hd, d), P(None, atp, "fsdp")),
        }
    )
    dec.update(mlp_defs(nd, ""))
    for k, v in dec.items():
        defs[f"decoder.{k}"] = v
    return defs


def _sinusoid(t: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _ln(x, scale, bias):
    return L.layer_norm(x, scale, bias)


def _mlp(cfg, x, lp):
    hidden = jax.nn.gelu(
        jnp.einsum("btd,df->btf", x, lp["w_up"].astype(x.dtype))
        + lp["b_up"].astype(x.dtype)
    )
    hidden = logical_constraint(hidden, P("dp", None, "tp"))
    return (
        jnp.einsum("btf,fd->btd", hidden, lp["w_down"].astype(x.dtype))
        + lp["b_down"].astype(x.dtype)
    )


def _self_attn(cfg, x, lp, causal, prefix=""):
    h = _ln(x, lp[f"{prefix}ln1_scale"], lp[f"{prefix}ln1_bias"])
    b, t, _ = h.shape
    q = jnp.einsum("btd,dk->btk", h, lp[f"{prefix}wq"].astype(h.dtype))
    k = jnp.einsum("btd,dk->btk", h, lp[f"{prefix}wk"].astype(h.dtype))
    v = jnp.einsum("btd,dk->btk", h, lp[f"{prefix}wv"].astype(h.dtype))
    q = q.reshape(b, t, cfg.num_heads, cfg.hd)
    k = k.reshape(b, t, cfg.kv_heads, cfg.hd)
    v = v.reshape(b, t, cfg.kv_heads, cfg.hd)
    attn = L.attention(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk)
    return x + L.out_project(attn, lp, prefix=prefix), (k, v)


def _cross_attn(cfg, x, enc_k, enc_v, lp):
    h = _ln(x, lp["ln2_scale"], lp["ln2_bias"])
    b, t, _ = h.shape
    q = jnp.einsum("btd,dk->btk", h, lp["xwq"].astype(h.dtype)).reshape(
        b, t, cfg.num_heads, cfg.hd
    )
    attn = L.attention(q, enc_k, enc_v, causal=False, q_chunk=cfg.attn_q_chunk)
    return x + jnp.einsum(
        "btk,kd->btd",
        attn.reshape(b, t, cfg.num_heads * cfg.hd),
        lp["xwo"].astype(h.dtype),
    )


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def encode(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T, d) precomputed frame embeddings (frontend stub)."""
    x = frames.astype(cfg.activation_dtype())
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    x = logical_constraint(x, P("dp", None, None))

    def block(x, lp):
        x, _ = _self_attn(cfg, x, lp, causal=False)
        x = x + _mlp(cfg, _ln(x, lp["lnm_scale"], lp["lnm_bias"]), lp)
        return logical_constraint(x, P("dp", None, None))

    blk = _remat(cfg, block)

    def body(carry, lp):
        return blk(carry, lp), None

    x, _ = lax.scan(
        body, x, params["encoder"],
        unroll=cfg.encoder_layers if cfg.scan_unroll else 1,
    )
    return _ln(x, params["enc_final_scale"], params["enc_final_bias"])


def _cross_kv(cfg, params, enc_out):
    """Per-decoder-layer cross K/V from the encoder output."""
    b, s, _ = enc_out.shape

    def one(lp):
        k = jnp.einsum("bsd,dk->bsk", enc_out, lp["xwk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dk->bsk", enc_out, lp["xwv"].astype(enc_out.dtype))
        return (
            k.reshape(b, s, cfg.kv_heads, cfg.hd),
            v.reshape(b, s, cfg.kv_heads, cfg.hd),
        )

    return jax.vmap(one)(params["decoder"])  # (Ld, B, S, KV, hd) x2


def decode_train(cfg, params, tokens, enc_out):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype())
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    x = logical_constraint(x, P("dp", None, None))
    xk, xv = _cross_kv(cfg, params, enc_out)

    def block(x, scanned):
        lp, ek, ev = scanned
        x, _ = _self_attn(cfg, x, lp, causal=True)
        x = _cross_attn(cfg, x, ek, ev, lp)
        x = x + _mlp(cfg, _ln(x, lp["lnm_scale"], lp["lnm_bias"]), lp)
        return logical_constraint(x, P("dp", None, None))

    blk = _remat(cfg, block)

    def body(carry, scanned):
        return blk(carry, scanned), None

    x, _ = lax.scan(
        body, x, (params["decoder"], xk, xv),
        unroll=cfg.num_layers if cfg.scan_unroll else 1,
    )
    x = _ln(x, params["dec_final_scale"], params["dec_final_bias"])
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    return logical_constraint(logits, P("dp", None, "tp"))


def loss_fn(params, batch, cfg):
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], enc_out).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def prefill(params, batch, cfg):
    """Encode + decoder prefill over the given decoder tokens."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, t = tokens.shape
    xk, xv = _cross_kv(cfg, params, enc_out)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype())
    x = x + _sinusoid(t, cfg.d_model, x.dtype)[None]

    def body(carry, scanned):
        lp, ek, ev = scanned
        x = carry
        x, (k, v) = _self_attn(cfg, x, lp, causal=True)
        x = _cross_attn(cfg, x, ek, ev, lp)
        x = x + _mlp(cfg, _ln(x, lp["lnm_scale"], lp["lnm_bias"]), lp)
        return logical_constraint(x, P("dp", None, None)), (k, v)

    x, (ks, vs) = lax.scan(
        body, x, (params["decoder"], xk, xv),
        unroll=cfg.num_layers if cfg.scan_unroll else 1,
    )
    x = _ln(x, params["dec_final_scale"], params["dec_final_bias"])
    logits = jnp.einsum("btd,vd->btv", x[:, -1:], params["embed"].astype(x.dtype))
    cache = {
        "self_k": ks, "self_v": vs,  # (Ld, B, T, KV, hd)
        "cross_k": xk, "cross_v": xv,  # (Ld, B, S, KV, hd)
        "cross_len": jnp.array(enc_out.shape[1], jnp.int32),
        "pos": jnp.array(t, jnp.int32),
    }
    return logical_constraint(logits, P("dp", None, "tp")), cache


def decode_step(params, cache, batch, cfg):
    tokens = batch["tokens"]  # (B, 1)
    pos = cache["pos"]
    cross_len = cache["cross_len"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype())
    t_pos = _sinusoid_at(pos, cfg.d_model, x.dtype)
    x = x + t_pos[None, None, :]

    def body(carry, scanned):
        x, k_all, v_all = carry
        lp, ek, ev, layer = scanned
        kc = lax.dynamic_index_in_dim(k_all, layer, axis=0, keepdims=False)
        vc = lax.dynamic_index_in_dim(v_all, layer, axis=0, keepdims=False)
        h = _ln(x, lp["ln1_scale"], lp["ln1_bias"])
        b = h.shape[0]
        q = jnp.einsum("btd,dk->btk", h, lp["wq"].astype(h.dtype)).reshape(
            b, 1, cfg.num_heads, cfg.hd
        )
        k = jnp.einsum("btd,dk->btk", h, lp["wk"].astype(h.dtype)).reshape(
            b, 1, cfg.kv_heads, cfg.hd
        )
        v = jnp.einsum("btd,dk->btk", h, lp["wv"].astype(h.dtype)).reshape(
            b, 1, cfg.kv_heads, cfg.hd
        )
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        attn = L.decode_attention(q, kc, vc, pos + 1)
        x = x + L.out_project(attn, lp)
        # cross attention with explicit length mask (cache may be padded)
        h2 = _ln(x, lp["ln2_scale"], lp["ln2_bias"])
        q2 = jnp.einsum("btd,dk->btk", h2, lp["xwq"].astype(h2.dtype)).reshape(
            b, 1, cfg.num_heads, cfg.hd
        )
        xattn = L.decode_attention(q2, ek, ev, cross_len)
        x = x + jnp.einsum(
            "btk,kd->btd",
            xattn.reshape(b, 1, cfg.num_heads * cfg.hd),
            lp["xwo"].astype(h2.dtype),
        )
        x = x + _mlp(cfg, _ln(x, lp["lnm_scale"], lp["lnm_bias"]), lp)
        k_all = lax.dynamic_update_slice_in_dim(
            k_all, kc[None].astype(k_all.dtype), layer, axis=0)
        v_all = lax.dynamic_update_slice_in_dim(
            v_all, vc[None].astype(v_all.dtype), layer, axis=0)
        return (x, k_all, v_all), None

    (x, ks, vs), _ = lax.scan(
        body, (x, cache["self_k"], cache["self_v"]),
        (params["decoder"], cache["cross_k"], cache["cross_v"],
         jnp.arange(cfg.num_layers)),
        unroll=cfg.num_layers if cfg.scan_unroll else 1,
    )
    x = _ln(x, params["dec_final_scale"], params["dec_final_bias"])
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    new_cache = dict(cache)
    new_cache.update({"self_k": ks, "self_v": vs, "pos": pos + 1})
    return logical_constraint(logits, P("dp", None, "tp")), new_cache


def _sinusoid_at(pos, d, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def init_cache(cfg: ArchConfig, batch: int, seq: int, abstract: bool = False):
    nd = cfg.num_layers
    dt = cfg.activation_dtype()
    self_shape = (nd, batch, seq, cfg.kv_heads, cfg.hd)
    cross_shape = (nd, batch, seq, cfg.kv_heads, cfg.hd)
    if abstract:
        mk = lambda s: jax.ShapeDtypeStruct(s, dt)  # noqa: E731
        pos = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        mk = lambda s: jnp.zeros(s, dt)  # noqa: E731
        pos = jnp.array(seq - 1, jnp.int32)
    return {
        "self_k": mk(self_shape), "self_v": mk(self_shape),
        "cross_k": mk(cross_shape), "cross_v": mk(cross_shape),
        "cross_len": pos if abstract else jnp.array(seq, jnp.int32),
        "pos": pos,
    }


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    kv = P(None, "dp", "tp", None, None)
    return {
        "self_k": kv, "self_v": kv, "cross_k": kv, "cross_v": kv,
        "cross_len": P(), "pos": P(),
    }


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    gb, t = shape.global_batch, shape.seq_len
    dt = cfg.activation_dtype()
    frames = jax.ShapeDtypeStruct((gb, t, cfg.d_model), dt)
    i32 = jnp.int32
    if shape.kind == "train":
        return {
            "frames": frames,
            "tokens": jax.ShapeDtypeStruct((gb, t), i32),
            "labels": jax.ShapeDtypeStruct((gb, t), i32),
        }
    if shape.kind == "prefill":
        return {"frames": frames, "tokens": jax.ShapeDtypeStruct((gb, t), i32)}
    return {"tokens": jax.ShapeDtypeStruct((gb, 1), i32)}


register_family(
    "encdec",
    ModelImpl(
        param_defs=param_defs,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
        input_specs=input_specs,
    ),
)
