"""RWKV6 "Finch" (arXiv:2404.05892) — attention-free LM with data-dependent
per-channel decay.  Family "rwkv".

Per layer: time-mix (the attention replacement) + channel-mix (the FFN
replacement).  Head dim 64; recurrent state per head is a (64, 64) matrix,
so the decode "cache" is O(1) in sequence length — which is why this arch
runs the long_500k cell (DESIGN.md §5).

Time-mix (heads H, head dim e):
    ddlerp token-shift mixing for r,k,v,w,g (base mu + low-rank data term)
    w_t = exp(-exp(decay(x)))            # data-dependent decay in (0,1)
    y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    out = W_o (GroupNorm_head(y) * silu(g))

Train/prefill uses a lax.scan over time (baseline); the chunked
matmul-parallel form is the §Perf hillclimb lever for this family.

TP: the d axis is laid out as H*e with H % 16 == 0, so r/k/v/g projections
are column-parallel, W_o row-parallel, and the recurrent state shards its
head axis over "tp".
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.meshes import logical_constraint
from repro.models import layers as L
from repro.models.model_api import (
    ArchConfig,
    ModelImpl,
    ParamDefs,
    ShapeConfig,
    register_family,
)

HEAD_DIM = 64
MIX_RANK = 32
DECAY_RANK = 64


def _heads(cfg: ArchConfig) -> int:
    return cfg.d_model // HEAD_DIM


def param_defs(cfg: ArchConfig) -> ParamDefs:
    d, ff, nl = cfg.d_model, cfg.d_ff, cfg.num_layers
    vp = cfg.padded_vocab()
    defs: ParamDefs = {
        "embed": ((vp, d), P(None, "fsdp")),
        "lm_head": ((vp, d), P("tp", None)),
        "final_norm_scale": ((d,), P(None)),
    }
    lyr: ParamDefs = {
        "ln1_scale": ((nl, d), P(None, None)),
        "ln2_scale": ((nl, d), P(None, None)),
        # --- time mix -------------------------------------------------------
        "tm_maa_x": ((nl, d), P(None, None)),
        "tm_maa": ((nl, 5, d), P(None, None, None)),  # r,k,v,w,g bases
        "tm_mix_w1": ((nl, d, 5 * MIX_RANK), P(None, "fsdp", None)),
        "tm_mix_w2": ((nl, 5, MIX_RANK, d), P(None, None, None, None)),
        "tm_decay_base": ((nl, d), P(None, "tp")),
        "tm_decay_w1": ((nl, d, DECAY_RANK), P(None, "fsdp", None)),
        "tm_decay_w2": ((nl, DECAY_RANK, d), P(None, None, "tp")),
        "tm_u": ((nl, d), P(None, "tp")),  # per-channel bonus
        "tm_wr": ((nl, d, d), P(None, "fsdp", "tp")),
        "tm_wk": ((nl, d, d), P(None, "fsdp", "tp")),
        "tm_wv": ((nl, d, d), P(None, "fsdp", "tp")),
        "tm_wg": ((nl, d, d), P(None, "fsdp", "tp")),
        "tm_wo": ((nl, d, d), P(None, "tp", "fsdp")),
        "tm_gn_scale": ((nl, d), P(None, "tp")),
        "tm_gn_bias": ((nl, d), P(None, "tp")),
        # --- channel mix ----------------------------------------------------
        "cm_mix_k": ((nl, d), P(None, None)),
        "cm_mix_r": ((nl, d), P(None, None)),
        "cm_wk": ((nl, d, ff), P(None, "fsdp", "tp")),
        "cm_wv": ((nl, ff, d), P(None, "tp", "fsdp")),
        "cm_wr": ((nl, d, d), P(None, "fsdp", "tp")),
    }
    for k, v in lyr.items():
        defs[f"layers.{k}"] = v
    return defs


# ----------------------------------------------------------------------------
# time mix
# ----------------------------------------------------------------------------


def _ddlerp(x, xprev, lp):
    """Data-dependent token-shift mixing -> (xr, xk, xv, xw, xg)."""
    xx = xprev - x
    xxx = x + xx * lp["tm_maa_x"].astype(x.dtype)
    b, t, d = x.shape
    lora = jnp.tanh(
        jnp.einsum("btd,dr->btr", xxx, lp["tm_mix_w1"].astype(x.dtype))
    ).reshape(b, t, 5, MIX_RANK)
    deltas = jnp.einsum("btfr,frd->btfd", lora, lp["tm_mix_w2"].astype(x.dtype))
    mix = lp["tm_maa"].astype(x.dtype)[None, None] + deltas  # (B,T,5,d)
    outs = [x + xx * mix[:, :, i] for i in range(5)]
    return outs


def _decay(xw, lp):
    """w_t in (0,1): exp(-exp(base + low-rank(x)))."""
    low = jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw, lp["tm_decay_w1"].astype(xw.dtype))),
        lp["tm_decay_w2"].astype(xw.dtype),
    )
    logw = lp["tm_decay_base"].astype(jnp.float32) + low.astype(jnp.float32)
    return jnp.exp(-jnp.exp(jnp.clip(logw, -8.0, 4.0)))  # f32 (B,T,d)


def _group_norm(y, scale, bias, h):
    """Per-head layer norm of (B, T, H, e) flattened to d."""
    b, t, _, e = y.shape
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    yn = (y32 - mu) * lax.rsqrt(var + 1e-5)
    yn = yn.reshape(b, t, h * e)
    return yn * scale.astype(jnp.float32) + bias.astype(jnp.float32)


TIME_CHUNK = 64  # gradient-checkpoint granularity over the time scan


def _wkv_scan(r, k, v, w, u, s0):
    """The RWKV6 recurrence.  r,k,v: (B,T,H,e); w: (B,T,H,e) decay in (0,1);
    u: (H,e); s0: (B,H,e,e).  Returns y (B,T,H,e), s_T.

    Time-chunked with per-chunk rematerialization: a plain scan's backward
    saves the (B,H,e,e) state at EVERY step (34 GB/device at train_4k);
    checkpointing every TIME_CHUNK steps bounds the saved states to chunk
    boundaries and recomputes inside — the classic sqrt(T) memory trade."""

    def step(s, rkvw):
        r_t, k_t, v_t, w_t = rkvw  # (B,H,e)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,e,e)
        y_t = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y_t

    t = r.shape[1]
    rkvw = tuple(x.swapaxes(0, 1) for x in (r, k, v, w))  # (T,B,H,e)
    if t <= TIME_CHUNK or t % TIME_CHUNK != 0:
        s_t, ys = lax.scan(step, s0, rkvw)
        return ys.swapaxes(0, 1), s_t

    nchunks = t // TIME_CHUNK
    chunked = tuple(
        x.reshape((nchunks, TIME_CHUNK) + x.shape[1:]) for x in rkvw
    )

    @jax.checkpoint
    def chunk_fn(s, xs):
        return lax.scan(step, s, xs)

    s_t, ys = lax.scan(chunk_fn, s0, chunked)  # ys: (nc, tc, B, H, e)
    ys = ys.reshape((t,) + ys.shape[2:])
    return ys.swapaxes(0, 1), s_t  # (B,T,H,e)


def _time_mix(cfg, x, xprev, lp, s0):
    b, t, d = x.shape
    h = _heads(cfg)
    xr, xk, xv, xw, xg = _ddlerp(x, xprev, lp)
    r = jnp.einsum("btd,de->bte", xr, lp["tm_wr"].astype(x.dtype)).reshape(b, t, h, HEAD_DIM)
    k = jnp.einsum("btd,de->bte", xk, lp["tm_wk"].astype(x.dtype)).reshape(b, t, h, HEAD_DIM)
    v = jnp.einsum("btd,de->bte", xv, lp["tm_wv"].astype(x.dtype)).reshape(b, t, h, HEAD_DIM)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, lp["tm_wg"].astype(x.dtype)))
    w = _decay(xw, lp).reshape(b, t, h, HEAD_DIM)
    u = lp["tm_u"].astype(jnp.float32).reshape(h, HEAD_DIM)
    r = logical_constraint(r, P("dp", None, "tp", None))
    y, s_t = _wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w, u, s0
    )
    y = _group_norm(y, lp["tm_gn_scale"], lp["tm_gn_bias"], h).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", (y * g.reshape(b, t, d)), lp["tm_wo"].astype(x.dtype))
    return out, s_t


def _channel_mix(x, xprev, lp):
    xx = xprev - x
    xk = x + xx * lp["cm_mix_k"].astype(x.dtype)
    xr = x + xx * lp["cm_mix_r"].astype(x.dtype)
    kk = jnp.einsum("btd,df->btf", xk, lp["cm_wk"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    kk = logical_constraint(kk, P("dp", None, "tp"))
    vv = jnp.einsum("btf,fd->btd", kk, lp["cm_wv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, lp["cm_wr"].astype(x.dtype)))
    return rr * vv


def _shift(x: jax.Array, x0: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1}, with x0 (B, d) carried in from the cache."""
    first = jnp.zeros_like(x[:, :1]) if x0 is None else x0[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _block(cfg, x, lp, s0, tm_x0=None, cm_x0=None):
    h = L.rms_norm(x, lp["ln1_scale"])
    tm_out, s_t = _time_mix(cfg, h, _shift(h, tm_x0), lp, s0)
    x = x + tm_out
    h2 = L.rms_norm(x, lp["ln2_scale"])
    x = x + _channel_mix(h2, _shift(h2, cm_x0), lp)
    x = logical_constraint(x, P("dp", None, None))
    # carry out the last normalized token for decode token-shift
    return x, s_t, h[:, -1], h2[:, -1]


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        if cfg.remat == "dots"
        else jax.checkpoint_policies.nothing_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype())
    return logical_constraint(x, P("dp", None, None))


def _logits(cfg, params, x):
    x = L.rms_norm(x, params["final_norm_scale"])
    logits = jnp.einsum("btd,vd->btv", x, params["lm_head"].astype(x.dtype))
    return logical_constraint(logits, P("dp", None, "tp"))


def _trunk(cfg, params, x, collect_states: bool):
    b = x.shape[0]
    h = _heads(cfg)
    s0 = jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32)
    block = _remat(cfg, functools.partial(_block, cfg))

    def body(carry, lp):
        x = carry
        x, s_t, tm_last, cm_last = block(x, lp, s0)
        ys = (s_t, tm_last, cm_last) if collect_states else None
        return x, ys

    x, ys = lax.scan(
        body, x, params["layers"], unroll=cfg.num_layers if cfg.scan_unroll else 1
    )
    return x, ys


def loss_fn(params, batch, cfg):
    x = _embed(cfg, params, batch["tokens"])
    x, _ = _trunk(cfg, params, x, collect_states=False)
    logits = _logits(cfg, params, x).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def prefill(params, batch, cfg):
    x = _embed(cfg, params, batch["tokens"])
    x, (s, tm_x, cm_x) = _trunk(cfg, params, x, collect_states=True)
    logits = _logits(cfg, params, x[:, -1:])
    cache = {
        "s": s,  # (L, B, H, e, e)
        "tm_x": tm_x,  # (L, B, d)
        "cm_x": cm_x,
        "pos": jnp.array(x.shape[1], jnp.int32),
    }
    return logits, cache


def decode_step(params, cache, batch, cfg):
    x = _embed(cfg, params, batch["tokens"])  # (B, 1, d)

    def body(carry, lp_state):
        lp, s0, tm_x0, cm_x0 = lp_state
        x = carry
        x, s_t, tm_last, cm_last = _block(cfg, x, lp, s0, tm_x0, cm_x0)
        return x, (s_t, tm_last, cm_last)

    x, (s, tm_x, cm_x) = lax.scan(
        body, x, (params["layers"], cache["s"], cache["tm_x"], cache["cm_x"]),
        unroll=cfg.num_layers if cfg.scan_unroll else 1,
    )
    logits = _logits(cfg, params, x)
    return logits, {"s": s, "tm_x": tm_x, "cm_x": cm_x, "pos": cache["pos"] + 1}


def init_cache(cfg: ArchConfig, batch: int, seq: int, abstract: bool = False):
    """RWKV's 'KV cache of seq_len' is its O(1) recurrent state (DESIGN.md §5);
    seq only sets the starting position counter."""
    h = _heads(cfg)
    shapes = {
        "s": ((cfg.num_layers, batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "tm_x": ((cfg.num_layers, batch, cfg.d_model), cfg.activation_dtype()),
        "cm_x": ((cfg.num_layers, batch, cfg.d_model), cfg.activation_dtype()),
    }
    if abstract:
        out: dict[str, Any] = {
            k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()
        }
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        out = {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}
        out["pos"] = jnp.array(seq - 1, jnp.int32)
    return out


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    return {
        "s": P(None, "dp", "tp", None, None),
        "tm_x": P(None, "dp", None),
        "cm_x": P(None, "dp", None),
        "pos": P(),
    }


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    gb, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((gb, t), jnp.int32),
            "labels": jax.ShapeDtypeStruct((gb, t), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((gb, t), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32)}


register_family(
    "rwkv",
    ModelImpl(
        param_defs=param_defs,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
        input_specs=input_specs,
    ),
)
