"""Model API: architecture config, shape config, and the family registry.

Every assigned architecture is a single ``ArchConfig`` (exact published
numbers live in ``repro/configs/<id>.py``) handled by one of five family
implementations (dense/moe/vlm share ``transformer.py``):

  dense | moe | vlm  -> transformer.py   (decoder-only, GQA, optional MoE FFN,
                                          optional provided prefix embeddings)
  rwkv               -> rwkv6.py         (attention-free, Finch)
  hybrid             -> hybrid.py        (Jamba: mamba/attention interleave + MoE)
  encdec             -> encdec.py        (Whisper: encoder + cross-attn decoder)

Each family module exposes a ``ModelImpl`` of pure functions — params are
plain nested dicts of arrays; sharding comes from a parallel dict of
PartitionSpecs built from the same ``param_defs`` table that defines shapes
(single source of truth, so specs can never drift from shapes).

Logical sharding axes used in specs (mapped to mesh axes at launch):
  "tp"    -> "model"            tensor-parallel dim (heads / ffn / vocab / experts)
  "fsdp"  -> "data"             fully-sharded param dim
  "dp"    -> ("pod","data")     batch dim of activations ("data" on single pod)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ----------------------------------------------------------------------------
# configs
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | rwkv | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0  # 0 -> num_heads
    head_dim: int = 0  # 0 -> d_model // num_heads
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE FFN on layers where (layer % moe_every == moe_every-1)
    capacity_factor: float = 1.25
    # attention flavor
    qkv_bias: bool = False
    rope_fraction: float = 1.0  # chatglm3 "2d" RoPE == rotary on half the head dim
    rope_theta: float = 10000.0
    mlp_act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # ssm / hybrid
    attn_period: int = 0  # hybrid: one attention layer per `attn_period` layers
    d_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # enc-dec / modality stubs
    encoder_layers: int = 0
    num_prefix_tokens: int = 0  # VLM patches / audio frames fed as embeddings
    tie_embeddings: bool = True
    # numerics & training knobs (per-arch so huge archs fit HBM)
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    optimizer: str = "adamw"  # adamw | adafactor | sgdm
    remat: str = "full"  # full | dots | none
    microbatches_train: int = 1
    residual_shard: str = "none"  # "none" | "seq": Megatron-SP-style seq-sharded
    #   residual stream between blocks (bounds the per-layer saved activations)
    grad_accum_dtype: str = "float32"  # microbatch gradient accumulator dtype
    fsdp_over_pod: bool = False  # multi-pod: shard params over ("pod","data")
    #   (32-way FSDP) instead of pure cross-pod DP — required for the >=300B
    #   archs to fit 16 GB/chip; costs cross-pod weight all-gathers
    scan_unroll: bool = False  # unroll layer scans (dry-run cost probes only:
    #   XLA cost_analysis counts while-loop bodies once, unrolling fixes that)
    attn_q_chunk: int = 512  # query-chunk size for exact tiled attention
    moe_dispatch_tokens: int = 32_768  # tokens per MoE routing round
    moe_combine_dtype: str = "auto"  # "auto" (= activation dtype) | "float32":
    #   accumulator for the top-k expert combine; bf16 halves the EP combine
    #   all-reduce bytes (§Perf)
    sub_quadratic: bool = False  # can run long_500k
    source: str = ""  # provenance note ([arXiv/hf; tier])

    # -- derived -------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def padded_vocab(self, tp: int = 16, lane: int = 128) -> int:
        """Pad vocab so it shards over tp and tiles the 128-lane registers."""
        mult = _lcm(tp, lane)
        return -(-self.vocab_size // mult) * mult

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def parameter_dtype(self):
        return jnp.dtype(self.param_dtype)

    def n_params(self) -> int:
        """Total parameter count (from the registered param defs)."""
        import math

        defs = get_model(self).param_defs(self)
        return sum(math.prod(shape) for shape, _ in defs.values())

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed top_k + shared)."""
        import math

        defs = get_model(self).param_defs(self)
        total = 0
        for name, (shape, _) in defs.items():
            count = math.prod(shape)
            if _is_routed_expert(name) and self.num_experts > self.top_k > 0:
                count = count * self.top_k // self.num_experts
            total += count
        return total


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


def _is_routed_expert(name: str) -> bool:
    return "moe_" in name and "router" not in name and "shared" not in name


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k requires sub-quadratic sequence mixing (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: O(L^2) attention infeasible at 524k"
    return True, ""


# ----------------------------------------------------------------------------
# family implementation protocol
# ----------------------------------------------------------------------------

# param_defs: cfg -> {path: ((shape...), PartitionSpec)}  — single source of truth
ParamDefs = dict[str, tuple[tuple[int, ...], P]]


class ModelImpl(NamedTuple):
    param_defs: Callable[[ArchConfig], ParamDefs]
    loss_fn: Callable[..., Any]  # (params, batch, cfg) -> (loss, metrics)
    prefill: Callable[..., Any]  # (params, batch, cfg) -> (logits, cache)
    decode_step: Callable[..., Any]  # (params, cache, batch, cfg) -> (logits, cache)
    init_cache: Callable[..., Any]  # (cfg, batch, seq) -> cache ShapeDtypeStructs/arrays
    cache_specs: Callable[..., Any]  # (cfg, batch, seq) -> pytree of PartitionSpec
    input_specs: Callable[..., Any]  # (cfg, shape) -> dict[str, ShapeDtypeStruct]


_REGISTRY: dict[str, ModelImpl] = {}


def register_family(name: str, impl: ModelImpl) -> None:
    _REGISTRY[name] = impl


def get_model(cfg: ArchConfig) -> ModelImpl:
    # dense / moe / vlm all route to the decoder-only transformer
    family = {"dense": "transformer", "moe": "transformer", "vlm": "transformer"}.get(
        cfg.family, cfg.family
    )
    if family not in _REGISTRY:
        # populate registry lazily to avoid import cycles
        import importlib

        for mod in ("transformer", "rwkv6", "hybrid", "encdec"):
            try:
                importlib.import_module(f"repro.models.{mod}")
            except ImportError:
                pass
    return _REGISTRY[family]


# ----------------------------------------------------------------------------
# param materialization from defs
# ----------------------------------------------------------------------------


def unflatten(flat: dict[str, Any]) -> dict:
    """'a.b.c' keyed dict -> nested dicts."""
    out: dict = {}
    for path, v in flat.items():
        node = out
        parts = path.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(key: jax.Array, cfg: ArchConfig, scale: float = 0.02):
    """Materialize parameters from param_defs (truncated-normal-ish init)."""
    defs = get_model(cfg).param_defs(cfg)
    dtype = cfg.parameter_dtype()
    flat = {}
    keys = jax.random.split(key, len(defs))
    for k, (path, (shape, _spec)) in zip(keys, sorted(defs.items())):
        if path.endswith(("scale",)):
            flat[path] = jnp.ones(shape, dtype)
        elif path.endswith(("bias", "a_log_bias")) or ".b_" in path:
            flat[path] = jnp.zeros(shape, dtype)
        else:
            flat[path] = (scale * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
    return unflatten(flat)


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    defs = get_model(cfg).param_defs(cfg)
    dtype = cfg.parameter_dtype()
    return unflatten(
        {path: jax.ShapeDtypeStruct(shape, dtype) for path, (shape, _) in defs.items()}
    )


def param_pspecs(cfg: ArchConfig):
    """PartitionSpec pytree matching the param tree, in logical axis names."""
    defs = get_model(cfg).param_defs(cfg)
    return unflatten({path: spec for path, (shape, spec) in defs.items()})
