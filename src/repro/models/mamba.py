"""Mamba selective-SSM block (Gu & Dao 2023) — used by the Jamba hybrid.

Block: in_proj -> (x, z); causal depthwise conv (width cfg.ssm_conv) + SiLU;
data-dependent (dt, B, C); selective scan
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t,   y_t = C_t . h_t + D * x_t
then y * SiLU(z) -> out_proj.

TP: d_inner shards over "tp" — the whole recurrence is elementwise over
d_inner so the scan needs no collectives; only in/out projections touch the
"tp"-sharded dim (column-/row-parallel).  Decode state is (B, d_inner,
d_state) + a (conv-1)-token conv buffer: O(1) in sequence length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.meshes import logical_constraint
from repro.models.model_api import ArchConfig, ParamDefs


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def dt_rank(cfg: ArchConfig) -> int:
    return max(cfg.d_model // 16, 8)


def param_defs(cfg: ArchConfig, lead: tuple[int, ...]) -> ParamDefs:
    """Mamba params with arbitrary leading stack dims (e.g. (periods, 7))."""
    d, di, ds, dr, ck = cfg.d_model, d_inner(cfg), cfg.d_state, dt_rank(cfg), cfg.ssm_conv
    n = (None,) * len(lead)
    return {
        "in_proj": (lead + (d, 2 * di), P(*n, "fsdp", "tp")),
        "conv_w": (lead + (ck, di), P(*n, None, "tp")),
        "conv_b": (lead + (di,), P(*n, "tp")),
        "x_proj": (lead + (di, dr + 2 * ds), P(*n, "tp", None)),
        "dt_w": (lead + (dr, di), P(*n, None, "tp")),
        "dt_bias": (lead + (di,), P(*n, "tp")),
        "a_log": (lead + (di, ds), P(*n, "tp", None)),
        "d_skip": (lead + (di,), P(*n, "tp")),
        "out_proj": (lead + (di, d), P(*n, "tp", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, buf: jax.Array | None):
    """Depthwise causal conv over time.  x: (B, T, di), w: (K, di).

    buf: (B, K-1, di) trailing context (decode) or None (train, zero pad).
    Returns (y, new_buf)."""
    k = w.shape[0]
    if buf is None:
        buf = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([buf.astype(x.dtype), x], axis=1)  # (B, T+K-1, di)
    y = sum(
        xx[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)[None, None, :]
        for i in range(k)
    )
    y = y + b.astype(x.dtype)
    new_buf = xx[:, -(k - 1) :, :]
    return y, new_buf


TIME_CHUNK = 64  # gradient-checkpoint granularity over the selective scan


def _ssm_scan(x_act: jax.Array, dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
              a: jax.Array, h0: jax.Array):
    """Selective scan.  x_act/dt: (B,T,di); bmat/cmat: (B,T,ds); a: (di,ds);
    h0: (B,di,ds).  Returns y (B,T,di) f32, h_T.

    Time-chunked + per-chunk remat (see rwkv6._wkv_scan): bounds the saved
    (B,di,ds) states to chunk boundaries instead of every timestep."""

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs  # (B,di),(B,di),(B,ds),(B,ds)
        da = jnp.exp(dt_t[..., None] * a[None])  # (B,di,ds)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y_t

    t = x_act.shape[1]
    xs = tuple(z.swapaxes(0, 1) for z in (x_act, dt, bmat, cmat))
    if t <= TIME_CHUNK or t % TIME_CHUNK != 0:
        h_t, ys = lax.scan(step, h0, xs)
        return ys.swapaxes(0, 1), h_t

    nchunks = t // TIME_CHUNK
    chunked = tuple(z.reshape((nchunks, TIME_CHUNK) + z.shape[1:]) for z in xs)

    @jax.checkpoint
    def chunk_fn(h, cxs):
        return lax.scan(step, h, cxs)

    h_t, ys = lax.scan(chunk_fn, h0, chunked)
    ys = ys.reshape((t,) + ys.shape[2:])
    return ys.swapaxes(0, 1), h_t


def mamba_forward(
    cfg: ArchConfig,
    x: jax.Array,  # (B, T, d)
    p: dict,  # mamba params (leading dims already indexed away)
    state: tuple[jax.Array, jax.Array] | None = None,  # (h, conv_buf) decode
):
    """Returns (out (B,T,d), (h_T, conv_buf_T))."""
    b, t, _ = x.shape
    di, ds, dr = d_inner(cfg), cfg.d_state, dt_rank(cfg)
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(x.dtype))
    xz = logical_constraint(xz, P("dp", None, "tp"))
    x_in, z = jnp.split(xz, 2, axis=-1)
    h0 = state[0] if state is not None else jnp.zeros((b, di, ds), jnp.float32)
    buf = state[1] if state is not None else None
    x_conv, new_buf = _causal_conv(x_in, p["conv_w"], p["conv_b"], buf)
    x_act = jax.nn.silu(x_conv)
    proj = jnp.einsum("bte,ef->btf", x_act, p["x_proj"].astype(x.dtype))
    dt_low, bmat, cmat = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,re->bte", dt_low, p["dt_w"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h_t = _ssm_scan(
        x_act.astype(jnp.float32), dt, bmat.astype(jnp.float32),
        cmat.astype(jnp.float32), a, h0,
    )
    y = y + p["d_skip"].astype(jnp.float32) * x_act.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    return out, (h_t, new_buf)


def init_state(cfg: ArchConfig, batch: int, lead: tuple[int, ...] = (), abstract=False):
    di, ds, ck = d_inner(cfg), cfg.d_state, cfg.ssm_conv
    h_shape = lead + (batch, di, ds)
    b_shape = lead + (batch, ck - 1, di)
    if abstract:
        return (
            jax.ShapeDtypeStruct(h_shape, jnp.float32),
            jax.ShapeDtypeStruct(b_shape, cfg.activation_dtype()),
        )
    return (
        jnp.zeros(h_shape, jnp.float32),
        jnp.zeros(b_shape, cfg.activation_dtype()),
    )


def state_specs(lead_n: int):
    n = (None,) * lead_n
    return (P(*n, "dp", "tp", None), P(*n, "dp", None, "tp"))
