"""Jamba-style hybrid (arXiv:2403.19887) — family "hybrid".

Layer pattern (jamba-1.5-large: 72 layers, attn:mamba = 1:7, MoE every other
layer): the stack is `num_layers // attn_period` PERIODS scanned with
lax.scan; inside each period, `attn_period` sublayers run unrolled —
one attention sublayer (at the period midpoint, as in Jamba), the rest
Mamba — each followed by an FFN that alternates dense MLP / 16-expert MoE.

Attention uses NO positional encoding (rope_fraction=0): the Mamba layers
carry position information, which is also what makes long_500k decodable —
only the 9 attention sublayers keep a (seq-"tp"-sharded) KV cache; the 63
Mamba sublayers carry O(1) state.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.meshes import logical_constraint
from repro.models import layers as L
from repro.models import mamba
from repro.models.model_api import (
    ArchConfig,
    ModelImpl,
    ParamDefs,
    ShapeConfig,
    register_family,
)


def _periods(cfg: ArchConfig) -> int:
    assert cfg.num_layers % cfg.attn_period == 0
    return cfg.num_layers // cfg.attn_period


def _attn_idx(cfg: ArchConfig) -> int:
    return cfg.attn_period // 2


def _n_moe(cfg: ArchConfig) -> int:
    return cfg.attn_period // cfg.moe_every


def _n_mlp(cfg: ArchConfig) -> int:
    return cfg.attn_period - _n_moe(cfg)


def param_defs(cfg: ArchConfig) -> ParamDefs:
    d, h, kv, hd, ff, e = (
        cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd, cfg.d_ff, cfg.num_experts,
    )
    pn, per = _periods(cfg), cfg.attn_period
    nm, nmoe, nmlp = per - 1, _n_moe(cfg), _n_mlp(cfg)
    vp = cfg.padded_vocab()
    defs: ParamDefs = {
        "embed": ((vp, d), P(None, "fsdp")),
        "lm_head": ((vp, d), P("tp", None)),
        "final_norm_scale": ((d,), P(None)),
    }
    lyr: ParamDefs = {
        # attention sublayer (1 per period)
        "attn_ln_scale": ((pn, d), P(None, None)),
        "wq": ((pn, d, h * hd), P(None, "fsdp", "tp")),
        "wk": ((pn, d, kv * hd), P(None, "fsdp", None)),
        "wv": ((pn, d, kv * hd), P(None, "fsdp", None)),
        "wo": ((pn, h * hd, d), P(None, "tp", "fsdp")),
        # mamba sublayers (per-1 per period)
        "mamba_ln_scale": ((pn, nm, d), P(None, None, None)),
        # ffn sublayers
        "ffn_ln_scale": ((pn, per, d), P(None, None, None)),
        "mlp_w_gate": ((pn, nmlp, d, ff), P(None, None, "fsdp", "tp")),
        "mlp_w_up": ((pn, nmlp, d, ff), P(None, None, "fsdp", "tp")),
        "mlp_w_down": ((pn, nmlp, ff, d), P(None, None, "tp", "fsdp")),
        "moe_router": ((pn, nmoe, d, e), P(None, None, "fsdp", None)),
        "moe_w_gate": ((pn, nmoe, e, d, ff), P(None, None, "tp", "fsdp", None)),
        "moe_w_up": ((pn, nmoe, e, d, ff), P(None, None, "tp", "fsdp", None)),
        "moe_w_down": ((pn, nmoe, e, ff, d), P(None, None, "tp", None, "fsdp")),
    }
    for k, v in mamba.param_defs(cfg, (pn, nm)).items():
        lyr[f"mamba_{k}"] = v
    for k, v in lyr.items():
        defs[f"layers.{k}"] = v
    return defs


def _res_spec(cfg: ArchConfig) -> P:
    return P("dp", "tp", None) if cfg.residual_shard == "seq" else P("dp", None, None)


def _sub_params(pp: dict, prefix: str, idx: int) -> dict:
    """Slice the per-period stacked params for one sublayer instance."""
    plen = len(prefix)
    return {k[plen:]: v[idx] for k, v in pp.items() if k.startswith(prefix)}


def _ffn(cfg: ArchConfig, x: jax.Array, pp: dict, j: int, mlp_i: int, moe_i: int):
    h = L.rms_norm(x, pp["ffn_ln_scale"][j])
    if j % cfg.moe_every == cfg.moe_every - 1:  # MoE sublayer
        p_moe = {
            "moe_router": pp["moe_router"][moe_i],
            "moe_w_gate": pp["moe_w_gate"][moe_i],
            "moe_w_up": pp["moe_w_up"][moe_i],
            "moe_w_down": pp["moe_w_down"][moe_i],
        }
        return x + L.moe_ffn(cfg, h, p_moe)
    p_mlp = {
        "w_gate": pp["mlp_w_gate"][mlp_i],
        "w_up": pp["mlp_w_up"][mlp_i],
        "w_down": pp["mlp_w_down"][mlp_i],
    }
    return x + L.mlp(cfg, h, p_mlp)


def _period_train(cfg: ArchConfig, x: jax.Array, pp: dict, positions: jax.Array,
                  collect_kv: bool = False):
    """One period = attn_period sublayers (train/prefill)."""
    mlp_i = moe_i = mamba_i = 0
    kv_out = None
    for j in range(cfg.attn_period):
        if j == _attn_idx(cfg):
            h = L.rms_norm(x, pp["attn_ln_scale"])
            q, k, v = L.qkv_project(cfg, h, pp)
            attn = L.attention(q, k, v, causal=True, q_chunk=cfg.attn_q_chunk)
            x = x + L.out_project(attn, pp)
            if collect_kv:
                kv_out = (k, v)
        else:
            h = L.rms_norm(x, pp["mamba_ln_scale"][mamba_i])
            mp = _sub_params(pp, "mamba_", mamba_i)
            out, _state = mamba.mamba_forward(cfg, h, mp)
            x = x + out
            mamba_i += 1
        x = _ffn(cfg, x, pp, j, mlp_i, moe_i)
        if j % cfg.moe_every == cfg.moe_every - 1:
            moe_i += 1
        else:
            mlp_i += 1
        x = logical_constraint(x, _res_spec(cfg))
    return x, kv_out


def _period_decode(cfg: ArchConfig, x, pp, kc, vc, hstates, bufs, pos):
    """One period, single token, stateful."""
    mlp_i = moe_i = mamba_i = 0
    new_h, new_b = [], []
    for j in range(cfg.attn_period):
        if j == _attn_idx(cfg):
            h = L.rms_norm(x, pp["attn_ln_scale"])
            q, k, v = L.qkv_project(cfg, h, pp)
            kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
            vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
            attn = L.decode_attention(q, kc, vc, pos + 1)
            x = x + L.out_project(attn, pp)
        else:
            h = L.rms_norm(x, pp["mamba_ln_scale"][mamba_i])
            mp = _sub_params(pp, "mamba_", mamba_i)
            out, (h_t, buf_t) = mamba.mamba_forward(
                cfg, h, mp, state=(hstates[mamba_i], bufs[mamba_i])
            )
            x = x + out
            new_h.append(h_t)
            new_b.append(buf_t)
            mamba_i += 1
        x = _ffn(cfg, x, pp, j, mlp_i, moe_i)
        if j % cfg.moe_every == cfg.moe_every - 1:
            moe_i += 1
        else:
            mlp_i += 1
    return x, kc, vc, jnp.stack(new_h), jnp.stack(new_b)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        if cfg.remat == "dots"
        else jax.checkpoint_policies.nothing_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def _embed(cfg, params, tokens, decode=False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype())
    return logical_constraint(x, P("dp", None, None) if decode else _res_spec(cfg))


def _logits(cfg, params, x):
    x = L.rms_norm(x, params["final_norm_scale"])
    logits = jnp.einsum("btd,vd->btv", x, params["lm_head"].astype(x.dtype))
    return logical_constraint(logits, P("dp", None, "tp"))


def loss_fn(params, batch, cfg):
    x = _embed(cfg, params, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    period = _remat(cfg, functools.partial(_period_train, cfg))

    def body(carry, pp):
        x, _ = period(carry, pp, positions)
        return x, None

    x, _ = lax.scan(
        body, x, params["layers"],
        unroll=_periods(cfg) if cfg.scan_unroll else 1,
    )
    logits = _logits(cfg, params, x).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def prefill(params, batch, cfg):
    x = _embed(cfg, params, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    b = x.shape[0]
    period = functools.partial(_period_train, cfg)

    def body(carry, pp):
        x, kv = period(carry, pp, positions, collect_kv=True)
        return x, kv

    x, (ks, vs) = lax.scan(
        body, x, params["layers"],
        unroll=_periods(cfg) if cfg.scan_unroll else 1,
    )
    # decode-time mamba states come from a dedicated state-collecting pass in
    # serving (cheap relative to prefill attention); the dry-run prefill cell
    # measures the dominant full-sequence compute, so states start zeroed here.
    cache = init_cache(cfg, b, x.shape[1])
    cache["attn_k"] = lax.dynamic_update_slice_in_dim(
        cache["attn_k"], ks.astype(cache["attn_k"].dtype), 0, axis=2
    )
    cache["attn_v"] = lax.dynamic_update_slice_in_dim(
        cache["attn_v"], vs.astype(cache["attn_v"].dtype), 0, axis=2
    )
    cache["pos"] = jnp.array(x.shape[1], jnp.int32)
    logits = _logits(cfg, params, x[:, -1:])
    return logits, cache


def decode_step(params, cache, batch, cfg):
    """Single-token decode.  Caches travel as scan CARRIES updated in place
    (see transformer.decode_step — avoids a second full KV allocation, which
    matters for the seq-sharded 524k attention cache)."""
    x = _embed(cfg, params, batch["tokens"], decode=True)
    pos = cache["pos"]

    def body(carry, scanned):
        x, k_all, v_all, h_all, b_all = carry
        pp, period = scanned
        kc = lax.dynamic_index_in_dim(k_all, period, axis=0, keepdims=False)
        vc = lax.dynamic_index_in_dim(v_all, period, axis=0, keepdims=False)
        hs = lax.dynamic_index_in_dim(h_all, period, axis=0, keepdims=False)
        bufs = lax.dynamic_index_in_dim(b_all, period, axis=0, keepdims=False)
        x, kc, vc, hs, bufs = _period_decode(cfg, x, pp, kc, vc, hs, bufs, pos)
        k_all = lax.dynamic_update_slice_in_dim(
            k_all, kc[None].astype(k_all.dtype), period, axis=0)
        v_all = lax.dynamic_update_slice_in_dim(
            v_all, vc[None].astype(v_all.dtype), period, axis=0)
        h_all = lax.dynamic_update_slice_in_dim(
            h_all, hs[None].astype(h_all.dtype), period, axis=0)
        b_all = lax.dynamic_update_slice_in_dim(
            b_all, bufs[None].astype(b_all.dtype), period, axis=0)
        return (x, k_all, v_all, h_all, b_all), None

    (x, ks, vs, hs, bufs), _ = lax.scan(
        body,
        (x, cache["attn_k"], cache["attn_v"], cache["mamba_h"], cache["mamba_buf"]),
        (params["layers"], jnp.arange(_periods(cfg))),
        unroll=_periods(cfg) if cfg.scan_unroll else 1,
    )
    logits = _logits(cfg, params, x)
    return logits, {
        "attn_k": ks, "attn_v": vs, "mamba_h": hs, "mamba_buf": bufs, "pos": pos + 1,
    }


def init_cache(cfg: ArchConfig, batch: int, seq: int, abstract: bool = False):
    pn, nm = _periods(cfg), cfg.attn_period - 1
    dt = cfg.activation_dtype()
    kv_shape = (pn, batch, seq, cfg.kv_heads, cfg.hd)
    h, buf = mamba.init_state(cfg, batch, (pn, nm), abstract=abstract)
    if abstract:
        kv = jax.ShapeDtypeStruct(kv_shape, dt)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        kv = jnp.zeros(kv_shape, dt)
        pos = jnp.array(seq - 1, jnp.int32)
    return {"attn_k": kv, "attn_v": kv, "mamba_h": h, "mamba_buf": buf, "pos": pos}


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    kv = P(None, "dp", "tp", None, None)
    h_spec, b_spec = mamba.state_specs(2)
    return {"attn_k": kv, "attn_v": kv, "mamba_h": h_spec, "mamba_buf": b_spec, "pos": P()}


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    gb, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((gb, t), jnp.int32),
            "labels": jax.ShapeDtypeStruct((gb, t), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((gb, t), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32)}


register_family(
    "hybrid",
    ModelImpl(
        param_defs=param_defs,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
        input_specs=input_specs,
    ),
)
