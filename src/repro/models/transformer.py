"""Decoder-only transformer LM — families "dense", "moe", "vlm".

Covers qwen2-1.5b, chatglm3-6b, command-r-plus-104b, llama3-405b (dense),
grok-1-314b, deepseek-moe-16b (MoE FFN), llava-next-mistral-7b (VLM: provided
patch embeddings prepended to the token sequence).

Structure: embedding -> lax.scan over L identical blocks (params stacked on a
leading L axis; per-block remat policy from cfg.remat) -> final norm -> tied
(or separate) LM head.

TP notes (see DESIGN.md §4): attention heads shard over "tp" only when
num_heads % 16 == 0 (qwen2's 12 heads and whisper's 8 stay replicated);
KV projections/caches keep heads replicated (GQA kv < 16) — decode caches
shard their *sequence* dim over "tp" instead, which XLA turns into
flash-decode-style partial attention + small psums.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.meshes import logical_constraint
from repro.models import layers as L
from repro.models.model_api import (
    ArchConfig,
    ModelImpl,
    ParamDefs,
    ShapeConfig,
    register_family,
)

TP = 16  # production tensor-parallel width (divisibility decisions)


def _attn_tp(cfg: ArchConfig) -> bool:
    return cfg.num_heads % TP == 0


def _expert_ep(cfg: ArchConfig) -> bool:
    return cfg.num_experts % TP == 0


def _moe_layer(cfg: ArchConfig, layer: int) -> bool:
    return cfg.num_experts > 0 and (layer % cfg.moe_every == cfg.moe_every - 1)


# ----------------------------------------------------------------------------
# parameter table — single source of truth for shapes AND shardings
# ----------------------------------------------------------------------------


def param_defs(cfg: ArchConfig) -> ParamDefs:
    d, h, kv, hd, ff = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd, cfg.d_ff
    nl, vp = cfg.num_layers, cfg.padded_vocab(TP)
    atp = "tp" if _attn_tp(cfg) else None
    # Embedding sharding (DESIGN.md §4): token-gather from a vocab-sharded
    # table forces SPMD to replicate it, so for untied storage the input
    # table shards its d dim ("fsdp") and the LM head shards vocab ("tp") —
    # both the gather and the logits matmul then partition cleanly.  Tied
    # tables (small archs only) keep P("tp","fsdp") and accept the gather.
    embed_spec = P("tp", "fsdp") if cfg.tie_embeddings else P(None, "fsdp")
    defs: ParamDefs = {
        "embed": ((vp, d), embed_spec),
        "final_norm_scale": ((d,), P(None)),
    }
    if cfg.norm == "layernorm":
        defs["final_norm_bias"] = ((d,), P(None))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ((vp, d), P("tp", None))

    lyr: ParamDefs = {
        "ln1_scale": ((nl, d), P(None, None)),
        "wq": ((nl, d, h * hd), P(None, "fsdp", atp)),
        "wk": ((nl, d, kv * hd), P(None, "fsdp", None)),
        "wv": ((nl, d, kv * hd), P(None, "fsdp", None)),
        "wo": ((nl, h * hd, d), P(None, atp, "fsdp")),
        "ln2_scale": ((nl, d), P(None, None)),
    }
    if cfg.norm == "layernorm":
        lyr["ln1_bias"] = ((nl, d), P(None, None))
        lyr["ln2_bias"] = ((nl, d), P(None, None))
    if cfg.qkv_bias:
        lyr["bq"] = ((nl, h * hd), P(None, atp))
        lyr["bk"] = ((nl, kv * hd), P(None, None))
        lyr["bv"] = ((nl, kv * hd), P(None, None))

    if cfg.num_experts and cfg.moe_every == 1:
        lyr.update(_moe_defs(cfg, nl))
    elif cfg.num_experts:
        # mixed dense/MoE stacks are handled by the hybrid module
        raise ValueError("transformer family expects moe_every == 1")
    else:
        lyr.update(_mlp_defs(cfg, nl, ff))

    for k, v in lyr.items():
        defs[f"layers.{k}"] = v
    return defs


def _mlp_defs(cfg: ArchConfig, nl: int, ff: int, prefix: str = "") -> ParamDefs:
    d = cfg.d_model
    out: ParamDefs = {}
    if cfg.mlp_act == "swiglu":
        out[f"{prefix}w_gate"] = ((nl, d, ff), P(None, "fsdp", "tp"))
        out[f"{prefix}w_up"] = ((nl, d, ff), P(None, "fsdp", "tp"))
        out[f"{prefix}w_down"] = ((nl, ff, d), P(None, "tp", "fsdp"))
    else:
        out[f"{prefix}w_up"] = ((nl, d, ff), P(None, "fsdp", "tp"))
        out[f"{prefix}b_up"] = ((nl, ff), P(None, "tp"))
        out[f"{prefix}w_down"] = ((nl, ff, d), P(None, "tp", "fsdp"))
        out[f"{prefix}b_down"] = ((nl, d), P(None, None))
    return out


def _moe_defs(cfg: ArchConfig, nl: int) -> ParamDefs:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ep = _expert_ep(cfg)
    # EP: experts over "tp"; otherwise TP the expert ffn dim (grok 8e)
    cspec = P(None, "tp", "fsdp", None) if ep else P(None, None, "fsdp", "tp")
    rspec = P(None, "tp", None, "fsdp") if ep else P(None, None, "tp", "fsdp")
    out: ParamDefs = {
        "moe_router": ((nl, d, e), P(None, "fsdp", None)),
        "moe_w_gate": ((nl, e, d, ff), cspec),
        "moe_w_up": ((nl, e, d, ff), cspec),
        "moe_w_down": ((nl, e, ff, d), rspec),
    }
    if cfg.num_shared_experts:
        sh_ff = cfg.num_shared_experts * ff
        out["moe_shared_w_gate"] = ((nl, d, sh_ff), P(None, "fsdp", "tp"))
        out["moe_shared_w_up"] = ((nl, d, sh_ff), P(None, "fsdp", "tp"))
        out["moe_shared_w_down"] = ((nl, sh_ff, d), P(None, "tp", "fsdp"))
    return out


# ----------------------------------------------------------------------------
# blocks
# ----------------------------------------------------------------------------



def _res_spec(cfg: ArchConfig) -> P:
    """Residual-stream sharding between blocks (Megatron-SP when "seq")."""
    return P("dp", "tp", None) if cfg.residual_shard == "seq" else P("dp", None, None)

def _block_train(cfg: ArchConfig, x: jax.Array, lp: dict, positions: jax.Array) -> jax.Array:
    """One transformer block over a full sequence (train/prefill)."""
    h = L.apply_norm(cfg, x, lp, "ln1")
    q, k, v = L.qkv_project(cfg, h, lp)
    q = L.apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = L.apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    attn = L.attention(q, k, v, causal=True, q_chunk=cfg.attn_q_chunk)
    x = x + L.out_project(attn, lp)
    h = L.apply_norm(cfg, x, lp, "ln2")
    if cfg.num_experts:
        x = x + L.moe_ffn(cfg, h, lp)
    else:
        x = x + L.mlp(cfg, h, lp)
    return logical_constraint(x, _res_spec(cfg))


def _block_prefill(cfg: ArchConfig, x, lp, positions):
    """Block that also returns the (k, v) cache entries."""
    h = L.apply_norm(cfg, x, lp, "ln1")
    q, k, v = L.qkv_project(cfg, h, lp)
    q = L.apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = L.apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    attn = L.attention(q, k, v, causal=True, q_chunk=cfg.attn_q_chunk)
    x = x + L.out_project(attn, lp)
    h = L.apply_norm(cfg, x, lp, "ln2")
    x = x + (L.moe_ffn(cfg, h, lp) if cfg.num_experts else L.mlp(cfg, h, lp))
    return logical_constraint(x, _res_spec(cfg)), k, v


def _block_decode(cfg: ArchConfig, x, lp, k_cache, v_cache, pos):
    """Single-token block against a KV cache; returns updated cache entries."""
    h = L.apply_norm(cfg, x, lp, "ln1")
    q, k, v = L.qkv_project(cfg, h, lp)
    posb = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = L.apply_rope(q, posb, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = L.apply_rope(k, posb, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    attn = L.decode_attention(q, k_cache, v_cache, pos + 1)
    x = x + L.out_project(attn, lp)
    h = L.apply_norm(cfg, x, lp, "ln2")
    x = x + (L.moe_ffn(cfg, h, lp) if cfg.num_experts else L.mlp(cfg, h, lp))
    return x, k_cache, v_cache


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ----------------------------------------------------------------------------
# forward passes
# ----------------------------------------------------------------------------


def _embed_tokens(
    cfg: ArchConfig, params: dict, tokens: jax.Array, decode: bool = False
) -> jax.Array:
    emb = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype())
    spec = P("dp", None, None) if decode else _res_spec(cfg)
    return logical_constraint(emb, spec)


def _assemble_sequence(cfg, params, batch) -> jax.Array:
    """Token embeddings, with VLM/audio prefix embeddings prepended if given."""
    x = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.num_prefix_tokens:
        pre = batch["prefix_embeds"].astype(x.dtype)
        pre = logical_constraint(pre, P("dp", None, None))
        x = jnp.concatenate([pre, x], axis=1)
    return x


def _trunk(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    positions = jnp.arange(x.shape[1])
    block = _remat(cfg, functools.partial(_block_train, cfg))

    def body(carry, lp):
        return block(carry, lp, positions), None

    x, _ = lax.scan(body, x, params["layers"], unroll=cfg.num_layers if cfg.scan_unroll else 1)
    return x


def _logits(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        x = L.layer_norm(x, params["final_norm_scale"], params["final_norm_bias"])
    else:
        x = L.rms_norm(x, params["final_norm_scale"])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,vd->btv", x, head.astype(x.dtype))
    return logical_constraint(logits, P("dp", None, "tp"))


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> tuple[jax.Array, dict]:
    """Mean next-token CE over positions with label >= 0."""
    x = _assemble_sequence(cfg, params, batch)
    x = _trunk(cfg, params, x)
    logits = _logits(cfg, params, x).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    return loss, {"loss": loss, "tokens": denom}


def prefill(params: dict, batch: dict, cfg: ArchConfig):
    """Full-sequence forward building the KV cache; returns (logits, cache)."""
    x = _assemble_sequence(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    block = _remat(cfg, functools.partial(_block_prefill, cfg))

    def body(carry, lp):
        x, k, v = block(carry, lp, positions)
        return x, (k.astype(cfg.activation_dtype()), v.astype(cfg.activation_dtype()))

    x, (ks, vs) = lax.scan(
        body, x, params["layers"], unroll=cfg.num_layers if cfg.scan_unroll else 1
    )
    logits = _logits(cfg, params, x[:, -1:, :])
    cache = {
        "k": logical_constraint(ks, _cache_pspec()),
        "v": logical_constraint(vs, _cache_pspec()),
        "pos": jnp.array(x.shape[1], jnp.int32),
    }
    return logits, cache


def decode_step(params: dict, cache: dict, batch: dict, cfg: ArchConfig):
    """One new token per sequence against the cache.  batch: tokens (B, 1).

    The cache travels as a scan CARRY updated with one-token
    dynamic_update_slice writes: XLA keeps while-loop carries in place, so a
    donated cache updates in-HBM.  (A scan-ys formulation allocates a second
    full cache — 8+ GiB/device at the 405B decode cell.)"""
    x = _embed_tokens(cfg, params, batch["tokens"], decode=True)
    pos = cache["pos"]

    def body(carry, scanned):
        x, k_all, v_all = carry
        lp, layer = scanned
        kc = lax.dynamic_index_in_dim(k_all, layer, axis=0, keepdims=False)
        vc = lax.dynamic_index_in_dim(v_all, layer, axis=0, keepdims=False)
        x, kc, vc = _block_decode(cfg, x, lp, kc, vc, pos)
        k_all = lax.dynamic_update_slice_in_dim(
            k_all, kc[None].astype(k_all.dtype), layer, axis=0
        )
        v_all = lax.dynamic_update_slice_in_dim(
            v_all, vc[None].astype(v_all.dtype), layer, axis=0
        )
        return (x, k_all, v_all), None

    (x, ks, vs), _ = lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(cfg.num_layers)),
        unroll=cfg.num_layers if cfg.scan_unroll else 1,
    )
    logits = _logits(cfg, params, x)
    new_cache = {
        "k": logical_constraint(ks, _cache_pspec()),
        "v": logical_constraint(vs, _cache_pspec()),
        "pos": pos + 1,
    }
    return logits, new_cache


# ----------------------------------------------------------------------------
# caches & input specs
# ----------------------------------------------------------------------------


def _cache_pspec() -> P:
    # (L, B, S, KV, hd): batch over dp, sequence over tp (flash-decode psums)
    return P(None, "dp", "tp", None, None)


def init_cache(cfg: ArchConfig, batch: int, seq: int, abstract: bool = False):
    shape = (cfg.num_layers, batch, seq, cfg.kv_heads, cfg.hd)
    dt = cfg.activation_dtype()
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, dt)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        arr = jnp.zeros(shape, dt)
        pos = jnp.array(seq - 1, jnp.int32)
    return {"k": arr, "v": arr, "pos": pos}


def cache_specs(cfg: ArchConfig, batch: int, seq: int):
    return {"k": _cache_pspec(), "v": _cache_pspec(), "pos": P()}


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run contract)."""
    gb, t = shape.global_batch, shape.seq_len
    pfx = cfg.num_prefix_tokens
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((gb, t - pfx), i32),
            "labels": jax.ShapeDtypeStruct((gb, t), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((gb, t - pfx), i32)}
    else:  # decode: one new token against a seq_len cache
        specs = {"tokens": jax.ShapeDtypeStruct((gb, 1), i32)}
    if pfx and shape.kind != "decode":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (gb, pfx, cfg.d_model), cfg.activation_dtype()
        )
    return specs


def batch_pspecs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, P]:
    specs: dict[str, P] = {}
    for name in input_specs(cfg, shape):
        specs[name] = P("dp", None, None) if name == "prefix_embeds" else P("dp", None)
    return specs


register_family(
    "transformer",
    ModelImpl(
        param_defs=param_defs,
        loss_fn=loss_fn,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
        input_specs=input_specs,
    ),
)
