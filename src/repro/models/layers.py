"""Shared transformer building blocks (pure functions over param dicts).

Numerics policy: parameters stored in cfg.param_dtype, computation in
cfg.dtype (bf16 by default), softmax/norm statistics and the attention
log-sum-exp always in f32, residual stream in cfg.dtype.

Attention is q-chunked (exact, not windowed): scores are materialized per
(query-chunk x full key length) tile so the per-device transient is bounded
— this is what makes the 32k prefill cells fit HBM in the dry-run and is the
XLA analogue of flash-attention tiling (DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.meshes import logical_constraint
from repro.models.model_api import ArchConfig

# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    if x.dtype == jnp.float32:
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * lax.rsqrt(var + eps) * scale.astype(x.dtype)
    # bf16 path: square in bf16, accumulate the mean in f32.  Avoiding the
    # explicit x.astype(f32) matters: XLA hoists that convert out of the
    # backward scan and materializes an f32 copy of the whole saved residual
    # stack (4 GiB/device at 405B).  bf16 squares cost ~1e-2 relative error
    # on the variance, which only perturbs the normalization scale.
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    r = lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * scale.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def apply_norm(cfg: ArchConfig, x: jax.Array, params: dict, prefix: str) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, params[f"{prefix}_scale"], params[f"{prefix}_bias"])
    return rms_norm(x, params[f"{prefix}_scale"])


# ----------------------------------------------------------------------------
# rotary embeddings (full or partial fraction — chatglm3 uses fraction=0.5)
# ----------------------------------------------------------------------------


def rope_frequencies(hd: int, fraction: float, theta: float) -> jax.Array:
    rot = int(hd * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(
    x: jax.Array, positions: jax.Array, *, fraction: float, theta: float
) -> jax.Array:
    """x: (B, T, H, hd), positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    freqs = rope_frequencies(hd, fraction, theta)  # (rot/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, T, 1, rot/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# ----------------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------------


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating groups (GQA -> MHA view)."""
    b, s, kv, hd = k.shape
    if kv == num_heads:
        return k
    reps = num_heads // kv
    return jnp.repeat(k, reps, axis=2)


def attention(
    q: jax.Array,  # (B, T, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,  # absolute position of q[0] (decode/chunking)
    q_chunk: int = 512,
    kv_sharded: bool = False,
) -> jax.Array:
    """Exact attention, q-chunked.  Returns (B, T, H, hd) in q.dtype."""
    b, t, h, hd = q.shape
    s = k.shape[1]
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    scale = hd**-0.5
    kv_spec = P("dp", "fsdp" if kv_sharded else None, "tp", None)
    k = logical_constraint(k, kv_spec)
    v = logical_constraint(v, kv_spec)

    def one_chunk(qc: jax.Array, start: jax.Array) -> jax.Array:
        # qc: (B, tc, H, hd)
        scores = jnp.einsum(
            "bthd,bshd->bhts", qc, k, preferred_element_type=jnp.float32
        ) * scale  # (B, H, tc, S) f32
        if causal:
            qpos = start + jnp.arange(qc.shape[1])
            kpos = jnp.arange(s)
            mask = qpos[:, None] >= kpos[None, :]
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bhts,bshd->bthd", probs.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return out.astype(q.dtype)

    if t <= q_chunk:
        return one_chunk(q, q_offset)
    assert t % q_chunk == 0, (t, q_chunk)
    nchunks = t // q_chunk
    q_r = q.reshape(b, nchunks, q_chunk, h, hd).swapaxes(0, 1)
    # checkpoint each chunk: otherwise the bwd saves per-chunk masks/probs,
    # which at 32k prefill is a multi-GiB stack per layer
    chunk_fn = jax.checkpoint(
        one_chunk, policy=jax.checkpoint_policies.nothing_saveable
    )
    out = lax.map(lambda i: chunk_fn(q_r[i], q_offset + i * q_chunk), jnp.arange(nchunks))
    return out.swapaxes(0, 1).reshape(b, t, h, hd)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd) — rolling cache, filled up to `length`
    v_cache: jax.Array,
    length: jax.Array,  # () int — valid prefix length (incl. current token)
) -> jax.Array:
    """Single-token attention against a (possibly seq-sharded) KV cache.

    GQA-grouped: queries reshape to (B, KV, G, hd) and contract against the
    cache directly — materializing repeat_kv'd K/V would multiply the
    dominant decode HBM traffic by G (=16 at llama3-405b), which the §Perf
    hillclimb measured as ~10x on the memory roofline term."""
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)  # (B, KV, G, hd); t == 1 folded into G dim
    scores = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * (hd**-0.5)
    mask = jnp.arange(s)[None, None, None, :] < length
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgs,bskh->bkgh", probs.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def qkv_project(
    cfg: ArchConfig, x: jax.Array, p: dict, prefix: str = ""
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, t, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    q = jnp.einsum("btd,dk->btk", x, p[f"{prefix}wq"].astype(x.dtype))
    k = jnp.einsum("btd,dk->btk", x, p[f"{prefix}wk"].astype(x.dtype))
    v = jnp.einsum("btd,dk->btk", x, p[f"{prefix}wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p[f"{prefix}bq"].astype(x.dtype)
        k = k + p[f"{prefix}bk"].astype(x.dtype)
        v = v + p[f"{prefix}bv"].astype(x.dtype)
    q = q.reshape(b, t, h, hd)
    k = k.reshape(b, t, kv, hd)
    v = v.reshape(b, t, kv, hd)
    q = logical_constraint(q, P("dp", None, "tp", None))
    return q, k, v


def out_project(x_attn: jax.Array, p: dict, prefix: str = "") -> jax.Array:
    b, t, h, hd = x_attn.shape
    return jnp.einsum(
        "btk,kd->btd", x_attn.reshape(b, t, h * hd), p[f"{prefix}wo"].astype(x_attn.dtype)
    )


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------


def mlp(cfg: ArchConfig, x: jax.Array, p: dict, prefix: str = "") -> jax.Array:
    if cfg.mlp_act == "swiglu":
        gate = jnp.einsum("btd,df->btf", x, p[f"{prefix}w_gate"].astype(x.dtype))
        up = jnp.einsum("btd,df->btf", x, p[f"{prefix}w_up"].astype(x.dtype))
        hidden = jax.nn.silu(gate) * up
    else:  # gelu
        hidden = jax.nn.gelu(
            jnp.einsum("btd,df->btf", x, p[f"{prefix}w_up"].astype(x.dtype))
            + p[f"{prefix}b_up"].astype(x.dtype)
        )
    hidden = logical_constraint(hidden, P("dp", None, "tp"))
    out = jnp.einsum("btf,fd->btd", hidden, p[f"{prefix}w_down"].astype(x.dtype))
    if cfg.mlp_act != "swiglu":
        out = out + p[f"{prefix}b_down"].astype(x.dtype)
    return out


# ----------------------------------------------------------------------------
# Mixture of Experts — sort/gather "dropless-with-capacity" dispatch
# ----------------------------------------------------------------------------


def moe_ffn(cfg: ArchConfig, x: jax.Array, p: dict, prefix: str = "moe_") -> jax.Array:
    """Top-k routed experts (+ optional always-on shared experts).

    Dispatch is gather-based (O(N·k·d) data movement instead of the O(N·E·C·d)
    one-hot einsum): sort token-assignments by expert, rank within expert via
    a cumulative count, drop beyond static capacity C, gather into (E, C, d),
    run the per-expert FFN as grouped matmuls, scatter-add back weighted by
    router gates.  Experts are EP-sharded over "tp" when divisible (deepseek
    64e, jamba 16e); otherwise the expert FFN dim is TP-sharded (grok 8e).

    The dispatch runs in token CHUNKS (lax.map): arbitrary-index gathers over
    a dp-sharded token table cannot be partitioned by SPMD (it replicates the
    table — ~120 GiB/device at 32k prefill), so chunking bounds the
    replicated working set to one chunk.  A shard_map all-to-all dispatch is
    the §Perf follow-up (see EXPERIMENTS.md).
    """
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    n = b * t
    xf = logical_constraint(x.reshape(n, d), P("dp", None))

    nchunks = 1
    while n // nchunks > cfg.moe_dispatch_tokens and n % (nchunks * 2) == 0:
        nchunks *= 2
    chunk = n // nchunks

    cap = int(cfg.capacity_factor * chunk * k / e + 0.5)
    cap = max(8, -(-cap // 8) * 8)
    ep = e % 16 == 0
    # capacity dim shards over "dp" (free inside the dispatch: no batch dim
    # survives the flatten) — without it the non-EP (grok) expert buffers
    # replicate (E, C, d) f32 on every device
    xe_spec = P("tp", "dp", None) if ep else P(None, "dp", None)
    hid_spec = P("tp", "dp", None) if ep else P(None, "dp", "tp")

    def route_chunk(xc: jax.Array) -> jax.Array:  # (chunk, d) -> (chunk, d)
        router_logits = jnp.einsum(
            "nd,de->ne", xc.astype(jnp.float32),
            p[f"{prefix}router"].astype(jnp.float32),
        )
        gates, experts = lax.top_k(jax.nn.softmax(router_logits, axis=-1), k)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

        flat_exp = experts.reshape(-1)  # (chunk*k,)
        flat_tok = jnp.repeat(jnp.arange(chunk), k)
        flat_gate = gates.reshape(-1)
        order = jnp.argsort(flat_exp)  # stable
        sorted_exp = flat_exp[order]
        group_start = jnp.searchsorted(sorted_exp, jnp.arange(e), side="left")
        rank = jnp.arange(chunk * k) - group_start[sorted_exp]
        keep = rank < cap
        slot = jnp.where(keep, sorted_exp * cap + rank, e * cap)

        tok_for_slot = jnp.full((e * cap + 1,), chunk, jnp.int32)
        gate_for_slot = jnp.zeros((e * cap + 1,), jnp.float32)
        tok_for_slot = tok_for_slot.at[slot].set(flat_tok[order].astype(jnp.int32))
        gate_for_slot = gate_for_slot.at[slot].set(flat_gate[order])
        tok_for_slot = tok_for_slot[: e * cap]
        gate_for_slot = gate_for_slot[: e * cap]

        # combine-accumulator dtype: bf16 activations accumulate the <=top_k
        # expert contributions in bf16 so the EP combine all-reduce moves
        # half the bytes (§Perf); f32 runs (tests) keep exact accumulation
        if cfg.moe_combine_dtype == "float32" or xc.dtype == jnp.float32:
            acc_dt = jnp.float32
        else:
            acc_dt = xc.dtype

        xc_pad = jnp.concatenate([xc, jnp.zeros((1, d), xc.dtype)], axis=0)
        xe = logical_constraint(xc_pad[tok_for_slot].reshape(e, cap, d), xe_spec)

        if cfg.mlp_act == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", xe, p[f"{prefix}w_gate"].astype(xe.dtype))
            u = jnp.einsum("ecd,edf->ecf", xe, p[f"{prefix}w_up"].astype(xe.dtype))
            hid = jax.nn.silu(g) * u
        else:
            hid = jax.nn.gelu(
                jnp.einsum("ecd,edf->ecf", xe, p[f"{prefix}w_up"].astype(xe.dtype))
            )
        hid = logical_constraint(hid, hid_spec)
        ye = jnp.einsum("ecf,efd->ecd", hid, p[f"{prefix}w_down"].astype(xe.dtype))
        # NOTE (§Perf iteration, refuted): scattering from the 3-D (E, C, d)
        # layout to keep the EP dim sharded INCREASED combine all-reduce
        # bytes 451 -> 780 GiB/dev at deepseek train — SPMD turns the
        # ep-sharded scatter into wider reductions.  The flatten is kept; the
        # structural fix is a shard_map all-to-all dispatch (future work).
        ye = logical_constraint(ye, xe_spec).reshape(e * cap, d)

        out = jnp.zeros((chunk + 1, d), acc_dt)
        out = out.at[tok_for_slot].add(
            ye.astype(acc_dt) * gate_for_slot[:, None].astype(acc_dt)
        )
        return out[:chunk].astype(xc.dtype)

    # checkpoint each routing round: the map's backward otherwise saves the
    # per-chunk f32 (E*C, d) dispatch buffers (a 7.7 GiB replicated stack at
    # grok train_4k)
    routed = jax.checkpoint(
        route_chunk, policy=jax.checkpoint_policies.nothing_saveable
    )
    if nchunks == 1:
        out = routed(xf)
    else:
        out = lax.map(routed, xf.reshape(nchunks, chunk, d))
        out = out.reshape(n, d)
    out = logical_constraint(out, P("dp", None))

    if cfg.num_shared_experts:
        shared = mlp(cfg, x, p, prefix=f"{prefix}shared_")
        out = out + shared.reshape(n, d)
    return out.reshape(b, t, d)


def moe_aux_loss(router_logits: jax.Array, experts: jax.Array, e: int) -> jax.Array:
    """Switch-style load-balancing loss (logged, weight configured upstream)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    density = jnp.mean(jax.nn.one_hot(experts[..., 0], e), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    return e * jnp.sum(density * density_prob)
