"""Train-step factory: loss -> grads (with microbatch accumulation) ->
optimizer update, all inside one jit-able function.

Gradient accumulation: the global batch is split into cfg.microbatches_train
microbatches scanned sequentially with f32 gradient accumulation — this is
what bounds activation memory for the >=100B cells (DESIGN.md §4).

Cross-pod gradient compression: the grads that cross the "pod" axis can be
psum'd in bf16 (grad_compression="bf16"), halving the only cross-pod
collective's bytes.  Implemented as a cast-before-constraint so XLA's
all-reduce runs at the narrow width.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model_api import ArchConfig, get_model
from repro.training.optimizers import Optimizer


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    *,
    grad_compression: str = "none",  # "none" | "bf16"
    loss_fn: Callable | None = None,
    param_specs=None,  # logical PartitionSpec tree: keeps optimizer math sharded
) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics)."""
    impl = get_model(cfg)
    loss_fn = loss_fn or impl.loss_fn
    m = max(int(cfg.microbatches_train), 1)

    def _grads(params, batch):
        def lf(p, b):
            loss, metrics = loss_fn(p, b, cfg)
            return loss, metrics

        # clamp microbatch count to what the actual batch divides into
        b0 = jax.tree.leaves(batch)[0].shape[0]
        m_eff = m
        while b0 % m_eff != 0:
            m_eff -= 1
        if m_eff == 1:
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape((m_eff, x.shape[0] // m_eff) + x.shape[1:])

        micro = jax.tree.map(split, batch)
        acc_dt = jnp.dtype(cfg.grad_accum_dtype)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        inv_m = 1.0 / m_eff  # fold the mean into the accumulation (one less
        # full-gradient-stack temp than a post-hoc divide)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + (g * jnp.asarray(inv_m, g.dtype)).astype(acc_dt),
                acc, grads,
            )
            return (acc, loss_acc + loss), None

        (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0)), micro)
        loss = loss_sum / m_eff
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = _grads(params, batch)
        if grad_compression == "bf16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        new_params, new_opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params, step, specs=param_specs
        )
        out_metrics = {"loss": loss, **opt_metrics}
        return new_params, new_opt_state, out_metrics

    return train_step
