"""Pure-JAX pytree optimizers: AdamW, Adafactor, SGD-momentum.

Adafactor (factored second moments, no first moment by default) is what the
>=100B configs use so optimizer state fits 16 GB/chip HBM: for a (.., n, m)
weight it stores one (.., n) row and one (.., m) column accumulator instead
of an (.., n, m) second moment (Shazeer & Stern 2018).

State layout mirrors the param tree (same shardings apply), so checkpointing
and elastic resharding treat optimizer state exactly like params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params, step) -> (new_params, new_state)
    state_specs: Callable[[Any, Any], Any]  # (param_spec_tree, param_struct) -> state spec tree


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype or p.dtype), params)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), tree, jnp.float32(0)
    )
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def _decay_mask(path: tuple) -> bool:
    """True if weight decay applies (skip norms, biases, 1-d params)."""
    name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
    return not any(s in name for s in ("scale", "bias", "b_", "ln"))


# ----------------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------------


def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def init(params):
        return {
            "m": _tree_zeros_like(params, jnp.float32),
            "v": _tree_zeros_like(params, jnp.float32),
        }

    def update(grads, state, params, step, specs=None):
        del specs  # adamw state/updates share the param shape, sharding follows
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(path, g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and _decay_mask(path):
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        flat = jax.tree_util.tree_map_with_path(
            lambda path, g, m, v, p: upd(path, g, m, v, p),
            grads, state["m"], state["v"], params,
        )
        new_params = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr_t}

    def state_specs(param_specs, _params_struct):
        return {"m": param_specs, "v": param_specs}

    return Optimizer(init=init, update=update, state_specs=state_specs)


# ----------------------------------------------------------------------------
# Adafactor
# ----------------------------------------------------------------------------


def adafactor(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    clip_norm: float = 0.0,  # 0 = no global clip: adafactor's per-param RMS
    # clipping replaces it (T5 practice) and the global-norm pass would
    # materialize f32 copies of every grad stack — a multi-GiB HBM hit at 405B
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def leaf(p):
            if _factored(p):
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),  # row accumulator
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(leaf, params)

    def update(grads, state, params, step, specs=None):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.float32(0)
        lr_t = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t**-0.8  # standard adafactor decay schedule, capped
        beta = jnp.minimum(beta, decay)

        def upd_leaf(decay_this, g, s, p, slice_spec=None):
            if slice_spec is not None:
                # keep per-slice math sharded like the param: without this the
                # lax.map body loses the annotation and XLA replicates the
                # update (a full f32 weight slice per device at 405B scale)
                from repro.distributed.meshes import logical_constraint

                g = logical_constraint(g, slice_spec)
                p = logical_constraint(p, slice_spec)
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                r = beta * s["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(r, axis=-1, keepdims=True)
                vhat = (r / jnp.maximum(rmean, eps))[..., None] * c[..., None, :]
                u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # RMS update clipping (per logical parameter)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and decay_this:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_s

        def upd(path, g, s, p, spec=None):
            decay_this = bool(weight_decay) and _decay_mask(path)
            # Layer-stacked (L, n, m) weights: run the update per layer slice
            # (lax.map) so the f32 intermediates are one-layer-sized instead
            # of whole-stack-sized — this is what keeps the >=100B update
            # inside HBM, and per-layer RMS clipping is the semantically
            # correct granularity anyway (each layer is a logical parameter).
            if p.ndim >= 3 and p.shape[0] > 4:
                from jax.sharding import PartitionSpec as PS

                slice_spec = PS(*tuple(spec)[1:]) if spec is not None else None
                return jax.lax.map(
                    lambda gsp: upd_leaf(decay_this, *gsp, slice_spec=slice_spec),
                    (g, s, p),
                )
            return upd_leaf(decay_this, g, s, p)

        flat = _map_with_state(upd, grads, state, params, specs)
        new_params = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}

    def state_specs(param_specs, params_struct):
        from jax.sharding import PartitionSpec as P

        def leaf(spec, p):
            if _factored(p):
                entries = list(spec) + [None] * (p.ndim - len(spec))
                return {"r": P(*entries[:-1]), "c": P(*(entries[:-2] + entries[-1:]))}
            return {"v": spec}

        return jax.tree.map(leaf, param_specs, params_struct, is_leaf=_is_pspec)

    return Optimizer(init=init, update=update, state_specs=state_specs)


def _map_with_state(fn, grads, state, params, specs=None):
    """tree_map_with_path where `state` leaves are {r,c}/{v} dicts."""
    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    state_leaves = _collect_state_leaves(state)
    if specs is None:
        spec_leaves = [None] * len(flat_g)
    else:
        spec_leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_pspec)
    out = [
        fn(path, g, s, p, spec)
        for (path, g), s, (_, p), spec in zip(flat_g, state_leaves, flat_p, spec_leaves)
    ]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), out)


def _collect_state_leaves(state):
    is_leaf = lambda x: isinstance(x, dict) and set(x) <= {"r", "c", "v"}  # noqa: E731
    return jax.tree_util.tree_leaves(state, is_leaf=is_leaf)


def _is_pspec(x) -> bool:
    from jax.sharding import PartitionSpec

    return isinstance(x, PartitionSpec)


# ----------------------------------------------------------------------------
# SGD + momentum
# ----------------------------------------------------------------------------


def sgdm(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def init(params):
        return {"m": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params, step, specs=None):
        del specs
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr_t = lr_fn(step)

        def upd(path, g, m, p):
            g32 = g.astype(jnp.float32)
            if weight_decay and _decay_mask(path):
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g32
            return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

        flat = jax.tree_util.tree_map_with_path(upd, grads, state["m"], params)
        new_params = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m}, {"grad_norm": gnorm, "lr": lr_t}

    def state_specs(param_specs, _params_struct):
        return {"m": param_specs}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](lr, **kw)
