"""sklearn-compatible estimator front end over the solver stack.

Three classes with the ``fit`` / ``predict`` / ``score`` / ``get_params``
surface sklearn tooling expects (``clone``, ``GridSearchCV``, pipelines):

* :class:`KernelRidge` — ``sklearn.kernel_ridge.KernelRidge`` semantics
  over ``solver_api.solve`` (whole kernel zoo + ``"precomputed"``; solver,
  precision, and mesh pass-throughs).
* :class:`KernelRidgeCV` — built-in (sigma, alpha) k-fold search over the
  tile-sharing tune engine, sklearn's ``best_params_`` / ``cv_results_``
  reporting idiom.
* :class:`MultipleKernelRidgeCV` — Dirichlet weight search over convex
  kernel combinations (per-kernel bandwidths included).

scikit-learn itself is optional: with it installed the classes subclass
``sklearn.base.BaseEstimator``; without it a structural shim provides the
same surface (``HAVE_SKLEARN`` reports which).
"""

from repro.estimators.base import HAVE_SKLEARN
from repro.estimators.cv import KernelRidgeCV, MultipleKernelRidgeCV
from repro.estimators.kernel_ridge import (
    AUTO_DIRECT_MAX_N,
    KernelRidge,
    resolve_sigma,
)

__all__ = [
    "AUTO_DIRECT_MAX_N",
    "HAVE_SKLEARN",
    "KernelRidge",
    "KernelRidgeCV",
    "MultipleKernelRidgeCV",
    "resolve_sigma",
]
