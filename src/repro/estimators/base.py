"""sklearn interop for the estimator front end — import guard + validation.

The estimators subclass ``sklearn.base.BaseEstimator``/``RegressorMixin``
when scikit-learn is importable (so ``sklearn.clone``, ``GridSearchCV``
nesting, and pipeline composition all work natively) and fall back to a
small structural shim otherwise — the public surface (``get_params`` /
``set_params`` / ``score`` with R^2) is identical either way, so nothing in
this repo requires scikit-learn at runtime.

The shared fit-time validation lives here too: estimator ``fit`` is the ONE
boundary where user data enters the solver stack, so shape/finite checks
raise clear ``ValueError``s here instead of surfacing as NaN solutions or
cryptic jit shape errors deep inside a solve.
"""

from __future__ import annotations

import inspect

import jax.numpy as jnp
import numpy as np

try:  # pragma: no cover - exercised implicitly by every estimator test
    from sklearn.base import BaseEstimator, RegressorMixin

    HAVE_SKLEARN = True
except ImportError:  # pragma: no cover
    HAVE_SKLEARN = False

    class BaseEstimator:  # type: ignore[no-redef]
        """Structural stand-in for ``sklearn.base.BaseEstimator``: the
        get_params/set_params contract over ``__init__`` keyword names."""

        @classmethod
        def _get_param_names(cls):
            sig = inspect.signature(cls.__init__)
            return sorted(
                p.name
                for p in sig.parameters.values()
                if p.name != "self" and p.kind != p.VAR_KEYWORD
            )

        def get_params(self, deep: bool = True) -> dict:
            """Constructor parameters by name (``deep`` accepted for API
            compatibility; these estimators have no nested estimators)."""
            return {k: getattr(self, k) for k in self._get_param_names()}

        def set_params(self, **params):
            """Set constructor parameters by name; unknown names raise."""
            valid = set(self._get_param_names())
            for k, v in params.items():
                if k not in valid:
                    raise ValueError(
                        f"invalid parameter {k!r} for {type(self).__name__}; "
                        f"valid: {sorted(valid)}"
                    )
                setattr(self, k, v)
            return self

        def __repr__(self) -> str:
            args = ", ".join(
                f"{k}={v!r}" for k, v in sorted(self.get_params().items())
            )
            return f"{type(self).__name__}({args})"

    class RegressorMixin:  # type: ignore[no-redef]
        """Structural stand-in for ``sklearn.base.RegressorMixin``."""

        def score(self, X, y) -> float:
            """R^2 of ``self.predict(X)`` vs ``y`` (uniform average over
            output heads — sklearn's default ``multioutput``)."""
            pred = np.asarray(self.predict(X))
            y = np.asarray(y)
            ss_res = np.sum((y - pred) ** 2, axis=0)
            ss_tot = np.sum((y - np.mean(y, axis=0)) ** 2, axis=0)
            r2 = 1.0 - ss_res / np.where(ss_tot == 0.0, 1.0, ss_tot)
            return float(np.mean(np.where(ss_tot == 0.0, 0.0, r2)))


class FittedPredictorMixin:
    """Shared predict for estimators whose ``fit`` stores ``dual_coef_`` and
    a per-method ``_predict_fn`` scorer (the ``solve()`` output's closure)."""

    def predict(self, X):
        """Predictions for ``X`` ((m, d) features, or the (m, n) cross Gram
        for a precomputed-kernel fit); (m,) or (m, t) matching the fit
        targets."""
        if not hasattr(self, "dual_coef_"):
            raise ValueError(
                f"{type(self).__name__} is not fitted; call fit(X, y) first"
            )
        X = check_array(X, "X")
        if X.shape[0] == 0:
            # dtype follows the weights (the serving-layer contract)
            return jnp.zeros(
                (0,) + self.dual_coef_.shape[1:], self.dual_coef_.dtype
            )
        return self._predict_fn(X)


def check_array(arr, name: str, *, ndim: tuple[int, ...] = (2,)):
    """Convert to a jnp float array, rejecting bad shapes/values with clear
    errors.  Preserves f64 when jax x64 is enabled (sklearn-parity runs);
    integer/low-precision inputs are promoted to the default float."""
    a = jnp.asarray(arr)
    if a.ndim not in ndim:
        raise ValueError(
            f"{name} must be {' or '.join(f'{d}-D' for d in ndim)}; got "
            f"shape {a.shape}"
        )
    if not jnp.issubdtype(a.dtype, jnp.floating):
        a = a.astype(jnp.result_type(float))
    if a.size and not bool(jnp.all(jnp.isfinite(a))):
        raise ValueError(
            f"{name} contains non-finite values (NaN or inf); clean or "
            f"impute the data before fit/predict"
        )
    return a


def check_fit_arrays(X, y, *, precomputed: bool = False):
    """Validate an (X, y) fit pair; returns jnp arrays.

    ``precomputed=True`` means X is the train Gram: it must be square (or
    already index-widened) and row-aligned with y.
    """
    X = check_array(X, "X")
    y = check_array(y, "y", ndim=(1, 2))
    if precomputed and X.shape[1] not in (X.shape[0], X.shape[0] + 1):
        raise ValueError(
            "kernel='precomputed' expects a square (n, n) train Gram matrix "
            f"for X; got shape {X.shape}"
        )
    if X.shape[0] != y.shape[0]:
        raise ValueError(
            f"X and y row counts differ: X has {X.shape[0]} rows, y has "
            f"{y.shape[0]}"
        )
    if X.shape[0] < 1:
        raise ValueError("fit needs at least one sample")
    return X, y
