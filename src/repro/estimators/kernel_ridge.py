"""KernelRidge — the sklearn-compatible front door to the solver stack.

``sklearn.kernel_ridge.KernelRidge`` semantics (same model, same ``alpha``
and ``gamma`` conventions, same multi-output behavior) over
``repro.core.solver_api.solve``: small fits default to the closed-form
direct solver and large ones to ASkotch, and every solver / precision /
mesh option of the stack is reachable through constructor parameters —
fit/predict/score is the only API a scientific user needs.

sklearn solves ``(K + alpha I) c = y`` while this stack solves
``(K + n lam_unscaled I) W = Y`` (the paper's App. C.2.1 scaling), so
``lam_unscaled = alpha / n`` makes the two models identical; bandwidths map
through ``core.kernels``'s single-sigma parameterization (each kernel's
docstring states its sklearn ``gamma`` equivalence).  The differential
suite ``tests/test_sklearn_api.py`` pins predictions to sklearn at
rtol 1e-5 across the whole kernel zoo.
"""

from __future__ import annotations

from repro.core.kernels import KERNEL_NAMES
from repro.core.krr import KRRProblem
from repro.core.solver_api import METHODS, solve
from repro.estimators.base import (
    BaseEstimator,
    FittedPredictorMixin,
    RegressorMixin,
    check_fit_arrays,
)

#: n up to which solver="auto" picks the O(n^3) closed-form direct solver;
#: beyond it ASkotch's O(n b) iterations win
AUTO_DIRECT_MAX_N = 2048


def resolve_sigma(kernel: str, sigma, gamma, n_features: int) -> float:
    """The single bandwidth ``sigma`` the operator layer runs on.

    Precedence: explicit ``sigma`` > explicit ``gamma`` (translated per
    kernel — the table in ``core.kernels``) > sklearn's default
    ``gamma = 1 / n_features``.  ``linear``/``cosine`` are gamma-free
    (sigma 1.0) and ``precomputed`` has no bandwidth at all.
    """
    if kernel == "precomputed":
        return 1.0
    if sigma is not None:
        sigma = float(sigma)
        if sigma <= 0:
            raise ValueError(f"sigma must be positive; got {sigma}")
        return sigma
    if kernel in ("linear", "cosine"):
        return 1.0
    g = 1.0 / n_features if gamma is None else float(gamma)
    if g <= 0:
        raise ValueError(f"gamma must be positive; got {g}")
    if kernel == "rbf":
        return (0.5 / g) ** 0.5  # k = exp(-g d^2) = exp(-d^2 / (2 sigma^2))
    if kernel in ("laplacian", "matern52"):
        return 1.0 / g  # laplacian k = exp(-g d1); matern length_scale
    if kernel in ("polynomial", "sigmoid"):
        return g**-0.5  # g <x, y> = <x, y> / sigma^2
    raise ValueError(
        f"unknown kernel {kernel!r}; available: "
        f"{KERNEL_NAMES + ('precomputed',)}"
    )


class KernelRidge(FittedPredictorMixin, RegressorMixin, BaseEstimator):
    """Kernel ridge regression with sklearn fit/predict/score semantics.

    Args:
      alpha: sklearn's ridge strength — the solved system is
        ``(K + alpha I) c = y`` exactly (internally ``lam_unscaled =
        alpha / n``).
      kernel: a ``core.kernels.KERNEL_NAMES`` name, or ``"precomputed"``
        (then ``fit`` X is the (n, n) train Gram and ``predict`` X is the
        (m, n) test-vs-train cross Gram).
      gamma: sklearn-convention bandwidth (``None`` -> ``1 / n_features``
        for the gamma-full kernels); translated to the stack's single
        ``sigma`` per kernel.
      sigma: direct bandwidth in this stack's parameterization — wins over
        ``gamma`` when both are given.
      solver: a ``solver_api.METHODS`` name, or ``"auto"`` (direct up to
        n = 2048, ASkotch beyond).
      solver_opts: extra keyword options for ``solve`` (``tol``,
        ``max_iters``, ``rank``, ``block_size``, ...), validated against
        the method's accepted list there.
      backend / precision: kernel-execution pass-throughs ("auto" backend;
        "f32" | "bf16" tile policy).
      mesh: optional ``jax.sharding.Mesh`` — the fit runs the distributed
        solver path (not valid with ``kernel="precomputed"``).

    Attributes (after fit):
      dual_coef_: (n,) or (n, t) representer weights.
      X_fit_: the training rows (features, or the widened Gram for
        ``precomputed``) predictions are computed against.
      n_features_in_: feature count of fit X.
      sigma_: the resolved bandwidth actually solved with.
      solve_info_: the ``solve()`` info dict (iterations, convergence).
    """

    def __init__(
        self,
        alpha: float = 1.0,
        *,
        kernel: str = "rbf",
        gamma: float | None = None,
        sigma: float | None = None,
        solver: str = "auto",
        solver_opts: dict | None = None,
        backend: str = "auto",
        precision: str = "f32",
        mesh=None,
    ):
        self.alpha = alpha
        self.kernel = kernel
        self.gamma = gamma
        self.sigma = sigma
        self.solver = solver
        self.solver_opts = solver_opts
        self.backend = backend
        self.precision = precision
        self.mesh = mesh

    def _method(self, n: int) -> str:
        if self.solver == "auto":
            return "direct" if n <= AUTO_DIRECT_MAX_N else "askotch"
        if self.solver not in METHODS:
            raise ValueError(
                f"unknown solver {self.solver!r}; available: "
                f"{METHODS + ('auto',)}"
            )
        return self.solver

    def fit(self, X, y):
        """Solve the dual system for ``X`` ((n, d) features, or the (n, n)
        train Gram when ``kernel="precomputed"``) and targets ``y`` ((n,) or
        (n, t) multi-output).  Returns self."""
        if float(self.alpha) <= 0:
            raise ValueError(f"alpha must be positive; got {self.alpha}")
        X, y = check_fit_arrays(X, y, precomputed=self.kernel == "precomputed")
        n = X.shape[0]
        sigma = resolve_sigma(self.kernel, self.sigma, self.gamma, X.shape[1])
        problem = KRRProblem(
            x=X, y=y, kernel=self.kernel, sigma=sigma,
            lam_unscaled=float(self.alpha) / n, backend=self.backend,
            precision=self.precision,
        )
        out = solve(
            problem, self._method(n), mesh=self.mesh,
            **dict(self.solver_opts or {}),
        )
        self._problem = problem
        # per-method scorer (Falkon's w lives on inducing points; mesh fits
        # serve from the sharded operator) — dual_coef_ stays the raw weights
        self._predict_fn = out.predict_fn
        self.dual_coef_ = out.w
        self.X_fit_ = problem.x
        self.n_features_in_ = int(X.shape[1])
        self.sigma_ = sigma
        self.solve_info_ = out.info
        return self
