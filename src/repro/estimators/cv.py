"""Cross-validated estimators over the ``core/tune`` engine.

``KernelRidgeCV`` sweeps a (sigma, alpha) grid with k-fold CV and refits the
winner; ``MultipleKernelRidgeCV`` adds himalaya-style Dirichlet weight search
over a convex kernel combination (per-kernel sigma vectors included).  Both
ride the tile-sharing stacked engine — per sigma, ONE blocked solve scores
every (alpha, fold, head[, weight]) candidate — so a CV sweep costs a few
kernel sweeps, not ``len(alphas) * folds`` of them, and both expose the
search through sklearn's ``best_params_`` / ``best_score_`` /
``cv_results_`` idiom (built from ``TuneResult.trace``).

Alpha convention, exactly as :class:`~repro.estimators.kernel_ridge.
KernelRidge`: the refit solves ``(K + alpha I) c = y``.  One documented
nuance: during CV the engine scales each candidate's shift by the TRAIN-FOLD
size (``n_fold * lam_unscaled``, the paper's per-problem rule), so a
candidate's effective CV alpha is ``alpha * (k-1)/k`` — ranking is on
slightly lighter regularization than the refit, the same direction every
k-fold ridge CV (sklearn included, which reuses one alpha across fold sizes
by a different convention) accepts.
"""

from __future__ import annotations

import numpy as np

from repro.core.krr import KRRProblem
from repro.core.solver_api import solve, tune
from repro.core.tune import apply_best
from repro.estimators.base import (
    BaseEstimator,
    FittedPredictorMixin,
    RegressorMixin,
    check_fit_arrays,
)
from repro.estimators.kernel_ridge import AUTO_DIRECT_MAX_N, METHODS


def _rank_desc(scores: list[float]) -> list[int]:
    """sklearn-style 1-based competition ranks, higher score = rank 1."""
    order = np.argsort([-s for s in scores], kind="stable")
    ranks = [0] * len(scores)
    rank = 0
    prev = None
    for pos, idx in enumerate(order):
        if prev is None or scores[idx] != prev:
            rank = pos + 1
            prev = scores[idx]
        ranks[idx] = rank
    return ranks


def _cv_results(result, n: int) -> dict:
    """``cv_results_`` dict from a TuneResult: one entry per candidate in
    trace order, scores in sklearn's higher-is-better convention (negated
    CV MSE)."""
    trace = result.trace or []
    sigmas = [t["sigma"] for t in trace]
    alphas = [float(t["lam_unscaled"]) * n for t in trace]
    mses = [float(t["scores"][-1]) for t in trace]
    scores = [-m for m in mses]
    out = {
        "param_sigma": sigmas,
        "param_alpha": alphas,
        "mean_test_mse": mses,
        "mean_test_score": scores,
        "rank_test_score": _rank_desc(scores),
        "pruned_at_rung": [t.get("pruned_at_rung") for t in trace],
        "trace": trace,
    }
    if trace and "weights" in trace[0]:
        out["param_weights"] = [t["weights"] for t in trace]
    return out


class _BaseTunedRidge(FittedPredictorMixin, RegressorMixin, BaseEstimator):
    """Shared tune -> refit plumbing; subclasses build the tune() call."""

    def _refit(self, problem: KRRProblem, result) -> None:
        refit_problem = apply_best(problem, result)
        n = refit_problem.n
        if self.solver == "auto":
            method = "direct" if n <= AUTO_DIRECT_MAX_N else "askotch"
        elif self.solver in METHODS:
            method = self.solver
        else:
            raise ValueError(
                f"unknown solver {self.solver!r}; available: "
                f"{METHODS + ('auto',)}"
            )
        out = solve(
            refit_problem, method, mesh=self.mesh,
            **dict(self.solver_opts or {}),
        )
        self._problem = refit_problem
        self._predict_fn = out.predict_fn
        self.dual_coef_ = out.w
        self.X_fit_ = refit_problem.x
        self.tune_result_ = result
        self.best_score_ = -float(result.best_score)
        self.cv_results_ = _cv_results(result, n)
        self.alpha_ = float(result.best["lam_unscaled"]) * n
        self.sigma_ = result.best["sigma"]
        self.solve_info_ = out.info


class KernelRidgeCV(_BaseTunedRidge):
    """Kernel ridge with built-in (sigma, alpha) search + winning refit.

    Args:
      alphas: candidate ridge strengths (sklearn's ``alpha`` convention).
      sigmas: candidate bandwidths in the stack's parameterization; for
        ``kernel="precomputed"`` the bandwidth axis collapses to (1.0,)
        automatically (the Gram already encodes it).
      kernel: one ``core.kernels.KERNEL_NAMES`` name or ``"precomputed"``.
      cv: number of CV folds (k-fold over a seeded shuffle split).
      policy: ``"grid"`` | ``"random"`` | ``"halving"`` search policy
        (``num_samples`` bounds the random draw).
      seed: rng seed for folds / sampling.
      tune_opts: extra ``tune()`` options (``rank``, ``max_iters``, ``tol``,
        ``warm_start``, ``sigma_continuation``, ...).
      solver / solver_opts / backend / precision / mesh: refit pass-throughs,
        as in :class:`~repro.estimators.kernel_ridge.KernelRidge`.

    Attributes (after fit): ``best_params_`` (``{"alpha", "sigma"}``),
    ``best_score_`` (negated CV MSE — sklearn's higher-is-better),
    ``cv_results_`` (per-candidate params/scores/ranks from
    ``TuneResult.trace``), ``tune_result_`` (the full audit trail), plus
    the fitted-model attributes of ``KernelRidge``.
    """

    def __init__(
        self,
        alphas=(0.01, 0.1, 1.0),
        *,
        sigmas=(0.5, 1.0, 2.0),
        kernel: str = "rbf",
        cv: int = 5,
        policy: str = "grid",
        num_samples: int | None = None,
        seed: int = 0,
        tune_opts: dict | None = None,
        solver: str = "auto",
        solver_opts: dict | None = None,
        backend: str = "auto",
        precision: str = "f32",
        mesh=None,
    ):
        self.alphas = alphas
        self.sigmas = sigmas
        self.kernel = kernel
        self.cv = cv
        self.policy = policy
        self.num_samples = num_samples
        self.seed = seed
        self.tune_opts = tune_opts
        self.solver = solver
        self.solver_opts = solver_opts
        self.backend = backend
        self.precision = precision
        self.mesh = mesh

    def fit(self, X, y):
        """Run the CV sweep over (sigmas, alphas) and refit the winner on
        all of ``X``/``y``.  Returns self."""
        X, y = check_fit_arrays(X, y, precomputed=self.kernel == "precomputed")
        n = X.shape[0]
        problem = KRRProblem(
            x=X, y=y, kernel=self.kernel, sigma=1.0,
            backend=self.backend, precision=self.precision,
        )
        sigmas = (
            (1.0,) if self.kernel == "precomputed" else tuple(self.sigmas)
        )
        kw = dict(self.tune_opts or {})
        if self.num_samples is not None:
            kw["num_samples"] = int(self.num_samples)
        result = tune(
            problem,
            sigmas=sigmas,
            lams=tuple(float(a) / n for a in self.alphas),
            folds=int(self.cv),
            policy=self.policy,
            seed=int(self.seed),
            mesh=self.mesh,
            **kw,
        )
        self._refit(problem, result)
        self.n_features_in_ = int(X.shape[1])
        self.best_params_ = {"alpha": self.alpha_, "sigma": self.sigma_}
        return self


class MultipleKernelRidgeCV(_BaseTunedRidge):
    """CV search over convex kernel combinations ``K_w = sum_i w_i K_i``.

    himalaya's ``MultipleKernelRidgeCV`` shape: Dirichlet-sample weight
    vectors on the simplex (or score explicit ``weights`` rows), sweep them
    jointly with (sigma, alpha) through the stacked multi-kernel engine —
    every weight candidate is one more COLUMN of the same solve, and the q
    per-kernel tiles come from one data sweep — then refit the winning
    (weights, sigma, alpha) on all the data.

    Args:
      kernels: the q base-kernel names of the combination.
      sigmas: candidate bandwidths — scalars (shared by all q kernels) or
        length-q tuples (per-kernel bandwidth vectors), freely mixed.
      alphas / cv / seed: as :class:`KernelRidgeCV`.
      n_weight_samples: Dirichlet draws from the simplex (ignored when
        ``weights`` rows are given).
      dirichlet_alpha: concentration of the Dirichlet sampler.
      weights: optional explicit (M, q) weight candidates.
      policy: ``"random"`` (default) or ``"halving"``.
      tune_opts / solver / solver_opts / backend / precision / mesh: as
        :class:`KernelRidgeCV`.

    Attributes (after fit): ``kernel_weights_`` (the winning (q,) weight
    vector), ``best_params_`` (``{"alpha", "sigma", "weights"}``), and the
    rest of the :class:`KernelRidgeCV` surface.
    """

    def __init__(
        self,
        kernels=("rbf", "laplacian"),
        *,
        alphas=(0.01, 0.1, 1.0),
        sigmas=(0.5, 1.0, 2.0),
        cv: int = 5,
        n_weight_samples: int = 8,
        dirichlet_alpha: float = 1.0,
        weights=None,
        policy: str = "random",
        seed: int = 0,
        tune_opts: dict | None = None,
        solver: str = "auto",
        solver_opts: dict | None = None,
        backend: str = "auto",
        precision: str = "f32",
        mesh=None,
    ):
        self.kernels = kernels
        self.alphas = alphas
        self.sigmas = sigmas
        self.cv = cv
        self.n_weight_samples = n_weight_samples
        self.dirichlet_alpha = dirichlet_alpha
        self.weights = weights
        self.policy = policy
        self.seed = seed
        self.tune_opts = tune_opts
        self.solver = solver
        self.solver_opts = solver_opts
        self.backend = backend
        self.precision = precision
        self.mesh = mesh

    def fit(self, X, y):
        """Joint (weights, sigma, alpha) CV search + winning refit."""
        X, y = check_fit_arrays(X, y)
        n = X.shape[0]
        problem = KRRProblem(
            x=X, y=y, kernel=tuple(self.kernels), sigma=1.0,
            backend=self.backend, precision=self.precision,
        )
        result = tune(
            problem,
            sigmas=tuple(self.sigmas),
            lams=tuple(float(a) / n for a in self.alphas),
            folds=int(self.cv),
            n_weight_samples=int(self.n_weight_samples),
            dirichlet_alpha=float(self.dirichlet_alpha),
            weights=self.weights,
            policy=self.policy,
            seed=int(self.seed),
            mesh=self.mesh,
            **dict(self.tune_opts or {}),
        )
        self._refit(problem, result)
        self.n_features_in_ = int(X.shape[1])
        self.kernel_weights_ = tuple(
            float(w) for w in result.best["weights"]
        )
        self.best_params_ = {
            "alpha": self.alpha_,
            "sigma": self.sigma_,
            "weights": self.kernel_weights_,
        }
        return self
