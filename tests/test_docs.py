"""Documentation contract: the public API is documented, the quickstart
snippets in docs/ actually run (doctest), and no markdown link is dead."""

import doctest
import inspect
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

#: the public surface — every module and every listed attribute must carry a
#: real docstring (args/returns/shape documentation lives there)
PUBLIC_MODULES = (
    "repro.core",
    "repro.core.solver_api",
    "repro.core.operator",
    "repro.core.krr",
    "repro.core.tune",
    "repro.core.tune.engine",
    "repro.core.tune.policies",
    "repro.core.tuning",  # the deprecation shim keeps its docstring
    "repro.core.multikernel",
    "repro.core.blocked_cg",
    "repro.kernels.ops",
    "repro.kernels.multi",
    "repro.kernels.precision",
    "repro.core.rff",
    "repro.distributed.sharded_operator",
    "repro.distributed.partition",
    "repro.distributed.dc",
    "repro.obs",
    "repro.obs.spans",
    "repro.obs.metrics",
    "repro.obs.trace",
    "repro.obs.telemetry",
    "repro.obs.sinks",
    "repro.obs.report",
    "repro.serving.krr_serve",
    "repro.serving.engine",
    "repro.estimators",
    "repro.estimators.base",
    "repro.estimators.kernel_ridge",
    "repro.estimators.cv",
)

PUBLIC_CALLABLES = {
    "repro.core.solver_api": ("solve", "tune"),
    "repro.core.tune": ("tune", "tune_multikernel", "apply_best",
                        "TuneResult", "SweepCounter", "SigmaGroup",
                        "solve_sigma_group", "GridSearch", "RandomSearch",
                        "SuccessiveHalving", "SearchPolicy", "make_policy"),
    "repro.core.krr": ("KRRProblem", "evaluate", "evaluate_per_head",
                       "scaled_lam", "residual_report"),
    "repro.core.multikernel": ("make_operator", "canonical_kernels"),
    "repro.core.direct": ("solve_direct", "loo_residuals", "loo_mse"),
    "repro.kernels.ops": ("kernel_matvec", "kernel_block", "resolve_backend",
                          "kernel_matvec_multi", "kernel_matvec_components",
                          "kernel_block_multi"),
    "repro.serving.krr_serve": ("make_krr_predict_fn",
                                "make_sharded_krr_predict_fn",
                                "make_krr_predict_fn_from_config",
                                "bind_operator_from_config"),
    "repro.serving.engine": ("ServingEngine", "save_model_artifact",
                             "load_model_artifact", "bucket_sizes",
                             "bucket_for"),
    "repro.core.blocked_cg": ("blocked_cg",),
    "repro.kernels.precision": ("check_precision",),
    "repro.core.rff": ("rff_features", "rff_factors", "sample_freqs"),
    "repro.distributed.partition": ("Partition", "make_partition",
                                    "random_partition", "kmeans_partition",
                                    "balanced_sizes", "chunked_sq_dists"),
    "repro.distributed.dc": ("solve_dc", "combiner_weights",
                             "collective_dispatch_delta", "DCSolveResult"),
    "repro.core.kernels": ("kernel_family", "kernel_diag", "kernel_matrix"),
    "repro.core.operator": ("widen_gram",),
    "repro.estimators": ("resolve_sigma",),
    "repro.obs": ("Telemetry", "as_telemetry", "TraceRecorder", "span",
                  "counter", "gauge", "histogram", "snapshot", "diff",
                  "prometheus_text", "record_tile_work", "validate_event",
                  "validate_jsonl", "log_buckets"),
    "repro.obs.report": ("summarize", "main"),
}

#: classes whose public methods must each be documented
PUBLIC_CLASSES = (
    ("repro.core.operator", "KernelOperator"),
    ("repro.core.operator", "PrecomputedKernelOperator"),
    ("repro.core.multikernel", "WeightedSumKernelOperator"),
    ("repro.distributed.sharded_operator", "ShardedKernelOperator"),
    ("repro.serving.engine", "ServingEngine"),
    ("repro.estimators", "KernelRidge"),
    ("repro.estimators", "KernelRidgeCV"),
    ("repro.estimators", "MultipleKernelRidgeCV"),
)


def _import(name):
    __import__(name)
    return sys.modules[name]


@pytest.mark.parametrize("mod_name", PUBLIC_MODULES)
def test_module_docstring(mod_name):
    mod = _import(mod_name)
    assert mod.__doc__ and len(mod.__doc__.strip()) > 40, (
        f"{mod_name} needs a real module docstring"
    )


@pytest.mark.parametrize(
    "mod_name,attr",
    [(m, a) for m, attrs in PUBLIC_CALLABLES.items() for a in attrs],
)
def test_public_callable_documented(mod_name, attr):
    obj = getattr(_import(mod_name), attr)
    assert obj.__doc__ and len(obj.__doc__.strip()) > 20, (
        f"{mod_name}.{attr} needs a real docstring"
    )


@pytest.mark.parametrize("mod_name,cls_name", PUBLIC_CLASSES)
def test_public_class_methods_documented(mod_name, cls_name):
    cls = getattr(_import(mod_name), cls_name)
    assert cls.__doc__ and len(cls.__doc__.strip()) > 20
    undocumented = [
        name
        for name, member in inspect.getmembers(cls)
        if not name.startswith("_")
        and (inspect.isfunction(member) or isinstance(member, property))
        and not (
            (member.fget.__doc__ if isinstance(member, property)
             else member.__doc__) or ""
        ).strip()
    ]
    assert not undocumented, (
        f"{cls_name} public members missing docstrings: {undocumented}"
    )


def test_tuning_module_doctest():
    import sys

    import repro.core.tune  # noqa: F401  (the package, not the function)

    tune_pkg = sys.modules["repro.core.tune"]
    res = doctest.testmod(tune_pkg, optionflags=doctest.ELLIPSIS, verbose=False)
    assert res.attempted > 0 and res.failed == 0


@pytest.mark.parametrize("doc", ["docs/tuning.md", "docs/solvers.md",
                                 "docs/serving.md", "docs/estimators.md",
                                 "docs/observability.md",
                                 "docs/distributed.md"])
def test_docs_quickstart_doctests(doc):
    res = doctest.testfile(
        str(ROOT / doc), module_relative=False,
        optionflags=doctest.ELLIPSIS | doctest.IGNORE_EXCEPTION_DETAIL,
    )
    assert res.attempted > 0, f"{doc} lost its quickstart doctest session"
    assert res.failed == 0, f"{doc} quickstart snippets failed"


def test_docs_exist_and_linked_from_readme():
    readme = (ROOT / "README.md").read_text()
    for page in ("architecture", "tuning", "solvers", "serving",
                 "estimators", "observability", "distributed"):
        assert (ROOT / "docs" / f"{page}.md").exists()
        assert f"docs/{page}.md" in readme, f"README must link docs/{page}.md"


def test_no_dead_markdown_links():
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    files = check_links.default_files(ROOT)
    assert len(files) >= 5  # README, DESIGN, 3 docs pages
    assert check_links.dead_links(files) == []
