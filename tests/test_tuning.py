"""Tuning subsystem: the tile-sharing (sigma, lam, fold) sweep must return
the SAME best config and CV scores as the naive per-candidate loop — locally
and through a 1-device mesh — while consuming far fewer kernel sweeps."""

import json
import runpy
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krr import KRRProblem
from repro.core.solver_api import TUNE_OPTIONS, tune
from repro.core.tune import apply_best
from repro.serving.krr_serve import make_krr_predict_fn_from_config

SIGMAS = (0.5, 2.0)
LAMS = (1e-3, 1e-1)
TUNE_KW = dict(sigmas=SIGMAS, lams=LAMS, folds=3, rank=32,
               max_iters=300, tol=1e-6, seed=0)


def _regression_problem(n=256, d=4, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    y = jnp.sin(2.0 * x[:, 0]) + 0.1 * x[:, 1]
    return KRRProblem(x=x, y=y, backend="xla")


def _onevsall_problem(n=240, d=4, classes=3, seed=0):
    from repro.data import synthetic

    x, y, _, _, _, _ = synthetic.krr_one_vs_all(seed, n, d, num_classes=classes)
    return KRRProblem(x=x, y=y, backend="xla")


def _assert_same_sweep(rs, rn, score_rtol=1e-3):
    assert rs.best["sigma"] == rn.best["sigma"]
    assert rs.best["lam_unscaled"] == rn.best["lam_unscaled"]
    assert len(rs.records) == len(rn.records)
    for a, b in zip(rs.records, rn.records):
        assert (a["sigma"], a["lam_unscaled"]) == (b["sigma"], b["lam_unscaled"])
        np.testing.assert_allclose(a["cv_mse"], b["cv_mse"], rtol=score_rtol)
        np.testing.assert_allclose(a["fold_mse"], b["fold_mse"], rtol=score_rtol)


def test_shared_matches_naive_regression():
    prob = _regression_problem()
    rs = tune(prob, strategy="shared", **TUNE_KW)
    rn = tune(prob, strategy="naive", **TUNE_KW)
    _assert_same_sweep(rs, rn)


def test_shared_matches_naive_one_vs_all():
    prob = _onevsall_problem()
    rs = tune(prob, strategy="shared", **TUNE_KW)
    rn = tune(prob, strategy="naive", **TUNE_KW)
    _assert_same_sweep(rs, rn)
    for a, b in zip(rs.records, rn.records):
        # one-vs-all candidates also carry top-1 CV accuracy
        assert 0.0 <= a["cv_acc"] <= 1.0
        np.testing.assert_allclose(a["cv_acc"], b["cv_acc"], atol=0.05)


def test_mesh_1device_matches_local():
    from repro.distributed.meshes import make_solver_mesh

    prob = _regression_problem()
    mesh = make_solver_mesh((1, 1))
    r_local = tune(prob, strategy="shared", **TUNE_KW)
    r_mesh = tune(prob, mesh=mesh, strategy="shared", **TUNE_KW)
    _assert_same_sweep(r_local, r_mesh)


def test_shared_saves_kernel_sweeps():
    # the acceptance claim at test scale: an s-sigma grid of l*k candidates
    # costs ~s stacked solves, not s*l*k independent ones
    prob = _regression_problem()
    kw = dict(sigmas=(0.5, 1.0, 2.0), lams=(1e-4, 1e-3, 1e-2, 1e-1),
              folds=4, rank=32, max_iters=200, tol=1e-5, seed=0)
    rs = tune(prob, strategy="shared", **kw)
    rn = tune(prob, strategy="naive", **kw)
    s = len(kw["sigmas"])
    iters = max(int(v) for v in rs.info["iters_by_sigma"].values())
    # shared: per sigma = sketch + warm-start matvec + iters + scoring sweep
    assert rs.sweeps <= s * (iters + 3) + 1e-6
    # and materially below the naive loop's measured consumption
    assert rs.sweeps < 0.5 * rn.sweeps


def test_warm_start_agrees_and_helps():
    prob = _regression_problem()
    r_ws = tune(prob, strategy="shared", warm_start=True, **TUNE_KW)
    r_cold = tune(prob, strategy="shared", warm_start=False, **TUNE_KW)
    _assert_same_sweep(r_ws, r_cold)
    it_ws = sum(int(v) for v in r_ws.info["iters_by_sigma"].values())
    it_cold = sum(int(v) for v in r_cold.info["iters_by_sigma"].values())
    assert it_ws <= it_cold  # the Woodbury start never costs iterations


def test_random_search_is_reproducible_grid_subset():
    prob = _regression_problem(n=128)
    kw = dict(sigmas=(0.5, 1.0, 2.0), lams=(1e-3, 1e-2, 1e-1), folds=2,
              rank=16, max_iters=100, tol=1e-4)
    r1 = tune(prob, search="random", num_samples=4, seed=7, **kw)
    r2 = tune(prob, search="random", num_samples=4, seed=7, **kw)
    assert len(r1.records) == 4
    grid = {(s, l) for s in kw["sigmas"] for l in kw["lams"]}
    assert {(r["sigma"], r["lam_unscaled"]) for r in r1.records} <= grid
    assert [r["cv_mse"] for r in r1.records] == [r["cv_mse"] for r in r2.records]


def test_tune_option_validation():
    prob = _regression_problem(n=64)
    with pytest.raises(ValueError, match="accepted"):
        tune(prob, bogus_option=3)
    with pytest.raises(ValueError, match="folds"):
        tune(prob, folds=1)
    with pytest.raises(ValueError, match="search"):
        tune(prob, search="bayes")
    with pytest.raises(ValueError, match="strategy"):
        tune(prob, strategy="magic")
    with pytest.raises(ValueError, match="positive"):
        tune(prob, sigmas=(0.0,))
    with pytest.raises(ValueError, match="num_samples"):
        tune(prob, search="grid", num_samples=4)
    assert set(TUNE_OPTIONS) >= {"sigmas", "lams", "folds", "search"}


def test_naive_strategy_rejects_multi_device_mesh():
    # the naive reference loop gathers whole folds replicated — reject it on
    # real meshes instead of silently defeating the sharding (1-device ok)
    import jax

    from repro.distributed.meshes import make_solver_mesh

    prob = _regression_problem(n=64)
    mesh1 = make_solver_mesh((1, 1))
    tune(prob, mesh=mesh1, strategy="naive", sigmas=(1.0,), lams=(1e-2,),
         folds=2, rank=8, max_iters=20, tol=1e-3)  # 1-device: allowed
    if jax.device_count() > 1:
        with pytest.raises(ValueError, match="single-device reference"):
            tune(prob, mesh=make_solver_mesh("auto"), strategy="naive",
                 sigmas=(1.0,), lams=(1e-2,))


def test_apply_best_and_config_serving_round_trip():
    prob = _regression_problem()
    res = tune(prob, strategy="shared", **TUNE_KW)
    best_prob = apply_best(prob, res)
    assert best_prob.sigma == res.best["sigma"]
    assert best_prob.lam_unscaled == res.best["lam_unscaled"]
    # refit + serve from the exported config == serving from the problem
    from repro.core.solver_api import solve

    out = solve(best_prob, "pcg-nystrom", rank=32, max_iters=200, tol=1e-6)
    cfg = json.loads(json.dumps(res.best))  # export/import round trip
    predict = make_krr_predict_fn_from_config(cfg, prob.x, out.w)
    xq = jnp.asarray(np.random.default_rng(1).standard_normal((17, 4)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(predict(xq)), np.asarray(best_prob.predict(out.w, xq)),
        rtol=1e-4, atol=1e-5,
    )


def test_refit_warm_start_from_cv_folds():
    # the winner's fold-averaged CV solution must (a) exist for the shared
    # strategy, (b) never cost the warm-started refit more iterations than
    # the zero start, (c) agree with it on the solution
    prob = _regression_problem()
    res = tune(prob, strategy="shared", **TUNE_KW)
    best_prob, w0 = apply_best(prob, res, with_w0=True)
    assert w0 is not None and w0.shape == (prob.n,)
    from repro.core.solver_api import solve

    cold = solve(best_prob, "pcg-nystrom", rank=32, max_iters=300, tol=1e-6)
    warm = solve(best_prob, "pcg-nystrom", rank=32, max_iters=300, tol=1e-6,
                 w0=w0)
    assert warm.info["iters"] <= cold.info["iters"]
    np.testing.assert_allclose(np.asarray(warm.w), np.asarray(cold.w),
                               rtol=1e-3, atol=1e-4)
    # back-compat: the plain call still returns just the problem
    assert apply_best(prob, res).sigma == best_prob.sigma
    # naive strategy has no stacked solution block to average
    rn = tune(prob, strategy="naive", sigmas=(0.5,), lams=(1e-2,), folds=2,
              rank=16, max_iters=60, tol=1e-4)
    assert rn.best_w0 is None


def test_loo_closed_form_matches_folds_n_cv():
    # tune(folds=n) IS leave-one-out; the direct solver's closed-form LOO
    # residuals from ONE Cholesky are its exact oracle
    from repro.core.direct import loo_mse, loo_residuals

    prob = _regression_problem(n=40, d=3)
    rs = tune(prob, sigmas=(1.0,), lams=(1e-2, 1e-1), folds=40, rank=24,
              max_iters=500, tol=1e-9, seed=0)
    for rec in rs.records:
        ref = loo_mse(KRRProblem(x=prob.x, y=prob.y, sigma=1.0,
                                 lam_unscaled=rec["lam_unscaled"],
                                 backend="xla"))
        np.testing.assert_allclose(rec["cv_mse"], ref, rtol=2e-3)
    # shape contract: (n,) residuals for a 1-D y, (n, t) for multi-head
    assert loo_residuals(prob).shape == (40,)


def test_tune_cli_smoke(tmp_path, capsys, monkeypatch):
    export = tmp_path / "best.json"
    monkeypatch.setattr(sys, "argv", [
        "krr_tune", "--n", "192", "--d", "3", "--n-test", "64",
        "--sigmas", "0.7,1.4", "--lams", "1e-3,1e-1", "--folds", "2",
        "--rank", "16", "--iters", "60", "--tol", "1e-4",
        "--method", "pcg-nystrom", "--refit-iters", "60",
        "--export", str(export),
    ])
    runpy.run_module("repro.launch.krr_tune", run_name="__main__")
    report = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert report["best"]["sigma"] in (0.7, 1.4)
    assert report["candidates"] == 4
    assert "test_rmse" in report["refit"]
    # the export is the serving-ready config PLUS the audit trail
    saved = json.loads(export.read_text())
    assert saved == {**report["best"], "trace": report["trace"]}
    assert len(saved["trace"]) == 4 and all(
        t["pruned_at_rung"] is None for t in saved["trace"]
    )


def test_tune_example_smoke(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", [
        "krr_tune.py", "--n", "160", "--classes", "3", "--n-test", "48",
        "--iters", "60",
    ])
    runpy.run_path("examples/krr_tune.py", run_name="__main__")
    out = capsys.readouterr().out
    assert "best" in out and "serve" in out

# ---------------------------------------------------------------------------
# PR 5: engine/policy split — policies, successive halving, sigma-continuation
# ---------------------------------------------------------------------------


def _halving_problem(n=256, d=4, seed=0):
    # noisy targets put the CV-optimal lam mid-grid, so the tiny lams are
    # slow LOSERS — the regime successive halving is built for
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    y = (jnp.sin(2.0 * x[:, 0]) + 0.1 * x[:, 1]
         + 0.1 * jnp.asarray(r.standard_normal(n).astype(np.float32)))
    return KRRProblem(x=x, y=y, backend="xla")


HALVING_KW = dict(sigmas=(0.5, 1.0, 2.0), lams=(1e-8, 1e-6, 1e-4, 1e-2),
                  folds=4, rank=32, max_iters=400, tol=1e-6, seed=0)


def test_policy_grid_reproduces_search_grid_exactly():
    prob = _regression_problem()
    r_legacy = tune(prob, search="grid", **TUNE_KW)
    r_policy = tune(prob, policy="grid", **TUNE_KW)
    assert r_legacy.best == r_policy.best
    assert r_legacy.records == r_policy.records
    assert r_legacy.sweeps == r_policy.sweeps
    np.testing.assert_array_equal(r_legacy.best_w0, r_policy.best_w0)


def test_policy_random_reproduces_search_random_exactly():
    prob = _regression_problem(n=128)
    kw = dict(sigmas=(0.5, 1.0, 2.0), lams=(1e-3, 1e-2, 1e-1), folds=2,
              rank=16, max_iters=100, tol=1e-4, seed=7)
    r_legacy = tune(prob, search="random", num_samples=4, **kw)
    r_policy = tune(prob, policy="random", num_samples=4, **kw)
    assert r_legacy.records == r_policy.records
    assert r_legacy.best == r_policy.best


def test_halving_beats_grid_at_equal_best_config():
    # the acceptance claim, SweepCounter-asserted: same best config as the
    # exhaustive grid, strictly fewer kernel sweeps
    prob = _halving_problem()
    rg = tune(prob, policy="grid", **HALVING_KW)
    rh = tune(prob, policy="halving", **HALVING_KW)
    assert rh.best["sigma"] == rg.best["sigma"]
    assert rh.best["lam_unscaled"] == rg.best["lam_unscaled"]
    assert rh.sweeps < rg.sweeps
    # pruning actually happened mid-solve, and the stacked solves ended
    # earlier than the grid's slowest-loser-bound iteration counts
    pruned = [t for t in rh.trace if t["pruned_at_rung"] is not None]
    assert pruned, "halving never pruned on the designed testbed"
    it_h = sum(int(v) for v in rh.info["iters_by_sigma"].values())
    it_g = sum(int(v) for v in rg.info["iters_by_sigma"].values())
    assert it_h < it_g
    # pruned candidates are marked in the records too
    assert any("pruned_at_rung" in r for r in rh.records)


def test_halving_never_prunes_the_running_best():
    prob = _halving_problem()
    rh = tune(prob, policy="halving", **HALVING_KW)
    # the returned best candidate must have survived to the end
    best_trace = [
        t for t in rh.trace
        if t["sigma"] == rh.best["sigma"]
        and t["lam_unscaled"] == rh.best["lam_unscaled"]
    ]
    assert len(best_trace) == 1 and best_trace[0]["pruned_at_rung"] is None
    # and best selection never returns a pruned candidate's stale score
    best_rec = [r for r in rh.records if r["cv_mse"] == rh.best["cv_mse"]][0]
    assert "pruned_at_rung" not in best_rec


def test_halving_trace_is_auditable():
    prob = _halving_problem()
    rh = tune(prob, policy="halving", **HALVING_KW)
    assert len(rh.trace) == len(rh.records) == rh.info["candidates"]
    for t, r in zip(rh.trace, rh.records):
        assert (t["sigma"], t["lam_unscaled"]) == (r["sigma"], r["lam_unscaled"])
        assert len(t["scores"]) == len(t["iters"]) >= 1
        assert t["scores"][-1] == r["cv_mse"]  # the final score closes the trail
        if t["pruned_at_rung"] is not None:
            # a pruned candidate stops accruing scores after its prune rung
            assert len(t["scores"]) == t["pruned_at_rung"] + 2
    # grid traces are the degenerate single-entry trail
    rg = tune(prob, policy="grid", **HALVING_KW)
    assert all(t["pruned_at_rung"] is None and len(t["scores"]) == 1
               for t in rg.trace)


def test_halving_eta_validation_and_naive_rejection():
    prob = _regression_problem(n=64)
    with pytest.raises(ValueError, match="halving_eta"):
        tune(prob, policy="halving", halving_eta=1.0)
    with pytest.raises(ValueError, match="strategy='shared'"):
        tune(prob, policy="halving", strategy="naive")
    with pytest.raises(ValueError, match="policy"):
        tune(prob, policy="bogus")
    with pytest.raises(ValueError, match="num_samples"):
        tune(prob, policy="halving", num_samples=3)
    with pytest.raises(ValueError, match="conflicting"):
        tune(prob, search="random", policy="halving")
    # the conflict check also covers SearchPolicy INSTANCES
    from repro.core.tune import GridSearch

    with pytest.raises(ValueError, match="conflicting"):
        tune(prob, search="random", num_samples=2, policy=GridSearch())
    with pytest.raises(ValueError, match="sigma_continuation"):
        tune(prob, strategy="naive", sigma_continuation=True)


def test_sigma_continuation_reduces_total_iterations():
    # acceptance: on a >= 3-sigma grid, seeding each sigma group from the
    # previous one cuts total stacked-CG iterations vs cold starts
    prob = _halving_problem()
    kw = dict(sigmas=(0.8, 1.0, 1.3, 1.6), lams=(1e-4, 1e-3, 1e-2), folds=3,
              rank=32, max_iters=400, tol=1e-6, seed=0)
    r_cont = tune(prob, sigma_continuation=True, warm_start=False, **kw)
    r_cold = tune(prob, sigma_continuation=False, warm_start=False, **kw)
    tot = lambda r: sum(int(v) for v in r.info["iters_by_sigma"].values())
    assert tot(r_cont) < tot(r_cold)
    # and the search outcome is unchanged
    assert r_cont.best["sigma"] == r_cold.best["sigma"]
    assert r_cont.best["lam_unscaled"] == r_cold.best["lam_unscaled"]
    assert r_cont.info["sigma_continuation"] is True


def test_halving_runs_unchanged_over_1device_mesh():
    from repro.distributed.meshes import make_solver_mesh

    prob = _halving_problem(n=160)
    kw = dict(HALVING_KW, max_iters=200)
    r_local = tune(prob, policy="halving", sigma_continuation=True, **kw)
    r_mesh = tune(prob, mesh=make_solver_mesh((1, 1)), policy="halving",
                  sigma_continuation=True, **kw)
    assert r_local.best["sigma"] == r_mesh.best["sigma"]
    assert r_local.best["lam_unscaled"] == r_mesh.best["lam_unscaled"]
    # identical prune decisions, and identical scores for the SURVIVORS —
    # pruned candidates' final scores are partially-converged by design and
    # numerically sensitive between the local and sharded matmul paths
    prunes_l = [t["pruned_at_rung"] for t in r_local.trace]
    prunes_m = [t["pruned_at_rung"] for t in r_mesh.trace]
    assert prunes_l == prunes_m
    for a, b, pr in zip(r_local.records, r_mesh.records, prunes_l):
        if pr is None:
            np.testing.assert_allclose(a["cv_mse"], b["cv_mse"], rtol=1e-3)


def test_multikernel_halving_prunes_weight_candidates():
    from repro.core.tune import tune_multikernel

    x = jnp.asarray(np.random.default_rng(0).standard_normal((144, 4)).astype(np.float32))
    y = (jnp.sin(2.0 * x[:, 0]) + 0.2 * jnp.sign(x[:, 1])
         + 0.3 * jnp.asarray(np.random.default_rng(1).standard_normal(144).astype(np.float32)))
    prob = KRRProblem(x=x, y=y, backend="xla")
    kw = dict(kernels=("rbf", "laplacian"), sigmas=(0.7, 1.5),
              lams=(1e-7, 1e-3, 1e-1), folds=3, n_weight_samples=3,
              rank=24, max_iters=400, tol=1e-6, seed=0)
    rr = tune_multikernel(prob, **kw)
    rh = tune_multikernel(prob, policy="halving", **kw)
    assert rh.search == "halving"
    assert rh.sweeps < rr.sweeps
    assert rh.best["sigma"] == rr.best["sigma"]
    assert rh.best["lam_unscaled"] == rr.best["lam_unscaled"]
    assert rh.best["weights"] == rr.best["weights"]
    assert any(t["pruned_at_rung"] is not None for t in rh.trace)
    with pytest.raises(ValueError, match="weight axis"):
        tune_multikernel(prob, policy="grid", **{k: v for k, v in kw.items()})


def test_custom_policy_object_drives_the_engine():
    from repro.core.tune import SuccessiveHalving

    prob = _halving_problem(n=128)
    pol = SuccessiveHalving(eta=2.0)
    res = tune(prob, policy=pol, sigmas=(0.5, 1.0), lams=(1e-7, 1e-4, 1e-2),
               folds=3, rank=16, max_iters=200, tol=1e-6, seed=0)
    assert res.search == "halving"
    assert res.info["policy"] == "halving"


def test_tuning_shim_backcompat():
    # core/tuning.py is now a thin shim over repro.core.tune — old imports
    # keep working
    import repro.core.tuning as shim

    prob = _regression_problem(n=64)
    res = shim.tune(prob, sigmas=(1.0,), lams=(1e-2,), folds=2, rank=8,
                    max_iters=30, tol=1e-3)
    assert isinstance(res, shim.TuneResult)
    assert shim.apply_best(prob, res).sigma == 1.0
    from repro.core.tune import TuneResult as pkg_result

    assert shim.TuneResult is pkg_result
