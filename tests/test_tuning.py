"""Tuning subsystem: the tile-sharing (sigma, lam, fold) sweep must return
the SAME best config and CV scores as the naive per-candidate loop — locally
and through a 1-device mesh — while consuming far fewer kernel sweeps."""

import json
import runpy
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krr import KRRProblem
from repro.core.solver_api import TUNE_OPTIONS, tune
from repro.core.tuning import apply_best
from repro.serving.krr_serve import make_krr_predict_fn_from_config

SIGMAS = (0.5, 2.0)
LAMS = (1e-3, 1e-1)
TUNE_KW = dict(sigmas=SIGMAS, lams=LAMS, folds=3, rank=32,
               max_iters=300, tol=1e-6, seed=0)


def _regression_problem(n=256, d=4, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    y = jnp.sin(2.0 * x[:, 0]) + 0.1 * x[:, 1]
    return KRRProblem(x=x, y=y, backend="xla")


def _onevsall_problem(n=240, d=4, classes=3, seed=0):
    from repro.data import synthetic

    x, y, _, _, _, _ = synthetic.krr_one_vs_all(seed, n, d, num_classes=classes)
    return KRRProblem(x=x, y=y, backend="xla")


def _assert_same_sweep(rs, rn, score_rtol=1e-3):
    assert rs.best["sigma"] == rn.best["sigma"]
    assert rs.best["lam_unscaled"] == rn.best["lam_unscaled"]
    assert len(rs.records) == len(rn.records)
    for a, b in zip(rs.records, rn.records):
        assert (a["sigma"], a["lam_unscaled"]) == (b["sigma"], b["lam_unscaled"])
        np.testing.assert_allclose(a["cv_mse"], b["cv_mse"], rtol=score_rtol)
        np.testing.assert_allclose(a["fold_mse"], b["fold_mse"], rtol=score_rtol)


def test_shared_matches_naive_regression():
    prob = _regression_problem()
    rs = tune(prob, strategy="shared", **TUNE_KW)
    rn = tune(prob, strategy="naive", **TUNE_KW)
    _assert_same_sweep(rs, rn)


def test_shared_matches_naive_one_vs_all():
    prob = _onevsall_problem()
    rs = tune(prob, strategy="shared", **TUNE_KW)
    rn = tune(prob, strategy="naive", **TUNE_KW)
    _assert_same_sweep(rs, rn)
    for a, b in zip(rs.records, rn.records):
        # one-vs-all candidates also carry top-1 CV accuracy
        assert 0.0 <= a["cv_acc"] <= 1.0
        np.testing.assert_allclose(a["cv_acc"], b["cv_acc"], atol=0.05)


def test_mesh_1device_matches_local():
    from repro.distributed.meshes import make_solver_mesh

    prob = _regression_problem()
    mesh = make_solver_mesh((1, 1))
    r_local = tune(prob, strategy="shared", **TUNE_KW)
    r_mesh = tune(prob, mesh=mesh, strategy="shared", **TUNE_KW)
    _assert_same_sweep(r_local, r_mesh)


def test_shared_saves_kernel_sweeps():
    # the acceptance claim at test scale: an s-sigma grid of l*k candidates
    # costs ~s stacked solves, not s*l*k independent ones
    prob = _regression_problem()
    kw = dict(sigmas=(0.5, 1.0, 2.0), lams=(1e-4, 1e-3, 1e-2, 1e-1),
              folds=4, rank=32, max_iters=200, tol=1e-5, seed=0)
    rs = tune(prob, strategy="shared", **kw)
    rn = tune(prob, strategy="naive", **kw)
    s = len(kw["sigmas"])
    iters = max(int(v) for v in rs.info["iters_by_sigma"].values())
    # shared: per sigma = sketch + warm-start matvec + iters + scoring sweep
    assert rs.sweeps <= s * (iters + 3) + 1e-6
    # and materially below the naive loop's measured consumption
    assert rs.sweeps < 0.5 * rn.sweeps


def test_warm_start_agrees_and_helps():
    prob = _regression_problem()
    r_ws = tune(prob, strategy="shared", warm_start=True, **TUNE_KW)
    r_cold = tune(prob, strategy="shared", warm_start=False, **TUNE_KW)
    _assert_same_sweep(r_ws, r_cold)
    it_ws = sum(int(v) for v in r_ws.info["iters_by_sigma"].values())
    it_cold = sum(int(v) for v in r_cold.info["iters_by_sigma"].values())
    assert it_ws <= it_cold  # the Woodbury start never costs iterations


def test_random_search_is_reproducible_grid_subset():
    prob = _regression_problem(n=128)
    kw = dict(sigmas=(0.5, 1.0, 2.0), lams=(1e-3, 1e-2, 1e-1), folds=2,
              rank=16, max_iters=100, tol=1e-4)
    r1 = tune(prob, search="random", num_samples=4, seed=7, **kw)
    r2 = tune(prob, search="random", num_samples=4, seed=7, **kw)
    assert len(r1.records) == 4
    grid = {(s, l) for s in kw["sigmas"] for l in kw["lams"]}
    assert {(r["sigma"], r["lam_unscaled"]) for r in r1.records} <= grid
    assert [r["cv_mse"] for r in r1.records] == [r["cv_mse"] for r in r2.records]


def test_tune_option_validation():
    prob = _regression_problem(n=64)
    with pytest.raises(ValueError, match="accepted"):
        tune(prob, bogus_option=3)
    with pytest.raises(ValueError, match="folds"):
        tune(prob, folds=1)
    with pytest.raises(ValueError, match="search"):
        tune(prob, search="bayes")
    with pytest.raises(ValueError, match="strategy"):
        tune(prob, strategy="magic")
    with pytest.raises(ValueError, match="positive"):
        tune(prob, sigmas=(0.0,))
    with pytest.raises(ValueError, match="num_samples"):
        tune(prob, search="grid", num_samples=4)
    assert set(TUNE_OPTIONS) >= {"sigmas", "lams", "folds", "search"}


def test_naive_strategy_rejects_multi_device_mesh():
    # the naive reference loop gathers whole folds replicated — reject it on
    # real meshes instead of silently defeating the sharding (1-device ok)
    import jax

    from repro.distributed.meshes import make_solver_mesh

    prob = _regression_problem(n=64)
    mesh1 = make_solver_mesh((1, 1))
    tune(prob, mesh=mesh1, strategy="naive", sigmas=(1.0,), lams=(1e-2,),
         folds=2, rank=8, max_iters=20, tol=1e-3)  # 1-device: allowed
    if jax.device_count() > 1:
        with pytest.raises(ValueError, match="single-device reference"):
            tune(prob, mesh=make_solver_mesh("auto"), strategy="naive",
                 sigmas=(1.0,), lams=(1e-2,))


def test_apply_best_and_config_serving_round_trip():
    prob = _regression_problem()
    res = tune(prob, strategy="shared", **TUNE_KW)
    best_prob = apply_best(prob, res)
    assert best_prob.sigma == res.best["sigma"]
    assert best_prob.lam_unscaled == res.best["lam_unscaled"]
    # refit + serve from the exported config == serving from the problem
    from repro.core.solver_api import solve

    out = solve(best_prob, "pcg-nystrom", rank=32, max_iters=200, tol=1e-6)
    cfg = json.loads(json.dumps(res.best))  # export/import round trip
    predict = make_krr_predict_fn_from_config(cfg, prob.x, out.w)
    xq = jnp.asarray(np.random.default_rng(1).standard_normal((17, 4)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(predict(xq)), np.asarray(best_prob.predict(out.w, xq)),
        rtol=1e-4, atol=1e-5,
    )


def test_refit_warm_start_from_cv_folds():
    # the winner's fold-averaged CV solution must (a) exist for the shared
    # strategy, (b) never cost the warm-started refit more iterations than
    # the zero start, (c) agree with it on the solution
    prob = _regression_problem()
    res = tune(prob, strategy="shared", **TUNE_KW)
    best_prob, w0 = apply_best(prob, res, with_w0=True)
    assert w0 is not None and w0.shape == (prob.n,)
    from repro.core.solver_api import solve

    cold = solve(best_prob, "pcg-nystrom", rank=32, max_iters=300, tol=1e-6)
    warm = solve(best_prob, "pcg-nystrom", rank=32, max_iters=300, tol=1e-6,
                 w0=w0)
    assert warm.info["iters"] <= cold.info["iters"]
    np.testing.assert_allclose(np.asarray(warm.w), np.asarray(cold.w),
                               rtol=1e-3, atol=1e-4)
    # back-compat: the plain call still returns just the problem
    assert apply_best(prob, res).sigma == best_prob.sigma
    # naive strategy has no stacked solution block to average
    rn = tune(prob, strategy="naive", sigmas=(0.5,), lams=(1e-2,), folds=2,
              rank=16, max_iters=60, tol=1e-4)
    assert rn.best_w0 is None


def test_loo_closed_form_matches_folds_n_cv():
    # tune(folds=n) IS leave-one-out; the direct solver's closed-form LOO
    # residuals from ONE Cholesky are its exact oracle
    from repro.core.direct import loo_mse, loo_residuals

    prob = _regression_problem(n=40, d=3)
    rs = tune(prob, sigmas=(1.0,), lams=(1e-2, 1e-1), folds=40, rank=24,
              max_iters=500, tol=1e-9, seed=0)
    for rec in rs.records:
        ref = loo_mse(KRRProblem(x=prob.x, y=prob.y, sigma=1.0,
                                 lam_unscaled=rec["lam_unscaled"],
                                 backend="xla"))
        np.testing.assert_allclose(rec["cv_mse"], ref, rtol=2e-3)
    # shape contract: (n,) residuals for a 1-D y, (n, t) for multi-head
    assert loo_residuals(prob).shape == (40,)


def test_tune_cli_smoke(tmp_path, capsys, monkeypatch):
    export = tmp_path / "best.json"
    monkeypatch.setattr(sys, "argv", [
        "krr_tune", "--n", "192", "--d", "3", "--n-test", "64",
        "--sigmas", "0.7,1.4", "--lams", "1e-3,1e-1", "--folds", "2",
        "--rank", "16", "--iters", "60", "--tol", "1e-4",
        "--method", "pcg-nystrom", "--refit-iters", "60",
        "--export", str(export),
    ])
    runpy.run_module("repro.launch.krr_tune", run_name="__main__")
    report = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert report["best"]["sigma"] in (0.7, 1.4)
    assert report["candidates"] == 4
    assert "test_rmse" in report["refit"]
    saved = json.loads(export.read_text())
    assert saved == report["best"]


def test_tune_example_smoke(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", [
        "krr_tune.py", "--n", "160", "--classes", "3", "--n-test", "48",
        "--iters", "60",
    ])
    runpy.run_path("examples/krr_tune.py", run_name="__main__")
    out = capsys.readouterr().out
    assert "best" in out and "serve" in out
