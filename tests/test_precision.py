"""The mixed-precision policy end to end: bf16 kernel tiles with f32
accumulation must converge like f32 (within the bf16 noise floor), the f32
path must stay bit-identical to the pre-policy behavior, and the RFF
preconditioner must be a usable stand-in for Nystrom on rbf problems.

The parity tolerances encode the measured physics of the policy: bf16 tile
noise is amplified by the problem's conditioning (roughly ||K||/lam), so each
check runs at a tolerance ABOVE that floor — PCG's recursive residual rides
through the noise (~1.1x iterations at tol=1e-5 on the testbed) while
ASkotch's block-coordinate updates track f32 step for step down to the floor
and stall below it (solver_api warns via BF16_TOL_FLOOR for targets bf16
cannot express at all)."""

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krr import KRRProblem
from repro.core.solver_api import BF16_TOL_FLOOR, solve, tune
from repro.kernels import ops


def _problem(n=300, d=5, seed=0, **kw):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(r.standard_normal((n,)).astype(np.float32))
    kw.setdefault("backend", "xla")
    return KRRProblem(x=x, y=y, sigma=1.0, **kw)


# ---------------------------------------------------------------------------
# solver parity: bf16 reaches the same tolerance within <= 1.25x iterations
# ---------------------------------------------------------------------------


def test_pcg_bf16_parity():
    p32 = _problem(lam_unscaled=1e-4, precision="f32")
    p16 = dataclasses.replace(p32, precision="bf16")
    o32 = solve(p32, "pcg-nystrom", max_iters=300, tol=1e-5, rank=100)
    o16 = solve(p16, "pcg-nystrom", max_iters=300, tol=1e-5, rank=100)
    assert o32.info["converged"] and o16.info["converged"]
    assert o16.info["iters"] <= 1.25 * o32.info["iters"]
    assert o16.w.dtype == jnp.float32  # solution stays f32 by construction


def test_askotch_bf16_parity():
    # tol sits above the bf16 noise floor for this conditioning (lam=1e-2);
    # there ASkotch-bf16 tracks f32 step for step.
    p32 = _problem(lam_unscaled=1e-2, precision="f32")
    p16 = dataclasses.replace(p32, precision="bf16")
    o32 = solve(p32, "askotch", max_iters=1000, tol=5e-3, rank=50)
    o16 = solve(p16, "askotch", max_iters=1000, tol=5e-3, rank=50)
    assert o32.info["converged"] and o16.info["converged"]
    assert o16.info["iters"] <= 1.25 * o32.info["iters"]


def test_solve_precision_override_and_validation():
    p = _problem(lam_unscaled=1e-3)
    out = solve(p, "pcg-nystrom", max_iters=200, tol=1e-4, rank=64,
                precision="bf16")
    assert out.info["converged"]
    with pytest.raises(ValueError, match="unknown precision"):
        solve(p, "pcg-nystrom", precision="f16")


def test_bf16_machine_precision_target_warns():
    p = _problem(lam_unscaled=1e-3, precision="bf16")
    with pytest.warns(UserWarning, match="bf16"):
        solve(p, "pcg-nystrom", max_iters=5, tol=BF16_TOL_FLOOR / 10, rank=32)
    # f32 solves at the same tol stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        solve(dataclasses.replace(p, precision="f32"), "pcg-nystrom",
              max_iters=5, tol=BF16_TOL_FLOOR / 10, rank=32)


# ---------------------------------------------------------------------------
# f32 is bit-identical: the policy only exists when asked for
# ---------------------------------------------------------------------------


def test_f32_path_bit_identical():
    r = np.random.default_rng(1)
    a = r.standard_normal((37, 6)).astype(np.float32)
    b = r.standard_normal((71, 6)).astype(np.float32)
    v = r.standard_normal((71, 2)).astype(np.float32)
    for backend in ("xla", "interpret"):
        base = np.asarray(
            ops.kernel_matvec(a, b, v, sigma=1.3, backend=backend)
        )
        explicit = np.asarray(
            ops.kernel_matvec(a, b, v, sigma=1.3, backend=backend,
                              precision="f32")
        )
        np.testing.assert_array_equal(base, explicit)


# ---------------------------------------------------------------------------
# tune(): precision threads through the sweep and into the best-config export
# ---------------------------------------------------------------------------


def test_tune_bf16_agrees_with_f32_and_exports_precision():
    kw = dict(sigmas=(0.5, 2.0), lams=(1e-3, 1e-1), folds=3, rank=32,
              max_iters=200, tol=1e-4, seed=0)
    p = _problem(n=200)
    r32 = tune(p, **kw)
    r16 = tune(p, precision="bf16", **kw)
    assert r16.best["precision"] == "bf16"
    assert r32.best["precision"] == "f32"
    assert r16.best["sigma"] == r32.best["sigma"]
    assert r16.best["lam_unscaled"] == r32.best["lam_unscaled"]
    for a, b in zip(r16.records, r32.records):
        np.testing.assert_allclose(a["cv_mse"], b["cv_mse"], rtol=0.05)


def test_mesh_1device_bf16_parity():
    from repro.distributed.meshes import make_solver_mesh

    p32 = _problem(lam_unscaled=1e-4, precision="f32")
    p16 = dataclasses.replace(p32, precision="bf16")
    mesh = make_solver_mesh((1, 1))
    o32 = solve(p32, "pcg-nystrom", mesh=mesh, max_iters=300, tol=1e-5,
                rank=100)
    o16 = solve(p16, "pcg-nystrom", mesh=mesh, max_iters=300, tol=1e-5,
                rank=100)
    assert o32.info["converged"] and o16.info["converged"]
    assert o16.info["iters"] <= 1.25 * o32.info["iters"]


# ---------------------------------------------------------------------------
# serving honors the exported precision
# ---------------------------------------------------------------------------


def test_serving_reconstructs_bf16_policy():
    from repro.serving.krr_serve import make_krr_predict_fn_from_config

    p = _problem(lam_unscaled=1e-3, precision="bf16")
    out = solve(p, "pcg-nystrom", max_iters=200, tol=1e-4, rank=64)
    cfg = {"kernel": "rbf", "sigma": 1.0, "backend": "xla",
           "precision": "bf16"}
    predict = make_krr_predict_fn_from_config(cfg, p.x, out.w)
    scores = predict(p.x[:16])
    assert scores.shape == (16,) and scores.dtype == jnp.float32
    # bf16 scoring agrees with f32 scoring to tile precision
    f32_scores = make_krr_predict_fn_from_config(
        {**cfg, "precision": "f32"}, p.x, out.w
    )(p.x[:16])
    np.testing.assert_allclose(np.asarray(scores), np.asarray(f32_scores),
                               rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# RFF preconditioner: Nystrom stand-in on every shift-invariant kernel with
# an implemented spectral measure (Gaussian / Cauchy / Student-t), hard
# error elsewhere
# ---------------------------------------------------------------------------


def test_rff_within_1p5x_of_nystrom():
    p = _problem(lam_unscaled=1e-4)
    on = solve(p, "pcg-nystrom", max_iters=300, tol=1e-5, rank=100)
    orf = solve(p, "pcg-rff", max_iters=300, tol=1e-5, rank=100)
    assert on.info["converged"] and orf.info["converged"]
    assert orf.info["iters"] <= 1.5 * on.info["iters"]


@pytest.mark.parametrize("kernel", ["laplacian", "matern52"])
def test_rff_spectral_measures_within_1p5x_of_nystrom(kernel):
    """The Cauchy (laplacian) and Student-t df=5 (matern52) spectral
    measures must precondition like a same-rank Nystrom sketch — the
    heavier-tailed frequency draws are absorbed by the oversampled-SVD
    truncation."""
    p = dataclasses.replace(
        _problem(n=500, lam_unscaled=1e-4, kernel=kernel), sigma=2.0
    )
    on = solve(p, "pcg-nystrom", max_iters=400, tol=1e-5, rank=60, seed=0)
    orf = solve(p, "pcg-rff", max_iters=400, tol=1e-5, rank=60, seed=0)
    assert on.info["converged"] and orf.info["converged"]
    assert orf.info["iters"] <= 1.5 * on.info["iters"]


def test_rff_feature_gram_approximates_kernel():
    """E[Z Z^T] = K for each implemented measure: at a generous feature
    count the Monte-Carlo Gram must sit near the exact kernel block."""
    import jax

    from repro.core.rff import RFF_KERNELS, rff_features

    r = np.random.default_rng(3)
    x = jnp.asarray(r.standard_normal((40, 4)).astype(np.float32))
    for kern in RFF_KERNELS:
        z = rff_features(jax.random.PRNGKey(0), x, 8192, 2.0, kernel=kern)
        k_exact = np.asarray(ops.kernel_block(x, x, kernel=kern, sigma=2.0))
        err = np.abs(np.asarray(z @ z.T) - k_exact).max()
        assert err < 0.08, (kern, err)


def test_rff_oversampling_beats_exact_rank():
    """Truncating an oversampled feature SVD must not be worse than using an
    exactly-rank-r feature set (whose noisy eigenvalue tail poisons the
    Woodbury damping)."""
    import jax

    from repro.core.blocked_cg import blocked_cg
    from repro.core.operator import as_multirhs
    from repro.core.rff import rff_factors

    p = _problem(lam_unscaled=1e-4)
    key = jax.random.PRNGKey(0)
    lam = jnp.float32(p.lam)
    matvec = jax.jit(p.k_lam_matvec)
    y, _ = as_multirhs(p.y)
    iters = {}
    for c in (1, 4):
        f = rff_factors(key, p.x, 100, 1.0, oversample=c)
        assert f.u.shape == (300, 100) and f.lam.shape == (100,)
        rho = lam + f.lam[-1]
        coeff = (f.lam[-1] + rho) / (f.lam + rho)

        def pinv(v, f=f, coeff=coeff):
            utv = f.u.T @ v
            return f.u @ (utv * coeff[:, None]) + (v - f.u @ utv)

        res = blocked_cg(matvec, y, jax.jit(pinv), max_iters=300, tol=1e-5)
        iters[c] = res.iters
    assert iters[4] <= iters[1]


def test_rff_rejects_non_shift_invariant():
    # linear has no shift-invariant spectral measure — still a hard error
    p = _problem(kernel="linear", lam_unscaled=1e-3)
    with pytest.raises(ValueError, match="shift-invariant"):
        solve(p, "pcg-rff", max_iters=10, rank=32)
