"""Sharded/dense parity: ShardedKernelOperator vs the single-device
KernelOperator, all three kernels, 1-D and (n, t) RHS.

The mesh adapts to the process' device count: (2, 2) under the
distributed-smoke CI job (XLA_FLAGS=--xla_force_host_platform_device_count=4),
degrading to (2, 1) / (1, 1) in a plain pytest run — size-1 axes make every
collective a no-op, so the SAME code paths run everywhere (the 1-device
fallback satellite) and genuinely multi-device under the smoke job.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operator import KernelOperator
from repro.distributed.jax_compat import make_mesh
from repro.distributed.sharded_operator import ShardedKernelOperator

N, D, T, B = 64, 5, 4, 12
TOL = 1e-5  # relative error floor from f32 reduction-order differences
KERNELS = ("rbf", "laplacian", "matern52")


def _mesh_shape():
    nd = len(jax.devices())
    if nd >= 4:
        return (2, 2)
    if nd >= 2:
        return (2, 1)
    return (1, 1)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(_mesh_shape(), ("data", "model"))


@pytest.fixture(scope="module")
def data():
    # module-scoped: owns its generator (the shared rng fixture is
    # function-scoped by design — see tests/conftest.py)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))
    v1 = jnp.asarray(rng.standard_normal((N,)).astype(np.float32))
    vt = jnp.asarray(rng.standard_normal((N, T)).astype(np.float32))
    a = jnp.asarray(rng.standard_normal((B, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, B))
    return x, v1, vt, a, idx


def _ops(mesh, x, kernel):
    op = KernelOperator(x=x, kernel=kernel, sigma=1.5, backend="xla")
    sop = ShardedKernelOperator.bind(mesh, x, kernel=kernel, sigma=1.5,
                                     backend="xla")
    return op, sop


def _rel(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-30)


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("ndim", [1, 2])
def test_matvec_parity(mesh, data, kernel, ndim):
    x, v1, vt, _, _ = data
    v = v1 if ndim == 1 else vt
    op, sop = _ops(mesh, x, kernel)
    v_sh = jax.device_put(v, sop.sharding(ndim))
    got = sop.matvec(v_sh)
    assert got.shape == v.shape
    assert _rel(got, op.matvec(v)) < TOL


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("ndim", [1, 2])
def test_row_block_matvec_parity(mesh, data, kernel, ndim):
    x, v1, vt, a, _ = data
    v = v1 if ndim == 1 else vt
    op, sop = _ops(mesh, x, kernel)
    v_sh = jax.device_put(v, sop.sharding(ndim))
    got = sop.row_block_matvec(a, v_sh)
    assert got.shape == (B,) + v.shape[1:]
    assert _rel(got, op.row_block_matvec(a, v)) < TOL


@pytest.mark.parametrize("kernel", KERNELS)
def test_block_idx_parity(mesh, data, kernel):
    x, _, _, _, idx = data
    op, sop = _ops(mesh, x, kernel)
    assert _rel(sop.block_idx(idx), op.block_idx(idx)) < TOL


@pytest.mark.parametrize("kernel", KERNELS)
def test_block_parity(mesh, data, kernel):
    x, _, _, a, _ = data
    op, sop = _ops(mesh, x, kernel)
    assert _rel(sop.block(a, x[:16]), op.block(a, x[:16])) < TOL


def test_gather_rows_packed(mesh, data):
    """ONE packed psum moves x rows and every extra together."""
    x, v1, vt, _, idx = data
    _, sop = _ops(mesh, x, "rbf")
    v1_sh = jax.device_put(v1, sop.sharding(1))
    vt_sh = jax.device_put(vt, sop.sharding(2))
    (xb, v1b, vtb), owned = sop.gather_rows(idx, v1_sh, vt_sh)
    np.testing.assert_allclose(np.asarray(xb), np.asarray(x[idx]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v1b), np.asarray(v1[idx]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vtb), np.asarray(vt[idx]), rtol=1e-6)
    # each sampled row is owned by exactly one row shard
    per_shard = np.asarray(owned).reshape(sop.n_row_shards, B)
    np.testing.assert_allclose(per_shard.sum(axis=0), np.ones(B))


def test_restrict_returns_replicated_operator(mesh, data):
    x, _, _, _, idx = data
    op, sop = _ops(mesh, x, "rbf")
    rop = sop.restrict(idx)
    assert isinstance(rop, KernelOperator)
    assert _rel(rop.block(rop.x), op.restrict(idx).block(np.asarray(x)[idx])) < TOL


@pytest.mark.parametrize("ndim", [1, 2])
def test_k_lam_matvec_and_sketch(mesh, data, ndim):
    x, v1, vt, _, _ = data
    v = v1 if ndim == 1 else vt
    op, sop = _ops(mesh, x, "rbf")
    v_sh = jax.device_put(v, sop.sharding(ndim))
    assert _rel(sop.k_lam_matvec(v_sh, 0.5), op.k_lam_matvec(v, 0.5)) < TOL
    assert float(sop.trace_est()) == float(op.trace_est()) == N


def test_with_points_and_divisibility_error(mesh, data):
    x, _, _, _, _ = data
    _, sop = _ops(mesh, x, "rbf")
    sub = sop.with_points(x[: sop.n_row_shards * 8])
    assert sub.n == sop.n_row_shards * 8
    if sop.n_row_shards > 1:
        with pytest.raises(ValueError, match="shard evenly"):
            sop.with_points(x[: sop.n_row_shards * 8 + 1])


def test_unbound_operator_errors(mesh):
    sop = ShardedKernelOperator(mesh=mesh)
    with pytest.raises(ValueError, match="unbound"):
        sop.matvec(jnp.zeros((8,)))


def test_serving_sharded_predict_parity(mesh, data):
    """serving/krr_serve drives the same closure over the sharded operator."""
    from repro.serving.krr_serve import (
        make_krr_predict_fn,
        make_sharded_krr_predict_fn,
    )

    x, _, vt, a, _ = data
    op, _ = _ops(mesh, x, "rbf")
    ref = make_krr_predict_fn(op, vt)(a)
    got = make_sharded_krr_predict_fn(mesh, x, vt, kernel="rbf", sigma=1.5,
                                      backend="xla")(a)
    assert got.shape == (B, T)
    assert _rel(got, ref) < TOL
    # empty request stays shape-correct without tracing a bucket
    empty = make_sharded_krr_predict_fn(mesh, x, vt, kernel="rbf", sigma=1.5,
                                        backend="xla")(a[:0])
    assert empty.shape == (0, T)
