"""Training substrate: optimizer math vs numpy references, grad-accumulation
equivalence, schedules, checkpoint roundtrip/crash-consistency/elastic
restore, data determinism, serving generate."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.base import get_reduced_config
from repro.data import synthetic
from repro.data.pipeline import LMDataPipeline
from repro.models.model_api import get_model, init_params
from repro.serving.serve_step import greedy_generate
from repro.training.optimizers import adafactor, adamw, global_norm, make_optimizer, sgdm
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adamw_matches_reference(rng):
    p0 = rng.standard_normal((4, 6)).astype(np.float32)
    g = rng.standard_normal((4, 6)).astype(np.float32)
    opt = adamw(0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray(p0)}
    state = opt.init(params)
    new_params, _, _ = opt.update({"w": jnp.asarray(g)}, state, params, jnp.int32(0))
    # step 0 reference
    m = 0.1 * g / (1 - 0.9)
    v = 0.01 * g * g / (1 - 0.99)
    want = p0 - 0.1 * (m / (np.sqrt(v) + 1e-8))
    np.testing.assert_allclose(np.asarray(new_params["w"]), want, rtol=1e-5)


def test_adamw_weight_decay_mask():
    params = {"w": jnp.ones((3, 3)), "ln_scale": jnp.ones((3,))}
    opt = adamw(0.1, weight_decay=0.5, clip_norm=1e9)
    state = opt.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_params, _, _ = opt.update(zero_g, state, params, jnp.int32(0))
    assert float(jnp.abs(new_params["w"] - 1).max()) > 0  # decayed
    np.testing.assert_allclose(np.asarray(new_params["ln_scale"]), 1.0)  # masked


def test_adafactor_factored_state_and_descent(rng):
    # stacked (L, n, m) leaf exercises the per-layer lax.map path
    params = {"w": jnp.asarray(rng.standard_normal((6, 32, 16)).astype(np.float32))}
    opt = adafactor(0.05)
    state = opt.init(params)
    assert state["w"]["r"].shape == (6, 32) and state["w"]["c"].shape == (6, 16)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    p = params
    prev = float(loss(p))
    for i in range(5):
        g = jax.grad(loss)(p)
        p, state, _ = opt.update(g, state, p, jnp.int32(i))
    assert float(loss(p)) < prev


def test_sgdm_descent(rng):
    params = {"w": jnp.asarray(rng.standard_normal((8,)).astype(np.float32))}
    opt = sgdm(0.1, momentum=0.9)
    state = opt.init(params)
    for i in range(10):
        g = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = opt.update(g, state, params, jnp.int32(i))
    assert float(jnp.linalg.norm(params["w"])) < 1.0


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_schedule_warmup_cosine():
    s = warmup_cosine(1.0, 10, 100, floor=0.1)
    assert float(s(jnp.int32(0))) == pytest.approx(0.0)
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# grad accumulation
# ---------------------------------------------------------------------------


def test_microbatch_accumulation_matches_full_batch():
    import dataclasses

    cfg = get_reduced_config("qwen2-1.5b")
    batch = synthetic.batch_for(cfg, (4, 16), seed=0, step=0)
    opt = make_optimizer("sgdm", 0.01, momentum=0.0, clip_norm=1e9)

    results = {}
    for m in (1, 2):
        cfg_m = dataclasses.replace(cfg, microbatches_train=m)
        step = make_train_step(cfg_m, opt)
        params = init_params(jax.random.PRNGKey(0), cfg_m)
        opt_state = opt.init(params)
        new_params, _, metrics = step(params, opt_state, batch, jnp.int32(0))
        results[m] = (new_params, float(metrics["loss"]))
    np.testing.assert_allclose(results[1][1], results[2][1], rtol=1e-5)
    for l1, l2 in zip(jax.tree.leaves(results[1][0]), jax.tree.leaves(results[2][0])):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(rng):
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32)),
                   "stack": (jnp.ones((2, 2)), jnp.zeros((3,)))},
        "opt_state": {"m": {"w": jnp.zeros((4, 3))}},
    }


def test_checkpoint_roundtrip(tmp_path, rng):
    state = _tree(rng)
    checkpointer.save(str(tmp_path), 7, state, extra={"data": {"seed": 1, "step": 7}})
    restored, extra, step = checkpointer.restore(str(tmp_path))
    assert step == 7 and extra["data"]["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_crash_consistency(tmp_path, rng):
    state = _tree(rng)
    checkpointer.save(str(tmp_path), 5, state)
    # simulate a crash mid-write of step 9: .tmp dir must be ignored
    os.makedirs(tmp_path / "step_00000009.tmp")
    (tmp_path / "step_00000009.tmp" / "arrays.npz").write_bytes(b"garbage")
    assert checkpointer.latest_step(str(tmp_path)) == 5
    _, _, step = checkpointer.restore(str(tmp_path))
    assert step == 5


def test_checkpoint_gc(tmp_path, rng):
    state = _tree(rng)
    for s in (1, 2, 3, 4, 5):
        checkpointer.save(str(tmp_path), s, state, keep_last=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_elastic_restore_resharding(tmp_path, rng):
    """Restore with explicit shardings (single device here; the relayout path
    is identical for any mesh since device_put handles distribution)."""
    state = _tree(rng)
    checkpointer.save(str(tmp_path), 3, state)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state
    )
    restored, _, _ = checkpointer.restore(str(tmp_path), shardings=sh)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf, jax.Array)


# ---------------------------------------------------------------------------
# data determinism + serving
# ---------------------------------------------------------------------------


def test_data_pipeline_deterministic_and_resumable():
    cfg = get_reduced_config("qwen2-1.5b")
    p1 = LMDataPipeline(cfg, 4, 16, seed=3)
    batches = [next(p1) for _ in range(3)]
    # resume from state after 2 batches
    p2 = LMDataPipeline.from_state(cfg, 4, 16, {"seed": 3, "step": 2})
    b3 = next(p2)
    np.testing.assert_array_equal(np.asarray(batches[2]["tokens"]), np.asarray(b3["tokens"]))
    # labels are next-token shifted
    assert batches[0]["tokens"].shape == (4, 16)


def test_greedy_generate_smoke():
    cfg = get_reduced_config("qwen2-1.5b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.batch_for(cfg, (2, 12), 0, 0)
    batch.pop("labels")
    out = greedy_generate(cfg, params, batch, max_new=5)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all()


def test_greedy_generate_matches_prefill_argmax():
    cfg = get_reduced_config("chatglm3-6b")
    impl = get_model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic.batch_for(cfg, (2, 10), 0, 0)
    batch.pop("labels")
    out = greedy_generate(cfg, params, batch, max_new=3)
    # cross-check token 0 against prefill argmax
    logits_p, _ = impl.prefill(params, batch, cfg)
    want0 = np.asarray(jnp.argmax(logits_p[:, -1], axis=-1))
    np.testing.assert_array_equal(np.asarray(out[:, 0]), want0)
