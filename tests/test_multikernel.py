"""Multi-kernel subsystem: the weighted-sum operator must agree with the
explicit weighted sum of dense kernels, the weight-axis tuner must return
the SAME best config and CV scores as the naive per-candidate loop (locally
and through a 1-device mesh), one-hot weights must reproduce single-kernel
tuning exactly, and the whole search must cost ~1 solve's kernel work per
sigma (the acceptance claim, asserted via SweepCounter)."""

import json
import runpy
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels import kernel_fn
from repro.core.krr import KRRProblem
from repro.core.multikernel import WeightedSumKernelOperator, make_operator
from repro.core.operator import KernelOperator
from repro.core.tune import apply_best, tune, tune_multikernel
from repro.serving.krr_serve import make_krr_predict_fn_from_config

KERNELS = ("rbf", "laplacian", "matern52")
SIGMAS = (0.7, 1.3, 2.1)
WEIGHTS = (0.5, 0.2, 0.3)

MK_TUNE_KW = dict(kernels=KERNELS, sigmas=(0.7, 1.5), lams=(1e-3, 1e-1),
                  folds=3, n_weight_samples=3, rank=32, max_iters=300,
                  tol=1e-6, seed=0)


def _xy(n=192, d=4, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    y = jnp.sin(2.0 * x[:, 0]) + 0.2 * jnp.sign(x[:, 1])
    return x, y


def _dense(x, a=None):
    a = x if a is None else a
    return sum(
        w * np.asarray(kernel_fn(k)(a, x, s))
        for k, s, w in zip(KERNELS, SIGMAS, WEIGHTS)
    )


def _mk_op(x, backend="xla"):
    return WeightedSumKernelOperator(
        x=x, kernels=KERNELS, sigma=SIGMAS, weights=WEIGHTS, backend=backend
    )


# ---------------------------------------------------------------------------
# operator parity vs the explicit weighted sum of dense kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("rhs_shape", ["1d", "2d"])
def test_weighted_operator_matvec_parity(backend, rhs_shape):
    x, _ = _xy(n=96)
    r = np.random.default_rng(1)
    v = r.standard_normal((96, 5)).astype(np.float32)
    if rhs_shape == "1d":
        v = v[:, 0]
    op = _mk_op(x, backend=backend)
    got = np.asarray(op.matvec(jnp.asarray(v)))
    np.testing.assert_allclose(got, _dense(x) @ v, rtol=2e-4, atol=2e-4)


def test_weighted_operator_block_and_row_block():
    x, _ = _xy(n=80)
    a = jnp.asarray(np.random.default_rng(2).standard_normal((17, 4)).astype(np.float32))
    op = _mk_op(x)
    np.testing.assert_allclose(
        np.asarray(op.block(a, x)), _dense(x, a), rtol=2e-4, atol=2e-4
    )
    v = np.random.default_rng(3).standard_normal((80, 3)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(op.row_block_matvec(a, jnp.asarray(v))),
        _dense(x, a) @ v, rtol=2e-4, atol=2e-4,
    )
    idx = jnp.asarray([3, 11, 40, 41])
    kbb = np.asarray(op.block_idx(idx))
    np.testing.assert_allclose(
        kbb, _dense(x)[np.ix_([3, 11, 40, 41], [3, 11, 40, 41])],
        rtol=2e-4, atol=2e-4,
    )


def test_weighted_operator_contract_extras():
    x, _ = _xy(n=64)
    op = _mk_op(x)
    assert op.q == 3 and op.shape == (64, 64)
    np.testing.assert_allclose(float(op.trace_est()), sum(WEIGHTS) * 64, rtol=1e-6)
    sub = op.restrict(jnp.arange(10))
    assert isinstance(sub, WeightedSumKernelOperator) and sub.n == 10
    assert op.with_weights((1.0, 0.0, 0.0)).weights == (1.0, 0.0, 0.0)
    comps = op.components()
    assert [c.kernel for c in comps] == list(KERNELS)
    # matvec_cols: per-column weight vectors
    r = np.random.default_rng(4)
    v = r.standard_normal((64, 4)).astype(np.float32)
    wc = r.dirichlet(np.ones(3), size=4).T.astype(np.float32)  # (q, 4)
    got = np.asarray(op.matvec_cols(jnp.asarray(v), jnp.asarray(wc)))
    dense = [np.asarray(kernel_fn(k)(x, x, s)) for k, s in zip(KERNELS, SIGMAS)]
    want = sum(K @ (v * wc[i][None, :]) for i, K in enumerate(dense))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # sketch_components: stacked per-kernel products
    om = r.standard_normal((64, 6)).astype(np.float32)
    got = np.asarray(op.sketch_components(jnp.asarray(om)))
    np.testing.assert_allclose(
        got, np.stack([K @ om for K in dense]), rtol=2e-4, atol=2e-4
    )


def test_make_operator_dispatch_and_validation():
    x, _ = _xy(n=32)
    assert isinstance(make_operator(x, kernel="rbf"), KernelOperator)
    assert isinstance(
        make_operator(x, kernel=("rbf", "laplacian")), WeightedSumKernelOperator
    )
    with pytest.raises(ValueError, match="weights"):
        make_operator(x, kernel="rbf", weights=(1.0,))
    with pytest.raises(ValueError, match="unknown kernel"):
        make_operator(x, kernel=("rbf", "bogus"))
    with pytest.raises(ValueError, match="entries"):
        make_operator(x, kernel=("rbf", "laplacian"), weights=(1.0,))
    with pytest.raises(ValueError, match="nonnegative"):
        make_operator(x, kernel=("rbf", "laplacian"), weights=(-1.0, 2.0))
    with pytest.raises(ValueError, match="one shared float"):
        make_operator(x, kernel=("rbf", "laplacian"), sigma=(1.0, 2.0, 3.0))


def test_problem_with_kernel_tuple_solves_like_dense():
    x, y = _xy(n=96)
    prob = KRRProblem(x=x, y=y, kernel=KERNELS, sigma=SIGMAS, weights=WEIGHTS,
                      lam_unscaled=1e-3, backend="xla")
    from repro.core.solver_api import solve

    wd = np.linalg.solve(
        _dense(x) + prob.lam * np.eye(96), np.asarray(y)
    )
    for method, kw in [
        ("direct", {}),
        ("pcg-nystrom", dict(rank=32, max_iters=300, tol=1e-8)),
    ]:
        out = solve(prob, method, **kw)
        np.testing.assert_allclose(np.asarray(out.w), wd, rtol=1e-3, atol=1e-4)
    # the universal solve overrides build the same problem on the fly
    out = solve(KRRProblem(x=x, y=y, sigma=SIGMAS[0], lam_unscaled=1e-3,
                           backend="xla"),
                "direct", kernel=KERNELS, weights=WEIGHTS)
    # note: sigma stays the problem's scalar -> different dense matrix; only
    # check shape/contract here
    assert out.w.shape == (96,)


# ---------------------------------------------------------------------------
# tune_multikernel: shared == naive, one-hot degeneracy, mesh parity, cost
# ---------------------------------------------------------------------------


def _assert_same_mk_sweep(rs, rn, score_rtol=1e-3):
    assert rs.best["weights"] == rn.best["weights"]
    assert rs.best["sigma"] == rn.best["sigma"]
    assert rs.best["lam_unscaled"] == rn.best["lam_unscaled"]
    assert len(rs.records) == len(rn.records)
    for a, b in zip(rs.records, rn.records):
        assert (a["sigma"], a["lam_unscaled"], a["weights"]) == (
            b["sigma"], b["lam_unscaled"], b["weights"])
        np.testing.assert_allclose(a["cv_mse"], b["cv_mse"], rtol=score_rtol)
        np.testing.assert_allclose(a["fold_mse"], b["fold_mse"], rtol=score_rtol)


def test_mk_shared_matches_naive_regression():
    x, y = _xy()
    prob = KRRProblem(x=x, y=y, backend="xla")
    rs = tune_multikernel(prob, strategy="shared", **MK_TUNE_KW)
    rn = tune_multikernel(prob, strategy="naive", **MK_TUNE_KW)
    _assert_same_mk_sweep(rs, rn)


def test_mk_shared_matches_naive_one_vs_all():
    from repro.data import synthetic

    x, y, _, _, _, _ = synthetic.krr_one_vs_all(0, 144, 4, num_classes=3)
    prob = KRRProblem(x=x, y=y, backend="xla")
    kw = dict(MK_TUNE_KW, n_weight_samples=2, folds=2)
    rs = tune_multikernel(prob, strategy="shared", **kw)
    rn = tune_multikernel(prob, strategy="naive", **kw)
    _assert_same_mk_sweep(rs, rn)
    for a, b in zip(rs.records, rn.records):
        assert 0.0 <= a["cv_acc"] <= 1.0
        np.testing.assert_allclose(a["cv_acc"], b["cv_acc"], atol=0.05)


def test_mk_one_hot_weights_reproduce_single_kernel_tune():
    x, y = _xy()
    prob = KRRProblem(x=x, y=y, backend="xla")
    eye = np.eye(3, dtype=np.float32)
    kw = {k: v for k, v in MK_TUNE_KW.items() if k != "n_weight_samples"}
    ro = tune_multikernel(prob, strategy="shared", weights=eye, **kw)
    for ki, kname in enumerate(KERNELS):
        rsingle = tune(
            KRRProblem(x=x, y=y, kernel=kname, backend="xla"),
            sigmas=MK_TUNE_KW["sigmas"], lams=MK_TUNE_KW["lams"],
            folds=MK_TUNE_KW["folds"], rank=MK_TUNE_KW["rank"],
            max_iters=MK_TUNE_KW["max_iters"], tol=MK_TUNE_KW["tol"], seed=0,
        )
        mk_map = {
            (rec["sigma"], rec["lam_unscaled"]): rec["cv_mse"]
            for rec in ro.records if rec["weights"] == list(eye[ki])
        }
        for rec in rsingle.records:
            np.testing.assert_allclose(
                mk_map[(rec["sigma"], rec["lam_unscaled"])], rec["cv_mse"],
                rtol=1e-3,
            )


def test_mk_mesh_1device_matches_local():
    from repro.distributed.meshes import make_solver_mesh

    x, y = _xy()
    prob = KRRProblem(x=x, y=y, backend="xla")
    kw = dict(MK_TUNE_KW, kernels=("rbf", "laplacian"), n_weight_samples=2)
    r_local = tune_multikernel(prob, strategy="shared", **kw)
    r_mesh = tune_multikernel(prob, strategy="shared",
                              mesh=make_solver_mesh((1, 1)), **kw)
    _assert_same_mk_sweep(r_local, r_mesh)


def test_mk_sweep_cost_acceptance():
    # the ISSUE acceptance shape: q=3 kernels, 8 weight samples, l=4, k=5 —
    # the whole search must cost <= 1.5x a single-candidate solve per sigma
    x, y = _xy(n=160)
    prob = KRRProblem(x=x, y=y, backend="xla")
    rs = tune_multikernel(
        prob, kernels=KERNELS, sigmas=(1.0,),
        lams=(1e-4, 1e-3, 1e-2, 1e-1), folds=5, n_weight_samples=8,
        rank=32, max_iters=200, tol=1e-5, seed=0,
    )
    assert rs.info["candidates"] == 8 * 4
    iters = max(int(v) for v in rs.info["iters_by_sigma"].values())
    single_candidate = iters + 2  # sketch + iters + scoring
    assert rs.sweeps <= 1.5 * single_candidate
    assert rs.sweeps <= iters + 3 + 1e-6  # the exact shared budget
    # and materially below what the naive loop would pay
    assert rs.sweeps < 0.25 * rs.info["naive_sweep_estimate"]


def test_mk_option_validation():
    x, y = _xy(n=64)
    prob = KRRProblem(x=x, y=y, backend="xla")
    from repro.core.solver_api import MULTIKERNEL_TUNE_OPTIONS
    from repro.core.solver_api import tune as tune_api

    with pytest.raises(ValueError, match="multi-kernel"):
        tune_api(prob, kernels=("rbf", "laplacian"), search="grid")
    with pytest.raises(ValueError, match="kernels"):
        tune_multikernel(prob)  # kernel is a plain string, no kernels=
    with pytest.raises(ValueError, match="n_weight_samples"):
        tune_multikernel(prob, kernels=KERNELS, n_weight_samples=0)
    with pytest.raises(ValueError, match="dirichlet_alpha"):
        tune_multikernel(prob, kernels=KERNELS, dirichlet_alpha=0.0)
    with pytest.raises(ValueError, match="nonnegative"):
        tune_multikernel(prob, kernels=KERNELS,
                         weights=np.asarray([[-1.0, 1.0, 1.0]]))
    with pytest.raises(ValueError, match="entries per row"):
        tune_multikernel(prob, kernels=KERNELS, weights=np.ones((2, 2)))
    assert set(MULTIKERNEL_TUNE_OPTIONS) >= {"kernels", "n_weight_samples",
                                             "weights", "dirichlet_alpha"}


def test_mk_apply_best_refit_and_config_serving_round_trip():
    x, y = _xy()
    prob = KRRProblem(x=x, y=y, backend="xla")
    res = tune_multikernel(prob, strategy="shared", **MK_TUNE_KW)
    best_prob, w0 = apply_best(prob, res, with_w0=True)
    assert best_prob.kernel == tuple(res.best["kernel"])
    assert list(best_prob.weights) == res.best["weights"]
    assert w0 is not None and w0.shape == (prob.n,)
    from repro.core.solver_api import solve

    out_cold = solve(best_prob, "pcg-nystrom", rank=32, max_iters=300, tol=1e-6)
    out_warm = solve(best_prob, "pcg-nystrom", rank=32, max_iters=300,
                     tol=1e-6, w0=w0)
    assert out_warm.info["iters"] <= out_cold.info["iters"]
    np.testing.assert_allclose(np.asarray(out_warm.w), np.asarray(out_cold.w),
                               rtol=1e-3, atol=1e-4)
    # serving from the JSON round-tripped export == problem.predict
    cfg = json.loads(json.dumps(res.best))
    predict = make_krr_predict_fn_from_config(cfg, prob.x, out_cold.w)
    xq = jnp.asarray(
        np.random.default_rng(1).standard_normal((17, 4)).astype(np.float32)
    )
    np.testing.assert_allclose(
        np.asarray(predict(xq)), np.asarray(best_prob.predict(out_cold.w, xq)),
        rtol=1e-4, atol=1e-5,
    )


def test_mk_loo_cross_check():
    # folds=n IS leave-one-out: the closed-form residuals from one Cholesky
    # must match the multi-kernel CV score exactly (small n, tight tol)
    from repro.core.direct import loo_mse

    x, y = _xy(n=40, d=3)
    prob = KRRProblem(x=x, y=y, backend="xla")
    w = np.asarray([[0.6, 0.4]], np.float32)
    rs = tune_multikernel(
        prob, kernels=("rbf", "laplacian"), weights=w, sigmas=(1.0,),
        lams=(1e-2,), folds=40, rank=24, max_iters=500, tol=1e-9, seed=0,
    )
    ref = loo_mse(KRRProblem(x=x, y=y, kernel=("rbf", "laplacian"),
                             weights=(0.6, 0.4), sigma=1.0, lam_unscaled=1e-2,
                             backend="xla"))
    np.testing.assert_allclose(rs.records[0]["cv_mse"], ref, rtol=2e-3)


# ---------------------------------------------------------------------------
# CLI / example smoke
# ---------------------------------------------------------------------------


def test_mk_cli_smoke(tmp_path, capsys, monkeypatch):
    export = tmp_path / "best_mk.json"
    monkeypatch.setattr(sys, "argv", [
        "krr_tune", "--n", "160", "--d", "3", "--n-test", "48",
        "--kernels", "rbf,laplacian", "--n-weight-samples", "2",
        "--sigmas", "0.7,1.4", "--lams", "1e-3,1e-1", "--folds", "2",
        "--rank", "16", "--iters", "60", "--tol", "1e-4",
        "--method", "pcg-nystrom", "--refit-iters", "60",
        "--export", str(export),
    ])
    runpy.run_module("repro.launch.krr_tune", run_name="__main__")
    report = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert report["best"]["kernel"] == ["rbf", "laplacian"]
    assert len(report["best"]["weights"]) == 2
    assert report["candidates"] == 2 * 2 * 2  # sigmas x weights x lams
    assert report["refit_warm_start"] is True
    assert "test_rmse" in report["refit"]
    saved = json.loads(export.read_text())
    # the export is the serving-ready config PLUS the audit trail
    assert saved == {**report["best"], "trace": report["trace"]}


def test_mk_example_smoke(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", [
        "krr_multikernel.py", "--n", "160", "--n-test", "48",
        "--n-weight-samples", "2", "--iters", "60",
    ])
    runpy.run_path("examples/krr_multikernel.py", run_name="__main__")
    out = capsys.readouterr().out
    assert "best" in out and "serve" in out and "weights" in out
