"""Per-architecture smoke tests: every assigned arch instantiates at REDUCED
size and runs one forward/train step on CPU — output shapes + no NaNs —
plus prefill/decode parity for the families where decode is exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_reduced_config
from repro.data import synthetic
from repro.models.model_api import get_model, init_params
from repro.training.optimizers import make_optimizer
from repro.training.train_step import make_train_step

B, T = 2, 16


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree))


def _batch(cfg):
    return synthetic.batch_for(cfg, (B, T), seed=0, step=0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_grads(arch):
    cfg = get_reduced_config(arch)
    impl = get_model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = impl.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(padded vocab)
    assert 0.5 * np.log(cfg.padded_vocab()) < float(loss) < 1.5 * np.log(cfg.padded_vocab())
    grads = jax.grad(lambda p: impl.loss_fn(p, batch, cfg)[0])(params)
    assert _finite(grads)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step_improves_loss(arch):
    cfg = get_reduced_config(arch)
    opt = make_optimizer("adamw", 3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    batch = _batch(cfg)  # same batch -> loss must drop
    losses = []
    for i in range(8):
        params, opt_state, m = step(params, opt_state, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert _finite(params)


@pytest.mark.parametrize(
    "arch",
    [a for a in ARCH_IDS if a not in ("jamba-1.5-large-398b",)],
)
def test_decode_matches_prefill(arch):
    """Greedy decode logits must equal a longer prefill's last-position
    logits.  (Jamba's prefill intentionally zeroes Mamba decode states —
    documented in hybrid.prefill — so it is checked separately.)"""
    cfg = get_reduced_config(arch)
    impl = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    batch.pop("labels", None)
    logits_p, cache = impl.prefill(params, batch, cfg)
    big = impl.init_cache(cfg, B, T + 4)
    for k, v in cache.items():
        if k not in big:
            continue
        tgt = big[k]
        if hasattr(v, "ndim") and v.ndim >= 3 and v.shape != tgt.shape:
            big[k] = jax.lax.dynamic_update_slice_in_dim(
                tgt, v.astype(tgt.dtype), 0, axis=2
            )
        else:
            big[k] = v
    nt = jnp.argmax(logits_p[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits_d, _ = impl.decode_step(params, big, {"tokens": nt}, cfg)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nt], axis=1)
    logits_chk, _ = impl.prefill(params, batch2, cfg)
    # MoE archs: capacity-based dispatch depends on the token population, and
    # router near-ties flip under fp reassociation -> small logit deltas are
    # expected (same behaviour as Switch/GShard-style serving); dense archs
    # must match tightly.
    tol = 5e-2 if cfg.num_experts else 2e-3
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(logits_chk[:, -1]),
        rtol=tol, atol=tol,
    )


def test_jamba_decode_runs_and_is_stateful():
    cfg = get_reduced_config("jamba-1.5-large-398b")
    impl = get_model(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)
    cache = impl.init_cache(cfg, B, T)
    cache["pos"] = jnp.array(0, jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits1, cache = impl.decode_step(params, cache, {"tokens": tok}, cfg)
    logits2, cache = impl.decode_step(params, cache, {"tokens": tok}, cfg)
    assert np.isfinite(np.asarray(logits1)).all()
    # state must influence the second step (mamba/attention carry)
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_defs_consistent(arch):
    """Full (published) configs: shapes/specs well-formed without allocation."""
    cfg = get_config(arch)
    impl = get_model(cfg)
    defs = impl.param_defs(cfg)
    for path, (shape, spec) in defs.items():
        assert len(spec) <= len(shape), (path, shape, spec)
        assert all(dim > 0 for dim in shape), (path, shape)
    n = cfg.n_params()
    assert n > 0
    # sanity vs the advertised scale
    advertised = {
        "whisper-base": 0.07e9, "grok-1-314b": 314e9, "deepseek-moe-16b": 16.4e9,
        "qwen2-1.5b": 1.5e9, "chatglm3-6b": 6.2e9, "command-r-plus-104b": 104e9,
        "llama3-405b": 405e9, "rwkv6-1.6b": 1.6e9,
        "jamba-1.5-large-398b": 398e9, "llava-next-mistral-7b": 7.2e9,
    }[arch]
    assert 0.75 * advertised < n < 1.35 * advertised, (arch, n)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    from repro.models.model_api import ALL_SHAPES, shape_applicable

    cfg = get_config(arch)
    impl = get_model(cfg)
    for shape in ALL_SHAPES:
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        specs = impl.input_specs(cfg, shape)
        assert "tokens" in specs
        for name, s in specs.items():
            assert isinstance(s, jax.ShapeDtypeStruct), name
            assert s.shape[0] == shape.global_batch
