"""Roofline machinery: HLO collective parsing against hand-built text,
extrapolation math, and term computation."""

import numpy as np

from repro.roofline import analyze, hw

HLO = """
HloModule test

ENTRY main {
  %p0 = f32[128,64]{1,0} parameter(0)
  %p1 = bf16[32,16]{1,0} parameter(1)
  %ag = f32[512,64]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[128,64]{1,0} all-reduce(%p0), to_apply=%sum
  %rs = bf16[8,16]{1,0} reduce-scatter(%p1), dimensions={0}
  %cp = bf16[32,16]{1,0} collective-permute(%p1), source_target_pairs={{0,1}}
  %aa = bf16[32,16]{1,0} all-to-all(%p1), dimensions={0}
  %ags = f32[256,64]{1,0} all-gather-start(%p0), dimensions={0}
  %dot = f32[128,128]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""


def test_collective_bytes_parsing():
    got = analyze.collective_bytes(HLO)
    p0 = 128 * 64 * 4  # 32768
    p1 = 32 * 16 * 2  # 1024
    assert got["all-gather"] == 2 * p0  # all-gather + all-gather-start
    assert got["all-reduce"] == p0
    assert got["reduce-scatter"] == p1
    assert got["collective-permute"] == p1
    assert got["all-to-all"] == p1


def test_extrapolation_linear():
    c1 = analyze.CellCost(flops=10.0, bytes_accessed=100.0, coll_bytes=4.0,
                          coll_breakdown={"all-reduce": 4.0})
    c2 = analyze.CellCost(flops=16.0, bytes_accessed=130.0, coll_bytes=6.0,
                          coll_breakdown={"all-reduce": 6.0})
    full = analyze.extrapolate(c1, c2, 1, 9)  # 10 layers total
    assert full.flops == 10.0 + 6.0 * 9
    assert full.bytes_accessed == 100.0 + 30.0 * 9
    assert full.coll_breakdown["all-reduce"] == 4.0 + 2.0 * 9


def test_roofline_terms_and_dominance():
    c = analyze.CellCost(
        flops=hw.PEAK_FLOPS_BF16,  # 1 second of compute
        bytes_accessed=hw.HBM_BW / 2,  # 0.5 s
        coll_bytes=hw.ICI_BW / 4,  # 0.25 s
        coll_breakdown={},
    )
    t = analyze.roofline_terms(c)
    assert t["compute_s"] == 1.0
    assert t["memory_s"] == 0.5
    assert t["collective_s"] == 0.25
    assert t["dominant"] == "compute"


def test_model_flops():
    assert analyze.model_flops(100, 0, 10, train=True) == 6000
    assert analyze.model_flops(100, 40, 10, train=True) == 2400  # MoE active
    assert analyze.model_flops(100, 0, 10, train=False) == 2000
