"""Estimator front-end behavior that does NOT need scikit-learn installed:
edge-case shapes, validation errors, solver pass-through, and the CV
reporting surface.  (The sklearn differential suite is
tests/test_sklearn_api.py.)"""

import numpy as np
import pytest

from repro.estimators import (
    AUTO_DIRECT_MAX_N,
    KernelRidge,
    KernelRidgeCV,
    MultipleKernelRidgeCV,
    resolve_sigma,
)


def _data(rng, n=50, d=4, t=None):
    X = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal((n,) if t is None else (n, t)).astype(np.float32)
    return X, y


# ---------------------------------------------------------------------------
# edge-case shapes
# ---------------------------------------------------------------------------


def test_n1_fit(rng):
    X, y = _data(rng, n=1)
    est = KernelRidge(alpha=1.0).fit(X, y)
    p = np.asarray(est.predict(X))
    assert p.shape == (1,) and np.isfinite(p).all()


def test_d1_fit(rng):
    X, y = _data(rng, d=1)
    est = KernelRidge(alpha=0.5, kernel="laplacian").fit(X, y)
    assert est.n_features_in_ == 1
    assert np.asarray(est.predict(X)).shape == (50,)


def test_empty_predict_dtype_follows_weights(rng):
    X, y = _data(rng, t=3)
    est = KernelRidge(alpha=0.5).fit(X, y)
    p = est.predict(np.zeros((0, 4), np.float32))
    assert p.shape == (0, 3)
    assert p.dtype == est.dual_coef_.dtype


def test_multioutput_shapes(rng):
    X, y = _data(rng, t=4)
    est = KernelRidge(alpha=0.5).fit(X, y)
    assert est.dual_coef_.shape == (50, 4)
    assert np.asarray(est.predict(X[:7])).shape == (7, 4)


# ---------------------------------------------------------------------------
# validation errors
# ---------------------------------------------------------------------------


def test_nonfinite_rejected(rng):
    X, y = _data(rng)
    Xb = X.copy(); Xb[3, 1] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        KernelRidge().fit(Xb, y)
    yb = y.copy(); yb[0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        KernelRidge().fit(X, yb)


def test_shape_mismatch_rejected(rng):
    X, y = _data(rng)
    with pytest.raises(ValueError, match="row counts"):
        KernelRidge().fit(X, y[:-1])
    with pytest.raises(ValueError, match="2-D"):
        KernelRidge().fit(X[:, 0], y)


def test_nonsquare_precomputed_rejected(rng):
    y = rng.standard_normal(6).astype(np.float32)
    with pytest.raises(ValueError, match="square"):
        KernelRidge(kernel="precomputed").fit(
            rng.standard_normal((6, 9)).astype(np.float32), y
        )


def test_bad_hyperparams_rejected(rng):
    X, y = _data(rng)
    with pytest.raises(ValueError, match="alpha"):
        KernelRidge(alpha=0.0).fit(X, y)
    with pytest.raises(ValueError, match="sigma"):
        KernelRidge(sigma=-1.0).fit(X, y)
    with pytest.raises(ValueError, match="gamma"):
        KernelRidge(gamma=-2.0).fit(X, y)
    with pytest.raises(ValueError, match="unknown kernel"):
        KernelRidge(kernel="nope").fit(X, y)
    with pytest.raises(ValueError, match="unknown solver"):
        KernelRidge(solver="nope").fit(X, y)


# ---------------------------------------------------------------------------
# sigma/gamma resolution + solver dispatch
# ---------------------------------------------------------------------------


def test_resolve_sigma_table():
    assert resolve_sigma("rbf", None, 0.5, 4) == 1.0  # sqrt(0.5/0.5)
    assert resolve_sigma("laplacian", None, 0.25, 4) == 4.0
    assert resolve_sigma("polynomial", None, 0.25, 4) == 2.0
    assert resolve_sigma("rbf", 3.0, 0.5, 4) == 3.0  # sigma wins over gamma
    assert resolve_sigma("linear", None, None, 4) == 1.0  # gamma-free
    assert resolve_sigma("precomputed", None, None, 4) == 1.0
    # default gamma = 1/n_features
    assert resolve_sigma("laplacian", None, None, 8) == 8.0


def test_solver_pass_through(rng):
    X, y = _data(rng)
    est = KernelRidge(
        alpha=0.5, solver="pcg-nystrom",
        solver_opts={"max_iters": 150, "tol": 1e-6, "rank": 20},
    ).fit(X, y)
    assert "iters" in est.solve_info_  # iterative path actually ran
    direct = KernelRidge(alpha=0.5).fit(X, y)  # auto -> direct at this n
    np.testing.assert_allclose(
        np.asarray(est.predict(X[:5])), np.asarray(direct.predict(X[:5])),
        rtol=1e-3, atol=1e-3,
    )
    assert X.shape[0] <= AUTO_DIRECT_MAX_N


def test_unknown_solver_opt_rejected(rng):
    X, y = _data(rng)
    with pytest.raises(ValueError, match="unknown option"):
        KernelRidge(solver="direct", solver_opts={"max_iters": 5}).fit(X, y)


# ---------------------------------------------------------------------------
# CV estimators (reporting surface; parity lives in test_sklearn_api.py)
# ---------------------------------------------------------------------------


def test_cv_results_surface(rng):
    X, y = _data(rng)
    cv = KernelRidgeCV(alphas=(0.1, 1.0), sigmas=(0.8, 1.5), cv=3).fit(X, y)
    res = cv.cv_results_
    assert len(res["param_sigma"]) == 4
    assert res["mean_test_score"] == [-m for m in res["mean_test_mse"]]
    best_idx = res["rank_test_score"].index(1)
    assert res["param_alpha"][best_idx] == pytest.approx(cv.best_params_["alpha"])
    assert cv.best_score_ == pytest.approx(max(res["mean_test_score"]), rel=1e-6)
    assert cv.alpha_ in [pytest.approx(a) for a in (0.1, 1.0)]
    assert cv.tune_result_.folds == 3


def test_cv_random_policy(rng):
    X, y = _data(rng)
    cv = KernelRidgeCV(
        alphas=(0.1, 1.0), sigmas=(0.8, 1.5), cv=3, policy="random",
        num_samples=3, seed=7,
    ).fit(X, y)
    assert len(cv.cv_results_["param_sigma"]) == 3
    assert np.asarray(cv.predict(X[:4])).shape == (4,)


def test_multiple_kernel_cv_smoke(rng):
    X, y = _data(rng, t=2)
    mk = MultipleKernelRidgeCV(
        kernels=("rbf", "laplacian"), alphas=(0.1, 1.0),
        sigmas=(1.0, (0.8, 1.6)), cv=3, n_weight_samples=3, seed=2,
    ).fit(X, y)
    assert len(mk.kernel_weights_) == 2
    assert sum(mk.kernel_weights_) == pytest.approx(1.0, abs=1e-5)
    assert set(mk.best_params_) == {"alpha", "sigma", "weights"}
    assert "param_weights" in mk.cv_results_
    assert np.asarray(mk.predict(X[:6])).shape == (6, 2)


def test_cv_precomputed_collapses_sigma_axis(rng):
    from repro.core.kernels import kernel_matrix

    X, y = _data(rng)
    K = np.asarray(kernel_matrix("rbf", X, X, 1.2))
    cv = KernelRidgeCV(
        alphas=(0.1, 1.0), sigmas=(0.5, 2.0), kernel="precomputed", cv=3
    ).fit(K, y)
    # sigma axis is meaningless for a stored Gram: only the alphas are swept
    assert len(cv.cv_results_["param_sigma"]) == 2
    assert set(cv.cv_results_["param_sigma"]) == {1.0}
