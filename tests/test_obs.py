"""Telemetry subsystem correctness: schemas round-trip through JSONL, solver
traces are a pure VIEW (histories bit-identical with telemetry on/off), the
disabled path is near-free, sinks survive concurrent writers, the metrics
registry agrees with the tuning engine's own sweep accounting, and the
Prometheus exposition is well-formed.
"""

import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krr import KRRProblem
from repro.core.solver_api import solve, tune
from repro.obs import (
    NULL_TELEMETRY,
    RingSink,
    Telemetry,
    as_telemetry,
    counter,
    diff,
    log_buckets,
    prometheus_text,
    snapshot,
    span,
    validate_event,
    validate_jsonl,
)
from repro.obs.metrics import Histogram
from repro.obs.report import main as report_main

N, D = 400, 5


@pytest.fixture(scope="module")
def problem():
    r = np.random.default_rng(7)
    x = jnp.asarray(r.standard_normal((N, D)).astype(np.float32))
    y = jnp.sin(2.0 * x[:, 0]) + 0.3 * x[:, 1]
    return KRRProblem(x=x, y=y, kernel="rbf", sigma=1.0, lam_unscaled=1e-4,
                      backend="xla")


# ---------------------------------------------------------------------------
# schemas + JSONL round-trip
# ---------------------------------------------------------------------------


def test_jsonl_round_trip_and_schema(problem, tmp_path):
    path = str(tmp_path / "tel.jsonl")
    tel = Telemetry(jsonl=path)
    solve(problem, "askotch", max_iters=20, telemetry=tel)
    tel.close()

    counts = validate_jsonl(path)
    assert counts["span"] >= 1
    assert counts["trace"] >= 1
    assert counts["metric"] >= 1

    # every line is standalone JSON an external consumer can parse
    with open(path) as fh:
        events = [json.loads(line) for line in fh]
    solvespans = [e for e in events if e["type"] == "span"
                  and e["name"] == "solve/askotch"]
    assert len(solvespans) == 1 and solvespans[0]["dur_s"] > 0

    traces = [e for e in events if e["type"] == "trace"]
    assert all(e["solver"] == "askotch" for e in traces)
    assert traces[-1]["rel_residual"] <= traces[0]["rel_residual"] * 1.01


def test_validate_rejects_mutations(tmp_path):
    good = {"type": "trace", "solver": "pcg", "iter": 1, "wall_s": 0.1,
            "rel_residual": 0.5}
    validate_event(good)
    with pytest.raises(ValueError, match="unknown fields"):
        validate_event({**good, "bogus": 1})
    with pytest.raises(ValueError, match="missing fields"):
        validate_event({k: v for k, v in good.items() if k != "rel_residual"})
    with pytest.raises(ValueError, match="unknown event type"):
        validate_event({"type": "nope"})

    path = tmp_path / "bad.jsonl"
    path.write_text(json.dumps(good) + "\n" + json.dumps({**good, "x": 1}) + "\n")
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        validate_jsonl(str(path))
    path.write_text("not json\n")
    with pytest.raises(ValueError, match="not valid JSON"):
        validate_jsonl(str(path))


# ---------------------------------------------------------------------------
# traces are a VIEW: histories identical with telemetry on and off
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,solver,keys", [
    ("askotch", "askotch", {"iter", "rel_residual", "rel_residual_per_head",
                            "sketch_res", "step_L", "time_s"}),
    ("pcg-nystrom", "pcg", {"iter", "rel_residual", "rel_residual_per_head",
                            "time_s"}),
])
def test_trace_parity_with_legacy_history(problem, method, solver, keys):
    off = solve(problem, method, max_iters=15)
    tel = Telemetry(ring=True)
    on = solve(problem, method, max_iters=15, telemetry=tel)

    assert len(off.history) == len(on.history) > 0
    assert set(off.history[0]) == keys
    for a, b in zip(off.history, on.history):
        assert set(a) == set(b) == keys
        for k in keys - {"time_s"}:  # wall time differs run to run
            assert a[k] == b[k], (method, k)

    traces = [e for e in tel.ring.events() if e["type"] == "trace"]
    assert len(traces) == len(on.history)
    for ev, rec in zip(traces, on.history):
        validate_event(ev)
        assert ev["solver"] == solver and ev["iter"] == rec["iter"]
        assert ev["rel_residual"] == rec["rel_residual"]


# ---------------------------------------------------------------------------
# disabled-path overhead
# ---------------------------------------------------------------------------


def test_null_telemetry_overhead_is_negligible(problem):
    tel = as_telemetry(None)
    assert tel is NULL_TELEMETRY and not tel.enabled

    t0 = time.perf_counter()
    solve(problem, "askotch", max_iters=20)
    solve_s = time.perf_counter() - t0

    # what a solve actually pays per iteration when disabled: one enabled
    # check + one span fast path + one recorder identity check.  10k of
    # those (>> any real iteration count) must cost <5% of the small solve.
    rec = tel.recorder("askotch", n=N)
    t0 = time.perf_counter()
    for i in range(10_000):
        _ = tel.enabled
        with tel.span("solve/askotch", n=N):
            pass
        rec.add(i, 0.5, time_s=0.0)
    null_s = time.perf_counter() - t0
    assert null_s < 0.05 * solve_s, (null_s, solve_s)


# ---------------------------------------------------------------------------
# thread safety: concurrent serving clients through one JSONL sink
# ---------------------------------------------------------------------------


def test_concurrent_serving_clients_one_jsonl(tmp_path):
    from repro.serving.engine import ServingEngine

    r = np.random.default_rng(0)
    x = r.standard_normal((64, D)).astype(np.float32)
    w = r.standard_normal((64,)).astype(np.float32)
    cfg = {"kernel": "rbf", "sigma": 1.0, "backend": "xla", "precision": "f32"}

    path = str(tmp_path / "serve.jsonl")
    tel = Telemetry(jsonl=path)
    with ServingEngine(max_batch=32, max_wait_ms=1.0, telemetry=tel) as eng:
        eng.register("m", cfg, x, w)

        def client(seed):
            rr = np.random.default_rng(seed)
            for _ in range(5):
                q = int(rr.integers(1, 9))
                eng.predict("m", rr.standard_normal((q, D)).astype(np.float32))

        threads = [threading.Thread(target=client, args=(s,)) for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        eng.drain()
        stats = eng.stats("m")
        assert stats["n_requests"] == 40
        assert stats["p50_ms"] > 0 and stats["p99_ms"] >= stats["p50_ms"]

        prom = eng.prometheus_text()
        assert 'repro_serving_requests_total{model="m"} 40.0' in prom
        assert 'repro_serving_latency_ms_bucket{model="m",le="+Inf"} 40' in prom

        eng.reset_stats()
        assert eng.stats("m")["n_requests"] == 0
    tel.close()

    counts = validate_jsonl(path)  # every concurrent line intact + valid
    assert counts["span"] >= 1 and counts["metric"] >= 1


# ---------------------------------------------------------------------------
# metrics registry: sweep accounting agreement + Prometheus format
# ---------------------------------------------------------------------------


def test_registry_agrees_with_sweep_counter(problem):
    snap0 = snapshot()
    res = tune(problem, sigmas=(0.5, 1.0), lams=(1e-4, 1e-2), folds=3,
               max_iters=50, seed=0)
    delta = diff(snap0, snapshot())
    # TuneResult.sweeps is pairs/n^2; the registry counted the same pairs
    pairs = delta["repro_kernel_pairs_total"]
    assert pairs / float(problem.n) ** 2 == pytest.approx(res.sweeps)
    assert delta["repro_cg_iterations_total"] > 0


def test_prometheus_exposition_format():
    c = counter("repro_test_events_total", labels={"case": "prom"},
                help="test counter")
    c.inc(3)
    text = prometheus_text()
    assert "# HELP repro_test_events_total test counter" in text
    assert "# TYPE repro_test_events_total counter" in text
    assert 'repro_test_events_total{case="prom"} 3' in text

    h = Histogram("t_ms", labels=(), help="", buckets=log_buckets(1, 100, 1))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    pairs = h.bucket_counts()
    assert pairs[-1] == (float("inf"), 4)  # cumulative, ends at +Inf
    assert h.quantile(0.5) <= h.quantile(0.99)
    assert h.count == 4 and h.sum == pytest.approx(555.5)


def test_spans_nest_and_isolate_threads():
    ring = RingSink()
    with span("outer", sink=ring):
        with span("inner", sink=ring):
            pass
    inner, outer = ring.events()  # inner closes first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["parent_id"] == outer["span_id"] and inner["depth"] == 1

    seen = []

    def worker():
        with span("t", sink=ring) as s:
            seen.append(s.parent_id)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert seen == [0]  # fresh stack per thread: no cross-thread parent


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------


def test_report_cli_smoke(problem, tmp_path, capsys):
    path = str(tmp_path / "tel.jsonl")
    with Telemetry(jsonl=path) as tel:
        solve(problem, "askotch", max_iters=10, telemetry=tel)
    assert report_main([path]) == 0
    out = capsys.readouterr().out
    assert "schema OK" in out and "trace[askotch]" in out

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "mystery"}\n')
    assert report_main([str(bad)]) == 1
