"""PrecomputedKernelOperator: the full operator contract over a stored Gram.

The design claim is that a precomputed solve is EXACTLY the in-memory solve
— block access is a gather over stored entries (bit-identical, not allclose)
and the direct solver therefore produces bit-identical dual weights.  The
validation surface (non-square Grams, bad row-block widths, weights, mesh)
must fail loudly at construction, not deep inside a solve.
"""

import numpy as np
import pytest

from repro.core.kernels import kernel_matrix
from repro.core.krr import KRRProblem
from repro.core.multikernel import make_operator
from repro.core.operator import PrecomputedKernelOperator, widen_gram
from repro.core.solver_api import solve, tune


@pytest.fixture
def gram_setup(rng):
    x = rng.standard_normal((40, 5)).astype(np.float32)
    k = np.asarray(kernel_matrix("rbf", x, x, 1.1))
    return x, k


def test_widen_gram_shape_and_idempotence(gram_setup):
    _, k = gram_setup
    wide = np.asarray(widen_gram(k))
    assert wide.shape == (40, 41)
    np.testing.assert_array_equal(wide[:, :-1], k)
    np.testing.assert_array_equal(wide[:, -1], np.arange(40))
    np.testing.assert_array_equal(np.asarray(widen_gram(wide)), wide)


def test_widen_gram_rejects_bad_shapes(rng):
    with pytest.raises(ValueError, match="square"):
        widen_gram(rng.standard_normal((4, 7)))
    with pytest.raises(ValueError, match="2-D"):
        widen_gram(rng.standard_normal(5))


def test_operator_contract_matches_dense(gram_setup, rng):
    _, k = gram_setup
    op = make_operator(k, kernel="precomputed")
    assert isinstance(op, PrecomputedKernelOperator)
    assert (op.n, op.n0, op.d) == (40, 40, 40)

    # stored entries come back bit-identical, every access path
    np.testing.assert_array_equal(np.asarray(op.block(op.x)), k)
    np.testing.assert_array_equal(
        np.asarray(op.block(op.x[3:9], op.x[:12])), k[3:9, :12]
    )
    idx = np.array([1, 7, 33])
    np.testing.assert_array_equal(
        np.asarray(op.block_idx(idx)), k[np.ix_(idx, idx)]
    )
    sub = op.restrict(idx)
    np.testing.assert_array_equal(np.asarray(sub.block(sub.x)), k[np.ix_(idx, idx)])
    assert float(op.trace_est()) == pytest.approx(float(np.trace(k)), rel=1e-6)

    v = rng.standard_normal((40, 2)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(op.matvec(v)), k @ v, rtol=1e-5, atol=1e-5)
    lam = np.float32(0.3)
    np.testing.assert_allclose(
        np.asarray(op.k_lam_matvec(v, lam)), k @ v + lam * v,
        rtol=1e-5, atol=1e-5,
    )


def test_raw_row_block_accepted_for_predict(gram_setup, rng):
    """(b, n0) un-widened rows — the predict-time cross Gram — work too."""
    x, k = gram_setup
    xt = rng.standard_normal((9, 5)).astype(np.float32)
    kt = np.asarray(kernel_matrix("rbf", xt, x, 1.1))
    op = make_operator(k, kernel="precomputed")
    w = rng.standard_normal((40,)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(op.row_block_matvec(kt, w)), kt @ w, rtol=1e-5, atol=1e-5
    )


def test_bad_row_block_width_raises(gram_setup):
    op = make_operator(gram_setup[1], kernel="precomputed")
    with pytest.raises(ValueError, match="width"):
        op.block(op.x[:4, :7])


def test_direct_solve_bit_identical(gram_setup, rng):
    """The acceptance criterion: same solver, same numbers, zero ulps."""
    x, k = gram_setup
    y = rng.standard_normal((40,)).astype(np.float32)
    p_mem = KRRProblem(x=x, y=y, kernel="rbf", sigma=1.1, lam_unscaled=1e-3,
                       backend="xla")
    p_pre = KRRProblem(x=k, y=y, kernel="precomputed", sigma=1.0,
                       lam_unscaled=1e-3, backend="xla")
    w_mem = np.asarray(solve(p_mem, "direct").w)
    w_pre = np.asarray(solve(p_pre, "direct").w)
    np.testing.assert_array_equal(w_pre, w_mem)


def test_iterative_solver_runs_on_precomputed(gram_setup, rng):
    _, k = gram_setup
    y = rng.standard_normal((40,)).astype(np.float32)
    prob = KRRProblem(x=k, y=y, kernel="precomputed", lam_unscaled=1e-2,
                      backend="xla")
    out = solve(prob, "pcg-nystrom", max_iters=200, tol=1e-6, rank=20)
    kn = k + 40 * 1e-2 * np.eye(40, dtype=np.float32)
    np.testing.assert_allclose(kn @ np.asarray(out.w), y, rtol=1e-3, atol=1e-3)


def test_tune_runs_on_precomputed(gram_setup, rng):
    _, k = gram_setup
    y = rng.standard_normal((40,)).astype(np.float32)
    prob = KRRProblem(x=k, y=y, kernel="precomputed", backend="xla")
    result = tune(prob, sigmas=(1.0,), lams=(1e-4, 1e-1), folds=3)
    assert result.best["kernel"] == "precomputed"
    assert len(result.records) == 2


def test_make_operator_rejects_weights(gram_setup):
    with pytest.raises(ValueError, match="weights"):
        make_operator(gram_setup[1], kernel="precomputed", weights=(0.5, 0.5))


def test_solve_and_tune_reject_mesh(gram_setup, rng):
    import jax
    from jax.sharding import Mesh

    _, k = gram_setup
    y = rng.standard_normal((40,)).astype(np.float32)
    prob = KRRProblem(x=k, y=y, kernel="precomputed", lam_unscaled=1e-2,
                      backend="xla")
    mesh = Mesh(np.array(jax.devices()[:1]), ("rows",))
    with pytest.raises(ValueError, match="mesh"):
        solve(prob, "askotch", mesh=mesh)
    with pytest.raises(ValueError, match="mesh"):
        tune(prob, sigmas=(1.0,), lams=(1e-2,), folds=2, mesh=mesh)


def test_serving_config_rejects_unknown_kernel(gram_setup, rng):
    from repro.serving.krr_serve import bind_operator_from_config

    x, k = gram_setup
    w = rng.standard_normal((40,)).astype(np.float32)
    with pytest.raises(ValueError, match="unknown kernel"):
        bind_operator_from_config({"kernel": "rbf9000", "sigma": 1.0}, x, w)
    # and "precomputed" IS valid single-device
    op, _ = bind_operator_from_config(
        {"kernel": "precomputed", "sigma": 1.0}, k, w
    )
    assert isinstance(op, PrecomputedKernelOperator)
