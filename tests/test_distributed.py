"""Distributed paths.

Two tiers per scenario (the 1-device-fallback satellite):

  * ``*_inprocess_*`` — run in THIS pytest process on the largest solver
    mesh the process' devices allow (a (1, 1) mesh on plain single-device
    runs: size-1 axes make every collective a no-op, so the whole sharded
    code path executes).  These MUST pass everywhere.
  * subprocess tests — force a genuinely multi-device host platform
    (``--xla_force_host_platform_device_count``), which must not leak into
    other tests' single-device world.  xfail(strict=False): multi-device CPU
    collectives time out in constrained containers; they pass (XPASS) where
    the host supports them.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)


def _collective_timeout_flags() -> str:
    """The collective stuck/terminate timeouts only exist in newer XLA CPU
    builds — older ones treat unknown XLA_FLAGS as fatal."""
    import jax

    if tuple(int(p) for p in jax.__version__.split(".")[:2]) < (0, 5):
        return ""
    return (" --xla_cpu_collective_call_warn_stuck_timeout_seconds=240"
            " --xla_cpu_collective_call_terminate_timeout_seconds=600")


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
        + _collective_timeout_flags()
    )
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


def _solver_mesh():
    """Largest (rows, model) solver mesh this process can build."""
    import jax

    from repro.distributed.meshes import make_solver_mesh

    nd = len(jax.devices())
    shape = (2, 2) if nd >= 4 else ((2, 1) if nd >= 2 else (1, 1))
    return make_solver_mesh(shape)


# ---------------------------------------------------------------------------
# in-process variants — MUST pass (1-device mesh fallback)
# ---------------------------------------------------------------------------


def _mrhs_problem(n=256, d=5, t=3, seed=0):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.krr import KRRProblem

    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    base = KRRProblem(x=x, y=jnp.zeros(n), kernel="rbf", sigma=2.0,
                      lam_unscaled=1e-5, backend="xla")
    w_true = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
    y = base.op.k_lam_matvec(w_true, base.lam)
    return KRRProblem(x=x, y=y, kernel="rbf", sigma=2.0, lam_unscaled=1e-5,
                      backend="xla")


def test_dist_askotch_inprocess_matches_single_device():
    """solve(..., mesh=...) ASkotch with (n, t) RHS converges on the same
    problem the single-device solver handles — the parity acceptance test."""
    from repro.core.solver_api import solve

    prob = _mrhs_problem()
    out = solve(prob, "askotch", mesh=_solver_mesh(), block_size=64, rank=24,
                max_iters=400, eval_every=50, tol=1e-6)
    assert out.w.shape == (256, 3)
    assert out.history[-1]["rel_residual"] < 0.01
    assert len(out.history[-1]["rel_residual_per_head"]) == 3
    pred = out.predict_fn(prob.x[:10])
    assert pred.shape == (10, 3)


def test_dist_pcg_inprocess_matches_single_device():
    """Distributed blocked PCG == single-device blocked PCG (same blocked_cg
    loop, operator matvec swapped) on a (n, t) one-vs-all system."""
    import jax.numpy as jnp

    from repro.core.solver_api import solve

    prob = _mrhs_problem()
    ref = solve(prob, "pcg-nystrom", rank=64, max_iters=200, tol=1e-9)
    out = solve(prob, "pcg-nystrom", mesh=_solver_mesh(), rank=64,
                max_iters=200, tol=1e-9)
    assert out.history[-1]["rel_residual"] < 1e-6
    dw = float(jnp.linalg.norm(out.w - ref.w) / jnp.linalg.norm(ref.w))
    assert dw < 1e-2, dw  # both sit on the true solution (tol 1e-9)
    # 1-D RHS path
    prob1 = _mrhs_problem(t=1)
    out1 = solve(prob1, "cg", mesh=_solver_mesh(), max_iters=300, tol=1e-9)
    assert out1.w.shape == (256, 1)
    assert out1.history[-1]["rel_residual"] < 1e-6


def test_dist_askotch_single_column_rhs():
    """(n, 1)-shaped y (t = 1 as a column) solves like the single-device
    path and keeps its column on the way out."""
    from repro.core.solver_api import solve

    prob = _mrhs_problem(t=1)  # y shape (256, 1)
    assert prob.y.ndim == 2 and prob.t == 1
    out = solve(prob, "askotch", mesh=_solver_mesh(), block_size=64, rank=24,
                max_iters=200, eval_every=50, tol=1e-6)
    assert out.w.shape == (256, 1)
    assert out.history[-1]["rel_residual"] < 0.05
    assert out.predict_fn(prob.x[:7]).shape == (7, 1)


def test_small_mesh_dryrun_inprocess_single_device():
    """Reduced-config lower+compile through the dryrun cell builder on a
    (1, 1) mesh — the sharding-spec machinery without forced devices."""
    from repro.configs.base import get_reduced_config
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_test_mesh
    from repro.models.model_api import ShapeConfig

    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = get_reduced_config("qwen2-1.5b")
    shape = ShapeConfig("train_small", "train", 64, 8)
    compiled = lower_cell(cfg, shape, mesh).compile()
    assert compiled.memory_analysis().temp_size_in_bytes >= 0


def test_elastic_checkpoint_inprocess_single_device(tmp_path):
    """Save row-sharded state, restore under a DIFFERENT sharding layout —
    the elastic-restore path on 1-device meshes."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import checkpointer
    from repro.distributed.jax_compat import make_mesh

    mesh_a = make_mesh((1,), ("data",))
    arr = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
    sharded = jax.device_put(arr, NamedSharding(mesh_a, P("data", None)))
    checkpointer.save(str(tmp_path), 1, {"params": {"w": sharded}})
    mesh_b = make_mesh((1,), ("data",))
    sh_b = {"params": {"w": NamedSharding(mesh_b, P(None, None))}}
    restored, _, _ = checkpointer.restore(str(tmp_path), shardings=sh_b)
    assert np.array_equal(np.asarray(restored["params"]["w"]), np.asarray(arr))


# ---------------------------------------------------------------------------
# subprocess multi-device tests (forced host platform; may time out in
# constrained containers — xfail non-strict, pass where supported)
# ---------------------------------------------------------------------------


@pytest.mark.xfail(strict=False, reason="forced multi-device CPU collectives can time out on constrained hosts; non-strict — XPASSes where supported (the in-process variants above are the hard gate)")
def test_dist_askotch_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.krr_dist import (DistKRRConfig,
            make_dist_askotch_step, init_dist_state)
        from repro.core.krr import KRRProblem
        mesh = make_test_mesh((2, 4), ("data", "model"))
        n, d = 512, 5
        cfg = DistKRRConfig(n=n, d=d, sigma=2.0, lam_unscaled=1e-5,
                            block_size=64, rank=24)
        step, sh = make_dist_askotch_step(mesh, cfg)
        r = np.random.default_rng(0)
        X = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
        base = KRRProblem(x=X, y=jnp.zeros(n), kernel="rbf", sigma=2.0,
                          lam_unscaled=1e-5, backend="xla")
        y = base.k_lam_matvec(jnp.asarray(r.standard_normal(n).astype(np.float32)))
        prob = KRRProblem(x=X, y=y, kernel="rbf", sigma=2.0,
                          lam_unscaled=1e-5, backend="xla")
        state = init_dist_state(cfg)
        with mesh:
            jstep = jax.jit(step)
            Xs = jax.device_put(X, sh["x"]); ys = jax.device_put(y, sh["y"])
            state = jax.device_put(state, sh["state"])
            for _ in range(200):
                state = jstep(state, Xs, ys)
                jax.block_until_ready(state.w)
        print(json.dumps({"rel": float(prob.relative_residual(state.w))}))
    """)
    rel = json.loads(out.strip().splitlines()[-1])["rel"]
    assert rel < 0.01, rel  # single-device reaches ~1e-3 in 200 iters


@pytest.mark.xfail(strict=False, reason="forced multi-device CPU collectives can time out on constrained hosts; non-strict — XPASSes where supported (the in-process variants above are the hard gate)")
def test_small_mesh_dryrun_two_archs():
    """Reduced-config lower+compile through the dryrun cell builder on a
    (2, 4) mesh — proves the sharding spec machinery end to end."""
    out = run_py("""
        import json, jax
        from repro.launch.mesh import make_test_mesh
        from repro.launch.dryrun import lower_cell
        from repro.configs.base import get_reduced_config
        from repro.models.model_api import ShapeConfig
        mesh = make_test_mesh((2, 4), ("data", "model"))
        results = {}
        shapes = [ShapeConfig("train_small", "train", 64, 8),
                  ShapeConfig("decode_small", "decode", 64, 8)]
        for arch in ("qwen2-1.5b", "rwkv6-1.6b"):
            cfg = get_reduced_config(arch)
            for shape in shapes:
                lowered = lower_cell(cfg, shape, mesh)
                compiled = lowered.compile()
                ma = compiled.memory_analysis()
                results[f"{arch}:{shape.name}"] = int(ma.temp_size_in_bytes)
        print(json.dumps(results))
    """, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert len(res) == 4
    assert all(v >= 0 for v in res.values())


@pytest.mark.xfail(strict=False, reason="forced multi-device CPU collectives can time out on constrained hosts; non-strict — XPASSes where supported (the in-process variants above are the hard gate)")
def test_elastic_checkpoint_across_meshes(tmp_path):
    """Save sharded state from a (4,) mesh; restore onto a (2,) mesh."""
    out = run_py(f"""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import checkpointer
        from repro.distributed.jax_compat import make_mesh
        mesh_a = make_mesh((4,), ("data",))
        arr = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
        sharded = jax.device_put(arr, NamedSharding(mesh_a, P("data", None)))
        checkpointer.save({str(tmp_path)!r}, 1, {{"params": {{"w": sharded}}}})
        mesh_b = make_mesh((2,), ("data",))
        sh_b = {{"params": {{"w": NamedSharding(mesh_b, P("data", None))}}}}
        restored, _, _ = checkpointer.restore({str(tmp_path)!r}, shardings=sh_b)
        w = restored["params"]["w"]
        ok = bool(np.array_equal(np.asarray(w), np.asarray(arr)))
        nshards = len(w.sharding.device_set)
        print(json.dumps({{"ok": ok, "nshards": nshards}}))
    """, devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["nshards"] == 2


def test_fault_injection_restart(tmp_path):
    """Training survives an injected failure via checkpoint-restart and the
    post-restart trajectory is deterministic (same data cursor)."""
    import argparse

    from repro.launch import train as train_mod

    args = argparse.Namespace(
        arch="qwen2-1.5b", reduced=True, steps=30, batch=4, seq=16, lr=1e-3,
        seed=0, ckpt_dir=str(tmp_path), ckpt_every=10, log_every=5,
        resume=False, inject_failure=17, straggler_factor=3.0,
    )
    res = train_mod.run(args)
    assert res["final_step"] == 30
    # clean run for comparison
    args2 = argparse.Namespace(**{**vars(args), "ckpt_dir": str(tmp_path) + "_clean",
                                  "inject_failure": -1})
    res2 = train_mod.run(args2)
    final = {r["step"]: r["loss"] for r in res["history"]}
    final2 = {r["step"]: r["loss"] for r in res2["history"]}
    # the last logged loss must agree to float tolerance (bit-exact data resume)
    assert abs(final[30] - final2[30]) < 1e-4, (final[30], final2[30])


@pytest.mark.slow
def test_production_mesh_krr_dryrun_compiles():
    """The paper-workload cell on the real 512-device multi-pod mesh."""
    out = run_py("""
        import json
        from repro.launch.mesh import make_production_mesh
        from repro.launch.dryrun import lower_krr_cell
        mesh = make_production_mesh(multi_pod=True)
        lowered, _ = lower_krr_cell(mesh)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print(json.dumps({"temp": int(ma.temp_size_in_bytes)}))
    """, devices=512, timeout=1200)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["temp"] < 16 * 2**30
