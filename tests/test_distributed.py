"""Distributed paths (subprocess-isolated: these force a multi-device host
platform, which must not leak into other tests' single-device world).

  * shard_map distributed ASkotch == single-device ASkotch quality
  * small-mesh dry-run of two archs (reduced configs) lowers + compiles
  * elastic checkpoint: save on mesh A, restore on mesh B
  * fault injection: train loop restarts from checkpoint and finishes
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        "--xla_cpu_collective_call_warn_stuck_timeout_seconds=240 "
        "--xla_cpu_collective_call_terminate_timeout_seconds=600"
    )
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.xfail(strict=False, reason="multi-device CPU collectives time out in constrained containers (known-failing since seed); passes where the host supports them")
def test_dist_askotch_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.krr_dist import (DistKRRConfig,
            make_dist_askotch_step, init_dist_state)
        from repro.core.krr import KRRProblem
        mesh = make_test_mesh((2, 4), ("data", "model"))
        n, d = 512, 5
        cfg = DistKRRConfig(n=n, d=d, sigma=2.0, lam_unscaled=1e-5,
                            block_size=64, rank=24)
        step, sh = make_dist_askotch_step(mesh, cfg)
        r = np.random.default_rng(0)
        X = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
        base = KRRProblem(x=X, y=jnp.zeros(n), kernel="rbf", sigma=2.0,
                          lam_unscaled=1e-5, backend="xla")
        y = base.k_lam_matvec(jnp.asarray(r.standard_normal(n).astype(np.float32)))
        prob = KRRProblem(x=X, y=y, kernel="rbf", sigma=2.0,
                          lam_unscaled=1e-5, backend="xla")
        state = init_dist_state(cfg)
        with mesh:
            jstep = jax.jit(step)
            Xs = jax.device_put(X, sh["x"]); ys = jax.device_put(y, sh["y"])
            state = jax.device_put(state, sh["state"])
            for _ in range(200):
                state = jstep(state, Xs, ys)
                jax.block_until_ready(state.w)
        print(json.dumps({"rel": float(prob.relative_residual(state.w))}))
    """)
    rel = json.loads(out.strip().splitlines()[-1])["rel"]
    assert rel < 0.01, rel  # single-device reaches ~1e-3 in 200 iters


@pytest.mark.xfail(strict=False, reason="multi-device CPU collectives time out in constrained containers (known-failing since seed); passes where the host supports them")
def test_small_mesh_dryrun_two_archs():
    """Reduced-config lower+compile through the dryrun cell builder on a
    (2, 4) mesh — proves the sharding spec machinery end to end."""
    out = run_py("""
        import json, jax
        from repro.launch.mesh import make_test_mesh
        from repro.launch.dryrun import lower_cell
        from repro.configs.base import get_reduced_config
        from repro.models.model_api import ShapeConfig
        mesh = make_test_mesh((2, 4), ("data", "model"))
        results = {}
        shapes = [ShapeConfig("train_small", "train", 64, 8),
                  ShapeConfig("decode_small", "decode", 64, 8)]
        for arch in ("qwen2-1.5b", "rwkv6-1.6b"):
            cfg = get_reduced_config(arch)
            for shape in shapes:
                lowered = lower_cell(cfg, shape, mesh)
                compiled = lowered.compile()
                ma = compiled.memory_analysis()
                results[f"{arch}:{shape.name}"] = int(ma.temp_size_in_bytes)
        print(json.dumps(results))
    """, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert len(res) == 4
    assert all(v >= 0 for v in res.values())


@pytest.mark.xfail(strict=False, reason="multi-device CPU collectives time out in constrained containers (known-failing since seed); passes where the host supports them")
def test_elastic_checkpoint_across_meshes(tmp_path):
    """Save sharded state from a (4,) mesh; restore onto a (2,) mesh."""
    out = run_py(f"""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import checkpointer
        devs = jax.devices()
        mesh_a = jax.make_mesh((4,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,))
        arr = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
        sharded = jax.device_put(arr, NamedSharding(mesh_a, P("data", None)))
        checkpointer.save({str(tmp_path)!r}, 1, {{"params": {{"w": sharded}}}})
        mesh_b = jax.make_mesh((2,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,))
        sh_b = {{"params": {{"w": NamedSharding(mesh_b, P("data", None))}}}}
        restored, _, _ = checkpointer.restore({str(tmp_path)!r}, shardings=sh_b)
        w = restored["params"]["w"]
        ok = bool(np.array_equal(np.asarray(w), np.asarray(arr)))
        nshards = len(w.sharding.device_set)
        print(json.dumps({{"ok": ok, "nshards": nshards}}))
    """, devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["nshards"] == 2


def test_fault_injection_restart(tmp_path):
    """Training survives an injected failure via checkpoint-restart and the
    post-restart trajectory is deterministic (same data cursor)."""
    import argparse

    sys.path.insert(0, SRC)
    from repro.launch import train as train_mod

    args = argparse.Namespace(
        arch="qwen2-1.5b", reduced=True, steps=30, batch=4, seq=16, lr=1e-3,
        seed=0, ckpt_dir=str(tmp_path), ckpt_every=10, log_every=5,
        resume=False, inject_failure=17, straggler_factor=3.0,
    )
    res = train_mod.run(args)
    assert res["final_step"] == 30
    # clean run for comparison
    args2 = argparse.Namespace(**{**vars(args), "ckpt_dir": str(tmp_path) + "_clean",
                                  "inject_failure": -1})
    res2 = train_mod.run(args2)
    final = {r["step"]: r["loss"] for r in res["history"]}
    final2 = {r["step"]: r["loss"] for r in res2["history"]}
    # the last logged loss must agree to float tolerance (bit-exact data resume)
    assert abs(final[30] - final2[30]) < 1e-4, (final[30], final2[30])


@pytest.mark.slow
def test_production_mesh_krr_dryrun_compiles():
    """The paper-workload cell on the real 512-device multi-pod mesh."""
    out = run_py("""
        import json
        from repro.launch.mesh import make_production_mesh
        from repro.launch.dryrun import lower_krr_cell
        mesh = make_production_mesh(multi_pod=True)
        lowered, _ = lower_krr_cell(mesh)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print(json.dumps({"temp": int(ma.temp_size_in_bytes)}))
    """, devices=512, timeout=1200)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["temp"] < 16 * 2**30
