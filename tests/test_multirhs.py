"""Multi-RHS (one-vs-all) solves: per-column parity with independent
single-RHS solves for askotch/pcg/direct, per-head residual reporting, the
KernelOperator layer, and the one-vs-all classification round trip through
solver_api.solve -> predict_fn -> evaluate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver_api
from repro.core.askotch import ASkotchConfig, solve
from repro.core.direct import solve_direct
from repro.core.get_l import get_l_dense
from repro.core.krr import KRRProblem, evaluate, evaluate_per_head
from repro.core.nystrom import (
    nystrom,
    stable_inv_apply,
    stable_inv_apply_setup,
    woodbury_inv_apply,
    woodbury_invsqrt_apply,
)
from repro.core.operator import KernelOperator, as_multirhs, maybe_squeeze
from repro.core.pcg import solve_pcg
from repro.data import synthetic

N, D, T = 500, 5, 3


@pytest.fixture(scope="module")
def problem():
    """(n, t) problem with a known generating W so every column is solvable."""
    r = np.random.default_rng(11)
    x = jnp.asarray(r.standard_normal((N, D)).astype(np.float32))
    base = KRRProblem(x=x, y=jnp.zeros(N), kernel="rbf", sigma=1.5,
                      lam_unscaled=1e-3, backend="xla")
    w_true = jnp.asarray(r.standard_normal((N, T)).astype(np.float32))
    y = base.op.k_lam_matvec(w_true, base.lam)
    return KRRProblem(x=x, y=y, kernel="rbf", sigma=1.5, lam_unscaled=1e-3,
                      backend="xla")


def _column_problem(problem, j):
    return KRRProblem(x=problem.x, y=problem.y[:, j], kernel=problem.kernel,
                      sigma=problem.sigma, lam_unscaled=problem.lam_unscaled,
                      backend=problem.backend)


# ---------------------------------------------------------------------------
# KernelOperator
# ---------------------------------------------------------------------------


def test_operator_matvec_multirhs(problem):
    op = problem.op
    k = np.asarray(op.block(problem.x))
    v = np.asarray(problem.y)
    np.testing.assert_allclose(np.asarray(op.matvec(problem.y)), k @ v,
                               rtol=2e-4, atol=2e-4)
    # 1-D column == column of the 2-D result
    col = np.asarray(op.matvec(problem.y[:, 0]))
    np.testing.assert_allclose(col, (k @ v)[:, 0], rtol=2e-4, atol=2e-4)


def test_operator_restrict_and_trace(problem):
    op = problem.op
    idx = jnp.arange(50)
    sub = op.restrict(idx)
    assert sub.n == 50 and sub.kernel == op.kernel
    np.testing.assert_allclose(np.asarray(sub.block(sub.x)),
                               np.asarray(op.block(problem.x[:50])), atol=1e-6)
    assert float(op.trace_est()) == problem.n  # unit-diagonal kernels


def test_as_multirhs_roundtrip():
    v = jnp.ones((7,))
    v2, squeeze = as_multirhs(v)
    assert v2.shape == (7, 1) and squeeze
    assert maybe_squeeze(v2, squeeze).shape == (7,)
    m = jnp.ones((7, 3))
    m2, squeeze = as_multirhs(m)
    assert m2.shape == (7, 3) and not squeeze


# ---------------------------------------------------------------------------
# Woodbury / get_L multi-RHS blocks
# ---------------------------------------------------------------------------


def test_woodbury_applies_batch_over_columns():
    # local generator: draining the shared session `rng` fixture here would
    # shift the stream for every later test in the session
    rng = np.random.default_rng(7)
    p, r, t = 64, 16, 5
    f = rng.standard_normal((p, 24)).astype(np.float32)
    fac = nystrom(jax.random.PRNGKey(1), jnp.asarray(f @ f.T / 24), r)
    rho = jnp.float32(0.3)
    g = jnp.asarray(rng.standard_normal((p, t)).astype(np.float32))
    batched = np.asarray(woodbury_inv_apply(fac, rho, g))
    chol = stable_inv_apply_setup(fac, rho)
    batched_s = np.asarray(stable_inv_apply(fac, rho, chol, g))
    batched_h = np.asarray(woodbury_invsqrt_apply(fac, rho, g))
    for j in range(t):
        np.testing.assert_allclose(
            np.asarray(woodbury_inv_apply(fac, rho, g[:, j])), batched[:, j],
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(stable_inv_apply(fac, rho, chol, g[:, j])), batched_s[:, j],
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(woodbury_invsqrt_apply(fac, rho, g[:, j])), batched_h[:, j],
            rtol=1e-5, atol=1e-6)


def test_get_l_block_powering_matches_single_probe():
    rng = np.random.default_rng(8)
    p, r = 96, 32
    f = rng.standard_normal((p, 48)).astype(np.float32)
    kbb = jnp.asarray(f @ f.T / 48)
    lam = jnp.float32(0.01)
    fac = nystrom(jax.random.PRNGKey(0), kbb, r)
    rho = lam + fac.lam[-1]
    one = float(get_l_dense(jax.random.PRNGKey(1), kbb, lam, fac, rho, num_iters=30))
    blk = float(get_l_dense(jax.random.PRNGKey(2), kbb, lam, fac, rho,
                            num_iters=10, num_probes=4))
    # block powering reaches the same top eigenvalue in fewer rounds
    assert blk == pytest.approx(one, rel=0.05)


# ---------------------------------------------------------------------------
# per-column parity: (n, t) solve vs t independent single-RHS solves
# ---------------------------------------------------------------------------


def test_direct_multirhs_parity(problem):
    w = np.asarray(solve_direct(problem))
    assert w.shape == (N, T)
    for j in range(T):
        wj = np.asarray(solve_direct(_column_problem(problem, j)))
        np.testing.assert_allclose(w[:, j], wj, rtol=1e-6, atol=1e-6)


def test_askotch_multirhs_parity(problem):
    """Same seed => identical block/preconditioner sequence, so the batched
    iterates must match the t independent solves to f32 roundoff."""
    cfg = ASkotchConfig(block_size=128, rank=64, backend="xla")
    res = solve(problem, cfg, max_iters=25, eval_every=25, seed=0)
    assert res.w.shape == (N, T)
    for j in range(T):
        rj = solve(_column_problem(problem, j), cfg, max_iters=25, eval_every=25,
                   seed=0)
        err = float(jnp.linalg.norm(res.w[:, j] - rj.w) / jnp.linalg.norm(rj.w))
        assert err <= 1e-5, (j, err)


def test_pcg_multirhs_parity(problem):
    res = solve_pcg(problem, precond="nystrom", rank=64, max_iters=100, tol=1e-11,
                    seed=0)
    assert res.w.shape == (N, T)
    w_star = solve_direct(problem)
    for j in range(T):
        rj = solve_pcg(_column_problem(problem, j), precond="nystrom", rank=64,
                       max_iters=100, tol=1e-11, seed=0)
        # both runs converge to the direct solution; compare against it
        err = float(jnp.linalg.norm(res.w[:, j] - rj.w) / jnp.linalg.norm(rj.w))
        assert err < 1e-4, (j, err)
        err_star = float(
            jnp.linalg.norm(res.w[:, j] - w_star[:, j]) / jnp.linalg.norm(w_star[:, j])
        )
        assert err_star < 1e-3, (j, err_star)


# ---------------------------------------------------------------------------
# per-head reporting
# ---------------------------------------------------------------------------


def test_per_head_residual_reporting(problem):
    # moderate tol: the recursively-updated CG residual still tracks the true
    # residual here (they only part ways at the f32 floor)
    res = solve_pcg(problem, precond="nystrom", rank=64, max_iters=60, tol=1e-5)
    rec = res.history[-1]
    heads = rec["rel_residual_per_head"]
    assert len(heads) == T
    # aggregate Frobenius residual is consistent with the per-head residuals
    agg, per_head = problem.residual_report(res.w)
    assert rec["rel_residual"] == pytest.approx(float(agg), rel=0.05, abs=1e-8)
    np.testing.assert_allclose(heads, np.asarray(per_head), rtol=0.05, atol=1e-8)
    assert min(heads) >= 0


def test_askotch_history_has_heads(problem):
    cfg = ASkotchConfig(block_size=128, rank=64, backend="xla")
    res = solve(problem, cfg, max_iters=20, eval_every=10)
    assert all(len(r["rel_residual_per_head"]) == T for r in res.history)
    # sketch_res tracks one value per head
    assert res.history[-1]["sketch_res"] >= 0


def test_solver_api_unknown_option_errors(problem):
    with pytest.raises(ValueError, match="unknown option.*askotch.*accepted"):
        solver_api.solve(problem, "askotch", bogus_knob=3)
    with pytest.raises(ValueError, match="unknown option.*pcg-nystrom"):
        solver_api.solve(problem, "pcg-nystrom", block_size=10)
    with pytest.raises(ValueError, match="unknown method"):
        solver_api.solve(problem, "not-a-method")


# ---------------------------------------------------------------------------
# one-vs-all round trip through the unified API
# ---------------------------------------------------------------------------


def test_one_vs_all_roundtrip():
    x_tr, y_tr, lab_tr, x_te, y_te, lab_te = synthetic.krr_one_vs_all(
        0, 600, 6, num_classes=4, n_test=200)
    assert y_tr.shape == (600, 4)
    prob = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.5,
                      lam_unscaled=1e-5, backend="xla")
    out = solver_api.solve(prob, "askotch", block_size=128, rank=64,
                           max_iters=150, eval_every=50)
    assert out.w.shape == (600, 4)
    assert out.info["t"] == 4
    assert len(out.info["rel_residual_per_head"]) == 4
    pred = out.predict_fn(x_te)
    assert pred.shape == (200, 4)
    m = evaluate(pred, y_te)  # top-1 argmax accuracy for t > 1
    assert float(m.accuracy) > 0.8, float(m.accuracy)
    top1 = float(jnp.mean((jnp.argmax(pred, axis=1) == lab_te).astype(jnp.float32)))
    assert top1 == pytest.approx(float(m.accuracy))
    mh = evaluate_per_head(pred, y_te)
    assert mh.accuracy.shape == (4,)
    assert float(jnp.min(mh.accuracy)) > 0.5


def test_krr_predict_server_buckets(problem):
    from repro.serving.krr_serve import make_krr_predict_fn

    w = solve_direct(problem)
    serve = make_krr_predict_fn(problem.op, w, max_batch=256)
    r = np.random.default_rng(2)
    for q in (1, 7, 33, 300):  # odd sizes, bucket boundaries, > max_batch
        xq = jnp.asarray(r.standard_normal((q, D)).astype(np.float32))
        got = np.asarray(serve(xq))
        want = np.asarray(problem.predict(w, xq))
        assert got.shape == (q, T)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_evaluate_single_head_unchanged():
    m = evaluate(jnp.asarray([1.0, -1.0, 2.0]), jnp.asarray([1.0, 1.0, 2.0]))
    assert m.accuracy == pytest.approx(2 / 3)
    # (n, 1) behaves like (n,): sign accuracy, not argmax
    m1 = evaluate(jnp.asarray([[1.0], [-1.0], [2.0]]),
                  jnp.asarray([[1.0], [1.0], [2.0]]))
    assert m1.accuracy == pytest.approx(2 / 3)
