"""Baseline solvers (paper comparison set): PCG variants, Falkon, EigenPro,
RPCholesky — correctness vs direct solve + the paper's qualitative orderings."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.direct import solve_direct
from repro.core.eigenpro import solve_eigenpro
from repro.core.falkon import falkon_predict, solve_falkon
from repro.core.krr import KRRProblem, evaluate
from repro.core.pcg import solve_pcg
from repro.core.rpcholesky import rp_cholesky
from repro.core.solver_api import METHODS, solve as solve_any


@pytest.fixture(scope="module")
def problem():
    r = np.random.default_rng(7)
    n, d = 900, 5
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    f = np.sin(2 * np.asarray(x[:, 0])) + 0.3 * np.asarray(x[:, 1])
    y = jnp.asarray((f + 0.05 * r.standard_normal(n)).astype(np.float32))
    return KRRProblem(x=x, y=y, kernel="rbf", sigma=1.5, lam_unscaled=1e-5,
                      backend="xla")


def test_pcg_nystrom_converges_to_direct(problem):
    w_star = solve_direct(problem)
    res = solve_pcg(problem, precond="nystrom", rank=80, max_iters=120, tol=1e-9)
    err = float(jnp.linalg.norm(res.w - w_star) / jnp.linalg.norm(w_star))
    assert err < 1e-2
    assert res.history[-1]["rel_residual"] < 1e-6


def test_pcg_rpcholesky_converges(problem):
    res = solve_pcg(problem, precond="rpcholesky", rank=80, max_iters=120, tol=1e-9)
    assert res.history[-1]["rel_residual"] < 1e-5


def test_preconditioning_beats_plain_cg(problem):
    it = {}
    for precond in ("identity", "nystrom"):
        res = solve_pcg(problem, precond=precond, rank=80, max_iters=150, tol=1e-6)
        it[precond] = res.iters
    assert it["nystrom"] <= it["identity"]


def test_rpcholesky_factor_quality(problem):
    from repro.core.operator import KernelOperator

    n = 300
    x = problem.x[:n]
    op = KernelOperator(x=x, kernel="rbf", sigma=1.5, backend="xla")
    f, pivots = rp_cholesky(jax.random.PRNGKey(0), op, 60)
    k = np.asarray(op.block(x))
    approx = np.asarray(f) @ np.asarray(f).T
    # residual trace must shrink well below trace(K) = n
    assert np.trace(k - approx) < 0.5 * n
    assert len(np.unique(np.asarray(pivots))) > 40  # mostly distinct pivots


def test_falkon_solves_inducing_system(problem):
    res = solve_falkon(problem, m=250, max_iters=80)
    # f32 CG floor ~1e-4/1e-5 (the paper runs Falkon in f64 — App. C.3)
    assert res.history[-1]["rel_residual"] < 1e-3
    # predictive quality close to full KRR (paper: full >= inducing)
    r = np.random.default_rng(1)
    xt = jnp.asarray(r.standard_normal((200, 5)).astype(np.float32))
    w_star = solve_direct(problem)
    full_pred = problem.predict(w_star, xt)
    ind_pred = falkon_predict(problem, res, xt)
    gap = float(jnp.mean(jnp.abs(full_pred - ind_pred)))
    assert gap < 0.3


def test_eigenpro_reduces_residual(problem):
    res = solve_eigenpro(problem, rank=60, subsample=400, epochs=6, eval_every=20)
    assert res.history, "no eval points"
    assert res.history[-1]["rel_residual"] < 0.9
    # downward trend overall
    assert res.history[-1]["rel_residual"] < res.history[0]["rel_residual"]


def test_unified_api_all_methods(problem):
    for method in METHODS:
        kw = {}
        if method == "falkon":
            kw = {"m": 150, "max_iters": 30}
        elif method == "eigenpro":
            kw = {"rank": 40, "subsample": 300, "epochs": 2}
        elif method.startswith("pcg") or method == "cg":
            kw = {"max_iters": 30}
        elif method in ("askotch", "skotch"):
            kw = {"block_size": 128, "rank": 64, "max_iters": 40, "eval_every": 40}
        out = solve_any(problem, method, **kw)
        assert out.w.shape[0] in (problem.n, 150)
        pred = out.predict_fn(problem.x[:50])
        assert np.isfinite(np.asarray(pred)).all()


def test_full_krr_beats_inducing_points_default(problem):
    """The paper's core claim, test-scale: ASkotch full-KRR predictions match
    direct full-KRR better than a small-m Falkon does."""
    r = np.random.default_rng(5)
    xt = jnp.asarray(r.standard_normal((300, 5)).astype(np.float32))
    w_star = solve_direct(problem)
    ref = problem.predict(w_star, xt)

    out_a = solve_any(problem, "askotch", block_size=220, rank=100,
                      max_iters=300, eval_every=100)
    full_gap = float(jnp.mean(jnp.abs(out_a.predict_fn(xt) - ref)))

    out_f = solve_any(problem, "falkon", m=60, max_iters=60)
    ind_gap = float(jnp.mean(jnp.abs(out_f.predict_fn(xt) - ref)))
    assert full_gap < ind_gap, (full_gap, ind_gap)


def test_metrics():
    m = evaluate(jnp.asarray([1.0, -1.0, 2.0]), jnp.asarray([1.0, 1.0, 2.0]))
    assert m.accuracy == pytest.approx(2 / 3)
    assert m.mae == pytest.approx(2 / 3)
