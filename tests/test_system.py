"""End-to-end behaviour tests for the paper's system: solve -> predict ->
metrics through the public API, plus the launchers' happy paths."""

import argparse
import json
import subprocess
import sys
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krr import KRRProblem, evaluate
from repro.core.solver_api import solve as solve_any
from repro.data import synthetic

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_end_to_end_regression_task():
    """The paper's workflow at test scale: data -> ASkotch (default hparams,
    §3.2) -> predictions beating a constant baseline by a wide margin."""
    x_tr, y_tr, x_te, y_te = synthetic.krr_regression(0, 3000, 8, 500)
    prob = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.5, lam_unscaled=1e-6,
                      backend="xla")
    out = solve_any(prob, "askotch", max_iters=250, eval_every=125)
    pred = out.predict_fn(x_te)
    m = evaluate(pred, y_te)
    base_rmse = float(jnp.std(y_te))
    assert float(m.rmse) < 0.45 * base_rmse, (float(m.rmse), base_rmse)


def test_end_to_end_classification_task():
    x_tr, y_tr, x_te, y_te = synthetic.krr_classification(1, 3000, 8, 500)
    prob = KRRProblem(x=x_tr, y=y_tr, kernel="laplacian", sigma=3.0,
                      lam_unscaled=1e-6, backend="xla")
    out = solve_any(prob, "askotch", max_iters=250, eval_every=125)
    m = evaluate(out.predict_fn(x_te), y_te)
    assert float(m.accuracy) > 0.8, float(m.accuracy)


def test_taxi_like_workload_matern():
    x, y = synthetic.taxi_like(0, 2000, 9)
    prob = KRRProblem(x=x[:1600], y=y[:1600], kernel="matern52", sigma=3.0,
                      lam_unscaled=1e-6, backend="xla")
    out = solve_any(prob, "askotch", block_size=160, rank=80,
                    max_iters=200, eval_every=100)
    pred = out.predict_fn(x[1600:])
    m = evaluate(pred, y[1600:])
    assert float(m.rmse) < float(jnp.std(y[1600:]))


def test_krr_solve_launcher_cli():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.krr_solve", "--n", "2000", "--d", "6",
         "--method", "askotch", "--iters", "120", "--dataset", "regression"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["rel_residual"] < 0.5
    assert np.isfinite(rec["test_rmse"])


def test_train_launcher_loss_decreases(tmp_path):
    sys.path.insert(0, SRC)
    from repro.launch import train as train_mod

    args = argparse.Namespace(
        arch="rwkv6-1.6b", reduced=True, steps=25, batch=4, seq=32, lr=3e-3,
        seed=0, ckpt_dir=str(tmp_path), ckpt_every=100, log_every=5,
        resume=False, inject_failure=-1, straggler_factor=3.0,
    )
    res = train_mod.run(args)
    losses = [r["loss"] for r in res["history"]]
    assert losses[-1] < losses[0], losses


def test_serve_launcher_cli():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "llava-next-mistral-7b",
         "--reduced", "--batch", "2", "--prompt-len", "12", "--max-new", "4"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["generated_shape"] == [2, 4]
