"""Differential suite: ``repro.estimators`` vs ``sklearn.kernel_ridge``.

The estimator front end claims sklearn SEMANTICS, not just an sklearn-shaped
API, so every zoo kernel is pinned to ``sklearn.kernel_ridge.KernelRidge``
predictions at rtol 1e-5 for 1-D and multi-output targets (matern52 — which
sklearn's pairwise-kernel registry lacks — goes through sklearn's
``precomputed`` path fed a ``gaussian_process.kernels.Matern(nu=2.5)`` Gram).
Runs under jax x64 (module fixture, restored on exit): the parity claim is
about the MODEL, so the comparison removes f32 solve noise.

Skips deterministically when scikit-learn is absent; the estimators
themselves do not require it (see ``repro.estimators.base``).
"""

import jax
import numpy as np
import pytest

sklearn = pytest.importorskip("sklearn")

from sklearn.base import clone
from sklearn.gaussian_process.kernels import Matern
from sklearn.kernel_ridge import KernelRidge as SkKernelRidge

from repro.estimators import KernelRidge, KernelRidgeCV, MultipleKernelRidgeCV


@pytest.fixture(scope="module", autouse=True)
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _data(rng, t=None, n=70, d=6, m=17):
    X = rng.standard_normal((n, d))
    y = rng.standard_normal((n,) if t is None else (n, t))
    Xt = rng.standard_normal((m, d))
    yt = rng.standard_normal((m,) if t is None else (m, t))
    return X, y, Xt, yt


# (zoo name, sklearn pairwise name, shared constructor kwargs) — gamma picked
# away from the 1/n_features default so the translation itself is exercised
PAIRS = [
    ("rbf", "rbf", dict(gamma=0.3)),
    ("laplacian", "laplacian", dict(gamma=0.45)),
    ("linear", "linear", dict()),
    ("polynomial", "polynomial", dict(gamma=0.2)),
    ("sigmoid", "sigmoid", dict(gamma=0.05)),
    ("cosine", "cosine", dict()),
]


@pytest.mark.parametrize("t", [None, 3], ids=["y1d", "multioutput"])
@pytest.mark.parametrize("kern,sk_kern,kw", PAIRS, ids=[p[0] for p in PAIRS])
def test_predict_and_score_match_sklearn(rng, kern, sk_kern, kw, t):
    X, y, Xt, yt = _data(rng, t)
    est = KernelRidge(alpha=0.8, kernel=kern, **kw).fit(X, y)
    sk = SkKernelRidge(alpha=0.8, kernel=sk_kern, **kw).fit(X, y)
    np.testing.assert_allclose(
        np.asarray(est.predict(Xt)), sk.predict(Xt), rtol=1e-5, atol=1e-8
    )
    assert est.score(Xt, yt) == pytest.approx(sk.score(Xt, yt), rel=1e-5)


@pytest.mark.parametrize("t", [None, 2], ids=["y1d", "multioutput"])
def test_matern52_matches_sklearn_precomputed(rng, t):
    """sklearn has no pairwise matern: pin against its precomputed path fed
    the Matern(nu=2.5) Gram at the same length scale."""
    X, y, Xt, yt = _data(rng, t)
    sigma = 1.4
    mk = Matern(nu=2.5, length_scale=sigma)
    est = KernelRidge(alpha=0.5, kernel="matern52", sigma=sigma).fit(X, y)
    sk = SkKernelRidge(alpha=0.5, kernel="precomputed").fit(mk(X), y)
    np.testing.assert_allclose(
        np.asarray(est.predict(Xt)), sk.predict(mk(Xt, X)),
        rtol=1e-5, atol=1e-8,
    )
    assert est.score(Xt, yt) == pytest.approx(
        sk.score(mk(Xt, X), yt), rel=1e-5
    )


def test_precomputed_matches_sklearn_precomputed(rng):
    from repro.core.kernels import kernel_matrix

    X, y, Xt, _ = _data(rng)
    K = np.asarray(kernel_matrix("rbf", X, X, 1.2))
    Kt = np.asarray(kernel_matrix("rbf", Xt, X, 1.2))
    est = KernelRidge(alpha=0.3, kernel="precomputed").fit(K, y)
    sk = SkKernelRidge(alpha=0.3, kernel="precomputed").fit(K, y)
    np.testing.assert_allclose(
        np.asarray(est.predict(Kt)), sk.predict(Kt), rtol=1e-5, atol=1e-8
    )


def test_default_gamma_matches_sklearn(rng):
    """gamma=None must mean sklearn's 1 / n_features, not some other default."""
    X, y, Xt, _ = _data(rng)
    est = KernelRidge(alpha=1.0, kernel="rbf").fit(X, y)
    sk = SkKernelRidge(alpha=1.0, kernel="rbf", gamma=None).fit(X, y)
    np.testing.assert_allclose(
        np.asarray(est.predict(Xt)), sk.predict(Xt), rtol=1e-5, atol=1e-8
    )


def test_cv_refit_matches_sklearn_at_best_params(rng):
    """KernelRidgeCV's winning refit is exactly KernelRidge(best_params_) —
    and therefore exactly sklearn at those params."""
    X, y, Xt, _ = _data(rng)
    cv = KernelRidgeCV(
        alphas=(0.1, 1.0, 10.0), sigmas=(0.7, 1.3), kernel="rbf", cv=3
    ).fit(X, y)
    sk = SkKernelRidge(
        alpha=cv.best_params_["alpha"], kernel="rbf",
        gamma=0.5 / cv.best_params_["sigma"] ** 2,
    ).fit(X, y)
    np.testing.assert_allclose(
        np.asarray(cv.predict(Xt)), sk.predict(Xt), rtol=1e-5, atol=1e-8
    )


# ---------------------------------------------------------------------------
# sklearn ecosystem contract: clone / get_params / set_params and the
# check_estimator-style structural invariants (hand-rolled subset — the full
# checker needs tags these jax-backed estimators don't claim).
# ---------------------------------------------------------------------------

ESTIMATORS = [
    KernelRidge(alpha=0.5, kernel="laplacian", sigma=2.0),
    KernelRidgeCV(alphas=(0.1, 1.0), sigmas=(1.0,), cv=3),
    MultipleKernelRidgeCV(
        kernels=("rbf", "linear"), alphas=(0.1,), sigmas=(1.0,),
        cv=3, n_weight_samples=3,
    ),
]


@pytest.mark.parametrize(
    "est", ESTIMATORS, ids=lambda e: type(e).__name__
)
def test_clone_and_params_round_trip(est):
    c = clone(est)
    assert c is not est
    assert c.get_params() == est.get_params()
    # set_params round-trips and returns self
    assert c.set_params(**c.get_params()) is c
    with pytest.raises(ValueError):
        c.set_params(definitely_not_a_param=1)


@pytest.mark.parametrize(
    "est", ESTIMATORS, ids=lambda e: type(e).__name__
)
def test_estimator_contract_subset(rng, est):
    X, y, Xt, yt = _data(rng, n=40)
    est = clone(est)
    params_before = est.get_params()

    out = est.fit(X, y)
    assert out is est  # fit returns self
    assert est.get_params() == params_before  # fit must not mutate params
    assert est.n_features_in_ == X.shape[1]
    assert hasattr(est, "dual_coef_") and hasattr(est, "X_fit_")

    p = np.asarray(est.predict(Xt))
    assert p.shape == (Xt.shape[0],)
    assert np.isfinite(p).all()
    assert np.isfinite(est.score(Xt, yt))

    # refit on different data fully overwrites the fitted state
    X2, y2, Xt2, _ = _data(rng, t=2, n=30, d=4)
    est.fit(X2, y2)
    assert est.n_features_in_ == 4
    assert np.asarray(est.predict(Xt2)).shape == (Xt2.shape[0], 2)


def test_unfitted_predict_raises(rng):
    with pytest.raises(ValueError, match="not fitted"):
        KernelRidge().predict(rng.standard_normal((3, 2)))


def test_works_inside_sklearn_grid_search(rng):
    """The real compatibility bar: sklearn's own GridSearchCV can drive it."""
    from sklearn.model_selection import GridSearchCV

    X, y, _, _ = _data(rng, n=40)
    gs = GridSearchCV(
        KernelRidge(kernel="rbf"), {"alpha": [0.1, 1.0]}, cv=3,
        error_score="raise",
    ).fit(np.asarray(X), np.asarray(y))
    assert set(gs.best_params_) == {"alpha"}
