"""Shared fixtures.  NOTE: device count is NOT forced here — smoke tests and
benches must see the real single device; only launch/dryrun.py (and the
subprocess-based distributed tests) set xla_force_host_platform_device_count.
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False, help="run slow tests"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
