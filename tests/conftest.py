"""Shared fixtures.  NOTE: device count is NOT forced here — smoke tests and
benches must see the real single device; only launch/dryrun.py (and the
subprocess-based distributed tests) set xla_force_host_platform_device_count.
"""

import random
import zlib

import numpy as np
import pytest


@pytest.fixture
def rng(request):
    """Per-test deterministic rng, seeded from the test's nodeid.

    Function-scoped on purpose: a shared session stream makes every
    consumer's data depend on which tests ran before it — the same test
    then sees different numbers under ``-k`` selection or ``--shuffle-seed``
    reordering, which is exactly the flakiness this fixture removes.  The
    crc32(nodeid) seed keeps each test's draw stable across runs, orderings,
    and subsets.
    """
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False, help="run slow tests"
    )
    parser.addoption(
        "--shuffle-seed", type=int, default=None,
        help="deterministically shuffle test order with this seed "
             "(flake audit: order-dependence shows up as a seed-dependent "
             "failure)",
    )


def pytest_collection_modifyitems(config, items):
    seed = config.getoption("--shuffle-seed")
    if seed is not None:
        random.Random(seed).shuffle(items)
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
