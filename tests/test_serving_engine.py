"""Serving-engine correctness: coalescing is bitwise-invisible, the registry
evicts/hot-swaps safely under load, artifacts round-trip through disk.

The load-bearing property: at f32 each output row of a fused kernel pass
depends only on its own query row, so coalescing k requests into one bucket
pass must be BITWISE-identical to k sequential ``make_krr_predict_fn`` calls
— single-kernel, multi-kernel, and sharded (1-device mesh) alike.
"""

import threading

import numpy as np
import pytest

from repro.serving.engine import (
    ServingEngine,
    bucket_for,
    bucket_sizes,
    load_model_artifact,
    save_model_artifact,
)
from repro.serving.krr_serve import make_krr_predict_fn_from_config

D = 5
T = 3
N = 60

CFG_RBF = {"kernel": "rbf", "sigma": 1.2, "backend": "xla",
           "precision": "f32"}
CFG_MULTI = {"kernel": ["rbf", "laplacian"], "sigma": 0.9,
             "weights": [0.6, 0.4], "backend": "xla", "precision": "f32"}


@pytest.fixture(scope="module")
def model():
    r = np.random.default_rng(3)
    x = r.standard_normal((N, D)).astype(np.float32)
    w = r.standard_normal((N, T)).astype(np.float32)
    return x, w


@pytest.fixture()
def engine():
    eng = ServingEngine(max_batch=64, max_wait_ms=2.0)
    yield eng
    eng.shutdown()


def test_bucket_ladder():
    assert bucket_sizes(64) == (8, 16, 32, 64)
    assert bucket_sizes(48) == (8, 16, 32, 48)  # cap always included
    assert bucket_sizes(8) == (8,)
    assert bucket_for(1, 64) == 8
    assert bucket_for(9, 64) == 16
    assert bucket_for(64, 64) == 64
    assert bucket_for(200, 64) == 64  # capped: served in max_batch chunks


def test_artifact_round_trip(tmp_path, model):
    x, w = model
    path = save_model_artifact(str(tmp_path / "m"), CFG_RBF, x, w)
    cfg, x2, w2 = load_model_artifact(path)
    assert cfg == CFG_RBF
    np.testing.assert_array_equal(x2, x)
    np.testing.assert_array_equal(w2, w)


def test_registry_survives_restart_via_artifacts_dir(tmp_path, model):
    """The persistence loop: export models into one tree, kill the engine,
    restore a fresh engine with load_artifacts_dir — same registry, bitwise
    identical predictions."""
    x, w = model
    save_model_artifact(str(tmp_path / "alpha"), CFG_RBF, x, w)
    save_model_artifact(str(tmp_path / "beta"), CFG_MULTI, x, w)
    (tmp_path / "not_a_model").mkdir()       # ignored: no artifact files
    (tmp_path / "stray.txt").write_text("x")  # ignored: not a directory
    xq = np.random.default_rng(5).standard_normal((7, D)).astype(np.float32)

    first = ServingEngine(max_batch=32, max_wait_ms=1.0)
    try:
        first.load_model("alpha", str(tmp_path / "alpha"))
        f = first.submit("alpha", xq)
        first.drain()
        before = np.asarray(f.result())
    finally:
        first.shutdown()

    restored = ServingEngine(max_batch=32, max_wait_ms=1.0)
    try:
        info = restored.load_artifacts_dir(str(tmp_path))
        assert sorted(info) == ["alpha", "beta"] == restored.models()
        assert info["alpha"]["version"] == 1 and info["alpha"]["d"] == D
        f = restored.submit("alpha", xq)
        restored.drain()
        np.testing.assert_array_equal(np.asarray(f.result()), before)
    finally:
        restored.shutdown()

    eng = ServingEngine(max_batch=32)
    try:
        with pytest.raises(FileNotFoundError, match="no model artifacts"):
            eng.load_artifacts_dir(str(tmp_path / "not_a_model"))
    finally:
        eng.shutdown()


@pytest.mark.parametrize("cfg", [CFG_RBF, CFG_MULTI],
                         ids=["single-kernel", "multi-kernel"])
def test_threaded_clients_bitwise_equal_sequential(engine, model, cfg):
    """Many threads hammering submit() coalesce into shared bucket passes,
    yet every result is bitwise-equal to the sequential predict closure."""
    x, w = model
    engine.register("m", cfg, x, w)
    predict = make_krr_predict_fn_from_config(cfg, x, w, max_batch=64)

    r = np.random.default_rng(7)
    queries = [
        r.standard_normal((int(r.integers(1, 20)), D)).astype(np.float32)
        for _ in range(40)
    ]
    expected = [np.asarray(predict(q)) for q in queries]

    results: list = [None] * len(queries)

    def client(lo, hi):
        for i in range(lo, hi):
            results[i] = engine.predict("m", queries[i])

    threads = [
        threading.Thread(target=client, args=(j * 10, (j + 1) * 10))
        for j in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    engine.drain()
    for got, want in zip(results, expected):
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, want)
    st = engine.stats("m")
    assert st["n_requests"] == len(queries)
    assert st["n_rows"] == sum(q.shape[0] for q in queries)


def test_sharded_model_same_front_end(engine, model):
    """A mesh-bound model serves behind the same submit() surface with
    bitwise-equal results (1-device mesh: same math, sharded plumbing)."""
    from repro.distributed.meshes import make_solver_mesh

    x, w = model
    mesh = make_solver_mesh("1x1")
    info = engine.register("sharded", CFG_RBF, x, w, mesh=mesh)
    assert info["warmed_buckets"] == [8, 16, 32, 64]
    predict = make_krr_predict_fn_from_config(CFG_RBF, x, w, max_batch=64)
    r = np.random.default_rng(11)
    for q in (1, 7, 33):
        xq = r.standard_normal((q, D)).astype(np.float32)
        np.testing.assert_array_equal(
            engine.predict("sharded", xq), np.asarray(predict(xq))
        )


def test_oversized_batch_chunks(engine, model):
    """A single request larger than max_batch is served in chunks, still
    bitwise-equal to the closure."""
    x, w = model
    engine.register("m", CFG_RBF, x, w)
    predict = make_krr_predict_fn_from_config(CFG_RBF, x, w, max_batch=64)
    xq = np.random.default_rng(5).standard_normal((150, D)).astype(np.float32)
    np.testing.assert_array_equal(
        engine.predict("m", xq), np.asarray(predict(xq))
    )


def test_empty_request_resolves_immediately(engine, model):
    x, w = model
    engine.register("m", CFG_RBF, x, w)
    fut = engine.submit("m", np.zeros((0, D), np.float32))
    out = fut.result(timeout=1)
    assert out.shape == (0, T)
    assert out.dtype == np.float32  # follows w.dtype, not hard-coded
    assert fut.latency_ms == 0.0


def test_submit_validation(engine, model):
    x, w = model
    engine.register("m", CFG_RBF, x, w)
    with pytest.raises(KeyError, match="unknown model"):
        engine.submit("nope", np.zeros((2, D), np.float32))
    with pytest.raises(ValueError, match=r"\(q, 5\)"):
        engine.submit("m", np.zeros((2, D + 1), np.float32))
    engine.drain()  # neither error may leak an inflight slot


def test_unknown_precision_rejected(model):
    x, w = model
    bad = dict(CFG_RBF, precision="f16")
    with pytest.raises(ValueError, match="precision"):
        make_krr_predict_fn_from_config(bad, x, w)


def test_lru_eviction_under_budget(model):
    """Registering past max_bytes LRU-evicts the least-recently-used model;
    the in-flight/most-recent ones survive."""
    x, w = model
    one = int(N * D * 4 + N * T * 4)  # f32 x_train + w
    with ServingEngine(max_batch=32, max_wait_ms=1.0,
                       max_bytes=2 * one + 16) as eng:
        eng.register("a", CFG_RBF, x, w)
        eng.register("b", CFG_RBF, x, w)
        eng.predict("a", np.zeros((2, D), np.float32))  # 'a' now most recent
        info = eng.register("c", CFG_RBF, x, w)
        assert info["evicted"] == ["b"]
        assert eng.models() == ["a", "c"]
        assert eng.stats()["evictions"] == 1
    with ServingEngine(max_bytes=one // 2) as tiny:
        with pytest.raises(ValueError, match="budget"):
            tiny.register("big", CFG_RBF, x, w)


def test_hot_swap_under_load(model):
    """Re-registering a name bumps the version; requests submitted before
    the swap finish on the OLD weights, later ones see the new."""
    x, w = model
    w2 = (w * 2.0).astype(np.float32)
    old = make_krr_predict_fn_from_config(CFG_RBF, x, w, max_batch=32)
    new = make_krr_predict_fn_from_config(CFG_RBF, x, w2, max_batch=32)
    r = np.random.default_rng(13)
    with ServingEngine(max_batch=32, max_wait_ms=50.0) as eng:
        info1 = eng.register("m", CFG_RBF, x, w)
        # long max_wait holds the pre-swap request open while we swap
        xq_old = r.standard_normal((3, D)).astype(np.float32)
        fut_old = eng.submit("m", xq_old)
        info2 = eng.register("m", CFG_RBF, x, w2)
        assert (info1["version"], info2["version"]) == (1, 2)
        xq_new = r.standard_normal((4, D)).astype(np.float32)
        out_new = eng.predict("m", xq_new)
        np.testing.assert_array_equal(
            fut_old.result(timeout=10), np.asarray(old(xq_old))
        )
        np.testing.assert_array_equal(out_new, np.asarray(new(xq_new)))


def test_stats_shape(engine, model):
    x, w = model
    engine.register("m", CFG_RBF, x, w)
    engine.predict("m", np.ones((3, D), np.float32))
    st = engine.stats()
    m = st["models"]["m"]
    assert m["compile_cache_depth"] == len(bucket_sizes(64))
    assert m["occupancy"][8] == {"runs": 1, "rows": 3, "fill": 3 / 8}
    assert m["p50_ms"] > 0 and m["qps"] >= 0
    assert st["bytes"] == m["bytes"]


def test_shutdown_rejects_new_work(model):
    x, w = model
    eng = ServingEngine(max_batch=16, max_wait_ms=1.0)
    eng.register("m", CFG_RBF, x, w)
    eng.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        eng.submit("m", np.zeros((1, D), np.float32))
