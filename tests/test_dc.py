"""The divide-and-conquer tier: partitioners, combiners, solve(method="dc").

The contract under test, in the order the ISSUE states it: deterministic
size-balanced partitioners that round-trip through JSON; exact k=1
degeneracy (bit-parity with the plain solver); row-stochastic combiner
weights; a 1-device mesh matching the sequential fallback; and — the point
of the tier — ZERO collective dispatches recorded by the
``repro_collective_dispatch_total`` counter across a whole DC solve.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krr import KRRProblem
from repro.core.solver_api import DC_METHOD_OPTIONS, METHODS, solve
from repro.distributed.dc import (
    COMBINERS,
    collective_dispatch_delta,
    combiner_weights,
    solve_dc,
)
from repro.distributed.partition import (
    PARTITION_KINDS,
    Partition,
    balanced_sizes,
    kmeans_partition,
    make_partition,
    random_partition,
)
from repro.obs import metrics as obs_metrics


def _data(n=240, d=4, seed=0, n_test=40):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((n + n_test, d)).astype(np.float32))
    y = jnp.asarray(r.standard_normal((n + n_test,)).astype(np.float32))
    return x[:n], y[:n], x[n:]


def _problem(n=240, d=4, seed=0, **kw):
    x, y, _ = _data(n, d, seed)
    kw.setdefault("backend", "xla")
    return KRRProblem(x=x, y=y, sigma=1.5, lam_unscaled=1e-4, **kw)


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", PARTITION_KINDS)
def test_partition_deterministic_and_balanced(kind):
    x = np.random.default_rng(7).standard_normal((101, 3)).astype(np.float32)
    a = make_partition(x, 4, kind=kind, seed=3)
    b = make_partition(x, 4, kind=kind, seed=3)
    np.testing.assert_array_equal(a.assignments, b.assignments)
    np.testing.assert_array_equal(a.centers, b.centers)
    # balanced to within one row: 101 over 4 -> (26, 25, 25, 25)
    np.testing.assert_array_equal(np.sort(a.sizes), np.sort(balanced_sizes(101, 4)))
    # a different seed must actually move rows (not a fixed split)
    c = make_partition(x, 4, kind=kind, seed=4)
    assert not np.array_equal(a.assignments, c.assignments)
    # shard_indices: ascending within each shard, a disjoint cover of range(n)
    idx = a.shard_indices()
    assert all(np.all(np.diff(i) > 0) for i in idx if len(i) > 1)
    np.testing.assert_array_equal(np.sort(np.concatenate(idx)), np.arange(101))


def test_kmeans_partition_groups_separated_clusters():
    r = np.random.default_rng(0)
    blobs = np.concatenate([
        r.standard_normal((30, 2)).astype(np.float32) + 20.0 * np.asarray(off)
        for off in ((0, 0), (1, 0), (0, 1))
    ])
    part = kmeans_partition(blobs, 3, seed=1)
    # with well-separated equal blobs the balanced assignment recovers them:
    # each shard is one blob (up to shard relabeling)
    labels = np.repeat(np.arange(3), 30)
    for j in range(3):
        assert len(set(part.assignments[labels == j])) == 1


def test_partition_json_roundtrip():
    x = np.random.default_rng(1).standard_normal((57, 5)).astype(np.float32)
    part = kmeans_partition(x, 3, seed=9)
    back = Partition.from_json(part.to_json())
    np.testing.assert_array_equal(part.assignments, back.assignments)
    np.testing.assert_array_equal(part.centers, back.centers)  # exact: f32<->f64
    assert (back.kind, back.seed) == ("kmeans", 9)
    # and a round-tripped partition drives a solve unchanged
    p = _problem(n=57, d=5, seed=1)
    out = solve(p, "dc", dc_partition=back, dc_method="direct")
    assert out.info["shards"] == 3


def test_partition_k1_is_identity():
    x = np.random.default_rng(2).standard_normal((20, 3)).astype(np.float32)
    for kind in PARTITION_KINDS:
        part = make_partition(x, 1, kind=kind, seed=0)
        np.testing.assert_array_equal(part.shard_indices()[0], np.arange(20))


def test_partition_validation():
    x = np.zeros((10, 2), np.float32)
    with pytest.raises(ValueError, match="invalid"):
        random_partition(x, 0)
    with pytest.raises(ValueError, match="invalid"):
        random_partition(x, 11)
    with pytest.raises(ValueError, match="unknown partition kind"):
        make_partition(x, 2, kind="voronoi")


# ---------------------------------------------------------------------------
# combiners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("combiner", COMBINERS)
def test_combiner_weights_sum_to_one(combiner):
    x = np.random.default_rng(3).standard_normal((90, 4)).astype(np.float32)
    part = kmeans_partition(x, 3, seed=0)
    xq = np.random.default_rng(4).standard_normal((17, 4)).astype(np.float32)
    w = combiner_weights(part, xq, combiner)
    assert w.shape == (17, 3) and np.all(w >= 0)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)


def test_softmax_combiner_favors_nearest_center():
    x = np.concatenate([
        np.zeros((10, 2), np.float32), np.full((10, 2), 30.0, np.float32)
    ])
    part = kmeans_partition(x, 2, seed=0)
    xq = np.asarray([[0.0, 0.0], [30.0, 30.0]], np.float32)
    w = combiner_weights(part, xq, "softmax")
    near = np.argmin(
        ((xq[:, None, :] - part.centers[None]) ** 2).sum(-1), axis=1
    )
    assert np.array_equal(w.argmax(axis=1), near)
    # a sharp temperature turns far-apart blobs into hard assignment
    w_sharp = combiner_weights(part, xq, "softmax", softmax_temp=1.0)
    assert w_sharp.min(axis=1).max() < 1e-6 and w_sharp.max() > 1.0 - 1e-6


# ---------------------------------------------------------------------------
# solve(method="dc")
# ---------------------------------------------------------------------------


def test_dc_k1_bitparity_with_plain_solver():
    p = _problem()
    base = solve(p, "pcg-nystrom", max_iters=120, tol=1e-7, seed=0, rank=40)
    dc = solve(p, "dc", dc_shards=1, dc_method="pcg-nystrom",
               max_iters=120, tol=1e-7, seed=0, rank=40)
    assert jnp.array_equal(base.w, dc.w)  # bitwise, not allclose
    _, _, xt = _data()
    assert jnp.array_equal(base.predict_fn(xt), dc.predict_fn(xt))


def test_dc_records_zero_collective_dispatches():
    p = _problem()
    before = obs_metrics.snapshot()
    out = solve(p, "dc", dc_shards=3, dc_method="askotch", max_iters=30,
                seed=0)
    after = obs_metrics.snapshot()
    assert collective_dispatch_delta(before, after) == 0.0
    assert out.info["collective_dispatches"] == 0.0


def test_dc_one_device_mesh_matches_sequential():
    from repro.distributed.meshes import make_solver_mesh

    p = _problem()
    mesh = make_solver_mesh("1x1")
    seq = solve(p, "dc", dc_shards=3, dc_method="pcg-nystrom", max_iters=60,
                seed=0)
    par = solve(p, "dc", dc_shards=3, dc_method="pcg-nystrom", max_iters=60,
                seed=0, mesh=mesh)
    assert jnp.array_equal(seq.w, par.w)
    _, _, xt = _data()
    np.testing.assert_array_equal(
        np.asarray(seq.predict_fn(xt)), np.asarray(par.predict_fn(xt))
    )
    assert par.info["mesh"] == {"data": 1, "model": 1}
    assert par.info["collective_dispatches"] == 0.0


def test_dc_scattered_weights_match_shard_solves():
    p = _problem()
    out = solve(p, "dc", dc_shards=3, dc_method="direct")
    res = solve_dc(p, shards=3, method="direct")
    for sub, idx in zip(res.shard_outputs, res.partition.shard_indices()):
        np.testing.assert_array_equal(
            np.asarray(out.w)[idx], np.asarray(sub.w)
        )


def test_dc_multirhs_and_multikernel_ride_through():
    r = np.random.default_rng(5)
    x = jnp.asarray(r.standard_normal((150, 4)).astype(np.float32))
    y = jnp.asarray(r.standard_normal((150, 3)).astype(np.float32))
    p = KRRProblem(x=x, y=y, sigma=1.5, lam_unscaled=1e-4, backend="xla")
    out = solve(p, "dc", dc_shards=2, dc_method="pcg-nystrom", max_iters=60,
                kernel=("rbf", "laplacian"), weights=(0.7, 0.3), seed=0)
    assert out.w.shape == (150, 3)
    xt = jnp.asarray(r.standard_normal((11, 4)).astype(np.float32))
    pred = out.predict_fn(xt)
    assert pred.shape == (11, 3) and bool(jnp.all(jnp.isfinite(pred)))


def test_dc_estimator_and_serving_consume_predict_fn():
    # KernelRidge(solver="dc") — the front end needs no DC-specific code
    from repro.estimators import KernelRidge

    x, y, xt = _data()
    est = KernelRidge(
        alpha=0.1, sigma=1.5, solver="dc",
        solver_opts={"dc_shards": 3, "dc_method": "pcg-nystrom",
                     "max_iters": 60, "seed": 0},
    )
    est.fit(np.asarray(x), np.asarray(y))
    pred = est.predict(np.asarray(xt))
    assert pred.shape == (len(xt),) and np.all(np.isfinite(pred))


def test_dc_option_validation():
    p = _problem()
    assert "dc" in METHODS and set(DC_METHOD_OPTIONS) >= {"dc_shards"}
    with pytest.raises(ValueError, match="inner solver"):
        solve(p, "dc", dc_method="dc")
    with pytest.raises(ValueError, match="dc_bogus"):
        solve(p, "dc", dc_bogus=1)
    with pytest.raises(ValueError, match="unknown option"):
        solve(p, "dc", dc_shards=2, dc_method="direct", max_iters=5)
    with pytest.raises(ValueError, match="combiner"):
        solve(p, "dc", dc_combiner="median")
    with pytest.raises(ValueError, match="partition"):
        solve(p, "dc", dc_partition="voronoi")
    with pytest.raises(ValueError, match="precomputed"):
        gram = np.eye(16, dtype=np.float32)
        gp = KRRProblem(x=jnp.asarray(gram), y=jnp.zeros(16),
                        kernel="precomputed")
        solve(gp, "dc", dc_shards=2)
    part = random_partition(np.zeros((10, 2), np.float32), 2)
    with pytest.raises(ValueError, match="covers 10 rows"):
        solve_dc(p, partition=part, method="direct")


def test_dc_telemetry_spans():
    from repro.obs import RingSink, Telemetry

    sink = RingSink(256)
    tel = Telemetry(sink=sink)
    p = _problem()
    solve(p, "dc", dc_shards=2, dc_method="direct", telemetry=tel)
    tel.close()
    names = [e.get("name") for e in sink.events() if e.get("type") == "span"]
    assert "solve/dc" in names
    assert names.count("dc/shard") == 2
