"""Solver correctness: Nystrom, get_L, samplers, Skotch/ASkotch convergence
against the direct solve, SAP references, and the paper's qualitative claims
at test scale (accel >= plain, damped rho works, identity-precond worse)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sap, samplers
from repro.core.askotch import ASkotchConfig, resolve_accel_params, solve, solve_scan
from repro.core.direct import solve_direct
from repro.core.get_l import get_l_dense
from repro.core.krr import KRRProblem
from repro.core.nystrom import (
    nystrom,
    nystrom_dense,
    stable_inv_apply,
    stable_inv_apply_setup,
    woodbury_inv_apply,
    woodbury_invsqrt_apply,
)


@pytest.fixture(scope="module")
def problem():
    r = np.random.default_rng(3)
    n, d = 1200, 6
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    base = KRRProblem(x=x, y=jnp.zeros(n), kernel="rbf", sigma=2.0,
                      lam_unscaled=1e-5, backend="xla")
    w_true = jnp.asarray(r.standard_normal(n).astype(np.float32))
    y = base.k_lam_matvec(w_true)
    return KRRProblem(x=x, y=y, kernel="rbf", sigma=2.0, lam_unscaled=1e-5,
                      backend="xla")


# ---------------------------------------------------------------------------
# Nystrom (Algorithm 4)
# ---------------------------------------------------------------------------


def test_nystrom_approximates_psd(rng):
    p, r = 120, 40
    f = rng.standard_normal((p, 30)).astype(np.float32)  # true rank 30 < r
    m = jnp.asarray(f @ f.T / 30)
    fac = nystrom(jax.random.PRNGKey(0), m, r)
    assert fac.u.shape == (p, r) and fac.lam.shape == (r,)
    assert (np.asarray(fac.lam) >= -1e-6).all()
    assert (np.diff(np.asarray(fac.lam)) <= 1e-3).all()  # descending
    # rank covers the matrix -> near-exact recovery of the spectrum
    true = np.linalg.eigvalsh(np.asarray(m))[::-1]
    np.testing.assert_allclose(np.asarray(fac.lam[:10]), true[:10], rtol=0.02)
    # and of the matrix itself
    np.testing.assert_allclose(
        np.asarray(nystrom_dense(fac)), np.asarray(m), rtol=0.05, atol=0.05
    )


def test_woodbury_inverse_paths_match_dense(rng):
    p, r = 64, 16
    f = rng.standard_normal((p, 24)).astype(np.float32)
    m = jnp.asarray(f @ f.T / 24)
    fac = nystrom(jax.random.PRNGKey(1), m, r)
    rho = jnp.float32(0.3)
    g = jnp.asarray(rng.standard_normal(p).astype(np.float32))
    dense = np.asarray(nystrom_dense(fac)) + 0.3 * np.eye(p)
    want = np.linalg.solve(dense, np.asarray(g))
    got_w = np.asarray(woodbury_inv_apply(fac, rho, g))
    chol = stable_inv_apply_setup(fac, rho)
    got_s = np.asarray(stable_inv_apply(fac, rho, chol, g))
    np.testing.assert_allclose(got_w, want, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got_s, want, rtol=1e-3, atol=1e-4)
    # inverse square root: applying twice == inverse
    half = woodbury_invsqrt_apply(fac, rho, g)
    got_hh = np.asarray(woodbury_invsqrt_apply(fac, rho, half))
    np.testing.assert_allclose(got_hh, want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# get_L (Algorithm 5)
# ---------------------------------------------------------------------------


def test_get_l_estimates_top_eigenvalue(rng):
    p, r = 96, 32
    f = rng.standard_normal((p, 48)).astype(np.float32)
    kbb = jnp.asarray(f @ f.T / 48)
    lam = jnp.float32(0.01)
    fac = nystrom(jax.random.PRNGKey(0), kbb, r)
    rho = lam + fac.lam[-1]
    est = float(get_l_dense(jax.random.PRNGKey(1), kbb, lam, fac, rho, num_iters=30))
    # exact preconditioned smoothness
    dense_pre = np.asarray(nystrom_dense(fac)) + float(rho) * np.eye(p)
    w, v = np.linalg.eigh(dense_pre)
    pinv_half = v @ np.diag(w**-0.5) @ v.T
    mat = pinv_half @ (np.asarray(kbb) + 0.01 * np.eye(p)) @ pinv_half
    want = np.linalg.eigvalsh(mat)[-1]
    assert est == pytest.approx(want, rel=0.05)


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------


def test_uniform_sampler_distinct():
    s = samplers.uniform_sampler(100, 32)
    idx = np.asarray(s(jax.random.PRNGKey(0)))
    assert len(np.unique(idx)) == 32
    assert idx.min() >= 0 and idx.max() < 100


def test_bless_scores_correlate_with_exact(problem):
    """BLESS needs a dictionary >= d_eff(lam); at the scaled-regularization
    regime the paper operates in (lam = n*lam_unsc >> lam_unsc) the capped
    k=O(sqrt n) dictionary resolves the scores well."""
    n = 400
    x = problem.x[:n]
    from repro.core.operator import KernelOperator

    op = KernelOperator(x=x, kernel="rbf", sigma=2.0, backend="xla")
    k = op.block(x)
    lam = jnp.float32(5.0)
    exact = np.asarray(samplers.exact_rls(k, lam))
    approx = np.asarray(
        samplers.approx_rls_bless(jax.random.PRNGKey(0), op, lam=lam, k_cap=120)
    )
    assert approx.shape == (n,)
    assert (approx > 0).all()
    corr = np.corrcoef(exact, approx)[0, 1]
    assert corr > 0.8, corr
    # c-approximation flavor (Def. 3): scores shouldn't grossly UNDERestimate
    assert np.mean(approx >= 0.5 * exact) > 0.95


def test_arls_probs_rounding():
    scores = jnp.asarray(np.array([0.5, 0.25, 0.125, 0.125], np.float32))
    p = np.asarray(samplers.arls_probs(scores))
    assert p.sum() == pytest.approx(1.0)
    assert (p > 0).all()
    assert p[0] >= p[2]  # monotone in scores


# ---------------------------------------------------------------------------
# Skotch / ASkotch convergence (Theorem 18 at test scale)
# ---------------------------------------------------------------------------


def test_askotch_converges_linearly(problem):
    cfg = ASkotchConfig(block_size=160, rank=80, backend="xla")
    res = solve(problem, cfg, max_iters=240, eval_every=60, tol=1e-9)
    rels = [h["rel_residual"] for h in res.history]
    assert rels[-1] < 5e-4
    # monotone-ish geometric decrease across windows
    assert rels[-1] < rels[0] * 0.3


def test_askotch_matches_direct_solution(problem):
    w_star = solve_direct(problem)
    cfg = ASkotchConfig(block_size=240, rank=120, backend="xla")
    res = solve(problem, cfg, max_iters=400, eval_every=100, tol=1e-7)
    err = float(jnp.linalg.norm(res.w - w_star) / jnp.linalg.norm(w_star))
    assert err < 0.05, err


def test_accel_beats_plain_on_average(problem):
    rel = {}
    for accel in (False, True):
        cfg = ASkotchConfig(accelerated=accel, block_size=160, rank=80, backend="xla")
        res = solve(problem, cfg, max_iters=200, eval_every=200)
        rel[accel] = res.history[-1]["rel_residual"]
    assert rel[True] <= rel[False] * 1.5  # accel at least comparable (paper §6.4)


def test_identity_precond_degrades(problem):
    """Paper §6.4: replacing the Nystrom projector with identity hurts."""
    out = {}
    for precond in ("nystrom", "identity"):
        cfg = ASkotchConfig(block_size=160, rank=80, precond=precond, backend="xla")
        res = solve(problem, cfg, max_iters=120, eval_every=120)
        out[precond] = res.history[-1]["rel_residual"]
    assert out["nystrom"] < out["identity"]


def test_arls_sampling_comparable_to_uniform(problem):
    out = {}
    for sampling in ("uniform", "arls"):
        cfg = ASkotchConfig(block_size=160, rank=80, sampling=sampling, backend="xla")
        res = solve(problem, cfg, max_iters=100, eval_every=100)
        out[sampling] = res.history[-1]["rel_residual"]
    # paper §6.4: little to no impact
    assert out["arls"] < out["uniform"] * 3
    assert out["uniform"] < out["arls"] * 3


def test_solve_scan_pure_jit(problem):
    w, res = solve_scan(problem, ASkotchConfig(block_size=160, rank=64, backend="xla"),
                        num_iters=50)
    assert w.shape == (problem.n,)
    assert np.isfinite(np.asarray(res)).all()
    assert float(problem.relative_residual(w)) < 0.5


def test_accel_param_safeguards():
    cfg = ASkotchConfig()
    mu, nu = resolve_accel_params(cfg, n=10_000, lam=5.0)
    assert mu <= nu and mu * nu <= 1.0 + 1e-6


def test_rho_modes(problem):
    for mode in ("damped", "regularization"):
        cfg = ASkotchConfig(block_size=160, rank=64, rho_mode=mode, backend="xla")
        res = solve(problem, cfg, max_iters=60, eval_every=60)
        assert res.history[-1]["rel_residual"] < 0.6


# ---------------------------------------------------------------------------
# exact SAP references (§2.1)
# ---------------------------------------------------------------------------


def test_randomized_newton_converges(problem):
    w = sap.run(problem, sap.make_randomized_newton_step(problem, 160), 120)
    assert float(problem.relative_residual(w)) < 2e-3


def test_nsap_converges(problem):
    mu, nu = 0.01, problem.n / 160
    w = sap.run(problem, sap.make_nsap_step(problem, 160, mu, nu), 120)
    assert float(problem.relative_residual(w)) < 2e-3


def test_kaczmarz_and_cd_make_progress():
    r = np.random.default_rng(0)
    n, d = 200, 4
    x = jnp.asarray(r.standard_normal((n, d)).astype(np.float32))
    base = KRRProblem(x=x, y=jnp.zeros(n), kernel="rbf", sigma=1.5,
                      lam_unscaled=1e-3, backend="xla")
    y = base.k_lam_matvec(jnp.asarray(r.standard_normal(n).astype(np.float32)))
    prob = dataclasses.replace(base, y=y)
    for maker in (sap.make_kaczmarz_step, sap.make_cd_step):
        w = sap.run(prob, maker(prob), 400)
        assert float(prob.relative_residual(w)) < 0.9
