"""Per-kernel correctness: Pallas (interpret mode) and chunked-XLA streaming
vs the dense oracle, swept over shapes, dtypes, and kernel functions —
including a hypothesis property sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels import KERNEL_NAMES, kernel_matrix
from repro.kernels import ops

# The property sweep uses hypothesis when available; without it we fall back
# to a deterministic parametrized sweep so the module always collects and the
# shape/kernel coverage survives.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SHAPES = [
    (7, 13, 1),  # awkward/odd
    (32, 64, 3),
    (129, 257, 2),  # just past tile boundaries
    (256, 300, 4),
]


def _dense(kern, a, b, sigma):
    return np.asarray(kernel_matrix(kern, a, b, sigma))


def _tol(ref, rtol, atol):
    """Tolerances scaled to the reference magnitude: the dot-family kernels
    produce O(10^2..10^4) values (polynomial cubes the dots), where a fixed
    absolute tolerance sized for (0, 1]-range kernels only measures
    cancellation noise."""
    return dict(rtol=rtol, atol=atol * max(1.0, float(np.abs(ref).max())))


@pytest.mark.parametrize("kern", KERNEL_NAMES)
@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_kernel_matvec_allclose(rng, kern, m, n, k, backend):
    d = 11
    a = rng.standard_normal((m, d)).astype(np.float32)
    b = rng.standard_normal((n, d)).astype(np.float32)
    v = rng.standard_normal((n, k)).astype(np.float32)
    sigma = 1.7
    want = _dense(kern, a, b, sigma) @ v
    got = np.asarray(
        ops.kernel_matvec(a, b, v, kernel=kern, sigma=sigma, backend=backend,
                          chunk_a=64, chunk_b=96)
    )
    np.testing.assert_allclose(got, want, **_tol(want, 2e-4, 2e-5))


@pytest.mark.parametrize("kern", KERNEL_NAMES)
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_kernel_block_allclose(rng, kern, backend):
    a = rng.standard_normal((53, 9)).astype(np.float32)
    b = rng.standard_normal((171, 9)).astype(np.float32)
    want = _dense(kern, a, b, 0.9)
    got = np.asarray(ops.kernel_block(a, b, kernel=kern, sigma=0.9, backend=backend))
    np.testing.assert_allclose(got, want, **_tol(want, 2e-4, 2e-5))


@pytest.mark.parametrize("kern", KERNEL_NAMES)
def test_kernel_matvec_1d_vector(rng, kern):
    a = rng.standard_normal((19, 5)).astype(np.float32)
    b = rng.standard_normal((37, 5)).astype(np.float32)
    v = rng.standard_normal(37).astype(np.float32)
    want = _dense(kern, a, b, 1.1) @ v
    for backend in ("xla", "interpret"):
        got = np.asarray(
            ops.kernel_matvec(a, b, v, kernel=kern, sigma=1.1, backend=backend)
        )
        assert got.shape == (19,)
        np.testing.assert_allclose(got, want, **_tol(want, 2e-4, 2e-5))


def test_bf16_inputs_accumulate_f32(rng):
    """bf16 operands must still produce f32-accumulated output."""
    a = rng.standard_normal((33, 8)).astype(np.float32)
    b = rng.standard_normal((65, 8)).astype(np.float32)
    v = rng.standard_normal((65, 2)).astype(np.float32)
    want = _dense("rbf", a, b, 1.3) @ v
    got = np.asarray(
        ops.kernel_matvec(
            jnp.asarray(a, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16),
            jnp.asarray(v, jnp.bfloat16), kernel="rbf", sigma=1.3,
            backend="interpret",
        )
    )
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, rtol=0.07, atol=0.05)


# ---------------------------------------------------------------------------
# Precision policy: every ops.py entry point must return f32 under
# precision="bf16" and stay within bf16-tile error of its f32 result, on both
# CPU backends, for 1-D and (n, t) RHS.  precision="f32" is the exact
# pre-policy behavior (bit-identity is asserted in tests/test_precision.py).
# ---------------------------------------------------------------------------

_BF16_TOL = (0.05, 0.02)  # rtol, atol-per-unit-ref-magnitude (see _tol)


@pytest.mark.parametrize("kern", KERNEL_NAMES)
@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("vshape", ["1d", "2d"])
def test_precision_bf16_matvec(rng, kern, backend, vshape):
    a = rng.standard_normal((33, 7)).astype(np.float32)
    b = rng.standard_normal((67, 7)).astype(np.float32)
    v = rng.standard_normal((67,) if vshape == "1d" else (67, 3)).astype(np.float32)
    f32 = np.asarray(
        ops.kernel_matvec(a, b, v, kernel=kern, sigma=1.2, backend=backend,
                          chunk_a=16, chunk_b=32)
    )
    got = np.asarray(
        ops.kernel_matvec(a, b, v, kernel=kern, sigma=1.2, backend=backend,
                          chunk_a=16, chunk_b=32, precision="bf16")
    )
    assert got.dtype == np.float32 and got.shape == f32.shape
    np.testing.assert_allclose(got, f32, **_tol(f32, *_BF16_TOL))


@pytest.mark.parametrize("kern", KERNEL_NAMES)
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_precision_bf16_block(rng, kern, backend):
    a = rng.standard_normal((21, 5)).astype(np.float32)
    b = rng.standard_normal((43, 5)).astype(np.float32)
    f32 = np.asarray(ops.kernel_block(a, b, kernel=kern, sigma=0.8, backend=backend))
    got = np.asarray(
        ops.kernel_block(a, b, kernel=kern, sigma=0.8, backend=backend,
                         precision="bf16")
    )
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, f32, **_tol(f32, *_BF16_TOL))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("vshape", ["1d", "2d"])
def test_precision_bf16_multi_entry_points(rng, backend, vshape):
    kernels = ("rbf", "laplacian")
    sigmas = (1.0, 1.6)
    a = rng.standard_normal((19, 6)).astype(np.float32)
    b = rng.standard_normal((41, 6)).astype(np.float32)
    t = 1 if vshape == "1d" else 2
    v = rng.standard_normal((41,) if vshape == "1d" else (41, t)).astype(np.float32)
    w = rng.uniform(0.2, 1.0, size=(2,)).astype(np.float32)

    for fn, kw in (
        (ops.kernel_matvec_multi, dict(weights=w)),
        (ops.kernel_matvec_components, {}),
    ):
        f32 = np.asarray(
            fn(a, b, v, kernels=kernels, sigmas=sigmas, backend=backend,
               chunk_a=8, chunk_b=16, **kw)
        )
        got = np.asarray(
            fn(a, b, v, kernels=kernels, sigmas=sigmas, backend=backend,
               chunk_a=8, chunk_b=16, precision="bf16", **kw)
        )
        assert got.dtype == np.float32 and got.shape == f32.shape
        np.testing.assert_allclose(got, f32, **_tol(f32, *_BF16_TOL))

    f32 = np.asarray(
        ops.kernel_block_multi(a, b, kernels=kernels, sigmas=sigmas,
                               weights=(0.5, 0.5), backend=backend)
    )
    got = np.asarray(
        ops.kernel_block_multi(a, b, kernels=kernels, sigmas=sigmas,
                               weights=(0.5, 0.5), backend=backend,
                               precision="bf16")
    )
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, f32, **_tol(f32, *_BF16_TOL))


def test_precision_rejects_unknown(rng):
    a = rng.standard_normal((4, 3)).astype(np.float32)
    v = rng.standard_normal((4,)).astype(np.float32)
    with pytest.raises(ValueError, match="unknown precision"):
        ops.kernel_matvec(a, a, v, backend="xla", precision="f16")


def test_sigma_dtype_canonicalized(rng):
    """numpy/jnp scalars, ints and 0-d arrays all dispatch identically."""
    a = rng.standard_normal((9, 4)).astype(np.float32)
    b = rng.standard_normal((17, 4)).astype(np.float32)
    v = rng.standard_normal((17,)).astype(np.float32)
    want = np.asarray(ops.kernel_matvec(a, b, v, sigma=2.0, backend="xla"))
    for sigma in (2, np.float64(2.0), np.float32(2.0), jnp.asarray(2.0),
                  jnp.bfloat16(2.0)):
        got = np.asarray(ops.kernel_matvec(a, b, v, sigma=sigma, backend="xla"))
        assert got.dtype == np.float32
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def _check_matvec_oracle(m, n, d, kern, seed):
    r = np.random.default_rng(seed)
    a = r.standard_normal((m, d)).astype(np.float32)
    b = r.standard_normal((n, d)).astype(np.float32)
    v = r.standard_normal((n, 1)).astype(np.float32)
    want = _dense(kern, a, b, 1.0) @ v
    got = np.asarray(
        ops.kernel_matvec(a, b, v, kernel=kern, sigma=1.0, backend="interpret")
    )
    np.testing.assert_allclose(got, want, **_tol(want, 3e-4, 3e-5))


def _check_kernel_matrix_invariants(seed, kern):
    """Symmetry for every kernel; family-specific diagonal/range invariants
    (only the distance kernels have unit diagonals and (0, 1] values — the
    dot-product family's diagonal follows ||x||)."""
    from repro.core.kernels import UNIT_DIAG_KERNELS, kernel_diag

    r = np.random.default_rng(seed)
    x = r.standard_normal((24, 6)).astype(np.float32)
    k = np.asarray(ops.kernel_block(x, x, kernel=kern, sigma=1.5, backend="xla"))
    np.testing.assert_allclose(k, k.T, atol=1e-5)
    np.testing.assert_allclose(
        np.diag(k), np.asarray(kernel_diag(kern, x, 1.5)),
        rtol=1e-4, atol=1e-5,
    )
    if kern in UNIT_DIAG_KERNELS:
        np.testing.assert_allclose(np.diag(k), 1.0, atol=1e-5)
    if kern in ("rbf", "laplacian", "matern52"):
        assert (k > 0).all() and (k <= 1 + 1e-5).all()
    if kern == "cosine":
        assert (np.abs(k) <= 1 + 1e-5).all()
    if kern == "sigmoid":
        assert (np.abs(k) <= 1 + 1e-6).all()  # tanh range


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 40),
        n=st.integers(1, 70),
        d=st.integers(1, 16),
        kern=st.sampled_from(KERNEL_NAMES),
        seed=st.integers(0, 2**16),
    )
    def test_property_matvec_matches_oracle(m, n, d, kern, seed):
        _check_matvec_oracle(m, n, d, kern, seed)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), kern=st.sampled_from(KERNEL_NAMES))
    def test_property_kernel_matrix_invariants(seed, kern):
        _check_kernel_matrix_invariants(seed, kern)

else:

    @pytest.mark.parametrize("kern", KERNEL_NAMES)
    @pytest.mark.parametrize("seed", range(5))
    def test_property_matvec_matches_oracle(kern, seed):
        r = np.random.default_rng(1000 + seed)
        m, n, d = (int(r.integers(1, 40)), int(r.integers(1, 70)),
                   int(r.integers(1, 16)))
        _check_matvec_oracle(m, n, d, kern, seed)

    @pytest.mark.parametrize("kern", KERNEL_NAMES)
    @pytest.mark.parametrize("seed", range(4))
    def test_property_kernel_matrix_invariants(kern, seed):
        _check_kernel_matrix_invariants(seed, kern)
