"""Kernel-zoo properties: the mathematical invariants each zoo member must
satisfy regardless of tile path, plus the precomputed-operator bit-identity
claim.  Uses hypothesis when available and a deterministic parametrized
sweep otherwise (same checks, fixed seeds), so the module always collects.
"""

import numpy as np
import pytest

from repro.core.kernels import (
    KERNEL_FAMILIES,
    KERNEL_NAMES,
    UNIT_DIAG_KERNELS,
    kernel_diag,
    kernel_matrix,
)
from repro.kernels import ops

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# sigmoid (tanh) is the textbook indefinite kernel — excluded from PSD
PSD_KERNELS = tuple(k for k in KERNEL_NAMES if k != "sigmoid")


def _x(seed, n=28, d=5):
    return np.random.default_rng(seed).standard_normal((n, d)).astype(np.float32)


def _tol(ref, rtol, atol):
    """Scale atol to the reference magnitude: the dot-family kernels produce
    O(10^2..10^4) entries, where a fixed atol sized for (0, 1]-range kernels
    only measures f32 cancellation noise (see tests/test_kernels_pallas.py)."""
    return dict(rtol=rtol, atol=atol * max(1.0, float(np.abs(ref).max())))


def _check_symmetry(kern, seed):
    x = _x(seed)
    k = np.asarray(kernel_matrix(kern, x, x, 1.3))
    np.testing.assert_allclose(k, k.T, **_tol(k, 0.0, 1e-5))


def _check_psd(kern, seed):
    x = _x(seed)
    k = np.asarray(kernel_matrix(kern, x, x, 1.3), dtype=np.float64)
    evals = np.linalg.eigvalsh((k + k.T) / 2)
    assert evals.min() >= -1e-4 * max(1.0, evals.max())


def _check_diag(kern, seed):
    x = _x(seed)
    k = np.asarray(kernel_matrix(kern, x, x, 0.9))
    want = np.asarray(kernel_diag(kern, x, 0.9))
    np.testing.assert_allclose(np.diag(k), want, **_tol(want, 1e-4, 1e-5))
    if kern in UNIT_DIAG_KERNELS:
        np.testing.assert_allclose(want, 1.0)


def _check_backend_parity(kern, seed):
    """xla streaming vs Pallas interpret tiles — same kernel, same numbers."""
    r = np.random.default_rng(seed)
    a = r.standard_normal((26, 7)).astype(np.float32)
    b = r.standard_normal((41, 7)).astype(np.float32)
    v = r.standard_normal((41, 2)).astype(np.float32)
    xla = np.asarray(
        ops.kernel_matvec(a, b, v, kernel=kern, sigma=1.1, backend="xla",
                          chunk_a=16, chunk_b=16)
    )
    interp = np.asarray(
        ops.kernel_matvec(a, b, v, kernel=kern, sigma=1.1, backend="interpret",
                          chunk_a=16, chunk_b=16)
    )
    np.testing.assert_allclose(interp, xla, **_tol(xla, 3e-4, 3e-5))


def _check_precomputed_bit_identity(kern, seed):
    """A PrecomputedKernelOperator over the materialized Gram must return
    exactly the stored entries — block access is a gather, not a recompute."""
    from repro.core.multikernel import make_operator

    x = _x(seed, n=24, d=4)
    k_mem = np.asarray(kernel_matrix(kern, x, x, 1.2))
    op = make_operator(x, kernel=kern, sigma=1.2, backend="xla")
    pre = make_operator(k_mem, kernel="precomputed")
    np.testing.assert_array_equal(np.asarray(pre.block(pre.x)), k_mem)
    np.testing.assert_array_equal(
        np.asarray(pre.block_idx(np.arange(5))), k_mem[:5, :5]
    )
    assert float(pre.trace_est()) == pytest.approx(float(np.trace(k_mem)), rel=1e-6)
    # matvec through the gather path agrees with the fused operator
    v = np.random.default_rng(seed + 1).standard_normal((24,)).astype(np.float32)
    got, ref = np.asarray(pre.matvec(v)), np.asarray(op.matvec(v))
    np.testing.assert_allclose(got, ref, **_tol(ref, 5e-5, 5e-5))


def test_zoo_registry_consistent():
    assert set(KERNEL_FAMILIES) == set(KERNEL_NAMES)
    assert set(UNIT_DIAG_KERNELS) <= set(KERNEL_NAMES)
    assert set(KERNEL_FAMILIES.values()) == {"l2", "l1", "dot", "cos"}


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(kern=st.sampled_from(KERNEL_NAMES), seed=st.integers(0, 2**16))
    def test_property_symmetry(kern, seed):
        _check_symmetry(kern, seed)

    @settings(max_examples=15, deadline=None)
    @given(kern=st.sampled_from(PSD_KERNELS), seed=st.integers(0, 2**16))
    def test_property_psd(kern, seed):
        _check_psd(kern, seed)

    @settings(max_examples=15, deadline=None)
    @given(kern=st.sampled_from(KERNEL_NAMES), seed=st.integers(0, 2**16))
    def test_property_diag(kern, seed):
        _check_diag(kern, seed)

    @settings(max_examples=10, deadline=None)
    @given(kern=st.sampled_from(KERNEL_NAMES), seed=st.integers(0, 2**16))
    def test_property_backend_parity(kern, seed):
        _check_backend_parity(kern, seed)

    @settings(max_examples=8, deadline=None)
    @given(kern=st.sampled_from(KERNEL_NAMES), seed=st.integers(0, 2**16))
    def test_property_precomputed_bit_identity(kern, seed):
        _check_precomputed_bit_identity(kern, seed)

else:

    @pytest.mark.parametrize("kern", KERNEL_NAMES)
    @pytest.mark.parametrize("seed", range(3))
    def test_property_symmetry(kern, seed):
        _check_symmetry(kern, seed)

    @pytest.mark.parametrize("kern", PSD_KERNELS)
    @pytest.mark.parametrize("seed", range(3))
    def test_property_psd(kern, seed):
        _check_psd(kern, seed)

    @pytest.mark.parametrize("kern", KERNEL_NAMES)
    @pytest.mark.parametrize("seed", range(3))
    def test_property_diag(kern, seed):
        _check_diag(kern, seed)

    @pytest.mark.parametrize("kern", KERNEL_NAMES)
    @pytest.mark.parametrize("seed", range(2))
    def test_property_backend_parity(kern, seed):
        _check_backend_parity(kern, seed)

    @pytest.mark.parametrize("kern", KERNEL_NAMES)
    @pytest.mark.parametrize("seed", range(2))
    def test_property_precomputed_bit_identity(kern, seed):
        _check_precomputed_bit_identity(kern, seed)
