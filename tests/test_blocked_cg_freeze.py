"""blocked_cg external-freeze contract: externally frozen columns stop
moving, surviving columns' trajectories are bit-identical to a solve without
the pruned columns, the all-frozen early-exit works, and an all-zero RHS
column is frozen at iteration 0 with rel_residual_per_head = 0 (no NaNs).

The bit-identity tests use a DIAGONAL operator so every per-column float
operation is elementwise — trajectories cannot be perturbed by matmul tiling
across a different column count, isolating the blocked-CG mechanics (which
is what the freeze hook must not disturb)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocked_cg import blocked_cg


def _diag_problem(n=32, t=4, seed=0):
    r = np.random.default_rng(seed)
    d = jnp.asarray(np.linspace(1.0, 10.0, n).astype(np.float32))
    rhs = jnp.asarray(r.standard_normal((n, t)).astype(np.float32))
    return (lambda v: d[:, None] * v), rhs, d


def test_externally_frozen_columns_stop_moving():
    matvec, rhs, _ = _diag_problem()
    snapshots = {}

    def cb(it, x, rel_heads, frozen):
        snapshots[it] = np.asarray(x).copy()
        if it == 3:
            m = np.zeros(rhs.shape[1], bool)
            m[1] = True
            return m
        return None

    res = blocked_cg(matvec, rhs, None, max_iters=30, tol=1e-12,
                     freeze_at=range(1, 31), freeze_callback=cb)
    assert res.frozen is not None and res.frozen[1] and not res.frozen[0]
    # column 1 holds its iteration-3 value in the final solution
    np.testing.assert_array_equal(np.asarray(res.x)[:, 1], snapshots[3][:, 1])
    # while unfrozen columns kept converging past it
    assert not np.array_equal(np.asarray(res.x)[:, 0], snapshots[3][:, 0])


def test_survivor_trajectories_bit_identical_without_pruned_columns():
    matvec, rhs, _ = _diag_problem(t=3)
    # reference: solve ONLY columns 0 and 2
    ref_traj = []

    def ref_cb(it, x, rel_heads, frozen):
        ref_traj.append(np.asarray(x).copy())
        return None

    ref = blocked_cg(matvec, rhs[:, [0, 2]], None, max_iters=12, tol=1e-30,
                     freeze_at=range(1, 13), freeze_callback=ref_cb)
    # full solve with column 1 externally frozen at the FIRST iteration
    full_traj = []

    def cb(it, x, rel_heads, frozen):
        full_traj.append(np.asarray(x).copy())
        if it == 1:
            return np.asarray([False, True, False])
        return None

    full = blocked_cg(matvec, rhs, None, max_iters=12, tol=1e-30,
                      freeze_at=range(1, 13), freeze_callback=cb)
    assert full.iters == ref.iters
    for got, want in zip(full_traj, ref_traj):
        np.testing.assert_array_equal(got[:, [0, 2]], want)
    np.testing.assert_array_equal(np.asarray(full.x)[:, [0, 2]],
                                  np.asarray(ref.x))


def test_all_columns_frozen_early_exit():
    matvec, rhs, _ = _diag_problem()

    def cb(it, x, rel_heads, frozen):
        if it == 2:
            return np.ones(rhs.shape[1], bool)
        return None

    res = blocked_cg(matvec, rhs, None, max_iters=50, tol=1e-30,
                     freeze_at=(2,), freeze_callback=cb)
    assert res.iters == 2  # exited at the freeze, not max_iters
    assert res.frozen is not None and res.frozen.all()
    assert not res.converged  # frozen != converged; the statement stays strict


def test_freeze_only_at_listed_rungs():
    matvec, rhs, _ = _diag_problem()
    calls = []

    def cb(it, x, rel_heads, frozen):
        calls.append(it)
        return None

    blocked_cg(matvec, rhs, None, max_iters=10, tol=1e-30,
               freeze_at=(3, 7), freeze_callback=cb)
    assert calls == [3, 7]


def test_zero_rhs_column_frozen_at_iteration_zero():
    matvec, rhs, _ = _diag_problem(t=3)
    rhs = rhs.at[:, 1].set(0.0)
    res = blocked_cg(matvec, rhs, None, max_iters=40, tol=1e-10)
    x = np.asarray(res.x)
    assert np.isfinite(x).all()
    np.testing.assert_array_equal(x[:, 1], 0.0)  # the exact solution
    assert res.frozen is not None and res.frozen[1]
    for h in res.history:
        heads = h["rel_residual_per_head"]
        assert heads[1] == 0.0 and np.isfinite(heads).all()
    assert res.converged  # the live columns still converge normally


def test_zero_rhs_column_with_warm_start_and_pinv():
    # a nonzero x0 in a zero-RHS column must be zeroed, not iterated on
    matvec, rhs, d = _diag_problem(t=2)
    rhs = rhs.at[:, 0].set(0.0)
    x0 = jnp.ones_like(rhs)
    pinv = lambda r: r / d[:, None]
    res = blocked_cg(matvec, rhs, pinv, x0=x0, max_iters=40, tol=1e-10)
    x = np.asarray(res.x)
    assert np.isfinite(x).all()
    np.testing.assert_array_equal(x[:, 0], 0.0)
    assert res.converged


def test_all_zero_rhs_returns_immediately():
    matvec, rhs, _ = _diag_problem()
    res = blocked_cg(matvec, jnp.zeros_like(rhs), None, max_iters=40, tol=1e-10)
    assert res.iters == 0 and res.converged
    np.testing.assert_array_equal(np.asarray(res.x), 0.0)
    assert res.frozen is not None and res.frozen.all()


def test_no_freeze_args_matches_legacy_behavior():
    # the default path (no freeze_at/callback, no zero columns) must be the
    # plain convergence-freezing loop: converged result, frozen is None
    matvec, rhs, _ = _diag_problem()
    res = blocked_cg(matvec, rhs, None, max_iters=100, tol=1e-10)
    assert res.converged and res.frozen is None
    d = np.linspace(1.0, 10.0, rhs.shape[0]).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(res.x), np.asarray(rhs) / d[:, None], rtol=1e-5, atol=1e-6
    )


def test_kernel_operator_freeze_smoke():
    # the same hook through a REAL kernel matvec (allclose, not bitwise —
    # matmul tiling may differ): frozen column holds, survivors converge
    from repro.core.operator import KernelOperator

    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((48, 3)).astype(np.float32))
    op = KernelOperator(x=x, kernel="rbf", sigma=1.0, backend="xla")
    rhs = jnp.asarray(r.standard_normal((48, 3)).astype(np.float32))
    lam = 0.1

    def matvec(v):
        return op.matvec(v) + lam * v

    frozen_snap = {}

    def cb(it, xk, rel, frozen):
        if it == 2:
            frozen_snap["x"] = np.asarray(xk).copy()
            return np.asarray([False, False, True])
        return None

    res = blocked_cg(matvec, rhs, None, max_iters=200, tol=1e-8,
                     freeze_at=(2,), freeze_callback=cb)
    np.testing.assert_array_equal(np.asarray(res.x)[:, 2], frozen_snap["x"][:, 2])
    ref = blocked_cg(matvec, rhs[:, :2], None, max_iters=200, tol=1e-8)
    np.testing.assert_allclose(np.asarray(res.x)[:, :2], np.asarray(ref.x),
                               rtol=1e-5, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
