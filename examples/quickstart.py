"""Quickstart: solve a full KRR problem with ASkotch in ~20 lines, then a
10-class one-vs-all problem as ONE multi-RHS solve.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import ASkotchConfig, KRRProblem, evaluate, solve
from repro.data import synthetic

# 1. data (any (n, d) features + (n,) targets work)
x_train, y_train, x_test, y_test = synthetic.krr_regression(seed=0, n=5000, d=8,
                                                            n_test=1000)

# 2. the full-KRR problem: (K + lam I) w = y, K never materialized
problem = KRRProblem(x=x_train, y=y_train, kernel="rbf", sigma=1.5,
                     lam_unscaled=1e-6)

# 3. ASkotch with the paper's default hyperparameters (b = n/100, r = 100,
#    damped rho, uniform sampling, Nesterov acceleration)
result = solve(problem, ASkotchConfig(), max_iters=300, eval_every=100)

# 4. predict + evaluate
metrics = evaluate(problem.predict(result.w, x_test), y_test)
print(f"relative residual: {result.history[-1]['rel_residual']:.3e}")
print(f"test RMSE: {float(metrics.rmse):.4f}  (target std: "
      f"{float(jnp.std(y_test)):.4f})")

# 5. one-vs-all classification: y is (n, t) and ALL t heads ride one solve —
#    the block sample, preconditioner, and fused kernel tiles are shared, so
#    this costs roughly one solve, not t (see benchmarks/bench_multirhs.py)
x_tr, y_tr, _, x_te, _, labels_te = synthetic.krr_one_vs_all(
    seed=0, n=4000, d=8, num_classes=10, n_test=1000)
ova = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.5, lam_unscaled=1e-5)
res = solve(ova, ASkotchConfig(), max_iters=200, eval_every=100)
scores = ova.predict(res.w, x_te)  # (1000, 10)
top1 = float(jnp.mean(jnp.argmax(scores, axis=1) == labels_te))
worst_head = max(res.history[-1]["rel_residual_per_head"])
print(f"one-vs-all: top-1 acc {top1:.3f}, worst-head residual {worst_head:.2e}")
