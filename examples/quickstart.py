"""Quickstart: solve a full KRR problem with ASkotch in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import ASkotchConfig, KRRProblem, evaluate, solve
from repro.data import synthetic

# 1. data (any (n, d) features + (n,) targets work)
x_train, y_train, x_test, y_test = synthetic.krr_regression(seed=0, n=5000, d=8,
                                                            n_test=1000)

# 2. the full-KRR problem: (K + lam I) w = y, K never materialized
problem = KRRProblem(x=x_train, y=y_train, kernel="rbf", sigma=1.5,
                     lam_unscaled=1e-6)

# 3. ASkotch with the paper's default hyperparameters (b = n/100, r = 100,
#    damped rho, uniform sampling, Nesterov acceleration)
result = solve(problem, ASkotchConfig(), max_iters=300, eval_every=100)

# 4. predict + evaluate
metrics = evaluate(problem.predict(result.w, x_test), y_test)
print(f"relative residual: {result.history[-1]['rel_residual']:.3e}")
print(f"test RMSE: {float(metrics.rmse):.4f}  (target std: "
      f"{float(jnp.std(y_test)):.4f})")
