"""Serve a small model with batched requests: prefill once per batch, then
greedy decode — the serving path the decode_32k/long_500k dry-run cells lower.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b \
        --batch 4 --prompt-len 48 --max-new 24
"""

import argparse
import time

import jax

from repro.configs.base import get_reduced_config
from repro.data import synthetic
from repro.models.model_api import init_params
from repro.serving.serve_step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    requests = synthetic.batch_for(cfg, (args.batch, args.prompt_len), 0, 0)
    requests.pop("labels", None)

    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, requests, args.max_new)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.max_new}")
    print(f"throughput: {args.batch * args.max_new / dt:.1f} tok/s "
          f"(CPU, reduced config)")
    for i in range(min(args.batch, 2)):
        print(f"  seq{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
