"""End-to-end hyperparameter tuning: tune -> refit -> serve.

    PYTHONPATH=src python examples/krr_tune.py [--n 4000 --classes 4]

A synthetic one-vs-all classification task goes through the whole production
path (docs/tuning.md): the tile-sharing (sigma, lam) sweep with k-fold CV
picks the config, the winner is refit on the full training set with one
multi-RHS ASkotch solve, and the exported best-config dict drives the batched
serving closure — the same three calls a real deployment makes.
"""

import argparse

import numpy as np

from repro.core import KRRProblem, apply_best, evaluate, solve_any, tune
from repro.data import synthetic
from repro.serving.krr_serve import make_krr_predict_fn_from_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4_000)
    ap.add_argument("--d", type=int, default=6)
    ap.add_argument("--n-test", type=int, default=500)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=200)
    args = ap.parse_args()

    x_tr, y_tr, _, x_te, y_te, labels_te = synthetic.krr_one_vs_all(
        0, args.n, args.d, num_classes=args.classes, n_test=args.n_test
    )
    prob = KRRProblem(x=x_tr, y=y_tr, backend="xla")

    # 1. tune: all (sigma, lam) candidates x folds x heads share kernel tiles
    result = tune(
        prob, sigmas=(0.5, 1.0, 2.0), lams=(1e-4, 1e-2), folds=3,
        rank=min(64, args.n // 4), max_iters=args.iters, tol=1e-4,
    )
    print(f"best config: {result.best}")
    print(f"kernel sweeps: {result.sweeps:.1f} "
          f"(naive loop estimate: {result.info['naive_sweep_estimate']:.0f})")

    # 2. refit the winner on ALL training rows — one multi-RHS solve
    out = solve_any(apply_best(prob, result), "askotch", max_iters=args.iters)

    # 3. serve from the exported config (what --export hands a deployment)
    predict = make_krr_predict_fn_from_config(result.best, x_tr, out.w)
    scores = np.asarray(predict(x_te))
    m = evaluate(scores, y_te)
    top1 = float(np.mean(scores.argmax(axis=1) == np.asarray(labels_te)))
    print(f"serve: test top-1 acc {top1:.3f} (rmse {float(m.rmse):.3f}) "
          f"over {args.classes} one-vs-all heads")


if __name__ == "__main__":
    main()
