"""End-to-end driver for the paper's workload: large-scale full KRR solve
with checkpointing, solver comparison, and final test metrics.

    PYTHONPATH=src python examples/krr_end_to_end.py [--n 50000]

This is the CPU-scale rendition of the paper's §6.2 taxi showcase: a
taxi-flavored dataset, the paper's default hyperparameters, a wall-clock
budget shared across solvers, and ASkotch checkpoint/restart mid-solve
(the solver state is just (w, v, z, key) — restart is exact).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.core import ASkotchConfig, KRRProblem, evaluate, solve_any
from repro.core.askotch import init_state, make_step
from repro.data import synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--budget-s", type=float, default=60.0)
    ap.add_argument("--ckpt", default="/tmp/krr_ckpt")
    args = ap.parse_args()

    n = args.n
    x, y = synthetic.taxi_like(0, n + 5000, 9)
    x_tr, y_tr, x_te, y_te = x[:n], y[:n], x[n:], y[n:]
    prob = KRRProblem(x=x_tr, y=y_tr, kernel="rbf", sigma=1.0,
                      lam_unscaled=2e-7, backend="xla")

    # --- ASkotch with mid-solve checkpoint/restart -------------------------
    cfg = ASkotchConfig(backend="xla")
    step = jax.jit(make_step(prob, cfg))
    state = init_state(prob)
    t0 = time.perf_counter()
    it = 0
    while time.perf_counter() - t0 < args.budget_s / 2:
        state, _ = step(state)
        it += 1
        if it % 100 == 0:
            checkpointer.save(args.ckpt, it, {"w": state.w, "v": state.v,
                                              "z": state.z, "key": state.key})
    # simulate a restart: reload the latest checkpoint and keep solving
    if checkpointer.latest_step(args.ckpt):
        saved, _, it = checkpointer.restore(args.ckpt)
        state = state._replace(
            w=jnp.asarray(saved["w"]), v=jnp.asarray(saved["v"]),
            z=jnp.asarray(saved["z"]), key=jnp.asarray(saved["key"]),
        )
        print(f"[restart] resumed at iteration {it}")
    while time.perf_counter() - t0 < args.budget_s:
        state, _ = step(state)
        it += 1
    rel = float(prob.relative_residual(state.w))
    m = evaluate(prob.predict(state.w, x_te), y_te)
    print(f"askotch: iters={it} rel_res={rel:.3e} test_rmse={float(m.rmse):.2f}")

    # --- the comparison the paper runs (equal budget) -----------------------
    for method, kw in (
        ("falkon", dict(m=min(1000, n // 20), max_iters=10_000,
                        time_budget_s=args.budget_s)),
        ("pcg-nystrom", dict(rank=100, max_iters=10_000,
                             time_budget_s=args.budget_s)),
    ):
        out = solve_any(prob, method, **kw)
        mm = evaluate(out.predict_fn(x_te), y_te)
        print(f"{method}: iters={out.info.get('iters')} "
              f"test_rmse={float(mm.rmse):.2f}")

    print(f"const-baseline rmse: {float(jnp.std(y_te)):.2f}")


if __name__ == "__main__":
    main()
