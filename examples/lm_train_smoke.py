"""Train a small LM for a few hundred steps with the full training substrate
(any --arch; reduced configs by default so it runs on CPU in minutes).

    PYTHONPATH=src python examples/lm_train_smoke.py --arch qwen2-1.5b \
        --steps 300 --batch 8 --seq 64
"""

import argparse
import sys

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    ns = argparse.Namespace(
        arch=args.arch, reduced=True, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=3e-3, seed=0, ckpt_dir="/tmp/lm_smoke_ckpt",
        ckpt_every=100, log_every=20, resume=False, inject_failure=-1,
        straggler_factor=3.0,
    )
    res = train_mod.run(ns)
    losses = [r["loss"] for r in res["history"]]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")
    if losses[-1] >= losses[0]:
        sys.exit("loss did not improve")


if __name__ == "__main__":
    main()
